"""Render EXPERIMENTS.md §Roofline tables from dryrun_results.json.

  PYTHONPATH=src python -m benchmarks.roofline_report dryrun_results.json
"""
from __future__ import annotations

import json
import sys


def _fix(r: dict) -> dict:
    return r


_ADVICE = {
    "compute": "raise MXU utilization: cut remat recompute / skip masked "
               "attention tiles (causal block skipping)",
    "memory": "cut HBM traffic: fuse residual+norm, larger attention tiles, "
              "bf16 loss accumulation, weight-stationary decode batching",
    "collective": "shrink wire bytes: compressed grad all-reduce, overlap "
                  "reduce-scatter with backward, 2D-shard the vocab matmul",
}


def render(results, mesh_filter="16x16"):
    rows = [r for r in results
            if r.get("status") == "ok" and r.get("mesh") == mesh_filter]
    skips = [r for r in results
             if r.get("status") == "skipped" and r.get("mesh") == mesh_filter]
    out = []
    if rows and "t_compute" not in rows[0]:
        # multi-pod pass: compile + fits proof only (roofline is single-pod)
        out.append("| arch | shape | compile (s) | bytes/device | status |")
        out.append("|---|---|---|---|---|")
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
            gb = r.get("bytes_per_device", -1) / 1e9
            out.append(f"| {r['arch']} | {r['shape']} | {r['compile_s']} | "
                       f"{gb:.2f} GB | compiled |")
        for r in sorted(skips, key=lambda r: (r["arch"], r["shape"])):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | "
                       f"{r['reason']} |")
        return "\n".join(out)
    out.append("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
               "bottleneck | MODEL/HLO flops | roofline frac | next lever |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3e} | "
            f"{r['t_memory']:.3e} | {r['t_collective']:.3e} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {_ADVICE[r['bottleneck']]} |")
    for r in sorted(skips, key=lambda r: (r["arch"], r["shape"])):
        out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — "
                   f"| — | {r['reason']} |")
    return "\n".join(out)


def main():
    results = json.load(open(sys.argv[1]))
    print("### Single-pod mesh 16x16 (256 chips)\n")
    print(render(results, "16x16"))
    print("\n### Multi-pod mesh 2x16x16 (512 chips)\n")
    print(render(results, "2x16x16"))


if __name__ == "__main__":
    main()
