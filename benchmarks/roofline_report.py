"""Codec-kernel roofline report from ``BENCH_decode.json``.

The codec kernels are memory-bound: a few integer/fma ops per element
against streaming plane words, negabinary states, and f64 residuals.  The
meaningful roofline axis is therefore BYTES PER SECOND, not flops —
``kernels.dispatch`` meters the HBM bytes every wrapper moves per launch
(``measure_bytes``), ``benchmarks/backend_speed.py`` records them next to
the wall-clock of each decode op, and this report divides the two:

    achieved bytes/s per kernel  vs  the substrate's peak bandwidth

Interpret-mode CPU numbers are tiny fractions of any roofline — that is
expected and still useful as a *trend* (a regression that doubles bytes
moved per launch shows up regardless of the substrate).  On compiled
TPU/XLA runs the fraction becomes the real utilization figure.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline_report BENCH_decode.json \
      [--peak-gbs 819]

``--peak-gbs`` sets the roofline (defaults to TPU v5e HBM, 819 GB/s; pass
your host's STREAM number for CPU runs).
"""
from __future__ import annotations

import argparse
import json


def kernel_rows(records):
    """Aggregate per-kernel (dispatches, bytes, seconds) over every record
    that carries the ``kernel_bytes`` meter.

    A record's wall-clock covers all its kernels, so per-kernel seconds
    attribute the op's time proportionally to bytes moved — exact enough
    for a bandwidth trend, and it keeps the report free of per-launch
    timers the wrappers do not have.
    """
    agg: dict = {}
    for r in records:
        kb = r.get("kernel_bytes")
        if not kb:
            continue
        total_b = sum(kb.values()) or 1
        for k, nb in kb.items():
            disp = r.get("dispatches_by_kernel", {}).get(k, 0)
            a = agg.setdefault(k, dict(dispatches=0, nbytes=0, seconds=0.0))
            a["dispatches"] += disp
            a["nbytes"] += nb
            a["seconds"] += r["seconds"] * (nb / total_b)
    return agg


def render(results: dict, peak_gbs: float) -> str:
    agg = kernel_rows(results.get("records", []))
    out = [f"### Codec kernel roofline (peak {peak_gbs:.0f} GB/s)", ""]
    out.append("| kernel | dispatches | bytes moved | bytes/launch | "
               "achieved GB/s | roofline frac |")
    out.append("|---|---|---|---|---|---|")
    for k in sorted(agg, key=lambda k: -agg[k]["nbytes"]):
        a = agg[k]
        per_launch = a["nbytes"] / max(a["dispatches"], 1)
        gbs = a["nbytes"] / max(a["seconds"], 1e-12) / 1e9
        out.append(f"| {k} | {a['dispatches']} | {a['nbytes'] / 1e6:.1f} MB "
                   f"| {per_launch / 1e3:.1f} kB | {gbs:.3f} | "
                   f"{gbs / peak_gbs:.5f} |")
    if len(out) == 4:
        out.append("| (no kernel_bytes records — rerun "
                   "benchmarks.backend_speed) | — | — | — | — | — |")
    out.append("")
    fused = [r for r in results.get("records", [])
             if r.get("case") == "fused_decode"]
    if fused:
        out.append("### Fused vs unfused decode (2^20 case)")
        out.append("")
        out.append("| backend | op | MB/s | dispatches | launches/level |")
        out.append("|---|---|---|---|---|")
        for r in fused:
            out.append(f"| {r['backend']} | {r['op']} | {r['mbps']:.1f} | "
                       f"{r['dispatches']} | "
                       f"{r.get('dispatches_per_level', 0):.1f} |")
    return "\n".join(out)


def main():
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("bench_json", help="BENCH_decode.json from "
                   "benchmarks.backend_speed")
    p.add_argument("--peak-gbs", type=float, default=819.0,
                   help="roofline bandwidth in GB/s (default: TPU v5e HBM)")
    args = p.parse_args()
    with open(args.bench_json) as f:
        results = json.load(f)
    print(render(results, args.peak_gbs))


if __name__ == "__main__":
    main()
