"""Serving-tier load generator: the paper's many-readers workload.

Drives the continuous-batching :class:`repro.serving.RetrievalServer`
with a >=16-request mixed-fidelity workload (coarse previews, tight
bounds, byte budgets, bitrates, full reads, refine chains) over several
archives, in three execution modes:

* ``percall``   — no coalescing, no cache: every request is planned and
  decoded as its own group (the per-request baseline);
* ``coalesced`` — cross-request coalescing: same-shape chunk jobs from
  different requests share one batched kernel launch per scheduler tick;
* ``cached``    — coalescing plus the shared :class:`PlaneCache`:
  requests reuse each other's decoded plane prefixes.

Recorded per mode: wall time, requests/sec, p50/p99 request latency,
backend-primitive dispatch counts (``decode_level`` / ``reconstruct`` /
``dedup_reuse`` from the server's counters — backend-independent), the
Pallas launch counts from ``repro.kernels.dispatch``, and cache
hit/miss/byte accounting.  Claim checks pin the serving wins: nonzero
cache-hit rate with byte accounting, strictly fewer dispatches coalesced
than per-call, and every served reconstruction bit-identical to a
private uncached session at the same fidelity (refine chains compared
against a private session walking the same ladder).  Results go to
``BENCH_serve.json`` (a CI artifact).

A fourth section benchmarks the storage layout itself: the same refine
ladder over IPC2 (chunk-major) and IPC3 (plane-major) archives of one
array, with every byte-range request logged through a
:class:`~repro.core.bytesource.CountingSource`.  Claim checks pin the
v3 layout win — monotone, single-run contiguous reads, strictly fewer
coalesced ranges and less seek distance than v2.

A fifth section runs that refine ladder over real loopback HTTP through
:class:`~repro.core.remote.HTTPSource` against the test suite's
in-process range server — once clean, once with a dropped GET — pinning
bit parity with a local session, one coalesced data run on the wire,
and retry-path recovery.

CPU caveat (same as ``backend_speed``): off-TPU the jax backend runs
Pallas in interpret mode, so wall-clock favors numpy and the dispatch /
cache counters are the trendable metrics.

  PYTHONPATH=src python -m benchmarks.serve_bench [--requests 18]
      [--backend jax] [--json-out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from .common import csv_row
from repro import Archive, Codec, ExecPolicy, Fidelity
from repro.core.bytesource import CountingSource
from repro.kernels import dispatch
from repro.serving import PlaneCache, RetrievalServer

JSON_OUT = "BENCH_serve.json"
CACHE_BYTES = 32 << 20


def _archives():
    """Three small archives spanning the container shapes the scheduler
    handles: a v2 uneven chunk grid, a v3 plane-major even grid, and a
    v1 single slab."""
    rng = np.random.default_rng(11)
    fields = {
        "turb": np.cumsum(rng.standard_normal((96, 96)), axis=0) / 10.0,
        "wave": (np.sin(np.linspace(0, 9, 64 * 64)).reshape(64, 64)
                 * 3.0),
        "blob": np.exp(-((np.mgrid[0:64, 0:64] - 32) ** 2
                         ).sum(0) / 300.0),
    }
    codecs = {
        "turb": Codec(eb=1e-5, chunk_elems=2048),
        "wave": Codec(eb=1e-5, chunk_elems=1024, version=3),
        "blob": Codec(eb=1e-5),              # v1: single slab
    }
    return {name: codecs[name].compress(x) for name, x in fields.items()}


def _workload(n_requests: int):
    """The mixed-fidelity request mix, as (archive_id, Fidelity, chain)
    tuples; ``chain`` marks a refine riding on the previous request for
    the same archive.  Cycled to ``n_requests`` entries."""
    base = [
        ("turb", Fidelity.error_bound(1e-2), False),
        ("turb", Fidelity.error_bound(1e-2), False),   # duplicate consumer
        ("turb", Fidelity.error_bound(1e-4), False),
        ("turb", Fidelity.full(), True),               # refine the preview
        ("wave", Fidelity.error_bound(1e-2), False),
        ("wave", Fidelity.bitrate(4.0), False),
        ("wave", Fidelity.full(), False),
        ("blob", Fidelity.error_bound(1e-3), False),
        ("blob", Fidelity.max_bytes(3000), False),
        ("blob", Fidelity.full(), True),               # refine the budget read
        ("turb", Fidelity.bitrate(6.0), False),
        ("wave", Fidelity.error_bound(1e-2), False),   # duplicate consumer
    ]
    return [base[i % len(base)] for i in range(n_requests)]


def _submit_all(server, workload):
    """Queue the workload; refine chains attach to the latest earlier
    request for the same archive."""
    reqs, last = [], {}
    for archive_id, fid, chain in workload:
        parent = last.get(archive_id) if chain else None
        req = server.submit(archive_id, fid, refine_of=parent)
        last[archive_id] = req
        reqs.append(req)
    return reqs


def _reference_bits(archives, workload):
    """Private uncached numpy sessions, one per request; refine chains
    walk the same ladder inside one session."""
    outs, last_session = [], {}
    for archive_id, fid, chain in workload:
        if chain and archive_id in last_session:
            session = last_session[archive_id]
        else:
            session = archives[archive_id].open()
        outs.append(session.read(fid))
        last_session[archive_id] = session
    return outs


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q))


def _run_mode(mode, archives, workload, policy):
    cache = PlaneCache(max_bytes=CACHE_BYTES) if mode == "cached" else None
    server = RetrievalServer(policy=policy, cache=cache,
                             coalesce=mode != "percall")
    for name, arc in archives.items():
        server.add_archive(name, arc)
    reqs = _submit_all(server, workload)
    with dispatch.measure() as launches:
        t0 = time.perf_counter()
        server.drain()
        dt = time.perf_counter() - t0
    assert all(r.status == "done" for r in reqs), \
        [(r.req_id, r.error) for r in reqs if r.status != "done"]
    lat = [r.latency_s for r in reqs]
    record = dict(
        mode=mode, requests=len(reqs), seconds=dt,
        req_per_s=len(reqs) / dt, ticks=server.ticks,
        p50_latency_s=_percentile(lat, 50),
        p99_latency_s=_percentile(lat, 99),
        counters=dict(server.counters),
        primitive_dispatches=sum(v for k, v in server.counters.items()
                                 if k != "dedup_reuse"),
        pallas_launches=sum(launches.values()),
        bytes_read=[int(r.bytes_read) for r in reqs],
    )
    if cache is not None:
        record["cache"] = cache.stats()
    return record, [r.result for r in reqs]


LAYOUT_LADDER = [1e-2, 1e-3, 1e-4, 1e-5]


def _layout_bench():
    """IPC3 plane-major layout vs IPC2 chunk-major, as the storage tier
    sees it: the same refine ladder over the same array, with every
    byte-range request logged by a :class:`CountingSource`.  Recorded per
    version: request count, coalesced run count, and total backward /
    gap seek distance over the data section.  The claim is the format's
    reason to exist — the v3 ladder reads strictly fewer contiguous
    ranges (one run, monotone) than v2's per-chunk scatter."""
    rng = np.random.default_rng(23)
    x = np.cumsum(rng.standard_normal((96, 96)), axis=0) / 10.0
    fids = [Fidelity.error_bound(E) for E in LAYOUT_LADDER]
    record, outs = {}, {}
    for name, codec in (
            ("v2", Codec(eb=1e-5, chunk_elems=2048)),
            ("v3", Codec(eb=1e-5, chunk_elems=2048, version=3))):
        arc = codec.compress(x)
        cs = CountingSource(arc.tobytes())
        session = Archive.from_source(cs).open()
        for f in fids:
            out = session.read(f)
        outs[name] = out
        header_end = arc._meta.header_end
        data = [r for r in cs.requests if r[0] >= header_end]
        runs = CountingSource(b"")
        runs.requests = data
        record[name] = dict(
            archive_bytes=arc.nbytes, session_bytes_read=session.bytes_read,
            data_requests=len(data), coalesced_runs=len(runs.coalesced()),
            monotone=runs.monotone(), seek_distance=runs.seek_distance)
    checks = [
        ("serve_v3_monotone_contiguous", "ladder", "layout",
         record["v3"]["monotone"] and record["v3"]["coalesced_runs"] == 1),
        ("serve_v3_fewer_ranges", "ladder", "layout",
         record["v3"]["coalesced_runs"] < record["v2"]["coalesced_runs"]
         and record["v3"]["seek_distance"] < record["v2"]["seek_distance"]),
        ("serve_v3_ladder_bits_bounded", "ladder", "layout",
         float(np.abs(outs["v3"] - x).max()) <= LAYOUT_LADDER[-1]
         and float(np.abs(outs["v2"] - x).max()) <= LAYOUT_LADDER[-1]),
    ]
    row = csv_row(
        "serve/layout/v3_vs_v2", 0.0,
        f"v2_runs={record['v2']['coalesced_runs']};"
        f"v3_runs={record['v3']['coalesced_runs']};"
        f"v2_seek={record['v2']['seek_distance']};"
        f"v3_seek={record['v3']['seek_distance']}")
    return record, checks, row


REMOTE_LADDER = [1e-2, 1e-3, 1e-4, 1e-5]


def _remote_bench():
    """The same refine ladder pulled over real (loopback) HTTP through
    :class:`~repro.core.remote.HTTPSource`, against the in-process range
    server the network test suites use.  Two passes over one v3 archive:
    a clean server, and one that drops a connection mid-ladder so the
    retry/backoff path is on the measured path.  Recorded: wall time,
    GET counts, wire bytes vs archive bytes, and retry counts.  Claim
    checks pin the remote story — bit parity with a local BufferSource
    session, one coalesced data run over the wire, and fault recovery
    with a nonzero retry count."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tests"))
    from range_server import RangeHTTPServer, ServerFault

    from repro.core.remote import HTTPSource

    rng = np.random.default_rng(23)
    x = np.cumsum(rng.standard_normal((96, 96)), axis=0) / 10.0
    arc = Codec(eb=1e-5, chunk_elems=2048, version=3).compress(x)
    buf = arc.tobytes()
    header_end = int(arc._meta.header_end)
    fids = [Fidelity.error_bound(E) for E in REMOTE_LADDER]
    local = Archive.frombytes(buf).open()
    reference = [local.read(f) for f in fids]

    record = {}
    outs = {}
    for name, faults in (
            ("clean", None),
            ("faulted", [ServerFault("drop", at=3)])):
        srv = RangeHTTPServer(buf, faults=faults)
        try:
            src = HTTPSource(srv.url, timeout=10.0, backoff=0.01)
            session = Archive.from_source(src).open()
            t0 = time.perf_counter()
            for f in fids:
                out = session.read(f)
            dt = time.perf_counter() - t0
            outs[name] = out
            data = [r for r in src.requests if r[0] >= header_end]
            runs = CountingSource(b"")
            runs.requests = data
            record[name] = dict(
                seconds=dt, archive_bytes=len(buf),
                session_bytes_read=session.bytes_read,
                gets=srv.n_gets, retries=src.retry_count,
                wire_bytes=src.wire_bytes,
                data_coalesced_runs=len(runs.coalesced()),
                monotone=runs.monotone())
            src.close()
        finally:
            srv.stop()
    checks = [
        ("serve_remote_bits_match_local", "ladder", "remote",
         all(np.array_equal(outs[n], reference[-1]) for n in outs)),
        ("serve_remote_one_data_run", "ladder", "remote",
         record["clean"]["data_coalesced_runs"] == 1
         and record["clean"]["monotone"]),
        ("serve_remote_fault_recovered", "ladder", "remote",
         record["faulted"]["retries"] > 0),
        # no data byte crosses the wire twice: wire volume is bounded by
        # the framing/header region plus the bytes the session planned
        ("serve_remote_no_refetch", "ladder", "remote",
         record["clean"]["wire_bytes"]
         <= header_end + record["clean"]["session_bytes_read"] + 16),
    ]
    row = csv_row(
        "serve/remote/http_ladder", record["clean"]["seconds"] * 1e6,
        f"gets={record['clean']['gets']};"
        f"wire={record['clean']['wire_bytes']};"
        f"faulted_retries={record['faulted']['retries']}")
    return record, checks, row


def run(scale=None, n_requests: int = 18, backend: str = "jax",
        json_out: str = JSON_OUT):
    if n_requests < 16:
        raise SystemExit(f"--requests must be >= 16, got {n_requests}")
    archives = _archives()
    workload = _workload(n_requests)
    policy = ExecPolicy(backend=backend)
    rows, checks, records = [], [], []
    reference = _reference_bits(archives, workload)

    results = {}
    for mode in ("percall", "coalesced", "cached"):
        record, outs = _run_mode(mode, archives, workload, policy)
        records.append(record)
        results[mode] = outs
        derived = (f"req_per_s={record['req_per_s']:.1f};"
                   f"p50={record['p50_latency_s'] * 1e3:.1f}ms;"
                   f"p99={record['p99_latency_s'] * 1e3:.1f}ms;"
                   f"dispatches={record['primitive_dispatches']}")
        if "cache" in record:
            derived += (f";hit_rate={record['cache']['hit_rate']:.2f};"
                        f"fetch_saved={record['cache']['fetch_bytes_saved']}")
        rows.append(csv_row(f"serve/{n_requests}req/{mode}",
                            record["seconds"] * 1e6, derived))
        print(rows[-1])

    # (c) served bits == private uncached per-session bits, every mode
    for mode, outs in results.items():
        ok = all(np.array_equal(a, b) for a, b in zip(outs, reference))
        checks.append((f"serve_bits_match_sessions_{mode}",
                       f"{n_requests}req", "serve", ok))
    # (b) coalescing strictly reduces dispatch counts vs per-request
    percall, coalesced, cached = records
    checks.append(("serve_coalesce_fewer_dispatches", f"{n_requests}req",
                   "serve", coalesced["primitive_dispatches"]
                   < percall["primitive_dispatches"]))
    # (a) the shared cache sees real reuse, with byte accounting
    cstats = cached["cache"]
    checks.append(("serve_cache_hits", f"{n_requests}req", "serve",
                   cstats["hits"] > 0 and cstats["hit_rate"] > 0))
    checks.append(("serve_cache_byte_accounting", f"{n_requests}req",
                   "serve", cstats["bytes_cached"] > 0
                   and cstats["hit_bytes"] > 0))
    # (d) IPC3 plane-major layout: strictly fewer, monotone, contiguous
    # byte ranges than v2 for the same refine ladder
    layout_record, layout_checks, layout_row = _layout_bench()
    checks.extend(layout_checks)
    rows.append(layout_row)
    print(layout_row)
    # (e) the same ladder over real loopback HTTP: bit parity, one range
    # per rung on the wire, and the retry path survives a dropped GET
    remote_record, remote_checks, remote_row = _remote_bench()
    checks.extend(remote_checks)
    rows.append(remote_row)
    print(remote_row)

    if json_out:
        with open(json_out, "w") as f:
            json.dump(dict(
                requests=n_requests, backend=backend,
                cache_max_bytes=CACHE_BYTES,
                workload=[(a, repr(f), c) for a, f, c in workload],
                records=records, layout=layout_record,
                remote=remote_record,
                checks=[dict(name=c[0], case=c[1], op=c[2], ok=bool(c[3]))
                        for c in checks]), f, indent=2)
        print(f"wrote {json_out} ({len(records)} mode records)")
    return rows, checks


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=18,
                    help="workload size (>= 16)")
    ap.add_argument("--backend", default="jax",
                    choices=["numpy", "jax"],
                    help="server ExecPolicy backend")
    ap.add_argument("--json-out", default=JSON_OUT,
                    help="JSON artifact path ('' disables)")
    args = ap.parse_args()
    _, checks = run(n_requests=args.requests, backend=args.backend,
                    json_out=args.json_out)
    for name, ds, op, ok in checks:
        print(f"check {name}[{ds}/{op}]: {'ok' if ok else 'FAILED'}")
    if not all(c[-1] for c in checks):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
