"""Fig. 6: retrieval volume (bitrate) vs requested error bound.

Paper claim: IPComp needs the smallest data volume to reach a given L_inf
(up to 83% less), supports arbitrary bounds, and does it in a single pass.
"""
from __future__ import annotations

import numpy as np

from .common import csv_row, datasets, progressive_compressors, timed
from repro.core import metrics


BOUNDS_REL = [1e-2, 1e-3, 1e-4, 1e-5, 1e-6]


def run(scale=None):
    rows, checks = [], []
    for name, x in datasets(scale).items():
        rng = float(x.max() - x.min())
        eb = 1e-7 * rng
        blobs = {}
        for comp in progressive_compressors():
            blobs[comp.name] = comp.compress(x, eb)
        for rel in BOUNDS_REL:
            E = rel * rng
            vols = {}
            for comp in progressive_compressors():
                (out, bytes_read, passes), dt = timed(
                    comp.retrieve, blobs[comp.name], error_bound=E)
                err = metrics.linf(x, out)
                bpp = 8.0 * bytes_read / x.size
                vols[comp.name] = bpp
                ok = err <= E * (1 + 1e-9)
                rows.append(csv_row(
                    f"fig6/{name}/E{rel:.0e}/{comp.name}", dt * 1e6,
                    f"bpp={bpp:.3f};linf={err:.3e};passes={passes};ok={ok}"))
                checks.append(("error_bound_respected", name,
                               f"{comp.name}@{rel}", ok))
            others = [v for k, v in vols.items() if k != "ipcomp"]
            checks.append(("ipcomp_lowest_volume", name, rel,
                           vols["ipcomp"] <= min(others) * 1.35))
    return rows, checks
