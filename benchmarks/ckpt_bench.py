"""Checkpoint subsystem benchmark: coarse-first restart economics.

Builds a model-shaped IPCB bundle (transformer-ish smooth leaves + raw
norms) and measures the save/restore paths end to end, writing the
trendable artifact ``BENCH_ckpt.json``.  The claim checks gate the
subsystem's load-bearing promises:

* ``ckpt_coarse_byte_fraction``   — a coarse restore at the benchmark
  ``weight_error`` reads <= 35% of the bytes a full restore reads;
* ``ckpt_refine_never_rereads``   — refining coarse -> full fetches
  exactly the missing plane segments (session ``bytes_read`` delta ==
  ladder-prefix byte delta), and repeating a round reads zero;
* ``ckpt_remote_bit_identical``   — the same session over HTTP range
  requests, WITH one injected transient fault (a dropped GET mid-
  ladder), restores bit-identically to the local FileSource session;
* ``ckpt_parallel_encode_deterministic`` — 1-worker and 4-worker saves
  publish byte-identical bundles.

  PYTHONPATH=src python -m benchmarks.ckpt_bench [--json-out ...]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import numpy as np

from .common import csv_row, timed

JSON_OUT = "BENCH_ckpt.json"
#: checkpoint fidelity: 1e-9 of each leaf's range is below f32 ulp for
#: most weights — the refined restore is effectively lossless, and the
#: deep bitplane ladder is exactly what makes the coarse prefix cheap
REL_EB = 1e-9
WEIGHT_ERR = 1e-2


def _model_leaves(scale=None):
    """Transformer-shaped float32 leaves with init-scaled Gaussian
    statistics (what real weight matrices look like: dense, noise-like,
    ~N(0, 1/d)) plus near-one norm scales stored raw."""
    s = 1.0 if scale is None else max(scale / 0.15, 0.25)
    d = int(256 * min(s, 2.0))
    rng = np.random.default_rng(0)

    def winit(shape, seed):
        r = np.random.default_rng(seed)
        return (r.standard_normal(shape) / np.sqrt(shape[-1])) \
            .astype(np.float32)

    leaves = {"embed.table": winit((4 * d, d), 1)}
    for i in range(4):
        leaves[f"blocks.{i}.attn.wqkv"] = winit((d, 3 * d), 10 + i)
        leaves[f"blocks.{i}.mlp.win"] = winit((d, 4 * d), 20 + i)
        leaves[f"blocks.{i}.norm.scale"] = \
            (1.0 + 0.01 * rng.standard_normal(d)).astype(np.float32)
    return leaves


def _write(path, leaves, workers):
    from repro.checkpoint import LeafSpec, write_bundle
    specs = [LeafSpec(lid=k, arr=v, dtype="float32", raw_nbytes=v.nbytes)
             for k, v in leaves.items()]
    return write_bundle(path, specs, step=1, rel_eb=REL_EB, interp="cubic",
                        workers=workers)


def _local_sessions(path, leaves):
    from repro.checkpoint import Bundle, RestoreSession
    record = {}
    with RestoreSession(Bundle.open(path)) as s:
        coarse, t_coarse = timed(s.restore, WEIGHT_ERR)
        record["coarse_bytes"] = b0 = s.bytes_read
        pos0 = s.ladder_positions()
        full, t_full = timed(s.restore, None)
        record["full_bytes"] = s.bytes_read
        planes = s.plane_bytes_between(pos0, s.ladder_positions())
        record["refine_delta_bytes"] = record["full_bytes"] - b0
        record["refine_plane_bytes"] = planes
        s.restore(None)
        record["reread_bytes"] = s.bytes_read - record["full_bytes"]
        record["coarse_seconds"] = t_coarse
        record["refine_seconds"] = t_full
        record["achieved_bound"] = s.achieved_bound
    record["byte_fraction"] = record["coarse_bytes"] / record["full_bytes"]
    for lid, ref in leaves.items():
        err = float(np.max(np.abs(coarse[lid] - ref)))
        rng_v = max(float(ref.max() - ref.min()), 1e-12)
        assert err <= WEIGHT_ERR * rng_v * 1.01 or ref.size <= 4096, \
            (lid, err)
    return coarse, full, record


def _remote_session(path, local_coarse, local_full):
    """The SAME restore over loopback HTTP with one dropped GET mid-
    ladder — the remote layer retries and the bits must not change."""
    from repro.checkpoint import Bundle, RestoreSession
    from tests.range_server import ServerFault, serve
    payload = open(path, "rb").read()
    record = {}
    with serve(payload, faults=[ServerFault("drop", at=2)]) as srv:
        with RestoreSession(Bundle.open(srv.url, timeout=5.0,
                                        backoff=0.01)) as s:
            coarse, t_coarse = timed(s.restore, WEIGHT_ERR)
            full, t_full = timed(s.restore, None)
            record["coarse_seconds"] = t_coarse
            record["refine_seconds"] = t_full
            src = s.bundle.source
            record["stats"] = getattr(src, "stats", lambda: {})()
        record["gets"] = sum(1 for m, _ in srv.log if m == "GET")
    ok = all(np.array_equal(coarse[k], local_coarse[k])
             for k in local_coarse) and \
        all(np.array_equal(full[k], local_full[k]) for k in local_full)
    return ok, record


def run(scale=None, json_out: str = JSON_OUT):
    rows, checks = [], []
    leaves = _model_leaves(scale)
    raw_bytes = sum(v.nbytes for v in leaves.values())
    with tempfile.TemporaryDirectory() as td:
        p1 = os.path.join(td, "w1.ckpt")
        p4 = os.path.join(td, "w4.ckpt")
        man, t_w1 = timed(_write, p1, leaves, 1)
        _, t_w4 = timed(_write, p4, leaves, 4)
        same = open(p1, "rb").read() == open(p4, "rb").read()
        bundle_bytes = os.path.getsize(p1)
        rows.append(csv_row("ckpt/save/workers1", t_w1 * 1e6,
                            f"bundle_bytes={bundle_bytes};"
                            f"ratio={raw_bytes / bundle_bytes:.2f}x"))
        rows.append(csv_row("ckpt/save/workers4", t_w4 * 1e6,
                            f"speedup={t_w1 / max(t_w4, 1e-9):.2f}x"))
        checks.append(("ckpt_parallel_encode_deterministic", "model", "save",
                       same))

        coarse, full, local = _local_sessions(p1, leaves)
        rows.append(csv_row(
            "ckpt/restore/coarse", local["coarse_seconds"] * 1e6,
            f"bytes={local['coarse_bytes']};"
            f"fraction={local['byte_fraction']:.3f};"
            f"weight_error={WEIGHT_ERR}"))
        rows.append(csv_row(
            "ckpt/restore/refine_to_full", local["refine_seconds"] * 1e6,
            f"delta_bytes={local['refine_delta_bytes']};"
            f"plane_bytes={local['refine_plane_bytes']}"))
        checks.append(("ckpt_coarse_byte_fraction", "model", "restore",
                       local["byte_fraction"] <= 0.35))
        checks.append(("ckpt_refine_never_rereads", "model", "restore",
                       local["refine_delta_bytes"]
                       == local["refine_plane_bytes"]
                       and local["reread_bytes"] == 0))

        remote_ok, remote = _remote_session(p1, coarse, full)
        rows.append(csv_row(
            "ckpt/restore/remote_coarse", remote["coarse_seconds"] * 1e6,
            f"gets={remote['gets']};faulted=1"))
        checks.append(("ckpt_remote_bit_identical", "model", "restore",
                       remote_ok))

    if json_out:
        with open(json_out, "w") as f:
            json.dump(dict(
                rel_eb=REL_EB, weight_error=WEIGHT_ERR,
                raw_bytes=raw_bytes, bundle_bytes=bundle_bytes,
                n_leaves=len(leaves),
                kinds={k: e["kind"] for k, e in man["leaves"].items()},
                local=local, remote=remote,
                save_seconds={"workers1": t_w1, "workers4": t_w4},
                checks=[dict(name=c[0], case=c[1], op=c[2], ok=bool(c[3]))
                        for c in checks]), f, indent=2)
        print(f"wrote {json_out}")
    return rows, checks


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--json-out", default=JSON_OUT,
                    help="JSON artifact path ('' disables)")
    args = ap.parse_args()
    rows, checks = run(scale=args.scale, json_out=args.json_out)
    for r in rows:
        print(r)
    for name, ds, op, ok in checks:
        print(f"check {name}[{ds}/{op}]: {'ok' if ok else 'FAILED'}")
    if not all(c[-1] for c in checks):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
