"""Fig. 10: PSNR vs retrieved bitrate (L2 fidelity even though IPComp
optimizes L_inf)."""
from __future__ import annotations

from .common import csv_row, datasets, progressive_compressors, timed
from repro.core import metrics

BITRATES = [1.0, 2.0, 4.0]


def run(scale=None):
    rows, checks = [], []
    for name, x in list(datasets(scale).items())[:3]:
        rng = float(x.max() - x.min())
        blobs = {c.name: c.compress(x, 1e-7 * rng)
                 for c in progressive_compressors()}
        for bpp in BITRATES:
            budget = int(bpp * x.size / 8)
            ps, within = {}, {}
            for comp in progressive_compressors():
                (out, bytes_read, _), dt = timed(comp.retrieve,
                                                 blobs[comp.name],
                                                 max_bytes=budget)
                p = metrics.psnr(x, out)
                ps[comp.name] = p
                within[comp.name] = bytes_read <= budget * 1.02
                rows.append(csv_row(f"fig10/{name}/bpp{bpp}/{comp.name}",
                                    dt * 1e6,
                                    f"psnr={p:.2f}"
                                    f";within_budget={within[comp.name]}"))
            others = [v for k, v in ps.items() if k != "ipcomp" and within[k]]
            if others:
                checks.append(("ipcomp_competitive_psnr", name, bpp,
                               bool(ps["ipcomp"] >= max(others) - 10.0)))
    return rows, checks
