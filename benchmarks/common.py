"""Shared benchmark helpers: datasets, compressor registry, timing."""
from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

from repro.configs.paper import TABLE3, generate
from repro.core import compress as ipc_compress, retrieve as ipc_retrieve, \
    open_archive, metrics
from repro.core.baselines import PMGARD, SZ3, SZ3M, SZ3R, ZFP, ZFPR

#: scale of the paper's dataset shapes (env-overridable; 1.0 = full size)
SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "0.15"))


def datasets(scale: float = None) -> Dict[str, np.ndarray]:
    s = SCALE if scale is None else scale
    return {d.name: generate(d, scale=s) for d in TABLE3}


def timed(fn, *args, repeat: int = 1, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


class IPCompAdapter:
    """Uniform compress/retrieve interface for the benchmark tables.

    Default propagation is the corrected SAFE bound: the paper's Theorem-1
    factor was observed to VIOLATE a requested bound on the Density-like
    field at E=1e-2*range (caught by the per-row ok flag; EXPERIMENTS.md
    §Repro-findings).  Pass propagation="paper" to reproduce Theorem 1.
    """
    name = "ipcomp"

    def __init__(self, propagation: str = "safe"):
        self.propagation = propagation

    def compress(self, x, eb):
        return ipc_compress(x, eb)

    def decompress(self, buf):
        out, _ = ipc_retrieve(buf)
        return out

    def retrieve(self, buf, error_bound=None, max_bytes=None):
        out, st = ipc_retrieve(buf, error_bound=error_bound,
                               max_bytes=max_bytes,
                               propagation=self.propagation)
        return out, st.bytes_read, 1


def progressive_compressors():
    return [IPCompAdapter(), SZ3M(), SZ3R(), ZFPR(), PMGARD()]


def all_compressors():
    return [IPCompAdapter(), SZ3(), SZ3M(), SZ3R(), ZFP(), ZFPR(), PMGARD()]


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
