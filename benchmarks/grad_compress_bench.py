"""Cross-pod gradient-reduction wire traffic: plain f32 psum vs IPComp
bitplane-compressed psum (the paper's §4.4 pipeline on the inter-pod links).

Collective bytes are read from the compiled HLO of the isolated reduction
(the integrated train step compresses the same tensors; on XLA:CPU the
mixed manual/auto module trips a compiler bug in AllReducePromotion —
EXPERIMENTS.md §Perf cell 3 — so the wire measurement is taken here).

The host-side section always runs (no dry-run env needed): it pushes
seeded gradient-shaped leaves through the actual quantize/truncate path
(``grad._quantize_leaf``), negabinary-codes them, and measures the
entropy-coded occupied bitplanes — the compressed bits per value that
would cross the wire.  Claim: <= ``keep_bits`` per value (truncation
really dropped the planes it claims to drop), with the measurement
written to ``BENCH_grad.json``.
"""
from __future__ import annotations

import json
import zlib

import numpy as np

JSON_OUT = "BENCH_grad.json"
KEEP_BITS = 14
REL_EB = 1e-4


def _leaf_wire_bits(g, keep_bits: int, rel_eb: float) -> float:
    """Compressed wire bits/value for one gradient leaf: quantize +
    occupied-width truncate (the grad path), negabinary, then zlib over
    each occupied MSB-first bitplane (the codec's plane channel)."""
    import jax.numpy as jnp
    from repro.compression.grad import _quantize_leaf
    from repro.core.negabinary import to_negabinary
    q, _, _ = _quantize_leaf(jnp.asarray(g, jnp.float32),
                             jnp.zeros(g.shape, jnp.float32),
                             rel_eb, keep_bits)
    nb = to_negabinary(np.asarray(q, np.int64))
    occupied = int(nb.max()).bit_length()
    total_bytes = 0
    for b in range(occupied - 1, -1, -1):   # MSB-first, like the codec
        plane = np.packbits((nb >> np.uint32(b)) & np.uint32(1))
        total_bytes += len(zlib.compress(plane.tobytes(), 6))
    return total_bytes * 8.0 / g.size


def _wire_bits_bench(scale=None):
    rows, checks = [], []
    s = 1.0 if scale is None else max(scale / 0.15, 0.25)
    n = int((1 << 18) * min(s, 4.0))
    shapes = {"mlp.win": (n // 256, 256), "attn.wqkv": (n // 512, 512)}
    bits = {}
    for name, shape in shapes.items():
        rng = np.random.default_rng(zlib.crc32(name.encode()))
        g = (rng.standard_normal(shape) / np.sqrt(shape[-1])) \
            .astype(np.float32)
        bits[name] = _leaf_wire_bits(g, KEEP_BITS, REL_EB)
        rows.append(f"grad_compress/wire_bits/{name},0.0,"
                    f"bits_per_value={bits[name]:.2f};keep_bits={KEEP_BITS};"
                    f"vs_f32=32")
    worst = max(bits.values())
    checks.append(("grad_bits_per_value_within_keep",
                   f"{len(shapes)}leaves", "wire", worst <= KEEP_BITS))
    return rows, checks, bits


def run(scale=None, json_out: str = JSON_OUT):
    import os
    rows, checks, bits = _wire_bits_bench(scale)
    if json_out:
        with open(json_out, "w") as f:
            json.dump(dict(keep_bits=KEEP_BITS, rel_eb=REL_EB,
                           bits_per_value=bits,
                           checks=[dict(name=c[0], case=c[1], op=c[2],
                                        ok=bool(c[3])) for c in checks]),
                      f, indent=2)
    if "XLA_FLAGS" not in os.environ:  # needs the 512-device dry-run env
        rows.append("grad_compress/skipped(no XLA_FLAGS),0.0,run via dryrun")
        return rows, checks
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compression.grad import compressed_psum
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import collective_bytes
    from repro.parallel.compat import shard_map

    mesh = make_production_mesh(multi_pod=True)
    npods = mesh.shape["pod"]
    # yi-6b-sized flat gradient shard per device pair
    n = 6_061_000_000 // 512  # one device's FSDP+TP shard of the grads
    n = (n // 128) * 128
    g = jax.ShapeDtypeStruct((npods, n), jnp.float32)

    def plain(x):
        return jax.lax.psum(x, "pod") / npods

    def comp(x):
        return compressed_psum(x, "pod", keep_bits=14, rel_eb=1e-4) / npods

    out = []
    for name, fn in (("plain_f32", plain), ("ipcomp_bitplane", comp)):
        f = jax.jit(shard_map(fn, mesh=mesh, in_specs=P("pod"),
                              out_specs=P("pod"), axis_names={"pod"},
                              check_vma=False))
        hlo = f.lower(g).compile().as_text()
        coll = collective_bytes(hlo)
        tot = sum(coll.values())
        out.append(tot)
        rows.append(f"grad_compress/{name},0.0,"
                    f"coll_bytes={tot};breakdown={coll}")
    ratio = out[0] / max(out[1], 1)
    rows.append(f"grad_compress/reduction,0.0,ratio={ratio:.2f}x")
    checks.append(("compressed_wire_smaller", "yi-6b", "", out[1] < out[0]))
    return rows, checks


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--json-out", default=JSON_OUT,
                    help="JSON artifact path ('' disables)")
    args = ap.parse_args()
    rows, checks = run(scale=args.scale, json_out=args.json_out)
    for r in rows:
        print(r)
    for name, ds, op, ok in checks:
        print(f"check {name}[{ds}/{op}]: {'ok' if ok else 'FAILED'}")
    if not all(c[-1] for c in checks):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
