"""Cross-pod gradient-reduction wire traffic: plain f32 psum vs IPComp
bitplane-compressed psum (the paper's §4.4 pipeline on the inter-pod links).

Collective bytes are read from the compiled HLO of the isolated reduction
(the integrated train step compresses the same tensors; on XLA:CPU the
mixed manual/auto module trips a compiler bug in AllReducePromotion —
EXPERIMENTS.md §Perf cell 3 — so the wire measurement is taken here).
"""
from __future__ import annotations

import numpy as np


def run(scale=None):
    import os
    rows, checks = [], []
    if "XLA_FLAGS" not in os.environ:  # needs the 512-device dry-run env
        rows.append("grad_compress/skipped(no XLA_FLAGS),0.0,run via dryrun")
        return rows, checks
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compression.grad import compressed_psum
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import collective_bytes
    from repro.parallel.compat import shard_map

    mesh = make_production_mesh(multi_pod=True)
    npods = mesh.shape["pod"]
    # yi-6b-sized flat gradient shard per device pair
    n = 6_061_000_000 // 512  # one device's FSDP+TP shard of the grads
    n = (n // 128) * 128
    g = jax.ShapeDtypeStruct((npods, n), jnp.float32)

    def plain(x):
        return jax.lax.psum(x, "pod") / npods

    def comp(x):
        return compressed_psum(x, "pod", keep_bits=14, rel_eb=1e-4) / npods

    out = []
    for name, fn in (("plain_f32", plain), ("ipcomp_bitplane", comp)):
        f = jax.jit(shard_map(fn, mesh=mesh, in_specs=P("pod"),
                              out_specs=P("pod"), axis_names={"pod"},
                              check_vma=False))
        hlo = f.lower(g).compile().as_text()
        coll = collective_bytes(hlo)
        tot = sum(coll.values())
        out.append(tot)
        rows.append(f"grad_compress/{name},0.0,"
                    f"coll_bytes={tot};breakdown={coll}")
    ratio = out[0] / max(out[1], 1)
    rows.append(f"grad_compress/reduction,0.0,ratio={ratio:.2f}x")
    checks.append(("compressed_wire_smaller", "yi-6b", "", out[1] < out[0]))
    return rows, checks
