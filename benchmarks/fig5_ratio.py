"""Fig. 5: compression ratio at rel-eb 1e-6 / 1e-9 across compressors.

Paper claim: IPComp leads all *progressive* baselines (20%..500% higher CR)
and is competitive with non-progressive SZ3.
"""
from __future__ import annotations

import numpy as np

from .common import all_compressors, csv_row, datasets, timed
from repro.core import metrics


def run(scale=None):
    rows = []
    checks = []
    for name, x in datasets(scale).items():
        rng = float(x.max() - x.min())
        for rel in (1e-6, 1e-9):
            eb = rel * rng
            crs = {}
            for comp in all_compressors():
                buf, dt = timed(comp.compress, x, eb)
                cr = x.nbytes / len(buf)
                crs[comp.name] = cr
                rows.append(csv_row(
                    f"fig5/{name}/eb{rel:.0e}/{comp.name}", dt * 1e6,
                    f"cr={cr:.2f}"))
            prog = {k: v for k, v in crs.items()
                    if k in ("ipcomp", "sz3m", "sz3r", "zfpr", "pmgard")}
            best_other = max(v for k, v in prog.items() if k != "ipcomp")
            checks.append(("ipcomp_leads_progressive",
                           name, rel, crs["ipcomp"] >= 0.95 * best_other))
    return rows, checks
