"""Table 2: predictive bitplane coding reduces bit entropy (0/1/2/3-bit
prefix XOR); 2-bit prefix is the best — the design choice of §4.4.1."""
from __future__ import annotations

import numpy as np

from .common import csv_row, datasets, timed
from repro.core import interpolation, negabinary, quantize as Q


def _bit_entropy(bits: np.ndarray) -> float:
    p = bits.mean()
    if p in (0.0, 1.0):
        return 0.0
    return float(-p * np.log2(p) - (1 - p) * np.log2(1 - p))


def _mean_plane_entropy(nb: np.ndarray, prefix: int) -> float:
    nbits = int(nb.max()).bit_length()
    if nbits == 0:
        return 0.0
    enc = nb.copy()
    if prefix >= 1:
        enc = enc ^ (nb >> np.uint32(1))
    if prefix >= 2:
        enc = enc ^ (nb >> np.uint32(2))
    if prefix >= 3:
        enc = enc ^ (nb >> np.uint32(3))
    es = []
    for k in range(nbits):
        es.append(_bit_entropy(((enc >> np.uint32(k)) & 1).astype(np.uint8)))
    return float(np.mean(es))


def run(scale=None):
    rows, checks = [], []
    for name, x in list(datasets(scale).items())[:3]:
        eb = 1e-6 * float(x.max() - x.min())

        def quantizer(res, tv):
            q = Q.quantize(res, eb)
            q[Q.escape_mask(q)] = 0
            return q, Q.dequantize(q, eb), (np.zeros(0, np.int64),
                                            np.zeros(0, np.float64))

        _, qs, _, _ = interpolation.decorrelate(
            x.astype(np.float64), eb, interpolation.CUBIC, quantizer)
        nb = negabinary.to_negabinary(np.concatenate(qs))
        ents = {p: _mean_plane_entropy(nb, p) for p in (0, 1, 2, 3)}
        rows.append(csv_row(
            f"table2/{name}", 0.0,
            ";".join(f"p{p}={e:.4f}" for p, e in ents.items())))
        checks.append(("prefix2_reduces_entropy", name, "",
                       ents[2] <= ents[0]))
    return rows, checks
