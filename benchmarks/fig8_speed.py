"""Fig. 8/9: compression + retrieval speed; residual-count slowdown curve.

Paper claims: IPComp is up to ~3x faster than progressive baselines (except
non-progressive SZ3-M); residual compressors slow down sharply as the
number of pre-defined bounds grows.
"""
from __future__ import annotations

import numpy as np

from .common import csv_row, datasets, progressive_compressors, timed
from repro.core.baselines import SZ3
from repro.core.baselines.residual import ResidualProgressive
from repro.core import metrics


def run(scale=None):
    rows, checks = [], []
    data = datasets(scale)
    name = "Density"
    x = data[name]
    rng = float(x.max() - x.min())
    eb = 1e-9 * rng
    speeds = {}
    for comp in progressive_compressors():
        buf, tc = timed(comp.compress, x, eb)
        (_, _, passes), td = timed(comp.retrieve, buf, error_bound=eb * 4)
        mbps_c = x.nbytes / tc / 1e6
        mbps_d = x.nbytes / td / 1e6
        speeds[comp.name] = (mbps_c, mbps_d)
        rows.append(csv_row(f"fig8/{name}/{comp.name}/compress", tc * 1e6,
                            f"MBps={mbps_c:.1f}"))
        rows.append(csv_row(f"fig8/{name}/{comp.name}/retrieve", td * 1e6,
                            f"MBps={mbps_d:.1f};passes={passes}"))
    checks.append(("ipcomp_faster_than_residual", name, "compress",
                   speeds["ipcomp"][0] >= 0.8 * speeds["sz3r"][0]))

    # Fig 9: residual rung count vs compression time
    import repro.core.baselines.residual as R
    base_ladder = R.LADDER
    for rungs in (2, 5, 9):
        R.LADDER = [4 ** k for k in range(rungs - 1, -1, -1)]
        comp = R.SZ3R()
        _, tc = timed(comp.compress, x, eb * (4 ** (9 - rungs)))
        rows.append(csv_row(f"fig9/{name}/sz3r/rungs{rungs}", tc * 1e6,
                            f"MBps={x.nbytes / tc / 1e6:.1f}"))
    R.LADDER = base_ladder
    return rows, checks
