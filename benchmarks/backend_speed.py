"""Compression backend throughput: numpy reference vs jax/Pallas kernels.

Reports compress throughput for both backends on a >=2^20-element field
(the acceptance smoke case), plus the chunked variant of the jax backend —
chunking makes every slab share one jit cache entry, which is where the
batched/vmapped encoding of the roadmap picks up.

CPU caveat: off-TPU the Pallas kernels run in *interpret mode*, a
correctness harness, so the jax numbers on CPU measure dispatch overhead,
not kernel speed; parity of the emitted bytes is asserted regardless.  On
TPU the same path compiles to Mosaic.

Usage:
  PYTHONPATH=src python -m benchmarks.backend_speed [--n 1048576] [--full]

CI-smoke mode (default) runs one warm repetition per backend; --full adds
a second field and best-of-3 timing.
"""
from __future__ import annotations

import argparse

import numpy as np

from .common import csv_row, timed
from repro.core import compress


def _field(n: int) -> np.ndarray:
    side = int(np.sqrt(n))
    i, j = np.meshgrid(np.arange(side), np.arange(n // side), indexing="ij")
    return np.sin(i * 0.01) * np.cos(j * 0.013) + 1e-3 * np.sin(i * j * 1e-4)


def run(scale=None, n: int = 1 << 20, smoke: bool = True):
    rows, checks = [], []
    if n < 1 << 20:
        raise SystemExit(f"--n must be >= {1 << 20} (2^20) elements, got {n}")
    x = _field(n)
    eb = 1e-5
    repeat = 1 if smoke else 3
    variants = [
        ("numpy", dict(backend="numpy")),
        ("jax", dict(backend="jax")),
        ("jax_chunked", dict(backend="jax", chunk_elems=1 << 18)),
    ]
    bufs = {}
    for name, kw in variants:
        if name.startswith("jax"):
            compress(x, eb, **kw)  # warm the jit caches out of the timing
        buf, dt = timed(compress, x, eb, repeat=repeat, **kw)
        bufs[name] = buf
        mbps = x.nbytes / dt / 1e6
        rows.append(csv_row(f"backend_speed/{x.size}el/{name}/compress",
                            dt * 1e6, f"MBps={mbps:.1f};bytes={len(buf)}"))
        print(rows[-1])
    checks.append(("backend_parity_bytes", f"{x.size}el", "compress",
                   bufs["numpy"] == bufs["jax"]))
    if not smoke:
        y = _field(1 << 22)
        for name, kw in variants:
            buf, dt = timed(compress, y, eb, repeat=1, **kw)
            rows.append(csv_row(f"backend_speed/{y.size}el/{name}/compress",
                                dt * 1e6,
                                f"MBps={y.nbytes / dt / 1e6:.1f}"))
            print(rows[-1])
    return rows, checks


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1 << 20,
                    help="elements in the benchmark field (>= 2^20)")
    ap.add_argument("--full", action="store_true",
                    help="best-of-3 timing plus a 4M-element field")
    args = ap.parse_args()
    _, checks = run(n=args.n, smoke=not args.full)
    for name, ds, op, ok in checks:
        print(f"check {name}[{ds}/{op}]: {'ok' if ok else 'FAILED'}")
    if not all(c[-1] for c in checks):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
