"""Codec backend throughput: numpy reference vs jax/Pallas kernels.

Reports compress AND decode throughput for both backends on a >=2^20-element
field (the acceptance smoke case), plus the chunked variant — chunking makes
every slab share one jit cache entry, which is where the batched/vmapped
encoding of the roadmap picks up.  Decode is measured as the two retrieval
operations the paper optimizes (§5): a full-precision ``decompress`` and one
incremental ``refine`` step (Algorithm 2's delta cascade) on top of a
coarse first retrieval.

CPU caveat: off-TPU the Pallas kernels run in *interpret mode*, a
correctness harness, so the jax numbers on CPU measure dispatch overhead,
not kernel speed; parity of the emitted bytes (encode) and reconstructed
bits (decode) is asserted regardless.  On TPU the same path compiles to
Mosaic.

Usage:
  PYTHONPATH=src python -m benchmarks.backend_speed [--n 1048576] [--full]
      [--json-out BENCH_decode.json]

CI-smoke mode (default) runs one warm repetition per backend; --full adds
a second field and best-of-3 timing.  The decode measurements are written
to ``BENCH_decode.json`` (uploaded as a CI artifact).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from .common import csv_row, timed
from repro.core import compress, decompress, open_archive, refine, retrieve

JSON_OUT = "BENCH_decode.json"

#: coarse-then-refine targets for the Algorithm 2 timing, relative to eb
REFINE_COARSE = 1e3
REFINE_FINE = 1e1


def _field(n: int) -> np.ndarray:
    side = int(np.sqrt(n))
    i, j = np.meshgrid(np.arange(side), np.arange(n // side), indexing="ij")
    return np.sin(i * 0.01) * np.cos(j * 0.013) + 1e-3 * np.sin(i * j * 1e-4)


def _decode_rows(x: np.ndarray, eb: float, buf: bytes, case: str,
                 repeat: int, rows, records, outs):
    """Measure full decompress + one refine step for both decode backends."""
    for bk in ("numpy", "jax"):
        if bk == "jax":
            # warm every jit cache entry the timed calls will hit — incl.
            # the refine ladder, whose plane prefixes are distinct static
            # args of the unpack kernel (a cold refine would time tracing)
            decompress(buf, backend=bk)
            _, ws = retrieve(open_archive(buf),
                             error_bound=REFINE_COARSE * eb, backend=bk)
            refine(ws, error_bound=REFINE_FINE * eb, backend=bk)
        out, dt = timed(decompress, buf, repeat=repeat, backend=bk)
        outs.setdefault(case, {})[bk] = out
        mbps = x.nbytes / dt / 1e6
        rows.append(csv_row(f"backend_speed/{case}/{bk}/decompress",
                            dt * 1e6, f"MBps={mbps:.1f}"))
        print(rows[-1])
        records.append(dict(case=case, backend=bk, op="decompress",
                            seconds=dt, mbps=mbps, bytes=len(buf)))

        # one refine step: coarse retrieval outside the clock, then time
        # the incremental delta cascade to the tighter bound
        reader = open_archive(buf)
        _, st = retrieve(reader, error_bound=REFINE_COARSE * eb, backend=bk)
        (_, st), dt = timed(refine, st, error_bound=REFINE_FINE * eb,
                            repeat=1, backend=bk)
        mbps = x.nbytes / dt / 1e6
        rows.append(csv_row(f"backend_speed/{case}/{bk}/refine",
                            dt * 1e6,
                            f"MBps={mbps:.1f};bytes_read={st.bytes_read}"))
        print(rows[-1])
        records.append(dict(case=case, backend=bk, op="refine",
                            seconds=dt, mbps=mbps,
                            bytes_read=int(st.bytes_read)))


def run(scale=None, n: int = 1 << 20, smoke: bool = True,
        json_out: str = JSON_OUT):
    rows, checks, records = [], [], []
    if n < 1 << 20:
        raise SystemExit(f"--n must be >= {1 << 20} (2^20) elements, got {n}")
    x = _field(n)
    eb = 1e-5
    repeat = 1 if smoke else 3
    variants = [
        ("numpy", dict(backend="numpy")),
        ("jax", dict(backend="jax")),
        ("jax_chunked", dict(backend="jax", chunk_elems=1 << 18)),
    ]
    bufs = {}
    for name, kw in variants:
        if name.startswith("jax"):
            compress(x, eb, **kw)  # warm the jit caches out of the timing
        buf, dt = timed(compress, x, eb, repeat=repeat, **kw)
        bufs[name] = buf
        mbps = x.nbytes / dt / 1e6
        rows.append(csv_row(f"backend_speed/{x.size}el/{name}/compress",
                            dt * 1e6, f"MBps={mbps:.1f};bytes={len(buf)}"))
        print(rows[-1])
    checks.append(("backend_parity_bytes", f"{x.size}el", "compress",
                   bufs["numpy"] == bufs["jax"]))

    # decode direction: v1 archive and the chunked v2 archive
    outs = {}
    _decode_rows(x, eb, bufs["numpy"], f"{x.size}el_v1", repeat, rows,
                 records, outs)
    _decode_rows(x, eb, bufs["jax_chunked"], f"{x.size}el_v2", repeat, rows,
                 records, outs)
    for case, by_bk in outs.items():
        checks.append(("decode_parity_bits", case, "decompress",
                       bool(np.array_equal(by_bk["numpy"], by_bk["jax"]))))

    if not smoke:
        y = _field(1 << 22)
        for name, kw in variants:
            buf, dt = timed(compress, y, eb, repeat=1, **kw)
            rows.append(csv_row(f"backend_speed/{y.size}el/{name}/compress",
                                dt * 1e6,
                                f"MBps={y.nbytes / dt / 1e6:.1f}"))
            print(rows[-1])
    if json_out:
        with open(json_out, "w") as f:
            json.dump(dict(n=int(x.size), eb=eb,
                           refine_bounds=[REFINE_COARSE * eb,
                                          REFINE_FINE * eb],
                           records=records,
                           checks=[dict(name=c[0], case=c[1], op=c[2],
                                        ok=bool(c[3])) for c in checks]),
                      f, indent=2)
        print(f"wrote {json_out} ({len(records)} decode records)")
    return rows, checks


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1 << 20,
                    help="elements in the benchmark field (>= 2^20)")
    ap.add_argument("--full", action="store_true",
                    help="best-of-3 timing plus a 4M-element field")
    ap.add_argument("--json-out", default=JSON_OUT,
                    help="decode-benchmark JSON artifact path ('' disables)")
    args = ap.parse_args()
    _, checks = run(n=args.n, smoke=not args.full, json_out=args.json_out)
    for name, ds, op, ok in checks:
        print(f"check {name}[{ds}/{op}]: {'ok' if ok else 'FAILED'}")
    if not all(c[-1] for c in checks):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
