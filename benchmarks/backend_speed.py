"""Codec backend throughput: numpy reference vs jax/Pallas kernels.

Reports compress AND decode throughput for both backends on a >=2^20-element
field (the acceptance smoke case), plus the chunked variant in BOTH
execution modes — the per-chunk loop and the batched shape-group engine
(``ExecPolicy(batch_chunks=...)``), whose ``jax.vmap``-ed dispatches are
the roadmap's equal-shape chunk batching, plus — whenever more than one
device is visible — a sharded entry (``ExecPolicy(shard="auto")``) that
runs the chunk grid data-parallel over the local device mesh and records
sharded vs single-device MB/s and per-device launch fan-out, plus a
fused-decode entry that races the ``jax`` backend's decode megakernel
(one ``decode_fused`` + one whole-level recon launch per level) against
the pre-fusion ``jax_unfused`` baseline, recording MB/s, dispatches,
launches per level, and per-kernel HBM bytes (the roofline report's
input).  Everything
drives the object API (``Codec`` / ``Archive`` / ``Fidelity`` /
``ExecPolicy``), so the benchmark doubles as its smoke test.  Kernel
dispatch counts for all modes come from ``repro.kernels.dispatch``, so the
batched-vs-looped launch-count reduction (and the sharded fan-out) is a
recorded, trendable number, not a claim.  Decode is measured
as the two retrieval operations the paper optimizes (§5): a full-precision
read and one incremental ``refine`` step (Algorithm 2's delta cascade) on
top of a coarse first retrieval.

CPU caveat: off-TPU the Pallas kernels run in *interpret mode*, a
correctness harness, so the jax numbers on CPU measure dispatch overhead,
not kernel speed; parity of the emitted bytes (encode) and reconstructed
bits (decode) is asserted regardless.  On TPU the same path compiles to
Mosaic.  That cuts both ways for the chunk-batch entry: the vmapped
interpreter can make *batched wall-clock slower on CPU* even as launches
collapse — off-TPU the dispatch counts are the trendable metric, the MB/s
columns become meaningful on real hardware.

Usage:
  PYTHONPATH=src python -m benchmarks.backend_speed [--n 1048576] [--full]
      [--json-out BENCH_decode.json] [--json-out-compress BENCH_compress.json]

CI-smoke mode (default) runs one warm repetition per backend; --full adds
a second field and best-of-3 timing.  The decode measurements are written
to ``BENCH_decode.json`` and the compress measurements (including the
chunk-batch speed entry) to ``BENCH_compress.json`` (both uploaded as CI
artifacts).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from .common import csv_row, timed
from repro import Archive, Codec, ExecPolicy, Fidelity
from repro.core import chunk_bounds
from repro.kernels import dispatch

JSON_OUT = "BENCH_decode.json"
JSON_OUT_COMPRESS = "BENCH_compress.json"

#: coarse-then-refine targets for the Algorithm 2 timing, relative to eb
REFINE_COARSE = 1e3
REFINE_FINE = 1e1

#: chunk size for the chunk-batch entries (16 chunks on the 2^20 field)
CHUNK_ELEMS = 1 << 16


def _field(n: int) -> np.ndarray:
    side = int(np.sqrt(n))
    i, j = np.meshgrid(np.arange(side), np.arange(n // side), indexing="ij")
    return np.sin(i * 0.01) * np.cos(j * 0.013) + 1e-3 * np.sin(i * j * 1e-4)


def _decode_rows(x: np.ndarray, eb: float, buf: bytes, case: str,
                 repeat: int, rows, records, outs):
    """Measure full read + one refine step for both decode backends."""
    archive = Archive(buf)
    for bk in ("numpy", "jax"):
        policy = ExecPolicy(backend=bk)
        if bk == "jax":
            # warm every jit cache entry the timed calls will hit — incl.
            # the refine ladder, whose plane prefixes are distinct static
            # args of the unpack kernel (a cold refine would time tracing)
            archive.open(policy).read()
            warm = archive.open(policy)
            warm.read(Fidelity.error_bound(REFINE_COARSE * eb))
            warm.refine(Fidelity.error_bound(REFINE_FINE * eb))
        out, dt = timed(lambda: archive.open(policy).read(), repeat=repeat)
        outs.setdefault(case, {})[bk] = out
        mbps = x.nbytes / dt / 1e6
        rows.append(csv_row(f"backend_speed/{case}/{bk}/decompress",
                            dt * 1e6, f"MBps={mbps:.1f}"))
        print(rows[-1])
        records.append(dict(case=case, backend=bk, op="decompress",
                            seconds=dt, mbps=mbps, bytes=len(buf)))

        # one refine step: coarse retrieval outside the clock, then time
        # the incremental delta cascade to the tighter bound
        session = archive.open(policy)
        session.read(Fidelity.error_bound(REFINE_COARSE * eb))
        _, dt = timed(session.refine, Fidelity.error_bound(REFINE_FINE * eb),
                      repeat=1)
        mbps = x.nbytes / dt / 1e6
        rows.append(csv_row(f"backend_speed/{case}/{bk}/refine",
                            dt * 1e6,
                            f"MBps={mbps:.1f};"
                            f"bytes_read={session.bytes_read}"))
        print(rows[-1])
        records.append(dict(case=case, backend=bk, op="refine",
                            seconds=dt, mbps=mbps,
                            bytes_read=int(session.bytes_read)))


def _fused_rows(x: np.ndarray, eb: float, buf: bytes, rows, checks,
                dec_records):
    """The fused-decode megakernel entry: ``jax`` (fused decode path) vs
    ``jax_unfused`` (the pre-fusion per-phase pipeline, kept registered as
    the baseline) on the v1 2^20 archive.  Records MB/s, total dispatches,
    per-kernel launch counts and HBM bytes, and launches per level — the
    inputs of ``benchmarks/roofline_report.py``.  The fused path must
    issue strictly FEWER dispatches (a structural property, asserted even
    in interpret mode) and reach >= 2x the unfused MB/s on this case.
    """
    from repro.core import open_archive

    archive = Archive(buf)
    L = open_archive(buf).meta.L
    stats, outs = {}, {}
    for bk in ("jax_unfused", "jax"):
        policy = ExecPolicy(backend=bk)
        archive.open(policy).read()  # warm jit caches out of the timing
        warm = archive.open(policy)
        warm.read(Fidelity.error_bound(REFINE_COARSE * eb))
        warm.refine(Fidelity.error_bound(REFINE_FINE * eb))
        with dispatch.measure() as d, dispatch.measure_bytes() as db:
            outs[bk], dt = timed(lambda: archive.open(policy).read(),
                                 repeat=1)
        nd = sum(d.values())
        mbps = x.nbytes / dt / 1e6
        rows.append(csv_row(f"backend_speed/fused_decode/{bk}/decompress",
                            dt * 1e6, f"MBps={mbps:.1f};dispatches={nd};"
                            f"per_level={nd / L:.1f}"))
        print(rows[-1])
        dec_records.append(dict(case="fused_decode", backend=bk,
                                op="decompress", seconds=dt, mbps=mbps,
                                dispatches=nd, levels=L,
                                dispatches_per_level=nd / L,
                                dispatches_by_kernel=dict(d),
                                kernel_bytes=dict(db)))
        stats[bk] = (mbps, nd)

        session = archive.open(policy)
        session.read(Fidelity.error_bound(REFINE_COARSE * eb))
        with dispatch.measure() as d, dispatch.measure_bytes() as db:
            _, dt = timed(session.refine,
                          Fidelity.error_bound(REFINE_FINE * eb), repeat=1)
        nd = sum(d.values())
        mbps = x.nbytes / dt / 1e6
        rows.append(csv_row(f"backend_speed/fused_decode/{bk}/refine",
                            dt * 1e6, f"MBps={mbps:.1f};dispatches={nd}"))
        print(rows[-1])
        dec_records.append(dict(case="fused_decode", backend=bk, op="refine",
                                seconds=dt, mbps=mbps, dispatches=nd,
                                levels=L, dispatches_per_level=nd / L,
                                dispatches_by_kernel=dict(d),
                                kernel_bytes=dict(db)))
    checks.append(("fused_parity_bits", "fused_decode", "decompress",
                   bool(np.array_equal(outs["jax"], outs["jax_unfused"]))))
    checks.append(("fused_fewer_dispatches", "fused_decode", "decompress",
                   stats["jax"][1] < stats["jax_unfused"][1]))
    checks.append(("fused_2x_mbps", "fused_decode", "decompress",
                   stats["jax"][0] >= 2.0 * stats["jax_unfused"][0]))


def _chunk_batch_rows(x: np.ndarray, eb: float, rows, checks,
                      comp_records, dec_records):
    """The chunk-batch speed entry: batched vs looped dispatch counts and
    MB/s for both codec directions on a CHUNK_ELEMS-slabbed archive."""
    codec = Codec(eb=eb, chunk_elems=CHUNK_ELEMS)
    n_chunks = len(chunk_bounds(x.shape, CHUNK_ELEMS))
    bufs = {}
    for mode, flag in (("looped", False), ("batched", True)):
        policy = ExecPolicy(backend="jax", batch_chunks=flag)
        codec.compress(x, policy)  # warm jit caches out of the timing
        with dispatch.measure() as d:
            arc, dt = timed(codec.compress, x, policy, repeat=1)
        bufs[mode] = arc.tobytes()
        mbps = x.nbytes / dt / 1e6
        nd = sum(d.values())
        rows.append(csv_row(f"backend_speed/chunk_batch/{mode}/compress",
                            dt * 1e6,
                            f"MBps={mbps:.1f};dispatches={nd}"))
        print(rows[-1])
        comp_records.append(dict(case="chunk_batch", mode=mode,
                                 op="compress", seconds=dt, mbps=mbps,
                                 chunks=n_chunks, dispatches=nd,
                                 dispatches_by_kernel=d))

        coarse = Fidelity.error_bound(REFINE_COARSE * eb)
        arc.open(policy).read(coarse)  # warm
        with dispatch.measure() as d:
            _, dt = timed(lambda: arc.open(policy).read(coarse), repeat=1)
        mbps = x.nbytes / dt / 1e6
        nd = sum(d.values())
        rows.append(csv_row(f"backend_speed/chunk_batch/{mode}/retrieve",
                            dt * 1e6,
                            f"MBps={mbps:.1f};dispatches={nd}"))
        print(rows[-1])
        dec_records.append(dict(case="chunk_batch", mode=mode, op="retrieve",
                                seconds=dt, mbps=mbps, chunks=n_chunks,
                                dispatches=nd, dispatches_by_kernel=d))
    checks.append(("chunk_batch_parity_bytes", "chunked", "compress",
                   bufs["looped"] == bufs["batched"]))
    loop_d = sum(comp_records[-2]["dispatches_by_kernel"].values())
    bat_d = sum(comp_records[-1]["dispatches_by_kernel"].values())
    checks.append(("chunk_batch_fewer_dispatches", "chunked", "compress",
                   bat_d < loop_d))


def _sharded_rows(x: np.ndarray, eb: float, rows, checks,
                  comp_records, dec_records):
    """Sharded-vs-single-device entry: both codec directions over the
    chunk grid on a mesh of every local device (run the benchmark under
    XLA_FLAGS=--xla_force_host_platform_device_count=8 for a forced CPU
    mesh).  Byte/bit parity is asserted; on CPU the MB/s delta measures
    shard_map + interpret-mode overhead, on real hardware it measures the
    scale-out.  Skipped (one informational record) on single-device hosts.
    """
    import jax
    n_dev = jax.device_count()
    if n_dev < 2:
        comp_records.append(dict(case="sharded", mode="skipped",
                                 op="compress", devices=n_dev))
        print("backend_speed/sharded: single device visible, skipped "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return
    codec = Codec(eb=eb, chunk_elems=CHUNK_ELEMS)
    n_chunks = len(chunk_bounds(x.shape, CHUNK_ELEMS))
    bufs, outs = {}, {}
    for mode, shard in (("single", None), ("sharded", "auto")):
        policy = ExecPolicy(backend="jax", shard=shard)
        codec.compress(x, policy)  # warm jit caches out of the timing
        with dispatch.measure() as d, dispatch.measure_devices() as dd:
            arc, dt = timed(codec.compress, x, policy, repeat=1)
        bufs[mode] = arc.tobytes()
        mbps = x.nbytes / dt / 1e6
        rows.append(csv_row(f"backend_speed/sharded/{mode}/compress",
                            dt * 1e6, f"MBps={mbps:.1f};devices="
                            f"{n_dev if shard else 1};"
                            f"dispatches={sum(d.values())};"
                            f"device_launches={sum(dd.values())}"))
        print(rows[-1])
        comp_records.append(dict(case="sharded", mode=mode, op="compress",
                                 seconds=dt, mbps=mbps, chunks=n_chunks,
                                 devices=n_dev if shard else 1,
                                 dispatches=sum(d.values()),
                                 device_launches=sum(dd.values()),
                                 dispatches_by_kernel=d))

        coarse = Fidelity.error_bound(REFINE_COARSE * eb)
        arc.open(policy).read(coarse)  # warm
        with dispatch.measure() as d, dispatch.measure_devices() as dd:
            outs[mode], dt = timed(lambda: arc.open(policy).read(coarse),
                                   repeat=1)
        mbps = x.nbytes / dt / 1e6
        rows.append(csv_row(f"backend_speed/sharded/{mode}/retrieve",
                            dt * 1e6, f"MBps={mbps:.1f};devices="
                            f"{n_dev if shard else 1};"
                            f"dispatches={sum(d.values())};"
                            f"device_launches={sum(dd.values())}"))
        print(rows[-1])
        dec_records.append(dict(case="sharded", mode=mode, op="retrieve",
                                seconds=dt, mbps=mbps, chunks=n_chunks,
                                devices=n_dev if shard else 1,
                                dispatches=sum(d.values()),
                                device_launches=sum(dd.values()),
                                dispatches_by_kernel=d))
    checks.append(("sharded_parity_bytes", "sharded", "compress",
                   bufs["single"] == bufs["sharded"]))
    checks.append(("sharded_parity_bits", "sharded", "retrieve",
                   bool(np.array_equal(outs["single"], outs["sharded"]))))


def run(scale=None, n: int = 1 << 20, smoke: bool = True,
        json_out: str = JSON_OUT, json_out_compress: str = JSON_OUT_COMPRESS):
    rows, checks, records, comp_records = [], [], [], []
    if n < 1 << 20:
        raise SystemExit(f"--n must be >= {1 << 20} (2^20) elements, got {n}")
    x = _field(n)
    eb = 1e-5
    repeat = 1 if smoke else 3
    variants = [
        ("numpy", Codec(eb=eb), ExecPolicy(backend="numpy")),
        ("jax", Codec(eb=eb), ExecPolicy(backend="jax")),
        ("jax_chunked", Codec(eb=eb, chunk_elems=1 << 18),
         ExecPolicy(backend="jax")),
    ]
    bufs = {}
    for name, codec, policy in variants:
        if name.startswith("jax"):
            codec.compress(x, policy)  # warm the jit caches out of timing
        arc, dt = timed(codec.compress, x, policy, repeat=repeat)
        bufs[name] = arc.tobytes()
        mbps = x.nbytes / dt / 1e6
        rows.append(csv_row(f"backend_speed/{x.size}el/{name}/compress",
                            dt * 1e6,
                            f"MBps={mbps:.1f};bytes={arc.nbytes}"))
        print(rows[-1])
        comp_records.append(dict(case=f"{x.size}el", variant=name,
                                 op="compress", seconds=dt, mbps=mbps,
                                 bytes=arc.nbytes))
    checks.append(("backend_parity_bytes", f"{x.size}el", "compress",
                   bufs["numpy"] == bufs["jax"]))

    # decode direction: v1 archive and the chunked v2 archive
    outs = {}
    _decode_rows(x, eb, bufs["numpy"], f"{x.size}el_v1", repeat, rows,
                 records, outs)
    _decode_rows(x, eb, bufs["jax_chunked"], f"{x.size}el_v2", repeat, rows,
                 records, outs)
    for case, by_bk in outs.items():
        checks.append(("decode_parity_bits", case, "decompress",
                       bool(np.array_equal(by_bk["numpy"], by_bk["jax"]))))

    # fused decode megakernel vs the pre-fusion jax baseline
    _fused_rows(x, eb, bufs["numpy"], rows, checks, records)

    # chunk-batch speed entry: batched vs looped dispatch counts + MB/s
    _chunk_batch_rows(x, eb, rows, checks, comp_records, records)

    # sharded entry: chunk grid over a device mesh vs single device
    _sharded_rows(x, eb, rows, checks, comp_records, records)

    if not smoke:
        y = _field(1 << 22)
        for name, codec, policy in variants:
            arc, dt = timed(codec.compress, y, policy, repeat=1)
            rows.append(csv_row(f"backend_speed/{y.size}el/{name}/compress",
                                dt * 1e6,
                                f"MBps={y.nbytes / dt / 1e6:.1f}"))
            print(rows[-1])
    # each artifact carries only the checks about the ops it records, so a
    # per-file "all ok" read is unambiguous about which direction failed
    def _check_dicts(ops):
        return [dict(name=c[0], case=c[1], op=c[2], ok=bool(c[3]))
                for c in checks if c[2] in ops]

    if json_out:
        with open(json_out, "w") as f:
            json.dump(dict(n=int(x.size), eb=eb,
                           refine_bounds=[REFINE_COARSE * eb,
                                          REFINE_FINE * eb],
                           records=records,
                           checks=_check_dicts(("decompress", "retrieve"))),
                      f, indent=2)
        print(f"wrote {json_out} ({len(records)} decode records)")
    if json_out_compress:
        with open(json_out_compress, "w") as f:
            json.dump(dict(n=int(x.size), eb=eb,
                           chunk_elems=CHUNK_ELEMS,
                           records=comp_records,
                           checks=_check_dicts(("compress",))),
                      f, indent=2)
        print(f"wrote {json_out_compress} ({len(comp_records)} compress "
              "records)")
    return rows, checks


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1 << 20,
                    help="elements in the benchmark field (>= 2^20)")
    ap.add_argument("--full", action="store_true",
                    help="best-of-3 timing plus a 4M-element field")
    ap.add_argument("--json-out", default=JSON_OUT,
                    help="decode-benchmark JSON artifact path ('' disables)")
    ap.add_argument("--json-out-compress", default=JSON_OUT_COMPRESS,
                    help="compress-benchmark JSON artifact path "
                         "('' disables)")
    args = ap.parse_args()
    _, checks = run(n=args.n, smoke=not args.full, json_out=args.json_out,
                    json_out_compress=args.json_out_compress)
    for name, ds, op, ok in checks:
        print(f"check {name}[{ds}/{op}]: {'ok' if ok else 'FAILED'}")
    if not all(c[-1] for c in checks):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
