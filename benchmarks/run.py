"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; claim-checks are summarized at the
end (a failed claim check is a regression against the paper's comparisons,
not a crash).

The ``backend_speed`` module (in the default set) also writes the
trendable JSON artifacts ``BENCH_compress.json`` and ``BENCH_decode.json``
to the working directory — run from the repo root so CI picks them up.
``BENCH_compress.json`` carries the chunk-batch speed entry: batched vs
looped kernel dispatch counts and MB/s for the vmapped shape-group engine.
The ``serve`` module drives the serving tier's mixed-fidelity workload
(per-call vs coalesced vs cached) and writes ``BENCH_serve.json``.

  PYTHONPATH=src python -m benchmarks.run [--scale 0.15] [--only fig5,...]
"""
from __future__ import annotations

import argparse
import sys

from . import (backend_speed, ckpt_bench, fig5_ratio, fig6_retrieval,
               fig7_bitrate, fig8_speed, fig10_psnr, serve_bench,
               table2_entropy, grad_compress_bench)

MODULES = {
    "fig5": fig5_ratio, "fig6": fig6_retrieval, "fig7": fig7_bitrate,
    "fig8": fig8_speed, "fig10": fig10_psnr, "table2": table2_entropy,
    "grad_compress": grad_compress_bench, "backend_speed": backend_speed,
    "serve": serve_bench, "ckpt": ckpt_bench,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)
    all_checks = []
    print("name,us_per_call,derived")
    for n in names:
        rows, checks = MODULES[n].run(args.scale)
        for r in rows:
            print(r)
        all_checks.extend(checks)
    ok = sum(1 for c in all_checks if c[-1])
    print(f"\n# claim-checks: {ok}/{len(all_checks)} hold", file=sys.stderr)
    for c in all_checks:
        if not c[-1]:
            print(f"#   FAILED: {c}", file=sys.stderr)


if __name__ == "__main__":
    main()
