"""Fig. 7: reconstruction error vs retrieval bitrate budget.

Paper claim: under the same bitrate, IPComp reconstructs the lowest L_inf
error (up to 99% lower); residual baselines form a staircase.
"""
from __future__ import annotations

import numpy as np

from .common import csv_row, datasets, progressive_compressors, timed
from repro.core import metrics

BITRATES = [0.5, 1.0, 2.0, 4.0, 8.0]


def run(scale=None):
    rows, checks = [], []
    for name, x in datasets(scale).items():
        rng = float(x.max() - x.min())
        eb = 1e-7 * rng
        blobs = {c.name: c.compress(x, eb) for c in progressive_compressors()}
        for bpp in BITRATES:
            budget = int(bpp * x.size / 8)
            errs, within = {}, {}
            for comp in progressive_compressors():
                (out, bytes_read, passes), dt = timed(
                    comp.retrieve, blobs[comp.name], max_bytes=budget)
                err = metrics.linf(x, out)
                errs[comp.name] = err
                # residual baselines whose coarsest rung exceeds the budget
                # blow past it (min-viable load); flag and exclude from the
                # "best error at this bitrate" comparison
                within[comp.name] = bytes_read <= budget * 1.02
                rows.append(csv_row(
                    f"fig7/{name}/bpp{bpp}/{comp.name}", dt * 1e6,
                    f"linf={err:.3e};read={bytes_read}"
                    f";within_budget={within[comp.name]}"))
            others = [v for k, v in errs.items()
                      if k != "ipcomp" and within[k]]
            if others and within["ipcomp"]:
                checks.append(("ipcomp_lowest_error_at_bitrate", name, bpp,
                               errs["ipcomp"] <= min(others) * 1.5 + 1e-12))
    return rows, checks
