"""The paper's Fig.-11 use case: different post-analyses need different
fidelity.  Curl of a velocity field stabilizes with ~0.3% of the data;
the Laplacian (second derivatives amplify high-frequency error) needs more.
One progressive session serves both from ONE archive without
recompression — each ladder rung fetches only the planes it adds.

  PYTHONPATH=src python examples/progressive_analysis.py
"""
import numpy as np

from repro import Codec, Fidelity
from repro.configs.paper import TABLE3, generate


def curl_mag(v):
    gz, gy, gx = np.gradient(v)
    return np.sqrt(gx ** 2 + gy ** 2 + gz ** 2)


def laplacian(v):
    out = np.zeros_like(v)
    for ax in range(v.ndim):
        out += np.gradient(np.gradient(v, axis=ax), axis=ax)
    return out


def rel_err(a, b):
    return float(np.linalg.norm(a - b) / (np.linalg.norm(a) + 1e-30))


def main():
    x = generate(TABLE3[2], scale=0.12)            # VelocityX-like
    rng = float(x.max() - x.min())
    archive = Codec(eb=1e-7 * rng).compress(x)
    ref_curl, ref_lap = curl_mag(x), laplacian(x)

    session = archive.open()
    print(f"archive {archive.nbytes/1e6:.2f} MB")
    print(f"{'loaded%':>8} {'curl rel-err':>14} {'laplace rel-err':>16}")
    ladder = (Fidelity.error_bound(e * rng)
              for e in (1e-2, 1e-3, 1e-4, 1e-5))
    for fid, out in session.ladder(ladder):
        frac = 100 * session.bytes_read / archive.nbytes
        print(f"{frac:7.1f}% {rel_err(ref_curl, curl_mag(out)):14.3e} "
              f"{rel_err(ref_lap, laplacian(out)):16.3e}")
    print("-> first-derivative analysis converges with a fraction of the "
          "bytes; the Laplacian needs more planes — load them incrementally.")


if __name__ == "__main__":
    main()
