"""Archive serving example: mixed-fidelity requests through the
continuous-batching retrieval server (``repro.serving``).

Compresses two fields, registers them with a :class:`RetrievalServer`
backed by a shared plane cache, submits a mixed-fidelity request wave
(coarse previews, byte-budgeted reads, full reads, and a refine chained
onto an earlier request), and drains the queue — printing per-request
accounting plus the cache/dispatch stats that make serving cheap:
requests reuse each other's decoded plane prefixes, and same-shape chunk
decodes from different requests share one batched kernel launch.

  PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np

from repro.api import Codec, Fidelity
from repro.serving import PlaneCache, RetrievalServer


def main():
    rng = np.random.default_rng(7)
    fields = {
        "turbulence": np.cumsum(
            rng.standard_normal((96, 96)), axis=0) / 10.0,
        "pressure": np.sin(np.linspace(0, 12, 64 * 64)
                           ).reshape(64, 64) * 5.0,
    }
    codec = Codec(eb=1e-5, chunk_elems=2048)

    cache = PlaneCache(max_bytes=8 << 20)
    server = RetrievalServer(cache=cache, coalesce=True)
    archives = {name: codec.compress(x) for name, x in fields.items()}
    for name, arc in archives.items():
        server.add_archive(name, arc)
        print(f"registered {name}: {arc!r}")

    # a mixed-fidelity wave: several consumers per archive, none equal
    wave = [
        server.submit("turbulence", Fidelity.error_bound(1e-2)),
        server.submit("turbulence", Fidelity.error_bound(1e-4)),
        server.submit("turbulence", Fidelity.full()),
        server.submit("pressure", Fidelity.error_bound(1e-2)),
        server.submit("pressure", Fidelity.bitrate(4.0)),
        server.submit("pressure", Fidelity.full()),
    ]
    # progressive chaining across requests: refine the coarse preview to
    # full precision -- only the missing planes are fetched
    refined = server.submit("turbulence", Fidelity.full(),
                            refine_of=wave[0])

    for req in server.drain():
        tag = f"{req.archive_id}/{req.fidelity}"
        if req.status == "done":
            print(f"  req{req.req_id} {tag}: bound={req.err_bound:.2e} "
                  f"bytes_read={req.bytes_read} "
                  f"latency={req.latency_s * 1e3:.1f}ms")
        else:
            print(f"  req{req.req_id} {tag}: FAILED ({req.error})")

    # served bits == private-session bits, always (the reference session
    # walks the same coarse -> full ladder the refine chain took)
    sess = archives["turbulence"].open()
    sess.read(Fidelity.error_bound(1e-2))
    assert np.array_equal(sess.read(Fidelity.full()), refined.result)
    for name, x in fields.items():
        full = [r for r in wave
                if r.archive_id == name and r.fidelity.kind == "full"][0]
        assert np.abs(full.result - x).max() <= codec.eb

    s = server.stats()
    print(f"ticks={s['ticks']} dispatches={s['counters']} ")
    print(f"cache: hit_rate={s['cache']['hit_rate']:.2f} "
          f"hits={s['cache']['hits']} "
          f"fetch_bytes_saved={s['cache']['fetch_bytes_saved']} "
          f"cached={s['cache']['bytes_cached']}B")
    assert s["cache"]["hits"] > 0, "mixed-fidelity wave must share prefixes"
    print("OK")


if __name__ == "__main__":
    main()
