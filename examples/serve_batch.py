"""Batched serving example (deliverable b): thin wrapper over the serving
launcher — heterogeneous prompts, continuous batched decode.

  PYTHONPATH=src python examples/serve_batch.py
"""
import subprocess
import sys

sys.exit(subprocess.call(
    [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen2-0.5b",
     "--reduced", "--requests", "8", "--max-new", "12"]))
