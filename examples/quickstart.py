"""Quickstart: compress a scientific field, retrieve progressively.

The object API in four moves: a ``Codec`` holds the bytes-affecting
spec, ``compress`` returns an ``Archive``, ``open()`` starts a
progressive session, and each ``read(Fidelity...)`` fetches only the
bitplanes the new target adds.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro import Codec, Fidelity
from repro.configs.paper import TABLE3, generate
from repro.core import metrics


def main():
    x = generate(TABLE3[0], scale=0.12)            # Density-like field
    rng = float(x.max() - x.min())

    archive = Codec(eb=1e-6, relative=True).compress(x)
    print(f"field {x.shape}  raw {x.nbytes/1e6:.1f} MB  "
          f"archive {archive.nbytes/1e6:.2f} MB  "
          f"CR={x.nbytes/archive.nbytes:.1f}")

    session = archive.open()
    ladder = [Fidelity.error_bound(e * rng) for e in (1e-2, 1e-4, 1e-6)]
    for fid, out in session.ladder(ladder):
        print(f"request L_inf <= {fid.value/rng:.0e}*range: "
              f"achieved {metrics.linf(x, out)/rng:.2e}*range, "
              f"read {session.bytes_read/1e6:.2f} MB "
              f"({100*session.bytes_read/archive.nbytes:.0f}% of archive), "
              f"single pass")


if __name__ == "__main__":
    main()
