"""Quickstart: compress a scientific field, retrieve progressively.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.paper import TABLE3, generate
from repro.core import compress, retrieve, open_archive, metrics


def main():
    x = generate(TABLE3[0], scale=0.12)            # Density-like field
    rng = float(x.max() - x.min())
    eb = 1e-6 * rng
    buf = compress(x, eb)
    print(f"field {x.shape}  raw {x.nbytes/1e6:.1f} MB  "
          f"archive {len(buf)/1e6:.2f} MB  CR={x.nbytes/len(buf):.1f}")

    reader = open_archive(buf)
    state = None
    for E_rel in (1e-2, 1e-4, 1e-6):
        out, state = retrieve(reader, error_bound=E_rel * rng, state=state)
        print(f"request L_inf <= {E_rel:.0e}*range: "
              f"achieved {metrics.linf(x, out)/rng:.2e}*range, "
              f"read {state.bytes_read/1e6:.2f} MB "
              f"({100*state.bytes_read/len(buf):.0f}% of archive), "
              f"single pass")


if __name__ == "__main__":
    main()
