"""End-to-end training driver example (deliverable b).

Runs the full production stack — sharded data pipeline, AdamW, progressive
IPComp checkpointing, fault-tolerant driver with an injected node failure —
on a CPU-sized model by default.  ``--full`` selects the real smollm-360m
config (use on accelerators; same code path).

  PYTHONPATH=src python examples/train_e2e.py --steps 120
"""
import argparse
import shutil
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    # fresh run: a leftover dir from an aborted run would resume mid-way
    shutil.rmtree("/tmp/repro_e2e_ckpt", ignore_errors=True)
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "smollm-360m", "--steps", str(args.steps),
           "--seq", "128", "--batch", "8",
           "--ckpt-every", str(max(10, args.steps // 4)),
           "--fail-at", str(args.steps // 2),
           "--progressive-restore",
           "--ckpt-dir", "/tmp/repro_e2e_ckpt"]
    if not args.full:
        cmd.append("--reduced")
    print(" ".join(cmd))
    sys.exit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
