"""Factored second-moment optimizer (Adafactor-style) for trillion-parameter
configs: O(rows+cols) state instead of O(rows*cols)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def init(p):
        if _factored(p.shape):
            return dict(r=jnp.zeros(p.shape[:-1], jnp.float32),
                        c=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
        return dict(v=jnp.zeros(p.shape, jnp.float32))
    return dict(stats=jax.tree_util.tree_map(
        init, params, is_leaf=lambda x: hasattr(x, "shape")),
        count=jnp.zeros((), jnp.int32))


def adafactor_update(grads, opt, params, lr, *, decay=0.99, eps=1e-30,
                     clip_norm=1.0, weight_decay=0.0):
    from .adamw import global_norm
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    count = opt["count"] + 1

    def upd(g, st, p):
        g = g.astype(jnp.float32) * scale
        g2 = g * g + eps
        if "r" in st:
            r = decay * st["r"] + (1 - decay) * jnp.mean(g2, axis=-1)
            c = decay * st["c"] + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = (r[..., None] * c[..., None, :]
                     / jnp.maximum(jnp.mean(r, axis=-1, keepdims=True)
                                   [..., None], eps))
            step = g / jnp.sqrt(jnp.maximum(denom, eps))
            new_st = dict(r=r, c=c)
        else:
            v = decay * st["v"] + (1 - decay) * g2
            step = g / jnp.sqrt(jnp.maximum(v, eps))
            new_st = dict(v=v)
        step = lr * step + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype), new_st

    leaves_p, tdef = jax.tree_util.tree_flatten(params)
    leaves_g = jax.tree_util.tree_leaves(grads)
    is_stat = lambda x: isinstance(x, dict) and ("r" in x or "v" in x)
    leaves_s = tdef.flatten_up_to(opt["stats"])
    out = [upd(g, s, p) for g, s, p in zip(leaves_g, leaves_s, leaves_p)]
    return (tdef.unflatten([o[0] for o in out]),
            dict(stats=tdef.unflatten([o[1] for o in out]), count=count),
            gnorm)
