"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, base_lr=3e-4, warmup=100, total=10000,
                    min_ratio=0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * (step + 1) / max(warmup, 1)  # step 0 must not be lr=0
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)
