from .adamw import adamw_init, adamw_update, TrainState, make_train_state
from .adafactor import adafactor_init, adafactor_update
from .schedule import cosine_schedule

__all__ = ["adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
           "TrainState", "make_train_state", "cosine_schedule"]
