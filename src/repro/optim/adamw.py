"""AdamW with global-norm clipping; optimizer-state dtype configurable
(bf16 moments at trillion scale; see DESIGN.md §4)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


def adamw_init(params, dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return dict(m=jax.tree_util.tree_map(zeros, params),
                v=jax.tree_util.tree_map(zeros, params),
                count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(grads, opt, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, clip_norm=1.0):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    count = opt["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m1 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v1 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        # clamp: a lossy-restored v can carry tiny negative error -> NaN sqrt
        v1 = jnp.maximum(v1, 0.0)
        step = lr * (m1 / c1) / (jnp.sqrt(v1 / c2) + eps)
        step = step + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype), \
            m1.astype(m.dtype), v1.astype(v.dtype)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt["m"])
    flat_v = jax.tree_util.tree_leaves(opt["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, dict(m=new_m, v=new_v, count=count), gnorm


@jax.tree_util.register_pytree_node_class
@dataclass
class TrainState:
    params: Any
    opt: Any
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def make_train_state(params, opt_kind: str = "adamw",
                     opt_dtype=jnp.float32) -> TrainState:
    from .adafactor import adafactor_init
    if opt_kind == "adafactor":
        opt = adafactor_init(params)
    else:
        opt = adamw_init(params, opt_dtype)
    return TrainState(params=params, opt=opt, step=jnp.zeros((), jnp.int32))
