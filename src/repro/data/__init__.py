from .pipeline import TokenStream, make_batch_iterator

__all__ = ["TokenStream", "make_batch_iterator"]
