"""Deterministic, restartable, sharded synthetic data pipeline.

Design mirrors a production grain/tf.data stack on the axes that matter for
fault tolerance:

  * **Stateless indexing** — batch ``i`` is a pure function of (seed, i), so
    restart-from-step-N needs no pipeline checkpoint and every data shard
    can be recomputed on any host (elastic re-sharding after node loss).
  * **Host sharding** — each process materializes only its
    ``(process_index, process_count)`` slice of the global batch.
  * **Prefetch** — a background thread keeps ``depth`` batches ready so the
    accelerator never waits on the host (CPU container: same code path).

The token distribution is a mixture of Zipfian unigrams and short Markov
repeats — enough structure for loss curves to be meaningfully decreasing.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    process_index: int = 0
    process_count: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.process_count == 0
        return self.global_batch // self.process_count

    def batch_at(self, step: int) -> np.ndarray:
        """Batch for global step ``step`` — pure function, restart-safe."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 7919 + self.process_index)
        B, S = self.local_batch, self.seq_len + 1
        # Zipf unigrams, clipped to vocab
        base = rng.zipf(1.3, size=(B, S)).astype(np.int64)
        tokens = np.minimum(base, self.vocab - 1)
        # inject Markov repeats: token[t] = token[t-k] for short runs
        n_runs = max(1, S // 64)
        for b in range(B):
            starts = rng.integers(1, S - 8, n_runs)
            for st in starts:
                ln = int(rng.integers(4, 8))
                k = int(rng.integers(1, min(st, 16) + 1))
                end = min(st + ln, S)
                tokens[b, st:end] = tokens[b, st - k:end - k]
        return tokens.astype(np.int32)


def make_batch_iterator(stream: TokenStream, start_step: int = 0,
                        prefetch_depth: int = 2,
                        extras: Optional[Dict[str, tuple]] = None
                        ) -> Iterator[Dict[str, np.ndarray]]:
    """Prefetching iterator over dict batches starting at ``start_step``.

    ``extras`` maps name -> shape for modality-stub inputs (frames/prefix)
    generated deterministically alongside tokens.
    """
    q: "queue.Queue" = queue.Queue(maxsize=prefetch_depth)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            batch = {"tokens": stream.batch_at(step)}
            if extras:
                rng = np.random.default_rng(stream.seed * 31 + step)
                for name, shape in extras.items():
                    batch[name] = rng.standard_normal(shape).astype(np.float32)
            q.put((step, batch))
            step += 1

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            step, batch = q.get()
            yield batch
    finally:
        stop.set()
