from .driver import TrainDriver, DriverConfig, FailureInjector

__all__ = ["TrainDriver", "DriverConfig", "FailureInjector"]
