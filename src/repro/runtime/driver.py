"""Fault-tolerant training driver.

Responsibilities at 1000+-node posture (the CPU container exercises every
code path with simulated failures):

  * checkpoint/restart — periodic progressive checkpoints (IPComp), atomic
    LATEST pointer, resume picks up step + data position (stateless data
    indexing makes the pipeline resume free).
  * node-failure handling — a step failure raises; the driver restores the
    last checkpoint and continues.  ``FailureInjector`` simulates crashes
    at chosen steps for the integration tests.
  * straggler mitigation — per-step wall times feed an EWMA; steps slower
    than ``straggler_factor``x the EWMA are logged and counted (on a real
    fleet this signal drives hot-spare swaps; here it is surfaced as a
    metric so the control loop is testable).
  * elastic restart — restore maps saved logical arrays onto whatever mesh
    the new world size provides (checkpoints are sharding-agnostic).
  * restore-while-refine — with ``progressive_restore`` on, a restart
    reads only the bitplanes needed for ``restore_weight_error`` and
    starts stepping immediately while a background
    :class:`~repro.checkpoint.RestoreSession` refiner streams the
    remaining planes; once ready, the refinement is folded into the live
    state as a per-leaf delta (``w <- w + (refined - coarse)``), so it
    composes with the training steps taken on the coarse weights.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..checkpoint.store import _leaf_id
from ..data.pipeline import TokenStream


def _non_param_leaves(state):
    """Leaf-id predicate marking everything OUTSIDE ``state.params`` as
    precision-critical for a coarse restore.  Model weights tolerate a
    range-relative error (training recovers, and the background refine
    folds the residual back in), but optimizer statistics do not: Adam's
    second moment is a near-zero positive field whose entries flip sign
    under the same bound, collapsing the ``sqrt(v)`` denominator and
    blowing up the first post-restart updates.  States without a
    ``params`` attribute restore fully (no leaf is coarse)."""
    params = getattr(state, "params", None)
    if params is None:
        return lambda lid: True
    flat, _ = jax.tree_util.tree_flatten_with_path(state)
    param_leaves = {id(leaf) for leaf in jax.tree_util.tree_leaves(params)}
    exact_ids = {_leaf_id(p) for p, leaf in flat
                 if id(leaf) not in param_leaves}
    return lambda lid: lid in exact_ids


@dataclass
class DriverConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    keep_n: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    rel_eb: float = 1e-6
    #: coarse-first restarts: restore at ``restore_weight_error`` and step
    #: immediately; a background refiner streams the remaining planes
    progressive_restore: bool = False
    restore_weight_error: float = 1e-2
    #: refine to full precision in the background after a progressive
    #: restore (False: stay at the coarse weights)
    restore_refine: bool = True


class FailureInjector:
    """Deterministic crash simulation for integration tests."""

    def __init__(self, fail_at_steps: Optional[List[int]] = None):
        self.fail_at = set(fail_at_steps or [])
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclass
class TrainDriver:
    step_fn: Callable        # (state, batch) -> (state, metrics)
    stream: TokenStream
    ckpt: CheckpointManager
    cfg: DriverConfig = field(default_factory=DriverConfig)
    injector: Optional[FailureInjector] = None
    extras: Optional[Dict[str, tuple]] = None

    def _batch(self, step: int) -> Dict[str, np.ndarray]:
        batch = {"tokens": self.stream.batch_at(step)}
        if self.extras:
            rng = np.random.default_rng(self.stream.seed * 31 + step)
            for name, shape in self.extras.items():
                batch[name] = rng.standard_normal(shape).astype(np.float32)
        return batch

    def run(self, state) -> Dict[str, Any]:
        """Run to total_steps with restart-on-failure. Returns a report."""
        session = None      # live RestoreSession with a background refiner
        coarse = None       # the tree the session's coarse round produced
        refined_adoptions = 0

        def restore(cur):
            """(step, tree) from the latest checkpoint; progressive mode
            returns coarse weights immediately and leaves ``session``
            refining in the background."""
            nonlocal session, coarse
            if session is not None:     # restart during a refine: drop it
                session.close()
                session, coarse = None, None
            if self.cfg.progressive_restore and \
                    hasattr(self.ckpt, "restore_progressive"):
                last, tree, sess = self.ckpt.restore_progressive(
                    cur, weight_error=self.cfg.restore_weight_error,
                    refine_to="full" if self.cfg.restore_refine else None,
                    exact=_non_param_leaves(cur))
                if sess is not None and self.cfg.restore_refine:
                    session, coarse = sess, tree
                elif sess is not None:
                    sess.close()
                return last, tree
            return self.ckpt.restore_latest(cur)

        def adopt_refined(cur, block=False):
            """Fold a finished background refine into the live state as a
            per-leaf delta on the coarse tree — it composes with the
            steps taken since restore; the optimizer state (part of the
            checkpointed tree) refines the same way."""
            nonlocal session, coarse, refined_adoptions
            if session is None:
                return cur
            refined = session.refined() if block else session.poll_refined()
            if refined is None:
                return cur
            cur = jax.tree_util.tree_map(lambda s, f, c: s + (f - c),
                                         cur, refined, coarse)
            session.close()
            session, coarse = None, None
            refined_adoptions += 1
            return cur

        start, restored = restore(state)
        if start is not None:
            state = restored
            step = start
        else:
            step = 0
        losses: List[float] = []
        straggler_steps: List[int] = []
        restarts = 0
        ewma = None
        while step < self.cfg.total_steps:
            t0 = time.time()
            try:
                if self.injector:
                    self.injector.maybe_fail(step)
                state, metrics = self.step_fn(state, self._batch(step))
            except RuntimeError as e:
                # node failure: restore last checkpoint, rebuild state
                restarts += 1
                last, restored = restore(state)
                if last is None:
                    raise RuntimeError("failure before first checkpoint") from e
                state = restored
                step = last
                continue
            state = adopt_refined(state)
            dt = time.time() - t0
            ewma = dt if ewma is None else \
                (1 - self.cfg.ewma_alpha) * ewma + self.cfg.ewma_alpha * dt
            if ewma and dt > self.cfg.straggler_factor * ewma and step > 3:
                straggler_steps.append(step)
            losses.append(float(metrics["loss"]))
            step += 1
            if step % self.cfg.ckpt_every == 0:
                state = adopt_refined(state, block=step == self.cfg.ckpt_every)
                self.ckpt.save(step, state)
        state = adopt_refined(state, block=True)  # never persist coarse-only
        self.ckpt.save(step, state)
        return dict(final_step=step, losses=losses, restarts=restarts,
                    stragglers=straggler_steps,
                    refined_adoptions=refined_adoptions)
