"""Fault-tolerant training driver.

Responsibilities at 1000+-node posture (the CPU container exercises every
code path with simulated failures):

  * checkpoint/restart — periodic progressive checkpoints (IPComp), atomic
    LATEST pointer, resume picks up step + data position (stateless data
    indexing makes the pipeline resume free).
  * node-failure handling — a step failure raises; the driver restores the
    last checkpoint and continues.  ``FailureInjector`` simulates crashes
    at chosen steps for the integration tests.
  * straggler mitigation — per-step wall times feed an EWMA; steps slower
    than ``straggler_factor``x the EWMA are logged and counted (on a real
    fleet this signal drives hot-spare swaps; here it is surfaced as a
    metric so the control loop is testable).
  * elastic restart — restore maps saved logical arrays onto whatever mesh
    the new world size provides (checkpoints are sharding-agnostic).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..data.pipeline import TokenStream


@dataclass
class DriverConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    keep_n: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    rel_eb: float = 1e-6


class FailureInjector:
    """Deterministic crash simulation for integration tests."""

    def __init__(self, fail_at_steps: Optional[List[int]] = None):
        self.fail_at = set(fail_at_steps or [])
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclass
class TrainDriver:
    step_fn: Callable        # (state, batch) -> (state, metrics)
    stream: TokenStream
    ckpt: CheckpointManager
    cfg: DriverConfig = field(default_factory=DriverConfig)
    injector: Optional[FailureInjector] = None
    extras: Optional[Dict[str, tuple]] = None

    def _batch(self, step: int) -> Dict[str, np.ndarray]:
        batch = {"tokens": self.stream.batch_at(step)}
        if self.extras:
            rng = np.random.default_rng(self.stream.seed * 31 + step)
            for name, shape in self.extras.items():
                batch[name] = rng.standard_normal(shape).astype(np.float32)
        return batch

    def run(self, state) -> Dict[str, Any]:
        """Run to total_steps with restart-on-failure. Returns a report."""
        start, restored = self.ckpt.restore_latest(state)
        if start is not None:
            state = restored
            step = start
        else:
            step = 0
        losses: List[float] = []
        straggler_steps: List[int] = []
        restarts = 0
        ewma = None
        while step < self.cfg.total_steps:
            t0 = time.time()
            try:
                if self.injector:
                    self.injector.maybe_fail(step)
                state, metrics = self.step_fn(state, self._batch(step))
            except RuntimeError as e:
                # node failure: restore last checkpoint, rebuild state
                restarts += 1
                last, restored = self.ckpt.restore_latest(state)
                if last is None:
                    raise RuntimeError("failure before first checkpoint") from e
                state = restored
                step = last
                continue
            dt = time.time() - t0
            ewma = dt if ewma is None else \
                (1 - self.cfg.ewma_alpha) * ewma + self.cfg.ewma_alpha * dt
            if ewma and dt > self.cfg.straggler_factor * ewma and step > 3:
                straggler_steps.append(step)
            losses.append(float(metrics["loss"]))
            step += 1
            if step % self.cfg.ckpt_every == 0:
                self.ckpt.save(step, state)
        self.ckpt.save(step, state)
        return dict(final_step=step, losses=losses, restarts=restarts,
                    stragglers=straggler_steps)
