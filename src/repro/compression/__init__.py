from .grad import compress_gradients, compressed_psum, init_error_feedback

__all__ = ["compress_gradients", "compressed_psum", "init_error_feedback"]
