"""IPComp-style gradient compression for cross-pod all-reduce.

The paper's pipeline (error-bounded quantize -> negabinary -> bitplane
truncation, §4) applied to distributed training traffic: gradients are
quantized against a relative error bound, the negabinary bitplanes below
the kept-precision cut are dropped (exactly the paper's progressive
truncation), and the truncation residual is carried to the next step as
error feedback (so convergence is preserved — the lossy error is bounded
per step AND unbiased over time).

``compressed_psum`` is the collective-level version: inside shard_map over
the "pod" axis, the all-reduce operates on int16 words (kept bitplanes)
instead of f32 — a 2x wire-format reduction plus the entropy savings a real
fabric codec would add on the sparse high planes.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _trunc_occupied(q, keep_bits: int):
    """Drop LSB planes relative to the OCCUPIED bit width (paper §4.4:
    truncation counts from each level's nbits, not the word width)."""
    maxq = jnp.max(jnp.abs(q)).astype(jnp.float32)
    nbits = jnp.ceil(jnp.log2(maxq + 1.0)).astype(jnp.int32)
    shift = jnp.maximum(nbits - keep_bits, 0)
    return (q >> shift) << shift, shift


def _quantize_leaf(g, ef, rel_eb: float, keep_bits: int):
    """Returns (q int32 truncated, scale, new_error_feedback)."""
    g = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) * rel_eb
    q = jnp.round(g / (2.0 * scale)).astype(jnp.int32)
    if keep_bits < 32:
        q, _ = _trunc_occupied(q, keep_bits)
    recon = q.astype(jnp.float32) * (2.0 * scale)
    return q, scale, g - recon


def compress_gradients(grads, ef, *, rel_eb: float = 1e-3,
                       keep_bits: int = 16):
    """Error-feedback compressed gradients.

    Returns (dequantized grads ready for the optimizer, new error feedback,
    compressed_bits_per_value metric).
    """
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef)
    qs, news = [], []
    for g, e in zip(flat_g, flat_e):
        q, scale, err = _quantize_leaf(g, e, rel_eb, keep_bits)
        qs.append(q.astype(jnp.float32) * (2.0 * scale))
        news.append(err)
    return tdef.unflatten(qs), tdef.unflatten(news), float(keep_bits)


@functools.partial(jax.jit, static_argnames=("axis_name", "keep_bits",
                                             "rel_eb"))
def _psum_body(x, axis_name: str, keep_bits: int, rel_eb: float):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) * rel_eb
    scale = jax.lax.pmax(scale, axis_name)       # shared scale across pods
    q = jnp.round(x / (2.0 * scale)).astype(jnp.int32)
    if keep_bits < 32:
        q, shift = _trunc_occupied(q, keep_bits)
        shift = jax.lax.pmax(shift, axis_name)   # consistent wire format
        q = (q >> shift) << shift
        # wire format: kept planes travel as TRUE int16 words (the HLO
        # all-reduce is s16) when the pod-sum cannot overflow: |q|<2^keep,
        # summed over npods pods -> keep_bits + log2(npods) <= 15
        if keep_bits <= 14:
            q16 = (q >> shift).astype(jnp.int16)
            s = jax.lax.psum(q16, axis_name).astype(jnp.int32)
            return (s << shift).astype(jnp.float32) * (2.0 * scale)
    return jax.lax.psum(q, axis_name).astype(jnp.float32) * (2.0 * scale)


def compressed_psum(x, axis_name: str, *, keep_bits: int = 16,
                    rel_eb: float = 1e-4):
    """Error-bounded compressed all-reduce over ``axis_name``.

    Use inside shard_map with the "pod" axis manual (DESIGN.md §4):
    the summand travels as int16 bitplane words instead of f32.
    """
    return _psum_body(x, axis_name, keep_bits, rel_eb)
