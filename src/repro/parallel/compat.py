"""Version-tolerant jax API shims for the parallel substrate.

Every module that places work on a device mesh — the training launcher
(``launch/steps.py``), the logical-axis context (``parallel.api``), and the
codec's sharded chunk-grid executor (``parallel.codec_mesh``, see
``docs/architecture.md``) — goes through this file instead of calling jax's
mesh/shard APIs directly, because those APIs moved across the releases this
repo supports.  Two shims:

:func:`shard_map`
    ``shard_map`` moved twice across jax releases:

      * old:  ``jax.experimental.shard_map.shard_map(f, mesh, in_specs,
              out_specs, check_rep=...)``
      * new:  ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=...,
              axis_names=..., check_vma=...)``

    Call sites in this repo use the *new* keyword vocabulary
    (``axis_names``, ``check_vma``); the wrapper translates to whatever the
    installed jax provides so the same code runs on both sides of the
    rename.  On the legacy API, axes not named manual are forwarded via
    ``auto=`` (the legacy default is manual-everywhere, which would cost
    SPMD sharding on the untouched axes — see the inline note).

:func:`make_mesh`
    ``jax.make_mesh`` (device-order-aware constructor) only exists on
    newer jax; older releases spell it ``jax.sharding.Mesh`` over an
    explicit device array.  The wrapper takes (axis sizes, axis names,
    optional explicit devices) and returns a :class:`jax.sharding.Mesh`
    either way.

The contract both shims keep: pure API translation, no policy.  Axis
layout / sizing decisions live with the callers (``launch/mesh.py`` for
training, ``parallel.codec_mesh`` for the codec).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def shard_map(f, *, mesh, in_specs, out_specs, axis_names: Optional[set] = None,
              check_vma: bool = False):
    """Map ``f`` over shards of ``mesh`` (new-API keywords on any jax)."""
    if hasattr(jax, "shard_map"):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if axis_names is not None:
            kw["axis_names"] = axis_names
        try:
            return jax.shard_map(f, check_vma=check_vma, **kw)
        except TypeError:  # transitional releases: check_rep instead
            return jax.shard_map(f, check_rep=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    # legacy API is manual-by-default: axes NOT named manual must be passed
    # via auto=, or e.g. steps.py's pod-manual train step would lose SPMD
    # sharding over the data/model axes (every device recomputing the full
    # per-pod step)
    kw = {}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma), **kw)


def make_mesh(axis_shape: Tuple[int, ...], axis_names: Tuple[str, ...],
              devices: Optional[Sequence] = None) -> "jax.sharding.Mesh":
    """Build a :class:`jax.sharding.Mesh` on any supported jax release.

    ``axis_shape``/``axis_names`` follow ``jax.make_mesh``; ``devices``
    optionally pins an explicit device list (first ``prod(axis_shape)``
    local devices by default).  Newer jax goes through ``jax.make_mesh``
    (which may reorder devices for interconnect locality) only when the
    device list is implicit — an explicit list is always honored verbatim,
    on every release, so callers that slice ``jax.devices()`` themselves
    (e.g. ``codec_mesh.codec_mesh(n)``) get a deterministic mesh.
    """
    from jax.sharding import Mesh

    if devices is None:
        if hasattr(jax, "make_mesh"):
            return jax.make_mesh(axis_shape, axis_names)
        devices = jax.devices()[: int(np.prod(axis_shape))]
    return Mesh(np.asarray(devices).reshape(axis_shape), axis_names)
