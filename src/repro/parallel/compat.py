"""Version-tolerant jax API shims for the parallel substrate.

``shard_map`` moved twice across jax releases:

  * old:  ``jax.experimental.shard_map.shard_map(f, mesh, in_specs,
          out_specs, check_rep=...)``
  * new:  ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=...,
          axis_names=..., check_vma=...)``

Call sites in this repo use the *new* keyword vocabulary (``axis_names``,
``check_vma``); this wrapper translates to whatever the installed jax
provides so the same code runs on both sides of the rename.
"""
from __future__ import annotations

from typing import Optional

import jax


def shard_map(f, *, mesh, in_specs, out_specs, axis_names: Optional[set] = None,
              check_vma: bool = False):
    """Map ``f`` over shards of ``mesh`` (new-API keywords on any jax)."""
    if hasattr(jax, "shard_map"):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if axis_names is not None:
            kw["axis_names"] = axis_names
        try:
            return jax.shard_map(f, check_vma=check_vma, **kw)
        except TypeError:  # transitional releases: check_rep instead
            return jax.shard_map(f, check_rep=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    # legacy API is manual-by-default: axes NOT named manual must be passed
    # via auto=, or e.g. steps.py's pod-manual train step would lose SPMD
    # sharding over the data/model axes (every device recomputing the full
    # per-pod step)
    kw = {}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma), **kw)
