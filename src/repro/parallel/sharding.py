"""Parameter / input / cache sharding rules (DP + FSDP + TP + EP + SP).

Rules are matched on parameter-tree paths and tensor rank; every rule is
divisibility-checked against the actual dim and the mesh, falling back to
replication — the engine therefore produces a *valid* sharding for every
assigned architecture on every mesh (the multi-pod dry-run's contract).

Scheme (single-pod mesh ("data","model") = (16,16); multi-pod adds "pod"):
  batch              -> ("pod","data")                      DP
  weights' d_model   -> "data"                              FSDP (ZeRO-3)
  attn heads / ff / experts / vocab -> "model"              TP / EP
  KV-cache sequence  -> "model"                             SP (decode)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from .api import resolve_axis
from ..models.config import ModelConfig, ShapeConfig

P = PartitionSpec


def _spec(mesh: Mesh, shape, logicals) -> PartitionSpec:
    return P(*[resolve_axis(mesh, l, d) for l, d in zip(logicals, shape)])


#: (path-suffix, rank) -> logical axes; L-stacked block params have a
#: leading layer dim (None).  Matched longest-suffix-first.
_PARAM_RULES = [
    (("embed",), (None, "d_tp")),
    (("head",), ("d_fsdp", "vocab")),
    (("attn", "wq"), (None, "d_fsdp", "heads", None)),
    (("attn", "wk"), (None, "d_fsdp", "kv_heads", None)),
    (("attn", "wv"), (None, "d_fsdp", "kv_heads", None)),
    (("attn", "wo"), (None, "heads", None, "d_fsdp")),
    (("attn", "bq"), (None, "heads", None)),
    (("attn", "bk"), (None, "kv_heads", None)),
    (("attn", "bv"), (None, "kv_heads", None)),
    (("xattn", "wq"), (None, "d_fsdp", "heads", None)),
    (("xattn", "wk"), (None, "d_fsdp", "kv_heads", None)),
    (("xattn", "wv"), (None, "d_fsdp", "kv_heads", None)),
    (("xattn", "wo"), (None, "heads", None, "d_fsdp")),
    (("mlp", "w1"), (None, "d_fsdp", "ff")),
    (("mlp", "w3"), (None, "d_fsdp", "ff")),
    (("mlp", "w2"), (None, "ff", "d_fsdp")),
    (("moe", "router"), (None, "d_fsdp", "experts")),
    (("moe", "w1"), (None, "experts", "d_fsdp", None)),
    (("moe", "w3"), (None, "experts", "d_fsdp", None)),
    (("moe", "w2"), (None, "experts", None, "d_fsdp")),
    (("ssm", "in_x"), (None, "d_fsdp", "ff")),
    (("ssm", "in_z"), (None, "d_fsdp", "ff")),
    (("ssm", "in_B"), (None, "d_fsdp", None)),
    (("ssm", "in_C"), (None, "d_fsdp", None)),
    (("ssm", "in_dt"), (None, "d_fsdp", "ssm_heads")),
    (("ssm", "out"), (None, "ff", "d_fsdp")),
]


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return tuple(out)


def param_sharding(cfg: ModelConfig, mesh: Mesh, params_shape,
                   fsdp: bool = True) -> Any:
    """Tree of NamedSharding matching ``params_shape`` (ShapeDtypeStructs).

    ``fsdp=False`` (inference): weights are TP-sharded only.  FSDP's d-axis
    sharding contracts against the data axis that also shards the batch, so
    SPMD resolves matmuls with partial sums + an all-reduce of seq-length
    activations — 11.5 GB/layer at yi-6b prefill_32k (§Perf it.1 of the
    collective-bound cell).  With no optimizer state to shard, inference
    prefers replicated-d weights (the all-reduce disappears).
    """

    def assign(path, leaf):
        names = _path_names(path)
        stacked = names[0] in ("blocks", "enc_blocks")
        for suffix, logicals in _PARAM_RULES:
            if len(names) >= len(suffix) and tuple(names[-len(suffix):]) == suffix:
                logi = list(logicals)
                if not fsdp:
                    logi = [None if l == "d_fsdp" else l for l in logi]
                if len(logi) != len(leaf.shape):
                    # unstacked variant (e.g. encoder tested standalone)
                    logi = logi[1:] if len(logi) == len(leaf.shape) + 1 else \
                        [None] * len(leaf.shape)
                return NamedSharding(mesh, _spec(mesh, leaf.shape, logi))
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def batch_sharding(cfg: ModelConfig, mesh: Mesh, batch_shape) -> Any:
    def assign(path, leaf):
        logi = ["batch"] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, _spec(mesh, leaf.shape, logi))
    return jax.tree_util.tree_map_with_path(assign, batch_shape)


def cache_sharding(cfg: ModelConfig, mesh: Mesh, cache_shape) -> Any:
    """KV caches: (L, B, S, KV, hd) -> (None, batch, SP, None, None);
    SSM state: (L, B, H, P, N) -> (None, batch, TP(H), None, None)."""

    def assign(path, leaf):
        names = _path_names(path)
        key = names[-1]
        if key in ("k", "v", "ek", "ev"):
            logi = [None, "batch", "seq", None, None]
        elif key == "ssm":
            logi = [None, "batch", "ssm_heads", None, None]
        else:  # scalar length counter
            logi = [None] * len(leaf.shape)
        return NamedSharding(mesh, _spec(mesh, leaf.shape, logi))

    return jax.tree_util.tree_map_with_path(assign, cache_shape)


def replicated(mesh: Mesh, tree) -> Any:
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, P(*([None] * len(leaf.shape)))), tree)
