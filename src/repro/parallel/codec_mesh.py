"""Codec chunk-grid mesh: the sharded-execution seam of the v2 pipeline.

The chunked (``IPC2``) container frames independently decodable axis-0
slabs, and the shape-group scheduler already stacks equal-shaped chunks
into one batch array per group (see ``core/pipeline/encode.py`` and
``docs/architecture.md``).  That stack axis is a pure data-parallel axis:
no chunk ever reads another chunk's data, in either codec direction.  This
module maps it onto devices:

  * :func:`codec_mesh` builds the 1-D device mesh (axis ``"chunks"``) the
    sharded kernel entry points shard over;
  * :func:`resolve_shard` turns the user-facing ``shard=`` argument of
    ``compress`` / ``retrieve`` / ``refine`` / ``decompress``
    (``None`` | ``"auto"`` | an explicit 1-D ``Mesh``) into a mesh or
    ``None``;
  * :func:`shard_vmap` wraps a per-chunk kernel function in
    ``vmap``-inside-``shard_map``: every device runs the same vmapped
    kernel on its local slice of the chunk stack — one collective-free
    launch per device per call;
  * :func:`pad_to_shards` rounds a ragged group's stack up to a multiple
    of the mesh size so ``shard_map`` can split it evenly (pad problems
    are all-zero and their outputs are sliced off; the codec never sees
    them).

Mesh construction and ``shard_map`` itself go through the version-tolerant
``parallel.compat`` shims.  The sharded path is an execution detail by
contract: archives stay byte-identical and reconstructions bit-identical
to the single-device path (``tests/test_sharded_codec.py`` pins this), so
``shard=`` can differ between the writer and every reader.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, PartitionSpec

from . import compat

#: the codec mesh's only axis: position in the stacked chunk group
CODEC_AXIS = "chunks"

AUTO = "auto"


def codec_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D mesh over the first ``n_devices`` local devices (all by default).

    The deterministic ``jax.devices()`` prefix order matters: dispatch
    accounting and the parity tests assume device i always holds stack
    rows ``[i*per_dev, (i+1)*per_dev)``.
    """
    n = jax.device_count() if n_devices is None else int(n_devices)
    if not 1 <= n <= jax.device_count():
        raise ValueError(f"codec mesh needs 1..{jax.device_count()} local "
                         f"devices, got {n}")
    return compat.make_mesh((n,), (CODEC_AXIS,), devices=jax.devices()[:n])


def shard_count(mesh: Mesh) -> int:
    """Devices in a codec mesh (validates it is 1-D)."""
    if len(mesh.axis_names) != 1:
        raise ValueError("codec sharding needs a 1-D mesh (one chunk-stack "
                         f"axis); got axes {tuple(mesh.axis_names)}")
    return int(mesh.devices.size)


def resolve_shard(shard) -> Optional[Mesh]:
    """User-facing ``shard=`` -> codec mesh or None (unsharded).

    ``None``/``False`` -> unsharded.  ``"auto"`` -> a mesh over every
    local device when there is more than one, else None — single-device
    "auto" stays on the plain batched path rather than paying shard_map
    overhead for a 1-way split.  An explicit :class:`Mesh` is validated
    (1-D) and used as-is, including the 1-device case (useful for parity
    tests).  Whether the *backend* can shard is the pipeline's call
    (``CodecBackend.shards_encode`` / ``shards_decode``): backends without
    sharded primitives fall back to their scalar/batched path.
    """
    if shard is None or shard is False:
        return None
    if isinstance(shard, Mesh):
        shard_count(shard)  # validates 1-D
        return shard
    if shard == AUTO:
        return codec_mesh() if jax.device_count() > 1 else None
    raise ValueError(f"shard must be None, 'auto', or a 1-D Mesh; "
                     f"got {shard!r}")


def pad_to_shards(b: int, mesh: Mesh) -> int:
    """Rows to append so a ``b``-row stack splits evenly over the mesh."""
    return (-b) % shard_count(mesh)


def shard_vmap(fn, mesh: Mesh, *, n_out: int = 1):
    """``shard_map(vmap(fn))`` over axis 0 of every argument.

    ``fn`` is a per-chunk kernel function (the exact function the batched
    entry points vmap); the returned callable takes stacked arrays whose
    leading dimension is a multiple of the mesh size (see
    :func:`pad_to_shards`) and runs ``vmap(fn)`` on each device's local
    rows.  ``n_out`` is the number of outputs (each sharded the same way).
    No collectives are emitted — the chunk axis is embarrassingly parallel
    — so the per-device program is exactly the single-device batched
    program on a smaller stack, which is why sharded results are
    bit-identical.
    """
    spec = PartitionSpec(CODEC_AXIS)
    out_specs = spec if n_out == 1 else tuple(spec for _ in range(n_out))
    return compat.shard_map(jax.vmap(fn), mesh=mesh, in_specs=spec,
                            out_specs=out_specs)
