"""Logical-axis sharding context.

Models call ``shard_act(x, "batch", None, "heads", None)`` with *logical*
axis names; a context installed by the launcher maps them to mesh axes with
divisibility checks (falling back to replication — e.g. qwen2's 14 heads
cannot tile a 16-way model axis, so its attention runs data-parallel while
its FFN/vocab still use TP; see DESIGN.md §4).  Without a context the call
is a no-op, so the same model code runs in CPU unit tests.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_STATE = threading.local()

#: logical name -> mesh axis (or tuple of axes for the batch dimension)
LOGICAL_TO_MESH = {
    "batch": ("pod", "data"),
    "seq": ("model",),        # sequence sharding (KV caches, long-context)
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "d_fsdp": ("data",),      # FSDP weight sharding
    "d_tp": ("model",),       # embedding d: vocab-sharded gathers trip an
                              # XLA:CPU SPMD crash on 3-axis meshes
    "ssm_heads": ("model",),
}


def _mesh_axes(mesh: Mesh, want: Tuple[str, ...]) -> Tuple[str, ...]:
    return tuple(a for a in want if a in mesh.axis_names)


def resolve_axis(mesh: Mesh, logical: Optional[str], dim: int):
    """Mesh axes for one tensor dim, or None if not divisible/unknown."""
    if logical is None:
        return None
    axes = _mesh_axes(mesh, _logical_map(logical))
    if not axes:
        return None
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if dim % size != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


@contextmanager
def sharding_ctx(mesh: Mesh, overrides: Optional[dict] = None):
    """``overrides`` remaps logical names (e.g. {"batch": ("data",)} inside
    a shard_map body where the "pod" axis is manual)."""
    prev = getattr(_STATE, "mesh", None)
    prev_ovr = getattr(_STATE, "overrides", None)
    _STATE.mesh = mesh
    _STATE.overrides = overrides
    try:
        yield
    finally:
        _STATE.mesh = prev
        _STATE.overrides = prev_ovr


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def _logical_map(name: str):
    ovr = getattr(_STATE, "overrides", None)
    if ovr and name in ovr:
        return ovr[name]
    return LOGICAL_TO_MESH[name]


def shard_act(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = PartitionSpec(*[resolve_axis(mesh, l, d)
                           for l, d in zip(logical, x.shape)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
