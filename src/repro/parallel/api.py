"""Logical-axis sharding context for the training/serving stack.

This is the *model-side* half of the parallel substrate (the codec's
chunk-grid sharding is the separate, simpler ``parallel.codec_mesh`` —
see ``docs/architecture.md`` §4 for how the two relate): models annotate
activations with **logical** axis names and a thread-local context
installed by the launcher maps those names to physical mesh axes.

The contract, piece by piece:

  * :data:`LOGICAL_TO_MESH` — the default logical-name -> mesh-axes table
    ("batch" -> ("pod", "data"), "heads"/"ff"/"experts"/"vocab" ->
    ("model",), ...).  It is a *vocabulary*, not a guarantee: a name maps
    only onto axes the active mesh actually has.
  * :func:`sharding_ctx` — context manager installing (mesh, overrides)
    thread-locally.  ``overrides`` remaps names for a lexical scope, e.g.
    ``{"batch": ("data",)}`` inside a shard_map body where the "pod" axis
    is already manual.  Contexts nest; the previous mapping is restored
    on exit.
  * :func:`shard_act` — models call
    ``shard_act(x, "batch", None, "heads", None)`` with one logical name
    (or None) per tensor dim.  With no context installed it is a no-op —
    the exact property that lets the same model code run in CPU unit
    tests — otherwise it emits a ``with_sharding_constraint``.
  * :func:`resolve_axis` — the divisibility check behind it: a dim that
    the mapped mesh axes do not divide falls back to replication rather
    than erroring (e.g. qwen2's 14 heads cannot tile a 16-way model axis,
    so its attention runs data-parallel while its FFN/vocab still use TP;
    see DESIGN.md §4).  Every lookup is therefore total: any model runs
    on any mesh, just with less sharding than requested.

Nothing here touches jax's shard_map API surface directly — version
tolerance lives in ``parallel.compat``.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_STATE = threading.local()

#: logical name -> mesh axis (or tuple of axes for the batch dimension)
LOGICAL_TO_MESH = {
    "batch": ("pod", "data"),
    "seq": ("model",),        # sequence sharding (KV caches, long-context)
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "d_fsdp": ("data",),      # FSDP weight sharding
    "d_tp": ("model",),       # embedding d: vocab-sharded gathers trip an
                              # XLA:CPU SPMD crash on 3-axis meshes
    "ssm_heads": ("model",),
}


def _mesh_axes(mesh: Mesh, want: Tuple[str, ...]) -> Tuple[str, ...]:
    return tuple(a for a in want if a in mesh.axis_names)


def resolve_axis(mesh: Mesh, logical: Optional[str], dim: int):
    """Mesh axes for one tensor dim, or None if not divisible/unknown."""
    if logical is None:
        return None
    axes = _mesh_axes(mesh, _logical_map(logical))
    if not axes:
        return None
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if dim % size != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


@contextmanager
def sharding_ctx(mesh: Mesh, overrides: Optional[dict] = None):
    """``overrides`` remaps logical names (e.g. {"batch": ("data",)} inside
    a shard_map body where the "pod" axis is manual)."""
    prev = getattr(_STATE, "mesh", None)
    prev_ovr = getattr(_STATE, "overrides", None)
    _STATE.mesh = mesh
    _STATE.overrides = overrides
    try:
        yield
    finally:
        _STATE.mesh = prev
        _STATE.overrides = prev_ovr


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def _logical_map(name: str):
    ovr = getattr(_STATE, "overrides", None)
    if ovr and name in ovr:
        return ovr[name]
    return LOGICAL_TO_MESH[name]


def shard_act(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = PartitionSpec(*[resolve_axis(mesh, l, d)
                           for l, d in zip(logical, x.shape)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
