"""Roofline-term extraction from compiled dry-run artifacts.

  compute    = HLO_FLOPs / (chips * peak_FLOPs)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
NOT in cost_analysis: we parse the *optimized* (post-SPMD) HLO text and sum
the result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind summed result bytes of collectives in optimized HLO."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0) + b
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float
    bytes_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = dict(compute=self.t_compute, memory=self.t_memory,
                     collective=self.t_collective)
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-roofline bound spent on useful compute:
        (model_flops / chips / peak) / max(term)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        worst = max(self.t_compute, self.t_memory, self.t_collective)
        return ideal / max(worst, 1e-30)

    def row(self) -> dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(cfg, shape) -> float:
    """6*N*D for train (fwd+bwd), 2*N*D for inference; N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n * tokens
