"""Step functions (train / prefill / decode) + abstract input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, no device allocation — which is
what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ModelConfig, ShapeConfig
from ..optim import adamw_update, adafactor_update, cosine_schedule
from ..optim.adamw import TrainState

S = jax.ShapeDtypeStruct


def extra_inputs(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    """Modality-stub inputs (precomputed frame/patch embeddings)."""
    out = {}
    if cfg.family == "encdec":
        out["frames"] = S((batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm" and cfg.n_prefix_embeds:
        out["prefix"] = S((batch, cfg.n_prefix_embeds, cfg.d_model),
                          jnp.dtype(cfg.dtype))
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, L = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = dict(tokens=S((B, L + 1), jnp.int32))
        specs.update(extra_inputs(cfg, B))
        return specs
    if shape.kind == "prefill":
        specs = dict(tokens=S((B, L), jnp.int32))
        specs.update(extra_inputs(cfg, B))
        return specs
    # decode: one new token against a cache of length seq_len
    cache = jax.eval_shape(lambda: M.init_cache(cfg, B, L))
    return dict(token=S((B, 1), jnp.int32), cache=cache)


# ------------------------------------------------------------------ train

def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        tokens = batch["tokens"]
        h = M.forward(params, tokens[:, :-1], cfg,
                      prefix_embeds=batch.get("prefix"),
                      encoder_frames=batch.get("frames"))
        if cfg.family == "vlm" and cfg.n_prefix_embeds:
            h = h[:, cfg.n_prefix_embeds:]
        return M.chunked_ce_loss(params, h, tokens[:, 1:], cfg)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_kind: str = "adamw",
                    lr_kwargs: Optional[dict] = None):
    loss_fn = make_loss_fn(cfg)
    lrk = lr_kwargs or {}

    def train_step(state: TrainState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        lr = cosine_schedule(state.step, **lrk)
        if opt_kind == "adafactor":
            new_p, new_opt, gnorm = adafactor_update(
                grads, state.opt, state.params, lr)
        else:
            new_p, new_opt, gnorm = adamw_update(
                grads, state.opt, state.params, lr)
        new_state = TrainState(params=new_p, opt=new_opt, step=state.step + 1)
        return new_state, dict(loss=loss, gnorm=gnorm, lr=lr)

    return train_step


def make_train_step_compressed(cfg: ModelConfig, mesh, opt_kind="adamw",
                               keep_bits: int = 14,
                               lr_kwargs: Optional[dict] = None):
    """Train step with IPComp-compressed cross-pod gradient reduction.

    The "pod" mesh axis is manual (shard_map axis_names={"pod"}); data/
    model stay auto, so the per-pod loss+grad is ordinary pjit SPMD.  The
    cross-pod sync — the slow inter-pod links at 1000-node scale — runs the
    paper's pipeline: error-bounded quantize + occupied-bitplane truncation,
    summed as int16 words (§4.4 applied to the wire; error feedback is
    omitted because the truncation bound is fixed per step).
    """
    from jax.sharding import PartitionSpec as P
    from ..compression.grad import compressed_psum
    from ..parallel.api import sharding_ctx
    loss_fn = make_loss_fn(cfg)
    lrk = lr_kwargs or {}
    npods = mesh.shape.get("pod", 1)

    def body(state: TrainState, batch):
        # activation constraints are disabled inside the manual-pod region:
        # NamedShardings built against the concrete (all-Auto) mesh clash
        # with the Manual-pod abstract mesh; jit-level in_shardings on
        # params/batch still steer SPMD.
        with sharding_ctx(None):
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
            loss = jax.lax.psum(loss, "pod") / npods
            grads = jax.tree_util.tree_map(
                lambda g: compressed_psum(g, "pod",
                                          keep_bits=keep_bits) / npods,
                grads)
            lr = cosine_schedule(state.step, **lrk)
            if opt_kind == "adafactor":
                new_p, new_opt, gnorm = adafactor_update(
                    grads, state.opt, state.params, lr)
            else:
                new_p, new_opt, gnorm = adamw_update(
                    grads, state.opt, state.params, lr)
            new_state = TrainState(params=new_p, opt=new_opt,
                                   step=state.step + 1)
            return new_state, dict(loss=loss, gnorm=gnorm, lr=lr)

    def train_step(state, batch):
        from ..parallel.compat import shard_map
        rep = jax.tree_util.tree_map(lambda _: P(), state)
        bspec = jax.tree_util.tree_map(lambda _: P("pod"), batch)
        return shard_map(body, mesh=mesh, in_specs=(rep, bspec),
                         out_specs=(rep, dict(loss=P(), gnorm=P(),
                                              lr=P())),
                         axis_names={"pod"}, check_vma=False)(state, batch)

    return train_step


# ------------------------------------------------------------------ serve

def make_prefill_step(cfg: ModelConfig, max_len: Optional[int] = None):
    def prefill_step(params, batch):
        return M.prefill(params, batch["tokens"], cfg, max_len=max_len,
                         prefix_embeds=batch.get("prefix"),
                         encoder_frames=batch.get("frames"))
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, token) -> (logits, cache)."""
    def serve_step(params, cache, token):
        return M.decode_step(params, cache, token, cfg)
    return serve_step
