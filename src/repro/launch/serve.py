"""Batched serving loop: continuous batching over a shared decode cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 8 --max-new 16

Requests arrive with different prompt lengths; the server left-pads into a
fixed batch, prefills once, then decodes step-by-step, retiring finished
sequences.  On the production mesh the same step functions run under the
sharded cache layout (decode_32k dry-run cell).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B = args.requests
    # heterogeneous prompts, right-aligned into the batch
    rng = np.random.default_rng(0)
    lens = rng.integers(args.prompt_len // 2, args.prompt_len + 1, B)
    prompts = np.zeros((B, args.prompt_len), np.int32)
    for i, ln in enumerate(lens):
        prompts[i, -ln:] = rng.integers(1, cfg.vocab, ln)

    max_len = args.prompt_len + args.max_new + 1
    kw = {}
    if cfg.family == "encdec":
        kw["encoder_frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        kw["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_prefix_embeds, cfg.d_model)),
            jnp.dtype(cfg.dtype))

    prefill = jax.jit(lambda p, t: M.prefill(p, t, cfg, max_len=max_len +
                                             cfg.n_prefix_embeds, **kw))
    decode = jax.jit(lambda p, c, t: M.decode_step(p, c, t, cfg))

    t0 = time.time()
    logits, cache = prefill(params, jnp.asarray(prompts))
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = [tok]
    t0 = time.time()
    for _ in range(args.max_new - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    print(f"arch={cfg.name} B={B} prefill={t_prefill*1e3:.1f}ms "
          f"decode={dt/max(args.max_new-1,1)*1e3:.1f}ms/token "
          f"throughput={B*(args.max_new-1)/max(dt,1e-9):.1f} tok/s")
    assert np.isfinite(gen).all()
    for i in range(min(3, B)):
        print(f"req{i} len={lens[i]}: {gen[i][:10].tolist()}...")
    print("OK")


if __name__ == "__main__":
    main()
