import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: build the production mesh, abstract params/optimizer state
(jax.eval_shape — no allocation), resolve shardings, then
``jit(step).lower(...).compile()``.  Success proves the distribution config
is coherent; ``memory_analysis()`` proves it fits; ``cost_analysis()`` +
optimized-HLO collective parsing feed §Roofline.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out results.json
"""
import argparse
import json
import sys
import time
import traceback
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, cell_is_applicable, get_config,
                           get_opt_kind, get_shape)
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (input_specs, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models import model as M
from repro.models.config import SHAPES
from repro.optim.adamw import TrainState
from repro.parallel import sharding as SH
from repro.parallel.api import sharding_ctx


def abstract_state(cfg, opt_kind: str):
    """Abstract TrainState via eval_shape — no allocation."""
    def build():
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        from repro.optim import make_train_state
        return make_train_state(params, opt_kind)
    return jax.eval_shape(build)


def _tuned(cfg, shape):
    """Shape-dependent tuning knobs (documented in EXPERIMENTS.md §Perf)."""
    if shape.kind == "prefill":
        cfg = replace(cfg, q_chunk=2048, kv_chunk=4096)
    return cfg


def _lower_compile(cfg, shape, mesh, opt_kind, grad_compress: bool = False):
    """Lower + compile one step function under the mesh; returns compiled."""
    with sharding_ctx(mesh):
        specs = input_specs(cfg, shape)
        if shape.kind == "train":
            state = abstract_state(cfg, opt_kind)
            state_sh = TrainState(
                params=SH.param_sharding(cfg, mesh, state.params),
                opt=_opt_sharding(cfg, mesh, state.opt),
                step=SH.replicated(mesh, state.step))
            batch_sh = SH.batch_sharding(cfg, mesh, specs)
            if grad_compress and "pod" in mesh.axis_names:
                from repro.launch.steps import make_train_step_compressed
                step_fn = make_train_step_compressed(cfg, mesh, opt_kind)
            else:
                step_fn = make_train_step(cfg, opt_kind)
            jitted = jax.jit(step_fn,
                             in_shardings=(state_sh, batch_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, specs)
        elif shape.kind == "prefill":
            params = jax.eval_shape(
                lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
            p_sh = SH.param_sharding(cfg, mesh, params, fsdp=False)
            b_sh = SH.batch_sharding(cfg, mesh, specs)
            jitted = jax.jit(make_prefill_step(cfg),
                             in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params, specs)
        else:  # decode
            params = jax.eval_shape(
                lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
            p_sh = SH.param_sharding(cfg, mesh, params, fsdp=False)
            c_sh = SH.cache_sharding(cfg, mesh, specs["cache"])
            t_sh = SH.batch_sharding(cfg, mesh, specs["token"])
            jitted = jax.jit(make_serve_step(cfg),
                             in_shardings=(p_sh, c_sh, t_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params, specs["cache"], specs["token"])
        return lowered.compile()


def _probe_costs(compiled):
    cost = compiled.cost_analysis()
    coll = RL.collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = cell_is_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return dict(arch=arch, shape=shape_name, mesh=mesh_name,
                    status="skipped", reason=why)
    cfg = _tuned(cfg, shape)
    opt_kind = get_opt_kind(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        # 1) full-depth scanned compile: the deliverable + memory analysis
        compiled = _lower_compile(cfg, shape, mesh, opt_kind)
        mem = compiled.memory_analysis()

        if multi_pod:
            # the roofline table is single-pod by spec; the multi-pod pass
            # proves the "pod" axis shards + fits.  (Unrolled probes also
            # trip an XLA:CPU SPMD crash on 3-axis meshes; see EXPERIMENTS.)
            return dict(status="ok", compile_s=round(time.time() - t0, 1),
                        arch=arch, shape=shape_name, mesh=mesh_name,
                        chips=chips, memory_analysis=str(mem),
                        bytes_per_device=_mem_bytes(mem))

        # 2) cost probes: XLA's cost_analysis counts a while-loop body ONCE
        # regardless of trip count, so flops/bytes/collectives of the scanned
        # module are depth-independent.  Two unrolled shallow compiles give
        # the exact per-layer slope: true(L) = f(1) + (L-1) * (f(2) - f(1)).
        L = cfg.n_layers
        enc = cfg.encoder_layers

        def probe(k):
            c = replace(cfg, n_layers=k,
                        encoder_layers=(k if enc else 0),
                        scan_layers=False, unroll_scans=True)
            return _probe_costs(_lower_compile(c, shape, mesh, opt_kind))

        f1, b1, c1 = probe(1)
        f2, b2, c2 = probe(2)
        flops = f1 + (L - 1) * (f2 - f1)
        byt = b1 + (L - 1) * (b2 - b1)
        coll = {k: c1.get(k, 0) + (L - 1) * (c2.get(k, 0) - c1.get(k, 0))
                for k in set(c1) | set(c2)}
        # cost_analysis reports per-device numbers for SPMD modules
        rl = RL.Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            hlo_flops=flops * chips, hlo_bytes=byt * chips,
            coll_bytes=float(sum(coll.values())) * chips,
            coll_breakdown=coll,
            model_flops=RL.model_flops(get_config(arch), shape),
            bytes_per_device=_mem_bytes(mem))
        out = dict(status="ok", compile_s=round(time.time() - t0, 1),
                   memory_analysis=str(mem), **rl.row())
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] OK "
                  f"compile={out['compile_s']}s "
                  f"flops/dev={flops:.3e} bytes/dev={byt:.3e} "
                  f"coll/dev={sum(coll.values()):.3e} "
                  f"bottleneck={rl.bottleneck} "
                  f"useful={rl.useful_flops_ratio:.2f} "
                  f"frac={rl.roofline_fraction:.3f}", flush=True)
            print("  memory:", str(mem), flush=True)
        return out
    except Exception as e:
        if verbose:
            traceback.print_exc()
        return dict(arch=arch, shape=shape_name, mesh=mesh_name,
                    status="error", error=f"{type(e).__name__}: {e}",
                    compile_s=round(time.time() - t0, 1))


def _opt_sharding(cfg, mesh, opt):
    """Optimizer states inherit their parameter's sharding (same shapes);
    factored Adafactor stats drop the last/second-last dim spec."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def assign(path, leaf):
        if not hasattr(leaf, "shape"):
            return NamedSharding(mesh, P())
        names = SH._path_names(path)
        if names and names[-1] in ("count",):
            return NamedSharding(mesh, P())
        # reuse param rules by stripping the m/v/stats/r/c prefix
        core = tuple(n for n in names if n not in
                     ("m", "v", "stats", "r", "c"))
        for suffix, logicals in SH._PARAM_RULES:
            if len(core) >= len(suffix) and core[-len(suffix):] == suffix:
                logi = list(logicals)
                if names[-1] == "r":      # row stats: last dim reduced away
                    logi = logi[:-1]
                elif names[-1] == "c":    # col stats: second-last reduced
                    logi = logi[:-2] + logi[-1:]
                if len(logi) != len(leaf.shape):
                    logi = [None] * len(leaf.shape)
                return NamedSharding(mesh, SH._spec(mesh, leaf.shape, logi))
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))

    return jax.tree_util.tree_map_with_path(assign, opt)


def _mem_bytes(mem) -> float:
    """Per-device HBM estimate from memory_analysis (API varies by backend)."""
    try:
        return float(mem.temp_size_in_bytes + mem.argument_size_in_bytes
                     + mem.output_size_in_bytes)
    except Exception:
        return -1.0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_cell(arch, shape, mp))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if r["status"] == "error"]
    print(f"\n{len(results)} cells: "
          f"{sum(r['status'] == 'ok' for r in results)} ok, "
          f"{sum(r['status'] == 'skipped' for r in results)} skipped, "
          f"{len(bad)} errors")
    for r in bad:
        print("ERROR:", r["arch"], r["shape"], r["mesh"], r["error"][:200])
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
