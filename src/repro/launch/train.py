"""End-to-end training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 200 --seq 256 --batch 8 --reduced --ckpt-dir /tmp/ckpt

``--reduced`` shrinks the config for CPU runs (the ~100M-scale example uses
the real smollm-360m config with a short sequence).  On a TPU fleet the
same entry point runs under the production mesh with
``--mesh single|multi``; gradient compression toggles the cross-pod
IPComp path.
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, get_config, get_opt_kind
from repro.data.pipeline import TokenStream
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import make_train_state
from repro.runtime import DriverConfig, FailureInjector, TrainDriver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=None,
                    help="simulate node failures at these steps")
    ap.add_argument("--progressive-restore", action="store_true",
                    help="restart coarse-first: restore only the bitplanes "
                         "for --restore-weight-error, refine in background")
    ap.add_argument("--restore-weight-error", type=float, default=1e-2)
    ap.add_argument("--ckpt-workers", type=int, default=1,
                    help="parallel encoder shards per checkpoint save")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--report", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = replace(cfg, dtype="float32", remat=False)
    print(f"arch={cfg.name} params={cfg.param_count():.3e} "
          f"(active {cfg.active_param_count():.3e})")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"materialized params: {n:.3e}")
    state = make_train_state(params, get_opt_kind(args.arch))

    step_fn = jax.jit(make_train_step(
        cfg, get_opt_kind(args.arch),
        lr_kwargs=dict(base_lr=args.lr, warmup=max(10, args.steps // 20),
                       total=args.steps)))
    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = (args.batch, cfg.encoder_seq, cfg.d_model)
    if cfg.family == "vlm":
        extras["prefix"] = (args.batch, cfg.n_prefix_embeds, cfg.d_model)

    driver = TrainDriver(
        step_fn=step_fn, stream=stream,
        ckpt=CheckpointManager(args.ckpt_dir, keep_n=2,
                               workers=args.ckpt_workers),
        cfg=DriverConfig(total_steps=args.steps,
                         ckpt_every=args.ckpt_every,
                         progressive_restore=args.progressive_restore,
                         restore_weight_error=args.restore_weight_error),
        injector=FailureInjector(args.fail_at) if args.fail_at else None,
        extras=extras or None)

    t0 = time.time()
    report = driver.run(state)
    dt = time.time() - t0
    losses = report["losses"]
    k = max(1, len(losses) // 10)
    print(f"steps={report['final_step']} wall={dt:.1f}s "
          f"restarts={report['restarts']} stragglers={len(report['stragglers'])} "
          f"refined={report.get('refined_adoptions', 0)}")
    print(f"loss first10={np.mean(losses[:k]):.4f} "
          f"last10={np.mean(losses[-k:]):.4f}")
    if args.report:
        with open(args.report, "w") as f:
            json.dump(dict(report, wall_s=dt), f)
    assert np.mean(losses[-k:]) < np.mean(losses[:k]), "loss did not improve"
    print("OK: loss decreased")


if __name__ == "__main__":
    main()
