"""Archive serving tier: continuous-batching retrieval over progressive
archives (queue -> coalescer -> plane cache -> batched kernels; see
``docs/architecture.md`` §8 and ``benchmarks/serve_bench.py``)."""
from .cache import PlaneCache
from .server import (DONE, FAILED, QUEUED, RUNNING, RetrievalServer,
                     ServeRequest)

__all__ = ["PlaneCache", "RetrievalServer", "ServeRequest",
           "QUEUED", "RUNNING", "DONE", "FAILED"]
