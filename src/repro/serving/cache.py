"""Shared plane cache: decoded bitplane prefixes reused across sessions.

The progressive container stores each level as an ordered stream of XOR
predictive-coded bitplanes; what a session actually consumes is the
*decoded* truncated-negabinary prefix (``pipeline.state.nb_partial``), a
pure function of (archive bytes, level, prefix length).  Concurrent
readers at different fidelities therefore walk the same small set of
prefixes — the sharing structure the paper's progressive representation
creates and the serving tier exploits (``docs/architecture.md`` §8).

:class:`PlaneCache` is that sharing made explicit: an LRU-bounded map
``(cache_scope, level, prefix) -> frozen uint32 stream`` with hit/miss/
byte accounting.  The contract consumed by ``pipeline.state`` is three
methods — ``get`` / ``put`` / ``saved_fetch`` — so tests can substitute
plain recording fakes.  Entries are immutable (``state._freeze``) and a
hit never changes reconstruction bits: the cached stream is exactly what
the decode would have produced.  Thread-safe; eviction is LRU by entry
byte size under ``max_bytes``.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Optional

import numpy as np


class PlaneCache:
    """LRU cache of decoded plane prefixes with byte accounting.

    ``max_bytes``
        Eviction cap on the summed ``nbytes`` of cached streams; None =
        unbounded.  A single entry larger than the cap is not admitted
        (caching it would immediately evict everything else for a
        one-shot entry).

    Accounting (all monotone counters, read via :meth:`stats`):

    * ``hits`` / ``misses`` — ``get`` outcomes;
    * ``hit_bytes`` — decoded bytes served from cache (decode work
      avoided);
    * ``fetch_bytes_saved`` — compressed plane bytes whose *fetch* a hit
      made unnecessary, credited by the consumer via
      :meth:`saved_fetch` (the consumer knows which planes its reader
      had already pulled for shallower prefixes);
    * ``evictions`` / ``insertions`` and the live ``bytes_cached``.
    """

    def __init__(self, max_bytes: Optional[int] = None):
        if max_bytes is not None and max_bytes <= 0:
            raise ValueError(f"max_bytes must be positive, got {max_bytes}")
        self.max_bytes = max_bytes
        self._entries: "OrderedDict" = OrderedDict()
        self._lock = threading.Lock()
        self.bytes_cached = 0
        self.hits = 0
        self.misses = 0
        self.hit_bytes = 0
        self.fetch_bytes_saved = 0
        self.evictions = 0
        self.insertions = 0

    # ---- the consumer protocol (pipeline.state)

    def get(self, key) -> Optional[np.ndarray]:
        """The cached stream for ``key``, or None.  A hit refreshes the
        entry's LRU position."""
        with self._lock:
            arr = self._entries.get(key)
            if arr is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            self.hit_bytes += arr.nbytes
            return arr

    def put(self, key, arr: np.ndarray) -> None:
        """Publish a decoded stream (expected frozen read-only); evicts
        LRU entries until the byte cap holds again."""
        with self._lock:
            if key in self._entries:
                # decode is deterministic: same key, same bytes — but the
                # re-publish is still a use, so refresh recency like get()
                self._entries.move_to_end(key)
                return
            if self.max_bytes is not None and arr.nbytes > self.max_bytes:
                return
            self._entries[key] = arr
            self.bytes_cached += arr.nbytes
            self.insertions += 1
            while (self.max_bytes is not None
                   and self.bytes_cached > self.max_bytes):
                _, old = self._entries.popitem(last=False)
                self.bytes_cached -= old.nbytes
                self.evictions += 1

    def saved_fetch(self, nbytes: int) -> None:
        """Credit ``nbytes`` of plane fetches a cache hit avoided."""
        with self._lock:
            self.fetch_bytes_saved += int(nbytes)

    # ---- introspection

    def _hit_rate_locked(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def hit_rate(self) -> float:
        """hits / (hits + misses); 0.0 before any lookup."""
        with self._lock:
            return self._hit_rate_locked()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._entries

    def stats(self) -> dict:
        """Consistent snapshot of every counter, taken under the lock
        (plain dict, JSON-serializable)."""
        with self._lock:
            return {
                "entries": len(self._entries),
                "bytes_cached": self.bytes_cached,
                "max_bytes": self.max_bytes,
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self._hit_rate_locked(),
                "hit_bytes": self.hit_bytes,
                "fetch_bytes_saved": self.fetch_bytes_saved,
                "evictions": self.evictions,
                "insertions": self.insertions,
            }

    def clear(self) -> None:
        """Drop every entry (counters are kept — they are lifetime
        accounting, not occupancy)."""
        with self._lock:
            self._entries.clear()
            self.bytes_cached = 0

    def __repr__(self) -> str:
        cap = "unbounded" if self.max_bytes is None else f"{self.max_bytes}B"
        return (f"PlaneCache({len(self._entries)} entries, "
                f"{self.bytes_cached}B/{cap}, hit_rate={self.hit_rate:.2f})")
