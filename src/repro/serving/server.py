"""Continuous-batching retrieval server over progressive archives.

The production shape of the paper's workload (ROADMAP item 1): many
concurrent readers ask for the *same* archives at *different* fidelities,
and progressive bytes are shared ordered streams — so both the decoded
prefixes and the kernel launches are shareable across requests.  The
server realizes both:

* a request queue of ``(archive_id, Fidelity)`` jobs
  (:meth:`RetrievalServer.submit`), drained in scheduler ticks
  (:meth:`run_tick` / :meth:`drain`) — the structural twin of the model
  decode loop in ``launch.serve``, with bitplane prefixes in place of KV
  caches;
* a shared :class:`~.cache.PlaneCache` (``plane cache``): requests that
  reach a (chunk, prefix) another session already decoded skip the fetch
  *and* the unpack kernel;
* **cross-request coalescing**: each tick, the per-chunk decode jobs of
  *all* runnable requests are grouped by shape signature and executed
  through :func:`~repro.core.pipeline.decode.decode_group` — the same
  batched executor in-session chunk groups use — so one
  ``decode_level_batch`` / ``reconstruct_batch`` launch serves chunks
  from many requests at once (``coalesce=False`` keeps groups
  per-request, for A/B dispatch accounting).

Requests are isolated: a planner error (e.g. an infeasible
``Fidelity.max_bytes``) fails that request with the error message and
the tick goes on.  Transient transport errors (remote sources timing
out, resetting, running out of their own wire retries) consume a
per-request retry budget instead: the request re-queues and re-plans
from its committed progressive state; when the budget runs out it
settles ``partial`` at the last fully decoded rung — a bit-exact
coarser answer with the error recorded — and stays chainable for
children (``docs/architecture.md`` "Remote retrieval").  Reconstruction bits are identical to a private
uncached session per request — caching, dedup, and coalescing are
execution details (pinned by ``tests/test_serve_tier.py`` and the
``benchmarks/serve_bench.py`` parity check).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..api import Archive, ExecPolicy, Fidelity
from ..core import loader
from ..core.container import V3ArchiveReader
from ..core.pipeline import decode, spec
from ..core.pipeline.encode import group_cap
from ..core.pipeline.state import (ChunkedRetrievalState, RetrievalState,
                                   fork_state)
from ..core.remote import RemoteProtocolError
from .cache import PlaneCache

QUEUED = "queued"
RUNNING = "running"
DONE = "done"
FAILED = "failed"
#: retries exhausted mid-refine, but an earlier rung was fully decoded:
#: the request settles with that rung's reconstruction, its achieved
#: ``err_bound``, and the transport error recorded — a degraded answer,
#: not a poisoned session (children may still refine from it)
PARTIAL = "partial"


def _retryable(exc: BaseException) -> bool:
    """Transient transport failures are worth re-planning in a later
    tick: every :class:`OSError` (timeouts, resets, ``RemoteReadError``)
    except a decisive :class:`RemoteProtocolError`.  Anything else —
    ``CorruptArchiveError``, planner rejections — is permanent: the same
    plan would fail the same way."""
    return isinstance(exc, OSError) and not isinstance(exc, RemoteProtocolError)


@dataclass
class ServeRequest:
    """One queued retrieval: target fidelity against a registered archive.

    The server fills in lifecycle fields as the request moves
    ``queued -> done | failed``; ``result`` is the reconstruction,
    ``bytes_read`` / ``err_bound`` the session accounting, ``latency_s``
    wall time from submit to completion.  ``refine_of`` chains onto a
    finished request's progressive state: the child branches a private
    copy of it (forked reader accounting included) and fetches only the
    planes its tighter fidelity adds (Algorithm 2, across requests);
    sibling refinements of one parent are fully independent sessions.

    Transient transport errors re-queue the request for a later tick up
    to its retry budget (``retry_budget``, defaulting to the server's);
    an exhausted budget settles the request ``partial`` at its last
    fully decoded rung — result, achieved ``err_bound``, and the
    transport error all recorded — or ``failed`` if no rung ever
    completed.
    """
    req_id: int
    archive_id: str
    fidelity: Fidelity
    propagation: str = loader.SAFE
    refine_of: Optional["ServeRequest"] = None
    status: str = QUEUED
    result: Optional[np.ndarray] = None
    error: Optional[str] = None
    bytes_read: int = 0
    err_bound: float = float("inf")
    submitted_s: float = field(default_factory=time.perf_counter)
    latency_s: float = 0.0
    retries: int = 0                  # transport retries consumed so far
    retry_budget: Optional[int] = None  # None -> the server's default
    # session internals (reader + progressive state), server-managed
    _reader: object = None
    _state: object = None
    _ladder_t: object = None          # v3: this tick's planned prefix length


@dataclass
class _Job:
    """One chunk decode unit: the coalescer's currency."""
    req: ServeRequest
    chunk_idx: Optional[int]          # None = v1 archive (single slab)
    sub_reader: object
    prior_state: Optional[RetrievalState]
    keep_planes: List[int]
    new_state: Optional[RetrievalState] = None


def _shape_sig(meta) -> tuple:
    """Batch-compatibility signature: jobs with equal signatures may share
    one stacked kernel launch (same contract as ``encode.shape_groups``
    plus the level/anchor structure ``*_batch`` helpers assume)."""
    return (tuple(meta.shape), meta.interp,
            tuple(lv.n for lv in meta.levels),
            tuple(meta.anchors_shape))


class RetrievalServer:
    """Continuous-batching server over a registry of progressive archives.

    ``policy``
        :class:`ExecPolicy` executing every tick (default
        ``spec.DEFAULT_POLICY``); like sessions, the policy never changes
        reconstruction bits — only dispatch counts and speed.
    ``cache``
        A shared :class:`PlaneCache` (None disables prefix reuse).
    ``coalesce``
        True (default) groups decode jobs across requests; False keeps
        each request's jobs in their own groups — the per-request
        baseline the benchmark compares dispatch counts against.
    ``propagation``
        Default error-propagation model for requests that don't pick one.

    Dispatch accounting lives in :attr:`counters`
    (``decode_level`` / ``reconstruct`` / ``dedup_reuse`` primitive
    invocations, backend-independent — see ``pipeline.state``).
    """

    def __init__(self, policy: Optional[ExecPolicy] = None,
                 cache: Optional[PlaneCache] = None, coalesce: bool = True,
                 propagation: str = loader.SAFE, retry_budget: int = 2):
        self.policy = policy if policy is not None else spec.DEFAULT_POLICY
        self.cache = cache
        self.coalesce = coalesce
        self.propagation = propagation
        #: default transport retries per request (re-queue + re-plan in a
        #: later tick) before a request degrades to ``partial``/``failed``
        self.retry_budget = int(retry_budget)
        self.counters: Dict[str, int] = {}
        self.ticks = 0
        self._archives: Dict[str, Archive] = {}
        self._queue: List[ServeRequest] = []
        self._next_id = 0
        self._done = 0
        self._failed = 0
        self._partial = 0
        self._retries = 0               # lifetime re-queues
        self._tick_retries = 0          # re-queues in the latest tick

    # ---- registry / queue

    def add_archive(self, archive_id: str, archive: Archive) -> None:
        """Register ``archive`` under ``archive_id``.

        The id becomes the plane-cache scope for every session the server
        opens on it, so it must be stable: rebinding an id to *different*
        bytes would poison cache keys and is rejected (idempotent
        re-registration of equal bytes is fine).
        """
        prev = self._archives.get(archive_id)
        if prev is not None and prev != archive:
            raise ValueError(
                f"archive_id {archive_id!r} is already bound to different "
                "bytes; cache scopes require a stable id -> bytes mapping")
        self._archives[archive_id] = archive

    def submit(self, archive_id: str, fidelity: Optional[Fidelity] = None,
               propagation: Optional[str] = None,
               refine_of: Optional[ServeRequest] = None,
               retry_budget: Optional[int] = None) -> ServeRequest:
        """Enqueue a retrieval; returns the live :class:`ServeRequest`.

        ``refine_of`` chains onto an earlier request for the same
        archive: once the parent has settled with a result (DONE, or
        PARTIAL after degradation), the child branches a private copy of
        its progressive state and fetches only the additional planes.
        ``retry_budget`` overrides the server's default transport-retry
        allowance for this request alone.
        """
        if archive_id not in self._archives:
            raise KeyError(f"unknown archive_id {archive_id!r}; "
                           "add_archive() it first")
        if refine_of is not None and refine_of.archive_id != archive_id:
            raise ValueError(
                f"refine_of targets archive {refine_of.archive_id!r}, "
                f"not {archive_id!r}")
        req = ServeRequest(
            req_id=self._next_id, archive_id=archive_id,
            fidelity=fidelity if fidelity is not None else Fidelity.full(),
            propagation=propagation if propagation is not None
            else self.propagation,
            refine_of=refine_of, retry_budget=retry_budget)
        self._next_id += 1
        self._queue.append(req)
        return req

    @property
    def pending(self) -> int:
        return len(self._queue)

    # ---- scheduling

    def _runnable(self) -> Tuple[List[ServeRequest], List[ServeRequest]]:
        """Dequeue requests whose refine parent (if any) has settled.

        A PARTIAL parent is chainable: it settled with a complete (if
        coarser) progressive state, so children branch from its achieved
        rung — degradation never poisons the chain.  Returns ``(ready,
        failed)``: runnable requests, plus the children of FAILED
        parents — failed immediately here, and returned so ``run_tick``
        reports them as settled this tick."""
        ready, still, failed = [], [], []
        for req in self._queue:
            parent = req.refine_of
            if parent is None or parent.status in (DONE, PARTIAL):
                ready.append(req)
            elif parent.status == FAILED:
                self._fail(req, f"refine parent request {parent.req_id} "
                           f"failed: {parent.error}")
                failed.append(req)
            else:
                still.append(req)
        self._queue = still
        return ready, failed

    def _fail(self, req: ServeRequest, error: str) -> None:
        req.status = FAILED
        req.error = error
        req.latency_s = time.perf_counter() - req.submitted_s
        self._failed += 1

    def _budget(self, req: ServeRequest) -> int:
        return self.retry_budget if req.retry_budget is None \
            else req.retry_budget

    def _settle_partial(self, req: ServeRequest, error: str) -> bool:
        """Settle ``req`` at its last fully decoded rung, if one exists.

        The committed progressive state (``req._state``) only ever holds
        rungs whose every chunk assembled — failed reads raise before any
        state is merged — so if it is complete, its reconstruction is a
        bit-exact coarser answer.  Returns False when nothing was ever
        achieved (the caller then fails the request outright)."""
        st = req._state
        if st is None or req._reader is None:
            return False
        m = req._reader.meta
        if isinstance(st, ChunkedRetrievalState):
            if any(cs is None for cs in st.chunk_states):
                return False
            out = np.empty(m.shape, np.dtype(m.dtype))
            for i, cm in enumerate(m.chunks):
                out[cm.start:cm.stop] = \
                    st.chunk_states[i].xhat.astype(out.dtype)
            req.result = out
        elif getattr(st, "xhat", None) is not None:
            req.result = st.xhat.astype(np.dtype(m.dtype))
        else:
            return False
        req.err_bound = st.err_bound
        req.bytes_read = req._reader.bytes_read
        req.status = PARTIAL
        req.error = error
        req.latency_s = time.perf_counter() - req.submitted_s
        self._partial += 1
        return True

    def _resolve_failure(self, req: ServeRequest, exc: BaseException,
                         settled: List[ServeRequest]) -> None:
        """Route one request's tick failure: re-queue (transient error,
        budget left), degrade to PARTIAL (budget exhausted, a rung
        achieved), or FAIL (permanent error / nothing achieved)."""
        msg = f"{type(exc).__name__}: {exc}"
        if _retryable(exc):
            if req.retries < self._budget(req):
                req.retries += 1
                req.status = QUEUED
                req._ladder_t = None
                self._retries += 1
                self._tick_retries += 1
                self._queue.append(req)
                return
            if self._settle_partial(
                    req, f"retry budget exhausted "
                    f"({req.retries} retries): {msg}"):
                settled.append(req)
                return
            msg = f"retry budget exhausted ({req.retries} retries): {msg}"
        self._fail(req, msg)
        settled.append(req)

    def _plan_jobs(self, req: ServeRequest) -> List[_Job]:
        """Open/reuse the request's session and plan its chunk jobs.

        Planner errors (infeasible byte targets, bounds below eb) raise —
        the tick isolates them to this request.
        """
        archive = self._archives[req.archive_id]
        if req._reader is None:
            if req.refine_of is not None:
                # branch a PRIVATE session off the parent: siblings that
                # refine the same parent in the same tick must not alias
                # one mutable state/reader, or the later sibling's delta
                # would be computed against the earlier sibling's planes
                # (breaking per-request bit parity with private sessions)
                req._state = fork_state(req.refine_of._state)
                req._reader = req._state.reader
            else:
                req._reader = archive.new_reader(cache_scope=req.archive_id)
        reader, state = req._reader, req._state
        prop = req.propagation
        if not archive.chunked:
            keep = decode.plan_retrieval(reader.meta, req.fidelity,
                                         prop).keep_planes
            return [_Job(req, None, reader, state, keep)]
        if isinstance(reader, V3ArchiveReader):
            # plane-major: one ladder plan for the whole grid, ONE
            # contiguous range staged up front — the per-chunk jobs then
            # decode from the staged prefix, so coalesced ticks keep the
            # v3 monotone-contiguous read pattern (the server is the
            # range-request client the layout was designed for)
            if state is None:
                state = req._state = ChunkedRetrievalState(
                    reader=reader,
                    chunk_states=[None] * len(reader.meta.chunks))
            t = decode.plan_ladder(reader.meta, req.fidelity, prop,
                                   t_min=state.ladder_pos)
            reader.ensure_prefix(t)
            keeps = reader.meta.ladder_keeps(t)
            req._ladder_t = t
            return [_Job(req, i, reader.chunk_reader(i),
                         state.chunk_states[i], keeps[i])
                    for i in range(len(reader.meta.chunks))]
        budgets = decode.chunk_budgets(reader, req.fidelity, state)
        if state is None:
            state = req._state = ChunkedRetrievalState(
                reader=reader,
                chunk_states=[None] * len(reader.meta.chunks))
        jobs = []
        for i in range(len(reader.meta.chunks)):
            sub = reader.chunk_reader(i)
            keep = decode.plan_retrieval(
                sub.meta, decode.sub_fidelity(req.fidelity, budgets, i),
                prop).keep_planes
            jobs.append(_Job(req, i, sub, state.chunk_states[i], keep))
        return jobs

    def run_tick(self) -> List[ServeRequest]:
        """One scheduler tick: plan every runnable request, coalesce the
        chunk jobs into shape groups, execute each group as one batched
        launch sequence, assemble per-request results.  Returns the
        requests that settled (DONE or FAILED) this tick.
        """
        self.ticks += 1
        self._tick_retries = 0
        ready, settled = self._runnable()
        groups: Dict[tuple, List[_Job]] = {}
        by_req: Dict[int, List[_Job]] = {}
        for req in ready:
            req.status = RUNNING
            try:
                jobs = self._plan_jobs(req)
            except Exception as e:
                # planner rejection or a transport error while staging
                # the ladder prefix: isolate to this request — retry,
                # degrade, or fail per _resolve_failure
                self._resolve_failure(req, e, settled)
                continue
            by_req[req.req_id] = jobs
            for job in jobs:
                # v1 slabs never group with v2 chunks: they bind the
                # policy differently (no chunk grid to place on a mesh)
                sig = (job.chunk_idx is not None,) \
                    + _shape_sig(job.sub_reader.meta) + (req.propagation,)
                if not self.coalesce:
                    sig = sig + (req.req_id,)
                groups.setdefault(sig, []).append(job)
        # one bound context per archive kind, mirroring read_archive: v1
        # jobs run under chunked=False (an explicit mesh is rejected there
        # exactly as it is for sessions — isolated to the v1 requests)
        ctxs: Dict[bool, object] = {}
        for sig, jobs in groups.items():
            chunked, prop = sig[0], jobs[0].req.propagation
            try:
                if chunked not in ctxs:
                    ctxs[chunked] = self.policy.bind(chunked=chunked,
                                                     encode=False)
            except Exception as e:
                for job in jobs:
                    if job.req.status == RUNNING:
                        self._fail(job.req, f"{type(e).__name__}: {e}")
                        settled.append(job.req)
                continue
            ctx = ctxs[chunked]
            cap = group_cap(ctx.mesh)
            for lo in range(0, len(jobs), cap):
                # a request resolved by an earlier failing slice drops
                # out of later slices: its jobs will be re-planned (or
                # never run) — decoding them now would waste the launch
                part = [j for j in jobs[lo:lo + cap]
                        if j.req.status == RUNNING]
                if not part:
                    continue
                try:
                    # requests sharing a group share a propagation (in sig)
                    sts = decode.decode_group(
                        [j.sub_reader for j in part],
                        [j.prior_state for j in part],
                        [j.keep_planes for j in part],
                        ctx, prop, cache=self.cache, counters=self.counters)
                except Exception as e:
                    # a mid-group fetch failure aborts the whole slice:
                    # every owning request resolves (retry/degrade/fail)
                    # — committed states are untouched, since failed
                    # reads raise before any accounting or state merge
                    for r in {j.req.req_id: j.req for j in part}.values():
                        if r.status == RUNNING:
                            self._resolve_failure(r, e, settled)
                    continue
                for job, st in zip(part, sts):
                    job.new_state = st
        for req in ready:
            if req.status != RUNNING:
                continue
            self._assemble(req, by_req[req.req_id])
            settled.append(req)
        return settled

    def _assemble(self, req: ServeRequest, jobs: List[_Job]) -> None:
        """Merge a request's finished chunk states into its result and
        session accounting (mirrors ``decode._retrieve_chunked``'s
        epilogue)."""
        reader = req._reader
        m = reader.meta
        if jobs[0].chunk_idx is None:
            st = jobs[0].new_state
            req._state = st
            req.result = st.xhat.astype(np.dtype(m.dtype))
            req.err_bound = st.err_bound
            req.bytes_read = reader.bytes_read
        else:
            state: ChunkedRetrievalState = req._state
            for job in jobs:
                state.chunk_states[job.chunk_idx] = job.new_state
            out = np.empty(m.shape, np.dtype(m.dtype))
            for i, cm in enumerate(m.chunks):
                out[cm.start:cm.stop] = \
                    state.chunk_states[i].xhat.astype(np.dtype(m.dtype))
            state.err_bound = max(cs.err_bound
                                  for cs in state.chunk_states)
            state.bytes_read = reader.bytes_read
            if req._ladder_t is not None:   # v3: record the held prefix
                state.ladder_pos = max(state.ladder_pos, req._ladder_t)
                req._ladder_t = None
            req.result = out
            req.err_bound = state.err_bound
            req.bytes_read = state.bytes_read
        req.status = DONE
        req.latency_s = time.perf_counter() - req.submitted_s
        self._done += 1

    def drain(self, max_ticks: int = 1000) -> List[ServeRequest]:
        """Run ticks until the queue is empty; returns every request that
        settled.  ``max_ticks`` guards against a stuck dependency chain
        (a child whose parent never settles)."""
        settled: List[ServeRequest] = []
        while self._queue:
            if self.ticks >= max_ticks:
                raise RuntimeError(
                    f"drain exceeded {max_ticks} ticks with "
                    f"{len(self._queue)} requests still queued")
            progressed = self.run_tick()
            # a tick that only re-queued transport retries is progress
            # (the budget bounds it); zero settlements AND zero retries
            # with a non-empty queue is a real dependency deadlock
            if not progressed and not self._tick_retries and self._queue:
                raise RuntimeError(
                    "scheduler stalled: queued requests have unsatisfied "
                    "refine dependencies")
            settled.extend(progressed)
        return settled

    # ---- introspection

    def stats(self) -> dict:
        """Lifetime accounting snapshot (JSON-serializable)."""
        out = {
            "ticks": self.ticks,
            "pending": len(self._queue),
            "done": self._done,
            "failed": self._failed,
            "partial": self._partial,
            "retries": self._retries,
            "retry_budget": self.retry_budget,
            "coalesce": self.coalesce,
            "counters": dict(self.counters),
            "archives": len(self._archives),
        }
        if self.cache is not None:
            out["cache"] = self.cache.stats()
        return out

    def __repr__(self) -> str:
        return (f"RetrievalServer({len(self._archives)} archives, "
                f"{len(self._queue)} queued, {self._done} done, "
                f"{self._partial} partial, {self._failed} failed, "
                f"coalesce={self.coalesce})")
