"""IPComp first-class object API: Codec / Archive / Fidelity / ExecPolicy.

The paper's value proposition is the *progressive session* (§4,
Algorithm 2): open an archive coarse, then incrementally refine toward a
stated fidelity, paying only for the bitplanes each step adds.  This
module is that interaction model as objects::

    from repro import Codec, Archive, Fidelity, ExecPolicy

    codec = Codec(eb=1e-6, chunk_elems=1 << 20)      # bytes-affecting spec
    archive = codec.compress(x)                      # -> Archive
    archive.save("field.ipc")

    session = Archive.load("field.ipc").open(ExecPolicy(backend="jax"))
    coarse = session.read(Fidelity.error_bound(1e-2))
    finer = session.refine(Fidelity.error_bound(1e-5))   # only new planes
    session.bytes_read, session.achieved_bound           # live accounting

The four types split the old kwarg-threaded surface along its real
seams:

* :class:`Codec` — everything that *changes archive bytes* (error bound,
  interpolator, relative scaling, chunking).
* :class:`ExecPolicy` — everything that *never* changes bytes or bits
  (backend substrate, chunk batching, mesh sharding), validated once at
  construction.  ``tests/test_policy_matrix.py`` pins the invariance.
* :class:`Fidelity` — the retrieval target as a sum type
  (``error_bound`` / ``max_bytes`` / ``bitrate`` / ``full``); exactly one
  alternative per instance, so over-specification is unrepresentable.
* :class:`Archive` + :class:`ProgressiveReader` — the bytes and the
  session.  The session owns the progressive state the legacy API made
  callers hand-carry between ``retrieve``/``refine`` calls.

The legacy free functions (``compress`` / ``retrieve`` / ``refine`` /
``decompress``) remain as one-screen shims over these objects — same
bytes, same bits, one :class:`IPCompDeprecationWarning` per call — so
every existing archive and call site keeps working.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Iterable, Iterator, Optional, Tuple, Union

import numpy as np

from .core import container, interpolation, loader
from .core.bytesource import ByteSource, FileSource, as_source
from .core.container import CorruptArchiveError
from .core.pipeline import decode, encode
from .core.pipeline.spec import (DEFAULT_POLICY, ExecContext, ExecPolicy,
                                 Fidelity, IPCompDeprecationWarning)
from .core.pipeline.state import ChunkedRetrievalState, RetrievalState

# legacy free functions, re-exported so ``repro`` is a one-stop import for
# both generations of the API (each emits one IPCompDeprecationWarning)
from .core.pipeline.decode import (decompress, open_archive, refine,
                                   retrieve)
from .core.pipeline.encode import compress

__all__ = [
    "Codec", "Archive", "ProgressiveReader", "Fidelity", "ExecPolicy",
    "ExecContext", "DEFAULT_POLICY", "CorruptArchiveError",
    "IPCompDeprecationWarning",
    "compress", "decompress", "retrieve", "refine", "open_archive",
    "RetrievalState", "ChunkedRetrievalState",
]


@dataclass(frozen=True)
class Codec:
    """The bytes-affecting compression spec (paper Fig. 2 pipeline).

    Two arrays compressed with equal :class:`Codec`s yield comparable
    archives no matter which :class:`ExecPolicy` runs the work; change
    any field here and the bytes change.  Frozen + hashable, so a Codec
    can key caches and be shared freely.

    ``eb``
        Point-wise error bound (> 0).  With ``relative=True`` it is a
        fraction of each array's value range instead of an absolute bound.
    ``interp``
        Interpolation predictor: ``"cubic"`` (default) or ``"linear"``.
    ``chunk_elems``
        None = single v1 archive; N = chunked container of independent
        ~N-element slabs (the unit of batched and sharded execution).
    ``version``
        Container framing: 1 (plain), 2 (chunk-major), 3 (plane-major —
        the streaming/range-read layout, ``docs/format.md`` §3).  None
        picks the historical default from ``chunk_elems`` (1 unchunked /
        2 chunked).  The framing regroups identical per-chunk streams, so
        v2 and v3 archives of one array reconstruct bit-identically.
    """
    eb: float
    interp: str = interpolation.CUBIC
    relative: bool = False
    chunk_elems: Optional[int] = None
    version: Optional[int] = None

    def __post_init__(self):
        if not self.eb > 0:
            raise ValueError(f"error bound must be positive, got {self.eb}")
        if self.interp not in (interpolation.LINEAR, interpolation.CUBIC):
            raise ValueError(
                f"unknown interpolator {self.interp!r}; use "
                f"{interpolation.LINEAR!r} or {interpolation.CUBIC!r}")
        if self.chunk_elems is not None and self.chunk_elems <= 0:
            raise ValueError("chunk_elems must be positive, got "
                             f"{self.chunk_elems}")
        if self.version is not None:
            if self.version not in (1, 2, 3):
                raise ValueError(f"unknown container version "
                                 f"{self.version!r}; expected 1, 2 or 3")
            if self.version == 1 and self.chunk_elems is not None:
                raise ValueError("version=1 cannot hold chunks; drop "
                                 "chunk_elems or use version 2 or 3")
            if self.version == 2 and self.chunk_elems is None:
                raise ValueError("version=2 is the chunked container; "
                                 "pass chunk_elems (or use version=1)")

    def compress(self, x: np.ndarray,
                 policy: Optional[ExecPolicy] = None) -> "Archive":
        """Compress ``x`` under this spec -> :class:`Archive`.

        ``policy`` selects the execution substrate only; archives are
        byte-identical across policies.
        """
        return Archive(encode.encode_array(
            x, self.eb, interp=self.interp, relative=self.relative,
            chunk_elems=self.chunk_elems, policy=policy,
            version=self.version))


class Archive:
    """An IPComp archive: an immutable byte source plus the parsed header.

    Wraps any container version (v1 plain / v2 chunk-major / v3
    plane-major) behind one type; construction validates the buffer
    (:class:`CorruptArchiveError` on unknown magic, truncation, or
    undecodable headers), so an Archive in hand is known-well-formed.
    Round-trips losslessly through :meth:`tobytes` / :meth:`frombytes`
    and :meth:`save` / :meth:`load`.

    The backing storage is a pluggable
    :class:`~repro.core.bytesource.ByteSource`: in-memory bytes (the
    default), a file opened by :meth:`load` (mmap-backed — header and
    planned blob ranges are the only bytes ever touched, never a full
    read), or any caller-provided source via :meth:`from_source` (e.g. a
    ``CountingSource`` for range accounting).

    Reading is a *session*: :meth:`open` returns a
    :class:`ProgressiveReader` owning its own retrieval state and byte
    accounting, so several sessions can progress through one Archive
    independently.
    """

    def __init__(self, data: Union[bytes, bytearray, memoryview,
                                   ByteSource]):
        self._src = as_source(data)
        self._meta = container.open_reader(self._src).meta  # validates

    # ---- construction / serialization

    @classmethod
    def frombytes(cls, data: Union[bytes, bytearray, memoryview]
                  ) -> "Archive":
        """Wrap serialized archive bytes (the :meth:`tobytes` inverse)."""
        return cls(data)

    @classmethod
    def from_source(cls, src: ByteSource) -> "Archive":
        """Open an archive over an explicit byte source — a
        ``FileSource``, a ``CountingSource`` wrapper, or any custom
        range-read transport satisfying the ``ByteSource`` contract."""
        return cls(src)

    def tobytes(self) -> bytes:
        """The raw archive bytes, materialized (``IPC1``/``IPC2``/``IPC3``
        container).  On a file-backed archive this reads the whole file —
        use :meth:`save` to copy without keeping it in memory."""
        return bytes(self._src.read(0, self._src.size))

    #: streaming block size for save/compare — large enough to amortize
    #: syscalls, small enough to never matter for memory
    _BLOCK = 1 << 20

    @classmethod
    def load(cls, path: Union[str, "os.PathLike"]) -> "Archive":
        """Open an archive file written by :meth:`save` (or any producer
        of the container format).  Accepts ``str`` or ``pathlib.Path``.
        The file is opened through a mmap-backed ``FileSource``, NOT read
        into memory: a session over a loaded archive touches only the
        header and the byte ranges its fidelity plans actually need."""
        return cls(FileSource(path))

    def save(self, path: Union[str, "os.PathLike"]) -> None:
        """Write the archive bytes to ``path`` (``str`` or
        ``pathlib.Path``), streaming in blocks — a file-backed archive is
        copied without ever materializing in memory."""
        with open(os.fspath(path), "wb") as f:
            for off in range(0, self._src.size, self._BLOCK):
                f.write(self._src.read(
                    off, min(self._BLOCK, self._src.size - off)))

    # ---- parsed-header views

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self._meta.shape)

    @property
    def dtype(self) -> np.dtype:
        return np.dtype(self._meta.dtype)

    @property
    def eb(self) -> float:
        """The point-wise error bound the archive was written with
        (absolute — ``Codec.relative`` is resolved at compression time)."""
        return float(self._meta.eb)

    @property
    def interp(self) -> str:
        return self._meta.interp

    @property
    def nbytes(self) -> int:
        """Total serialized size (the compressed-ratio denominator)."""
        return self._src.size

    @property
    def version(self) -> int:
        """Container version of the underlying bytes (1, 2 or 3)."""
        if isinstance(self._meta, container.V3Meta):
            return 3
        if isinstance(self._meta, container.ChunkedMeta):
            return 2
        return 1

    @property
    def n_chunks(self) -> int:
        """Independent slabs: 1 for a v1 archive, the chunk-grid size for
        v2/v3."""
        return len(getattr(self._meta, "chunks", ())) or 1

    @property
    def chunked(self) -> bool:
        return hasattr(self._meta, "chunks")

    def __len__(self) -> int:
        return self._src.size

    def __eq__(self, other) -> bool:
        """Content equality, compared block-wise — file-backed archives
        compare without materializing (identity and size short-circuit
        first).  Equality is what makes an Archive a sound plane-cache
        scope: equal keys imply equal bytes."""
        if not isinstance(other, Archive):
            return NotImplemented
        if self is other or self._src is other._src:
            return True
        if self._src.size != other._src.size:
            return False
        for off in range(0, self._src.size, self._BLOCK):
            n = min(self._BLOCK, self._src.size - off)
            if bytes(self._src.read(off, n)) != \
                    bytes(other._src.read(off, n)):
                return False
        return True

    def __hash__(self) -> int:
        # size + header prefix: cheap, stable, and consistent with __eq__
        # (equal bytes always collide onto the same hash)
        return hash((self._src.size, bytes(self._src.read(
            0, min(4096, self._src.size)))))

    def __repr__(self) -> str:
        kind = (f"v{self.version}[{self.n_chunks} chunks]" if self.chunked
                else "v1")
        return (f"Archive({kind}, shape={self.shape}, dtype={self.dtype}, "
                f"eb={self.eb:g}, {self.nbytes} bytes)")

    # ---- reading

    def new_reader(self, cache_scope=None):
        """A fresh low-level container reader over this archive's byte
        source (``ArchiveReader`` / ``ChunkedArchiveReader`` /
        ``V3ArchiveReader``) with independent fetched-range accounting.

        ``cache_scope`` opts the reader into shared plane-cache keying
        (see ``pipeline.state``); equal scopes MUST mean identical
        archive bytes.  The serving tier uses its registry id; sessions
        opened with a ``plane_cache`` use the Archive itself (Archives
        compare by content, so equal keys imply equal bytes).
        """
        reader = container.open_reader(self._src, meta=self._meta)
        reader.cache_scope = cache_scope
        return reader

    def open(self, policy: Optional[ExecPolicy] = None,
             propagation: str = loader.SAFE,
             plane_cache=None) -> "ProgressiveReader":
        """Start a progressive session -> :class:`ProgressiveReader`.

        Each call returns an independent session with fresh byte
        accounting; ``policy`` is the session's initial execution policy
        (swap it mid-session via :attr:`ProgressiveReader.policy` — the
        state is policy-agnostic by design).  ``propagation`` picks the
        error-propagation model of the DP planner (``loader.SAFE``
        default / ``loader.PAPER``).  ``plane_cache`` attaches a shared
        ``repro.serving.PlaneCache``: sessions over equal archives then
        reuse each other's decoded plane prefixes (bits never change;
        ``bytes_read`` may shrink on cache hits).
        """
        return ProgressiveReader(self, policy=policy,
                                 propagation=propagation,
                                 plane_cache=plane_cache)


class ProgressiveReader:
    """A progressive retrieval session over one :class:`Archive`.

    Owns what the legacy API made callers hand-carry: the container
    reader (with its fetched-range accounting) and the
    :class:`RetrievalState` of Algorithm 2.  Every :meth:`read` /
    :meth:`refine` fetches only the bitplanes the new
    :class:`Fidelity` adds on top of what the session already holds and
    pushes a linear delta cascade — never a from-scratch decode.

    The session's :attr:`policy` may be swapped between calls (backend,
    batching, mesh): reconstruction bits never depend on it, so a
    retrieval started on one substrate can be refined on another.
    """

    def __init__(self, archive: Archive,
                 policy: Optional[ExecPolicy] = None,
                 propagation: str = loader.SAFE,
                 plane_cache=None):
        self._archive = archive
        # with a shared plane cache the content-equal Archive is the cache
        # scope: equal scope keys then imply equal archive bytes, so two
        # sessions over the same data reuse each other's decoded prefixes
        self._reader = archive.new_reader(
            cache_scope=archive if plane_cache is not None else None)
        self._cache = plane_cache
        self._propagation = propagation
        self._state: Optional[RetrievalState] = None
        self._data: Optional[np.ndarray] = None
        self.policy = policy if policy is not None else DEFAULT_POLICY

    # ---- policy (swappable mid-session)

    @property
    def policy(self) -> ExecPolicy:
        """The session's execution policy.  Assignable mid-session; never
        changes reconstruction bits."""
        return self._policy

    @policy.setter
    def policy(self, policy: ExecPolicy) -> None:
        if not isinstance(policy, ExecPolicy):
            raise TypeError("policy must be an ExecPolicy, got "
                            f"{type(policy).__name__}")
        self._policy = policy

    # ---- progressive reads

    def read(self, fidelity: Optional[Fidelity] = None) -> np.ndarray:
        """Advance the session to (at least) ``fidelity`` and return the
        reconstruction.

        Default: :meth:`Fidelity.full`.  Refinement never drops planes,
        so a looser target than the session already satisfies is a no-op
        returning the current data.
        """
        if fidelity is not None and not isinstance(fidelity, Fidelity):
            raise TypeError(
                f"fidelity must be a Fidelity, got {fidelity!r} — e.g. "
                "Fidelity.error_bound(E), .max_bytes(n), .bitrate(b), or "
                ".full()")
        out, self._state = decode.read_archive(
            self._reader, fidelity, self._policy,
            propagation=self._propagation, state=self._state,
            cache=self._cache)
        self._data = out
        return out

    def refine(self, fidelity: Optional[Fidelity] = None) -> np.ndarray:
        """Alias of :meth:`read`, named for the Algorithm 2 reading: on a
        session with loaded planes, only the *additional* planes the
        target needs are fetched and cascaded."""
        return self.read(fidelity)

    def ladder(self, fidelities: Iterable[Fidelity]
               ) -> Iterator[Tuple[Fidelity, np.ndarray]]:
        """Iterate a fidelity ladder: yield ``(fidelity, data)`` after
        refining to each rung in turn.

        Lazy — each rung's planes are fetched when the iterator reaches
        it, so breaking out early reads no more than was consumed::

            for fid, out in session.ladder(map(Fidelity.error_bound,
                                               (1e-2, 1e-4, 1e-6))):
                if analysis_converged(out):
                    break
        """
        for fid in fidelities:
            yield fid, self.read(fid)

    # ---- session introspection

    @property
    def archive(self) -> Archive:
        return self._archive

    @property
    def data(self) -> Optional[np.ndarray]:
        """The latest reconstruction (None before the first read)."""
        return self._data

    @property
    def bytes_read(self) -> int:
        """Cumulative data bytes this session fetched (the retrieval-
        volume metric of paper Figs. 6/7; header bytes excluded)."""
        return self._reader.bytes_read

    @property
    def achieved_bound(self) -> float:
        """Guaranteed L_inf bound of the current reconstruction (inf
        before the first read)."""
        return self._state.err_bound if self._state is not None \
            else float("inf")

    def __repr__(self) -> str:
        bound = self.achieved_bound
        return (f"ProgressiveReader({self._archive!r}, "
                f"bytes_read={self.bytes_read}, "
                f"achieved_bound={bound:g})")
