"""Unified model: dense / MoE / SSM / hybrid / enc-dec / VLM families.

One parameter pytree + three apply paths (train forward, prefill, decode),
all built on the same block primitives.  The layer stack is ``lax.scan``-ned
over stacked parameters, so HLO size and compile time are O(1) in depth —
essential for the 61-layer trillion-parameter dry-runs on a CPU host.

Sharding is injected via ``repro.parallel.api.shard_act`` constraints so the
same code runs unsharded on CPU tests and fully sharded under the
production mesh.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers, moe as moe_mod, ssm as ssm_mod
from .config import ModelConfig
from ..parallel.api import shard_act

P = Dict[str, jax.Array]


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ================================================================== init

def init_layer(cfg: ModelConfig, key, cross: bool = False) -> P:
    dtype = _dt(cfg)
    ks = jax.random.split(key, 8)
    p: P = dict(ln1=jnp.ones((cfg.d_model,), dtype))
    fam = cfg.family
    if fam in ("dense", "vlm", "encdec"):
        p["attn"] = layers.init_attn(ks[0], cfg, dtype)
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        p["mlp"] = layers.init_mlp(ks[1], cfg, dtype)
        if cross:
            p["lnx"] = jnp.ones((cfg.d_model,), dtype)
            p["xattn"] = layers.init_attn(ks[2], cfg, dtype)
    elif fam == "moe":
        p["attn"] = layers.init_attn(ks[0], cfg, dtype)
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    elif fam == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg, dtype)
    elif fam == "hybrid":
        p["attn"] = layers.init_attn(ks[0], cfg, dtype)
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg, dtype)
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        p["mlp"] = layers.init_mlp(ks[2], cfg, dtype)
    else:
        raise ValueError(fam)
    return p


def init_params(cfg: ModelConfig, key) -> P:
    dtype = _dt(cfg)
    ks = jax.random.split(key, 6)
    stack = jax.vmap(lambda k: init_layer(cfg, k, cross=cfg.family == "encdec")
                     )(jax.random.split(ks[0], cfg.n_layers))
    p: P = dict(
        embed=(jax.random.normal(ks[1], (cfg.vocab, cfg.d_model)) * 0.02
               ).astype(dtype),
        blocks=stack,
        norm_f=jnp.ones((cfg.d_model,), dtype),
    )
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(ks[2], (cfg.d_model, cfg.vocab))
                     * 0.02).astype(dtype)
    if cfg.family == "encdec":
        enc_cfg = cfg  # same dims; bidirectional attention in apply
        p["enc_blocks"] = jax.vmap(lambda k: init_layer(enc_cfg, k))(
            jax.random.split(ks[3], cfg.encoder_layers))
        p["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
    return p


# ================================================================== blocks

def _attn_block(x, p, cfg: ModelConfig, positions, causal=True,
                kv_override=None, window=None):
    h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = layers.attn_proj(h, p["attn"], cfg)
    q = layers.rope(q, positions, cfg.rope_theta)
    if kv_override is None:
        k = layers.rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
    q = shard_act(q, "batch", None, "heads", None)
    k = shard_act(k, "batch", None, "kv_heads", None)
    w = cfg.sliding_window if window is None else window
    o = layers.flash_attention(q, k, v, causal=causal, window=w,
                               q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                               unroll=cfg.unroll_scans)
    return layers.attn_out(o, p["attn"]), (k, v)


def block_train(x, p, cfg: ModelConfig, positions):
    fam = cfg.family
    x = shard_act(x, "batch", None, None)
    if fam in ("dense", "vlm", "moe", "encdec"):
        a, kv = _attn_block(x, p, cfg, positions)
        x = x + a
        h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if fam == "moe":
            x = x + moe_mod.moe_block(h, p["moe"], cfg)
        else:
            x = x + layers.swiglu(h, p["mlp"])
    elif fam == "ssm":
        h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
        x = x + ssm_mod.ssm_block(h, p["ssm"], cfg)
    elif fam == "hybrid":
        h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = layers.attn_proj(h, p["attn"], cfg)
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
        attn_o = layers.flash_attention(q, k, v, causal=True,
                                        window=cfg.sliding_window,
                                        q_chunk=cfg.q_chunk,
                                        kv_chunk=cfg.kv_chunk,
                                        unroll=cfg.unroll_scans)
        attn_o = layers.attn_out(attn_o, p["attn"])
        ssm_o = ssm_mod.ssm_block(h, p["ssm"], cfg)
        x = x + 0.5 * (attn_o + ssm_o)          # Hymba parallel heads (mean)
        h2 = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + layers.swiglu(h2, p["mlp"])
    return x


def _remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat_policy == "dots" else
              jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(fn, policy=policy)


def _layer_slice(stacked, i):
    return jax.tree_util.tree_map(lambda p: p[i], stacked)


def _scan_blocks(x, stacked: P, cfg: ModelConfig, fn):
    body = _remat(fn, cfg)
    if not cfg.scan_layers:  # unrolled: exact cost_analysis per layer
        L = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        for i in range(L):
            x = body(x, _layer_slice(stacked, i))
        return x

    def step(h, lp):
        return body(h, lp), None

    x, _ = jax.lax.scan(step, x, stacked)
    return x


# ================================================================== forward

def embed_tokens(params: P, tokens, cfg: ModelConfig,
                 prefix_embeds=None):
    x = params["embed"][tokens] * 1.0
    # pin the gather output: without this, SPMD explores a pathological
    # vocab-shard -> batch-shard reshard on the multi-pod mesh (hard crash
    # in spmd_partitioner_util on XLA:CPU; see EXPERIMENTS.md §Dry-run)
    x = shard_act(x, "batch", None, None)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return x


def encoder_apply(params: P, frames, cfg: ModelConfig):
    """Whisper-style bidirectional encoder over precomputed frame embeds."""
    x = frames.astype(_dt(cfg))
    pos = jnp.arange(x.shape[1])[None, :]

    def fn(h, lp):
        a, _ = _attn_block(h, lp, cfg, pos, causal=False)
        h = h + a
        hh = layers.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        return h + layers.swiglu(hh, lp["mlp"])

    x = _scan_blocks(x, params["enc_blocks"], cfg, fn)
    return layers.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def forward(params: P, tokens, cfg: ModelConfig, prefix_embeds=None,
            encoder_frames=None):
    """Training forward -> final hidden states (B, S, d)."""
    x = embed_tokens(params, tokens, cfg, prefix_embeds)
    positions = jnp.arange(x.shape[1])[None, :]
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encoder_apply(params, encoder_frames, cfg)
        ek, ev = None, None

        def fn(h, lp):
            a, _ = _attn_block(h, lp, cfg, positions, causal=True)
            h = h + a
            hx = layers.rmsnorm(h, lp["lnx"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhe->bshe", hx, lp["xattn"]["wq"])
            k = jnp.einsum("bsd,dhe->bshe", enc_out, lp["xattn"]["wk"])
            v = jnp.einsum("bsd,dhe->bshe", enc_out, lp["xattn"]["wv"])
            o = layers.flash_attention(q, k, v, causal=False,
                                       q_chunk=cfg.q_chunk,
                                       kv_chunk=cfg.kv_chunk,
                                       unroll=cfg.unroll_scans)
            h = h + jnp.einsum("bshe,hed->bsd", o, lp["xattn"]["wo"])
            hh = layers.rmsnorm(h, lp["ln2"], cfg.norm_eps)
            return h + layers.swiglu(hh, lp["mlp"])

        x = _scan_blocks(x, params["blocks"], cfg, fn)
    else:
        x = _scan_blocks(x, params["blocks"], cfg,
                         lambda h, lp: block_train(h, lp, cfg, positions))
    return layers.rmsnorm(x, params["norm_f"], cfg.norm_eps)


def lm_head(params: P, h, cfg: ModelConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", h, w)


def chunked_ce_loss(params: P, h, labels, cfg: ModelConfig,
                    chunk: int = 512):
    """Cross-entropy without materializing (B, S, V) logits at once."""
    B, S, d = h.shape
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    c = min(chunk, S)
    n = -(-S // c)
    pad = n * c - S
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    hc = h.reshape(B, n, c, d)
    lc = labels.reshape(B, n, c)

    @jax.checkpoint  # recompute (B,c,V) logits in backward: never resident
    def chunk_loss(hb, lb):
        logits = jnp.einsum("bcd,dv->bcv", hb, w).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    def step(acc, inp):
        hb, lb = inp                      # (B, c, d), (B, c)
        return acc + chunk_loss(hb, lb), None

    if cfg.unroll_scans:  # probe mode: make every chunk's matmul visible
        tot = jnp.float32(0.0)
        for i in range(n):
            tot = tot + chunk_loss(hc[:, i], lc[:, i])
    else:
        tot, _ = jax.lax.scan(step, jnp.float32(0.0),
                              (hc.transpose(1, 0, 2, 3), lc.transpose(1, 0, 2)))
    return tot / (B * S)


# ================================================================== prefill

def prefill(params: P, tokens, cfg: ModelConfig, max_len: Optional[int] = None,
            prefix_embeds=None, encoder_frames=None):
    """Forward pass that also builds the decode cache.

    Returns (last-position logits (B, V), cache).  Attention KV are cached
    post-RoPE at absolute positions; SSM blocks return their final state.
    """
    x = embed_tokens(params, tokens, cfg, prefix_embeds)
    B, S = x.shape[0], x.shape[1]
    max_len = max(max_len or 0, S)  # prefix embeds extend the true length
    positions = jnp.arange(S)[None, :]
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encoder_apply(params, encoder_frames, cfg)

    def fn(h, lp):
        ys = {}
        fam = cfg.family
        if fam in ("dense", "vlm", "moe", "encdec"):
            hn = layers.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = layers.attn_proj(hn, lp["attn"], cfg)
            q = layers.rope(q, positions, cfg.rope_theta)
            k = layers.rope(k, positions, cfg.rope_theta)
            o = layers.flash_attention(q, k, v, causal=True,
                                       window=cfg.sliding_window,
                                       q_chunk=cfg.q_chunk,
                                       kv_chunk=cfg.kv_chunk,
                                       unroll=cfg.unroll_scans)
            h = h + layers.attn_out(o, lp["attn"])
            ys["k"], ys["v"] = k, v
            if fam == "encdec":
                hx = layers.rmsnorm(h, lp["lnx"], cfg.norm_eps)
                qx = jnp.einsum("bsd,dhe->bshe", hx, lp["xattn"]["wq"])
                ek = jnp.einsum("bsd,dhe->bshe", enc_out, lp["xattn"]["wk"])
                ev = jnp.einsum("bsd,dhe->bshe", enc_out, lp["xattn"]["wv"])
                ox = layers.flash_attention(qx, ek, ev, causal=False,
                                            q_chunk=cfg.q_chunk,
                                            kv_chunk=cfg.kv_chunk,
                                            unroll=cfg.unroll_scans)
                h = h + jnp.einsum("bshe,hed->bsd", ox, lp["xattn"]["wo"])
                ys["ek"], ys["ev"] = ek, ev
            hn2 = layers.rmsnorm(h, lp["ln2"], cfg.norm_eps)
            if fam == "moe":
                h = h + moe_mod.moe_block(hn2, lp["moe"], cfg)
            else:
                h = h + layers.swiglu(hn2, lp["mlp"])
        elif fam == "ssm":
            hn = layers.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            o, st = ssm_mod.ssm_block(hn, lp["ssm"], cfg, return_state=True)
            h = h + o
            ys["ssm"] = st
        elif fam == "hybrid":
            hn = layers.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = layers.attn_proj(hn, lp["attn"], cfg)
            q = layers.rope(q, positions, cfg.rope_theta)
            k = layers.rope(k, positions, cfg.rope_theta)
            ao = layers.flash_attention(q, k, v, causal=True,
                                        window=cfg.sliding_window,
                                        q_chunk=cfg.q_chunk,
                                        kv_chunk=cfg.kv_chunk,
                                        unroll=cfg.unroll_scans)
            ao = layers.attn_out(ao, lp["attn"])
            so, st = ssm_mod.ssm_block(hn, lp["ssm"], cfg, return_state=True)
            h = h + 0.5 * (ao + so)
            hn2 = layers.rmsnorm(h, lp["ln2"], cfg.norm_eps)
            h = h + layers.swiglu(hn2, lp["mlp"])
            ys["k"], ys["v"], ys["ssm"] = k, v, st
        return h, ys

    if cfg.scan_layers:
        x, ys = jax.lax.scan(lambda h, lp: fn(h, lp), x, params["blocks"])
    else:
        ys_list = []
        for i in range(cfg.n_layers):
            x, y = fn(x, _layer_slice(params["blocks"], i))
            ys_list.append(y)
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys_list)
    h = layers.rmsnorm(x, params["norm_f"], cfg.norm_eps)
    logits = lm_head(params, h[:, -1:], cfg)[:, 0]

    cache = init_cache(cfg, B, max_len)
    if "k" in cache:
        eff = cache["k"].shape[2]
        src_k, src_v = ys["k"], ys["v"]
        if cfg.sliding_window and eff < src_k.shape[2]:
            # rotating buffer invariant: position p lives in slot p % eff
            src_k = jnp.roll(src_k[:, :, -eff:], (S - eff) % eff, axis=2)
            src_v = jnp.roll(src_v[:, :, -eff:], (S - eff) % eff, axis=2)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], src_k.astype(cache["k"].dtype), 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], src_v.astype(cache["v"].dtype), 0, axis=2)
    if "ssm" in cache:
        cache["ssm"] = ys["ssm"]
    if "ek" in cache:
        cache["ek"], cache["ev"] = ys["ek"], ys["ev"]
    cache["len"] = jnp.asarray(S, jnp.int32)
    return logits, cache


# ================================================================== decode

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> P:
    """Decode cache pytree; attention caches are sequence-sharded."""
    dtype = _dt(cfg)
    L = cfg.n_layers
    cache: P = dict(len=jnp.zeros((), jnp.int32))
    eff = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    if cfg.family in ("dense", "vlm", "moe", "encdec", "hybrid"):
        cache["k"] = jnp.zeros((L, batch, eff, cfg.n_kv_heads, cfg.hd), dtype)
        cache["v"] = jnp.zeros((L, batch, eff, cfg.n_kv_heads, cfg.hd), dtype)
    if cfg.family in ("ssm", "hybrid"):
        cache["ssm"] = jnp.zeros((L, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                                  cfg.ssm_state), jnp.float32)
    if cfg.family == "encdec":
        cache["ek"] = jnp.zeros((L, batch, cfg.encoder_seq, cfg.n_kv_heads,
                                 cfg.hd), dtype)
        cache["ev"] = jnp.zeros((L, batch, cfg.encoder_seq, cfg.n_kv_heads,
                                 cfg.hd), dtype)
    return cache


def decode_step(params: P, cache: P, token, cfg: ModelConfig):
    """One token for the whole batch. token: (B, 1) int32."""
    x = params["embed"][token] * 1.0
    pos = cache["len"]
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    has_attn = "k" in cache
    eff = cache["k"].shape[2] if has_attn else 0
    # rotating slot only for sliding-window caches; full caches write at pos
    # (XLA clamps OOB starts — callers must size max_len for decode room)
    widx = (pos % eff if cfg.sliding_window else pos) if has_attn else 0

    def step(h, lp_and_cache):
        lp, kc, vc, sc, ekc, evc = lp_and_cache
        new_k, new_v, new_s = kc, vc, sc
        if cfg.family in ("dense", "vlm", "moe", "encdec"):
            hn = layers.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = layers.attn_proj(hn, lp["attn"], cfg)
            q = layers.rope(q, positions, cfg.rope_theta)
            k = layers.rope(k, positions, cfg.rope_theta)
            new_k = jax.lax.dynamic_update_slice_in_dim(kc, k, widx, axis=1)
            new_v = jax.lax.dynamic_update_slice_in_dim(vc, v, widx, axis=1)
            clen = jnp.minimum(pos + 1, eff) * jnp.ones((h.shape[0],), jnp.int32)
            o = layers.decode_attention(q, new_k, new_v, clen)
            h = h + layers.attn_out(o, lp["attn"])
            if cfg.family == "encdec":
                hx = layers.rmsnorm(h, lp["lnx"], cfg.norm_eps)
                qx = jnp.einsum("bsd,dhe->bshe", hx, lp["xattn"]["wq"])
                enc_len = ekc.shape[1] * jnp.ones((h.shape[0],), jnp.int32)
                ox = layers.decode_attention(qx, ekc, evc, enc_len)
                h = h + jnp.einsum("bshe,hed->bsd", ox, lp["xattn"]["wo"])
            hn2 = layers.rmsnorm(h, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                h = h + moe_mod.moe_block(hn2, lp["moe"], cfg)
            else:
                h = h + layers.swiglu(hn2, lp["mlp"])
        elif cfg.family == "ssm":
            hn = layers.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            o, new_s = ssm_mod.ssm_decode_step(hn, lp["ssm"], cfg, sc)
            h = h + o
        elif cfg.family == "hybrid":
            hn = layers.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            q, k, v = layers.attn_proj(hn, lp["attn"], cfg)
            q = layers.rope(q, positions, cfg.rope_theta)
            k = layers.rope(k, positions, cfg.rope_theta)
            new_k = jax.lax.dynamic_update_slice_in_dim(kc, k, widx, axis=1)
            new_v = jax.lax.dynamic_update_slice_in_dim(vc, v, widx, axis=1)
            clen = jnp.minimum(pos + 1, eff) * jnp.ones((h.shape[0],), jnp.int32)
            ao = layers.attn_out(
                layers.decode_attention(q, new_k, new_v, clen), lp["attn"])
            so, new_s = ssm_mod.ssm_decode_step(hn, lp["ssm"], cfg, sc)
            h = h + 0.5 * (ao + so)
            hn2 = layers.rmsnorm(h, lp["ln2"], cfg.norm_eps)
            h = h + layers.swiglu(hn2, lp["mlp"])
        return h, (new_k, new_v, new_s)

    L = cfg.n_layers
    dummy = jnp.zeros((L, 1, 1), _dt(cfg))
    kc = cache.get("k", dummy)
    vc = cache.get("v", dummy)
    sc = cache.get("ssm", jnp.zeros((L, 1, 1, 1, 1), jnp.float32))
    ekc = cache.get("ek", dummy)
    evc = cache.get("ev", dummy)

    xs_all = (params["blocks"], kc, vc, sc, ekc, evc)
    if cfg.scan_layers:
        h, (nk, nv, ns) = jax.lax.scan(lambda h, xs: step(h, xs), x, xs_all)
    else:
        h = x
        outs = []
        for i in range(cfg.n_layers):
            h, o = step(h, jax.tree_util.tree_map(lambda p: p[i], xs_all))
            outs.append(o)
        nk, nv, ns = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *outs)
    h = layers.rmsnorm(h, params["norm_f"], cfg.norm_eps)
    logits = lm_head(params, h, cfg)
    new_cache = dict(cache)
    new_cache["len"] = cache["len"] + 1
    if "k" in cache:
        new_cache["k"], new_cache["v"] = nk, nv
    if "ssm" in cache:
        new_cache["ssm"] = ns
    return logits, new_cache
