"""Mamba2 SSD (state-space duality) block — chunked parallel scan.

Minimal SSD algorithm (Dao & Gu 2024): within a chunk, the sequence mixing
is a masked quadratic form (the "duality" with attention); across chunks a
linear recurrence carries the (H, P, N) state with scalar-per-head decay.
Decode is the O(1) recurrent update.  Pure jnp/lax — scan-friendly and
shardable (heads on the "model" axis).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _he


def init_ssm(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    di = d * cfg.ssm_expand
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return dict(
        in_x=_he(ks[0], (d, di), dtype, d),
        in_z=_he(ks[1], (d, di), dtype, d),
        in_B=_he(ks[2], (d, N), dtype, d),
        in_C=_he(ks[3], (d, N), dtype, d),
        in_dt=_he(ks[4], (d, H), dtype, d),
        out=_he(ks[5], (di, d), dtype, di),
        A_log=jnp.zeros((H,), jnp.float32),
        D=jnp.ones((H,), jnp.float32),
        dt_bias=jnp.zeros((H,), jnp.float32),
    )


def _segsum(z):
    """log-space cumulative decay matrix: L[i,j] = sum_{j<m<=i} z[m] (i>=j)."""
    T = z.shape[-1]
    cs = jnp.cumsum(z, axis=-1)
    L = cs[..., :, None] - cs[..., None, :]
    mask = np.tril(np.ones((T, T), bool))
    return jnp.where(mask, L, -jnp.inf)


def ssd_scan(x, dt, B, C, A, prev_state=None, chunk: int = 256,
             unroll: bool = False):
    """Chunked SSD. x: (b,S,H,P), dt: (b,S,H), B/C: (b,S,N), A: (H,) < 0.

    Returns (y (b,S,H,P), final_state (b,H,P,N)).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    xc = x.reshape(b, nc, Q, H, P)
    dtc = dt.reshape(b, nc, Q, H)
    Bc = B.reshape(b, nc, Q, N)
    Cc = C.reshape(b, nc, Q, N)
    dA = dtc * A  # (b,nc,Q,H) negative decays

    # within-chunk (diagonal blocks): y_i += C_i . sum_j exp(seg) dt_j B_j x_j
    Ls = _segsum(dA.transpose(0, 1, 3, 2))                 # (b,nc,H,Q,Q)
    att = jnp.exp(Ls) * jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)[:, :, None]
    y_diag = jnp.einsum("bchqs,bcsh,bcshp->bcqhp", att, dtc, xc)

    # chunk states: contribution of each chunk to the carried state
    dA_cum = jnp.cumsum(dA, axis=2)                        # (b,nc,Q,H)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (b,nc,Q,H)
    chunk_state = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                             Bc, dtc * decay_to_end, xc)   # (b,nc,H,P,N)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])             # (b,nc,H)

    def carry_fn(h, inp):
        st, dec = inp
        h_new = h * dec[..., None, None] + st
        return h_new, h

    h0 = prev_state if prev_state is not None else jnp.zeros((b, H, P, N),
                                                             jnp.float32)
    sts = chunk_state.transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    dcs = chunk_decay.transpose(1, 0, 2)
    if unroll:  # probe mode: cross-chunk recurrence visible to cost_analysis
        hs, hcur = [], h0
        for i in range(nc):
            hs.append(hcur)
            hcur = hcur * dcs[i][..., None, None] + sts[i]
        hT = hcur
        h_before = jnp.stack(hs, axis=1)                   # (b,nc,H,P,N)
    else:
        hT, h_before = jax.lax.scan(carry_fn, h0, (sts, dcs))
        h_before = h_before.transpose(1, 0, 2, 3, 4)       # (b,nc,H,P,N)

    # cross-chunk: y_i += C_i . exp(cum dA_i) h_in
    decay_in = jnp.exp(dA_cum)                             # (b,nc,Q,H)
    y_cross = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         Cc, decay_in, h_before.astype(x.dtype))
    y = (y_diag + y_cross).reshape(b, nc * Q, H, P)[:, :S]
    return y, hT


def ssm_block(x, p: Dict, cfg: ModelConfig, prev_state=None,
              return_state: bool = False):
    """Full Mamba2 mixer. x: (B,S,d)."""
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xin = jnp.einsum("bsd,de->bse", x, p["in_x"])
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["in_B"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["in_C"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["in_dt"]).astype(jnp.float32)
        + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    b, S, di = xin.shape
    xh = xin.reshape(b, S, H, P)
    y, state = ssd_scan(xh, dt, Bm, Cm, A, prev_state, cfg.ssm_chunk,
                        unroll=cfg.unroll_scans)
    y = y + xh * p["D"][None, None, :, None]
    y = (y.reshape(b, S, di) * jax.nn.silu(z)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out"])
    if return_state:
        return out, state
    return out


def ssm_decode_step(x, p: Dict, cfg: ModelConfig, state):
    """O(1) recurrent update. x: (B,1,d), state: (B,H,P,N)."""
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xin = jnp.einsum("bsd,de->bse", x, p["in_x"])
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["in_B"])[:, 0]      # (B,N)
    Cm = jnp.einsum("bsd,dn->bsn", x, p["in_C"])[:, 0]
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["in_dt"]).astype(jnp.float32)[:, 0]
        + p["dt_bias"])                                     # (B,H)
    A = -jnp.exp(p["A_log"])
    b = x.shape[0]
    xh = xin.reshape(b, H, P)
    decay = jnp.exp(dt * A)                                 # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh.astype(jnp.float32),
                     Bm.astype(jnp.float32))
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
    y = y + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = (y.reshape(b, 1, H * P) * jax.nn.silu(z)).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["out"]), state
