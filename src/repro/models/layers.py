"""Core transformer layers: RMSNorm, RoPE, chunked flash attention, SwiGLU.

Everything is a pure function over parameter pytrees (dicts of arrays) —
no framework dependency.  Attention uses a pure-XLA flash pattern (double
lax.scan over query/key chunks with running max/denominator) so that (a)
S^2 logits never hit HBM for 32k prefill and (b) the dry-run's
``cost_analysis()`` still sees every FLOP (a custom kernel would hide them;
see DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Params = Dict[str, jax.Array]


def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    freqs = theta ** (-np.arange(0, d, 2, dtype=np.float32) / d)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention

def _chunk_attn_inner(q, k, v, qpos, kpos, k_limit: int, window: int,
                      causal: bool):
    """One (q_chunk x kv_chunk) tile with masking; fp32 accumulation.

    q: (B, Tq, H, D)  k/v: (B, Tk, KV, D) with H = KV * G.
    ``k_limit`` masks right-padded keys (kpos >= k_limit invalid).
    """
    B, Tq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Tq, KV, G, D)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k,
                   preferred_element_type=jnp.float32)
    s = s * (1.0 / np.sqrt(D))
    mask = (kpos < k_limit)[None, :] * jnp.ones((Tq, 1), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    m = jnp.max(s, axis=-1)                      # (B,KV,G,Tq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return m, l, acc


def flash_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_chunk: int = 1024, kv_chunk: int = 2048,
                    q_offset=0, unroll: bool = False) -> jax.Array:
    """Pure-XLA flash attention with GQA.

    q: (B, Sq, H, D), k/v: (B, Sk, KV, D).  q_offset: position of q[0]
    relative to k[0] (prefill: 0; decode-with-cache: cache length).
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    nq, nk = -(-Sq // qc), -(-Sk // kc)
    pad_q, pad_k = nq * qc - Sq, nk * kc - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    def q_step(qi: int):
        # qi is a PYTHON int: the kv range below is static, so causal and
        # sliding-window tiles outside the band are never built — the
        # classic flash block-skipping, done at trace time (§Perf: halves
        # attention FLOPs vs masking full tiles).
        qblk = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=1)
        qpos = qi * qc + jnp.arange(qc) + q_offset
        if causal and isinstance(q_offset, int):
            hi = min(Sk, (qi + 1) * qc + q_offset)
        else:
            hi = Sk
        lo = 0
        if window and isinstance(q_offset, int):
            lo = max(0, qi * qc + q_offset - window + 1)
        lo = (lo // kc) * kc
        n_tiles = max(1, -(-(hi - lo) // kc))

        # checkpointed: scan autodiff would otherwise SAVE every (Tq x Tk)
        # probability tile for the backward — O(S^2) HBM, the exact thing
        # flash attention exists to avoid.  Recompute tiles in the bwd sweep.
        @jax.checkpoint
        def kv_step_body(m, l, acc, ki):
            kblk = jax.lax.dynamic_slice_in_dim(k, lo + ki * kc, kc, axis=1)
            vblk = jax.lax.dynamic_slice_in_dim(v, lo + ki * kc, kc, axis=1)
            kpos = lo + ki * kc + jnp.arange(kc)
            mi, li, acci = _chunk_attn_inner(qblk, kblk, vblk, qpos, kpos,
                                             Sk, window, causal)
            mnew = jnp.maximum(m, mi)
            a = jnp.exp(m - mnew)
            b = jnp.exp(mi - mnew)
            l2 = l * a + li * b
            acc2 = (acc * a.transpose(0, 3, 1, 2)[..., None]
                    + acci * b.transpose(0, 3, 1, 2)[..., None])
            return mnew, l2, acc2

        def kv_step(carry, ki):
            m, l, acc = carry
            return kv_step_body(m, l, acc, ki), None

        m0 = jnp.full((B, KV, G, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, qc, KV, G, D), jnp.float32)
        if unroll:  # probe mode: every tile visible to cost_analysis
            m, l, acc = m0, l0, a0
            for ki in range(n_tiles):
                m, l, acc = kv_step_body(m, l, acc, jnp.int32(ki))
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(n_tiles))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return out.reshape(B, qc, H, D)

    outs = [q_step(qi) for qi in range(nq)]
    out = jnp.concatenate(outs, axis=1) if nq > 1 else outs[0]
    return out[:, :Sq].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len) -> jax.Array:
    """Single-token attention over a (possibly padded) KV cache.

    q: (B, 1, H, D), caches: (B, S, KV, D); positions >= cache_len masked.
    """
    B, _, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) / np.sqrt(D)
    valid = jnp.arange(S)[None] < cache_len[:, None]  # (B,S)
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ------------------------------------------------------------- projections

def attn_proj(x, p: Params, cfg: ModelConfig):
    """QKV projections -> (q, k, v) with per-head layout."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def attn_out(o, p: Params):
    return jnp.einsum("bshe,hed->bsd", o, p["wo"])


def swiglu(x, p: Params):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w1"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["w3"])
    return jnp.einsum("bsf,fd->bsd", h, p["w2"])


# ------------------------------------------------------------- init helpers

def _he(key, shape, dtype, fan_in):
    return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)


def init_attn(key, cfg: ModelConfig, dtype) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = dict(
        wq=_he(ks[0], (d, H, hd), dtype, d),
        wk=_he(ks[1], (d, KV, hd), dtype, d),
        wv=_he(ks[2], (d, KV, hd), dtype, d),
        wo=_he(ks[3], (H, hd, d), dtype, H * hd),
    )
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((H, hd), dtype), bk=jnp.zeros((KV, hd), dtype),
                 bv=jnp.zeros((KV, hd), dtype))
    return p


def init_mlp(key, cfg: ModelConfig, dtype, width: Optional[int] = None) -> Params:
    d, f = cfg.d_model, width or cfg.d_ff
    ks = jax.random.split(key, 3)
    return dict(w1=_he(ks[0], (d, f), dtype, d),
                w3=_he(ks[1], (d, f), dtype, d),
                w2=_he(ks[2], (f, d), dtype, f))
