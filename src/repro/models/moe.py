"""Mixture-of-Experts block with capacity-based token dropping (EP-friendly).

Dispatch uses the scatter/gather formulation: tokens claim a slot inside
their expert's capacity buffer (cumsum position), are scattered into an
(E, C, d) buffer — sharded expert-parallel on the "model" mesh axis — run
through a per-expert SwiGLU einsum, and are gathered back weighted by the
router gates.  Top-k routing with softmax-over-selected renormalization
(Kimi-K2 / Llama-4 style).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _he
from ..parallel.api import shard_act


def init_moe(key, cfg: ModelConfig, dtype) -> Dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return dict(
        router=_he(ks[0], (d, E), jnp.float32, d),  # router in fp32
        w1=_he(ks[1], (E, d, f), dtype, d),
        w3=_he(ks[2], (E, d, f), dtype, d),
        w2=_he(ks[3], (E, f, d), dtype, f),
    )


def _num_groups(T: int, target: int = 1024) -> int:
    """Largest group count <= target dividing T (power-of-two friendly)."""
    g = 1
    while g * 2 <= target and T % (g * 2) == 0:
        g *= 2
    return g


def moe_block(x: jax.Array, p: Dict, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).

    Grouped capacity dispatch: tokens are split into G groups, the
    position-in-expert cumsum runs WITHIN each group, and the dispatch
    buffer is (G, E, cap, d) sharded group->data (DP) and expert->model
    (EP).  A single global cumsum would be sequential across data shards —
    SPMD replicates it, costing data_axis x redundant FLOPs and terabytes
    of HLO bytes (the kimi-k2 train_4k baseline; EXPERIMENTS.md §Perf it.1).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = _num_groups(T)
    gs = T // G                                            # tokens per group
    xt = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    gates_all = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(gates_all, k)               # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    cap = int(np.ceil(cfg.moe_capacity_factor * gs * k / E))
    cap = max(4, -(-cap // 4) * 4)

    # per-group sort-based ranking -> slot within (group, expert).
    # (iteration 2: the one-hot cumsum materialized (G, gs*k, E) int32 —
    # ~13 TB of HLO bytes per layer at kimi scale; a stable sort ranks
    # tokens in O(gs*k log) with only (G, gs*k) intermediates.)
    expert = idx.reshape(G, gs * k)
    order = jnp.argsort(expert, axis=-1, stable=True)      # (G, gs*k)
    sorted_ex = jnp.take_along_axis(expert, order, axis=-1)
    # first position of each expert's run inside the sorted array
    seg_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_ex)
    pos_sorted = (jnp.arange(gs * k)[None, :]
                  - jnp.take_along_axis(seg_start, sorted_ex, axis=-1))
    inv = jnp.argsort(order, axis=-1)                      # inverse perm
    slot_in_e = jnp.take_along_axis(pos_sorted, inv, axis=-1)
    keep = slot_in_e < cap
    slot = jnp.where(keep, expert * cap + slot_in_e, E * cap)

    xin = jnp.repeat(xt, k, axis=0).reshape(G, gs * k, d)
    masked = xin * keep[..., None].astype(x.dtype)
    # NOTE §Perf it.3 (refuted): sharding the token-choice dim over the
    # model axis here doubled collective bytes (extra resharding both ways);
    # left data-sharded + model-replicated intentionally.

    def scatter_group(sl, xi):
        return jnp.zeros((E * cap + 1, d), x.dtype).at[sl].add(xi)

    buf = jax.vmap(scatter_group)(slot, masked)[:, : E * cap]
    buf = buf.reshape(G, E, cap, d)
    # (G->data, E->model): expert-parallel with data-parallel capacity
    buf = shard_act(buf, "batch", "experts", None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w1"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["w3"])
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w2"])
    out_buf = shard_act(out_buf, "batch", "experts", None, None)

    flat_out = out_buf.reshape(G, E * cap, d)
    safe = jnp.minimum(slot, E * cap - 1)
    picked = jnp.take_along_axis(flat_out, safe[..., None], axis=1)
    picked = jnp.where(keep[..., None], picked, 0)
    w = (gates.reshape(G, gs * k) * keep).astype(x.dtype)
    y = jnp.sum((picked * w[..., None]).reshape(T, k, d).reshape(G * gs, k, d),
                axis=1)
    return y.reshape(B, S, d)
