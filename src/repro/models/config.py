"""Model configuration covering all assigned architecture families."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                # 0 => attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    # attention details
    qkv_bias: bool = False
    attn_out_bias: bool = False
    head_dim: int = 0           # 0 => d_model // n_heads
    rope_theta: float = 10000.0
    sliding_window: int = 0     # 0 = full attention (hybrid uses SWA)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_expand: int = 2
    # encoder-decoder
    encoder_layers: int = 0
    encoder_seq: int = 1500     # whisper 30s frame count
    # multimodal stub frontends
    frontend: str = "none"      # none | audio_stub | vision_stub
    n_prefix_embeds: int = 0    # vision: patch embeddings prepended
    # numerics / training
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # attention chunking for long-context prefill (pure-XLA flash pattern)
    q_chunk: int = 1024
    kv_chunk: int = 2048
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots (saveable policies)
    scan_layers: bool = True        # False: unroll (cost-analysis probes)
    unroll_scans: bool = False      # unroll inner scans too (probes only:
                                    # XLA cost_analysis counts loop bodies
                                    # once, undercounting attention/loss)

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0 or self.family == "hybrid"

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / sliding-window hybrid)."""
        return self.family == "ssm" or (
            self.family == "hybrid" and self.sliding_window > 0)

    @property
    def ssm_heads(self) -> int:
        if self.ssm_state == 0:
            return 0
        return (self.d_model * self.ssm_expand) // self.ssm_head_dim

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=64,
            n_prefix_embeds=min(self.n_prefix_embeds, 16),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            q_chunk=64, kv_chunk=64, ssm_chunk=32,
            head_dim=32 if self.n_heads else 0,
            dtype="float32",
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return replace(self, **small)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        n = V * d  # embed
        if not self.tie_embeddings:
            n += V * d
        def attn():
            a = d * H * hd + 2 * d * KV * hd + H * hd * d
            if self.qkv_bias:
                a += (H + 2 * KV) * hd
            return a
        def mlp(width=ff):
            return 3 * d * width  # swiglu
        def ssm():
            di = d * self.ssm_expand
            # in_proj (x, z, B, C, dt) + out_proj + A, D, dt_bias, conv
            ngroups = 1
            return (d * (2 * di + 2 * ngroups * self.ssm_state + self.ssm_heads)
                    + di * d + 3 * self.ssm_heads + 4 * di)
        per_layer = 2 * d  # norms
        if self.family in ("dense", "vlm", "encdec"):
            per_layer += attn() + mlp()
        elif self.family == "moe":
            per_layer += attn() + self.n_experts * mlp() + d * self.n_experts
        elif self.family == "ssm":
            per_layer = d + ssm()
        elif self.family == "hybrid":
            per_layer += attn() + ssm() + mlp()
        n += self.n_layers * per_layer
        if self.family == "encdec":
            enc_layer = 2 * d + attn() + mlp()
            dec_cross = attn()  # cross-attention per decoder layer
            n += self.encoder_layers * enc_layer + self.n_layers * dec_cross
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_like = self.param_count() - self.n_layers * (self.n_experts * 3 * d * ff)
        return dense_like + self.n_layers * (self.top_k * 3 * d * ff)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
