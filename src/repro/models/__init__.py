from .config import ModelConfig, ShapeConfig, SHAPES
from . import model, layers, moe, ssm

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "model", "layers",
           "moe", "ssm"]
