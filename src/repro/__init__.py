"""repro — IPComp reproduction grown toward a production JAX/Pallas system.

The public codec surface lives in :mod:`repro.api` and is re-exported
here::

    from repro import Codec, Archive, Fidelity, ExecPolicy

    archive = Codec(eb=1e-6).compress(x)
    session = archive.open(ExecPolicy(backend="jax"))
    out = session.read(Fidelity.error_bound(1e-3))

The legacy free functions (``compress`` / ``retrieve`` / ``refine`` /
``decompress``) are importable from here too; they are compatibility
shims over the object API and emit one
:class:`~repro.api.IPCompDeprecationWarning` per call.

Attribute access is lazy (PEP 562): ``import repro`` stays cheap, and
subsystems that never touch the codec (``repro.models``,
``repro.launch``, ...) do not pay for its import.
"""

_API_NAMES = (
    "Codec", "Archive", "ProgressiveReader", "Fidelity", "ExecPolicy",
    "ExecContext", "DEFAULT_POLICY", "CorruptArchiveError",
    "IPCompDeprecationWarning",
    "compress", "decompress", "retrieve", "refine", "open_archive",
    "RetrievalState", "ChunkedRetrievalState",
)

__all__ = list(_API_NAMES) + ["api"]


def __getattr__(name: str):
    if name in _API_NAMES:
        from . import api
        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_API_NAMES))
