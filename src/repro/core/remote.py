"""Remote retrieval: an HTTP/1.1 Range-request :class:`ByteSource`.

The whole point of the v3 plane-major layout is that a progressive
refine costs ONE contiguous range request (``docs/format.md`` §3) — but
until this module the claim was only ever exercised against the
in-memory :class:`~.bytesource.CountingSource` double.  ``HTTPSource``
makes the access pattern real: each ``read(offset, size)`` becomes an
HTTP/1.1 ``Range: bytes=o-(o+n-1)`` request against an object-store /
static-file endpoint, using only the stdlib ``http.client`` (no new
dependencies).

Design points, each pinned by ``tests/test_remote_retrieval.py`` /
``tests/test_fault_injection.py``:

* **Bounded retries with exponential backoff + jitter.**  Transport
  errors (connect refused, reset, timeout, short body, malformed 206)
  are retried up to ``retries`` times with ``backoff * 2**k`` capped at
  ``backoff_max`` and multiplied by ``1 + jitter·U[0,1)``; exhausting
  the budget raises :class:`RemoteReadError`.  Decisive server answers
  (4xx) raise :class:`RemoteProtocolError` immediately — retrying a 404
  cannot help.
* **206-vs-200 validation.**  A ``206`` must carry a ``Content-Range``
  whose start matches the request and whose body length matches its
  claim (short/broken bodies are retried).  A ``200`` means the server
  ignored ``Range``: the full body is accepted, sliced locally, and
  counted in :attr:`range_ignored` — correctness is preserved even
  against servers with no range support, at a bandwidth cost the
  accounting makes visible.  A valid ``206`` shorter than the request
  because the *object* ends early is returned short — the container
  layer turns that into ``CorruptArchiveError`` at the exact boundary.
* **Lazy size probe.**  :attr:`size` issues one ``HEAD`` on first use
  (or is learned for free from ``Content-Range`` totals), so opening an
  archive costs no extra data request and the one-Range-per-rung
  accounting stays clean.
* **Bounded readahead.**  With ``readahead=n``, each wire fetch extends
  ``n`` bytes past the request (clamped to EOF) and the surplus is kept;
  a monotone v3 ladder then streams ahead of the decoder and sequential
  header reads collapse into one wire request (:attr:`readahead_hits`).
* **CountingSource-compatible accounting.**  The shared
  :class:`~.bytesource.RangeLog` machinery records every *wire* range in
  order, so ``coalesced()`` / ``monotone()`` / ``seek_distance`` mean
  the same thing for a remote archive as for the in-memory double, and
  ``benchmarks/serve_bench.py`` can put both in one table.

Thread safety: the serving tier reads one shared source from concurrent
sessions, and one ``http.client`` connection is not concurrency-safe —
all wire I/O (and the readahead buffer) is serialized under one lock;
the range log has its own (see :class:`~.bytesource.RangeLog`).
"""
from __future__ import annotations

import http.client
import random
import re
import threading
import time
import urllib.parse
from typing import Optional

from .bytesource import ByteSource, RangeLog


class RemoteError(OSError):
    """Base class for remote-retrieval failures.  Subclasses
    :class:`OSError` so generic transport-error handling — including the
    serving tier's retryable-vs-permanent classification — catches it
    without importing this module."""


class RemoteProtocolError(RemoteError):
    """The server answered decisively wrong (4xx status, a bogus 416):
    the request as formed can never succeed, so it is NOT retried."""


class RemoteReadError(RemoteError):
    """The retry budget was exhausted without one valid response.  The
    last underlying error rides along as ``__cause__``."""


class _RetryableResponse(http.client.HTTPException):
    """Internal marker: a response that is malformed/transient (5xx,
    short body, bad Content-Range) and worth retrying."""


_CONTENT_RANGE = re.compile(r"bytes (\d+)-(\d+)/(\d+|\*)$")


class HTTPSource(RangeLog, ByteSource):
    """HTTP/1.1 Range-request source over a single remote object.

    Parameters
    ----------
    url:
        ``http://`` or ``https://`` URL of the archive object.
    timeout:
        Per-request socket timeout in seconds (connect + each read).
    retries:
        Extra attempts after the first failure (``retries=3`` means at
        most 4 wire attempts per range).
    backoff, backoff_max, jitter:
        Sleep before retry ``k`` (1-based) is
        ``min(backoff * 2**(k-1), backoff_max) * (1 + jitter·U[0,1))``.
    readahead:
        Extra bytes fetched past each request and cached (0 disables).
    sleep, rng:
        Injection points for tests: the backoff sleeper and the jitter
        RNG (any object with ``random()``).
    """

    def __init__(self, url: str, *, timeout: float = 5.0, retries: int = 3,
                 backoff: float = 0.05, backoff_max: float = 2.0,
                 jitter: float = 0.25, readahead: int = 0,
                 sleep=time.sleep, rng=None):
        RangeLog.__init__(self)
        self.url = url
        p = urllib.parse.urlsplit(url)
        if p.scheme not in ("http", "https"):
            raise ValueError(f"HTTPSource needs an http(s) URL, got {url!r}")
        if not p.hostname:
            raise ValueError(f"HTTPSource URL has no host: {url!r}")
        self._secure = p.scheme == "https"
        self._host = p.hostname
        self._port = p.port
        self._path = (p.path or "/") + (f"?{p.query}" if p.query else "")
        self.timeout = float(timeout)
        self.retries = int(retries)
        self.backoff = float(backoff)
        self.backoff_max = float(backoff_max)
        self.jitter = float(jitter)
        self.readahead = int(readahead)
        self._sleep = sleep
        self._rng = rng if rng is not None else random.Random()
        self._conn: Optional[http.client.HTTPConnection] = None
        self._io_lock = threading.Lock()
        self._size: Optional[int] = None
        self._ra_start = 0
        self._ra_buf = b""
        # wire counters (the serve_bench "over the wire" columns)
        self.wire_bytes = 0        # payload bytes actually received
        self.retry_count = 0       # attempts beyond the first, cumulative
        self.range_ignored = 0     # 200-instead-of-206 full-body responses
        self.readahead_hits = 0    # reads served from the readahead buffer

    # ------------------------------------------------------------ transport

    def _connection(self) -> http.client.HTTPConnection:
        if self._conn is None:
            cls = (http.client.HTTPSConnection if self._secure
                   else http.client.HTTPConnection)
            self._conn = cls(self._host, self._port, timeout=self.timeout)
        return self._conn

    def _drop_conn(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            except OSError:
                pass
            self._conn = None

    def _with_retries(self, attempt_fn, what: str):
        """Run ``attempt_fn()`` under the retry policy.  Retryable
        failures are transport-level (:class:`OSError`) and malformed
        responses (:class:`http.client.HTTPException`); a
        :class:`RemoteProtocolError` is decisive and re-raised as is."""
        last: Optional[BaseException] = None
        for k in range(self.retries + 1):
            if k:
                self.retry_count += 1
                delay = min(self.backoff * (2 ** (k - 1)), self.backoff_max)
                self._sleep(delay * (1.0 + self.jitter * self._rng.random()))
            try:
                return attempt_fn()
            except RemoteProtocolError:
                self._drop_conn()
                raise
            except (OSError, http.client.HTTPException) as e:
                last = e
                self._drop_conn()
        raise RemoteReadError(
            f"{what} of {self.url} failed after {self.retries + 1} "
            f"attempts: {last}") from last

    # ------------------------------------------------------------- requests

    def _attempt_range(self, offset: int, want: int) -> bytes:
        conn = self._connection()
        conn.request("GET", self._path,
                     headers={"Range": f"bytes={offset}-{offset + want - 1}"})
        resp = conn.getresponse()
        status = resp.status
        if status == 206:
            m = _CONTENT_RANGE.match(resp.getheader("Content-Range") or "")
            if not m:
                resp.read()
                raise _RetryableResponse(
                    f"206 with unparseable Content-Range "
                    f"{resp.getheader('Content-Range')!r}")
            start, end, total = m.groups()
            start, end = int(start), int(end)
            body = bytes(resp.read())
            self.wire_bytes += len(body)
            if start != offset:
                raise _RetryableResponse(
                    f"206 starts at {start}, requested {offset}")
            if len(body) != end - start + 1:
                raise _RetryableResponse(
                    f"206 body carries {len(body)} of the "
                    f"{end - start + 1} bytes its Content-Range claims")
            if total != "*":
                self._size = int(total)
            self.record_range(offset, len(body))
            return body
        if status == 200:
            # server ignored Range: the body is the whole object — slice
            # locally so correctness survives range-less servers, and
            # count the waste so benchmarks surface it
            body = bytes(resp.read())
            self.wire_bytes += len(body)
            if self._size is not None and len(body) != self._size:
                raise _RetryableResponse(
                    f"200 body is {len(body)} bytes, object size is "
                    f"{self._size}")
            self._size = len(body)
            self.range_ignored += 1
            self.record_range(0, len(body))
            return body[offset: offset + want]
        if status == 416:
            m = re.match(r"bytes \*/(\d+)$",
                         resp.getheader("Content-Range") or "")
            resp.read()
            if m:
                self._size = int(m.group(1))
            if self._size is not None and offset >= self._size:
                # past-EOF reads mirror BufferSource slicing: empty
                return b""
            raise _RetryableResponse(
                f"416 for in-bounds range [{offset}, {offset + want})")
        resp.read()
        if status >= 500:
            raise _RetryableResponse(f"HTTP {status}")
        raise RemoteProtocolError(
            f"HTTP {status} for range [{offset}, {offset + want}) "
            f"of {self.url}")

    def _attempt_head(self) -> int:
        conn = self._connection()
        conn.request("HEAD", self._path)
        resp = conn.getresponse()
        resp.read()
        if resp.status != 200:
            if 400 <= resp.status < 500:
                raise RemoteProtocolError(
                    f"HTTP {resp.status} for HEAD {self.url}")
            raise _RetryableResponse(f"HTTP {resp.status} for HEAD")
        clen = resp.getheader("Content-Length")
        if clen is None or not clen.isdigit():
            raise _RetryableResponse(
                f"HEAD without usable Content-Length ({clen!r})")
        return int(clen)

    # ------------------------------------------------------ ByteSource API

    def read(self, offset: int, size: int):
        offset, size = int(offset), int(size)
        if size <= 0:
            return b""
        with self._io_lock:
            lo = offset - self._ra_start
            if 0 <= lo and lo + size <= len(self._ra_buf):
                self.readahead_hits += 1
                return self._ra_buf[lo: lo + size]
            want = size
            if self.readahead:
                end = offset + size + self.readahead
                if self._size is not None:
                    end = min(end, self._size)
                want = max(size, end - offset)
            data = self._with_retries(
                lambda: self._attempt_range(offset, want),
                f"range [{offset}, {offset + want})")
            if self.readahead:
                self._ra_start, self._ra_buf = offset, data
            return data[:size]

    @property
    def size(self) -> int:
        with self._io_lock:
            if self._size is None:
                self._size = self._with_retries(self._attempt_head,
                                                "size probe (HEAD)")
            return self._size

    def close(self) -> None:
        with self._io_lock:
            self._drop_conn()
            self._ra_buf = b""

    # ------------------------------------------------------------- metrics

    def stats(self) -> dict:
        """One benchmark-ready snapshot of the wire accounting."""
        return dict(url=self.url, n_requests=self.n_requests,
                    coalesced_ranges=len(self.coalesced()),
                    monotone=self.monotone(),
                    seek_distance=self.seek_distance,
                    bytes_requested=self.bytes_requested,
                    wire_bytes=self.wire_bytes,
                    retry_count=self.retry_count,
                    range_ignored=self.range_ignored,
                    readahead_hits=self.readahead_hits)

    def __repr__(self) -> str:
        return (f"HTTPSource({self.url!r}, {self.n_requests} requests, "
                f"{self.wire_bytes} wire bytes, "
                f"{self.retry_count} retries)")
