"""IPComp legacy surface: compress / retrieve / refine (paper Algorithms 1 & 2).

Compatibility shim twice over: the implementation lives in the
``core/pipeline`` package (``spec`` / ``encode`` / ``decode`` / ``state``
/ ``backends`` — see its docstring for the module map), and the
*supported* public surface is the object API in :mod:`repro.api`
(``Codec`` / ``Archive`` / ``Fidelity`` / ``ExecPolicy`` /
``ProgressiveReader``).  This module re-exports the historical
``core.ipcomp`` names so existing imports keep working unchanged; the
free functions emit one ``IPCompDeprecationWarning`` per call.

Compression pipeline (Fig. 2):
  x --interpolation predictor--> residuals y_l --quantize--> q_l
    --negabinary--> nb_l --bitplanes + XOR predictive coding--> blobs
    --container--> archive bytes

Retrieval: the DP loader (§5) plans the minimum bitplane set for the
requested error bound / bitrate; a single reconstruction pass produces the
output.  ``refine`` implements Algorithm 2: it loads only the *additional*
bitplanes and pushes a linear delta cascade on top of the previous
reconstruction.

Both directions run on interchangeable backends (``backend="numpy"`` |
``"jax"`` | ``"auto"``): the jax path routes the phase sweeps and bitplane
coding through the Pallas kernel pairs (``interp_quant``/``interp_recon``,
``bitplane_pack``/``bitplane_unpack``), emitting byte-identical archives
and bit-identical reconstructions.  ``chunk_elems=N`` compresses to the
chunked v2 container; retrieval accepts both versions transparently.
"""
from __future__ import annotations

from .pipeline.backends import CodecBackend, get as get_backend
from .pipeline.decode import (_retrieve_chunked, decompress, open_archive,
                              read_archive, refine, retrieve, split_budget)
from .pipeline.encode import (_compress_single, _pack_escapes, chunk_bounds,
                              compress, encode_array)
from .pipeline.spec import ExecPolicy, Fidelity, IPCompDeprecationWarning
from .pipeline.state import (ChunkedRetrievalState, RetrievalState,
                             _unpack_escapes, initial_state)


def _initial_state(reader) -> RetrievalState:
    """Historical one-arg helper: initial state via the numpy backend."""
    return initial_state(reader, get_backend("numpy"))


__all__ = [
    "compress", "chunk_bounds", "decompress", "retrieve", "refine",
    "open_archive", "split_budget", "RetrievalState",
    "ChunkedRetrievalState", "CodecBackend",
    "encode_array", "read_archive", "Fidelity", "ExecPolicy",
    "IPCompDeprecationWarning",
]
