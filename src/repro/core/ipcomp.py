"""IPComp public API: compress / retrieve / refine (paper Algorithms 1 & 2).

Compression pipeline (Fig. 2):
  x --interpolation predictor--> residuals y_l --quantize--> q_l
    --negabinary--> nb_l --bitplanes + XOR predictive coding--> blobs
    --container--> archive bytes

Two interchangeable compression backends produce this pipeline:
``backend="numpy"`` (reference) and ``backend="jax"`` (Pallas kernels for
the predict+quantize sweep and the bitplane packing; interpret mode on CPU,
Mosaic on TPU — see ``jax_backend``).  Archives are byte-compatible: the
decode path never needs to know which backend wrote them.

``chunk_elems=N`` splits the array into independent slabs of ~N elements
along axis 0 and frames the per-slab archives in a v2 container
(``container.write_chunked_archive``).  Chunking bounds compression working
memory, lets equal-shaped chunks share jit cache entries, and is the unit
of future vmapped/sharded encoding; v1 (unchunked) archives remain the
default and are always readable.

Retrieval: the DP loader (§5) plans the minimum bitplane set for the
requested error bound / bitrate; a single reconstruction pass produces the
output (no multi-pass residual decompression).  ``refine`` implements
Algorithm 2: it loads only the *additional* bitplanes and pushes a linear
delta cascade on top of the previous reconstruction.  For chunked archives
every plan/refine step runs per chunk (a per-chunk L_inf bound implies the
global one) and ``bytes_read`` aggregates across chunks.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import (bitplane, container, interpolation, jax_backend, loader,
               negabinary, quantize)
from .container import ArchiveReader, ChunkedArchiveReader
from .loader import LoadPlan


# ----------------------------------------------------------------- compress

def compress(x: np.ndarray, eb: float, interp: str = interpolation.CUBIC,
             relative: bool = False, backend: Optional[str] = "numpy",
             chunk_elems: Optional[int] = None) -> bytes:
    """Compress ``x`` with point-wise error bound ``eb``.

    ``relative=True`` interprets eb as a fraction of the value range.
    ``backend`` is "numpy" | "jax" | "auto"/None (jax on TPU where the
    kernels compile, numpy elsewhere); both emit identical bytes.
    ``chunk_elems`` switches to the chunked v2 container with
    ~chunk_elems-sized independent slabs.
    """
    x = np.asarray(x)
    if relative:
        eb = eb * (float(x.max()) - float(x.min()) or 1.0)
    if eb <= 0:
        raise ValueError("error bound must be positive")
    bk = jax_backend.resolve(backend)
    if chunk_elems is None:
        return _compress_single(x, eb, interp, bk)
    bounds = chunk_bounds(x.shape, chunk_elems)
    bufs = [_compress_single(x[a:b], eb, interp, bk) for a, b in bounds]
    return container.write_chunked_archive(x.shape, x.dtype, eb, interp,
                                           bounds, bufs)


def chunk_bounds(shape, chunk_elems: int) -> List[Tuple[int, int]]:
    """Split axis 0 into slabs of ~chunk_elems elements (>=1 row each)."""
    if chunk_elems <= 0:
        raise ValueError("chunk_elems must be positive")
    row_elems = int(np.prod(shape[1:])) if len(shape) > 1 else 1
    rows = max(1, chunk_elems // max(row_elems, 1))
    return [(a, min(a + rows, shape[0])) for a in range(0, shape[0], rows)]


def _compress_single(x: np.ndarray, eb: float, interp: str,
                     backend: str) -> bytes:
    """One (chunk-sized) array -> one v1 archive, via the chosen backend."""
    shape, dtype = x.shape, x.dtype
    L = interpolation.num_levels(shape)

    if backend == jax_backend.JAX:
        _, qs, escs, anchors = jax_backend.decorrelate(
            x.astype(np.float64), eb, interp)
    else:
        def quantizer(res: np.ndarray, tvals: np.ndarray):
            q = quantize.quantize(res, eb)
            esc = quantize.escape_mask(q)
            recon = quantize.dequantize(q, eb)
            if esc.any():
                flat = np.flatnonzero(esc.ravel())
                vals = tvals.ravel()[flat].astype(np.float64)  # absolute values
                q.ravel()[flat] = 0
                return q, recon, (flat, vals)
            return q, recon, (np.zeros(0, np.int64), np.zeros(0, np.float64))

        _, qs, escs, anchors = interpolation.decorrelate(
            x.astype(np.float64), eb, interp, quantizer)

    level_blobs, level_meta, esc_blobs = [], [], []
    for li in range(L):
        q = qs[li]
        nb = negabinary.to_negabinary(q)
        if backend == jax_backend.JAX:
            blobs, nbits = jax_backend.encode_level(q)
        else:
            blobs, nbits = bitplane.encode_level(nb)
        delta = negabinary.truncation_loss_table(nb, nbits, eb)
        level_blobs.append(blobs)
        level_meta.append(dict(level=L - li, n=int(q.size), nbits=nbits,
                               delta_table=delta.tolist()))
        esc_blobs.append(_pack_escapes(escs[li]))
    return container.write_archive(shape, dtype, eb, interp, L, anchors,
                                   level_blobs, level_meta, esc_blobs)


def _pack_escapes(phase_escs) -> bytes:
    """Escape records (level-global flat idx, exact residuals) -> one blob."""
    idx_parts = [i for i, v in phase_escs if i.size]
    val_parts = [v for i, v in phase_escs if i.size]
    if not idx_parts:
        return b""
    idx = np.concatenate(idx_parts).astype(np.int64)
    val = np.concatenate(val_parts).astype(np.float64)
    raw = np.int64(idx.size).tobytes() + idx.tobytes() + val.tobytes()
    return zlib.compress(raw, 6)


def _unpack_escapes(blob: bytes) -> Tuple[np.ndarray, np.ndarray]:
    if not blob:
        return np.zeros(0, np.int64), np.zeros(0, np.float64)
    raw = zlib.decompress(blob)
    n = int(np.frombuffer(raw[:8], np.int64)[0])
    idx = np.frombuffer(raw[8:8 + 8 * n], np.int64)
    val = np.frombuffer(raw[8 + 8 * n:], np.float64)
    return idx, val


# ----------------------------------------------------------------- retrieve

@dataclass
class RetrievalState:
    """Progressive state carried between retrievals (Algorithm 2)."""
    reader: ArchiveReader
    planes_loaded: List[int]              # per level, MSB-first count
    nb_partial: List[np.ndarray]          # truncated negabinary per level
    esc_idx: List[np.ndarray]             # escape stream positions per level
    xhat: np.ndarray                      # current reconstruction
    err_bound: float
    bytes_read: int = 0


@dataclass
class ChunkedRetrievalState:
    """Progressive state for a v2 archive: one RetrievalState per chunk."""
    reader: ChunkedArchiveReader
    chunk_states: List[Optional[RetrievalState]]
    err_bound: float = float("inf")
    bytes_read: int = 0


def open_archive(buf: bytes):
    """Reader for any archive version (v1 plain / v2 chunked)."""
    return container.open_reader(buf)


def _initial_state(reader: ArchiveReader) -> RetrievalState:
    """Coarsest approximation: anchors + escapes only, zero bitplanes."""
    m = reader.meta
    anchors = reader.anchors()
    yhat, overrides = [], []
    for li, lv in enumerate(m.levels):
        yhat.append(np.zeros(lv.n, np.float64))
        idx, val = _unpack_escapes(reader.escapes(li))
        overrides.append((idx, val))
    xhat = interpolation.reconstruct(m.shape, m.interp, anchors, yhat,
                                     overrides=overrides)
    full_err = m.eb + sum(
        float(lv.delta_table[lv.nbits]) *
        loader._prop_factor(m, lv.level, loader.SAFE)
        for lv in m.levels)
    return RetrievalState(reader=reader,
                          planes_loaded=[0] * len(m.levels),
                          nb_partial=[np.zeros(lv.n, np.uint32) for lv in m.levels],
                          esc_idx=[o[0] for o in overrides],
                          xhat=xhat, err_bound=full_err,
                          bytes_read=reader.bytes_read)


def retrieve(buf_or_reader, error_bound: Optional[float] = None,
             max_bytes: Optional[int] = None,
             bitrate: Optional[float] = None,
             propagation: str = loader.SAFE,
             state: Optional[RetrievalState] = None,
             ) -> Tuple[np.ndarray, RetrievalState]:
    """Single-pass progressive retrieval.

    Exactly one of (error_bound, max_bytes, bitrate) selects the plan; None
    of them = full-precision.  Pass ``state`` from a previous call to refine
    incrementally (Algorithm 2) — only missing bitplanes are fetched.

    Accepts v1 and v2 (chunked) archives / readers transparently.
    """
    if isinstance(buf_or_reader, (ArchiveReader, ChunkedArchiveReader)):
        reader = buf_or_reader
    else:
        reader = container.open_reader(buf_or_reader)
    if isinstance(reader, ChunkedArchiveReader):
        return _retrieve_chunked(reader, error_bound, max_bytes, bitrate,
                                 propagation, state)
    m = reader.meta
    if bitrate is not None:
        max_bytes = int(bitrate * m.n_elements / 8)
    if error_bound is not None:
        plan = loader.plan_error_mode(m, error_bound, propagation)
    elif max_bytes is not None:
        plan = loader.plan_bitrate_mode(m, max_bytes, propagation)
    else:
        plan = loader.plan_full(m)

    if state is None:
        state = _initial_state(reader)
    delta_y: List[np.ndarray] = []
    any_new = False
    for li, lv in enumerate(m.levels):
        have = state.planes_loaded[li]
        want = max(have, plan.keep_planes[li])  # refinement never drops planes
        if want > have:
            any_new = True
            blobs: List[Optional[bytes]] = [None] * lv.nbits
            # XOR decode needs planes k+1, k+2; re-decode the prefix from the
            # already-fetched blobs (reader caches fetched ranges; re-reads of
            # the same tag are not double-counted).
            for i in range(want):
                blobs[i] = reader.plane(li, i)
            nb_new = bitplane.decode_level(blobs, lv.nbits, lv.n)
            dq = negabinary.from_negabinary(nb_new) - \
                negabinary.from_negabinary(state.nb_partial[li])
            delta_y.append(dq.astype(np.float64) * 2.0 * m.eb)
            state.nb_partial[li] = nb_new
            state.planes_loaded[li] = want
        else:
            delta_y.append(np.zeros(lv.n, np.float64))
    if any_new:
        zero_anchors = np.zeros(m.anchors_shape, np.float64)
        # escaped points are exact from the first pass: their delta is pinned 0
        zero_ovr = [(idx, np.zeros(idx.size)) for idx in state.esc_idx]
        delta = interpolation.reconstruct(m.shape, m.interp, zero_anchors,
                                          delta_y, overrides=zero_ovr)
        state.xhat = state.xhat + delta
    # achieved bound: from the *union* of loaded planes
    errs, _ = loader._level_cost_tables(m, propagation)
    state.err_bound = m.eb + sum(
        float(errs[li][lv.nbits - state.planes_loaded[li]])
        for li, lv in enumerate(m.levels))
    state.bytes_read = reader.bytes_read
    out = state.xhat.astype(np.dtype(m.dtype))
    return out, state


def _retrieve_chunked(reader: ChunkedArchiveReader,
                      error_bound: Optional[float],
                      max_bytes: Optional[int],
                      bitrate: Optional[float],
                      propagation: str,
                      state: Optional[ChunkedRetrievalState],
                      ) -> Tuple[np.ndarray, ChunkedRetrievalState]:
    """Per-chunk plan + reconstruct; the global bound is the chunk max.

    Error mode passes ``error_bound`` straight through (each chunk holding
    L_inf <= E makes the assembled array hold it).  Byte/bitrate budgets are
    split across chunks proportionally to element count, which keeps the
    loaded bit-per-point uniform — the same objective the v1 DP optimizes.
    """
    m = reader.meta
    if state is None:
        state = ChunkedRetrievalState(reader=reader,
                                      chunk_states=[None] * len(m.chunks))
    if bitrate is not None:
        max_bytes = int(bitrate * m.n_elements / 8)
    out = np.empty(m.shape, np.dtype(m.dtype))
    errs = []
    for i, cm in enumerate(m.chunks):
        kw = {}
        if error_bound is not None:
            kw["error_bound"] = error_bound
        elif max_bytes is not None:
            sub_n = reader.chunk_reader(i).meta.n_elements
            kw["max_bytes"] = int(max_bytes * sub_n / m.n_elements)
        sub, st = retrieve(reader.chunk_reader(i), propagation=propagation,
                           state=state.chunk_states[i], **kw)
        state.chunk_states[i] = st
        out[cm.start:cm.stop] = sub
        errs.append(st.err_bound)
    state.err_bound = max(errs)
    state.bytes_read = reader.bytes_read
    return out, state


def decompress(buf: bytes) -> np.ndarray:
    """Full-precision decompression (error <= eb everywhere)."""
    out, _ = retrieve(buf)
    return out
