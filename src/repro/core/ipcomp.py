"""IPComp public API: compress / retrieve / refine (paper Algorithms 1 & 2).

Compression pipeline (Fig. 2):
  x --interpolation predictor--> residuals y_l --quantize--> q_l
    --negabinary--> nb_l --bitplanes + XOR predictive coding--> blobs
    --container--> archive bytes

Retrieval: the DP loader (§5) plans the minimum bitplane set for the
requested error bound / bitrate; a single reconstruction pass produces the
output (no multi-pass residual decompression).  ``refine`` implements
Algorithm 2: it loads only the *additional* bitplanes and pushes a linear
delta cascade on top of the previous reconstruction.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import bitplane, container, interpolation, loader, negabinary, quantize
from .container import ArchiveReader
from .loader import LoadPlan


# ----------------------------------------------------------------- compress

def compress(x: np.ndarray, eb: float, interp: str = interpolation.CUBIC,
             relative: bool = False) -> bytes:
    """Compress ``x`` with point-wise error bound ``eb``.

    ``relative=True`` interprets eb as a fraction of the value range.
    """
    x = np.asarray(x)
    if relative:
        eb = eb * (float(x.max()) - float(x.min()) or 1.0)
    if eb <= 0:
        raise ValueError("error bound must be positive")
    shape, dtype = x.shape, x.dtype
    L = interpolation.num_levels(shape)
    esc_records: List[List[Tuple[np.ndarray, np.ndarray]]] = [[] for _ in range(L)]

    def quantizer(res: np.ndarray, tvals: np.ndarray):
        q = quantize.quantize(res, eb)
        esc = quantize.escape_mask(q)
        recon = quantize.dequantize(q, eb)
        if esc.any():
            flat = np.flatnonzero(esc.ravel())
            vals = tvals.ravel()[flat].astype(np.float64)  # absolute values
            q.ravel()[flat] = 0
            return q, recon, (flat, vals)
        return q, recon, (np.zeros(0, np.int64), np.zeros(0, np.float64))

    _, qs, escs, anchors = interpolation.decorrelate(
        x.astype(np.float64), eb, interp, quantizer)

    level_blobs, level_meta, esc_blobs = [], [], []
    for li in range(L):
        q = qs[li]
        nb = negabinary.to_negabinary(q)
        blobs, nbits = bitplane.encode_level(nb)
        delta = negabinary.truncation_loss_table(nb, nbits, eb)
        level_blobs.append(blobs)
        level_meta.append(dict(level=L - li, n=int(q.size), nbits=nbits,
                               delta_table=delta.tolist()))
        esc_blobs.append(_pack_escapes(escs[li]))
    return container.write_archive(shape, dtype, eb, interp, L, anchors,
                                   level_blobs, level_meta, esc_blobs)


def _pack_escapes(phase_escs) -> bytes:
    """Escape records (level-global flat idx, exact residuals) -> one blob."""
    idx_parts = [i for i, v in phase_escs if i.size]
    val_parts = [v for i, v in phase_escs if i.size]
    if not idx_parts:
        return b""
    idx = np.concatenate(idx_parts).astype(np.int64)
    val = np.concatenate(val_parts).astype(np.float64)
    raw = np.int64(idx.size).tobytes() + idx.tobytes() + val.tobytes()
    return zlib.compress(raw, 6)


def _unpack_escapes(blob: bytes) -> Tuple[np.ndarray, np.ndarray]:
    if not blob:
        return np.zeros(0, np.int64), np.zeros(0, np.float64)
    raw = zlib.decompress(blob)
    n = int(np.frombuffer(raw[:8], np.int64)[0])
    idx = np.frombuffer(raw[8:8 + 8 * n], np.int64)
    val = np.frombuffer(raw[8 + 8 * n:], np.float64)
    return idx, val


# ----------------------------------------------------------------- retrieve

@dataclass
class RetrievalState:
    """Progressive state carried between retrievals (Algorithm 2)."""
    reader: ArchiveReader
    planes_loaded: List[int]              # per level, MSB-first count
    nb_partial: List[np.ndarray]          # truncated negabinary per level
    esc_idx: List[np.ndarray]             # escape stream positions per level
    xhat: np.ndarray                      # current reconstruction
    err_bound: float
    bytes_read: int = 0


def open_archive(buf: bytes) -> ArchiveReader:
    return ArchiveReader(buf)


def _initial_state(reader: ArchiveReader) -> RetrievalState:
    """Coarsest approximation: anchors + escapes only, zero bitplanes."""
    m = reader.meta
    anchors = reader.anchors()
    yhat, overrides = [], []
    for li, lv in enumerate(m.levels):
        yhat.append(np.zeros(lv.n, np.float64))
        idx, val = _unpack_escapes(reader.escapes(li))
        overrides.append((idx, val))
    xhat = interpolation.reconstruct(m.shape, m.interp, anchors, yhat,
                                     overrides=overrides)
    full_err = m.eb + sum(
        float(lv.delta_table[lv.nbits]) *
        loader._prop_factor(m, lv.level, loader.SAFE)
        for lv in m.levels)
    return RetrievalState(reader=reader,
                          planes_loaded=[0] * len(m.levels),
                          nb_partial=[np.zeros(lv.n, np.uint32) for lv in m.levels],
                          esc_idx=[o[0] for o in overrides],
                          xhat=xhat, err_bound=full_err,
                          bytes_read=reader.bytes_read)


def retrieve(buf_or_reader, error_bound: Optional[float] = None,
             max_bytes: Optional[int] = None,
             bitrate: Optional[float] = None,
             propagation: str = loader.SAFE,
             state: Optional[RetrievalState] = None,
             ) -> Tuple[np.ndarray, RetrievalState]:
    """Single-pass progressive retrieval.

    Exactly one of (error_bound, max_bytes, bitrate) selects the plan; None
    of them = full-precision.  Pass ``state`` from a previous call to refine
    incrementally (Algorithm 2) — only missing bitplanes are fetched.
    """
    reader = buf_or_reader if isinstance(buf_or_reader, ArchiveReader) \
        else ArchiveReader(buf_or_reader)
    m = reader.meta
    if bitrate is not None:
        max_bytes = int(bitrate * m.n_elements / 8)
    if error_bound is not None:
        plan = loader.plan_error_mode(m, error_bound, propagation)
    elif max_bytes is not None:
        plan = loader.plan_bitrate_mode(m, max_bytes, propagation)
    else:
        plan = loader.plan_full(m)

    if state is None:
        state = _initial_state(reader)
    delta_y: List[np.ndarray] = []
    any_new = False
    for li, lv in enumerate(m.levels):
        have = state.planes_loaded[li]
        want = max(have, plan.keep_planes[li])  # refinement never drops planes
        if want > have:
            any_new = True
            blobs: List[Optional[bytes]] = [None] * lv.nbits
            # XOR decode needs planes k+1, k+2; re-decode the prefix from the
            # already-fetched blobs (reader caches fetched ranges; re-reads of
            # the same tag are not double-counted).
            for i in range(want):
                blobs[i] = reader.plane(li, i)
            nb_new = bitplane.decode_level(blobs, lv.nbits, lv.n)
            dq = negabinary.from_negabinary(nb_new) - \
                negabinary.from_negabinary(state.nb_partial[li])
            delta_y.append(dq.astype(np.float64) * 2.0 * m.eb)
            state.nb_partial[li] = nb_new
            state.planes_loaded[li] = want
        else:
            delta_y.append(np.zeros(lv.n, np.float64))
    if any_new:
        zero_anchors = np.zeros(m.anchors_shape, np.float64)
        # escaped points are exact from the first pass: their delta is pinned 0
        zero_ovr = [(idx, np.zeros(idx.size)) for idx in state.esc_idx]
        delta = interpolation.reconstruct(m.shape, m.interp, zero_anchors,
                                          delta_y, overrides=zero_ovr)
        state.xhat = state.xhat + delta
    # achieved bound: from the *union* of loaded planes
    errs, _ = loader._level_cost_tables(m, propagation)
    state.err_bound = m.eb + sum(
        float(errs[li][lv.nbits - state.planes_loaded[li]])
        for li, lv in enumerate(m.levels))
    state.bytes_read = reader.bytes_read
    out = state.xhat.astype(np.dtype(m.dtype))
    return out, state


def decompress(buf: bytes) -> np.ndarray:
    """Full-precision decompression (error <= eb everywhere)."""
    out, _ = retrieve(buf)
    return out
