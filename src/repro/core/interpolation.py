"""Multi-level interpolation predictor (paper §4.1–§4.3).

The data grid is decomposed into L orthogonal levels.  Level ``l``
(l = L..1, finest = 1) predicts the points whose finest stride is
s = 2**(l-1) from the already-reconstructed points at stride 2*s, sweeping
dimension-by-dimension (Fig. 3).  Interpolation is used as a *prediction*
model: each level predicts from the lossy reconstruction ``xhat`` of the
previous level, so quantization error never amplifies (Eq. 4), unlike
transform models where ||T^-1||_inf can be O(n) (Eq. 3).

Formulas (paper Eq. 1/2):
  linear:  y_i = (x_{i-s} + x_{i+s}) / 2                        L_inf(P) = 1
  cubic:   y_i = (-x_{i-3s} + 9 x_{i-s} + 9 x_{i+s} - x_{i+3s})/16
                                                               L_inf(P) = 1.25
Boundary fallback: cubic -> linear -> copy-left.

Traversal order is shared verbatim by the compressor and the decompressor;
the quantized residual stream is the concatenation of every (level, phase)
target block in C order.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

LINEAR = "linear"
CUBIC = "cubic"

#: L_inf norm of the prediction operator, used by Theorem 1 (p^l factors).
PRED_NORM = {LINEAR: 1.0, CUBIC: 1.25}


def num_levels(shape: Sequence[int]) -> int:
    """L such that the anchor grid (stride 2^L) collapses to index 0 per dim."""
    m = int(max(shape))
    L = 1
    while (1 << L) < m:
        L += 1
    return L


def anchor_slices(shape: Sequence[int], L: int) -> Tuple[slice, ...]:
    s = 1 << L
    return tuple(slice(0, None, s) for _ in shape)


@dataclass(frozen=True)
class Phase:
    """One dimension-sweep inside a level."""
    level: int          # L..1
    stride: int         # 2**(level-1)
    dim: int            # axis being interpolated
    view: Tuple[slice, ...]   # restriction of the full array for this phase
    targets: np.ndarray       # target indices along `dim` (odd multiples of stride)
    n_dim: int                # full extent along `dim`
    count: int                # number of scalars predicted in this phase


def iter_phases(shape: Sequence[int], L: int) -> Iterator[Phase]:
    """Deterministic (level, dim) traversal shared by comp/decomp."""
    ndim = len(shape)
    for level in range(L, 0, -1):
        s = 1 << (level - 1)
        for d in range(ndim):
            targets = np.arange(s, shape[d], 2 * s)
            if targets.size == 0:
                continue
            view = tuple(
                slice(0, None, s) if dd < d else
                (slice(None) if dd == d else slice(0, None, 2 * s))
                for dd in range(ndim)
            )
            cnt = targets.size
            for dd in range(ndim):
                if dd < d:
                    cnt *= len(range(0, shape[dd], s))
                elif dd > d:
                    cnt *= len(range(0, shape[dd], 2 * s))
            yield Phase(level, s, d, view, targets, shape[d], cnt)


def level_sizes(shape: Sequence[int], L: int) -> List[int]:
    """Number of predicted scalars per level, index 0 = level L (coarsest)."""
    sizes = [0] * L
    for ph in iter_phases(shape, L):
        sizes[L - ph.level] += ph.count
    return sizes


def _bcast(mask: np.ndarray, axis: int, ndim: int) -> np.ndarray:
    shp = [1] * ndim
    shp[axis] = mask.size
    return mask.reshape(shp)


def predict_block(view: np.ndarray, axis: int, idx: np.ndarray, s: int,
                  n: int, interp: str) -> np.ndarray:
    """Interpolate values at ``idx`` (odd multiples of s) along ``axis``.

    ``view`` holds the already-known values (previous level at 2s multiples).
    Pure gather/arith — linear in the data, which Algorithm 2 (incremental
    delta reconstruction) relies on.
    """
    nd = view.ndim
    l1 = np.take(view, idx - s, axis=axis)
    r_ok = idx + s <= n - 1
    r1 = np.take(view, np.minimum(idx + s, n - 1), axis=axis)
    lin = 0.5 * (l1 + r1)
    if interp == LINEAR:
        return np.where(_bcast(r_ok, axis, nd), lin, l1)
    ll_ok = idx - 3 * s >= 0
    rr_ok = idx + 3 * s <= n - 1
    l3 = np.take(view, np.maximum(idx - 3 * s, 0), axis=axis)
    r3 = np.take(view, np.minimum(idx + 3 * s, n - 1), axis=axis)
    cub = (-l3 + 9.0 * l1 + 9.0 * r1 - r3) / 16.0
    pred = np.where(_bcast(ll_ok & rr_ok & r_ok, axis, nd), cub,
                    np.where(_bcast(r_ok, axis, nd), lin, l1))
    return pred


def _assign(view: np.ndarray, axis: int, idx: np.ndarray, vals: np.ndarray) -> None:
    view[(slice(None),) * axis + (idx,)] = vals


def decorrelate(x: np.ndarray, eb: float, interp: str,
                quantizer: Callable[[np.ndarray, np.ndarray], Tuple],
                ) -> Tuple[np.ndarray, List[np.ndarray], List[List[Tuple]], np.ndarray]:
    """Compression-side sweep.

    ``quantizer(residual, tvals) -> (q, recon_residual, (esc_idx, esc_vals))``
    returns int64 bins, the dequantized residual, and escape records holding
    the block-local flat indices and *absolute original values* of points the
    quantizer cannot represent.  Escapes are applied as exact overwrites —
    storing residuals would lose the value to catastrophic cancellation when
    |pred| >> |x|.

    Returns (xhat, per-level q arrays [index 0 = level L], per-level escape
    records with level-global indices, anchors).
    """
    shape = x.shape
    L = num_levels(shape)
    xhat = np.zeros_like(x, dtype=np.float64)
    anc = anchor_slices(shape, L)
    anchors = np.array(x[anc], np.float64, copy=True)
    xhat[anc] = anchors  # P_L(0) replaced by exact anchors (lossless channel)

    qs: List[List[np.ndarray]] = [[] for _ in range(L)]
    escs: List[List[Tuple]] = [[] for _ in range(L)]
    offsets = [0] * L
    for ph in iter_phases(shape, L):
        xv = x[ph.view]
        hv = xhat[ph.view]
        pred = predict_block(hv, ph.dim, ph.targets, ph.stride, ph.n_dim, interp)
        tvals = np.take(xv, ph.targets, axis=ph.dim).astype(np.float64)
        q, recon_res, esc = quantizer(tvals - pred, tvals)
        flat, vals = esc
        block = pred + recon_res
        if flat.size:
            block.reshape(-1)[flat] = vals  # exact overwrite, no cancellation
        _assign(hv, ph.dim, ph.targets, block)
        li = L - ph.level
        qs[li].append(q.ravel())
        escs[li].append((flat + offsets[li], vals))  # level-global indices
        offsets[li] += q.size
    return xhat, [np.concatenate(v) if v else np.zeros(0, np.int64) for v in qs], escs, anchors


def reconstruct(shape: Sequence[int], interp: str, anchors: np.ndarray,
                yhat_per_level: List[np.ndarray],
                overrides: Optional[List[Tuple[np.ndarray, np.ndarray]]] = None,
                out_dtype=np.float64, block_fn: Optional[Callable] = None,
                ) -> np.ndarray:
    """Decompression-side sweep (Algorithm 1 core).

    ``yhat_per_level[i]`` is the dequantized residual stream for level L-i.
    ``overrides[i]`` = (stream_idx, values): positions whose output is set to
    ``values`` exactly instead of pred+res (the lossless escape channel; for
    Algorithm 2's delta cascade the values are zeros, since escaped points
    never change across refinements).  Aside from overrides, purely linear in
    (anchors, yhat): the same routine reconstructs incremental deltas by
    feeding zero anchors and residual *differences*.

    ``block_fn(hv, ph, res)`` is the backend seam: given the phase view, the
    Phase, and the flat residual slice, return the reconstructed target
    block (pred + res) in original axis order as a writable C-order array.
    None = the numpy reference (predict_block).  Traversal, per-level offset
    accounting, and the override writeback stay here — shared by every
    backend — so the semantics cannot drift between substrates.
    """
    L = num_levels(shape)
    xhat = np.zeros(shape, np.float64)
    xhat[anchor_slices(shape, L)] = anchors
    offs = [0] * L
    for ph in iter_phases(shape, L):
        hv = xhat[ph.view]
        li = L - ph.level
        lo = offs[li]
        res = yhat_per_level[li][lo: lo + ph.count]
        offs[li] += ph.count
        if block_fn is None:
            pred = predict_block(hv, ph.dim, ph.targets, ph.stride,
                                 ph.n_dim, interp)
            tgt_shape = list(hv.shape)
            tgt_shape[ph.dim] = ph.targets.size
            block = pred + res.reshape(tgt_shape)
        else:
            block = block_fn(hv, ph, res)
        if overrides is not None:
            oidx, ovals = overrides[li]
            if oidx.size:
                sel = (oidx >= lo) & (oidx < lo + ph.count)
                if sel.any():
                    block.reshape(-1)[oidx[sel] - lo] = ovals[sel]
        _assign(hv, ph.dim, ph.targets, block)
    return xhat.astype(out_dtype)


def reconstruct_batch(shape: Sequence[int], interp: str, anchors: np.ndarray,
                      yhat_per_level: List[np.ndarray],
                      overrides: Optional[List[List[Tuple[np.ndarray, np.ndarray]]]] = None,
                      out_dtype=np.float64, block_fn: Optional[Callable] = None,
                      ) -> np.ndarray:
    """Batched :func:`reconstruct` over B equal-``shape`` items.

    ``anchors`` is (B, *anchors_shape), ``yhat_per_level[i]`` is (B, n_i),
    ``overrides[b][i]`` the per-item escape records, and the result is
    (B, *shape).  The traversal is the single-item one with a leading batch
    axis: every phase processes the whole stack at once (the unit of the
    vmapped chunk engine), while override writebacks stay per item.  The
    default (numpy) block path is element-for-element the same arithmetic
    as B independent :func:`reconstruct` calls, so results are
    bit-identical to the loop; batched backends plug in via ``block_fn(hv,
    ph, res)`` with ``hv`` the batched view and ``res`` (B, count).
    """
    B = anchors.shape[0]
    L = num_levels(shape)
    xhat = np.zeros((B,) + tuple(shape), np.float64)
    xhat[(slice(None),) + anchor_slices(shape, L)] = anchors
    offs = [0] * L
    for ph in iter_phases(shape, L):
        hv = xhat[(slice(None),) + ph.view]
        li = L - ph.level
        lo = offs[li]
        res = yhat_per_level[li][:, lo: lo + ph.count]
        offs[li] += ph.count
        if block_fn is None:
            pred = predict_block(hv, ph.dim + 1, ph.targets, ph.stride,
                                 ph.n_dim, interp)
            tgt_shape = list(hv.shape)
            tgt_shape[ph.dim + 1] = ph.targets.size
            block = pred + res.reshape(tgt_shape)
        else:
            block = block_fn(hv, ph, res)
        if overrides is not None:
            for b in range(B):
                oidx, ovals = overrides[b][li]
                if oidx.size:
                    sel = (oidx >= lo) & (oidx < lo + ph.count)
                    if sel.any():
                        block[b].reshape(-1)[oidx[sel] - lo] = ovals[sel]
        _assign(hv, ph.dim + 1, ph.targets, block)
    return xhat.astype(out_dtype)
