"""Standard scientific-lossy-compression metrics (paper §3.1.1)."""
from __future__ import annotations

import numpy as np


def value_range(x: np.ndarray) -> float:
    x = np.asarray(x)
    return float(x.max() - x.min())


def linf(x: np.ndarray, xhat: np.ndarray) -> float:
    """L-infinity norm of the decompression error (max point-wise |diff|)."""
    return float(np.max(np.abs(np.asarray(x, np.float64) - np.asarray(xhat, np.float64))))


def mse(x: np.ndarray, xhat: np.ndarray) -> float:
    d = np.asarray(x, np.float64) - np.asarray(xhat, np.float64)
    return float(np.mean(d * d))


def psnr(x: np.ndarray, xhat: np.ndarray) -> float:
    """Peak signal-to-noise ratio: 20*log10(range / sqrt(MSE))."""
    m = mse(x, xhat)
    if m == 0.0:
        return float("inf")
    return 20.0 * np.log10(value_range(x) / np.sqrt(m))


def compression_ratio(original_nbytes: int, compressed_nbytes: int) -> float:
    return original_nbytes / max(1, compressed_nbytes)


def bitrate(nbytes: int, n_elements: int) -> float:
    """Average number of bits stored per scalar value."""
    return 8.0 * nbytes / max(1, n_elements)
