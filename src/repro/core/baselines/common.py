"""Shared helpers for baseline compressors."""
from __future__ import annotations

import json
import struct
import zlib
from typing import List, Tuple

import numpy as np

ZLEVEL = 6


def zigzag(q: np.ndarray) -> np.ndarray:
    """Signed -> unsigned interleave, keeps small |q| in few bytes."""
    q = q.astype(np.int64)
    return ((q << 1) ^ (q >> 63)).astype(np.uint32)


def unzigzag(u: np.ndarray) -> np.ndarray:
    u = u.astype(np.uint32).astype(np.int64)
    return (u >> 1) ^ -(u & 1)


def byteplane_encode(q: np.ndarray) -> bytes:
    """Zigzag + byte-plane split + zlib (SZ3's Huffman+zstd stand-in).

    Byte-plane decomposition keeps high bytes (mostly zero) in long runs,
    which zlib exploits — same role Huffman+zstd plays in SZ3.
    """
    u = zigzag(q)
    planes = [((u >> np.uint32(8 * k)) & np.uint32(0xFF)).astype(np.uint8)
              for k in range(4)]
    blobs = [zlib.compress(p.tobytes(), ZLEVEL) for p in planes]
    head = struct.pack("<Q4I", q.size, *[len(b) for b in blobs])
    return head + b"".join(blobs)


def byteplane_decode(buf: bytes) -> Tuple[np.ndarray, int]:
    n, *sizes = struct.unpack("<Q4I", buf[:24])
    off = 24
    u = np.zeros(n, np.uint32)
    for k in range(4):
        raw = zlib.decompress(buf[off:off + sizes[k]])
        u |= np.frombuffer(raw, np.uint8).astype(np.uint32) << np.uint32(8 * k)
        off += sizes[k]
    return unzigzag(u), off


def pack_sections(meta: dict, sections: List[bytes]) -> bytes:
    meta = dict(meta, sections=[len(s) for s in sections])
    hj = json.dumps(meta, separators=(",", ":")).encode()
    return struct.pack("<I", len(hj)) + hj + b"".join(sections)


def unpack_sections(buf: bytes) -> Tuple[dict, List[bytes]]:
    (hlen,) = struct.unpack("<I", buf[:4])
    meta = json.loads(buf[4:4 + hlen].decode())
    out, off = [], 4 + hlen
    for sz in meta["sections"]:
        out.append(buf[off:off + sz])
        off += sz
    return meta, out
