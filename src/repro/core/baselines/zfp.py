"""ZFP-like orthogonal block-transform compressor (paper §2, §6.1.3).

4^d blocks, separable orthonormal 4-point DCT-II per dimension ("nearly
orthogonal block transform"), uniform coefficient quantization, byteplane
entropy coding.  Error control is transform-model style: the coefficient
bound is eb / ||T^-1||_inf^d (Eq. 3's amplification — the structural
disadvantage vs prediction models the paper analyzes in §4.2).
"""
from __future__ import annotations

import numpy as np

from . import common

_B = 4  # block edge


def _dct4() -> np.ndarray:
    k = np.arange(_B)[:, None]
    n = np.arange(_B)[None, :]
    m = np.cos(np.pi * (2 * n + 1) * k / (2 * _B))
    m[0] *= np.sqrt(1.0 / _B)
    m[1:] *= np.sqrt(2.0 / _B)
    return m  # orthonormal: m @ m.T == I


_T = _dct4()
_TINV_NORM = float(np.abs(_T.T).sum(axis=1).max())  # ||T^-1||_inf per dim


def _pad(x: np.ndarray) -> np.ndarray:
    pads = [(0, (-s) % _B) for s in x.shape]
    return np.pad(x, pads, mode="edge")


def _apply(x: np.ndarray, mat: np.ndarray) -> np.ndarray:
    for ax in range(x.ndim):
        x = np.moveaxis(np.tensordot(mat, np.moveaxis(x, ax, 0), axes=(1, 0)), 0, ax)
    return x


def _blockify(x: np.ndarray):
    nd = x.ndim
    shape = x.shape
    nb = [s // _B for s in shape]
    view = x.reshape([v for s in nb for v in (s, _B)])
    # (n0,4,n1,4,...) -> (n0,n1,...,4,4,...)
    perm = [2 * i for i in range(nd)] + [2 * i + 1 for i in range(nd)]
    return view.transpose(perm), nb


def _unblockify(blocks: np.ndarray, nb, nd) -> np.ndarray:
    perm = []
    for i in range(nd):
        perm += [i, nd + i]
    x = blocks.transpose(perm)
    return x.reshape([n * _B for n in nb])


class ZFP:
    name = "zfp"

    def compress(self, x: np.ndarray, eb: float) -> bytes:
        x = np.asarray(x)
        orig_shape = x.shape
        xp = _pad(x.astype(np.float64))
        blocks, nb = _blockify(xp)
        nd = x.ndim
        # transform the trailing nd axes (each of size 4)
        c = blocks
        for ax in range(nd, 2 * nd):
            c = np.moveaxis(np.tensordot(_T, np.moveaxis(c, ax, 0), axes=(1, 0)), 0, ax)
        eb_c = eb / (_TINV_NORM ** nd)
        q = np.rint(c / (2.0 * eb_c)).astype(np.int64)
        big = (q > (1 << 40)) | (q < -(1 << 40))
        esc_i = np.flatnonzero(big.ravel())
        esc_v = c.ravel()[esc_i] if esc_i.size else np.zeros(0)
        q.ravel()[esc_i] = 0
        sections = [common.byteplane_encode(np.clip(q, -(1 << 31), (1 << 31) - 1)),
                    esc_i.astype(np.int64).tobytes(),
                    np.asarray(esc_v, np.float64).tobytes()]
        meta = dict(shape=list(orig_shape), dtype=str(x.dtype), eb=eb,
                    nb=nb, nd=nd, qshape=list(q.shape))
        return common.pack_sections(meta, sections)

    def decompress(self, buf: bytes) -> np.ndarray:
        meta, secs = common.unpack_sections(buf)
        q, _ = common.byteplane_decode(secs[0])
        q = q.astype(np.float64).reshape(meta["qshape"])
        esc_i = np.frombuffer(secs[1], np.int64)
        esc_v = np.frombuffer(secs[2], np.float64)
        nd = meta["nd"]
        eb_c = meta["eb"] / (_TINV_NORM ** nd)
        c = q * (2.0 * eb_c)
        if esc_i.size:
            c.ravel()[esc_i] = esc_v
        for ax in range(nd, 2 * nd):
            c = np.moveaxis(np.tensordot(_T.T, np.moveaxis(c, ax, 0), axes=(1, 0)), 0, ax)
        xp = _unblockify(c, meta["nb"], nd)
        sl = tuple(slice(0, s) for s in meta["shape"])
        return xp[sl].astype(np.dtype(meta["dtype"]))
