"""Residual-based progressive wrappers: SZ3-R / ZFP-R (paper §6.1.3).

Compress at a large bound, then repeatedly compress the residual error at a
4x smaller bound until the target eb is reached (9 rungs: 2^16 eb .. eb).
Retrieval at fidelity rung k must load AND decompress rungs 0..k — the
multi-pass cost the paper criticizes.  Only the ladder's bounds are
retrievable (no arbitrary-eb support).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from . import common
from .sz3 import SZ3
from .zfp import ZFP

LADDER = [2 ** k for k in range(16, -1, -2)]


class ResidualProgressive:
    def __init__(self, base, name: str):
        self.base = base
        self.name = name

    def compress(self, x: np.ndarray, eb: float) -> bytes:
        x64 = np.asarray(x, np.float64)
        sections = []
        recon = np.zeros_like(x64)
        for f in LADDER:
            blob = self.base.compress((x64 - recon).astype(x.dtype), eb * f)
            sections.append(blob)
            recon = recon + np.asarray(self.base.decompress(blob), np.float64)
        meta = dict(eb=eb, ladder=LADDER, dtype=str(x.dtype))
        return common.pack_sections(meta, sections)

    def decompress(self, buf: bytes) -> np.ndarray:
        out, _, _ = self.retrieve(buf)
        return out

    def retrieve(self, buf: bytes, error_bound: Optional[float] = None,
                 max_bytes: Optional[int] = None
                 ) -> Tuple[np.ndarray, int, int]:
        """Returns (output, bytes_read, decompression_passes)."""
        meta, secs = common.unpack_sections(buf)
        eb = meta["eb"]
        upto = len(secs)
        if error_bound is not None:
            upto = len(secs)
            for i, f in enumerate(meta["ladder"]):
                if eb * f <= error_bound:
                    upto = i + 1
                    break
        elif max_bytes is not None:
            tot, upto = 0, 0
            for i, s in enumerate(secs):
                if tot + len(s) > max_bytes:
                    break
                tot += len(s)
                upto = i + 1
            upto = max(upto, 1) if len(secs[0]) <= (max_bytes or 0) else upto
        out = None
        bytes_read = 0
        for i in range(upto):
            part = np.asarray(self.base.decompress(secs[i]), np.float64)
            out = part if out is None else out + part
            bytes_read += len(secs[i])
        if out is None:  # nothing fits the budget: coarsest rung anyway
            out = np.asarray(self.base.decompress(secs[0]), np.float64)
            bytes_read = len(secs[0])
            upto = 1
        return out.astype(np.dtype(meta["dtype"])), bytes_read, upto


def SZ3R(interp: str = "cubic") -> ResidualProgressive:
    return ResidualProgressive(SZ3(interp), "sz3r")


def ZFPR() -> ResidualProgressive:
    return ResidualProgressive(ZFP(), "zfpr")
