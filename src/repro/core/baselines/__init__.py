"""Baseline compressors from the paper's evaluation (§6.1.3).

All baselines share the zlib entropy backend (container has no zstd; see
DESIGN.md §7) so speed/ratio comparisons measure the *algorithms*, not the
entropy coder.

  SZ3      — non-progressive interpolation compressor (ratio/speed reference)
  SZ3M     — multi-fidelity: independent archives at a bound ladder
  SZ3R     — progressive by residual re-compression (multi-pass retrieval)
  ZFP      — orthogonal 4^d block-transform compressor
  ZFPR     — residual-progressive ZFP
  PMGARD   — multilevel hierarchical-basis (transform-mode) progressive
"""
from .sz3 import SZ3
from .multifidelity import SZ3M
from .residual import ResidualProgressive, SZ3R, ZFPR
from .zfp import ZFP
from .mgard import PMGARD

__all__ = ["SZ3", "SZ3M", "SZ3R", "ZFP", "ZFPR", "PMGARD",
           "ResidualProgressive"]
