"""SZ3-like non-progressive interpolation compressor.

Same interpolation decorrelation + linear-scale quantization as IPComp, but
the quantized stream is entropy-coded monolithically (no bitplanes): a
single fidelity level per archive, decompress-all-or-nothing.  This is the
"leading non-progressive" reference of the paper.
"""
from __future__ import annotations

import numpy as np

from .. import interpolation, quantize
from . import common


class SZ3:
    name = "sz3"

    def __init__(self, interp: str = interpolation.CUBIC):
        self.interp = interp

    def compress(self, x: np.ndarray, eb: float) -> bytes:
        x = np.asarray(x)
        L = interpolation.num_levels(x.shape)

        def quantizer(res, tvals):
            q = quantize.quantize(res, eb)
            esc = quantize.escape_mask(q)
            recon = quantize.dequantize(q, eb)
            if esc.any():
                flat = np.flatnonzero(esc.ravel())
                vals = tvals.ravel()[flat].astype(np.float64)
                q.ravel()[flat] = 0
                return q, recon, (flat, vals)
            return q, recon, (np.zeros(0, np.int64), np.zeros(0, np.float64))

        _, qs, escs, anchors = interpolation.decorrelate(
            x.astype(np.float64), eb, self.interp, quantizer)
        q_all = np.concatenate(qs) if qs else np.zeros(0, np.int64)
        lvl_sizes = [int(q.size) for q in qs]
        esc_idx, esc_val, base = [], [], 0
        for li, recs in enumerate(escs):
            for idx, vals in recs:
                if idx.size:
                    esc_idx.append(idx + base)
                    esc_val.append(vals)
            base += lvl_sizes[li]
        ei = np.concatenate(esc_idx) if esc_idx else np.zeros(0, np.int64)
        ev = np.concatenate(esc_val) if esc_val else np.zeros(0, np.float64)
        sections = [common.byteplane_encode(q_all),
                    anchors.astype(np.float64).tobytes(),
                    ei.tobytes(), ev.tobytes()]
        meta = dict(shape=list(x.shape), dtype=str(x.dtype), eb=eb,
                    interp=self.interp, L=L, lvl=lvl_sizes,
                    anc=list(anchors.shape), nesc=int(ei.size))
        return common.pack_sections(meta, sections)

    def decompress(self, buf: bytes) -> np.ndarray:
        meta, secs = common.unpack_sections(buf)
        q_all, _ = common.byteplane_decode(secs[0])
        anchors = np.frombuffer(secs[1], np.float64).reshape(meta["anc"])
        ei = np.frombuffer(secs[2], np.int64)
        ev = np.frombuffer(secs[3], np.float64)
        yhat, overrides, off = [], [], 0
        for n in meta["lvl"]:
            y = quantize.dequantize(q_all[off:off + n], meta["eb"])
            sel = (ei >= off) & (ei < off + n)
            overrides.append((ei[sel] - off, ev[sel]))
            yhat.append(y)
            off += n
        out = interpolation.reconstruct(meta["shape"], meta["interp"], anchors,
                                        yhat, overrides=overrides)
        return out.astype(np.dtype(meta["dtype"]))
