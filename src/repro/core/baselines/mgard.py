"""PMGARD-like: multilevel hierarchical-basis progressive compressor.

Simplified MGARD stand-in (see DESIGN.md §7): linear-interpolation
hierarchical-basis *transform* computed from the ORIGINAL data top-down
(a transform model — coefficient errors amplify through levels, Eq. 3),
with per-level negabinary bitplane coding for progressive retrieval.
The coefficient bound is eb / sum_l(amp_l), which is what costs MGARD-style
codecs compression ratio relative to prediction models — the comparison the
paper draws in §4.2 and §6.

Retrieval: greedy MSB-first plane loading, steepest error-reduction per byte
(real PMGARD orders by L2 impact; same spirit).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from .. import bitplane, interpolation, negabinary
from . import common

_P = 1.0  # linear hierarchical basis


def _amp_factor(level: int, ndim: int) -> float:
    geo = sum(_P ** k for k in range(ndim))
    return geo * _P ** (ndim * (level - 1))


class PMGARD:
    name = "pmgard"

    def compress(self, x: np.ndarray, eb: float) -> bytes:
        x = np.asarray(x)
        x64 = x.astype(np.float64)
        shape = x.shape
        L = interpolation.num_levels(shape)
        ndim = x.ndim
        amp_total = sum(_amp_factor(l, ndim) for l in range(1, L + 1))
        eb_c = eb / amp_total
        # transform mode: predict every level from the ORIGINAL data
        coeffs: List[List[np.ndarray]] = [[] for _ in range(L)]
        for ph in interpolation.iter_phases(shape, L):
            xv = x64[ph.view]
            pred = interpolation.predict_block(xv, ph.dim, ph.targets,
                                               ph.stride, ph.n_dim, interpolation.LINEAR)
            tvals = np.take(xv, ph.targets, axis=ph.dim)
            coeffs[L - ph.level].append((tvals - pred).ravel())
        anchors = x64[interpolation.anchor_slices(shape, L)]
        sections = [anchors.tobytes()]
        lvl_meta = []
        for li in range(L):
            y = np.concatenate(coeffs[li]) if coeffs[li] else np.zeros(0)
            q = np.rint(y / (2.0 * eb_c)).astype(np.int64)
            q = np.clip(q, -(1 << 30), 1 << 30)  # baseline: no escape channel
            nb = negabinary.to_negabinary(q)
            blobs, nbits = bitplane.encode_level(nb)
            delta = negabinary.truncation_loss_table(nb, nbits, eb_c)
            lvl_meta.append(dict(n=int(q.size), nbits=nbits,
                                 sizes=[len(b) for b in blobs],
                                 delta=delta.tolist(), level=L - li))
            sections.extend(blobs)
        meta = dict(shape=list(shape), dtype=str(x.dtype), eb=eb, eb_c=eb_c,
                    L=L, anc=list(anchors.shape), levels=lvl_meta)
        return common.pack_sections(meta, sections)

    def decompress(self, buf: bytes) -> np.ndarray:
        out, _, _ = self.retrieve(buf)
        return out

    def retrieve(self, buf: bytes, error_bound: Optional[float] = None,
                 max_bytes: Optional[int] = None
                 ) -> Tuple[np.ndarray, int, int]:
        meta, secs = common.unpack_sections(buf)
        L, ndim = meta["L"], len(meta["shape"])
        eb_c = meta["eb_c"]
        anchors = np.frombuffer(secs[0], np.float64).reshape(meta["anc"])
        # per (level, plane): propagated error drop and byte cost
        entries = []  # (level_idx, plane_idx, err_drop, bytes)
        sec_idx = 1
        level_secs = []
        for li, lv in enumerate(meta["levels"]):
            level_secs.append(secs[sec_idx:sec_idx + lv["nbits"]])
            sec_idx += lv["nbits"]
            amp = _amp_factor(lv["level"], ndim)
            d = lv["delta"]
            for pi in range(lv["nbits"]):
                drop = (d[lv["nbits"] - pi] - d[lv["nbits"] - pi - 1]) * amp
                entries.append((li, pi, drop, lv["sizes"][pi]))
        base_err = meta["eb"] + sum(
            lv["delta"][lv["nbits"]] * _amp_factor(lv["level"], ndim)
            for lv in meta["levels"])
        # greedy: best error reduction per byte, but planes of a level must be
        # loaded MSB-first -> process in (level, plane) prefix order per level
        keep = [0] * L
        cur_err = base_err
        cur_bytes = 0
        while True:
            best = None
            for li, lv in enumerate(meta["levels"]):
                pi = keep[li]
                if pi >= lv["nbits"]:
                    continue
                amp = _amp_factor(lv["level"], ndim)
                drop = (lv["delta"][lv["nbits"] - pi]
                        - lv["delta"][lv["nbits"] - pi - 1]) * amp
                cost = max(1, lv["sizes"][pi])
                score = drop / cost
                if best is None or score > best[0]:
                    best = (score, li, drop, lv["sizes"][pi])
            if best is None:
                break
            _, li, drop, cost = best
            if error_bound is not None:
                if cur_err <= error_bound:
                    break
            elif max_bytes is not None:
                if cur_bytes + cost > max_bytes:
                    break
            else:
                pass  # full retrieval
            keep[li] += 1
            cur_err -= drop
            cur_bytes += cost
        if error_bound is not None and cur_err > error_bound:
            pass  # loaded everything; eb floor reached
        # reconstruct
        yhat = []
        bytes_read = len(secs[0])
        for li, lv in enumerate(meta["levels"]):
            blobs = [level_secs[li][i] for i in range(keep[li])]
            bytes_read += sum(lv["sizes"][: keep[li]])
            nb = bitplane.decode_level(
                list(blobs) + [None] * (lv["nbits"] - keep[li]),
                lv["nbits"], lv["n"])
            yhat.append(negabinary.from_negabinary(nb).astype(np.float64)
                        * 2.0 * eb_c)
        out = interpolation.reconstruct(meta["shape"], interpolation.LINEAR,
                                        anchors, yhat)
        return out.astype(np.dtype(meta["dtype"])), bytes_read, 1
