"""SZ3-M: multi-fidelity via independent archives (paper §6.1.3).

Compresses the input at a ladder of error bounds (2^16 eb ... eb, factor 4
apart) and stores all archives together.  Supports multi-fidelity retrieval
but is NOT progressive: each retrieval decompresses one archive from
scratch; nothing is reused between fidelity levels.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .sz3 import SZ3
from . import common

LADDER = [2 ** k for k in range(16, -1, -2)]  # 2^16 eb ... eb


class SZ3M:
    name = "sz3m"

    def __init__(self, interp: str = "cubic"):
        self.base = SZ3(interp)

    def compress(self, x: np.ndarray, eb: float) -> bytes:
        sections = [self.base.compress(x, eb * f) for f in LADDER]
        meta = dict(eb=eb, ladder=LADDER)
        return common.pack_sections(meta, sections)

    def decompress(self, buf: bytes) -> np.ndarray:
        _, secs = common.unpack_sections(buf)
        return self.base.decompress(secs[-1])

    def retrieve(self, buf: bytes, error_bound: Optional[float] = None,
                 max_bytes: Optional[int] = None
                 ) -> Tuple[np.ndarray, int, int]:
        """Returns (output, bytes_read, decompression_passes)."""
        meta, secs = common.unpack_sections(buf)
        eb = meta["eb"]
        pick = len(secs) - 1
        if error_bound is not None:
            for i, f in enumerate(meta["ladder"]):
                if eb * f <= error_bound:
                    pick = i
                    break
        elif max_bytes is not None:
            pick = 0
            for i in range(len(secs)):
                if len(secs[i]) <= max_bytes:
                    pick = i  # largest archive under budget (finest fitting)
            # ladder sizes grow with precision; choose the biggest that fits
            best = None
            for i, s in enumerate(secs):
                if len(s) <= max_bytes and (best is None or len(s) > len(secs[best])):
                    best = i
            pick = best if best is not None else 0
        return self.base.decompress(secs[pick]), len(secs[pick]), 1
