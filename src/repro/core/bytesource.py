"""Pluggable byte-range I/O under the container readers.

The container layer (``container.py``) historically assumed the whole
archive lived in one in-memory buffer — every ``reader.read(offset,
size, tag)`` was a slice.  That assumption is wrong for the access
pattern the format exists to serve: progressive retrieval over
object-store / HTTP-range / parallel-FS storage reads *byte ranges* of a
large remote object, and the plane-major v3 layout (``docs/format.md``
§3) is designed so a fidelity ladder reads monotone contiguous ranges of
exactly such a source.

:class:`ByteSource` is that seam made explicit: the minimal random-access
contract (``read(offset, size)`` + ``size``) the readers are rebased
onto.  Three implementations cover the repo's needs:

* :class:`BufferSource` — zero-copy view over an in-memory buffer
  (bytes / bytearray / memoryview); the historical behaviour.
* :class:`FileSource` — mmap-backed file reads: opening an archive from
  disk touches only the ranges actually requested (header first, then
  planned blob ranges), never the whole file.
* :class:`CountingSource` — a transparent wrapper recording every range
  request in order, with coalesced-range and seek-distance accounting.
  This is the test double behind the v3 monotone-contiguous-ranges
  assertions and the ``benchmarks/serve_bench.py`` layout comparison:
  it measures *how* an archive was read, not just how much.

Any source can be windowed (:meth:`ByteSource.window`): a
:class:`_Window` forwards reads to the parent at absolute offsets, so a
chunk sub-reader of a v2 container still surfaces its requests at real
container positions — which is what makes the range accounting
comparable across container versions.
"""
from __future__ import annotations

import io
import mmap
import os
import threading
from typing import List, Optional, Tuple, Union


class ByteSource:
    """Minimal random-access byte contract the container readers consume.

    Subclasses implement :meth:`read` and :attr:`size`.  ``read`` may
    return ``bytes`` or a ``memoryview`` (consumers — ``np.frombuffer``,
    ``zlib.decompress`` — accept both); reads are never cached here, the
    readers own all fetch accounting.
    """

    def read(self, offset: int, size: int):
        """The ``size`` bytes at ``offset``.  Short reads are a contract
        violation — callers request only ranges the header declared and
        the parser bounds-checked."""
        raise NotImplementedError

    @property
    def size(self) -> int:
        """Total byte length of the underlying archive."""
        raise NotImplementedError

    def window(self, offset: int, size: int) -> "ByteSource":
        """A view of ``[offset, offset + size)`` whose position 0 is the
        parent's ``offset``.  Reads forward to the parent at absolute
        positions, so range accounting on the parent sees real container
        offsets."""
        return _Window(self, offset, size)

    def close(self) -> None:
        """Release any held OS resources (no-op for memory sources)."""


def as_source(obj) -> ByteSource:
    """Coerce ``obj`` to a :class:`ByteSource`.

    Already-a-source passes through; bytes-like objects wrap in a
    zero-copy :class:`BufferSource`.  This is the single coercion point
    every reader/parser entry uses, so the whole container layer accepts
    either currency.
    """
    if isinstance(obj, ByteSource):
        return obj
    return BufferSource(obj)


class BufferSource(ByteSource):
    """In-memory source: zero-copy ``memoryview`` slices of one buffer."""

    def __init__(self, buf: Union[bytes, bytearray, memoryview]):
        self._view = memoryview(buf)

    def read(self, offset: int, size: int):
        return self._view[offset: offset + size]

    @property
    def size(self) -> int:
        return self._view.nbytes

    def tobytes(self) -> bytes:
        return bytes(self._view)


class FileSource(ByteSource):
    """mmap-backed file source: page cache does the buffering, the
    process never materializes the whole archive.

    ``Archive.load`` opens file archives through this, so a coarse read
    of a large on-disk archive touches only the header and the planned
    blob ranges.  The mapping is read-only and shared; :meth:`close`
    releases it (reads after close raise).
    """

    def __init__(self, path: Union[str, "os.PathLike"]):
        self.path = os.fspath(path)
        self._f = open(self.path, "rb")
        self._size = os.fstat(self._f.fileno()).st_size
        # a zero-length file cannot be mapped; parsers reject it anyway
        # (every archive needs >= 8 framing bytes), so serve empty reads
        self._mm = (mmap.mmap(self._f.fileno(), 0, access=mmap.ACCESS_READ)
                    if self._size else None)

    def read(self, offset: int, size: int):
        if self._mm is None:
            return b""
        return memoryview(self._mm)[offset: offset + size]

    @property
    def size(self) -> int:
        return self._size

    def close(self) -> None:
        if self._mm is not None:
            self._mm.close()
            self._mm = None
        if not self._f.closed:
            self._f.close()

    def __repr__(self) -> str:
        return f"FileSource({self.path!r}, {self._size} bytes)"


class RangeLog:
    """Thread-safe ordered log of range requests, plus derived metrics.

    Shared accounting machinery for every source that records its range
    traffic — :class:`CountingSource` (the in-memory test double) and
    ``remote.HTTPSource`` (real wire requests) expose the SAME metric
    surface through it, which is what makes in-memory layout claims and
    over-the-network measurements directly comparable
    (``docs/format.md`` §3.5):

    * :attr:`requests` — the raw ``(offset, size)`` log, in call order;
    * :meth:`coalesced` — the log merged greedily *in order*: a request
      starting exactly at the previous run's end extends it, anything
      else opens a new run.  A reader whose access pattern is truly
      streaming produces ONE coalesced run per contiguous sweep.
    * :attr:`seek_distance` — summed ``|start - previous_end|`` over
      consecutive requests: 0 for a perfectly sequential reader, large
      for a scatter-read pattern (the v2-vs-v3 benchmark metric).
    * :meth:`monotone` — True when request offsets never move backward.

    Appends take a lock: the serving tier reads many sessions over one
    shared source concurrently, and an unguarded ``list.append`` +
    metric sweep interleaving would tear the log (pinned by the
    concurrent-reader test in ``tests/test_bytesource.py``).  Metric
    reads operate on an atomic snapshot, so they are safe to call while
    other threads keep appending.
    """

    def __init__(self):
        self.requests: List[Tuple[int, int]] = []
        self._log_lock = threading.Lock()

    def record_range(self, offset: int, size: int) -> None:
        """Append one range request to the log (thread-safe).  Zero-byte
        requests (empty planes, empty escape blobs) are not recorded:
        they hit no storage and would distort the range counts."""
        if size:
            with self._log_lock:
                self.requests.append((int(offset), int(size)))

    def _ranges(self) -> List[Tuple[int, int]]:
        with self._log_lock:
            return list(self.requests)

    @property
    def n_requests(self) -> int:
        return len(self._ranges())

    @property
    def bytes_requested(self) -> int:
        return sum(s for _, s in self._ranges())

    def coalesced(self) -> List[Tuple[int, int]]:
        """In-order greedy coalescing: adjacent-in-time AND
        adjacent-in-space requests merge into one run."""
        runs: List[List[int]] = []
        for off, size in self._ranges():
            if runs and off == runs[-1][0] + runs[-1][1]:
                runs[-1][1] += size
            else:
                runs.append([off, size])
        return [(o, s) for o, s in runs]

    def monotone(self) -> bool:
        """Did the request stream ever seek backward?"""
        reqs = self._ranges()
        return all(b[0] >= a[0] for a, b in zip(reqs, reqs[1:]))

    @property
    def seek_distance(self) -> int:
        """Summed absolute gap between consecutive requests (0 = pure
        streaming)."""
        reqs = self._ranges()
        return sum(abs(b[0] - (a[0] + a[1]))
                   for a, b in zip(reqs, reqs[1:]))

    def reset(self) -> None:
        """Drop the log (metrics restart; the source itself is kept)."""
        with self._log_lock:
            self.requests = []


class CountingSource(RangeLog, ByteSource):
    """Transparent wrapper recording every range request, in order.

    The range-accounting test double of the I/O layer: wraps any source
    and logs ``(offset, size)`` per :meth:`read` through the shared
    :class:`RangeLog` machinery — the metric surface the v3 layout
    claims are stated in.  It measures *how* an archive was read, not
    just how much.
    """

    def __init__(self, inner):
        RangeLog.__init__(self)
        self.inner = as_source(inner)

    def read(self, offset: int, size: int):
        self.record_range(offset, size)
        return self.inner.read(offset, size)

    @property
    def size(self) -> int:
        return self.inner.size

    def close(self) -> None:
        self.inner.close()

    def __repr__(self) -> str:
        return (f"CountingSource({self.n_requests} requests, "
                f"{len(self.coalesced())} coalesced ranges, "
                f"seek_distance={self.seek_distance})")


class _Window(ByteSource):
    """A positioned view over a parent source (see
    :meth:`ByteSource.window`); reads land on the parent at absolute
    offsets so accounting wrappers see real container positions."""

    def __init__(self, parent: ByteSource, base: int, size: int):
        self._parent = parent
        self._base = int(base)
        self._size = int(size)

    def read(self, offset: int, size: int):
        return self._parent.read(self._base + offset, size)

    @property
    def size(self) -> int:
        return self._size
