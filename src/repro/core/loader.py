"""Optimized data loading (paper §5): knapsack DP over (level x bitplanes).

Two modes:
  * error-bound mode (§5.2): minimize loaded bytes s.t.
        sum_l p^(l-1) * delta_y_l(b_l) + eb <= E
  * bitrate / fixed-size mode (§5.3): minimize the error bound s.t.
        sum_l LoadedSize(l, b_l) <= S

``b_l`` = number of LSB planes discarded at level l.  delta_y_l(b) is the
exact per-level truncation loss table pre-computed at compression time
(container header), p = L_inf(P) (1.0 linear / 1.25 cubic, Theorem 1).

The DP discretizes the continuous budget into ``NBUCKETS`` units (the paper
normalizes E/eb into [128, 1023]); costs are rounded UP when consuming
budget, so the returned plan is always feasible (conservative).

``propagation="paper"`` uses Theorem 1's p^(l-1).  ``propagation="safe"``
uses p^((l-1+1)*ndim_phases) — an upper bound that also covers within-level
dimension-sequential amplification (see DESIGN.md §3); used by the
adversarial property tests.

Chunked (v2) archives run this planner per chunk: error mode passes the
requested bound straight through (per-chunk L_inf <= E implies the global
bound), byte/bitrate budgets are pre-split across chunks proportionally to
element count with largest-remainder rounding, after reserving each
chunk's escape-channel plan floor (see
``pipeline.decode._retrieve_chunked`` / ``refine_budgets``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from .container import ArchiveMeta
from .interpolation import PRED_NORM

NBUCKETS = 1024
PAPER = "paper"
SAFE = "safe"


@dataclass
class LoadPlan:
    keep_planes: List[int]        # planes to load per level (MSB-first count)
    loaded_bytes: int             # data bytes the plan touches (excl. header)
    err_bound: float              # guaranteed L_inf bound of the plan
    mode: str


def _prop_factor(meta: ArchiveMeta, level: int, propagation: str) -> float:
    """Amplification applied to level ``level``'s truncation loss (level 1 = finest).

    PAPER: Theorem 1's p^(l-1).  SAFE: corrected bound that also accounts for
    within-level dimension-sequential propagation.  Per level, a phase-d
    target's delta obeys e_d = p*e_{d-1} + delta_l over ndim phases, so level
    l contributes (sum_{k<ndim} p^k) * p^(ndim*(l-1)) * delta_l.  Empirically
    the paper's factor under-covers cubic 3D by up to ~2.3x (see
    EXPERIMENTS.md §Repro-findings); SAFE is the default so the paper's
    "error guarantee" objective actually holds.
    """
    p = PRED_NORM[meta.interp]
    if propagation == PAPER:
        return p ** (level - 1)
    ndim = len(meta.shape)
    geo = sum(p ** k for k in range(ndim))
    return geo * p ** (ndim * (level - 1))


def _level_cost_tables(meta: ArchiveMeta, propagation: str):
    """Per level: arrays over b (0..nbits) of [propagated error, loaded bytes]."""
    errs, sizes = [], []
    for li, lv in enumerate(meta.levels):
        f = _prop_factor(meta, lv.level, propagation)
        e = np.asarray(lv.delta_table, np.float64) * f          # err(l, b)
        tot = np.cumsum([0] + lv.plane_sizes)                    # prefix sums
        # keeping (nbits - b) MSB planes loads tot[nbits-b] bytes (+escapes)
        s = np.array([tot[lv.nbits - b] for b in range(lv.nbits + 1)], np.int64)
        s += lv.esc_size  # escape channel always loaded with the level
        errs.append(e)
        sizes.append(s)
    return errs, sizes


def plan_error_mode(meta: ArchiveMeta, E: float,
                    propagation: str = PAPER) -> LoadPlan:
    """Minimum-volume plan with guaranteed L_inf error <= E (requires E >= eb)."""
    if E < meta.eb:
        raise ValueError(f"requested bound {E} < compression bound {meta.eb}")
    errs, sizes = _level_cost_tables(meta, propagation)
    budget = E - meta.eb
    nl = len(meta.levels)
    if budget <= 0:
        keep = [meta.levels[i].nbits for i in range(nl)]
        return _finish(meta, keep, errs, mode="error")
    unit = budget / NBUCKETS
    # err in integer units, rounded UP => conservative
    err_units = [np.minimum(np.ceil(e / unit), NBUCKETS + 1).astype(np.int64)
                 for e in errs]
    # DP[u] = min bytes with total err units <= u, processed levels so far
    INF = np.int64(1 << 60)
    dp = np.full(NBUCKETS + 1, INF, np.int64)
    dp[:] = 0  # zero levels processed: zero bytes whatever the budget
    choice = np.zeros((nl, NBUCKETS + 1), np.int16)
    for li in range(nl):
        ndp = np.full(NBUCKETS + 1, INF, np.int64)
        nch = np.zeros(NBUCKETS + 1, np.int16)
        for b in range(meta.levels[li].nbits + 1):
            eu = int(err_units[li][b])
            if eu > NBUCKETS:
                continue  # this choice alone blows the budget
            cost = sizes[li][b]
            # shifting: state u can take choice b if u >= eu
            cand = np.full(NBUCKETS + 1, INF, np.int64)
            cand[eu:] = dp[: NBUCKETS + 1 - eu] + cost
            upd = cand < ndp
            ndp[upd] = cand[upd]
            nch[upd] = b
        dp = ndp
        choice[li] = nch
    # backtrack from the full budget
    u = NBUCKETS
    keep = []
    discard = []
    for li in range(nl - 1, -1, -1):
        b = int(choice[li][u])
        discard.append(b)
        u -= int(err_units[li][b])
    discard.reverse()
    keep = [meta.levels[i].nbits - discard[i] for i in range(nl)]
    return _finish(meta, keep, errs, mode="error")


def plan_bitrate_mode(meta: ArchiveMeta, max_bytes: int,
                      propagation: str = PAPER) -> LoadPlan:
    """Minimum-error plan with loaded bytes <= max_bytes.

    Every plan loads the escape channels (lossless outliers travel with
    their level), so the smallest representable plan costs
    ``sum(esc_size)`` bytes — the *plan floor*.  A ``max_bytes`` below the
    floor is infeasible and raises ``ValueError``: silently returning the
    floor plan (the old behaviour) violated the ``Fidelity.max_bytes``
    contract with no signal, reporting ``loaded_bytes > max_bytes``.
    ``max_bytes`` exactly at the floor is feasible and returns the
    zero-plane plan.
    """
    errs, sizes = _level_cost_tables(meta, propagation)
    nl = len(meta.levels)
    min_bytes = int(sum(int(s[-1]) for s in sizes))  # b = nbits per level
    if max_bytes < min_bytes:
        raise ValueError(
            f"max_bytes={max_bytes} is infeasible: the smallest plan for "
            f"this archive loads {min_bytes} bytes (escape channels are "
            "always loaded with their level); request at least that many "
            "bytes or use an error-bound target")
    budget = max_bytes - min_bytes
    if budget <= 0:  # exactly the escape-channel floor: load the minimum
        return _finish(meta, [0] * nl, errs, mode="bitrate")
    # ceil-rounded units guarantee sum(sizes) <= NBUCKETS*unit = budget
    unit = budget / NBUCKETS
    size_units = [np.minimum(np.ceil((s - s[-1]) / unit), NBUCKETS + 1).astype(np.int64)
                  for s in sizes]
    INF = float("inf")
    dp = np.zeros(NBUCKETS + 1, np.float64)
    choice = np.zeros((nl, NBUCKETS + 1), np.int16)
    for li in range(nl):
        ndp = np.full(NBUCKETS + 1, INF, np.float64)
        nch = np.full(NBUCKETS + 1, meta.levels[li].nbits, np.int16)
        for b in range(meta.levels[li].nbits + 1):
            su = int(size_units[li][b])
            if su > NBUCKETS:
                continue
            e = errs[li][b]
            cand = np.full(NBUCKETS + 1, INF, np.float64)
            cand[su:] = dp[: NBUCKETS + 1 - su] + e
            upd = cand < ndp
            ndp[upd] = cand[upd]
            nch[upd] = b
        dp = ndp
        choice[li] = nch
    u = NBUCKETS
    discard = []
    for li in range(nl - 1, -1, -1):
        b = int(choice[li][u])
        discard.append(b)
        u -= int(size_units[li][b])
    discard.reverse()
    keep = [meta.levels[i].nbits - discard[i] for i in range(nl)]
    return _finish(meta, keep, errs, mode="bitrate")


def plan_full(meta: ArchiveMeta, propagation: str = PAPER) -> LoadPlan:
    """Full-precision plan: every plane of every level.

    ``propagation`` selects the error-propagation model for the reported
    ``err_bound`` exactly like the other planners — it used to be
    hardcoded to PAPER, so a session planning under SAFE could receive a
    plan whose reported bound was computed under a different (tighter)
    model than the session's own ``update_achieved_bound`` accounting.
    """
    errs, _ = _level_cost_tables(meta, propagation)
    return _finish(meta, [lv.nbits for lv in meta.levels], errs, mode="full")


# ------------------------------------------------ v3 ladder (plane-major)
#
# A v3 archive's layout IS its retrieval plan: the writer lays plane
# segments in one global order and every fidelity resolves to a *prefix
# length* ``t`` over that order.  The planners below are the two halves:
# ``ladder_order`` (write time) picks the order, ``ladder_error_mode`` /
# ``ladder_bitrate_mode`` (read time) walk it.  Unlike the per-chunk
# knapsack above, the prefix cannot tailor plane counts per chunk — that
# is the deliberate trade: a slightly less byte-optimal plan in exchange
# for monotone contiguous range reads (docs/format.md §3).

def ladder_order(chunk_metas: Sequence[ArchiveMeta],
                 propagation: str = SAFE) -> List[tuple]:
    """Greedy rate-distortion order of (level index, plane index) over the
    whole chunk grid: at each step, take the plane segment with the best
    summed error reduction per byte.

    Within a level the candidate is always the next MSB-first plane (XOR
    plane coding makes planes order-dependent), so the order interleaves
    *levels*, never planes within a level.  A level's candidate is
    scored with a LOOKAHEAD: the best cumulative gain per byte over any
    *run* of its next planes, and the whole winning run is emitted at
    once.  The lookahead matters because ``delta_table`` need not be
    monotone at the top — keeping only the MSB negabinary digit can
    reconstruct FARTHER from the data than truncating to zero (the
    lone digit overshoots), so plane 0 alone can score a negative gain.
    A per-plane greedy then parks that level's entire ladder at the end
    of the order, and every error-mode prefix through it degenerates to
    a near-total read; the run score sees past the dip (plane 0+1
    together are a large gain for few bytes).  For levels with monotone
    decaying gains the best run is always length 1 and the order —
    hence the archive bytes — is unchanged.  Scores use the SAFE
    propagation model by default — the write-time order must serve
    whichever model retrieval later plans under, and SAFE is the
    conservative one.  Zero-byte segments score infinite (free error
    reduction) and drain first; ties break toward the coarser level
    (lower level index = higher ``LevelMeta.level``), matching the
    knapsack's tendency to fill coarse levels first.  Deterministic:
    depends only on the chunk headers.
    """
    nlev = max(len(m.levels) for m in chunk_metas)
    errs = [_level_cost_tables(m, propagation)[0] for m in chunk_metas]
    nbits_max = [max((m.levels[li].nbits for m in chunk_metas
                      if li < len(m.levels)), default=0)
                 for li in range(nlev)]
    next_k = [0] * nlev
    order: List[tuple] = []

    def best_run(li: int):
        """(score, run length) of the best prefix of level li's
        remaining planes by cumulative gain per cumulative byte."""
        gain, size = 0.0, 0
        best = None
        for k in range(next_k[li], nbits_max[li]):
            for m, e in zip(chunk_metas, errs):
                if li >= len(m.levels) or k >= m.levels[li].nbits:
                    continue
                nb = m.levels[li].nbits
                gain += float(e[li][nb - k] - e[li][nb - k - 1])
                size += m.levels[li].plane_sizes[k]
            score = math.inf if size == 0 else gain / size
            if best is None or score > best[0]:
                best = (score, k - next_k[li] + 1)
            if best[0] == math.inf:
                break          # free prefix: emit now, rescore the rest
        return best

    while True:
        best = None
        for li in range(nlev):
            if next_k[li] >= nbits_max[li]:
                continue
            score, run = best_run(li)
            key = (score, -li)
            if best is None or key > best[0]:
                best = (key, li, run)
        if best is None:
            return order
        _, li, run = best
        for _ in range(run):
            order.append((li, next_k[li]))
            next_k[li] += 1


def ladder_error_mode(meta, E: float, propagation: str = PAPER,
                      t_min: int = 0) -> int:
    """Shortest ladder prefix ``t`` with every chunk's guaranteed L_inf
    bound <= ``E`` (requires ``E >= eb``, like :func:`plan_error_mode`).

    ``meta`` is a :class:`~.container.V3Meta`.  Walks the write-time
    segment order, applying each segment's exact per-chunk error delta
    (from the header delta tables) until the worst chunk meets the bound.
    ``t_min`` floors the result for refinement: a session that already
    holds ``t_min`` segments never plans a shorter prefix (planes are
    never dropped), so a looser follow-up target is a no-op.
    """
    if E < meta.eb:
        raise ValueError(f"requested bound {E} < compression bound {meta.eb}")
    errs = [_level_cost_tables(m, propagation)[0] for m in meta.chunk_metas]
    cur = [m.eb + sum(float(errs[c][li][lv.nbits])
                      for li, lv in enumerate(m.levels))
           for c, m in enumerate(meta.chunk_metas)]
    segs = meta.plane_segments
    t = 0
    while t < len(segs) and (t < t_min or max(cur) > E):
        s = segs[t]
        for c, m in enumerate(meta.chunk_metas):
            if s.level >= len(m.levels):
                continue
            nb = m.levels[s.level].nbits
            if s.plane >= nb:
                continue
            cur[c] += float(errs[c][s.level][nb - s.plane - 1]
                            - errs[c][s.level][nb - s.plane])
        t += 1
    return t


def ladder_bitrate_mode(meta, max_bytes: int, t_min: int = 0) -> int:
    """Longest ladder prefix whose loaded bytes fit ``max_bytes``.

    Byte accounting matches the v1/v2 planners: escapes count (they
    always load — the plan floor), anchors do not.  ``meta.cum_bytes[t]``
    is exactly that cost for prefix ``t``, so this is a table lookup.
    ``t_min`` floors the result for refinement, like
    :func:`ladder_error_mode` (the budget check still applies to the
    *requested* bytes, so a refine below the floor of already-held bytes
    simply no-ops at ``t_min``).
    """
    cum = meta.cum_bytes
    if max_bytes < cum[0]:
        raise ValueError(
            f"max_bytes={max_bytes} is infeasible: the smallest plan for "
            f"this archive loads {cum[0]} bytes (escape channels are "
            "always loaded with their level); request at least that many "
            "bytes or use an error-bound target")
    t = 0
    while t + 1 < len(cum) and cum[t + 1] <= max_bytes:
        t += 1
    return max(t, t_min)


def _finish(meta: ArchiveMeta, keep: List[int], errs, mode: str) -> LoadPlan:
    total = sum(sum(lv.plane_sizes[: keep[li]]) + lv.esc_size
                for li, lv in enumerate(meta.levels))
    # same summation shape as state.update_achieved_bound, so the plan's
    # reported bound and the session's achieved bound agree to the bit
    err = meta.eb + sum(float(errs[li][lv.nbits - keep[li]])
                        for li, lv in enumerate(meta.levels))
    return LoadPlan(keep_planes=keep, loaded_bytes=int(total),
                    err_bound=float(err), mode=mode)
