"""Bitplane split + predictive XOR coding (paper §4.4.1) + lossless backend.

Each level's negabinary integers are sliced into bitplanes (bit k of every
integer forms plane k).  Planes are stored MSB-first so progressively loading
a *prefix* of planes refines precision.  Cross-bitplane correlation is
recovered with 2-bit-prefix predictive coding:

    enc_k = b_{k+2} ^ b_{k+1} ^ b_k        (prefix = two more-significant bits)

which the paper's Table 2 shows minimizes entropy.  Encoded planes are
bit-packed and zlib-compressed independently, so any prefix of planes is
independently decodable (the "blocks" of Fig. 2).
"""
from __future__ import annotations

import os
import zlib
from typing import List, Optional, Tuple

import numpy as np

#: default zlib compression level; override per process with
#: ``IPCOMP_ZLIB_LEVEL`` (0–9).  Both backends read the same knob, so the
#: byte-identical-archive invariant holds at every setting.
ZLEVEL = 6

ZLEVEL_ENV = "IPCOMP_ZLIB_LEVEL"


def zlib_level() -> int:
    """Resolve the encode-side zlib level (env knob, default :data:`ZLEVEL`).

    Read per call so tests and long-lived servers can flip the knob without
    reimporting; an out-of-range or non-integer value is an error, not a
    silent fallback.
    """
    v = os.environ.get(ZLEVEL_ENV)
    if v is None:
        return ZLEVEL
    lvl = int(v)
    if not 0 <= lvl <= 9:
        raise ValueError(f"{ZLEVEL_ENV} must be in 0..9, got {lvl}")
    return lvl


class Raw(bytes):
    """In-memory marker: a plane payload that is ALREADY the raw packed-bit
    stream, not a zlib blob.  The archive format never stores this — it
    exists so cache layers and tests can hand pre-inflated payloads to the
    decoders and :func:`inflate` can skip the decompressobj round-trip.
    """
    __slots__ = ()


def inflate(blob) -> bytes:
    """Shared blob -> raw packed-bit stream helper for every decode path.

    Falsy (``b''`` all-zero convention / None) -> ``b''``; :class:`Raw`
    payloads pass through without touching zlib; anything else is a stored
    zlib blob and is decompressed.
    """
    if not blob:
        return b""
    if isinstance(blob, Raw):
        return bytes(blob)
    return zlib.decompress(blob)


def split_planes(nb: np.ndarray, nbits: int) -> List[np.ndarray]:
    """uint32 negabinary -> list of uint8 bit arrays, index k = bit k."""
    return [((nb >> np.uint32(k)) & np.uint32(1)).astype(np.uint8)
            for k in range(nbits)]


def join_planes(planes: List[Optional[np.ndarray]], n: int) -> np.ndarray:
    """Inverse of split_planes; missing (None) planes contribute 0."""
    nb = np.zeros(n, np.uint32)
    for k, p in enumerate(planes):
        if p is not None:
            nb |= p.astype(np.uint32) << np.uint32(k)
    return nb


def xor_encode(planes: List[np.ndarray]) -> List[np.ndarray]:
    """enc_k = b_k ^ b_{k+1} ^ b_{k+2} (more-significant planes are prefix)."""
    nb = len(planes)
    out = []
    for k in range(nb):
        e = planes[k]
        if k + 1 < nb:
            e = e ^ planes[k + 1]
        if k + 2 < nb:
            e = e ^ planes[k + 2]
        out.append(e)
    return out


def xor_decode_plane(enc_k: np.ndarray, b_k1: Optional[np.ndarray],
                     b_k2: Optional[np.ndarray]) -> np.ndarray:
    """Decode plane k given already-loaded planes k+1, k+2 (None if absent)."""
    b = enc_k
    if b_k1 is not None:
        b = b ^ b_k1
    if b_k2 is not None:
        b = b ^ b_k2
    return b


def compress_plane(bits: np.ndarray) -> bytes:
    """Pack a 0/1 uint8 array and zlib it. All-zero planes compress to b''."""
    if bits.size == 0 or not bits.any():
        return b""
    return zlib.compress(np.packbits(bits).tobytes(), zlib_level())


def decompress_plane(blob: bytes, n: int) -> np.ndarray:
    if not blob:
        return np.zeros(n, np.uint8)
    raw = np.frombuffer(inflate(blob), np.uint8)
    return np.unpackbits(raw, count=n)


def encode_level(nb: np.ndarray) -> Tuple[List[bytes], int]:
    """negabinary ints -> (blobs MSB-first, nbits). blobs[i] is plane nbits-1-i."""
    nbits = int(nb.max()).bit_length() if nb.size else 0
    if nbits == 0:
        return [], 0
    planes = split_planes(nb, nbits)
    enc = xor_encode(planes)
    blobs = [compress_plane(enc[k]) for k in range(nbits - 1, -1, -1)]
    return blobs, nbits


def blobs_from_packed(packed: np.ndarray, n: int) -> Tuple[List[bytes], int]:
    """Pre-packed XOR-coded plane words -> (blobs MSB-first, nbits).

    ``packed`` is the (32, R, W) uint32 output of the ``bitplane_pack``
    Pallas kernel *for 1-D input*: plane k = bit k of the XOR-encoded
    negabinary word, each uint32 covering 32 consecutive elements with
    element 0 at the MSB — the same bit order ``np.packbits`` emits.  Only
    the first ``n`` elements are real; the 1-D wrapper appends its pad at
    the END of the flat stream and pad words are all-zero (q=0 -> nb=0 ->
    enc=0), so truncating the big-endian byte stream to ceil(n/8) bytes
    reproduces ``compress_plane``'s output byte-for-byte.  (The wrapper's
    2-D path pads columns mid-stream instead — callers must flatten first,
    as ``jax_backend.encode_level`` does.)  Both backends therefore write
    one archive format, and a mixed read path cannot exist.
    """
    occupied = [bool(packed[k].any()) for k in range(packed.shape[0])]
    nbits = max((k + 1 for k, nz in enumerate(occupied) if nz), default=0)
    if nbits == 0:
        return [], 0
    nbytes = (n + 7) // 8
    blobs = []
    for k in range(nbits - 1, -1, -1):
        if not occupied[k]:
            blobs.append(b"")  # all-zero plane: same convention as compress_plane
            continue
        raw = packed[k].astype(">u4").tobytes()[:nbytes]
        blobs.append(zlib.compress(raw, zlib_level()))
    return blobs, nbits


def decode_level(blobs: List[Optional[bytes]], nbits: int, n: int) -> np.ndarray:
    """Prefix of MSB-first blobs (None = not loaded) -> truncated negabinary."""
    planes: List[Optional[np.ndarray]] = [None] * nbits
    for i, blob in enumerate(blobs):
        k = nbits - 1 - i
        if blob is None:
            break  # prefix property: once a plane is missing, rest are too
        enc_k = decompress_plane(blob, n)
        planes[k] = xor_decode_plane(
            enc_k,
            planes[k + 1] if k + 1 < nbits else None,
            planes[k + 2] if k + 2 < nbits else None,
        )
    return join_planes(planes, n)
