"""IPComp core: interpolation-based progressive error-bounded lossy compression.

Public API:
    compress(x, eb, interp)            -> archive bytes
    decompress(buf)                    -> full-precision array
    retrieve(buf, error_bound=|max_bytes=|bitrate=) -> (array, RetrievalState)
    retrieve(reader, ..., state=state) -> incremental refinement (Algorithm 2)
"""
from .ipcomp import compress, decompress, retrieve, open_archive, RetrievalState
from .interpolation import LINEAR, CUBIC
from . import metrics

__all__ = ["compress", "decompress", "retrieve", "open_archive",
           "RetrievalState", "LINEAR", "CUBIC", "metrics"]
