"""IPComp core: interpolation-based progressive error-bounded lossy compression.

Public API:
    compress(x, eb, interp, backend="numpy"|"jax"|"auto" (jax on TPU),
             chunk_elems=None)         -> archive bytes (v1; v2 if chunked)
    decompress(buf)                    -> full-precision array
    retrieve(buf, error_bound=|max_bytes=|bitrate=) -> (array, RetrievalState)
    retrieve(reader, ..., state=state) -> incremental refinement (Algorithm 2)

The "jax" backend runs the predict+quantize and bitplane-packing hot loops
through the Pallas kernels (interpret mode on CPU) and emits archives
byte-identical to the numpy reference; see ``jax_backend``.
"""
from .ipcomp import (compress, decompress, retrieve, open_archive,
                     RetrievalState, ChunkedRetrievalState, chunk_bounds)
from .interpolation import LINEAR, CUBIC
from . import jax_backend, metrics

__all__ = ["compress", "decompress", "retrieve", "open_archive",
           "RetrievalState", "ChunkedRetrievalState", "chunk_bounds",
           "LINEAR", "CUBIC", "jax_backend", "metrics"]
