"""IPComp core: interpolation-based progressive error-bounded lossy compression.

The supported public surface is the object API in :mod:`repro.api`
(``Codec`` / ``Archive`` / ``Fidelity`` / ``ExecPolicy`` /
``ProgressiveReader``); what this package exports directly is the legacy
free-function generation, kept as compatibility shims:

    compress(x, eb, interp, backend="numpy"|"jax"|"auto" (jax on TPU),
             chunk_elems=None)         -> archive bytes (v1; v2 if chunked)
    decompress(buf, backend=...)       -> full-precision array
    retrieve(buf, error_bound=|max_bytes=|bitrate=, backend=...)
                                       -> (array, RetrievalState)
    retrieve(reader, ..., state=state) -> incremental refinement (Algorithm 2)
    refine(state, error_bound=..., backend=...) -> same, as a first-class call

Each shim delegates to the policy-native pipeline entries
(``pipeline.encode.encode_array`` / ``pipeline.decode.read_archive``)
with unchanged behavior, bytes, and bits, and emits one
``IPCompDeprecationWarning`` per call.

Both directions are backend-pluggable (see ``pipeline.backends``): the
"jax" backend runs the predict+quantize / predict+reconstruct sweeps and
the bitplane pack/unpack through the Pallas kernels (interpret mode on
CPU), emitting archives byte-identical — and reconstructions bit-identical
— to the numpy reference.  Chunked (v2) archives are scheduled in
equal-shape groups and, where the backend ships batched primitives, each
group runs through ``jax.vmap``-ed kernel launches (``batch_chunks=``
opts out); ``shard="auto"``/a 1-D mesh additionally splits each group
across local devices via shard_map (``parallel.codec_mesh``).  Bytes and
bits never depend on the execution mode — see docs/format.md and
docs/architecture.md.
"""
from .ipcomp import (compress, decompress, retrieve, refine, open_archive,
                     RetrievalState, ChunkedRetrievalState, chunk_bounds,
                     Fidelity, ExecPolicy, IPCompDeprecationWarning)
from .container import CorruptArchiveError
from .interpolation import LINEAR, CUBIC
from . import jax_backend, metrics, pipeline

__all__ = ["compress", "decompress", "retrieve", "refine", "open_archive",
           "RetrievalState", "ChunkedRetrievalState", "chunk_bounds",
           "Fidelity", "ExecPolicy", "IPCompDeprecationWarning",
           "CorruptArchiveError",
           "LINEAR", "CUBIC", "jax_backend", "metrics", "pipeline"]
