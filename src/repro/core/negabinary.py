"""Negabinary (base -2) integer coding (paper §4.4.2).

Negabinary needs no separate sign bit and keeps high-order bitplanes sparse
for values fluctuating around zero:  1 -> ...0001, -1 -> ...0011 (vs two's
complement ...1111).  Truncating d low digits yields uncertainty ~(2/3)*2^d,
vs 2^d - 1 for sign-magnitude (paper's uncertainty formulas).

Conversion uses the classic O(1) trick with M = 0xAAAAAAAA (bits at the
negative powers of -2):   nb = (x + M) ^ M,   x = (nb ^ M) - M   (mod 2^32).
"""
from __future__ import annotations

import numpy as np

_M = np.uint32(0xAAAAAAAA)


def to_negabinary(q: np.ndarray) -> np.ndarray:
    """int64 (two's-complement range of int32) -> uint32 negabinary digits."""
    u = q.astype(np.int64).astype(np.uint32)  # modular wrap = two's complement
    return (u + _M) ^ _M


def from_negabinary(nb: np.ndarray) -> np.ndarray:
    """uint32 negabinary digits -> int64 value."""
    u = (nb.astype(np.uint32) ^ _M) - _M  # modular wrap
    return u.view(np.int32).astype(np.int64)


def truncate(nb: np.ndarray, discard_bits: int) -> np.ndarray:
    """Zero the ``discard_bits`` least-significant negabinary digits."""
    if discard_bits <= 0:
        return nb
    if discard_bits >= 32:
        return np.zeros_like(nb)
    mask = np.uint32(0xFFFFFFFF) << np.uint32(discard_bits)
    return nb & mask


def truncation_loss_table(nb: np.ndarray, nbits: int, eb: float) -> np.ndarray:
    """delta_y_l(b) for b = 0..nbits: exact max |value - truncated value| * 2eb.

    Pre-computed during compression (paper Thm. 1: "its value can be
    pre-computed during compression"); drives the DP loader.
    """
    vals = from_negabinary(nb)
    out = np.zeros(nbits + 1, np.float64)
    for b in range(1, nbits + 1):
        tv = from_negabinary(truncate(nb, b))
        out[b] = float(np.max(np.abs(vals - tv))) * 2.0 * eb if nb.size else 0.0
    return out
