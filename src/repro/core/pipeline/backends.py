"""Codec backend registry: the numpy reference and the jax/Pallas kernels.

Replaces the ad-hoc ``if bk == jax_backend.JAX:`` string checks that used to
live inside ``ipcomp``: each :class:`CodecBackend` bundles the four hot-path
primitives both directions of the codec need, ``encode.py`` / ``decode.py``
call through the resolved backend object, and neither ever tests a backend
name again.  Registering a third backend (a future GPU path, a vmapped
chunk-batch path, ...) is one :func:`register` call — the pipeline code does
not change.

Primitive contracts (all bit-identical across backends — the parity test
suites pin this down):

  decorrelate(x_f64, eb, interp) -> (xhat, qs, escs, anchors)
      compression-side sweep: per-level int64 bin streams + escape records
      with level-global indices (see ``interpolation.decorrelate``).
  encode_level(q_int64, nb_uint32) -> (blobs MSB-first, nbits)
      negabinary + XOR-predictive bitplane packing of one level stream;
      both representations of the same values are passed so each substrate
      starts from whichever it prefers (numpy from the host-precomputed
      negabinary words, the kernel from the raw bins it converts on-device)
      without a redundant O(n) conversion.
  decode_level(blobs, nbits, n) -> uint32 truncated negabinary
      inverse of encode_level for a loaded MSB-first blob prefix
      (None = not loaded; b'' = loaded, all-zero encoded plane).
  reconstruct(shape, interp, anchors, yhat_per_level, overrides=, out_dtype=)
      decompression-side sweep (Algorithm 1 core); linear in (anchors,
      yhat), which Algorithm 2's zero-anchor delta cascade relies on.

Each primitive may also ship an OPTIONAL batched twin (``*_batch``) that
processes a stack of equal-shaped chunk problems in one kernel dispatch —
the unit the v2 chunk scheduler feeds (see ``encode``/``decode`` shape-group
scheduling and ``docs/architecture.md`` for the full dataflow):

  decorrelate_batch(xs_f64 (B, *shape), eb, interp) -> B-list of the
      scalar tuples;
  encode_level_batch(q2 (B, n), nb2 (B, n)) -> B-list of (blobs, nbits);
  decode_level_batch(B blob-prefix lists w/ equal nbits AND equal loaded
      prefix, nbits, n) -> B-list of truncated negabinary arrays;
  reconstruct_batch(shape, interp, anchors (B, ...), yhat [(B, n_l)],
      overrides=per-item list, out_dtype=) -> (B, *shape).

And each batched twin may ship an OPTIONAL *sharded* twin (``*_sharded``)
— identical contract plus one trailing required argument, a 1-D device
mesh (``parallel.codec_mesh``), over which the stack axis is split so
every mesh device executes the batched primitive on its local chunks:

  decorrelate_sharded(xs, eb, interp, mesh)        -> as decorrelate_batch
  encode_level_sharded(q2, nb2, mesh)              -> as encode_level_batch
  decode_level_sharded(blob_lists, nbits, n, mesh) -> as decode_level_batch
  reconstruct_sharded(shape, interp, anchors, yhat, mesh, overrides=,
      out_dtype=)                                  -> as reconstruct_batch

Decode-side FUSED slots (all optional, adopted by the progressive session
scheduler in ``pipeline/state.py`` when present):

  inflate_level(blobs, nbits, n) -> ((32, ceil(n/32)) uint32 words, want)
      host-side zlib inflate + word packing of one level's loaded blob
      prefix — the CPU half the scheduler can overlap with device work;
  inflate_level_batch(blob_lists, nbits, n) -> ((B, 32, nw) words, wants)
  decode_level_fused(blobs, nbits, n, nb_old, eb, words=) ->
      (nb_new uint32, delta f64): ONE launch fusing plane-unpack +
      negabinary dequantize + the Algorithm 2 delta against the session's
      previous truncation ``nb_old`` (delta = (q_new - q_old) * 2 * eb,
      bit-identical to the host spelling); ``words=`` accepts a prefetched
      ``inflate_level`` result so the zlib work can run ahead of time;
  decode_level_fused_batch(blob_lists, nbits, n, nb_olds, ebs, words=)
      -> B-list of (nb_new, delta) with PER-CHUNK loaded prefixes and
      per-chunk error bounds (mixed prefixes in one dispatch);
  decode_level_fused_sharded(..., mesh=) — same over the 1-D codec mesh.

``dynamic_low_zero=True`` declares that the batched decode paths accept
*mixed* loaded-plane prefixes in one dispatch (the truncation mask is a
runtime operand, not a trace constant) — the scheduler then groups chunk
jobs by ``(nbits,)`` instead of ``(nbits, prefix)``, collapsing what used
to be one dispatch per distinct prefix into one per level.

``None`` slots mean "no batched/sharded form": the pipeline falls back to
the next-simpler execution (sharded -> batched -> per-chunk loop over the
scalar primitive), so the numpy reference needs no batch code and
third-party backends can adopt batching/sharding incrementally.  The
capability properties (:attr:`CodecBackend.batches_encode` /
``batches_decode`` / ``shards_encode`` / ``shards_decode``) are what the
schedulers consult — pipeline code never tests a backend name.  Batched
AND sharded results must be bit-identical to the loop: the batch axis and
the mesh are execution details, never a format change (the chunk-batching
and sharded-codec test suites pin this).

Selection: ``"numpy"`` | ``"jax"`` | ``"jax_unfused"`` | ``"auto"``/None.
"auto" picks jax only where the kernels actually compile (TPU); on GPU/CPU
they would run in the (slow) Pallas interpreter — valid for parity testing,
so request it explicitly with ``backend="jax"`` rather than have "auto"
silently emulate.  ``"jax_unfused"`` is the pre-fusion jax path (per-phase
reconstruction, per-prefix decode grouping, no fused decode slots), kept
registered as the benchmark baseline the fused path is measured against.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .. import bitplane, interpolation, jax_backend, negabinary, quantize
# single source for the backend-name constants (the reverse import would be
# circular: jax_backend.resolve delegates here function-locally)
from ..jax_backend import AUTO, JAX, JAX_UNFUSED, NUMPY


@dataclass(frozen=True)
class CodecBackend:
    """The four codec primitives one execution substrate provides, plus
    optional batched twins over stacks of equal-shaped chunk problems and
    optional sharded twins over (stack, 1-D device mesh) — None slots mean
    the pipeline falls back to the next-simpler execution (sharded ->
    batched -> per-chunk scalar loop)."""
    name: str
    decorrelate: Callable
    encode_level: Callable
    decode_level: Callable
    reconstruct: Callable
    decorrelate_batch: Optional[Callable] = None
    encode_level_batch: Optional[Callable] = None
    decode_level_batch: Optional[Callable] = None
    reconstruct_batch: Optional[Callable] = None
    decorrelate_sharded: Optional[Callable] = None
    encode_level_sharded: Optional[Callable] = None
    decode_level_sharded: Optional[Callable] = None
    reconstruct_sharded: Optional[Callable] = None
    # fused decode megakernel family (see module docstring): one launch per
    # level fusing plane-unpack + dequantize + the Algorithm 2 delta, plus
    # the host-side inflate half the scheduler overlaps with device work
    decode_level_fused: Optional[Callable] = None
    decode_level_fused_batch: Optional[Callable] = None
    decode_level_fused_sharded: Optional[Callable] = None
    inflate_level: Optional[Callable] = None
    inflate_level_batch: Optional[Callable] = None
    #: batched decode accepts mixed loaded-plane prefixes in one dispatch
    #: (truncation mask is a runtime operand) -> scheduler groups by
    #: ``(nbits,)`` instead of ``(nbits, prefix)``
    dynamic_low_zero: bool = False

    @property
    def batches_encode(self) -> bool:
        return (self.decorrelate_batch is not None
                and self.encode_level_batch is not None)

    @property
    def batches_decode(self) -> bool:
        return (self.decode_level_batch is not None
                and self.reconstruct_batch is not None)

    @property
    def shards_encode(self) -> bool:
        return (self.decorrelate_sharded is not None
                and self.encode_level_sharded is not None)

    @property
    def shards_decode(self) -> bool:
        return (self.decode_level_sharded is not None
                and self.reconstruct_sharded is not None)


_REGISTRY: Dict[str, CodecBackend] = {}


def register(backend: CodecBackend) -> CodecBackend:
    """Add (or replace) a backend under ``backend.name``."""
    _REGISTRY[backend.name] = backend
    return backend


def names() -> List[str]:
    return sorted(_REGISTRY)


def resolve_name(choice) -> str:
    """Map a user-facing backend choice to a registered backend name.

    "auto"/None picks jax only where the kernels compile to native code
    (TPU); everywhere else the numpy reference wins on speed.
    """
    if choice in (None, AUTO):
        import jax
        return JAX if jax.default_backend() == "tpu" else NUMPY
    if choice not in _REGISTRY:
        opts = "|".join(names() + [AUTO])
        raise ValueError(f"unknown backend {choice!r}; use {opts}")
    return choice


def get(choice) -> CodecBackend:
    """Resolve a backend choice ("numpy" | "jax" | "auto"/None) to its
    registered :class:`CodecBackend`."""
    return _REGISTRY[resolve_name(choice)]


# ---------------------------------------------------------- numpy reference

def _numpy_decorrelate(x: np.ndarray, eb: float, interp: str):
    """Reference sweep: ``interpolation.decorrelate`` with the linear-scale
    quantizer + lossless escape channel (paper §4.2)."""

    def quantizer(res: np.ndarray, tvals: np.ndarray):
        q = quantize.quantize(res, eb)
        esc = quantize.escape_mask(q)
        recon = quantize.dequantize(q, eb)
        if esc.any():
            flat = np.flatnonzero(esc.ravel())
            vals = tvals.ravel()[flat].astype(np.float64)  # absolute values
            q.ravel()[flat] = 0
            return q, recon, (flat, vals)
        return q, recon, (np.zeros(0, np.int64), np.zeros(0, np.float64))

    return interpolation.decorrelate(x, eb, interp, quantizer)


def _numpy_encode_level(q: np.ndarray, nb: np.ndarray) -> Tuple[List[bytes], int]:
    return bitplane.encode_level(nb)


def _jax_encode_level(q: np.ndarray, nb: np.ndarray) -> Tuple[List[bytes], int]:
    return jax_backend.encode_level(q)


def _jax_encode_level_batch(q2: np.ndarray, nb2: np.ndarray,
                            ) -> List[Tuple[List[bytes], int]]:
    return jax_backend.encode_level_batch(q2)


def _jax_encode_level_sharded(q2: np.ndarray, nb2: np.ndarray, mesh,
                              ) -> List[Tuple[List[bytes], int]]:
    return jax_backend.encode_level_sharded(q2, mesh)


register(CodecBackend(
    name=NUMPY,
    decorrelate=_numpy_decorrelate,
    encode_level=_numpy_encode_level,
    decode_level=bitplane.decode_level,
    reconstruct=interpolation.reconstruct,
    # no batch slots: the reference stays a per-chunk loop by construction
))

register(CodecBackend(
    name=JAX,
    decorrelate=jax_backend.decorrelate,
    encode_level=_jax_encode_level,
    decode_level=jax_backend.decode_level,
    reconstruct=jax_backend.reconstruct,
    decorrelate_batch=jax_backend.decorrelate_batch,
    encode_level_batch=_jax_encode_level_batch,
    decode_level_batch=jax_backend.decode_level_batch,
    reconstruct_batch=jax_backend.reconstruct_batch,
    decorrelate_sharded=jax_backend.decorrelate_sharded,
    encode_level_sharded=_jax_encode_level_sharded,
    decode_level_sharded=jax_backend.decode_level_sharded,
    reconstruct_sharded=jax_backend.reconstruct_sharded,
    decode_level_fused=jax_backend.decode_level_fused,
    decode_level_fused_batch=jax_backend.decode_level_fused_batch,
    decode_level_fused_sharded=jax_backend.decode_level_fused_sharded,
    inflate_level=jax_backend.inflate_level,
    inflate_level_batch=jax_backend.inflate_level_batch,
    dynamic_low_zero=True,
))

# the pre-fusion jax path: identical encode side and archives, but decode
# runs the separate unpack / host-dequantize / per-phase recon pipeline with
# per-prefix dispatch grouping.  Kept registered (and so selectable through
# ExecPolicy) as the measured baseline for the fused megakernel benchmarks.
register(CodecBackend(
    name=JAX_UNFUSED,
    decorrelate=jax_backend.decorrelate,
    encode_level=_jax_encode_level,
    decode_level=jax_backend.decode_level,
    reconstruct=jax_backend.reconstruct_unfused,
    decorrelate_batch=jax_backend.decorrelate_batch,
    encode_level_batch=_jax_encode_level_batch,
    decode_level_batch=jax_backend.decode_level_batch,
    reconstruct_batch=jax_backend.reconstruct_batch_unfused,
    decorrelate_sharded=jax_backend.decorrelate_sharded,
    encode_level_sharded=_jax_encode_level_sharded,
    decode_level_sharded=jax_backend.decode_level_sharded,
    reconstruct_sharded=jax_backend.reconstruct_sharded_unfused,
))
