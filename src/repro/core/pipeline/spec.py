"""Typed specification objects for the codec pipeline: Fidelity + ExecPolicy.

These two small value types are the vocabulary of the first-class API
(``repro.api``) and the *native* currency of the pipeline internals —
``encode.py`` / ``decode.py`` / ``state.py`` accept them directly instead
of re-threading ``backend=`` / ``batch_chunks=`` / ``shard=`` kwargs and
the mutually-exclusive retrieval-target trio through every call:

:class:`Fidelity`
    A sum type over the four retrieval targets the DP loader (paper §5)
    plans for — ``error_bound`` / ``max_bytes`` / ``bitrate`` / ``full``.
    Exactly one alternative exists per instance, so the historical
    over-specification bug class ("pass two targets, one silently wins")
    is unrepresentable; the legacy kwarg trio is coerced through
    :meth:`Fidelity.from_targets`, which raises on over-specification.

:class:`ExecPolicy`
    The bits-invariant execution knobs — ``backend``, ``batch_chunks``,
    ``shard`` — validated ONCE at construction instead of per call.  The
    structural guarantee (pinned by ``tests/test_policy_matrix.py``): no
    policy ever changes archive bytes or reconstruction bits; policies
    select *how* the same work runs, never *what* it computes.  The
    ``shard=`` resolution rules that used to live in
    ``encode.resolve_exec_mesh`` live here (:func:`resolve_exec_mesh` /
    :meth:`ExecPolicy.resolve_mesh`).

:class:`ExecContext`
    An :class:`ExecPolicy` bound for one call — resolved
    :class:`~.backends.CodecBackend`, resolved mesh (or None), and the
    batching decision for each codec direction.  This is what the
    shape-group schedulers and the ``state.py`` batch helpers consume.

:class:`IPCompDeprecationWarning` is the category every legacy free
function (``compress`` / ``retrieve`` / ``refine`` / ``decompress``)
emits exactly once per call; the CI deprecation lane runs the new-API
suites with ``-W error::repro.api.IPCompDeprecationWarning`` to prove the
object API never routes through a shim.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, replace
from typing import Any, Optional

from . import backends


class IPCompDeprecationWarning(DeprecationWarning):
    """Category for the legacy free-function shims (``compress`` /
    ``retrieve`` / ``refine`` / ``decompress``).  Each shim emits exactly
    one of these per call; the new object API emits none — the CI
    deprecation lane pins both."""


def warn_legacy(old: str, new: str) -> None:
    """One deprecation warning per legacy entry-point call.

    ``stacklevel=3`` points at the *caller* of the legacy function (shim
    body -> legacy function -> caller)."""
    warnings.warn(f"{old} is a compatibility shim; use {new} "
                  "(see repro.api)", IPCompDeprecationWarning, stacklevel=3)


# ------------------------------------------------------------------ Fidelity

#: the four Fidelity alternatives
FULL = "full"
ERROR_BOUND = "error_bound"
MAX_BYTES = "max_bytes"
BITRATE = "bitrate"

_KINDS = (FULL, ERROR_BOUND, MAX_BYTES, BITRATE)


@dataclass(frozen=True)
class Fidelity:
    """One retrieval target: what a progressive read must achieve.

    A sum type — construct through the named alternatives, never by
    juggling mutually-exclusive kwargs::

        Fidelity.error_bound(1e-4)   # point-wise L_inf bound
        Fidelity.max_bytes(1 << 20)  # retrieval-volume budget (data bytes)
        Fidelity.bitrate(2.0)        # bits per point, = max_bytes(b*n/8)
        Fidelity.full()              # every plane: error <= eb everywhere

    The DP loader plans the minimum plane set for the target
    (``loader.plan_error_mode`` / ``plan_bitrate_mode`` / ``plan_full``);
    byte-denominated targets convert through :meth:`target_bytes`.
    Instances are frozen, hashable, and safe to reuse across archives.
    """
    kind: str
    value: Optional[float] = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fidelity kind {self.kind!r}; "
                             f"use one of {'/'.join(_KINDS)}")
        if self.kind == FULL:
            if self.value is not None:
                raise ValueError("Fidelity.full() carries no value")
            return
        if self.value is None:
            raise ValueError(f"Fidelity kind {self.kind!r} needs a value")
        v = float(self.value)
        if self.kind == MAX_BYTES:
            if v < 0 or v != int(v):
                raise ValueError("max_bytes must be a non-negative integer "
                                 f"byte count, got {self.value!r}")
            object.__setattr__(self, "value", int(v))  # normalize 64.0 -> 64
        elif v <= 0:
            raise ValueError(f"{self.kind} must be positive, "
                             f"got {self.value!r}")

    # ---- named constructors (the canonical spelling)

    @classmethod
    def error_bound(cls, eb: float) -> "Fidelity":
        """Target a point-wise L_inf error bound."""
        return cls(ERROR_BOUND, float(eb))

    @classmethod
    def max_bytes(cls, n: int) -> "Fidelity":
        """Target a retrieval-volume budget in data bytes (validation in
        ``__post_init__`` — a fractional byte count raises rather than
        silently truncating)."""
        return cls(MAX_BYTES, n)

    @classmethod
    def bitrate(cls, bits_per_point: float) -> "Fidelity":
        """Target a loaded bitrate in bits per point."""
        return cls(BITRATE, float(bits_per_point))

    @classmethod
    def full(cls) -> "Fidelity":
        """Full precision: load every plane (error <= eb everywhere)."""
        return cls(FULL)

    @classmethod
    def from_targets(cls, error_bound: Optional[float] = None,
                     max_bytes: Optional[int] = None,
                     bitrate: Optional[float] = None) -> "Fidelity":
        """Coerce the legacy kwarg trio; over-specification raises.

        This is the one place the historical "exactly one of" contract is
        policed — the message matches the old ``_check_one_target`` so
        callers (and tests) pinned to it keep working.  ``max_bytes`` is
        floored like the old code path tolerated (the legacy planner took
        float budgets); only the canonical :meth:`max_bytes` constructor
        rejects fractional byte counts.
        """
        given = [name for name, v in ((ERROR_BOUND, error_bound),
                                      (MAX_BYTES, max_bytes),
                                      (BITRATE, bitrate)) if v is not None]
        if len(given) > 1:
            raise ValueError("pass at most one of error_bound/max_bytes/"
                             f"bitrate (got {', '.join(given)})")
        if error_bound is not None:
            return cls.error_bound(error_bound)
        if max_bytes is not None:
            return cls.max_bytes(int(max_bytes))
        if bitrate is not None:
            return cls.bitrate(bitrate)
        return cls.full()

    # ---- planning helpers

    def target_bytes(self, n_elements: int) -> Optional[int]:
        """Byte budget for byte-denominated targets, else None.

        ``bitrate`` converts exactly as the legacy path did:
        ``int(bits_per_point * n / 8)``.
        """
        if self.kind == MAX_BYTES:
            return int(self.value)
        if self.kind == BITRATE:
            return int(self.value * n_elements / 8)
        return None

    def __repr__(self) -> str:
        if self.kind == FULL:
            return "Fidelity.full()"
        v = int(self.value) if self.kind == MAX_BYTES else self.value
        return f"Fidelity.{self.kind}({v!r})"


# ---------------------------------------------------------------- ExecPolicy

def resolve_exec_mesh(shard, backend_shards: bool, *, chunked: bool,
                      batch_chunks: Optional[bool]):
    """``shard=`` policy shared by both codec directions -> mesh or None.

    Delegates mesh resolution to ``parallel.codec_mesh.resolve_shard``
    ("auto" -> all local devices when >1, Mesh -> validated 1-D), then
    applies the pipeline rules: sharding needs a chunk grid and the
    stacked scheduler, so an *explicit* mesh combined with an unchunked
    archive or ``batch_chunks=False`` is a contradiction and raises, while
    ``"auto"`` quietly stays unsharded in those cases.  A backend without
    sharded primitives (the numpy reference) always falls back to its
    unsharded path — mirroring how missing ``*_batch`` slots fall back to
    the per-chunk loop.
    """
    if shard is None or shard is False:
        return None
    from ...parallel import codec_mesh

    mesh = codec_mesh.resolve_shard(shard)
    if mesh is None:
        return None
    explicit = shard != codec_mesh.AUTO
    if not chunked:
        if explicit:
            raise ValueError("sharded execution runs over the chunk grid: "
                             "pass chunk_elems= (v1 archives have no "
                             "chunks to place on the mesh)")
        return None
    if batch_chunks is False:
        if explicit:
            raise ValueError("shard= needs the stacked shape-group "
                             "scheduler; it cannot be combined with "
                             "batch_chunks=False")
        return None
    return mesh if backend_shards else None


@dataclass(frozen=True)
class ExecPolicy:
    """How the codec executes — never what it computes.

    Bundles the three bits-invariant execution knobs:

    ``backend``
        "numpy" | "jax" | "auto"/None ("auto" = jax only where the Pallas
        kernels compile natively, i.e. TPU).
    ``batch_chunks``
        Equal-shape chunk batching for v2 archives: None/True = batch when
        the backend ships batched primitives, False = per-chunk loop.
    ``shard``
        None | "auto" | an explicit 1-D ``jax.sharding.Mesh`` — the chunk
        grid is split across the mesh and each device runs its local
        shard.  "auto" degrades quietly (no mesh on a single device, no
        mesh for v1 archives); an explicit mesh is a hard request and
        raises where it cannot apply.

    Validation happens ONCE here: unknown backends, malformed ``shard``
    values, and the explicit-mesh + ``batch_chunks=False`` contradiction
    all raise at construction.  Only the archive-dependent rule (an
    explicit mesh needs a chunk grid) waits for :meth:`bind`, because it
    depends on what is being read or written.

    The structural guarantee — enforced by the pipeline design (per-chunk
    metadata, escapes and accounting are always derived per chunk on the
    host) and pinned by the policy-invariance matrix — is that **no policy
    changes archive bytes or reconstruction bits**.  Writer and readers
    may therefore use different policies freely, including mid-session.
    """
    backend: Optional[str] = "numpy"
    batch_chunks: Optional[bool] = None
    shard: Any = None

    def __post_init__(self):
        if self.backend not in (None, backends.AUTO):
            backends.resolve_name(self.backend)  # raises on unknown names
        if self.batch_chunks not in (None, True, False):
            raise ValueError("batch_chunks must be None, True or False, "
                             f"got {self.batch_chunks!r}")
        if self.shard is not None and self.shard is not False:
            from ...parallel import codec_mesh
            if self.shard != codec_mesh.AUTO:
                codec_mesh.resolve_shard(self.shard)  # form + 1-D check
                if self.batch_chunks is False:
                    raise ValueError("shard= needs the stacked shape-group "
                                     "scheduler; it cannot be combined "
                                     "with batch_chunks=False")

    def resolve_mesh(self, backend_shards: bool, *, chunked: bool):
        """Apply the ``shard=`` rules for one call (see
        :func:`resolve_exec_mesh`)."""
        return resolve_exec_mesh(self.shard, backend_shards,
                                 chunked=chunked,
                                 batch_chunks=self.batch_chunks)

    def bind(self, *, chunked: bool, encode: bool) -> "ExecContext":
        """Resolve this policy for one call -> :class:`ExecContext`.

        ``chunked`` is the archive's property (v2 chunk grid or not);
        ``encode`` picks which direction's sharded capability gates the
        mesh.  Raises where an explicit mesh cannot apply (v1 archive).
        """
        bk = backends.get(self.backend)
        shards = bk.shards_encode if encode else bk.shards_decode
        mesh = self.resolve_mesh(shards, chunked=chunked)
        return ExecContext(bk=bk, mesh=mesh, batch_chunks=self.batch_chunks)

    def unsharded(self) -> "ExecPolicy":
        """This policy without the mesh (per-chunk scalar sub-calls)."""
        return replace(self, shard=None) if self.shard is not None else self


@dataclass(frozen=True)
class ExecContext:
    """An :class:`ExecPolicy` bound for one call: resolved backend,
    resolved mesh (or None), and the per-direction batching decision.
    This — not loose (bk, mesh) pairs — is what the shape-group
    schedulers and the ``state.py`` batch helpers consume."""
    bk: backends.CodecBackend
    mesh: Any = None
    batch_chunks: Optional[bool] = None

    @property
    def batch_encode(self) -> bool:
        """Schedule encode-side shape groups through the batched stack?"""
        return self.batch_chunks is not False and (
            self.bk.batches_encode or self.mesh is not None)

    @property
    def batch_decode(self) -> bool:
        """Schedule decode-side shape groups through the batched stack?"""
        return self.batch_chunks is not False and (
            self.bk.batches_decode or self.mesh is not None)


#: the default policy: numpy reference, batching decided by the backend,
#: no mesh.  Module-level singleton so hot paths need not rebuild it.
DEFAULT_POLICY = ExecPolicy()
