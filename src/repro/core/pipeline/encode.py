"""Compression side of the codec pipeline (paper Fig. 2, left to right).

  x --interpolation predictor--> residuals y_l --quantize--> q_l
    --negabinary--> nb_l --bitplanes + XOR predictive coding--> blobs
    --container--> archive bytes

The per-phase sweep and the per-level packing both go through the resolved
:class:`~.backends.CodecBackend` (numpy reference or Pallas kernels);
archives are byte-compatible, so the decode path never needs to know which
backend wrote them.

``chunk_elems=N`` splits the array into independent slabs of ~N elements
along axis 0 and frames the per-slab archives in a v2 container
(``container.write_chunked_archive``).  Chunking bounds compression working
memory, lets equal-shaped chunks share jit cache entries, and is the unit
of future vmapped/sharded encoding; v1 (unchunked) archives remain the
default and are always readable.
"""
from __future__ import annotations

import zlib
from typing import List, Optional, Tuple

import numpy as np

from .. import container, interpolation, negabinary
from . import backends


def compress(x: np.ndarray, eb: float, interp: str = interpolation.CUBIC,
             relative: bool = False, backend: Optional[str] = "numpy",
             chunk_elems: Optional[int] = None) -> bytes:
    """Compress ``x`` with point-wise error bound ``eb``.

    ``relative=True`` interprets eb as a fraction of the value range.
    ``backend`` is "numpy" | "jax" | "auto"/None (jax on TPU where the
    kernels compile, numpy elsewhere); both emit identical bytes.
    ``chunk_elems`` switches to the chunked v2 container with
    ~chunk_elems-sized independent slabs.
    """
    x = np.asarray(x)
    if relative:
        eb = eb * (float(x.max()) - float(x.min()) or 1.0)
    if eb <= 0:
        raise ValueError("error bound must be positive")
    bk = backends.get(backend)
    if chunk_elems is None:
        return _compress_single(x, eb, interp, bk)
    bounds = chunk_bounds(x.shape, chunk_elems)
    bufs = [_compress_single(x[a:b], eb, interp, bk) for a, b in bounds]
    return container.write_chunked_archive(x.shape, x.dtype, eb, interp,
                                           bounds, bufs)


def chunk_bounds(shape, chunk_elems: int) -> List[Tuple[int, int]]:
    """Split axis 0 into slabs of ~chunk_elems elements (>=1 row each)."""
    if chunk_elems <= 0:
        raise ValueError("chunk_elems must be positive")
    if len(shape) == 0:
        raise ValueError("chunked compression needs at least one axis; "
                         "got a 0-d array")
    if int(np.prod(shape)) == 0:
        raise ValueError("cannot chunk an empty array of shape "
                         f"{tuple(shape)}")
    row_elems = int(np.prod(shape[1:])) if len(shape) > 1 else 1
    rows = max(1, chunk_elems // max(row_elems, 1))
    return [(a, min(a + rows, shape[0])) for a in range(0, shape[0], rows)]


def _compress_single(x: np.ndarray, eb: float, interp: str,
                     bk: backends.CodecBackend) -> bytes:
    """One (chunk-sized) array -> one v1 archive, via the chosen backend."""
    shape, dtype = x.shape, x.dtype
    L = interpolation.num_levels(shape)
    _, qs, escs, anchors = bk.decorrelate(x.astype(np.float64), eb, interp)

    level_blobs, level_meta, esc_blobs = [], [], []
    for li in range(L):
        q = qs[li]
        nb = negabinary.to_negabinary(q)
        blobs, nbits = bk.encode_level(q, nb)
        delta = negabinary.truncation_loss_table(nb, nbits, eb)
        level_blobs.append(blobs)
        level_meta.append(dict(level=L - li, n=int(q.size), nbits=nbits,
                               delta_table=delta.tolist()))
        esc_blobs.append(_pack_escapes(escs[li]))
    return container.write_archive(shape, dtype, eb, interp, L, anchors,
                                   level_blobs, level_meta, esc_blobs)


def _pack_escapes(phase_escs) -> bytes:
    """Escape records (level-global flat idx, exact residuals) -> one blob."""
    idx_parts = [i for i, v in phase_escs if i.size]
    val_parts = [v for i, v in phase_escs if i.size]
    if not idx_parts:
        return b""
    idx = np.concatenate(idx_parts).astype(np.int64)
    val = np.concatenate(val_parts).astype(np.float64)
    raw = np.int64(idx.size).tobytes() + idx.tobytes() + val.tobytes()
    return zlib.compress(raw, 6)
