"""Compression side of the codec pipeline (paper Fig. 2, left to right).

  x --interpolation predictor--> residuals y_l --quantize--> q_l
    --negabinary--> nb_l --bitplanes + XOR predictive coding--> blobs
    --container--> archive bytes

The per-phase sweep and the per-level packing both go through the resolved
:class:`~.backends.CodecBackend` (numpy reference or Pallas kernels);
archives are byte-compatible, so the decode path never needs to know which
backend wrote them.

``chunk_elems=N`` splits the array into independent slabs of ~N elements
along axis 0 and frames the per-slab archives in a v2 container
(``container.write_chunked_archive``).  Chunking bounds compression working
memory and is the unit of batched execution: chunks are scheduled in
*shape groups* (every interior slab has the same shape; only the ragged
tail differs), and when the backend ships batched primitives
(``decorrelate_batch`` / ``encode_level_batch``), each group runs the
whole stack through ONE vmapped kernel dispatch per (level, dim) phase and
one per level for the bitplane pack — instead of one per chunk each.
Groups are capped at ``MAX_BATCH_CHUNKS`` chunks per stack, so batching
keeps the memory bound chunking exists to provide.  Archives are
byte-identical either way (``batch_chunks=False`` forces the per-chunk
loop; the parity tests pin the equivalence).  v1 (unchunked) archives
remain the default and are always readable.

``shard=`` lifts the same scheduler onto a device mesh: with a 1-D codec
mesh (``"auto"`` = all local devices when more than one; see
``parallel.codec_mesh`` and ``docs/architecture.md``), each shape group's
stacked slab is split across the mesh and every device runs the backend's
batched kernels on its local chunk shard — one collective-free logical
dispatch per (level, dim) phase for the whole grid.  The scheduler is
shard-aware in two places: the group cap scales to ``MAX_BATCH_CHUNKS x
mesh size`` (``MAX_BATCH_CHUNKS`` stays the *per-device* working-set
bound), and ragged groups are padded up to a mesh multiple at the sharded
kernel entry points (all-zero pad problems, outputs sliced off).  Sharding
never changes bytes: per-chunk metadata, escapes and blobs are still
derived per chunk on the host, so sharded archives are byte-identical to
single-device ones.
"""
from __future__ import annotations

import zlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .. import bitplane, container, interpolation, negabinary
from . import backends, spec
from .spec import ExecPolicy

# historical import site — tests and callers import the ``shard=`` policy
# from here; the logic itself lives with ExecPolicy in ``spec.py``
resolve_exec_mesh = spec.resolve_exec_mesh


def encode_array(x: np.ndarray, eb: float,
                 interp: str = interpolation.CUBIC, relative: bool = False,
                 chunk_elems: Optional[int] = None,
                 policy: Optional[ExecPolicy] = None,
                 version: Optional[int] = None) -> bytes:
    """Compress ``x`` with point-wise error bound ``eb`` (native entry).

    This is the policy-native encoder under ``repro.api.Codec.compress``:
    (eb, interp, relative, chunk_elems, version) are the *bytes-affecting*
    spec — the :class:`~.spec.ExecPolicy` only selects how the work
    executes (backend substrate, chunk batching, mesh sharding) and never
    changes the archive bytes.  ``relative=True`` interprets eb as a
    fraction of the value range.  ``chunk_elems`` switches to a chunked
    container with ~chunk_elems-sized independent slabs.

    ``version`` selects the container framing: 1 (plain v1, the unchunked
    default), 2 (chunk-major v2, the chunked default), or 3 (plane-major
    v3 — chunked compression laid out in retrieval-ladder order, see
    ``docs/format.md`` §3).  Compression itself is version-independent:
    v3 archives hold the exact per-chunk streams a v2 archive would,
    regrouped — only the byte layout (and thus the read access pattern)
    differs.  ``version=3`` without ``chunk_elems`` frames the whole
    array as one chunk.
    """
    policy = spec.DEFAULT_POLICY if policy is None else policy
    if version is None:
        version = 1 if chunk_elems is None else 2
    if version not in (1, 2, 3):
        raise ValueError(f"unknown container version {version!r}; "
                         "expected 1, 2 or 3")
    if version == 1 and chunk_elems is not None:
        raise ValueError("version=1 cannot hold chunks; "
                         "drop chunk_elems or use version 2 or 3")
    if version == 2 and chunk_elems is None:
        raise ValueError("version=2 is the chunked container; "
                         "pass chunk_elems (or use version=1)")
    x = np.asarray(x)
    if relative:
        eb = eb * (float(x.max()) - float(x.min()) or 1.0)
    if eb <= 0:
        raise ValueError("error bound must be positive")
    ctx = policy.bind(chunked=version != 1, encode=True)
    if version == 1:
        return _compress_single(x, eb, interp, ctx.bk)
    bounds = chunk_bounds(x.shape, chunk_elems if chunk_elems is not None
                          else max(1, int(x.size)))
    bufs: List[Optional[bytes]] = [None] * len(bounds)
    for idxs in shape_groups([b - a for a, b in bounds],
                             max_group=group_cap(ctx.mesh)):
        if ctx.batch_encode and len(idxs) > 1:
            xs = np.stack([x[bounds[i][0]: bounds[i][1]] for i in idxs])
            for i, buf in zip(idxs, _compress_batch(xs, eb, interp, ctx)):
                bufs[i] = buf
        else:
            for i in idxs:
                a, b = bounds[i]
                bufs[i] = _compress_single(x[a:b], eb, interp, ctx.bk)
    writer = (container.write_v3_archive if version == 3
              else container.write_chunked_archive)
    return writer(x.shape, x.dtype, eb, interp, bounds, bufs)


def compress(x: np.ndarray, eb: float, interp: str = interpolation.CUBIC,
             relative: bool = False, backend: Optional[str] = "numpy",
             chunk_elems: Optional[int] = None,
             batch_chunks: Optional[bool] = None,
             shard=None) -> bytes:
    """Legacy free function; shim over :func:`encode_array`.

    Prefer ``repro.api.Codec(eb, ...).compress(x, policy=ExecPolicy(...))``
    — the kwargs map 1:1: (eb, interp, relative, chunk_elems) are the
    :class:`~repro.api.Codec` spec, (backend, batch_chunks, shard) the
    :class:`~.spec.ExecPolicy`.  Behavior and bytes are unchanged.
    """
    spec.warn_legacy("compress()", "Codec(eb, ...).compress(x, policy=...)")
    return encode_array(x, eb, interp=interp, relative=relative,
                        chunk_elems=chunk_elems,
                        policy=ExecPolicy(backend=backend,
                                          batch_chunks=batch_chunks,
                                          shard=shard))


def group_cap(mesh) -> int:
    """Chunks per scheduled stack: ``MAX_BATCH_CHUNKS`` per device.

    Unsharded that is the plain batch cap; on a mesh the stack is split
    across ``n`` devices, so an ``n``-times-larger group still bounds each
    device's working set at ``MAX_BATCH_CHUNKS`` chunk problems.
    """
    if mesh is None:
        return MAX_BATCH_CHUNKS
    from ...parallel import codec_mesh

    return MAX_BATCH_CHUNKS * codec_mesh.shard_count(mesh)


def chunk_bounds(shape, chunk_elems: int) -> List[Tuple[int, int]]:
    """Split axis 0 into slabs of ~chunk_elems elements (>=1 row each)."""
    if chunk_elems <= 0:
        raise ValueError("chunk_elems must be positive")
    if len(shape) == 0:
        raise ValueError("chunked compression needs at least one axis; "
                         "got a 0-d array")
    if int(np.prod(shape)) == 0:
        raise ValueError("cannot chunk an empty array of shape "
                         f"{tuple(shape)}")
    row_elems = int(np.prod(shape[1:])) if len(shape) > 1 else 1
    rows = max(1, chunk_elems // max(row_elems, 1))
    return [(a, min(a + rows, shape[0])) for a in range(0, shape[0], rows)]


#: chunks stacked per batched dispatch.  Chunking exists to bound codec
#: working memory, and a batch materializes its whole group as one array —
#: so groups are split into runs of at most this many chunks: memory stays
#: O(MAX_BATCH_CHUNKS x chunk), while the dispatch count still drops by up
#: to that factor.
MAX_BATCH_CHUNKS = 16


def shape_groups(row_counts: Sequence[int],
                 max_group: Optional[int] = MAX_BATCH_CHUNKS,
                 ) -> List[List[int]]:
    """Chunk indices grouped by identical row count (= identical slab shape).

    ``chunk_bounds`` makes every interior slab the same height, so this is
    typically one big group plus a singleton ragged tail; grouping by the
    actual count keeps the scheduler correct for any bounds list.  Groups
    larger than ``max_group`` are split into consecutive runs so a batched
    executor never stacks more than that many chunks at once (None = no
    cap).  Groups keep first-occurrence order and indices stay ascending,
    so iteration order — and thus every side effect, e.g. reader byte
    accounting — is deterministic.
    """
    groups: dict = {}
    for i, rc in enumerate(row_counts):
        groups.setdefault(rc, []).append(i)
    if max_group is None:
        return list(groups.values())
    return [g[a: a + max_group] for g in groups.values()
            for a in range(0, len(g), max_group)]


def _compress_single(x: np.ndarray, eb: float, interp: str,
                     bk: backends.CodecBackend) -> bytes:
    """One (chunk-sized) array -> one v1 archive, via the chosen backend."""
    shape, dtype = x.shape, x.dtype
    L = interpolation.num_levels(shape)
    _, qs, escs, anchors = bk.decorrelate(x.astype(np.float64), eb, interp)

    level_blobs, level_meta, esc_blobs = [], [], []
    for li in range(L):
        q = qs[li]
        nb = negabinary.to_negabinary(q)
        blobs, nbits = bk.encode_level(q, nb)
        delta = negabinary.truncation_loss_table(nb, nbits, eb)
        level_blobs.append(blobs)
        level_meta.append(dict(level=L - li, n=int(q.size), nbits=nbits,
                               delta_table=delta.tolist()))
        esc_blobs.append(_pack_escapes(escs[li]))
    return container.write_archive(shape, dtype, eb, interp, L, anchors,
                                   level_blobs, level_meta, esc_blobs)


def _compress_batch(xs: np.ndarray, eb: float, interp: str,
                    ctx: spec.ExecContext) -> List[bytes]:
    """B equal-shape chunks (stacked on axis 0) -> B v1 archives.

    Exactly ``_compress_single`` per chunk, but the sweep and the per-level
    pack each run ONCE for the whole stack through the backend's batched
    primitives — or, with ``mesh``, through its *sharded* primitives, which
    split the stack across the mesh devices (each device then runs the
    batched kernels on its local chunk shard).  Per-chunk metadata (nbits,
    delta tables, escapes) is still derived from that chunk's own streams,
    so the archives are byte-identical to the per-chunk loop either way.
    """
    bk, mesh = ctx.bk, ctx.mesh
    B = xs.shape[0]
    shape, dtype = xs.shape[1:], xs.dtype
    L = interpolation.num_levels(shape)
    if mesh is not None:
        results = bk.decorrelate_sharded(xs.astype(np.float64), eb, interp,
                                         mesh)
    else:
        results = bk.decorrelate_batch(xs.astype(np.float64), eb, interp)

    blobs_pc: List[List[List[bytes]]] = [[] for _ in range(B)]
    meta_pc: List[List[dict]] = [[] for _ in range(B)]
    escb_pc: List[List[bytes]] = [[] for _ in range(B)]
    for li in range(L):
        q2 = np.stack([results[b][1][li] for b in range(B)])
        nb2 = negabinary.to_negabinary(q2)
        if mesh is not None:
            enc = bk.encode_level_sharded(q2, nb2, mesh)
        else:
            enc = bk.encode_level_batch(q2, nb2)
        for b in range(B):
            blobs, nbits = enc[b]
            delta = negabinary.truncation_loss_table(nb2[b], nbits, eb)
            blobs_pc[b].append(blobs)
            meta_pc[b].append(dict(level=L - li, n=int(q2.shape[1]),
                                   nbits=nbits, delta_table=delta.tolist()))
            escb_pc[b].append(_pack_escapes(results[b][2][li]))
    return [container.write_archive(shape, dtype, eb, interp, L,
                                    results[b][3], blobs_pc[b], meta_pc[b],
                                    escb_pc[b]) for b in range(B)]


def _pack_escapes(phase_escs) -> bytes:
    """Escape records (level-global flat idx, exact residuals) -> one blob."""
    idx_parts = [i for i, v in phase_escs if i.size]
    val_parts = [v for i, v in phase_escs if i.size]
    if not idx_parts:
        return b""
    idx = np.concatenate(idx_parts).astype(np.int64)
    val = np.concatenate(val_parts).astype(np.float64)
    raw = np.int64(idx.size).tobytes() + idx.tobytes() + val.tobytes()
    return zlib.compress(raw, bitplane.zlib_level())
