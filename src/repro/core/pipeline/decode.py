"""Retrieval side of the codec pipeline (paper §5, Algorithms 1–2).

The DP loader plans the minimum bitplane set for the requested error bound
/ bitrate; a single reconstruction pass produces the output (no multi-pass
residual decompression).  ``refine`` continues a previous retrieval: it
loads only the *additional* bitplanes and pushes a linear delta cascade on
top of the previous reconstruction (the state machinery lives in
``pipeline.state``).

Like the encode side, every hot step — plane decode and the reconstruction
sweep — goes through the resolved :class:`~.backends.CodecBackend`, so
``backend="jax"`` runs retrieval on the Pallas kernel pair
(``interp_recon`` + ``bitplane_unpack``) with bit-identical output to the
numpy reference; ``backend="auto"`` picks jax on TPU only.

For chunked (v2) archives every plan/refine step runs per chunk (a
per-chunk L_inf bound implies the global one) and ``bytes_read``
aggregates across chunks.  Byte/bitrate budgets are split across chunks
proportionally to element count by largest-remainder assignment
(:func:`split_budget`), so the total allocated budget equals the request
exactly — no silent remainder loss.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from .. import container, loader
from ..container import ArchiveReader, ChunkedArchiveReader
from . import backends
from .state import (ChunkedRetrievalState, RetrievalState, initial_state,
                    load_level_deltas, push_delta, update_achieved_bound)


def open_archive(buf: bytes):
    """Reader for any archive version (v1 plain / v2 chunked)."""
    return container.open_reader(buf)


def retrieve(buf_or_reader, error_bound: Optional[float] = None,
             max_bytes: Optional[int] = None,
             bitrate: Optional[float] = None,
             propagation: str = loader.SAFE,
             state: Optional[RetrievalState] = None,
             backend: Optional[str] = "numpy",
             ) -> Tuple[np.ndarray, RetrievalState]:
    """Single-pass progressive retrieval.

    Exactly one of (error_bound, max_bytes, bitrate) selects the plan; None
    of them = full-precision.  Pass ``state`` from a previous call to refine
    incrementally (Algorithm 2) — only missing bitplanes are fetched.
    ``backend`` selects the decode substrate ("numpy" | "jax" | "auto");
    every backend reconstructs bit-identical arrays, and the state is
    backend-agnostic, so successive calls may even switch backends.

    Accepts v1 and v2 (chunked) archives / readers transparently.
    """
    if isinstance(buf_or_reader, (ArchiveReader, ChunkedArchiveReader)):
        reader = buf_or_reader
    else:
        reader = container.open_reader(buf_or_reader)
    if isinstance(reader, ChunkedArchiveReader):
        return _retrieve_chunked(reader, error_bound, max_bytes, bitrate,
                                 propagation, state, backend)
    bk = backends.get(backend)
    m = reader.meta
    if bitrate is not None:
        max_bytes = int(bitrate * m.n_elements / 8)
    if error_bound is not None:
        plan = loader.plan_error_mode(m, error_bound, propagation)
    elif max_bytes is not None:
        plan = loader.plan_bitrate_mode(m, max_bytes, propagation)
    else:
        plan = loader.plan_full(m)

    if state is None:
        state = initial_state(reader, bk)
    delta_y, any_new = load_level_deltas(state, plan.keep_planes, bk)
    if any_new:
        push_delta(state, delta_y, bk)
    update_achieved_bound(state, propagation)
    out = state.xhat.astype(np.dtype(m.dtype))
    return out, state


def refine(state, error_bound: Optional[float] = None,
           max_bytes: Optional[int] = None,
           bitrate: Optional[float] = None,
           propagation: str = loader.SAFE,
           backend: Optional[str] = "numpy",
           ) -> Tuple[np.ndarray, RetrievalState]:
    """Algorithm 2 as a first-class call: continue a previous retrieval.

    ``refine(state, error_bound=E)`` is ``retrieve(state.reader, ...,
    state=state)`` — only the bitplanes the tighter target adds are fetched
    and pushed through the delta cascade.  Works on v1 and chunked states.
    """
    return retrieve(state.reader, error_bound=error_bound,
                    max_bytes=max_bytes, bitrate=bitrate,
                    propagation=propagation, state=state, backend=backend)


def decompress(buf: bytes, backend: Optional[str] = "numpy") -> np.ndarray:
    """Full-precision decompression (error <= eb everywhere)."""
    out, _ = retrieve(buf, backend=backend)
    return out


def split_budget(total: int, weights: Sequence[int]) -> List[int]:
    """Largest-remainder proportional split: non-negative ints that sum to
    exactly ``total``.

    Floor-dividing each share (the old behaviour) silently dropped up to
    ``len(weights) - 1`` bytes of budget; here every chunk gets
    ``floor(total * w / W)`` and the leftover units go to the largest
    fractional remainders first (ties: first chunk wins, deterministic).
    """
    w = np.asarray(weights, np.float64)
    if w.size == 0:
        return []
    quota = total * (w / w.sum())
    base = np.floor(quota).astype(np.int64)
    short = int(total - base.sum())
    if short > 0:
        order = np.argsort(base - quota, kind="stable")  # most-short first
        base[order[:short]] += 1
    return [int(b) for b in base]


def _retrieve_chunked(reader: ChunkedArchiveReader,
                      error_bound: Optional[float],
                      max_bytes: Optional[int],
                      bitrate: Optional[float],
                      propagation: str,
                      state: Optional[ChunkedRetrievalState],
                      backend: Optional[str] = "numpy",
                      ) -> Tuple[np.ndarray, ChunkedRetrievalState]:
    """Per-chunk plan + reconstruct; the global bound is the chunk max.

    Error mode passes ``error_bound`` straight through (each chunk holding
    L_inf <= E makes the assembled array hold it).  Byte/bitrate budgets
    are split across chunks proportionally to element count — keeping the
    loaded bit-per-point uniform, the same objective the v1 DP optimizes —
    with the integer remainder distributed largest-fraction-first so the
    chunk budgets sum to exactly ``max_bytes``.
    """
    m = reader.meta
    if state is None:
        state = ChunkedRetrievalState(reader=reader,
                                      chunk_states=[None] * len(m.chunks))
    if bitrate is not None:
        max_bytes = int(bitrate * m.n_elements / 8)
    budgets = None
    if error_bound is None and max_bytes is not None:
        sub_ns = [reader.chunk_reader(i).meta.n_elements
                  for i in range(len(m.chunks))]
        budgets = split_budget(max_bytes, sub_ns)
    out = np.empty(m.shape, np.dtype(m.dtype))
    errs = []
    for i, cm in enumerate(m.chunks):
        kw = {}
        if error_bound is not None:
            kw["error_bound"] = error_bound
        elif budgets is not None:
            kw["max_bytes"] = budgets[i]
        sub, st = retrieve(reader.chunk_reader(i), propagation=propagation,
                           state=state.chunk_states[i], backend=backend, **kw)
        state.chunk_states[i] = st
        out[cm.start:cm.stop] = sub
        errs.append(st.err_bound)
    state.err_bound = max(errs)
    state.bytes_read = reader.bytes_read
    return out, state
