"""Retrieval side of the codec pipeline (paper §5, Algorithms 1–2).

The DP loader plans the minimum bitplane set for the requested
:class:`~.spec.Fidelity`; a single reconstruction pass produces the output
(no multi-pass residual decompression).  A later call with the same state
*refines*: it loads only the *additional* bitplanes and pushes a linear
delta cascade on top of the previous reconstruction (the state machinery
lives in ``pipeline.state``).

The native entry point is :func:`read_archive` — (reader | bytes,
:class:`~.spec.Fidelity`, :class:`~.spec.ExecPolicy`, optional state) —
which ``repro.api.ProgressiveReader`` sessions drive; the historical
``retrieve`` / ``refine`` / ``decompress`` free functions are one-screen
compatibility shims over it.

Like the encode side, every hot step — plane decode and the
reconstruction sweep — goes through the policy's resolved
:class:`~.backends.CodecBackend`, so ``ExecPolicy(backend="jax")`` runs
retrieval on the Pallas kernel pair (``interp_recon`` +
``bitplane_unpack``) with bit-identical output to the numpy reference;
``"auto"`` picks jax on TPU only.

For chunked (v2) archives every plan/refine step runs per chunk (a
per-chunk L_inf bound implies the global one) and ``bytes_read``
aggregates across chunks.  Byte/bitrate budgets are split across chunks
proportionally to element count by largest-remainder assignment
(:func:`split_budget`), so the total allocated budget equals the request
exactly — no silent remainder loss; each chunk's escape-channel plan
floor is reserved before the proportional split, so a globally feasible
budget never starves an escape-heavy chunk into infeasibility; on a
refine, each chunk first keeps the bytes it already read and only the
*remaining* budget is split (:func:`refine_budgets`), so no chunk is
starved for having consumed its share earlier.

Execution over the chunk grid is scheduled in equal-shape groups: when
the backend ships batched primitives (``decode_level_batch`` /
``reconstruct_batch``), each group's plane decodes and reconstruction
sweeps run as ONE vmapped kernel dispatch per phase / per level-group key
instead of one per chunk — per-chunk plans, states and byte accounting
are untouched, and a refine still loads only each chunk's missing planes
(``ExecPolicy(batch_chunks=False)`` forces the per-chunk loop; outputs
are bit-identical either way).  The level-group key is backend-dependent:
``dynamic_low_zero`` backends take the loaded-prefix length as a runtime
kernel operand, so chunks at DIFFERENT fidelities share one ``(nbits,)``
dispatch; legacy backends bucket by ``(nbits, prefix)``.  Backends with
the fused decode slots further collapse each group's unpack + dequantize
+ delta cascade into one ``decode_level_fused_batch`` megakernel launch
per level, with the next level's zlib inflate prefetched on a worker
thread (see ``state.load_level_deltas_batch``).

``ExecPolicy(shard=...)`` ("auto" | a 1-D mesh | None, same contract as
the encode side) additionally splits each group's stack across a device
mesh through the backend's ``*_sharded`` primitives: every device decodes
and reconstructs its local chunk shard, collective-free, while the host
keeps all plane fetching, DP planning, and progressive accounting per
chunk — so ``bytes_read``, plane prefixes, and the delta cascade merge
back into :class:`ChunkedRetrievalState` exactly as on a single device,
and the reconstruction bits never depend on the policy
(``docs/architecture.md`` walks the full dataflow;
``tests/test_sharded_codec.py`` and ``tests/test_policy_matrix.py`` pin
the invariance).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from .. import container, loader
from ..container import ArchiveReader, ChunkedArchiveReader, V3ArchiveReader
from . import spec
from .spec import ExecPolicy, Fidelity
from .state import (ChunkedRetrievalState, RetrievalState, initial_state,
                    initial_state_batch, load_level_deltas,
                    load_level_deltas_batch, push_delta, push_delta_batch,
                    update_achieved_bound)
from .encode import group_cap, shape_groups


def open_archive(buf: bytes):
    """Reader for any archive version (v1 plain / v2 chunked)."""
    return container.open_reader(buf)


def plan_retrieval(meta, fidelity: Fidelity,
                   propagation: str) -> loader.LoadPlan:
    """Plan selection is a total function of the Fidelity sum type —
    no kwarg precedence left to get wrong.  ``propagation`` threads into
    every mode (including ``full``, whose reported bound used to be
    hardcoded to the PAPER model).  Public: the serving tier plans each
    request's chunks against this exact dispatcher so server plans can
    never drift from session plans."""
    if fidelity.kind == spec.ERROR_BOUND:
        return loader.plan_error_mode(meta, fidelity.value, propagation)
    budget = fidelity.target_bytes(meta.n_elements)
    if budget is not None:
        return loader.plan_bitrate_mode(meta, budget, propagation)
    return loader.plan_full(meta, propagation)


def plan_ladder(meta, fidelity: Fidelity, propagation: str,
                t_min: int = 0) -> int:
    """The v3 twin of :func:`plan_retrieval`: resolve a Fidelity to a
    ladder-prefix length over ``meta.plane_segments`` (``meta`` is a
    :class:`~..container.V3Meta`).  ``t_min`` is the session's held
    prefix — plans never shrink it, mirroring the v1/v2
    refine-never-drops-planes rule.  Public for the same reason as
    :func:`plan_retrieval`: the serving tier plans v3 requests against
    this exact dispatcher."""
    if fidelity.kind == spec.ERROR_BOUND:
        return loader.ladder_error_mode(meta, fidelity.value, propagation,
                                        t_min=t_min)
    budget = fidelity.target_bytes(meta.n_elements)
    if budget is not None:
        return loader.ladder_bitrate_mode(meta, budget, t_min=t_min)
    return len(meta.plane_segments)


def read_archive(buf_or_reader, fidelity: Optional[Fidelity] = None,
                 policy: Optional[ExecPolicy] = None,
                 propagation: str = loader.SAFE,
                 state: Optional[RetrievalState] = None,
                 cache=None, counters=None,
                 ) -> Tuple[np.ndarray, RetrievalState]:
    """Single-pass progressive retrieval (native entry).

    ``fidelity`` selects the plan (default: :meth:`Fidelity.full`);
    ``policy`` selects the execution substrate and parallelism — every
    policy reconstructs bit-identical arrays, and the state is
    policy-agnostic, so successive calls may switch backend, batching, or
    mesh freely.  Pass ``state`` from a previous call to refine
    incrementally (Algorithm 2) — only missing bitplanes are fetched.

    Accepts v1 and v2 (chunked) archives / readers transparently.
    ``cache`` / ``counters`` are the serving-tier hooks threaded into the
    state helpers (see ``pipeline.state``); both default off and never
    change reconstruction bits.
    """
    fidelity = Fidelity.full() if fidelity is None else fidelity
    policy = spec.DEFAULT_POLICY if policy is None else policy
    if isinstance(buf_or_reader, (ArchiveReader, ChunkedArchiveReader,
                                  V3ArchiveReader)):
        reader = buf_or_reader
    else:
        reader = container.open_reader(buf_or_reader)
    if isinstance(reader, V3ArchiveReader):
        return _retrieve_v3(reader, fidelity, propagation, state,
                            policy, cache=cache, counters=counters)
    if isinstance(reader, ChunkedArchiveReader):
        return _retrieve_chunked(reader, fidelity, propagation, state,
                                 policy, cache=cache, counters=counters)
    # v1: no chunk grid to shard — bind validates (explicit mesh raises)
    ctx = policy.bind(chunked=False, encode=False)
    m = reader.meta
    plan = plan_retrieval(m, fidelity, propagation)
    if state is None:
        state = initial_state(reader, ctx.bk, counters=counters)
    delta_y, any_new = load_level_deltas(state, plan.keep_planes, ctx.bk,
                                         cache=cache, counters=counters)
    if any_new:
        push_delta(state, delta_y, ctx.bk, counters=counters)
    update_achieved_bound(state, propagation)
    out = state.xhat.astype(np.dtype(m.dtype))
    return out, state


def retrieve(buf_or_reader, error_bound: Optional[float] = None,
             max_bytes: Optional[int] = None,
             bitrate: Optional[float] = None,
             propagation: str = loader.SAFE,
             state: Optional[RetrievalState] = None,
             backend: Optional[str] = "numpy",
             batch_chunks: Optional[bool] = None,
             shard=None,
             ) -> Tuple[np.ndarray, RetrievalState]:
    """Legacy free function; shim over :func:`read_archive`.

    Prefer ``repro.api``: ``Archive(buf).open(policy).read(fidelity)``.
    Exactly one of (error_bound, max_bytes, bitrate) selects the plan
    (passing several raises ValueError; they coerce through
    :meth:`Fidelity.from_targets`); none of them = full precision.
    (backend, batch_chunks, shard) form the :class:`~.spec.ExecPolicy`.
    Behavior and bits are unchanged.
    """
    spec.warn_legacy("retrieve()", "Archive.open(policy).read(fidelity)")
    return read_archive(buf_or_reader,
                        Fidelity.from_targets(error_bound, max_bytes,
                                              bitrate),
                        ExecPolicy(backend=backend,
                                   batch_chunks=batch_chunks, shard=shard),
                        propagation=propagation, state=state)


def refine(state, error_bound: Optional[float] = None,
           max_bytes: Optional[int] = None,
           bitrate: Optional[float] = None,
           propagation: str = loader.SAFE,
           backend: Optional[str] = "numpy",
           batch_chunks: Optional[bool] = None,
           shard=None,
           ) -> Tuple[np.ndarray, RetrievalState]:
    """Legacy free function; shim over :func:`read_archive` with a state.

    Prefer ``repro.api``: ``ProgressiveReader.refine(fidelity)`` on the
    session returned by ``Archive.open``.  Only the bitplanes the tighter
    target adds are fetched and pushed through the delta cascade.  Works
    on v1 and chunked states; at most one of (error_bound, max_bytes,
    bitrate) may be given.
    """
    spec.warn_legacy("refine()", "ProgressiveReader.refine(fidelity)")
    return read_archive(state.reader,
                        Fidelity.from_targets(error_bound, max_bytes,
                                              bitrate),
                        ExecPolicy(backend=backend,
                                   batch_chunks=batch_chunks, shard=shard),
                        propagation=propagation, state=state)


def decompress(buf: bytes, backend: Optional[str] = "numpy",
               shard=None, batch_chunks: Optional[bool] = None) -> np.ndarray:
    """Legacy free function: full-precision decompression (error <= eb
    everywhere).

    Prefer ``repro.api``: ``Archive(buf).open(policy).read()``.  Accepts
    the same execution kwargs as ``retrieve`` — including
    ``batch_chunks``, which it historically dropped — and delegates to
    the object API, so the semantics cannot drift again.
    """
    spec.warn_legacy("decompress()",
                     "Archive.open(policy).read(Fidelity.full())")
    from ... import api
    policy = ExecPolicy(backend=backend, batch_chunks=batch_chunks,
                        shard=shard)
    return api.Archive(buf).open(policy).read(Fidelity.full())


def split_budget(total: int, weights: Sequence[int]) -> List[int]:
    """Largest-remainder proportional split: non-negative ints that sum to
    exactly ``total``.

    Floor-dividing each share (the old behaviour) silently dropped up to
    ``len(weights) - 1`` bytes of budget; here every chunk gets
    ``floor(total * w / W)`` and the leftover units go to the largest
    fractional remainders first (ties: first chunk wins, deterministic).

    ``total`` must be non-negative and ``weights`` non-negative with a
    positive sum (a zero-sum vector used to produce NaN quotas and a crash
    deep inside ``np.floor(...).astype`` — now a clear ValueError).
    """
    if total < 0:
        raise ValueError(f"budget total must be non-negative, got {total}")
    w = np.asarray(weights, np.float64)
    if w.size == 0:
        return []
    if (w < 0).any():
        raise ValueError("budget weights must be non-negative, got "
                         f"{list(weights)}")
    if w.sum() == 0:
        raise ValueError("budget weights must have a positive sum; got "
                         "all-zero weights")
    quota = total * (w / w.sum())
    base = np.floor(quota).astype(np.int64)
    short = int(total - base.sum())
    if short > 0:
        order = np.argsort(base - quota, kind="stable")  # most-short first
        base[order[:short]] += 1
    return [int(b) for b in base]


def refine_budgets(total: int, weights: Sequence[int],
                   spent: Sequence[int],
                   floors: Optional[Sequence[int]] = None) -> List[int]:
    """Cumulative per-chunk byte budgets for a refine step.

    Each chunk keeps the bytes it already read (``spent``, from its
    progressive state) and only the *remaining* budget is split
    proportionally — re-splitting the full total from scratch (the old
    behaviour) handed a chunk that had already consumed more than its
    proportional share a from-scratch plan below its loaded prefix, i.e.
    a silent no-op, starving it of further planes while the request still
    had budget to give.  With no prior spending this reduces exactly to
    :func:`split_budget`.

    ``floors`` are per-chunk minimum feasible budgets (the escape-channel
    plan floors of ``loader.plan_bitrate_mode``): each chunk is allocated
    ``max(spent, floor)`` *first* and only the remainder is split
    proportionally, so a globally feasible ``total`` (>= the summed
    floors) can never starve one escape-heavy chunk below its floor and
    fail the whole read.  ``total`` below the summed floors is infeasible
    and raises.
    """
    spent = [int(s) for s in spent]
    floors = [0] * len(spent) if floors is None else [int(f) for f in floors]
    if total - sum(spent) <= 0 and \
            all(s >= f for s, f in zip(spent, floors)):
        return spent  # budget exhausted: every plan stays at what's loaded
    base = [max(s, f) for s, f in zip(spent, floors)]
    need = sum(base)
    if total < need:
        raise ValueError(
            f"max_bytes={total} is infeasible across the chunk grid: the "
            f"smallest per-chunk plans load {need} bytes together (escape "
            "channels are always loaded with their level); request at "
            "least that many bytes or use an error-bound target")
    return [b + extra
            for b, extra in zip(base, split_budget(total - need, weights))]


def chunk_budgets(reader: ChunkedArchiveReader, fidelity: Fidelity,
                  state: Optional[ChunkedRetrievalState] = None,
                  ) -> Optional[List[int]]:
    """Per-chunk cumulative byte budgets for a byte/bitrate fidelity, or
    None when the fidelity has no byte target (error-bound / full).

    Splits proportionally to element count via :func:`refine_budgets`,
    crediting each chunk's already-read bytes from ``state`` and
    reserving each chunk's escape-channel plan floor before the
    proportional split — the exact split ``_retrieve_chunked`` uses,
    exported so the serving tier's per-chunk job plans match in-session
    plans byte for byte.
    """
    m = reader.meta
    total_bytes = fidelity.target_bytes(m.n_elements)
    if total_bytes is None:
        return None
    subs = [reader.chunk_reader(i) for i in range(len(m.chunks))]
    sub_ns = [s.meta.n_elements for s in subs]
    floors = [sum(lv.esc_size for lv in s.meta.levels) for s in subs]
    spent = [cs.bytes_read if cs is not None else 0
             for cs in state.chunk_states] if state is not None \
        else [0] * len(m.chunks)
    return refine_budgets(total_bytes, sub_ns, spent, floors=floors)


def sub_fidelity(fidelity: Fidelity, budgets: Optional[List[int]],
                 i: int) -> Fidelity:
    """The per-chunk fidelity a global request induces on chunk ``i``:
    error bounds pass straight through (per-chunk L_inf <= E implies the
    global bound), byte targets take the chunk's split budget, full stays
    full."""
    if fidelity.kind == spec.ERROR_BOUND:
        return fidelity
    if budgets is not None:
        return Fidelity.max_bytes(budgets[i])
    return Fidelity.full()


def decode_group(readers: List[ArchiveReader],
                 states: List[Optional[RetrievalState]],
                 keeps: List[List[int]], ctx: spec.ExecContext,
                 propagation: str = loader.SAFE,
                 cache=None, counters=None) -> List[RetrievalState]:
    """Execute a group of equal-shape chunk decode jobs as one batched
    launch sequence; returns the updated per-job states (same order).

    This is the group executor shared by the in-session scheduler
    (:func:`_retrieve_group`) and the serving tier's cross-request
    coalescer (``repro.serving.server``): each job is (sub-reader,
    prior state or None, planned keep_planes).  Jobs may come from
    different sessions — and, through ``cache``/equal ``cache_scope``,
    reuse or deduplicate each other's decoded prefixes — without that
    ever changing any job's bits: the batch axis is an execution detail.
    Falls back to the scalar helpers for singleton groups or batch-less
    backends, bit-identically.
    """
    bk = ctx.bk
    batched = ctx.batch_decode and len(readers) > 1
    if not batched:
        out = []
        for r, st, keep in zip(readers, states, keeps):
            if st is None:
                st = initial_state(r, bk, counters=counters)
            delta_y, any_new = load_level_deltas(st, keep, bk, cache=cache,
                                                 counters=counters)
            if any_new:
                push_delta(st, delta_y, bk, counters=counters)
            update_achieved_bound(st, propagation)
            out.append(st)
        return out
    states = list(states)
    fresh = [p for p, st in enumerate(states) if st is None]
    if fresh:
        sts = initial_state_batch([readers[p] for p in fresh], ctx,
                                  counters=counters)
        for p, st in zip(fresh, sts):
            states[p] = st
    delta_ys, any_new = load_level_deltas_batch(states, keeps, ctx,
                                                cache=cache,
                                                counters=counters)
    live = [p for p, new in enumerate(any_new) if new]
    if live:
        push_delta_batch([states[p] for p in live],
                         [delta_ys[p] for p in live], ctx,
                         counters=counters)
    for st in states:
        update_achieved_bound(st, propagation)
    return states


def _retrieve_chunked(reader: ChunkedArchiveReader, fidelity: Fidelity,
                      propagation: str,
                      state: Optional[ChunkedRetrievalState],
                      policy: ExecPolicy, cache=None, counters=None,
                      ) -> Tuple[np.ndarray, ChunkedRetrievalState]:
    """Shape-group scheduled per-chunk plan + reconstruct; the global bound
    is the chunk max.

    Error mode passes the bound straight through (each chunk holding
    L_inf <= E makes the assembled array hold it).  Byte/bitrate budgets
    are split across chunks proportionally to element count — keeping the
    loaded bit-per-point uniform, the same objective the v1 DP optimizes —
    with the integer remainder distributed largest-fraction-first so the
    chunk budgets sum to exactly the request; refines split only the
    budget not already spent (:func:`refine_budgets`).  Equal-shape groups
    run batched when the backend supports it (one kernel dispatch per
    phase for the whole group) and, with a mesh in the policy,
    mesh-sharded (each device handles its local chunk shard, groups
    capped at ``MAX_BATCH_CHUNKS`` per device); singleton groups and
    batch-less backends take the per-chunk path.  All paths produce
    bit-identical states.
    """
    m = reader.meta
    ctx = policy.bind(chunked=True, encode=False)
    if state is None:
        state = ChunkedRetrievalState(reader=reader,
                                      chunk_states=[None] * len(m.chunks))
    budgets = chunk_budgets(reader, fidelity, state)
    # per-chunk scalar fallback: v1 sub-archives, so the mesh (which only
    # applies to the chunk grid as a whole) is stripped from the policy
    sub_policy = policy.unsharded()
    for idxs in shape_groups([cm.stop - cm.start for cm in m.chunks],
                             max_group=group_cap(ctx.mesh)):
        if ctx.batch_decode and len(idxs) > 1:
            _retrieve_group(reader, idxs, fidelity, budgets, propagation,
                            state, ctx, cache=cache, counters=counters)
        else:
            for i in idxs:
                _, st = read_archive(reader.chunk_reader(i),
                                     sub_fidelity(fidelity, budgets, i),
                                     sub_policy, propagation=propagation,
                                     state=state.chunk_states[i],
                                     cache=cache, counters=counters)
                state.chunk_states[i] = st
    out = np.empty(m.shape, np.dtype(m.dtype))
    for i, cm in enumerate(m.chunks):
        out[cm.start:cm.stop] = \
            state.chunk_states[i].xhat.astype(np.dtype(m.dtype))
    state.err_bound = max(cs.err_bound for cs in state.chunk_states)
    state.bytes_read = reader.bytes_read
    return out, state


def _retrieve_group(reader: ChunkedArchiveReader, idxs: List[int],
                    fidelity: Fidelity, budgets: Optional[List[int]],
                    propagation: str, state: ChunkedRetrievalState,
                    ctx: spec.ExecContext, cache=None,
                    counters=None) -> None:
    """One equal-shape chunk group through the batched retrieval steps.

    Plans each chunk against its induced :func:`sub_fidelity` (host DP,
    each chunk's own tables) and hands the group to the shared
    :func:`decode_group` executor — the same one the serving tier's
    cross-request coalescer drives.  Per-chunk states and reader
    accounting come out identical to the scalar loop; only the dispatch
    count (and its device fan-out) changes.
    """
    subs = [reader.chunk_reader(i) for i in idxs]
    keeps = [plan_retrieval(sub.meta, sub_fidelity(fidelity, budgets, i),
                            propagation).keep_planes
             for i, sub in zip(idxs, subs)]
    sts = decode_group(subs, [state.chunk_states[i] for i in idxs], keeps,
                       ctx, propagation, cache=cache, counters=counters)
    for i, st in zip(idxs, sts):
        state.chunk_states[i] = st


def _retrieve_v3(reader: V3ArchiveReader, fidelity: Fidelity,
                 propagation: str,
                 state: Optional[ChunkedRetrievalState],
                 policy: ExecPolicy, cache=None, counters=None,
                 ) -> Tuple[np.ndarray, ChunkedRetrievalState]:
    """Plane-major (v3) retrieval: one ladder plan, one contiguous read,
    then the same grouped chunk decode as v2.

    Where v2 plans per chunk and scatters per-chunk blob reads, v3
    resolves the whole request to a single ladder-prefix length ``t``
    (:func:`plan_ladder`), stages the byte gap with ONE contiguous source
    read (:meth:`~..container.V3ArchiveReader.ensure_prefix`), and decodes
    every chunk from the staged prefix — so a fidelity ladder issues
    monotone contiguous ranges no matter how many chunks refine.  Byte
    targets are global by construction (``cum_bytes`` sums the grid), so
    no proportional split is needed; the refine floor is the state's
    ``ladder_pos`` instead of per-chunk spent bytes.  Per-chunk decode
    states, accounting, and the assembled output follow v2 exactly, and
    the shared :func:`decode_group` executor handles batching / sharding
    / scalar fallback identically.
    """
    m = reader.meta
    ctx = policy.bind(chunked=True, encode=False)
    if state is None:
        state = ChunkedRetrievalState(reader=reader,
                                      chunk_states=[None] * len(m.chunks))
    t = plan_ladder(m, fidelity, propagation, t_min=state.ladder_pos)
    reader.ensure_prefix(t)
    keeps = m.ladder_keeps(t)
    for idxs in shape_groups([cm.stop - cm.start for cm in m.chunks],
                             max_group=group_cap(ctx.mesh)):
        subs = [reader.chunk_reader(i) for i in idxs]
        sts = decode_group(subs, [state.chunk_states[i] for i in idxs],
                           [keeps[i] for i in idxs], ctx, propagation,
                           cache=cache, counters=counters)
        for i, st in zip(idxs, sts):
            state.chunk_states[i] = st
    out = np.empty(m.shape, np.dtype(m.dtype))
    for i, cm in enumerate(m.chunks):
        out[cm.start:cm.stop] = \
            state.chunk_states[i].xhat.astype(np.dtype(m.dtype))
    state.err_bound = max(cs.err_bound for cs in state.chunk_states)
    state.bytes_read = reader.bytes_read
    state.ladder_pos = max(state.ladder_pos, t)
    return out, state
