"""Progressive retrieval state + Algorithm 2's delta-cascade logic.

A :class:`RetrievalState` carries everything a later ``retrieve``/``refine``
call needs to load *only* the missing bitplanes and push a linear delta on
top of the previous reconstruction instead of decoding from scratch:

  * ``planes_loaded`` / ``nb_partial`` — per level, how many MSB-first
    planes are in and the truncated negabinary stream they decode to
    (backend-agnostic: uint32 words, whichever backend produced them);
  * ``esc_idx`` — escape stream positions, whose deltas are pinned to zero
    (escaped points are exact from the very first pass);
  * ``xhat`` — the current reconstruction the next delta lands on.

The cascade itself (:func:`load_level_deltas` + :func:`push_delta`) is the
paper's Algorithm 2: residual *differences* are reconstructed through the
same interpolation sweep with zero anchors — valid because the sweep is
linear in (anchors, residuals) — and added to ``xhat``.  Both steps take
the resolved :class:`~.backends.CodecBackend`, so refinement runs on the
Pallas kernels exactly like a cold retrieval.

:class:`ChunkedRetrievalState` is the v2-archive twin: one per-chunk state
plus aggregated accounting.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from .. import loader, negabinary
from ..container import ArchiveReader, ChunkedArchiveReader
from .backends import CodecBackend


@dataclass
class RetrievalState:
    """Progressive state carried between retrievals (Algorithm 2)."""
    reader: ArchiveReader
    planes_loaded: List[int]              # per level, MSB-first count
    nb_partial: List[np.ndarray]          # truncated negabinary per level
    esc_idx: List[np.ndarray]             # escape stream positions per level
    xhat: np.ndarray                      # current reconstruction
    err_bound: float
    bytes_read: int = 0


@dataclass
class ChunkedRetrievalState:
    """Progressive state for a v2 archive: one RetrievalState per chunk."""
    reader: ChunkedArchiveReader
    chunk_states: List[Optional[RetrievalState]]
    err_bound: float = float("inf")
    bytes_read: int = 0


def _unpack_escapes(blob: bytes) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of ``encode._pack_escapes``: blob -> (flat idx, exact values)."""
    if not blob:
        return np.zeros(0, np.int64), np.zeros(0, np.float64)
    raw = zlib.decompress(blob)
    n = int(np.frombuffer(raw[:8], np.int64)[0])
    idx = np.frombuffer(raw[8:8 + 8 * n], np.int64)
    val = np.frombuffer(raw[8 + 8 * n:], np.float64)
    return idx, val


def initial_state(reader: ArchiveReader, bk: CodecBackend) -> RetrievalState:
    """Coarsest approximation: anchors + escapes only, zero bitplanes."""
    m = reader.meta
    anchors = reader.anchors()
    yhat, overrides = [], []
    for li, lv in enumerate(m.levels):
        yhat.append(np.zeros(lv.n, np.float64))
        idx, val = _unpack_escapes(reader.escapes(li))
        overrides.append((idx, val))
    xhat = bk.reconstruct(m.shape, m.interp, anchors, yhat,
                          overrides=overrides)
    full_err = m.eb + sum(
        float(lv.delta_table[lv.nbits]) *
        loader._prop_factor(m, lv.level, loader.SAFE)
        for lv in m.levels)
    return RetrievalState(reader=reader,
                          planes_loaded=[0] * len(m.levels),
                          nb_partial=[np.zeros(lv.n, np.uint32) for lv in m.levels],
                          esc_idx=[o[0] for o in overrides],
                          xhat=xhat, err_bound=full_err,
                          bytes_read=reader.bytes_read)


def load_level_deltas(state: RetrievalState, keep_planes: List[int],
                      bk: CodecBackend) -> Tuple[List[np.ndarray], bool]:
    """Fetch + decode the planes the plan adds; return residual deltas.

    Per level: refinement never drops planes, so the target is
    ``max(have, plan)``.  XOR decode needs planes k+1, k+2, so the prefix is
    re-decoded from the already-fetched blobs (the reader caches fetched
    ranges; re-reads of the same tag are not double-counted).  The returned
    stream is the *difference* of dequantized residuals — the input of the
    zero-anchor cascade in :func:`push_delta`.
    """
    m = state.reader.meta
    delta_y: List[np.ndarray] = []
    any_new = False
    for li, lv in enumerate(m.levels):
        have = state.planes_loaded[li]
        want = max(have, keep_planes[li])
        if want > have:
            any_new = True
            blobs: List[Optional[bytes]] = [None] * lv.nbits
            for i in range(want):
                blobs[i] = state.reader.plane(li, i)
            nb_new = bk.decode_level(blobs, lv.nbits, lv.n)
            dq = negabinary.from_negabinary(nb_new) - \
                negabinary.from_negabinary(state.nb_partial[li])
            delta_y.append(dq.astype(np.float64) * 2.0 * m.eb)
            state.nb_partial[li] = nb_new
            state.planes_loaded[li] = want
        else:
            delta_y.append(np.zeros(lv.n, np.float64))
    return delta_y, any_new


def push_delta(state: RetrievalState, delta_y: List[np.ndarray],
               bk: CodecBackend) -> None:
    """Algorithm 2 core: reconstruct the residual deltas through the sweep
    with zero anchors (linearity) and add onto the previous ``xhat``.
    Escaped points are exact from the first pass: their delta is pinned 0."""
    m = state.reader.meta
    zero_anchors = np.zeros(m.anchors_shape, np.float64)
    zero_ovr = [(idx, np.zeros(idx.size)) for idx in state.esc_idx]
    delta = bk.reconstruct(m.shape, m.interp, zero_anchors, delta_y,
                           overrides=zero_ovr)
    state.xhat = state.xhat + delta


def update_achieved_bound(state: RetrievalState, propagation: str) -> None:
    """Recompute the guaranteed bound from the *union* of loaded planes."""
    m = state.reader.meta
    errs, _ = loader._level_cost_tables(m, propagation)
    state.err_bound = m.eb + sum(
        float(errs[li][lv.nbits - state.planes_loaded[li]])
        for li, lv in enumerate(m.levels))
    state.bytes_read = state.reader.bytes_read
