"""Progressive retrieval state + Algorithm 2's delta-cascade logic.

A :class:`RetrievalState` carries everything a later ``retrieve``/``refine``
call needs to load *only* the missing bitplanes and push a linear delta on
top of the previous reconstruction instead of decoding from scratch:

  * ``planes_loaded`` / ``nb_partial`` — per level, how many MSB-first
    planes are in and the truncated negabinary stream they decode to
    (backend-agnostic: uint32 words, whichever backend produced them);
  * ``esc_idx`` — escape stream positions, whose deltas are pinned to zero
    (escaped points are exact from the very first pass);
  * ``xhat`` — the current reconstruction the next delta lands on.

The cascade itself (:func:`load_level_deltas` + :func:`push_delta`) is the
paper's Algorithm 2: residual *differences* are reconstructed through the
same interpolation sweep with zero anchors — valid because the sweep is
linear in (anchors, residuals) — and added to ``xhat``.  Both steps take
the resolved :class:`~.backends.CodecBackend`, so refinement runs on the
Pallas kernels exactly like a cold retrieval.

:class:`ChunkedRetrievalState` is the v2-archive twin: one per-chunk state
plus aggregated accounting.

Two optional cross-cutting hooks thread through every helper (both are
``None`` by default and cost nothing when absent):

``cache``
    A shared *plane cache* (``repro.serving.PlaneCache`` protocol:
    ``get(key) -> array | None`` / ``put(key, array)`` /
    ``saved_fetch(nbytes)``) keyed ``(reader.cache_scope, level, prefix)``.
    Decoded truncated-negabinary prefixes are deterministic functions of
    the archive bytes, so concurrent sessions at different fidelities can
    reuse each other's decodes: a hit skips both the plane-blob fetches
    and the unpack kernel, never changing reconstruction bits (a session's
    ``bytes_read`` may shrink — that is the serving win, see
    ``docs/architecture.md`` §8).  Readers opt in by carrying a non-None
    ``cache_scope`` (see ``container.ArchiveReader``).

``counters``
    A plain dict accumulating backend-primitive invocation counts
    (``decode_level`` / ``reconstruct`` / ``dedup_reuse``), one unit per
    primitive call whether scalar, batched, or sharded — the
    serving tier's dispatch accounting, backend-independent (the kernel
    layer's ``kernels.dispatch`` only counts Pallas launches).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from .. import bitplane, loader, negabinary
from ..container import ArchiveReader, ChunkedArchiveReader
from .backends import CodecBackend
from .spec import ExecContext


@dataclass
class RetrievalState:
    """Progressive state carried between retrievals (Algorithm 2)."""
    reader: ArchiveReader
    planes_loaded: List[int]              # per level, MSB-first count
    nb_partial: List[np.ndarray]          # truncated negabinary per level
    esc_idx: List[np.ndarray]             # escape stream positions per level
    xhat: np.ndarray                      # current reconstruction
    err_bound: float
    bytes_read: int = 0


@dataclass
class ChunkedRetrievalState:
    """Progressive state for a chunked (v2 or v3) archive: one
    RetrievalState per chunk.  ``ladder_pos`` only moves on v3: the
    ladder-prefix length already held, so refinement plans start there
    (the v3 twin of per-level ``planes_loaded`` floors)."""
    reader: ChunkedArchiveReader
    chunk_states: List[Optional[RetrievalState]]
    err_bound: float = float("inf")
    bytes_read: int = 0
    ladder_pos: int = 0


def fork_state(state):
    """Branch an independent progressive session off ``state``.

    Returns a new :class:`RetrievalState` / :class:`ChunkedRetrievalState`
    carrying the same loaded planes, reconstruction, and cumulative byte
    accounting, backed by *forked* readers
    (:meth:`~..container.ArchiveReader.fork`) — so several refinements can
    branch off one finished session concurrently, each fetching only the
    planes its own target adds, without sharing a mutable state or
    ledger.  Cheap: ``nb_partial`` streams are immutable-by-contract
    (replaced, never written in place) and ``xhat`` is only ever
    reassigned, so the arrays themselves are shared.
    """
    if isinstance(state, ChunkedRetrievalState):
        reader = state.reader.fork()
        chunk_states = [
            None if cs is None else RetrievalState(
                reader=reader.chunk_reader(i),
                planes_loaded=list(cs.planes_loaded),
                nb_partial=list(cs.nb_partial),
                esc_idx=list(cs.esc_idx),
                xhat=cs.xhat, err_bound=cs.err_bound,
                bytes_read=cs.bytes_read)
            for i, cs in enumerate(state.chunk_states)]
        return ChunkedRetrievalState(reader=reader,
                                     chunk_states=chunk_states,
                                     err_bound=state.err_bound,
                                     bytes_read=state.bytes_read,
                                     ladder_pos=state.ladder_pos)
    reader = state.reader.fork()
    return RetrievalState(reader=reader,
                          planes_loaded=list(state.planes_loaded),
                          nb_partial=list(state.nb_partial),
                          esc_idx=list(state.esc_idx),
                          xhat=state.xhat, err_bound=state.err_bound,
                          bytes_read=state.bytes_read)


def _count(counters, name: str, k: int = 1) -> None:
    """Accumulate a backend-primitive invocation into ``counters`` (no-op
    when the caller did not ask for accounting)."""
    if counters is not None:
        counters[name] = counters.get(name, 0) + k


def _cache_key(reader, level_idx: int, prefix: int):
    """Plane-cache key for a decoded prefix, or None when the reader is
    not cache-scoped."""
    scope = getattr(reader, "cache_scope", None)
    if scope is None:
        return None
    return (scope, level_idx, prefix)


def _freeze(arr: np.ndarray) -> np.ndarray:
    """Mark a decoded stream immutable before it is shared across
    sessions (cache entries / dedup fan-out).  ``nb_partial`` streams are
    only ever *replaced*, never written in place, so sharing is safe."""
    try:
        arr.flags.writeable = False
    except ValueError:
        pass  # views of external buffers may already be locked
    return arr


def _unpack_escapes(blob: bytes) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of ``encode._pack_escapes``: blob -> (flat idx, exact values).

    Routed through :func:`~..bitplane.inflate` so pre-inflated
    (:class:`~..bitplane.Raw`) payloads from cache layers skip zlib."""
    if not blob:
        return np.zeros(0, np.int64), np.zeros(0, np.float64)
    raw = bitplane.inflate(blob)
    n = int(np.frombuffer(raw[:8], np.int64)[0])
    idx = np.frombuffer(raw[8:8 + 8 * n], np.int64)
    val = np.frombuffer(raw[8 + 8 * n:], np.float64)
    return idx, val


_INFLATE_POOL = None


def _inflate_pool():
    """Lazy singleton worker for the two-slot inflate prefetch: while the
    device decodes level k, the NEXT level's zlib inflate (pure host work)
    runs here, so the serial host stage hides behind the kernel sweep.
    One worker is enough — there is exactly one level in flight ahead."""
    global _INFLATE_POOL
    if _INFLATE_POOL is None:
        from concurrent.futures import ThreadPoolExecutor
        _INFLATE_POOL = ThreadPoolExecutor(max_workers=1,
                                           thread_name_prefix="ipcomp-inflate")
    return _INFLATE_POOL


def initial_state(reader: ArchiveReader, bk: CodecBackend,
                  counters=None) -> RetrievalState:
    """Coarsest approximation: anchors + escapes only, zero bitplanes."""
    m = reader.meta
    anchors = reader.anchors()
    yhat, overrides = [], []
    for li, lv in enumerate(m.levels):
        yhat.append(np.zeros(lv.n, np.float64))
        idx, val = _unpack_escapes(reader.escapes(li))
        overrides.append((idx, val))
    xhat = bk.reconstruct(m.shape, m.interp, anchors, yhat,
                          overrides=overrides)
    _count(counters, "reconstruct")
    full_err = m.eb + sum(
        float(lv.delta_table[lv.nbits]) *
        loader._prop_factor(m, lv.level, loader.SAFE)
        for lv in m.levels)
    return RetrievalState(reader=reader,
                          planes_loaded=[0] * len(m.levels),
                          nb_partial=[np.zeros(lv.n, np.uint32) for lv in m.levels],
                          esc_idx=[o[0] for o in overrides],
                          xhat=xhat, err_bound=full_err,
                          bytes_read=reader.bytes_read)


def load_level_deltas(state: RetrievalState, keep_planes: List[int],
                      bk: CodecBackend, cache=None,
                      counters=None) -> Tuple[List[np.ndarray], bool]:
    """Fetch + decode the planes the plan adds; return residual deltas.

    Per level: refinement never drops planes, so the target is
    ``max(have, plan)``.  XOR decode needs planes k+1, k+2, so the prefix is
    re-decoded from the already-fetched blobs (the reader caches fetched
    ranges; re-reads of the same tag are not double-counted).  The returned
    stream is the *difference* of dequantized residuals — the input of the
    zero-anchor cascade in :func:`push_delta`.

    With a ``cache`` and a cache-scoped reader, the decoded prefix is
    looked up under ``(scope, level, prefix)`` first: a hit skips the
    plane fetches *and* the decode (crediting the avoided fetch bytes to
    the cache accounting); a miss decodes as usual and publishes the
    result for other sessions.

    Backends shipping the fused decode slots get two upgrades here: each
    level's unpack + dequantize + delta runs as ONE
    ``decode_level_fused`` launch (no host negabinary passes), and the
    next level's zlib inflate (``inflate_level``) is prefetched on a
    worker thread while the current level's kernel runs.  Bits are
    unchanged either way — the fused delta arithmetic is pinned identical
    to the host spelling by the parity suite.
    """
    m = state.reader.meta
    L = len(m.levels)
    delta_y: List[Optional[np.ndarray]] = [None] * L
    any_new = False
    fused = bk.decode_level_fused is not None
    djobs: List[Tuple[int, object, int, object, list]] = []
    for li, lv in enumerate(m.levels):
        have = state.planes_loaded[li]
        want = max(have, keep_planes[li])
        if want <= have:
            delta_y[li] = np.zeros(lv.n, np.float64)
            continue
        any_new = True
        key = _cache_key(state.reader, li, want) \
            if cache is not None else None
        nb_new = cache.get(key) if key is not None else None
        if nb_new is not None:
            cache.saved_fetch(sum(
                lv.plane_sizes[i] for i in range(want)
                if not state.reader.plane_fetched(li, i)))
            dq = negabinary.from_negabinary(nb_new) - \
                negabinary.from_negabinary(state.nb_partial[li])
            delta_y[li] = dq.astype(np.float64) * 2.0 * m.eb
            state.nb_partial[li] = nb_new
            state.planes_loaded[li] = want
            continue
        blobs: List[Optional[bytes]] = [None] * lv.nbits
        for i in range(want):
            blobs[i] = state.reader.plane(li, i)
        djobs.append((li, lv, want, key, blobs))
    prefetch = fused and bk.inflate_level is not None and len(djobs) > 1
    fut = None
    for k, (li, lv, want, key, blobs) in enumerate(djobs):
        words = None
        if prefetch:
            words = fut.result() if fut is not None \
                else bk.inflate_level(blobs, lv.nbits, lv.n)
            if k + 1 < len(djobs):
                nli, nlv, _nw, _nk, nblobs = djobs[k + 1]
                fut = _inflate_pool().submit(bk.inflate_level, nblobs,
                                             nlv.nbits, nlv.n)
            else:
                fut = None
        if fused:
            nb_new, dy = bk.decode_level_fused(blobs, lv.nbits, lv.n,
                                               state.nb_partial[li], m.eb,
                                               words=words)
        else:
            nb_new = bk.decode_level(blobs, lv.nbits, lv.n)
            dq = negabinary.from_negabinary(nb_new) - \
                negabinary.from_negabinary(state.nb_partial[li])
            dy = dq.astype(np.float64) * 2.0 * m.eb
        _count(counters, "decode_level")
        nb_new = np.asarray(nb_new)
        if key is not None:
            cache.put(key, _freeze(nb_new))
        delta_y[li] = dy
        state.nb_partial[li] = nb_new
        state.planes_loaded[li] = want
    return delta_y, any_new


def push_delta(state: RetrievalState, delta_y: List[np.ndarray],
               bk: CodecBackend, counters=None) -> None:
    """Algorithm 2 core: reconstruct the residual deltas through the sweep
    with zero anchors (linearity) and add onto the previous ``xhat``.
    Escaped points are exact from the first pass: their delta is pinned 0."""
    m = state.reader.meta
    zero_anchors = np.zeros(m.anchors_shape, np.float64)
    zero_ovr = [(idx, np.zeros(idx.size)) for idx in state.esc_idx]
    delta = bk.reconstruct(m.shape, m.interp, zero_anchors, delta_y,
                           overrides=zero_ovr)
    _count(counters, "reconstruct")
    state.xhat = state.xhat + delta


def update_achieved_bound(state: RetrievalState, propagation: str) -> None:
    """Recompute the guaranteed bound from the *union* of loaded planes."""
    m = state.reader.meta
    errs, _ = loader._level_cost_tables(m, propagation)
    state.err_bound = m.eb + sum(
        float(errs[li][lv.nbits - state.planes_loaded[li]])
        for li, lv in enumerate(m.levels))
    state.bytes_read = state.reader.bytes_read


# ------------------------------------------------- batched (chunk groups)
#
# The three steps above, over a GROUP of equal-shape chunks at once: the
# scheduler in ``decode._retrieve_group`` stacks the per-chunk inputs and
# the backend's ``*_batch`` primitives run one kernel dispatch per phase /
# per (level, prefix) group instead of one per chunk.  Everything that is
# per-chunk accounting — reader fetches, planes_loaded, nb_partial,
# err_bound — is still computed per chunk, so the resulting states are
# indistinguishable from the per-chunk loop (bit-identical xhat included;
# the batch axis is an execution detail).  Backends without batched slots
# fall back to the scalar loop transparently.
#
# Each helper takes the call's resolved :class:`~.spec.ExecContext` —
# backend + optional 1-D codec mesh: with a mesh, the same stack is run
# through the backend's ``*_sharded`` primitives, which split the group
# across the mesh devices (``parallel.codec_mesh``).  Shard-local results
# come back as ordinary per-chunk streams, so the merge into per-chunk
# ``RetrievalState``s — and from there into ``ChunkedRetrievalState``'s
# aggregated ``bytes_read``/``err_bound`` — is byte-for-byte the
# single-device merge; nothing in the state records which policy (if any)
# produced it, which is what lets a sharded retrieval be refined
# unsharded and vice versa.

def _stack_reconstruct(ctx: ExecContext, shape, interp, anchors, yhat,
                       overrides):
    """Group reconstruct through the sharded slot when a mesh is active,
    the batched slot otherwise (callers have already ruled out B == 1)."""
    bk = ctx.bk
    if ctx.mesh is not None and bk.reconstruct_sharded is not None:
        return bk.reconstruct_sharded(shape, interp, anchors, yhat,
                                      ctx.mesh, overrides=overrides)
    return bk.reconstruct_batch(shape, interp, anchors, yhat,
                                overrides=overrides)


def initial_state_batch(readers: List[ArchiveReader],
                        ctx: ExecContext,
                        counters=None) -> List[RetrievalState]:
    """Coarsest approximation for B equal-shape chunks: one batched
    (optionally mesh-sharded) reconstruct builds every initial ``xhat``."""
    bk = ctx.bk
    if ((bk.reconstruct_batch is None and bk.reconstruct_sharded is None)
            or len(readers) == 1):
        return [initial_state(r, bk, counters=counters) for r in readers]
    m0 = readers[0].meta
    anchors = np.stack([r.anchors() for r in readers])
    yhat = [np.zeros((len(readers), lv.n), np.float64) for lv in m0.levels]
    overrides = [[_unpack_escapes(r.escapes(li))
                  for li in range(len(r.meta.levels))] for r in readers]
    xhat = _stack_reconstruct(ctx, m0.shape, m0.interp, anchors, yhat,
                              overrides)
    _count(counters, "reconstruct")
    states = []
    for b, r in enumerate(readers):
        m = r.meta
        full_err = m.eb + sum(
            float(lv.delta_table[lv.nbits]) *
            loader._prop_factor(m, lv.level, loader.SAFE)
            for lv in m.levels)
        states.append(RetrievalState(
            reader=r, planes_loaded=[0] * len(m.levels),
            nb_partial=[np.zeros(lv.n, np.uint32) for lv in m.levels],
            esc_idx=[o[0] for o in overrides[b]],
            xhat=xhat[b], err_bound=full_err, bytes_read=r.bytes_read))
    return states


def load_level_deltas_batch(states: List[RetrievalState],
                            keep_planes_list: List[List[int]],
                            ctx: ExecContext, cache=None, counters=None,
                            ) -> Tuple[List[List[np.ndarray]], List[bool]]:
    """Batched :func:`load_level_deltas` over B equal-shape chunk states.

    Plane fetches stay per chunk (each chunk's reader counts its own
    bytes), but the decode itself is grouped and each group runs as one
    batched dispatch (mesh-sharded across devices when the context
    carries a mesh).  The group key depends on the backend: with
    ``dynamic_low_zero`` the loaded-prefix length is a *runtime* operand,
    so jobs group by ``(nbits,)`` alone and chunks at different fidelities
    share one launch; legacy backends group by ``(nbits, prefix)``.
    Backends with the fused slots run each group as one
    ``decode_level_fused_batch`` megakernel launch (per-chunk ``nb_old``
    and ``eb`` ride along as runtime operands), and the next group's zlib
    inflate is prefetched on a worker thread while the current group's
    kernel runs.  Returns per-chunk delta streams and per-chunk any-new
    flags, exactly like B scalar calls.

    Cross-session serving hooks: with a ``cache``, each job first probes
    the shared plane cache (a hit skips the fetch and leaves the batch);
    and jobs from *different sessions over the same archive bytes* (equal
    ``cache_scope``) wanting the same prefix are deduplicated — one leader
    decodes, followers share the immutable result (``dedup_reuse`` in
    ``counters``).  Followers and cache hits host-compute their own delta
    (their ``nb_old`` differs from the leader's), so the fused fast path
    never changes what they see.  Chunks within one session always have
    distinct scopes, so single-request behaviour is unchanged.
    """
    bk, mesh = ctx.bk, ctx.mesh
    m0 = states[0].reader.meta
    B = len(states)
    L = len(m0.levels)
    delta_ys: List[List[Optional[np.ndarray]]] = \
        [[None] * L for _ in range(B)]
    any_new = [False] * B
    fused = bk.decode_level_fused_batch is not None
    jobs_per_level: List[List[Tuple[int, int]]] = [[] for _ in range(L)]
    resolved: dict = {}        # (level, chunk pos) -> (nb_new, delta|None)
    followers: dict = {}       # (level, leader pos) -> [follower pos]
    calls: list = []           # (level, nbits, [(chunk pos, want)], blobs)
    for li, lv0 in enumerate(m0.levels):
        jobs: List[Tuple[int, int]] = []     # (chunk pos, want)
        for b, st in enumerate(states):
            have = st.planes_loaded[li]
            want = max(have, keep_planes_list[b][li])
            if want > have:
                jobs.append((b, want))
            else:
                delta_ys[b][li] = np.zeros(lv0.n, np.float64)
        jobs_per_level[li] = jobs
        # resolve cache hits and dedupe same-(scope, prefix) decode jobs
        decode_jobs: List[Tuple[int, int]] = []
        leaders: dict = {}                   # cache key -> leader pos
        for b, want in jobs:
            key = _cache_key(states[b].reader, li, want)
            nb = cache.get(key) if (cache is not None and key is not None) \
                else None
            if nb is not None:
                lv = states[b].reader.meta.levels[li]
                cache.saved_fetch(sum(
                    lv.plane_sizes[i] for i in range(want)
                    if not states[b].reader.plane_fetched(li, i)))
                resolved[(li, b)] = (nb, None)
            elif key is not None and key in leaders:
                followers.setdefault((li, leaders[key]), []).append(b)
                _count(counters, "dedup_reuse")
            else:
                if key is not None:
                    leaders[key] = b
                decode_jobs.append((b, want))
        groups: dict = {}        # (nbits[, want]) -> [(chunk pos, want)]
        for b, want in decode_jobs:
            nbits = states[b].reader.meta.levels[li].nbits
            gk = (nbits,) if bk.dynamic_low_zero else (nbits, want)
            groups.setdefault(gk, []).append((b, want))
        for gk, grp in groups.items():
            blob_lists = []
            for b, want in grp:
                st = states[b]
                blobs: List[Optional[bytes]] = [None] * gk[0]
                for i in range(want):
                    blobs[i] = st.reader.plane(li, i)
                blob_lists.append(blobs)
            calls.append((li, gk[0], grp, blob_lists))

    # execute the collected group dispatches; with the fused slots, the
    # NEXT group's host inflate overlaps the current group's kernel
    prefetch = fused and bk.inflate_level_batch is not None and len(calls) > 1
    fut = None
    for k, (li, nbits, grp, blob_lists) in enumerate(calls):
        n = m0.levels[li].n
        words = None
        if prefetch:
            words = fut.result() if fut is not None \
                else bk.inflate_level_batch(blob_lists, nbits, n)
            if k + 1 < len(calls):
                nli, nnbits, _g, nbl = calls[k + 1]
                fut = _inflate_pool().submit(bk.inflate_level_batch, nbl,
                                             nnbits, m0.levels[nli].n)
            else:
                fut = None
        bs = [b for b, _ in grp]
        if fused:
            nb_olds = [states[b].nb_partial[li] for b in bs]
            ebs = [states[b].reader.meta.eb for b in bs]
            if (mesh is not None and bk.decode_level_fused_sharded is not None
                    and len(bs) > 1):
                outs = bk.decode_level_fused_sharded(blob_lists, nbits, n,
                                                     nb_olds, ebs, mesh,
                                                     words=words)
            else:
                outs = bk.decode_level_fused_batch(blob_lists, nbits, n,
                                                   nb_olds, ebs, words=words)
            _count(counters, "decode_level")
        elif (mesh is not None and bk.decode_level_sharded is not None
                and len(bs) > 1):
            outs = [(nb, None) for nb in
                    bk.decode_level_sharded(blob_lists, nbits, n, mesh)]
            _count(counters, "decode_level")
        elif bk.decode_level_batch is not None and len(bs) > 1:
            outs = [(nb, None) for nb in
                    bk.decode_level_batch(blob_lists, nbits, n)]
            _count(counters, "decode_level")
        else:
            outs = [(bk.decode_level(bl, nbits, n), None)
                    for bl in blob_lists]
            _count(counters, "decode_level", len(bs))
        for (b, want), (nb_new, dy) in zip(grp, outs):
            nb_new = _freeze(np.asarray(nb_new))
            key = _cache_key(states[b].reader, li, want)
            if cache is not None and key is not None:
                cache.put(key, nb_new)
            resolved[(li, b)] = (nb_new, dy)
            for fb in followers.get((li, b), ()):
                resolved[(li, fb)] = (nb_new, None)

    for li in range(L):
        for b, want in jobs_per_level[li]:
            nb_new, dy = resolved[(li, b)]
            st = states[b]
            if dy is None:
                dq = negabinary.from_negabinary(nb_new) - \
                    negabinary.from_negabinary(st.nb_partial[li])
                dy = dq.astype(np.float64) * 2.0 * st.reader.meta.eb
            delta_ys[b][li] = dy
            st.nb_partial[li] = nb_new
            st.planes_loaded[li] = want
            any_new[b] = True
    return delta_ys, any_new


def push_delta_batch(states: List[RetrievalState],
                     delta_ys: List[List[np.ndarray]],
                     ctx: ExecContext, counters=None) -> None:
    """Batched :func:`push_delta`: one zero-anchor cascade reconstructs
    every chunk's delta in a single stack (escape deltas pinned 0 per
    chunk, as in the scalar path), mesh-sharded when the context carries
    a mesh."""
    bk = ctx.bk
    if ((bk.reconstruct_batch is None and bk.reconstruct_sharded is None)
            or len(states) == 1):
        for st, dy in zip(states, delta_ys):
            push_delta(st, dy, bk, counters=counters)
        return
    m0 = states[0].reader.meta
    B = len(states)
    zero_anchors = np.zeros((B,) + tuple(m0.anchors_shape), np.float64)
    yhat = [np.stack([delta_ys[b][li] for b in range(B)])
            for li in range(len(m0.levels))]
    overrides = [[(idx, np.zeros(idx.size)) for idx in st.esc_idx]
                 for st in states]
    delta = _stack_reconstruct(ctx, m0.shape, m0.interp, zero_anchors,
                               yhat, overrides)
    _count(counters, "reconstruct")
    for b, st in enumerate(states):
        st.xhat = st.xhat + delta[b]
