"""IPComp codec pipeline: compress / retrieve / refine as an explicit package.

What used to be one monolithic ``core/ipcomp.py`` is five modules with two
seams — the backend registry between the algorithm and the substrate that
executes it, and the spec types between the public API and the pipeline:

  ``spec.py``
      :class:`Fidelity` (sum type over the four retrieval targets) and
      :class:`ExecPolicy` / :class:`ExecContext` (the bits-invariant
      execution knobs, validated once) — the native currency of the
      pipeline and the vocabulary of the object API (``repro.api``).
      Also home of :class:`IPCompDeprecationWarning`, the category every
      legacy free-function shim emits.
  ``backends.py``
      :class:`CodecBackend` registry.  Bundles the four hot-path primitives
      (decorrelate, encode_level, decode_level, reconstruct) per substrate;
      ships "numpy" (reference) and "jax" (Pallas kernels: ``interp_quant``
      / ``interp_recon`` / ``bitplane_pack`` / ``bitplane_unpack``).  All
      primitives are bit-identical across backends.
  ``encode.py``
      ``encode_array`` (Fig. 2 pipeline, policy-native) + ``chunk_bounds``
      slab splitting for the v2 container + the escape-channel packer;
      ``compress`` is the legacy shim.
  ``decode.py``
      ``read_archive`` (§5, Algorithms 1–2, Fidelity/ExecPolicy-native):
      DP-planned progressive loading, shape-group scheduled (batched
      and/or mesh-sharded where the backend supports it) per-chunk
      dispatch for v2 archives, largest-remainder byte-budget splitting
      (``split_budget``; refines split only the unspent remainder via
      ``refine_budgets``); ``retrieve`` / ``refine`` / ``decompress`` are
      the legacy shims.
  ``state.py``
      :class:`RetrievalState` / :class:`ChunkedRetrievalState` and the
      Algorithm 2 delta-cascade steps (``load_level_deltas``,
      ``push_delta``, ``update_achieved_bound``, ``initial_state``),
      batched variants taking the call's :class:`~.spec.ExecContext`.

``core.ipcomp`` remains as a thin re-export of this package, and
``repro.api`` builds the object surface (Codec / Archive /
ProgressiveReader) on the native entries, so both generations of imports
keep working unchanged.
"""
from .backends import AUTO, JAX, NUMPY, CodecBackend, get, names, register
from .decode import (decompress, open_archive, read_archive, refine,
                     refine_budgets, retrieve, split_budget)
from .encode import chunk_bounds, compress, encode_array, shape_groups
from .spec import (DEFAULT_POLICY, ExecContext, ExecPolicy, Fidelity,
                   IPCompDeprecationWarning)
from .state import ChunkedRetrievalState, RetrievalState

__all__ = [
    "AUTO", "JAX", "NUMPY", "CodecBackend", "get", "names", "register",
    "compress", "encode_array", "chunk_bounds", "shape_groups",
    "retrieve", "refine", "decompress", "read_archive", "open_archive",
    "split_budget", "refine_budgets",
    "Fidelity", "ExecPolicy", "ExecContext", "DEFAULT_POLICY",
    "IPCompDeprecationWarning",
    "RetrievalState", "ChunkedRetrievalState",
]
