"""IPComp codec pipeline: compress / retrieve / refine as an explicit package.

What used to be one monolithic ``core/ipcomp.py`` is four modules with one
seam — the backend registry — between the algorithm and the substrate that
executes it:

  ``backends.py``
      :class:`CodecBackend` registry.  Bundles the four hot-path primitives
      (decorrelate, encode_level, decode_level, reconstruct) per substrate;
      ships "numpy" (reference) and "jax" (Pallas kernels: ``interp_quant``
      / ``interp_recon`` / ``bitplane_pack`` / ``bitplane_unpack``).  All
      primitives are bit-identical across backends.
  ``encode.py``
      ``compress`` (Fig. 2 pipeline) + ``chunk_bounds`` slab splitting for
      the v2 container + the escape-channel packer.
  ``decode.py``
      ``retrieve`` / ``refine`` / ``decompress`` (§5, Algorithms 1–2):
      DP-planned progressive loading, shape-group scheduled (batched
      and/or mesh-sharded where the backend supports it) per-chunk
      dispatch for v2 archives, largest-remainder byte-budget splitting
      (``split_budget``; refines split only the unspent remainder via
      ``refine_budgets``).
  ``state.py``
      :class:`RetrievalState` / :class:`ChunkedRetrievalState` and the
      Algorithm 2 delta-cascade steps (``load_level_deltas``,
      ``push_delta``, ``update_achieved_bound``, ``initial_state``).

``core.ipcomp`` remains as a thin re-export of this package, so existing
imports keep working unchanged.
"""
from .backends import AUTO, JAX, NUMPY, CodecBackend, get, names, register
from .decode import (decompress, open_archive, refine, refine_budgets,
                     retrieve, split_budget)
from .encode import chunk_bounds, compress, shape_groups
from .state import ChunkedRetrievalState, RetrievalState

__all__ = [
    "AUTO", "JAX", "NUMPY", "CodecBackend", "get", "names", "register",
    "compress", "chunk_bounds", "shape_groups",
    "retrieve", "refine", "decompress", "open_archive", "split_budget",
    "refine_budgets",
    "RetrievalState", "ChunkedRetrievalState",
]
