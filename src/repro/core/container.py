"""IPComp archive container: random-access, independently decodable blocks.

v1 layout:  magic "IPC1" | u32 header_len | header JSON | blob section.
The header carries every per-level table the DP loader needs (plane sizes,
truncation-loss tables, escape sizes), so planning a retrieval touches ONLY
the header; the reader then fetches exactly the planned byte ranges —
``bytes_read`` is the retrieval-volume metric of Fig. 6/7.

v2 (chunked) layout:  magic "IPC2" | u32 header_len | header JSON |
concatenated v1 archives, one per fixed-size slab of the array (split along
axis 0).  Chunks are compressed and decoded independently — the unit of
batched/vmapped encoding and, later, of sharded compression — and each
chunk's interior is still the v1 format, so every per-chunk read goes
through the same ``ArchiveReader``.  The v2 header records only the slab
boundaries and byte extents.  ``parse_meta``/``ArchiveReader`` keep
accepting v1 archives unchanged; use ``open_reader`` to dispatch on the
magic when the version is unknown.
"""
from __future__ import annotations

import io
import json
import struct
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

MAGIC = b"IPC1"
MAGIC2 = b"IPC2"


class CorruptArchiveError(ValueError):
    """A buffer that is not a well-formed IPComp archive: wrong/unknown
    magic, truncated framing, undecodable header, or declared blob extents
    that fall outside the buffer.  Subclasses :class:`ValueError` so
    pre-existing ``except ValueError`` handling keeps working; raised with
    a message naming what is wrong and where, instead of leaking
    ``struct.unpack`` / ``json`` noise from the middle of the parser."""


def _framing(buf, what: str):
    """Shared v1/v2 framing checks -> (header_len, decoded header dict).

    Validates, in order, each boundary a truncated buffer can violate:
    the 4-byte magic, the 4-byte header length, the header body, and the
    header being decodable JSON.  ``buf[:4]`` is checked by the caller
    (it is the version dispatch); everything after it is checked here.
    """
    if len(buf) < 8:
        raise CorruptArchiveError(
            f"truncated {what}: {len(buf)} bytes, need at least 8 for "
            "magic + header length")
    (hlen,) = struct.unpack("<I", buf[4:8])
    if 8 + hlen > len(buf):
        raise CorruptArchiveError(
            f"truncated {what}: header claims {hlen} bytes but only "
            f"{len(buf) - 8} follow the framing")
    try:
        header = json.loads(bytes(buf[8:8 + hlen]).decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise CorruptArchiveError(f"undecodable {what} header: {e}") from e
    if not isinstance(header, dict):
        raise CorruptArchiveError(f"malformed {what} header: expected an "
                                  f"object, got {type(header).__name__}")
    return hlen, header


def _check_extent(offset: int, size: int, total: int, what: str) -> None:
    if offset < 0 or size < 0 or offset + size > total:
        raise CorruptArchiveError(
            f"corrupt archive: {what} extent [{offset}, {offset + size}) "
            f"falls outside the {total}-byte buffer")


@dataclass
class LevelMeta:
    level: int                 # L..1 (1 = finest)
    n: int                     # number of quantized scalars in this level
    nbits: int                 # occupied negabinary bits
    plane_sizes: List[int]     # compressed bytes per plane, MSB-first
    plane_offsets: List[int]   # absolute offsets into the archive
    delta_table: List[float]   # truncation loss per #discarded-planes b=0..nbits
    esc_size: int
    esc_offset: int


@dataclass
class ArchiveMeta:
    shape: List[int]
    dtype: str
    eb: float
    interp: str
    L: int
    anchors_offset: int
    anchors_size: int
    anchors_shape: List[int]
    levels: List[LevelMeta]
    header_end: int
    total_size: int

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape))


def write_archive(shape, dtype, eb, interp, L, anchors: np.ndarray,
                  level_blobs: List[List[bytes]], level_meta: List[Dict],
                  esc_blobs: List[bytes]) -> bytes:
    """Assemble the archive. level index 0 = level L (coarsest)."""
    levels = []
    blobs: List[bytes] = []
    cursor = [0]  # patched after header length known

    def put(b: bytes) -> int:
        off = cursor[0]
        blobs.append(b)
        cursor[0] += len(b)
        return off

    anc_bytes = anchors.astype(np.float64).tobytes()
    anc_off = put(anc_bytes)
    for i, (pl, lm, eb_blob) in enumerate(zip(level_blobs, level_meta, esc_blobs)):
        offs = [put(b) for b in pl]
        eo = put(eb_blob)
        levels.append(dict(
            level=lm["level"], n=lm["n"], nbits=lm["nbits"],
            plane_sizes=[len(b) for b in pl], plane_offsets=offs,
            delta_table=lm["delta_table"], esc_size=len(eb_blob), esc_offset=eo,
        ))

    def render(base: int) -> bytes:
        abs_levels = [dict(lv, plane_offsets=[o + base for o in lv["plane_offsets"]],
                           esc_offset=lv["esc_offset"] + base) for lv in levels]
        header = dict(shape=list(shape), dtype=str(dtype), eb=float(eb),
                      interp=interp, L=int(L), anchors_offset=anc_off + base,
                      anchors_size=len(anc_bytes),
                      anchors_shape=list(anchors.shape), levels=abs_levels)
        hj = json.dumps(header, separators=(",", ":")).encode()
        return MAGIC + struct.pack("<I", len(hj)) + hj

    # fixed-point on header length (offsets may gain digits once absolute)
    base = 0
    for _ in range(8):
        prefix = render(base)
        if len(prefix) == base:
            break
        base = len(prefix)
    return prefix + b"".join(blobs)


def parse_meta(buf) -> ArchiveMeta:
    """Parse a v1 header (accepts bytes or a zero-copy memoryview).

    Truncated / undecodable buffers raise :class:`CorruptArchiveError`
    with the failing boundary named; declared blob extents are checked
    against the buffer so a truncated *data* section fails here, at parse
    time, instead of as a short read deep inside a retrieval.
    """
    if bytes(buf[:4]) == MAGIC2:
        raise ValueError("chunked (v2) archive: use parse_chunked_meta / "
                         "open_reader, or the top-level retrieve()")
    if bytes(buf[:4]) != MAGIC:
        raise CorruptArchiveError(
            "not an IPComp archive: expected magic "
            f"{MAGIC!r} or {MAGIC2!r}, got {bytes(buf[:4])!r}")
    hlen, h = _framing(buf, "v1 archive")
    try:
        levels = [LevelMeta(**lv) for lv in h["levels"]]
        meta = ArchiveMeta(shape=h["shape"], dtype=h["dtype"], eb=h["eb"],
                           interp=h["interp"], L=h["L"],
                           anchors_offset=h["anchors_offset"],
                           anchors_size=h["anchors_size"],
                           anchors_shape=h["anchors_shape"], levels=levels,
                           header_end=8 + hlen, total_size=len(buf))
    except (KeyError, TypeError) as e:
        raise CorruptArchiveError(f"malformed v1 archive header: {e}") from e
    _check_extent(meta.anchors_offset, meta.anchors_size, len(buf),
                  "anchors")
    if meta.anchors_size != 8 * int(np.prod(meta.anchors_shape)):
        raise CorruptArchiveError(
            f"corrupt archive: anchors_size {meta.anchors_size} does not "
            f"match anchors_shape {tuple(meta.anchors_shape)} "
            "(8 bytes/element)")
    for li, lv in enumerate(meta.levels):
        # internal consistency, so a header-corrupt buffer fails HERE and
        # not as an IndexError when a plan first touches the bad level
        if not (len(lv.plane_offsets) == len(lv.plane_sizes) == lv.nbits
                and len(lv.delta_table) == lv.nbits + 1):
            raise CorruptArchiveError(
                f"corrupt archive: level {li} declares nbits={lv.nbits} "
                f"but carries {len(lv.plane_offsets)} plane offsets / "
                f"{len(lv.plane_sizes)} sizes / "
                f"{len(lv.delta_table)}-entry delta table")
        for pi, (off, size) in enumerate(zip(lv.plane_offsets,
                                             lv.plane_sizes)):
            _check_extent(off, size, len(buf), f"level {li} plane {pi}")
        _check_extent(lv.esc_offset, lv.esc_size, len(buf),
                      f"level {li} escapes")
    return meta


class ArchiveReader:
    """Byte-range reader with retrieval-volume accounting.

    Mirrors object-store / parallel-FS partial reads: the header is always
    resident (it is the index), data blobs are fetched on demand and counted.
    """

    def __init__(self, buf: bytes, meta: Optional[ArchiveMeta] = None):
        self.buf = buf
        # meta is immutable once parsed: callers that already validated the
        # buffer (repro.api.Archive) pass it in so a new reader — a fresh
        # bytes_read accounting scope — does not re-parse the header
        self.meta = parse_meta(buf) if meta is None else meta
        self.bytes_read = 0          # data-blob bytes fetched so far
        self._fetched: set = set()
        #: opaque hashable token identifying *which archive bytes* this
        #: reader serves, for cross-session plane-cache keying (None =
        #: never cached).  Set by the session/server that owns the reader;
        #: equal tokens MUST mean identical underlying archive bytes.
        self.cache_scope = None

    def read(self, offset: int, size: int, tag: str) -> bytes:
        if size and tag not in self._fetched:
            self._fetched.add(tag)
            self.bytes_read += size
        return self.buf[offset: offset + size]

    def plane_fetched(self, level_idx: int, plane_idx: int) -> bool:
        """Has this reader (= this accounting scope) already fetched the
        given plane blob?  Used by the plane cache to credit exactly the
        fetch bytes a cache hit avoids."""
        return f"L{level_idx}P{plane_idx}" in self._fetched

    def fork(self) -> "ArchiveReader":
        """An independent accounting branch of this reader: same bytes and
        meta, same fetched-range history and cumulative ``bytes_read`` at
        the fork point — after which the two readers count independently.
        This is how a refine that branches off a shared session keeps its
        own retrieval-volume ledger (cumulative over its whole ancestry)
        without sibling branches bleeding fetches into each other."""
        dup = ArchiveReader(self.buf, meta=self.meta)
        dup.bytes_read = self.bytes_read
        dup._fetched = set(self._fetched)
        dup.cache_scope = self.cache_scope
        return dup

    def anchors(self) -> np.ndarray:
        m = self.meta
        raw = self.read(m.anchors_offset, m.anchors_size, "anchors")
        return np.frombuffer(raw, np.float64).reshape(m.anchors_shape)

    def plane(self, level_idx: int, plane_idx: int) -> bytes:
        lv = self.meta.levels[level_idx]
        return self.read(lv.plane_offsets[plane_idx], lv.plane_sizes[plane_idx],
                         f"L{level_idx}P{plane_idx}")

    def escapes(self, level_idx: int) -> bytes:
        lv = self.meta.levels[level_idx]
        return self.read(lv.esc_offset, lv.esc_size, f"L{level_idx}E")


# ------------------------------------------------------------- v2 (chunked)

@dataclass
class ChunkMeta:
    start: int                 # slab [start, stop) along axis 0
    stop: int
    offset: int                # absolute byte offset of the chunk's archive
    size: int                  # byte length of the chunk's archive


@dataclass
class ChunkedMeta:
    shape: List[int]
    dtype: str
    eb: float
    interp: str
    chunks: List[ChunkMeta]
    header_end: int
    total_size: int

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape))


def write_chunked_archive(shape, dtype, eb, interp,
                          bounds: List, chunk_bufs: List[bytes]) -> bytes:
    """Frame independently compressed slab archives into one v2 container.

    ``bounds[i] = (start, stop)`` is chunk i's row range along axis 0;
    ``chunk_bufs[i]`` is its complete v1 archive.  The header deliberately
    carries no record of the producing backend: numpy- and jax-written
    archives are byte-identical, which the parity tests pin down.
    """
    sizes = [len(b) for b in chunk_bufs]
    rel = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)

    def render(base: int) -> bytes:
        chunks = [dict(start=int(a), stop=int(b), offset=int(rel[i]) + base,
                       size=sizes[i]) for i, (a, b) in enumerate(bounds)]
        header = dict(version=2, shape=list(shape), dtype=str(dtype),
                      eb=float(eb), interp=interp, chunks=chunks)
        hj = json.dumps(header, separators=(",", ":")).encode()
        return MAGIC2 + struct.pack("<I", len(hj)) + hj

    base = 0
    for _ in range(8):  # fixed-point on header length (offsets gain digits)
        prefix = render(base)
        if len(prefix) == base:
            break
        base = len(prefix)
    return prefix + b"".join(chunk_bufs)


def parse_chunked_meta(buf: bytes) -> ChunkedMeta:
    """Parse a v2 header; see :func:`parse_meta` for the error contract."""
    if bytes(buf[:4]) != MAGIC2:
        raise CorruptArchiveError(
            "not a chunked (v2) IPComp archive: expected magic "
            f"{MAGIC2!r}, got {bytes(buf[:4])!r}")
    hlen, h = _framing(buf, "v2 archive")
    try:
        chunks = [ChunkMeta(**c) for c in h["chunks"]]
        meta = ChunkedMeta(shape=h["shape"], dtype=h["dtype"], eb=h["eb"],
                           interp=h["interp"], chunks=chunks,
                           header_end=8 + hlen, total_size=len(buf))
    except (KeyError, TypeError) as e:
        raise CorruptArchiveError(f"malformed v2 archive header: {e}") from e
    for i, cm in enumerate(meta.chunks):
        _check_extent(cm.offset, cm.size, len(buf), f"chunk {i}")
        if not 0 <= cm.start <= cm.stop:
            raise CorruptArchiveError(
                f"corrupt archive: chunk {i} claims slab rows "
                f"[{cm.start}, {cm.stop})")
    return meta


class ChunkedArchiveReader:
    """Per-chunk ``ArchiveReader``s sharing one retrieval-volume counter.

    Sub-readers are created lazily and cached, so refinement re-reads of a
    chunk hit the same fetched-range set and ``bytes_read`` stays the true
    cumulative retrieval volume across progressive calls.
    """

    def __init__(self, buf: bytes, meta: Optional[ChunkedMeta] = None):
        self.buf = buf
        self.meta = parse_chunked_meta(buf) if meta is None else meta
        self._view = memoryview(buf)  # zero-copy chunk slicing
        self._readers: Dict[int, ArchiveReader] = {}
        #: see :attr:`ArchiveReader.cache_scope`; chunk sub-readers derive
        #: ``(cache_scope, chunk_index)`` so every chunk keys independently
        self.cache_scope = None

    def chunk_reader(self, i: int) -> ArchiveReader:
        if i not in self._readers:
            cm = self.meta.chunks[i]
            self._readers[i] = ArchiveReader(
                self._view[cm.offset: cm.offset + cm.size])
        sub = self._readers[i]
        if self.cache_scope is not None and sub.cache_scope is None:
            sub.cache_scope = (self.cache_scope, i)
        return sub

    def fork(self) -> "ChunkedArchiveReader":
        """Independent accounting branch (see :meth:`ArchiveReader.fork`):
        every already-opened chunk sub-reader is forked with its fetch
        history, so the branch's aggregated ``bytes_read`` starts at the
        fork point and diverges independently."""
        dup = ChunkedArchiveReader(self.buf, meta=self.meta)
        dup.cache_scope = self.cache_scope
        dup._readers = {i: r.fork() for i, r in self._readers.items()}
        return dup

    @property
    def bytes_read(self) -> int:
        return sum(r.bytes_read for r in self._readers.values())


def open_reader(buf: bytes, meta=None):
    """Version dispatch: v1 -> ArchiveReader, v2 -> ChunkedArchiveReader.

    Anything that is not a well-formed archive of either version —
    unknown magic, truncated framing or data section, undecodable header
    — raises :class:`CorruptArchiveError` here rather than failing later
    inside a retrieval.  ``meta`` skips the re-parse when the caller holds
    the already-validated header of this exact buffer (a new reader is a
    fresh ``bytes_read`` accounting scope, not a fresh parse).
    """
    if meta is not None:
        cls = (ChunkedArchiveReader if isinstance(meta, ChunkedMeta)
               else ArchiveReader)
        return cls(buf, meta=meta)
    if bytes(buf[:4]) == MAGIC2:
        return ChunkedArchiveReader(buf)
    if bytes(buf[:4]) != MAGIC:
        raise CorruptArchiveError(
            "not an IPComp archive: expected magic "
            f"{MAGIC!r} or {MAGIC2!r}, got {bytes(buf[:4])!r}")
    return ArchiveReader(buf)
