"""IPComp archive container: random-access, independently decodable blocks.

v1 layout:  magic "IPC1" | u32 header_len | header JSON | blob section.
The header carries every per-level table the DP loader needs (plane sizes,
truncation-loss tables, escape sizes), so planning a retrieval touches ONLY
the header; the reader then fetches exactly the planned byte ranges —
``bytes_read`` is the retrieval-volume metric of Fig. 6/7.

v2 (chunked) layout:  magic "IPC2" | u32 header_len | header JSON |
concatenated v1 archives, one per fixed-size slab of the array (split along
axis 0).  Chunks are compressed and decoded independently — the unit of
batched/vmapped encoding and, later, of sharded compression — and each
chunk's interior is still the v1 format, so every per-chunk read goes
through the same ``ArchiveReader``.  The v2 header records only the slab
boundaries and byte extents.  ``parse_meta``/``ArchiveReader`` keep
accepting v1 archives unchanged; use ``open_reader`` to dispatch on the
magic when the version is unknown.

v3 (plane-major) layout:  magic "IPC3" | u32 header_len | header JSON |
contiguous *segments*.  Where v2 is chunk-major (a coarse read of N
chunks does N scattered reads and every refine re-seeks every chunk), v3
groups bytes across the chunk grid: first a base region (all chunks'
anchors, then all chunks' per-level escape blobs), then one segment per
(level, bitplane) holding every chunk's blob for that plane — segments
ordered by a rate-distortion *ladder* fixed at write time
(``loader.ladder_order``: best error-reduction-per-byte first).  A
fidelity ladder therefore reads monotone contiguous byte ranges of the
container — the access pattern HTTP-range / object-store serving wants
(``docs/format.md`` §3 is the normative spec).  Per-chunk headers ride in
the v3 header with absolute offsets, so each chunk still decodes through
the ordinary ``ArchiveReader`` over the staged prefix.

All readers sit on the :class:`~.bytesource.ByteSource` seam (in-memory
buffer, mmap-backed file, range-counting test double): ``read(offset,
size, tag)`` never assumes the archive is resident in memory.
"""
from __future__ import annotations

import json
import struct
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .bytesource import BufferSource, ByteSource, as_source

MAGIC = b"IPC1"
MAGIC2 = b"IPC2"
MAGIC3 = b"IPC3"


class CorruptArchiveError(ValueError):
    """A buffer that is not a well-formed IPComp archive: wrong/unknown
    magic, truncated framing, undecodable header, or declared blob extents
    that fall outside the buffer.  Subclasses :class:`ValueError` so
    pre-existing ``except ValueError`` handling keeps working; raised with
    a message naming what is wrong and where, instead of leaking
    ``struct.unpack`` / ``json`` noise from the middle of the parser."""


def _read_exact(src: ByteSource, offset: int, size: int, what: str) -> bytes:
    """``src.read`` that enforces the no-short-reads contract.

    :class:`~.bytesource.ByteSource.read` declares short reads a contract
    violation, but an implementation over real storage (a truncated file,
    a remote object whose tail was never written) can still return fewer
    bytes than requested.  Every framing/data boundary in this module
    reads through here so that failure surfaces as a
    :class:`CorruptArchiveError` naming the boundary — never as a
    ``struct.error`` / ``json`` exception from the middle of the parser,
    and never as silently-corrupt decoded data.
    """
    data = bytes(src.read(offset, size))
    if len(data) != size:
        raise CorruptArchiveError(
            f"short read of {what}: requested [{offset}, {offset + size}) "
            f"but the source returned {len(data)} of {size} bytes")
    return data


def _magic(src: ByteSource) -> bytes:
    """The 4 magic bytes (empty-safe): the version dispatch token."""
    return bytes(src.read(0, 4))


def _framing(src: ByteSource, what: str):
    """Shared framing checks -> (header_len, decoded header dict).

    Validates, in order, each boundary a truncated buffer can violate:
    the 4-byte magic, the 4-byte header length, the header body, and the
    header being decodable JSON.  The magic itself is checked by the
    caller (it is the version dispatch); everything after it is checked
    here.  Operates on a :class:`~.bytesource.ByteSource`, so parsing a
    file-backed archive touches exactly the framing + header bytes.
    """
    if src.size < 8:
        raise CorruptArchiveError(
            f"truncated {what}: {src.size} bytes, need at least 8 for "
            "magic + header length")
    (hlen,) = struct.unpack(
        "<I", _read_exact(src, 4, 4, f"{what} header length"))
    if 8 + hlen > src.size:
        raise CorruptArchiveError(
            f"truncated {what}: header claims {hlen} bytes but only "
            f"{src.size - 8} follow the framing")
    try:
        header = json.loads(
            _read_exact(src, 8, hlen, f"{what} header").decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise CorruptArchiveError(f"undecodable {what} header: {e}") from e
    if not isinstance(header, dict):
        raise CorruptArchiveError(f"malformed {what} header: expected an "
                                  f"object, got {type(header).__name__}")
    return hlen, header


def _check_extent(offset: int, size: int, total: int, what: str) -> None:
    if offset < 0 or size < 0 or offset + size > total:
        raise CorruptArchiveError(
            f"corrupt archive: {what} extent [{offset}, {offset + size}) "
            f"falls outside the {total}-byte buffer")


@dataclass
class LevelMeta:
    level: int                 # L..1 (1 = finest)
    n: int                     # number of quantized scalars in this level
    nbits: int                 # occupied negabinary bits
    plane_sizes: List[int]     # compressed bytes per plane, MSB-first
    plane_offsets: List[int]   # absolute offsets into the archive
    delta_table: List[float]   # truncation loss per #discarded-planes b=0..nbits
    esc_size: int
    esc_offset: int


@dataclass
class ArchiveMeta:
    shape: List[int]
    dtype: str
    eb: float
    interp: str
    L: int
    anchors_offset: int
    anchors_size: int
    anchors_shape: List[int]
    levels: List[LevelMeta]
    header_end: int
    total_size: int

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape))


def write_archive(shape, dtype, eb, interp, L, anchors: np.ndarray,
                  level_blobs: List[List[bytes]], level_meta: List[Dict],
                  esc_blobs: List[bytes]) -> bytes:
    """Assemble the archive. level index 0 = level L (coarsest)."""
    levels = []
    blobs: List[bytes] = []
    cursor = [0]  # patched after header length known

    def put(b: bytes) -> int:
        off = cursor[0]
        blobs.append(b)
        cursor[0] += len(b)
        return off

    anc_bytes = anchors.astype(np.float64).tobytes()
    anc_off = put(anc_bytes)
    for i, (pl, lm, eb_blob) in enumerate(zip(level_blobs, level_meta, esc_blobs)):
        offs = [put(b) for b in pl]
        eo = put(eb_blob)
        levels.append(dict(
            level=lm["level"], n=lm["n"], nbits=lm["nbits"],
            plane_sizes=[len(b) for b in pl], plane_offsets=offs,
            delta_table=lm["delta_table"], esc_size=len(eb_blob), esc_offset=eo,
        ))

    def render(base: int) -> bytes:
        abs_levels = [dict(lv, plane_offsets=[o + base for o in lv["plane_offsets"]],
                           esc_offset=lv["esc_offset"] + base) for lv in levels]
        header = dict(shape=list(shape), dtype=str(dtype), eb=float(eb),
                      interp=interp, L=int(L), anchors_offset=anc_off + base,
                      anchors_size=len(anc_bytes),
                      anchors_shape=list(anchors.shape), levels=abs_levels)
        hj = json.dumps(header, separators=(",", ":")).encode()
        return MAGIC + struct.pack("<I", len(hj)) + hj

    # fixed-point on header length (offsets may gain digits once absolute)
    base = 0
    for _ in range(8):
        prefix = render(base)
        if len(prefix) == base:
            break
        base = len(prefix)
    return prefix + b"".join(blobs)


def _assemble_v1_meta(h: dict, header_end: int, total: int,
                      what: str = "v1 archive") -> ArchiveMeta:
    """Header dict -> validated :class:`ArchiveMeta` (shared by the v1
    parser and the v3 per-chunk headers): structural consistency plus
    per-blob extent bounds against the ``total``-byte buffer."""
    try:
        levels = [LevelMeta(**lv) for lv in h["levels"]]
        meta = ArchiveMeta(shape=h["shape"], dtype=h["dtype"], eb=h["eb"],
                           interp=h["interp"], L=h["L"],
                           anchors_offset=h["anchors_offset"],
                           anchors_size=h["anchors_size"],
                           anchors_shape=h["anchors_shape"], levels=levels,
                           header_end=header_end, total_size=total)
    except (KeyError, TypeError) as e:
        raise CorruptArchiveError(f"malformed {what} header: {e}") from e
    _check_extent(meta.anchors_offset, meta.anchors_size, total, "anchors")
    if meta.anchors_size != 8 * int(np.prod(meta.anchors_shape)):
        raise CorruptArchiveError(
            f"corrupt archive: anchors_size {meta.anchors_size} does not "
            f"match anchors_shape {tuple(meta.anchors_shape)} "
            "(8 bytes/element)")
    for li, lv in enumerate(meta.levels):
        # internal consistency, so a header-corrupt buffer fails HERE and
        # not as an IndexError when a plan first touches the bad level
        if not (len(lv.plane_offsets) == len(lv.plane_sizes) == lv.nbits
                and len(lv.delta_table) == lv.nbits + 1):
            raise CorruptArchiveError(
                f"corrupt archive: level {li} declares nbits={lv.nbits} "
                f"but carries {len(lv.plane_offsets)} plane offsets / "
                f"{len(lv.plane_sizes)} sizes / "
                f"{len(lv.delta_table)}-entry delta table")
        for pi, (off, size) in enumerate(zip(lv.plane_offsets,
                                             lv.plane_sizes)):
            _check_extent(off, size, total, f"level {li} plane {pi}")
        _check_extent(lv.esc_offset, lv.esc_size, total,
                      f"level {li} escapes")
    return meta


def _check_v1_blob_order(meta: ArchiveMeta) -> None:
    """Reject overlapping or out-of-order v1 blob extents.

    ``write_archive`` lays blobs out strictly in order — anchors, then per
    level its planes MSB-first then its escapes — with no overlap, and
    ``docs/format.md`` §1 makes that order normative.  Bounds checks alone
    accept headers whose extents alias each other (two planes sharing
    bytes, an escape blob inside the anchors) — structurally valid JSON
    that no writer produces and that silently decodes garbage.  Zero-size
    blobs carry no bytes and are exempt from the ordering (their recorded
    offset is meaningless).
    """
    cursor = meta.header_end

    def step(off: int, size: int, what: str) -> None:
        nonlocal cursor
        if size == 0:
            return
        if off < cursor:
            raise CorruptArchiveError(
                f"corrupt archive: {what} extent [{off}, {off + size}) "
                f"overlaps or precedes the preceding blob (expected "
                f"offset >= {cursor})")
        cursor = off + size

    step(meta.anchors_offset, meta.anchors_size, "anchors")
    for li, lv in enumerate(meta.levels):
        for pi, (off, size) in enumerate(zip(lv.plane_offsets,
                                             lv.plane_sizes)):
            step(off, size, f"level {li} plane {pi}")
        step(lv.esc_offset, lv.esc_size, f"level {li} escapes")


def parse_meta(buf) -> ArchiveMeta:
    """Parse a v1 header (accepts bytes, a zero-copy memoryview, or a
    :class:`~.bytesource.ByteSource`).

    Truncated / undecodable buffers raise :class:`CorruptArchiveError`
    with the failing boundary named; declared blob extents are checked
    against the buffer — bounds, overlap, and write order — so a
    truncated or aliased *data* section fails here, at parse time,
    instead of as a short read deep inside a retrieval.
    """
    src = as_source(buf)
    magic = _magic(src)
    if magic in (MAGIC2, MAGIC3):
        raise ValueError(
            f"{'chunked (v2)' if magic == MAGIC2 else 'plane-major (v3)'} "
            "archive: use "
            f"{'parse_chunked_meta' if magic == MAGIC2 else 'parse_v3_meta'}"
            " / open_reader, or the top-level retrieve()")
    if magic != MAGIC:
        raise CorruptArchiveError(
            "not an IPComp archive: expected magic "
            f"{MAGIC!r}, {MAGIC2!r} or {MAGIC3!r}, got {magic!r}")
    hlen, h = _framing(src, "v1 archive")
    meta = _assemble_v1_meta(h, 8 + hlen, src.size)
    _check_v1_blob_order(meta)
    return meta


class ArchiveReader:
    """Byte-range reader with retrieval-volume accounting.

    Mirrors object-store / parallel-FS partial reads: the header is always
    resident (it is the index), data blobs are fetched on demand and
    counted.  Backed by a :class:`~.bytesource.ByteSource` (any bytes-like
    object coerces to an in-memory source), so the same reader serves
    in-memory buffers, mmap-backed files, and range-accounting doubles.
    """

    def __init__(self, buf, meta: Optional[ArchiveMeta] = None):
        self.src = as_source(buf)
        # meta is immutable once parsed: callers that already validated the
        # buffer (repro.api.Archive) pass it in so a new reader — a fresh
        # bytes_read accounting scope — does not re-parse the header
        self.meta = parse_meta(self.src) if meta is None else meta
        self.bytes_read = 0          # data-blob bytes fetched so far
        self._fetched: set = set()
        #: opaque hashable token identifying *which archive bytes* this
        #: reader serves, for cross-session plane-cache keying (None =
        #: never cached).  Set by the session/server that owns the reader;
        #: equal tokens MUST mean identical underlying archive bytes.
        self.cache_scope = None

    def read(self, offset: int, size: int, tag: str) -> bytes:
        # fetch and validate BEFORE accounting: a failing/short read (a
        # remote source out of retries, a truncated file) must not mark
        # the tag fetched — a successful retry then still counts its bytes
        data = _read_exact(self.src, offset, size, f"blob {tag!r}") \
            if size else b""
        if size and tag not in self._fetched:
            self._fetched.add(tag)
            self.bytes_read += size
        return data

    def plane_fetched(self, level_idx: int, plane_idx: int) -> bool:
        """Has this reader (= this accounting scope) already fetched the
        given plane blob?  Used by the plane cache to credit exactly the
        fetch bytes a cache hit avoids."""
        return f"L{level_idx}P{plane_idx}" in self._fetched

    def fork(self) -> "ArchiveReader":
        """An independent accounting branch of this reader: same bytes and
        meta, same fetched-range history and cumulative ``bytes_read`` at
        the fork point — after which the two readers count independently.
        This is how a refine that branches off a shared session keeps its
        own retrieval-volume ledger (cumulative over its whole ancestry)
        without sibling branches bleeding fetches into each other."""
        dup = ArchiveReader(self.src, meta=self.meta)
        dup.bytes_read = self.bytes_read
        dup._fetched = set(self._fetched)
        dup.cache_scope = self.cache_scope
        return dup

    def anchors(self) -> np.ndarray:
        m = self.meta
        raw = self.read(m.anchors_offset, m.anchors_size, "anchors")
        return np.frombuffer(raw, np.float64).reshape(m.anchors_shape)

    def plane(self, level_idx: int, plane_idx: int) -> bytes:
        lv = self.meta.levels[level_idx]
        return self.read(lv.plane_offsets[plane_idx], lv.plane_sizes[plane_idx],
                         f"L{level_idx}P{plane_idx}")

    def escapes(self, level_idx: int) -> bytes:
        lv = self.meta.levels[level_idx]
        return self.read(lv.esc_offset, lv.esc_size, f"L{level_idx}E")


# ------------------------------------------------------------- v2 (chunked)

@dataclass
class ChunkMeta:
    start: int                 # slab [start, stop) along axis 0
    stop: int
    offset: int                # absolute byte offset of the chunk's archive
    size: int                  # byte length of the chunk's archive


@dataclass
class ChunkedMeta:
    shape: List[int]
    dtype: str
    eb: float
    interp: str
    chunks: List[ChunkMeta]
    header_end: int
    total_size: int

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape))


def write_chunked_archive(shape, dtype, eb, interp,
                          bounds: List, chunk_bufs: List[bytes]) -> bytes:
    """Frame independently compressed slab archives into one v2 container.

    ``bounds[i] = (start, stop)`` is chunk i's row range along axis 0;
    ``chunk_bufs[i]`` is its complete v1 archive.  The header deliberately
    carries no record of the producing backend: numpy- and jax-written
    archives are byte-identical, which the parity tests pin down.
    """
    sizes = [len(b) for b in chunk_bufs]
    rel = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)

    def render(base: int) -> bytes:
        chunks = [dict(start=int(a), stop=int(b), offset=int(rel[i]) + base,
                       size=sizes[i]) for i, (a, b) in enumerate(bounds)]
        header = dict(version=2, shape=list(shape), dtype=str(dtype),
                      eb=float(eb), interp=interp, chunks=chunks)
        hj = json.dumps(header, separators=(",", ":")).encode()
        return MAGIC2 + struct.pack("<I", len(hj)) + hj

    base = 0
    for _ in range(8):  # fixed-point on header length (offsets gain digits)
        prefix = render(base)
        if len(prefix) == base:
            break
        base = len(prefix)
    return prefix + b"".join(chunk_bufs)


def parse_chunked_meta(buf) -> ChunkedMeta:
    """Parse a v2 header; see :func:`parse_meta` for the error contract.

    Chunk extents are checked for bounds AND for the normative write
    order — ascending, non-overlapping, starting at or after the header
    end — so a header whose chunks alias each other's bytes (decoding
    garbage) or run backward (defeating streamed reads) is rejected here.
    """
    src = as_source(buf)
    if _magic(src) != MAGIC2:
        raise CorruptArchiveError(
            "not a chunked (v2) IPComp archive: expected magic "
            f"{MAGIC2!r}, got {_magic(src)!r}")
    hlen, h = _framing(src, "v2 archive")
    try:
        chunks = [ChunkMeta(**c) for c in h["chunks"]]
        meta = ChunkedMeta(shape=h["shape"], dtype=h["dtype"], eb=h["eb"],
                           interp=h["interp"], chunks=chunks,
                           header_end=8 + hlen, total_size=src.size)
    except (KeyError, TypeError) as e:
        raise CorruptArchiveError(f"malformed v2 archive header: {e}") from e
    cursor = meta.header_end
    for i, cm in enumerate(meta.chunks):
        _check_extent(cm.offset, cm.size, src.size, f"chunk {i}")
        if cm.offset < cursor:
            raise CorruptArchiveError(
                f"corrupt archive: chunk {i} extent "
                f"[{cm.offset}, {cm.offset + cm.size}) overlaps or "
                f"precedes the preceding chunk (expected offset >= "
                f"{cursor})")
        cursor = cm.offset + cm.size
        if not 0 <= cm.start <= cm.stop:
            raise CorruptArchiveError(
                f"corrupt archive: chunk {i} claims slab rows "
                f"[{cm.start}, {cm.stop})")
    return meta


class ChunkedArchiveReader:
    """Per-chunk ``ArchiveReader``s sharing one retrieval-volume counter.

    Sub-readers are created lazily and cached, so refinement re-reads of a
    chunk hit the same fetched-range set and ``bytes_read`` stays the true
    cumulative retrieval volume across progressive calls.
    """

    def __init__(self, buf, meta: Optional[ChunkedMeta] = None):
        self.src = as_source(buf)
        self.meta = parse_chunked_meta(self.src) if meta is None else meta
        self._readers: Dict[int, ArchiveReader] = {}
        #: see :attr:`ArchiveReader.cache_scope`; chunk sub-readers derive
        #: ``(cache_scope, chunk_index)`` so every chunk keys independently
        self.cache_scope = None

    def chunk_reader(self, i: int) -> ArchiveReader:
        if i not in self._readers:
            cm = self.meta.chunks[i]
            # a window, not a slice: sub-reader offsets are chunk-relative
            # but the reads land on the shared source at absolute container
            # positions, so range accounting sees real archive offsets
            self._readers[i] = ArchiveReader(
                self.src.window(cm.offset, cm.size))
        sub = self._readers[i]
        if self.cache_scope is not None and sub.cache_scope is None:
            sub.cache_scope = (self.cache_scope, i)
        return sub

    def fork(self) -> "ChunkedArchiveReader":
        """Independent accounting branch (see :meth:`ArchiveReader.fork`):
        every already-opened chunk sub-reader is forked with its fetch
        history, so the branch's aggregated ``bytes_read`` starts at the
        fork point and diverges independently."""
        dup = ChunkedArchiveReader(self.src, meta=self.meta)
        dup.cache_scope = self.cache_scope
        dup._readers = {i: r.fork() for i, r in self._readers.items()}
        return dup

    @property
    def bytes_read(self) -> int:
        return sum(r.bytes_read for r in self._readers.values())


# --------------------------------------------------------- v3 (plane-major)

@dataclass
class SlabMeta:
    """Chunk i's row range along axis 0 (v3 carries no per-chunk byte
    extent — chunk bytes are scattered across the plane-major segments;
    the per-chunk headers hold the absolute blob offsets)."""
    start: int
    stop: int


@dataclass
class SegmentMeta:
    """One contiguous v3 segment: every chunk's blob for one archive
    component, concatenated in chunk order.

    ``kind`` is ``"anchors"`` (level/plane = -1), ``"escapes"`` (one per
    level, plane = -1), or ``"planes"`` (one per (level, bitplane)).
    Segments tile the data section contiguously in ladder order.
    """
    kind: str
    level: int
    plane: int
    offset: int
    size: int


@dataclass
class V3Meta:
    shape: List[int]
    dtype: str
    eb: float
    interp: str
    chunks: List[SlabMeta]
    chunk_metas: List[ArchiveMeta]     # per-chunk v1 headers, absolute offsets
    segments: List[SegmentMeta]        # contiguous, ladder order
    header_end: int
    total_size: int
    # derived at parse time:
    plane_segments: List[SegmentMeta] = field(default_factory=list)
    base_end: int = 0                  # end of the anchors+escapes region
    cum_bytes: List[int] = field(default_factory=list)

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape))

    def ladder_keeps(self, t: int) -> List[List[int]]:
        """Per-chunk MSB-first keep counts implied by the first ``t``
        plane segments of the ladder.  Within a level, segments appear in
        ascending plane order (enforced at parse), so the count of level-l
        segments in the prefix IS chunk c's loaded-plane prefix for level
        l (clamped to the chunk's own nbits — a ragged tail chunk may
        occupy fewer bits than the grid maximum)."""
        counts: Dict[int, int] = {}
        for s in self.plane_segments[:t]:
            counts[s.level] = counts.get(s.level, 0) + 1
        return [[min(counts.get(li, 0), lv.nbits)
                 for li, lv in enumerate(m.levels)]
                for m in self.chunk_metas]


def write_v3_archive(shape, dtype, eb, interp,
                     bounds: List, chunk_bufs: List[bytes]) -> bytes:
    """Re-lay per-slab v1 archives into one plane-major v3 container.

    Takes exactly the inputs of :func:`write_chunked_archive` — so any v2
    producer (and any existing v2 archive, via its chunk extents) can emit
    v3 — but instead of concatenating the chunk archives whole, their
    blobs are regrouped across the chunk grid: anchors segment, per-level
    escapes segments, then one segment per (level, bitplane) in the greedy
    rate-distortion ladder order (``loader.ladder_order``: most error
    reduction per byte first, SAFE propagation, deterministic
    tie-breaks).  The layout IS the retrieval schedule: a fidelity ladder
    reads a monotonically growing contiguous prefix of the data section.
    """
    from . import loader  # function-level: loader imports this module

    metas = [parse_meta(b) for b in chunk_bufs]
    order = loader.ladder_order(metas)
    nlev = max(len(m.levels) for m in metas)

    blobs: List[bytes] = []
    cursor = [0]                       # relative to the data section
    segments: List[dict] = []

    def put(buf_i: int, off: int, size: int) -> int:
        pos = cursor[0]
        blobs.append(bytes(chunk_bufs[buf_i][off: off + size]))
        cursor[0] += size
        return pos

    def seg(kind: str, level: int, plane: int, members) -> None:
        start = cursor[0]
        for c, off, size in members:
            rel_offsets[c][kind, level, plane] = put(c, off, size)
        segments.append(dict(kind=kind, level=level, plane=plane,
                             offset=start, size=cursor[0] - start))

    rel_offsets: List[Dict[tuple, int]] = [{} for _ in metas]
    seg("anchors", -1, -1,
        [(c, m.anchors_offset, m.anchors_size) for c, m in enumerate(metas)])
    for li in range(nlev):
        seg("escapes", li, -1,
            [(c, m.levels[li].esc_offset, m.levels[li].esc_size)
             for c, m in enumerate(metas) if li < len(m.levels)])
    for li, k in order:
        seg("planes", li, k,
            [(c, m.levels[li].plane_offsets[k], m.levels[li].plane_sizes[k])
             for c, m in enumerate(metas)
             if li < len(m.levels) and k < m.levels[li].nbits])

    def render(base: int) -> bytes:
        chunk_headers = []
        for c, m in enumerate(metas):
            rel = rel_offsets[c]
            levels = [dict(
                level=lv.level, n=lv.n, nbits=lv.nbits,
                plane_sizes=list(lv.plane_sizes),
                plane_offsets=[rel["planes", li, k] + base
                               for k in range(lv.nbits)],
                delta_table=list(lv.delta_table), esc_size=lv.esc_size,
                esc_offset=rel["escapes", li, -1] + base,
            ) for li, lv in enumerate(m.levels)]
            chunk_headers.append(dict(
                shape=list(m.shape), dtype=m.dtype, eb=m.eb,
                interp=m.interp, L=m.L,
                anchors_offset=rel["anchors", -1, -1] + base,
                anchors_size=m.anchors_size,
                anchors_shape=list(m.anchors_shape), levels=levels))
        header = dict(
            version=3, shape=list(shape), dtype=str(dtype), eb=float(eb),
            interp=interp,
            chunks=[dict(start=int(a), stop=int(b)) for a, b in bounds],
            chunk_headers=chunk_headers,
            segments=[dict(s, offset=s["offset"] + base) for s in segments])
        hj = json.dumps(header, separators=(",", ":")).encode()
        return MAGIC3 + struct.pack("<I", len(hj)) + hj

    base = 0
    for _ in range(8):  # fixed-point on header length (offsets gain digits)
        prefix = render(base)
        if len(prefix) == base:
            break
        base = len(prefix)
    return prefix + b"".join(blobs)


def parse_v3_meta(buf) -> V3Meta:
    """Parse + validate a v3 header; see :func:`parse_meta` for the error
    contract.

    Beyond framing and per-blob bounds, the segment directory is held to
    the format's structural promises — they are what make the streaming
    access pattern provable, so violations are corruption, not style:

    * segments tile ``[header_end, total_size)`` contiguously, in order;
    * all base segments (anchors, escapes) precede all plane segments,
      and within a level plane segments appear MSB-first (ascending);
    * every chunk blob lies inside its matching segment, blobs sit in
      chunk order, and each segment's size is exactly its blobs' sum.
    """
    src = as_source(buf)
    if _magic(src) != MAGIC3:
        raise CorruptArchiveError(
            "not a plane-major (v3) IPComp archive: expected magic "
            f"{MAGIC3!r}, got {_magic(src)!r}")
    hlen, h = _framing(src, "v3 archive")
    total = src.size
    header_end = 8 + hlen
    try:
        if h.get("version") != 3:
            raise CorruptArchiveError(
                f"corrupt archive: v3 magic but header version "
                f"{h.get('version')!r}")
        slabs = [SlabMeta(start=int(c["start"]), stop=int(c["stop"]))
                 for c in h["chunks"]]
        segments = [SegmentMeta(kind=s["kind"], level=int(s["level"]),
                                plane=int(s["plane"]), offset=int(s["offset"]),
                                size=int(s["size"])) for s in h["segments"]]
        chunk_metas = [_assemble_v1_meta(ch, header_end, total,
                                         what=f"v3 chunk {c}")
                       for c, ch in enumerate(h["chunk_headers"])]
        if len(slabs) != len(chunk_metas):
            raise CorruptArchiveError(
                f"corrupt archive: {len(slabs)} chunk slabs but "
                f"{len(chunk_metas)} chunk headers")
        meta = V3Meta(shape=h["shape"], dtype=h["dtype"], eb=h["eb"],
                      interp=h["interp"], chunks=slabs,
                      chunk_metas=chunk_metas, segments=segments,
                      header_end=header_end, total_size=total)
    except (KeyError, TypeError) as e:
        raise CorruptArchiveError(f"malformed v3 archive header: {e}") from e
    for i, cm in enumerate(meta.chunks):
        if not 0 <= cm.start <= cm.stop:
            raise CorruptArchiveError(
                f"corrupt archive: chunk {i} claims slab rows "
                f"[{cm.start}, {cm.stop})")

    # -- segment directory: contiguity, ordering, and a (kind, level,
    #    plane) index for the blob containment pass below
    seg_index: Dict[tuple, SegmentMeta] = {}
    cursor = header_end
    seen_planes = False
    last_plane: Dict[int, int] = {}
    for si, s in enumerate(meta.segments):
        if s.kind not in ("anchors", "escapes", "planes"):
            raise CorruptArchiveError(
                f"corrupt archive: segment {si} has unknown kind "
                f"{s.kind!r}")
        _check_extent(s.offset, s.size, total, f"segment {si}")
        if s.offset != cursor:
            raise CorruptArchiveError(
                f"corrupt archive: segment {si} ({s.kind}) starts at "
                f"{s.offset}, expected {cursor} — v3 segments must tile "
                "the data section contiguously in ladder order")
        cursor = s.offset + s.size
        if s.kind == "planes":
            seen_planes = True
            prev = last_plane.get(s.level, -1)
            if s.plane != prev + 1:
                raise CorruptArchiveError(
                    f"corrupt archive: level {s.level} plane segment "
                    f"{s.plane} follows plane {prev} — within a level, "
                    "plane segments must appear MSB-first (ascending)")
            last_plane[s.level] = s.plane
        elif seen_planes:
            raise CorruptArchiveError(
                f"corrupt archive: base segment {si} ({s.kind}) after the "
                "first plane segment — anchors and escapes must precede "
                "the ladder")
        key = (s.kind, s.level, s.plane)
        if key in seg_index:
            raise CorruptArchiveError(
                f"corrupt archive: duplicate segment {key}")
        seg_index[key] = s
    if cursor != total:
        raise CorruptArchiveError(
            f"corrupt archive: v3 segments end at {cursor} but the buffer "
            f"is {total} bytes")

    # -- every chunk blob inside its matching segment, in chunk order,
    #    sizes summing exactly to the segment size (no gaps, no aliasing)
    sums: Dict[tuple, int] = {k: 0 for k in seg_index}
    seg_cursor: Dict[tuple, int] = {k: s.offset for k, s in seg_index.items()}

    def member(key: tuple, off: int, size: int, what: str) -> None:
        s = seg_index.get(key)
        if s is None:
            raise CorruptArchiveError(
                f"corrupt archive: {what} has no segment {key}")
        if size and not (s.offset <= off and off + size <= s.offset + s.size):
            raise CorruptArchiveError(
                f"corrupt archive: {what} extent [{off}, {off + size}) "
                f"falls outside its segment "
                f"[{s.offset}, {s.offset + s.size})")
        if size and off < seg_cursor[key]:
            raise CorruptArchiveError(
                f"corrupt archive: {what} extent [{off}, {off + size}) "
                "overlaps or precedes the preceding chunk's blob in its "
                "segment")
        if size:
            seg_cursor[key] = off + size
        sums[key] += size

    for c, m in enumerate(meta.chunk_metas):
        member(("anchors", -1, -1), m.anchors_offset, m.anchors_size,
               f"chunk {c} anchors")
        for li, lv in enumerate(m.levels):
            member(("escapes", li, -1), lv.esc_offset, lv.esc_size,
                   f"chunk {c} level {li} escapes")
            for k in range(lv.nbits):
                member(("planes", li, k), lv.plane_offsets[k],
                       lv.plane_sizes[k], f"chunk {c} level {li} plane {k}")
    for key, s in seg_index.items():
        if sums[key] != s.size:
            raise CorruptArchiveError(
                f"corrupt archive: segment {key} declares {s.size} bytes "
                f"but its chunk blobs sum to {sums[key]}")

    # -- derived plan tables: the ladder prefix <-> byte cost map
    meta.plane_segments = [s for s in meta.segments if s.kind == "planes"]
    meta.base_end = (meta.plane_segments[0].offset if meta.plane_segments
                     else total)
    esc_total = sum(s.size for s in meta.segments if s.kind == "escapes")
    cum = [esc_total]  # plan floor: escapes always load (anchors excluded,
    for s in meta.plane_segments:  # matching v1/v2 loaded_bytes semantics)
        cum.append(cum[-1] + s.size)
    meta.cum_bytes = cum
    return meta


class _Stage:
    """The staged contiguous prefix of a v3 data section, shared by
    reference across reader forks (archive bytes are immutable, so
    branches can pool their transport buffer while keeping independent
    fetch accounting)."""

    def __init__(self, start: int):
        self.start = start
        self.buf = bytearray()

    @property
    def end(self) -> int:
        return self.start + len(self.buf)


class _StagedSource(ByteSource):
    """Chunk-blob reads of a :class:`V3ArchiveReader` resolve here: ranges
    inside the staged prefix are served from memory (bytes copies — small
    blobs — so the growable stage is never pinned by exported views);
    anything not yet staged falls through to the underlying source.  The
    fall-through keeps direct ``chunk_reader`` use correct without
    ``ensure_prefix``; planned retrievals always stage first, so their
    source sees exactly one contiguous range per ladder step."""

    def __init__(self, owner: "V3ArchiveReader"):
        self._owner = owner

    def read(self, offset: int, size: int):
        st = self._owner._stage
        if offset >= st.start and offset + size <= st.end:
            lo = offset - st.start
            return bytes(st.buf[lo: lo + size])
        return self._owner.src.read(offset, size)

    @property
    def size(self) -> int:
        return self._owner.src.size


class V3ArchiveReader:
    """Plane-major reader: per-chunk ``ArchiveReader``s over one staged
    contiguous prefix of the data section.

    The retrieval contract of the v3 layout: :meth:`ensure_prefix` grows
    the staged region to cover the first ``t`` ladder segments with ONE
    contiguous source read — successive calls with non-decreasing ``t``
    issue monotonically increasing, gap-free ranges (the property
    ``tests/test_v3_format.py`` pins through a counting source).  Chunk
    decodes then read their blobs from the stage with the usual per-tag
    ``bytes_read`` accounting, so retrieval-volume semantics match v1/v2
    exactly.
    """

    def __init__(self, buf, meta: Optional[V3Meta] = None):
        self.src = as_source(buf)
        self.meta = parse_v3_meta(self.src) if meta is None else meta
        self._stage = _Stage(self.meta.header_end)
        self._readers: Dict[int, ArchiveReader] = {}
        #: see :attr:`ArchiveReader.cache_scope`; chunk sub-readers derive
        #: ``(cache_scope, chunk_index)`` — with the level/prefix the state
        #: layer appends, cache keys align 1:1 with v3 segment-prefix ids
        self.cache_scope = None

    def ensure_prefix(self, t: int) -> None:
        """Stage the base region plus the first ``t`` plane segments.

        Issues at most one source read: the contiguous gap between the
        current staged end and the prefix's end.  Shrinking ``t`` is a
        no-op (the stage only grows, like loaded planes)."""
        m = self.meta
        t = max(0, min(int(t), len(m.plane_segments)))
        target = m.base_end if t == 0 else (
            m.plane_segments[t - 1].offset + m.plane_segments[t - 1].size)
        st = self._stage
        if target > st.end:
            # validated before appending: a short staged read would shift
            # every downstream blob offset and decode garbage silently
            st.buf += _read_exact(self.src, st.end, target - st.end,
                                  f"v3 ladder prefix t={t}")

    def chunk_reader(self, i: int) -> ArchiveReader:
        if i not in self._readers:
            self._readers[i] = ArchiveReader(
                _StagedSource(self), meta=self.meta.chunk_metas[i])
        sub = self._readers[i]
        if self.cache_scope is not None and sub.cache_scope is None:
            sub.cache_scope = (self.cache_scope, i)
        return sub

    def fork(self) -> "V3ArchiveReader":
        """Independent accounting branch (see :meth:`ArchiveReader.fork`).
        The staged prefix is shared by reference — it is a transport cache
        of immutable bytes, not accounting state — so sibling branches
        never re-fetch ranges either already staged."""
        dup = V3ArchiveReader(self.src, meta=self.meta)
        dup._stage = self._stage
        dup.cache_scope = self.cache_scope
        for i, r in self._readers.items():
            sub = ArchiveReader(_StagedSource(dup), meta=r.meta)
            sub.bytes_read = r.bytes_read
            sub._fetched = set(r._fetched)
            sub.cache_scope = r.cache_scope
            dup._readers[i] = sub
        return dup

    @property
    def bytes_read(self) -> int:
        return sum(r.bytes_read for r in self._readers.values())


def open_reader(buf, meta=None):
    """Version dispatch: v1 -> ArchiveReader, v2 -> ChunkedArchiveReader,
    v3 -> V3ArchiveReader.

    Anything that is not a well-formed archive of a known version —
    unknown magic, truncated framing or data section, undecodable header
    — raises :class:`CorruptArchiveError` here rather than failing later
    inside a retrieval.  ``meta`` skips the re-parse when the caller holds
    the already-validated header of this exact buffer (a new reader is a
    fresh ``bytes_read`` accounting scope, not a fresh parse).  Accepts
    bytes-like buffers or any :class:`~.bytesource.ByteSource`.
    """
    if meta is not None:
        if isinstance(meta, V3Meta):
            cls = V3ArchiveReader
        elif isinstance(meta, ChunkedMeta):
            cls = ChunkedArchiveReader
        else:
            cls = ArchiveReader
        return cls(buf, meta=meta)
    src = as_source(buf)
    magic = _magic(src)
    if magic == MAGIC3:
        return V3ArchiveReader(src)
    if magic == MAGIC2:
        return ChunkedArchiveReader(src)
    if magic != MAGIC:
        raise CorruptArchiveError(
            "not an IPComp archive: expected magic "
            f"{MAGIC!r}, {MAGIC2!r} or {MAGIC3!r}, got {magic!r}")
    return ArchiveReader(src)
