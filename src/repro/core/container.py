"""IPComp archive container: random-access, independently decodable blocks.

v1 layout:  magic "IPC1" | u32 header_len | header JSON | blob section.
The header carries every per-level table the DP loader needs (plane sizes,
truncation-loss tables, escape sizes), so planning a retrieval touches ONLY
the header; the reader then fetches exactly the planned byte ranges —
``bytes_read`` is the retrieval-volume metric of Fig. 6/7.

v2 (chunked) layout:  magic "IPC2" | u32 header_len | header JSON |
concatenated v1 archives, one per fixed-size slab of the array (split along
axis 0).  Chunks are compressed and decoded independently — the unit of
batched/vmapped encoding and, later, of sharded compression — and each
chunk's interior is still the v1 format, so every per-chunk read goes
through the same ``ArchiveReader``.  The v2 header records only the slab
boundaries and byte extents.  ``parse_meta``/``ArchiveReader`` keep
accepting v1 archives unchanged; use ``open_reader`` to dispatch on the
magic when the version is unknown.
"""
from __future__ import annotations

import io
import json
import struct
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

MAGIC = b"IPC1"
MAGIC2 = b"IPC2"


@dataclass
class LevelMeta:
    level: int                 # L..1 (1 = finest)
    n: int                     # number of quantized scalars in this level
    nbits: int                 # occupied negabinary bits
    plane_sizes: List[int]     # compressed bytes per plane, MSB-first
    plane_offsets: List[int]   # absolute offsets into the archive
    delta_table: List[float]   # truncation loss per #discarded-planes b=0..nbits
    esc_size: int
    esc_offset: int


@dataclass
class ArchiveMeta:
    shape: List[int]
    dtype: str
    eb: float
    interp: str
    L: int
    anchors_offset: int
    anchors_size: int
    anchors_shape: List[int]
    levels: List[LevelMeta]
    header_end: int
    total_size: int

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape))


def write_archive(shape, dtype, eb, interp, L, anchors: np.ndarray,
                  level_blobs: List[List[bytes]], level_meta: List[Dict],
                  esc_blobs: List[bytes]) -> bytes:
    """Assemble the archive. level index 0 = level L (coarsest)."""
    levels = []
    blobs: List[bytes] = []
    cursor = [0]  # patched after header length known

    def put(b: bytes) -> int:
        off = cursor[0]
        blobs.append(b)
        cursor[0] += len(b)
        return off

    anc_bytes = anchors.astype(np.float64).tobytes()
    anc_off = put(anc_bytes)
    for i, (pl, lm, eb_blob) in enumerate(zip(level_blobs, level_meta, esc_blobs)):
        offs = [put(b) for b in pl]
        eo = put(eb_blob)
        levels.append(dict(
            level=lm["level"], n=lm["n"], nbits=lm["nbits"],
            plane_sizes=[len(b) for b in pl], plane_offsets=offs,
            delta_table=lm["delta_table"], esc_size=len(eb_blob), esc_offset=eo,
        ))

    def render(base: int) -> bytes:
        abs_levels = [dict(lv, plane_offsets=[o + base for o in lv["plane_offsets"]],
                           esc_offset=lv["esc_offset"] + base) for lv in levels]
        header = dict(shape=list(shape), dtype=str(dtype), eb=float(eb),
                      interp=interp, L=int(L), anchors_offset=anc_off + base,
                      anchors_size=len(anc_bytes),
                      anchors_shape=list(anchors.shape), levels=abs_levels)
        hj = json.dumps(header, separators=(",", ":")).encode()
        return MAGIC + struct.pack("<I", len(hj)) + hj

    # fixed-point on header length (offsets may gain digits once absolute)
    base = 0
    for _ in range(8):
        prefix = render(base)
        if len(prefix) == base:
            break
        base = len(prefix)
    return prefix + b"".join(blobs)


def parse_meta(buf) -> ArchiveMeta:
    """Parse a v1 header (accepts bytes or a zero-copy memoryview)."""
    if buf[:4] == MAGIC2:
        raise ValueError("chunked (v2) archive: use parse_chunked_meta / "
                         "open_reader, or the top-level retrieve()")
    assert buf[:4] == MAGIC, "not an IPComp archive"
    (hlen,) = struct.unpack("<I", buf[4:8])
    h = json.loads(bytes(buf[8:8 + hlen]).decode())
    levels = [LevelMeta(**lv) for lv in h["levels"]]
    return ArchiveMeta(shape=h["shape"], dtype=h["dtype"], eb=h["eb"],
                       interp=h["interp"], L=h["L"],
                       anchors_offset=h["anchors_offset"],
                       anchors_size=h["anchors_size"],
                       anchors_shape=h["anchors_shape"], levels=levels,
                       header_end=8 + hlen, total_size=len(buf))


class ArchiveReader:
    """Byte-range reader with retrieval-volume accounting.

    Mirrors object-store / parallel-FS partial reads: the header is always
    resident (it is the index), data blobs are fetched on demand and counted.
    """

    def __init__(self, buf: bytes):
        self.buf = buf
        self.meta = parse_meta(buf)
        self.bytes_read = 0          # data-blob bytes fetched so far
        self._fetched: set = set()

    def read(self, offset: int, size: int, tag: str) -> bytes:
        if size and tag not in self._fetched:
            self._fetched.add(tag)
            self.bytes_read += size
        return self.buf[offset: offset + size]

    def anchors(self) -> np.ndarray:
        m = self.meta
        raw = self.read(m.anchors_offset, m.anchors_size, "anchors")
        return np.frombuffer(raw, np.float64).reshape(m.anchors_shape)

    def plane(self, level_idx: int, plane_idx: int) -> bytes:
        lv = self.meta.levels[level_idx]
        return self.read(lv.plane_offsets[plane_idx], lv.plane_sizes[plane_idx],
                         f"L{level_idx}P{plane_idx}")

    def escapes(self, level_idx: int) -> bytes:
        lv = self.meta.levels[level_idx]
        return self.read(lv.esc_offset, lv.esc_size, f"L{level_idx}E")


# ------------------------------------------------------------- v2 (chunked)

@dataclass
class ChunkMeta:
    start: int                 # slab [start, stop) along axis 0
    stop: int
    offset: int                # absolute byte offset of the chunk's archive
    size: int                  # byte length of the chunk's archive


@dataclass
class ChunkedMeta:
    shape: List[int]
    dtype: str
    eb: float
    interp: str
    chunks: List[ChunkMeta]
    header_end: int
    total_size: int

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape))


def write_chunked_archive(shape, dtype, eb, interp,
                          bounds: List, chunk_bufs: List[bytes]) -> bytes:
    """Frame independently compressed slab archives into one v2 container.

    ``bounds[i] = (start, stop)`` is chunk i's row range along axis 0;
    ``chunk_bufs[i]`` is its complete v1 archive.  The header deliberately
    carries no record of the producing backend: numpy- and jax-written
    archives are byte-identical, which the parity tests pin down.
    """
    sizes = [len(b) for b in chunk_bufs]
    rel = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)

    def render(base: int) -> bytes:
        chunks = [dict(start=int(a), stop=int(b), offset=int(rel[i]) + base,
                       size=sizes[i]) for i, (a, b) in enumerate(bounds)]
        header = dict(version=2, shape=list(shape), dtype=str(dtype),
                      eb=float(eb), interp=interp, chunks=chunks)
        hj = json.dumps(header, separators=(",", ":")).encode()
        return MAGIC2 + struct.pack("<I", len(hj)) + hj

    base = 0
    for _ in range(8):  # fixed-point on header length (offsets gain digits)
        prefix = render(base)
        if len(prefix) == base:
            break
        base = len(prefix)
    return prefix + b"".join(chunk_bufs)


def parse_chunked_meta(buf: bytes) -> ChunkedMeta:
    assert buf[:4] == MAGIC2, "not a chunked (v2) IPComp archive"
    (hlen,) = struct.unpack("<I", buf[4:8])
    h = json.loads(buf[8:8 + hlen].decode())
    chunks = [ChunkMeta(**c) for c in h["chunks"]]
    return ChunkedMeta(shape=h["shape"], dtype=h["dtype"], eb=h["eb"],
                       interp=h["interp"], chunks=chunks,
                       header_end=8 + hlen, total_size=len(buf))


class ChunkedArchiveReader:
    """Per-chunk ``ArchiveReader``s sharing one retrieval-volume counter.

    Sub-readers are created lazily and cached, so refinement re-reads of a
    chunk hit the same fetched-range set and ``bytes_read`` stays the true
    cumulative retrieval volume across progressive calls.
    """

    def __init__(self, buf: bytes):
        self.buf = buf
        self.meta = parse_chunked_meta(buf)
        self._view = memoryview(buf)  # zero-copy chunk slicing
        self._readers: Dict[int, ArchiveReader] = {}

    def chunk_reader(self, i: int) -> ArchiveReader:
        if i not in self._readers:
            cm = self.meta.chunks[i]
            self._readers[i] = ArchiveReader(
                self._view[cm.offset: cm.offset + cm.size])
        return self._readers[i]

    @property
    def bytes_read(self) -> int:
        return sum(r.bytes_read for r in self._readers.values())


def open_reader(buf: bytes):
    """Version dispatch: v1 -> ArchiveReader, v2 -> ChunkedArchiveReader."""
    if buf[:4] == MAGIC2:
        return ChunkedArchiveReader(buf)
    return ArchiveReader(buf)
