"""Error-bounded linear-scale quantization (paper §4.2.2).

q = round(y / (2*eb))  guarantees  |y - dequantize(q)| <= eb.

Values whose quantized magnitude exceeds ``QMAX`` do not fit the 32-digit
negabinary representation and are routed through a lossless escape channel
(SZ-style "unpredictable data").
"""
from __future__ import annotations

import numpy as np

# 32-digit negabinary covers [-2863311530, 1431655765]; |q| <= 2**30 is safe
# on both sides and leaves headroom for the XOR/bitplane pipeline.
QMAX = 1 << 30


def quantize(y: np.ndarray, eb: float) -> np.ndarray:
    """Quantize prediction residuals to int64 bins of width 2*eb."""
    return np.rint(np.asarray(y, np.float64) / (2.0 * eb)).astype(np.int64)


def dequantize(q: np.ndarray, eb: float) -> np.ndarray:
    return np.asarray(q, np.float64) * (2.0 * eb)


def escape_mask(q: np.ndarray) -> np.ndarray:
    """Positions that must go to the lossless escape channel.

    Written as two comparisons: np.abs(INT64_MIN) overflows back to a
    negative value (float->int64 casts of huge residuals produce INT64_MIN),
    which |q| > QMAX would silently miss.
    """
    return (q > QMAX) | (q < -QMAX)
