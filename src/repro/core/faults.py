"""Deterministic fault injection at the :class:`ByteSource` seam.

Remote retrieval fails in ways an in-memory buffer never does: a read
raises mid-refine, returns short, or stalls.  The retry/degradation
machinery (``core/remote.py``, the serving tier's retry budget) exists
for exactly those moments — and must be testable without real flaky
networks.  :class:`FaultInjectingSource` wraps any source with a
*scripted* fault schedule keyed on the read-call index, so every
failure path is replayed deterministically:

* ``error`` — the read raises :class:`ConnectionError` (an ``OSError``,
  the transport-failure class the retry layers classify as retryable);
* ``truncate`` — the read returns only the first ``arg`` bytes (the
  short-read path the container hardening turns into
  ``CorruptArchiveError`` at the exact framing boundary);
* ``stall`` — the read sleeps ``arg`` seconds, then succeeds (latency
  injection; with an injected ``sleep`` it costs no wall clock).

Faults either fire once (``at`` = one call index) or persist from an
index onward (``persist=True`` — a source that stays down).  The
schedule is mutable at runtime: tests arm a fault at the *current*
``calls`` position (``src.arm(Fault(...))``) instead of precomputing
brittle absolute indices.  Every fired fault is appended to
:attr:`FaultInjectingSource.fired` for assertions.

The companion HTTP-level harness — scripted drops, truncations, stalls
and wrong statuses on a real loopback server — lives in
``tests/range_server.py``; this wrapper covers the ByteSource layer so
property tests (``tests/test_fault_injection.py``) can hammer the whole
decode pipeline with random schedules and assert the invariant that
matters: *no schedule ever yields a wrong-bytes reconstruction* — every
outcome is correct data or a raised/structured failure.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

from .bytesource import ByteSource, as_source


@dataclass
class Fault:
    """One scripted fault.

    ``kind`` is ``"error"`` / ``"truncate"`` / ``"stall"``; ``at`` is
    the 0-based read-call index it fires on (``None`` = the next call at
    arm time); ``arg`` is kind-specific (bytes kept for ``truncate``,
    default half the request; seconds for ``stall``, default 0.01);
    ``persist=True`` makes it fire on every call from ``at`` onward.
    """
    kind: str
    at: Optional[int] = None
    arg: Optional[float] = None
    persist: bool = False

    def __post_init__(self):
        if self.kind not in ("error", "truncate", "stall"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclass
class FiredFault:
    """Log entry: fault ``kind`` fired on call ``call`` = read
    ``(offset, size)``."""
    call: int
    kind: str
    offset: int
    size: int


class FaultInjectingSource(ByteSource):
    """Transparent wrapper applying a scripted :class:`Fault` schedule.

    Reads that no fault matches pass straight through, byte-identical.
    ``calls`` counts every :meth:`read` (including zero-byte ones, so
    indices are stable); ``fired`` logs each fault that actually fired.
    """

    def __init__(self, inner, schedule: Optional[List[Fault]] = None,
                 sleep=time.sleep):
        self.inner = as_source(inner)
        self.schedule: List[Fault] = list(schedule or [])
        self.calls = 0
        self.fired: List[FiredFault] = []
        self._sleep = sleep
        for f in self.schedule:
            if f.at is None:
                raise ValueError(
                    "schedule faults need an explicit 'at' index; "
                    "use arm() for next-call faults")

    def arm(self, fault: Fault) -> Fault:
        """Add ``fault`` to the schedule; ``at=None`` resolves to the
        next read call, so tests can arm relative to live progress
        instead of precomputing absolute call indices."""
        if fault.at is None:
            fault.at = self.calls
        self.schedule.append(fault)
        return fault

    def _match(self, idx: int) -> Optional[Fault]:
        for f in self.schedule:
            if f.at == idx or (f.persist and f.at is not None
                               and idx >= f.at):
                return f
        return None

    def read(self, offset: int, size: int):
        idx = self.calls
        self.calls += 1
        f = self._match(idx)
        if f is None:
            return self.inner.read(offset, size)
        self.fired.append(FiredFault(idx, f.kind, int(offset), int(size)))
        if f.kind == "error":
            raise ConnectionError(
                f"injected fault: read #{idx} "
                f"[{offset}, {offset + size}) dropped")
        if f.kind == "stall":
            self._sleep(0.01 if f.arg is None else f.arg)
            return self.inner.read(offset, size)
        # truncate: serve a short prefix of the true bytes
        keep = int(size // 2 if f.arg is None else f.arg)
        keep = max(0, min(keep, size))
        return bytes(self.inner.read(offset, size))[:keep]

    @property
    def size(self) -> int:
        return self.inner.size

    def close(self) -> None:
        self.inner.close()

    def __repr__(self) -> str:
        return (f"FaultInjectingSource({self.calls} calls, "
                f"{len(self.fired)} faults fired, "
                f"{len(self.schedule)} scheduled)")
