"""JAX/Pallas codec backend: both codec hot paths on the accelerator.

``compress(..., backend="jax")`` routes the two inner loops of the paper's
compression pipeline through the Pallas TPU kernels instead of numpy:

  * ``kernels.interp_quant``  — fused interpolation-predict + quantize for
    every (level, dim) phase sweep (§4.1–§4.2 in one VMEM pass);
  * ``kernels.bitplane_pack`` — negabinary + 2-bit-prefix XOR + bitplane
    packing collapsed to three integer ops per element (§4.4).

``retrieve``/``refine``/``decompress(..., backend="jax")`` route the decode
direction — the operation progressive compression exists to make fast
(Algorithms 1–2) — through the inverse kernel pair:

  * ``kernels.interp_recon``  — fused interpolation-predict + add-residual
    for every (level, dim) phase of the reconstruction sweep;
  * ``kernels.bitplane_pack.bitplane_unpack`` — plane-word unpack +
    closed-form XOR-undo + negabinary decode back to the int32 bins.

Backend selection (see ``pipeline.backends``):

  * ``backend="numpy"``  — the pure-numpy reference pipeline (default on CPU);
  * ``backend="jax"``    — this module; on CPU the kernels run in Pallas
    interpret mode, on TPU they compile to Mosaic;
  * ``backend=None``/``"auto"`` — "jax" on TPU only: the kernels compile
    via Mosaic there, while on GPU/CPU they would fall back to the (slow)
    Pallas interpreter, so "auto" keeps the numpy reference everywhere
    else rather than silently emulating.

Both backends emit byte-identical archives: the kernel quantizer divides by
2*eb with the same f64 rounding as the numpy oracle (x64 is enabled for the
duration of the sweep), and the packed plane words are truncated to the
exact ``np.packbits`` byte stream (``bitplane.blobs_from_packed``).  The
decode path (``retrieve``/``refine``) is backend-agnostic, so archives
produced here are readable anywhere numpy runs.

Escape handling stays on the host: the kernel returns (q, pred), so the
full-precision requantization that flags outliers beyond ``quantize.QMAX``
(where the kernel's int32 bins wrap or saturate) is one vectorized numpy
pass over the phase — no second prediction sweep.  The writeback
``pred + 2*eb*q`` is also done host-side in numpy: it is the archive's
canonical rounding, shared verbatim with the numpy backend.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from . import bitplane, interpolation, quantize

NUMPY = "numpy"
JAX = "jax"
AUTO = "auto"


def resolve(backend) -> str:
    """Map a user-facing backend choice to a registered backend name.

    Compatibility alias for ``pipeline.backends.resolve_name`` (the
    registry owns selection now).  "auto" picks jax only where the kernels
    actually compile (TPU); on GPU/CPU they would run in interpret mode —
    valid for parity testing (request it explicitly with backend="jax")
    but far slower than numpy.
    """
    from .pipeline import backends
    return backends.resolve_name(backend)


def decorrelate(x: np.ndarray, eb: float, interp: str,
                interpret: bool | None = None,
                ) -> Tuple[np.ndarray, List[np.ndarray], List[List[Tuple]], np.ndarray]:
    """Kernel-backed twin of ``interpolation.decorrelate``.

    Same traversal, same return contract: (xhat, per-level q streams,
    per-level escape records with level-global indices, anchors).  Each
    (level, dim) phase moves the sweep axis onto lanes, runs the fused
    predict+quantize kernel, and writes the reconstruction back into
    ``xhat`` so later levels predict from the lossy surface — bit-exact
    with the numpy sweep.
    """
    import jax

    from ..kernels.interp_quant import interp_quant

    shape = x.shape
    L = interpolation.num_levels(shape)
    xhat = np.zeros_like(x, dtype=np.float64)
    anc = interpolation.anchor_slices(shape, L)
    anchors = np.array(x[anc], np.float64, copy=True)
    xhat[anc] = anchors

    qs: List[List[np.ndarray]] = [[] for _ in range(L)]
    escs: List[List[Tuple]] = [[] for _ in range(L)]
    offsets = [0] * L
    with jax.experimental.enable_x64():
        for ph in interpolation.iter_phases(shape, L):
            xv = x[ph.view]
            hv = xhat[ph.view]
            xm = np.ascontiguousarray(np.moveaxis(xv, ph.dim, -1))
            hm = np.ascontiguousarray(np.moveaxis(hv, ph.dim, -1))
            lead, C = xm.shape[:-1], xm.shape[-1]
            R = int(np.prod(lead)) if lead else 1
            q2, pred2 = interp_quant(xm.reshape(R, C), hm.reshape(R, C),
                                     s=ph.stride, eb=eb, interp=interp,
                                     interpret=interpret)
            T = q2.shape[1]
            # order='C' copies: device buffers arrive read-only, and ravel()
            # on an order-'K' copy of the moveaxis view would NOT alias the
            # data (escape zeroing below must write through)
            q = np.array(np.moveaxis(
                np.asarray(q2).reshape(lead + (T,)), -1, ph.dim),
                np.int64, order="C")
            pred = np.array(np.moveaxis(
                np.asarray(pred2, np.float64).reshape(lead + (T,)), -1,
                ph.dim), order="C")
            tvals = np.take(xv, ph.targets, axis=ph.dim).astype(np.float64)
            # canonical numpy writeback + full-precision escape requantize
            # (the kernel's int32 bins wrap/saturate past QMAX)
            block = pred + quantize.dequantize(q, eb)
            qf = quantize.quantize(tvals - pred, eb)
            esc = quantize.escape_mask(qf)
            if esc.any():
                flat = np.flatnonzero(esc.ravel())
                vals = tvals.ravel()[flat]
                q[esc] = 0
                block[esc] = vals  # exact overwrite, no cancellation
            else:
                flat = np.zeros(0, np.int64)
                vals = np.zeros(0, np.float64)
            interpolation._assign(hv, ph.dim, ph.targets, block)
            li = L - ph.level
            qs[li].append(q.ravel())
            escs[li].append((flat + offsets[li], vals))
            offsets[li] += q.size
    return (xhat,
            [np.concatenate(v) if v else np.zeros(0, np.int64) for v in qs],
            escs, anchors)


def encode_level(q: np.ndarray, interpret: bool | None = None,
                 ) -> Tuple[List[bytes], int]:
    """Kernel-backed twin of ``bitplane.encode_level`` (takes q, not nb).

    The Pallas kernel fuses negabinary conversion, XOR-predictive coding and
    bit-transposition; the host only truncates pad bytes and zlibs each
    plane.  Byte-identical blobs to the numpy encoder.
    """
    if q.size == 0:
        return [], 0
    from ..kernels.bitplane_pack import bitplane_pack

    # 1-D input only: the wrapper's 2-D path pads *columns*, which would
    # interleave pad zeros mid-stream and break blobs_from_packed's
    # valid-prefix truncation (level streams are always 1-D anyway)
    q1 = np.ascontiguousarray(q, np.int32).reshape(-1)
    packed, n = bitplane_pack(q1, interpret=interpret)
    return bitplane.blobs_from_packed(np.asarray(packed), int(n))


# ----------------------------------------------------------------- decode

def decode_level(blobs, nbits: int, n: int,
                 interpret: bool | None = None) -> np.ndarray:
    """Kernel-backed twin of ``bitplane.decode_level``.

    Takes the same MSB-first blob prefix (None = not loaded) and returns the
    same truncated negabinary words.  The host only unzlibs each loaded
    plane into its packed word stream; the bit unpack, XOR-undo and
    negabinary decode all happen in one ``bitplane_unpack`` kernel launch,
    which emits the truncated word alongside the bins — the progressive
    state stores exactly that word, so no host-side conversion remains.
    """
    import zlib

    from ..kernels.bitplane_pack import bitplane_unpack

    want = 0
    for b in blobs:
        if b is None:
            break  # prefix property: once a plane is missing, rest are too
        want = want + 1
    if nbits == 0 or n == 0 or want == 0:
        return np.zeros(n, np.uint32)
    nw = (n + 31) // 32
    words = np.zeros((32, nw), np.uint32)
    for i in range(want):
        blob = blobs[i]
        if not blob:
            continue  # all-zero encoded plane: b'' convention
        raw = zlib.decompress(blob)  # np.packbits stream, element 0 at MSB
        if len(raw) % 4:
            raw += b"\0" * (4 - len(raw) % 4)
        w = np.frombuffer(raw, ">u4")
        words[nbits - 1 - i, : w.size] = w
    _, nb = bitplane_unpack(words, n=n, low_zero=nbits - want,
                            with_nb=True, interpret=interpret)
    return np.asarray(nb, np.uint32)


def reconstruct(shape, interp: str, anchors: np.ndarray,
                yhat_per_level: List[np.ndarray],
                overrides=None, out_dtype=np.float64,
                interpret: bool | None = None) -> np.ndarray:
    """Kernel-backed twin of ``interpolation.reconstruct`` (Algorithm 1).

    Same routine, in fact: the traversal, offset accounting, and escape
    override writeback run in ``interpolation.reconstruct`` itself — this
    function only supplies the per-phase block primitive (the backend
    seam), which moves the sweep axis onto lanes and runs the fused
    predict+add-residual kernel.  Bit-exact with the numpy sweep: the
    prediction code is shared with the encode kernel.
    """
    import jax

    from ..kernels.interp_recon import interp_recon

    def block_fn(hv, ph, res):
        tgt_shape = list(hv.shape)
        tgt_shape[ph.dim] = ph.targets.size
        hm = np.ascontiguousarray(np.moveaxis(hv, ph.dim, -1))
        rm = np.ascontiguousarray(np.moveaxis(
            np.asarray(res, np.float64).reshape(tgt_shape), ph.dim, -1))
        lead, C = hm.shape[:-1], hm.shape[-1]
        R = int(np.prod(lead)) if lead else 1
        out2 = interp_recon(hm.reshape(R, C), rm.reshape(R, -1),
                            s=ph.stride, interp=interp, interpret=interpret)
        T = out2.shape[1]
        # order='C' copy: the override writeback addresses the block by
        # flat index in original-axis C order
        return np.array(np.moveaxis(
            np.asarray(out2, np.float64).reshape(lead + (T,)), -1, ph.dim),
            order="C")

    with jax.experimental.enable_x64():
        return interpolation.reconstruct(shape, interp, anchors,
                                         yhat_per_level, overrides=overrides,
                                         out_dtype=out_dtype,
                                         block_fn=block_fn)
