"""JAX/Pallas codec backend: both codec hot paths on the accelerator.

``compress(..., backend="jax")`` routes the two inner loops of the paper's
compression pipeline through the Pallas TPU kernels instead of numpy:

  * ``kernels.interp_quant``  — fused interpolation-predict + quantize for
    every (level, dim) phase sweep (§4.1–§4.2 in one VMEM pass);
  * ``kernels.bitplane_pack`` — negabinary + 2-bit-prefix XOR + bitplane
    packing collapsed to three integer ops per element (§4.4).

``retrieve``/``refine``/``decompress(..., backend="jax")`` route the decode
direction — the operation progressive compression exists to make fast
(Algorithms 1–2) — through the inverse kernel pair:

  * ``kernels.interp_recon``  — fused interpolation-predict + add-residual
    for every (level, dim) phase of the reconstruction sweep;
  * ``kernels.bitplane_pack.bitplane_unpack`` — plane-word unpack +
    closed-form XOR-undo + negabinary decode back to the int32 bins.

Backend selection (see ``pipeline.backends``):

  * ``backend="numpy"``  — the pure-numpy reference pipeline (default on CPU);
  * ``backend="jax"``    — this module; on CPU the kernels run in Pallas
    interpret mode, on TPU they compile to Mosaic;
  * ``backend=None``/``"auto"`` — "jax" on TPU only: the kernels compile
    via Mosaic there, while on GPU/CPU they would fall back to the (slow)
    Pallas interpreter, so "auto" keeps the numpy reference everywhere
    else rather than silently emulating.

Both backends emit byte-identical archives: the kernel quantizer divides by
2*eb with the same f64 rounding as the numpy oracle (x64 is enabled for the
duration of the sweep), and the packed plane words are truncated to the
exact ``np.packbits`` byte stream (``bitplane.blobs_from_packed``).  The
decode path (``retrieve``/``refine``) is backend-agnostic, so archives
produced here are readable anywhere numpy runs.

Escape handling stays on the host: the kernel returns (q, pred), so the
full-precision requantization that flags outliers beyond ``quantize.QMAX``
(where the kernel's int32 bins wrap or saturate) is one vectorized numpy
pass over the phase — no second prediction sweep.  The writeback
``pred + 2*eb*q`` is also done host-side in numpy: it is the archive's
canonical rounding, shared verbatim with the numpy backend.

Every primitive also has a ``*_batch`` twin over stacks of equal-shaped
chunk problems (the unit the v2 shape-group scheduler feeds): the stack
runs through the ``jax.vmap``-ed kernel entry points, so B chunks cost ONE
dispatch per phase / per level instead of B, with per-chunk outputs
bit-identical to B scalar calls.  On top of that, every ``*_batch`` twin
has a ``*_sharded`` twin (same stack, plus a 1-D device mesh): the stack
axis is split across the mesh via ``parallel.codec_mesh`` and every device
runs the vmapped kernel on its local chunks — data-parallel, collective-
free, and still bit-identical (``compress``/``retrieve``/``refine``/
``decompress`` expose this as ``shard="auto"`` / an explicit mesh; see
``docs/architecture.md``).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from . import bitplane, interpolation, quantize

NUMPY = "numpy"
JAX = "jax"
JAX_UNFUSED = "jax_unfused"
AUTO = "auto"


def resolve(backend) -> str:
    """Map a user-facing backend choice to a registered backend name.

    Compatibility alias for ``pipeline.backends.resolve_name`` (the
    registry owns selection now).  "auto" picks jax only where the kernels
    actually compile (TPU); on GPU/CPU they would run in interpret mode —
    valid for parity testing (request it explicitly with backend="jax")
    but far slower than numpy.
    """
    from .pipeline import backends
    return backends.resolve_name(backend)


def decorrelate(x: np.ndarray, eb: float, interp: str,
                interpret: bool | None = None,
                ) -> Tuple[np.ndarray, List[np.ndarray], List[List[Tuple]], np.ndarray]:
    """Kernel-backed twin of ``interpolation.decorrelate``.

    Same traversal, same return contract: (xhat, per-level q streams,
    per-level escape records with level-global indices, anchors).  Each
    (level, dim) phase moves the sweep axis onto lanes, runs the fused
    predict+quantize kernel, and writes the reconstruction back into
    ``xhat`` so later levels predict from the lossy surface — bit-exact
    with the numpy sweep.
    """
    import jax

    from ..kernels.interp_quant import interp_quant

    shape = x.shape
    L = interpolation.num_levels(shape)
    xhat = np.zeros_like(x, dtype=np.float64)
    anc = interpolation.anchor_slices(shape, L)
    anchors = np.array(x[anc], np.float64, copy=True)
    xhat[anc] = anchors

    qs: List[List[np.ndarray]] = [[] for _ in range(L)]
    escs: List[List[Tuple]] = [[] for _ in range(L)]
    offsets = [0] * L
    with jax.experimental.enable_x64():
        for ph in interpolation.iter_phases(shape, L):
            xv = x[ph.view]
            hv = xhat[ph.view]
            xm = np.ascontiguousarray(np.moveaxis(xv, ph.dim, -1))
            hm = np.ascontiguousarray(np.moveaxis(hv, ph.dim, -1))
            lead, C = xm.shape[:-1], xm.shape[-1]
            R = int(np.prod(lead)) if lead else 1
            q2, pred2 = interp_quant(xm.reshape(R, C), hm.reshape(R, C),
                                     s=ph.stride, eb=eb, interp=interp,
                                     interpret=interpret)
            T = q2.shape[1]
            # order='C' copies: device buffers arrive read-only, and ravel()
            # on an order-'K' copy of the moveaxis view would NOT alias the
            # data (escape zeroing below must write through)
            q = np.array(np.moveaxis(
                np.asarray(q2).reshape(lead + (T,)), -1, ph.dim),
                np.int64, order="C")
            pred = np.array(np.moveaxis(
                np.asarray(pred2, np.float64).reshape(lead + (T,)), -1,
                ph.dim), order="C")
            tvals = np.take(xv, ph.targets, axis=ph.dim).astype(np.float64)
            # canonical numpy writeback + full-precision escape requantize
            # (the kernel's int32 bins wrap/saturate past QMAX)
            block = pred + quantize.dequantize(q, eb)
            qf = quantize.quantize(tvals - pred, eb)
            esc = quantize.escape_mask(qf)
            if esc.any():
                flat = np.flatnonzero(esc.ravel())
                vals = tvals.ravel()[flat]
                q[esc] = 0
                block[esc] = vals  # exact overwrite, no cancellation
            else:
                flat = np.zeros(0, np.int64)
                vals = np.zeros(0, np.float64)
            interpolation._assign(hv, ph.dim, ph.targets, block)
            li = L - ph.level
            qs[li].append(q.ravel())
            escs[li].append((flat + offsets[li], vals))
            offsets[li] += q.size
    return (xhat,
            [np.concatenate(v) if v else np.zeros(0, np.int64) for v in qs],
            escs, anchors)


def decorrelate_batch(xs: np.ndarray, eb: float, interp: str,
                      interpret: bool | None = None,
                      mesh=None) -> List[Tuple]:
    """Batched twin of :func:`decorrelate` over stacked equal-shape chunks.

    ``xs`` is (B, *chunk_shape); returns a list of B per-chunk
    ``(xhat, qs, escs, anchors)`` tuples whose contents are bit-identical
    to B independent :func:`decorrelate` calls — the batch axis is purely
    an execution detail.  Every (level, dim) phase costs ONE vmapped
    kernel dispatch for the whole stack instead of B (the launch-count
    bottleneck cuSZ-i identifies for multi-level interpolation on GPUs);
    the host-side escape requantization runs vectorized over the batch,
    with per-chunk record extraction only.

    With ``mesh`` (a 1-D codec mesh), each phase dispatch is additionally
    ``shard_map``-ed: the stack axis is split across the mesh devices and
    every device runs the vmapped kernel on its local chunks
    (:func:`decorrelate_sharded` is the registry-facing alias).  Outputs
    stay bit-identical — sharding, like batching, is an execution detail.
    """
    import jax

    from ..kernels.interp_quant import (interp_quant_batch,
                                        interp_quant_sharded)

    def phase_sweep(xm, hm, s):
        if mesh is not None:
            return interp_quant_sharded(xm, hm, s=s, eb=eb, interp=interp,
                                        mesh=mesh, interpret=interpret)
        return interp_quant_batch(xm, hm, s=s, eb=eb, interp=interp,
                                  interpret=interpret)

    B = xs.shape[0]
    shape = xs.shape[1:]
    L = interpolation.num_levels(shape)
    xhat = np.zeros_like(xs, dtype=np.float64)
    anc = (slice(None),) + interpolation.anchor_slices(shape, L)
    anchors = np.array(xs[anc], np.float64, copy=True)
    xhat[anc] = anchors

    qs: List[List[List[np.ndarray]]] = [[[] for _ in range(L)] for _ in range(B)]
    escs: List[List[List[Tuple]]] = [[[] for _ in range(L)] for _ in range(B)]
    offsets = [0] * L
    with jax.experimental.enable_x64():
        for ph in interpolation.iter_phases(shape, L):
            ax = ph.dim + 1  # phase axis shifted by the leading batch axis
            xv = xs[(slice(None),) + ph.view]
            hv = xhat[(slice(None),) + ph.view]
            xm = np.ascontiguousarray(np.moveaxis(xv, ax, -1))
            hm = np.ascontiguousarray(np.moveaxis(hv, ax, -1))
            lead, C = xm.shape[1:-1], xm.shape[-1]
            R = int(np.prod(lead)) if lead else 1
            q3, pred3 = phase_sweep(xm.reshape(B, R, C),
                                    hm.reshape(B, R, C), ph.stride)
            T = q3.shape[-1]
            # order='C' copies: see decorrelate() — escape zeroing below
            # must write through, device buffers arrive read-only
            q = np.array(np.moveaxis(
                np.asarray(q3).reshape((B,) + lead + (T,)), -1, ax),
                np.int64, order="C")
            pred = np.array(np.moveaxis(
                np.asarray(pred3, np.float64).reshape((B,) + lead + (T,)),
                -1, ax), order="C")
            tvals = np.take(xv, ph.targets, axis=ax).astype(np.float64)
            block = pred + quantize.dequantize(q, eb)
            qf = quantize.quantize(tvals - pred, eb)
            esc = quantize.escape_mask(qf)
            li = L - ph.level
            for b in range(B):
                if esc[b].any():
                    flat = np.flatnonzero(esc[b].ravel())
                    vals = tvals[b].ravel()[flat]
                    q[b][esc[b]] = 0
                    block[b][esc[b]] = vals  # exact overwrite, no cancellation
                else:
                    flat = np.zeros(0, np.int64)
                    vals = np.zeros(0, np.float64)
                qs[b][li].append(q[b].ravel())
                escs[b][li].append((flat + offsets[li], vals))
            interpolation._assign(hv, ax, ph.targets, block)
            offsets[li] += int(q[0].size)
    return [(xhat[b],
             [np.concatenate(v) if v else np.zeros(0, np.int64)
              for v in qs[b]],
             escs[b], anchors[b]) for b in range(B)]


def decorrelate_sharded(xs: np.ndarray, eb: float, interp: str, mesh,
                        interpret: bool | None = None) -> List[Tuple]:
    """Sharded compression sweep: :func:`decorrelate_batch` with the chunk
    stack split over a 1-D device mesh (the ``CodecBackend`` sharded-slot
    signature: trailing ``mesh`` after the scalar arguments)."""
    return decorrelate_batch(xs, eb, interp, interpret=interpret, mesh=mesh)


def encode_level(q: np.ndarray, interpret: bool | None = None,
                 ) -> Tuple[List[bytes], int]:
    """Kernel-backed twin of ``bitplane.encode_level`` (takes q, not nb).

    The Pallas kernel fuses negabinary conversion, XOR-predictive coding and
    bit-transposition; the host only truncates pad bytes and zlibs each
    plane.  Byte-identical blobs to the numpy encoder.
    """
    if q.size == 0:
        return [], 0
    from ..kernels.bitplane_pack import bitplane_pack

    # 1-D input only: the wrapper's 2-D path pads *columns*, which would
    # interleave pad zeros mid-stream and break blobs_from_packed's
    # valid-prefix truncation (level streams are always 1-D anyway)
    q1 = np.ascontiguousarray(q, np.int32).reshape(-1)
    packed, n = bitplane_pack(q1, interpret=interpret)
    return bitplane.blobs_from_packed(np.asarray(packed), int(n))


def encode_level_batch(q2: np.ndarray, interpret: bool | None = None,
                       mesh=None) -> List[Tuple[List[bytes], int]]:
    """Batched twin of :func:`encode_level`: (B, n) stacked level streams.

    One vmapped pack launch covers the whole stack; the host then truncates
    and zlibs each chunk's planes independently (per-chunk ``nbits`` and
    blobs), so every returned ``(blobs, nbits)`` is byte-identical to an
    unbatched :func:`encode_level` call on that row.  With ``mesh``, the
    stack is split over the 1-D codec mesh first (one launch per device;
    :func:`encode_level_sharded` is the registry-facing alias).
    """
    B, n = q2.shape
    if n == 0:
        return [([], 0) for _ in range(B)]
    from ..kernels.bitplane_pack import (bitplane_pack_batch,
                                         bitplane_pack_sharded)

    q2i = np.ascontiguousarray(q2, np.int32)
    if mesh is not None:
        packed, n_valid = bitplane_pack_sharded(q2i, mesh=mesh,
                                                interpret=interpret)
    else:
        packed, n_valid = bitplane_pack_batch(q2i, interpret=interpret)
    packed = np.asarray(packed)
    return [bitplane.blobs_from_packed(packed[b], int(n_valid))
            for b in range(B)]


def encode_level_sharded(q2: np.ndarray, mesh,
                         interpret: bool | None = None,
                         ) -> List[Tuple[List[bytes], int]]:
    """Sharded per-level pack: :func:`encode_level_batch` over a mesh."""
    return encode_level_batch(q2, interpret=interpret, mesh=mesh)


# ----------------------------------------------------------------- decode

def _loaded_prefix(blobs) -> int:
    """Length of the loaded MSB-first plane prefix (None = not loaded)."""
    want = 0
    for blob in blobs:
        if blob is None:
            break  # prefix property: once a plane is missing, rest are too
        want += 1
    return want


def _inflate(blob) -> bytes:
    """Blob -> raw packed-bit stream (``bitplane.inflate``): b''/None pass
    through, :class:`~repro.core.bitplane.Raw` payloads skip zlib entirely
    (cache layers hand pre-inflated planes through this seam), stored
    blobs are decompressed."""
    return bitplane.inflate(blob)


def _fill_plane_words(words: np.ndarray, blobs, want: int,
                      nbits: int) -> None:
    """Inflate a loaded blob prefix into the unpack kernel's word rows.

    ``words`` is one stream's (32, nw) destination; row k holds negabinary
    digit k's packed words (32 consecutive elements per word, element 0 at
    the MSB — the ``np.packbits`` stream the archive stores).  Shared by
    the scalar and batched decoders so the b'' convention and padding
    cannot drift between them.
    """
    for i in range(want):
        raw = _inflate(blobs[i])  # np.packbits stream, element 0 at MSB
        if not raw:
            continue  # all-zero encoded plane: b'' convention
        if len(raw) % 4:
            raw += b"\0" * (4 - len(raw) % 4)
        w = np.frombuffer(raw, ">u4")
        words[nbits - 1 - i, : w.size] = w


def inflate_level(blobs, nbits: int, n: int) -> Tuple[np.ndarray, int]:
    """Host zlib stage of one level's decode, split out so it can run on a
    worker thread while the device decodes the PREVIOUS level (the two-slot
    prefetch in ``pipeline.state``).  Returns ``(words, want)``: the (32,
    ceil(n/32)) uint32 word grid the unpack/fused kernels consume and the
    loaded-prefix length.  Pure host work (zlib + numpy) — thread-safe.
    """
    want = _loaded_prefix(blobs)
    words = np.zeros((32, (n + 31) // 32), np.uint32)
    if nbits and n and want:
        _fill_plane_words(words, blobs, want, nbits)
    return words, want


def inflate_level_batch(blob_lists, nbits: int, n: int,
                        ) -> Tuple[np.ndarray, List[int]]:
    """Batched :func:`inflate_level`: B blob prefixes -> ((B, 32, nw) word
    stack, per-chunk prefix lengths)."""
    B = len(blob_lists)
    words = np.zeros((B, 32, (n + 31) // 32), np.uint32)
    wants = []
    for b, blobs in enumerate(blob_lists):
        want = _loaded_prefix(blobs)
        wants.append(want)
        if nbits and n and want:
            _fill_plane_words(words[b], blobs, want, nbits)
    return words, wants


def decode_level(blobs, nbits: int, n: int,
                 interpret: bool | None = None) -> np.ndarray:
    """Kernel-backed twin of ``bitplane.decode_level``.

    Takes the same MSB-first blob prefix (None = not loaded) and returns the
    same truncated negabinary words.  The host only unzlibs each loaded
    plane into its packed word stream; the bit unpack, XOR-undo and
    negabinary decode all happen in one ``bitplane_unpack`` kernel launch,
    which emits the truncated word alongside the bins — the progressive
    state stores exactly that word, so no host-side conversion remains.
    """
    from ..kernels.bitplane_pack import bitplane_unpack

    want = _loaded_prefix(blobs)
    if nbits == 0 or n == 0 or want == 0:
        return np.zeros(n, np.uint32)
    words = np.zeros((32, (n + 31) // 32), np.uint32)
    _fill_plane_words(words, blobs, want, nbits)
    _, nb = bitplane_unpack(words, n=n, low_zero=nbits - want,
                            with_nb=True, interpret=interpret)
    return np.asarray(nb, np.uint32)


def decode_level_batch(blob_lists, nbits: int, n: int,
                       interpret: bool | None = None,
                       mesh=None) -> List[np.ndarray]:
    """Batched twin of :func:`decode_level` for equal-``nbits`` groups.

    ``blob_lists`` holds B chunks' MSB-first blob prefixes with the same
    ``nbits``; the loaded-prefix length may DIFFER per chunk — ``low_zero``
    is a runtime operand of the unpack kernel, so every stream carries its
    own truncation mask inside the one vmapped launch (no more one launch
    per ``(nbits, prefix)`` bucket).  Each returned truncated negabinary
    array is bit-identical to an unbatched call.  With ``mesh``, the
    stream stack is split over the 1-D codec mesh (one launch per device;
    :func:`decode_level_sharded` is the registry-facing alias).
    """
    from ..kernels.bitplane_pack import (bitplane_unpack_batch,
                                         bitplane_unpack_sharded)

    B = len(blob_lists)
    words, wants = inflate_level_batch(blob_lists, nbits, n)
    if nbits == 0 or n == 0 or all(w == 0 for w in wants):
        return [np.zeros(n, np.uint32) for _ in range(B)]
    # a want-0 stream has all-zero words, so it decodes to zero whatever
    # its mask is; 31 keeps the shift within uint32 range
    lz = [nbits - w if w else 31 for w in wants]
    if mesh is not None:
        _, nb = bitplane_unpack_sharded(words, n=n, mesh=mesh, low_zero=lz,
                                        with_nb=True, interpret=interpret)
    else:
        _, nb = bitplane_unpack_batch(words, n=n, low_zero=lz,
                                      with_nb=True, interpret=interpret)
    nb = np.asarray(nb, np.uint32)
    return [nb[b] for b in range(B)]


def decode_level_sharded(blob_lists, nbits: int, n: int, mesh,
                         interpret: bool | None = None) -> List[np.ndarray]:
    """Sharded per-level unpack: :func:`decode_level_batch` over a mesh."""
    return decode_level_batch(blob_lists, nbits, n, interpret=interpret,
                              mesh=mesh)


def decode_level_fused(blobs, nbits: int, n: int, nb_old: np.ndarray,
                       eb: float, interpret: bool | None = None,
                       words=None) -> Tuple[np.ndarray, np.ndarray]:
    """Fused progressive decode of one level: ONE kernel launch replaces
    ``decode_level`` plus the three host passes of the delta cascade.

    ``nb_old`` is the session's current truncated negabinary stream for
    the level; returns ``(nb_new, delta)`` where ``delta`` is the
    dequantized residual increment ``(bin_new - bin_old) * 2 * eb``,
    bit-identical to the unfused host arithmetic.  ``words`` optionally
    carries a pre-inflated ``(words, want)`` pair from
    :func:`inflate_level` (the two-slot prefetch hands the worker thread's
    result through here).
    """
    from ..kernels.decode_fused import decode_fused

    if words is None:
        words = inflate_level(blobs, nbits, n)
    wgrid, want = words
    if nbits == 0 or n == 0 or want == 0:
        return np.asarray(nb_old, np.uint32), np.zeros(n, np.float64)
    nb_new, delta = decode_fused(wgrid, np.asarray(nb_old, np.uint32), n,
                                 eb=eb, low_zero=nbits - want,
                                 interpret=interpret)
    return np.asarray(nb_new, np.uint32), np.asarray(delta, np.float64)


def decode_level_fused_batch(blob_lists, nbits: int, n: int, nb_olds,
                             ebs, interpret: bool | None = None,
                             mesh=None, words=None,
                             ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Batched twin of :func:`decode_level_fused` for equal-``nbits``
    groups with per-chunk prefixes AND per-chunk error bounds (both are
    runtime kernel operands).  Returns B ``(nb_new, delta)`` pairs from
    one vmapped launch; with ``mesh``, the stack is split over the 1-D
    codec mesh.  ``words`` optionally carries the prefetched
    ``(word stack, wants)`` from :func:`inflate_level_batch`.
    """
    from ..kernels.decode_fused import decode_fused_batch

    B = len(blob_lists)
    if words is None:
        words = inflate_level_batch(blob_lists, nbits, n)
    wstack, wants = words
    olds = np.stack([np.asarray(o, np.uint32) for o in nb_olds])
    eb_list = list(ebs) if np.ndim(ebs) else [float(ebs)] * B
    if nbits == 0 or n == 0 or all(w == 0 for w in wants):
        return [(olds[b], np.zeros(n, np.float64)) for b in range(B)]
    lz = [nbits - w if w else 31 for w in wants]
    nb_new, delta = decode_fused_batch(wstack, olds, n, eb=eb_list,
                                       low_zero=lz, interpret=interpret,
                                       mesh=mesh)
    nb_new = np.asarray(nb_new, np.uint32)
    delta = np.asarray(delta, np.float64)
    out = []
    for b in range(B):
        if wants[b] == 0:  # nothing loaded: state and delta are untouched
            out.append((olds[b], np.zeros(n, np.float64)))
        else:
            out.append((nb_new[b], delta[b]))
    return out


def decode_level_fused_sharded(blob_lists, nbits: int, n: int, nb_olds,
                               ebs, mesh, interpret: bool | None = None,
                               words=None,
                               ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Sharded fused decode: :func:`decode_level_fused_batch` over a mesh."""
    return decode_level_fused_batch(blob_lists, nbits, n, nb_olds, ebs,
                                    interpret=interpret, mesh=mesh,
                                    words=words)


def _dense_override(oidx, ovals, lo: int, cnt: int, block_shape):
    """Level-global escape records -> a dense (mask, values) pair for one
    phase block, or None when the block has no escapes.  The fused level
    kernel applies ``mask != 0 -> value`` inside the launch — same
    semantics as the host writeback ``block.reshape(-1)[idx] = vals``."""
    sel = (oidx >= lo) & (oidx < lo + cnt)
    if not sel.any():
        return None
    m = np.zeros(cnt, np.int32)
    v = np.zeros(cnt, np.float64)
    m[oidx[sel] - lo] = 1
    v[oidx[sel] - lo] = ovals[sel]
    return m.reshape(block_shape), v.reshape(block_shape)


def _level_blocks(shape, s: int):
    """Static geometry of one 2-D level on its stride-s subgrid.

    Returns (Ms, Ns, T0, T1, Nse): subgrid extents, phase target counts
    (T0 odd rows, T1 odd columns) and the even-column count Nse.  The
    phase residual blocks are (T0, Nse) and (Ms, T1) in stream C-order —
    consecutive in the level stream, phase 0 first, matching
    ``interpolation.iter_phases`` exactly (empty target sets drop the
    phase there; here the count is simply 0).
    """
    M, N = shape
    Ms = (M - 1) // s + 1
    Ns = (N - 1) // s + 1
    return Ms, Ns, Ms // 2, Ns // 2, -(-Ns // 2)


def reconstruct(shape, interp: str, anchors: np.ndarray,
                yhat_per_level: List[np.ndarray],
                overrides=None, out_dtype=np.float64,
                interpret: bool | None = None) -> np.ndarray:
    """Kernel-backed twin of ``interpolation.reconstruct`` (Algorithm 1).

    For 2-D data the traversal is fused per LEVEL: both (level, dim) phase
    sweeps plus the escape overrides of the level run as one
    ``interp_recon_level`` launch on the level's stride-s subgrid
    (``xhat[::s, ::s]`` — level-s traversal touches only s-multiples, and
    on the subgrid the stride becomes 1 with identical boundary masks, so
    bits cannot change).  L launches total instead of 2L plus host
    override scatters.  Other ranks fall back to the per-phase sweep
    (:func:`reconstruct_unfused`).
    """
    if len(shape) != 2:
        return reconstruct_unfused(shape, interp, anchors, yhat_per_level,
                                   overrides=overrides, out_dtype=out_dtype,
                                   interpret=interpret)
    import jax

    from ..kernels.interp_recon import interp_recon_level

    L = interpolation.num_levels(shape)
    xhat = np.zeros(shape, np.float64)
    xhat[interpolation.anchor_slices(shape, L)] = anchors
    with jax.experimental.enable_x64():
        for level in range(L, 0, -1):
            s = 1 << (level - 1)
            li = L - level
            Ms, Ns, T0, T1, Nse = _level_blocks(shape, s)
            if T0 == 0 and T1 == 0:
                continue
            stream = np.asarray(yhat_per_level[li], np.float64)
            oidx, ovals = overrides[li] if overrides is not None else \
                (np.zeros(0, np.int64), np.zeros(0, np.float64))
            res0 = res1 = ov0 = ov1 = None
            lo = 0
            if T0 > 0:
                cnt0 = T0 * Nse
                res0 = stream[lo:lo + cnt0].reshape(T0, Nse)
                ov0 = _dense_override(oidx, ovals, lo, cnt0, (T0, Nse))
                lo += cnt0
            if T1 > 0:
                cnt1 = Ms * T1
                res1 = stream[lo:lo + cnt1].reshape(Ms, T1)
                ov1 = _dense_override(oidx, ovals, lo, cnt1, (Ms, T1))
                lo += cnt1
            g = np.ascontiguousarray(xhat[::s, ::s])
            out = interp_recon_level(g, res0, res1, interp=interp, ov0=ov0,
                                     ov1=ov1, interpret=interpret)
            xhat[::s, ::s] = np.asarray(out, np.float64)
    return xhat.astype(out_dtype)


def reconstruct_unfused(shape, interp: str, anchors: np.ndarray,
                        yhat_per_level: List[np.ndarray],
                        overrides=None, out_dtype=np.float64,
                        interpret: bool | None = None) -> np.ndarray:
    """Per-phase kernel reconstruction (the pre-fusion jax path, kept as
    the ``jax_unfused`` backend and the any-rank fallback).

    The traversal, offset accounting, and escape override writeback run in
    ``interpolation.reconstruct`` itself — this function only supplies the
    per-phase block primitive (the backend seam), which moves the sweep
    axis onto lanes and runs the fused predict+add-residual kernel.
    Bit-exact with the numpy sweep: the prediction code is shared with the
    encode kernel.
    """
    import jax

    from ..kernels.interp_recon import interp_recon

    def block_fn(hv, ph, res):
        tgt_shape = list(hv.shape)
        tgt_shape[ph.dim] = ph.targets.size
        hm = np.ascontiguousarray(np.moveaxis(hv, ph.dim, -1))
        rm = np.ascontiguousarray(np.moveaxis(
            np.asarray(res, np.float64).reshape(tgt_shape), ph.dim, -1))
        lead, C = hm.shape[:-1], hm.shape[-1]
        R = int(np.prod(lead)) if lead else 1
        out2 = interp_recon(hm.reshape(R, C), rm.reshape(R, -1),
                            s=ph.stride, interp=interp, interpret=interpret)
        T = out2.shape[1]
        # order='C' copy: the override writeback addresses the block by
        # flat index in original-axis C order
        return np.array(np.moveaxis(
            np.asarray(out2, np.float64).reshape(lead + (T,)), -1, ph.dim),
            order="C")

    with jax.experimental.enable_x64():
        return interpolation.reconstruct(shape, interp, anchors,
                                         yhat_per_level, overrides=overrides,
                                         out_dtype=out_dtype,
                                         block_fn=block_fn)


def reconstruct_batch(shape, interp: str, anchors: np.ndarray,
                      yhat_per_level: List[np.ndarray],
                      overrides=None, out_dtype=np.float64,
                      interpret: bool | None = None,
                      mesh=None) -> np.ndarray:
    """Batched twin of :func:`reconstruct` over B equal-``shape`` items.

    2-D stacks take the fused per-level path: ONE vmapped (optionally
    mesh-sharded) ``interp_recon_level`` launch per level covers both
    phase sweeps and every item's escape overrides (dense per-item mask
    planes).  Per-item outputs are bit-identical to B scalar
    :func:`reconstruct` calls.  Other ranks fall back to the per-phase
    sweep (:func:`reconstruct_batch_unfused`).
    """
    if len(shape) != 2:
        return reconstruct_batch_unfused(shape, interp, anchors,
                                         yhat_per_level, overrides=overrides,
                                         out_dtype=out_dtype,
                                         interpret=interpret, mesh=mesh)
    import jax

    from ..kernels.interp_recon import (interp_recon_level_batch,
                                        interp_recon_level_sharded)

    B = anchors.shape[0]
    L = interpolation.num_levels(shape)
    xhat = np.zeros((B,) + tuple(shape), np.float64)
    xhat[(slice(None),) + interpolation.anchor_slices(shape, L)] = anchors

    def stack_override(li, lo, cnt, block_shape):
        if overrides is None:
            return None
        pairs = [_dense_override(*overrides[b][li], lo, cnt, block_shape)
                 for b in range(B)]
        if all(p is None for p in pairs):
            return None
        zm = np.zeros(block_shape, np.int32)
        zv = np.zeros(block_shape, np.float64)
        return (np.stack([p[0] if p else zm for p in pairs]),
                np.stack([p[1] if p else zv for p in pairs]))

    with jax.experimental.enable_x64():
        for level in range(L, 0, -1):
            s = 1 << (level - 1)
            li = L - level
            Ms, Ns, T0, T1, Nse = _level_blocks(shape, s)
            if T0 == 0 and T1 == 0:
                continue
            stream = np.asarray(yhat_per_level[li], np.float64)
            res0 = res1 = ov0 = ov1 = None
            lo = 0
            if T0 > 0:
                cnt0 = T0 * Nse
                res0 = stream[:, lo:lo + cnt0].reshape(B, T0, Nse)
                ov0 = stack_override(li, lo, cnt0, (T0, Nse))
                lo += cnt0
            if T1 > 0:
                cnt1 = Ms * T1
                res1 = stream[:, lo:lo + cnt1].reshape(B, Ms, T1)
                ov1 = stack_override(li, lo, cnt1, (Ms, T1))
                lo += cnt1
            g = np.ascontiguousarray(xhat[:, ::s, ::s])
            if mesh is not None:
                out = interp_recon_level_sharded(g, res0, res1, mesh=mesh,
                                                 interp=interp, ov0=ov0,
                                                 ov1=ov1, interpret=interpret)
            else:
                out = interp_recon_level_batch(g, res0, res1, interp=interp,
                                               ov0=ov0, ov1=ov1,
                                               interpret=interpret)
            xhat[:, ::s, ::s] = np.asarray(out, np.float64)
    return xhat.astype(out_dtype)


def reconstruct_batch_unfused(shape, interp: str, anchors: np.ndarray,
                              yhat_per_level: List[np.ndarray],
                              overrides=None, out_dtype=np.float64,
                              interpret: bool | None = None,
                              mesh=None) -> np.ndarray:
    """Per-phase batched reconstruction (the pre-fusion jax path, kept as
    the ``jax_unfused`` backend and the any-rank fallback).

    Same seam as the scalar path: traversal, offset accounting, and the
    per-item escape writeback run in ``interpolation.reconstruct_batch``;
    this function only supplies the batched per-phase block primitive —
    one vmapped ``interp_recon`` launch per phase for the whole stack.
    With ``mesh``, each phase launch is ``shard_map``-ed over the 1-D
    codec mesh; bits still do not change.
    """
    import jax

    from ..kernels.interp_recon import (interp_recon_batch,
                                        interp_recon_sharded)

    def block_fn(hv, ph, res):
        B = hv.shape[0]
        ax = ph.dim + 1
        tgt_shape = list(hv.shape)
        tgt_shape[ax] = ph.targets.size
        hm = np.ascontiguousarray(np.moveaxis(hv, ax, -1))
        rm = np.ascontiguousarray(np.moveaxis(
            np.asarray(res, np.float64).reshape(tgt_shape), ax, -1))
        lead, C = hm.shape[1:-1], hm.shape[-1]
        R = int(np.prod(lead)) if lead else 1
        if mesh is not None:
            out3 = interp_recon_sharded(hm.reshape(B, R, C),
                                        rm.reshape(B, R, -1), s=ph.stride,
                                        interp=interp, mesh=mesh,
                                        interpret=interpret)
        else:
            out3 = interp_recon_batch(hm.reshape(B, R, C),
                                      rm.reshape(B, R, -1), s=ph.stride,
                                      interp=interp, interpret=interpret)
        T = out3.shape[-1]
        # order='C' copy: the override writeback addresses each item's
        # block by flat index in original-axis C order
        return np.array(np.moveaxis(
            np.asarray(out3, np.float64).reshape((B,) + lead + (T,)),
            -1, ax), order="C")

    with jax.experimental.enable_x64():
        return interpolation.reconstruct_batch(
            shape, interp, anchors, yhat_per_level, overrides=overrides,
            out_dtype=out_dtype, block_fn=block_fn)


def reconstruct_sharded(shape, interp: str, anchors: np.ndarray,
                        yhat_per_level: List[np.ndarray], mesh,
                        overrides=None, out_dtype=np.float64,
                        interpret: bool | None = None) -> np.ndarray:
    """Sharded reconstruction sweep: :func:`reconstruct_batch` over a 1-D
    codec mesh (the ``CodecBackend`` sharded-slot signature)."""
    return reconstruct_batch(shape, interp, anchors, yhat_per_level,
                             overrides=overrides, out_dtype=out_dtype,
                             interpret=interpret, mesh=mesh)


def reconstruct_sharded_unfused(shape, interp: str, anchors: np.ndarray,
                                yhat_per_level: List[np.ndarray], mesh,
                                overrides=None, out_dtype=np.float64,
                                interpret: bool | None = None) -> np.ndarray:
    """Sharded per-phase reconstruction (``jax_unfused`` backend slot)."""
    return reconstruct_batch_unfused(shape, interp, anchors, yhat_per_level,
                                     overrides=overrides, out_dtype=out_dtype,
                                     interpret=interpret, mesh=mesh)
