"""Re-lay existing archives plane-major: ``v1/v2 -> v3``, no re-compression.

The v3 plane-major layout (``docs/format.md`` §3) is a pure *byte
permutation* of the same compressed blobs a v2 container carries —
:func:`~repro.core.container.write_v3_archive` takes exactly
``write_chunked_archive``'s inputs — so any archive already compressed
as v2 (or v1: a single-slab grid) can be upgraded to the streaming
layout without touching a single codec kernel.  That is what this
module does, as a function (:func:`repack`) and as the CLI the ROADMAP
promised::

    python -m repro.repack in.ipc2 out.ipc3 [--verify]

Properties, pinned by ``tests/test_repack.py``:

* the output is a byte-for-byte valid IPC3 archive — in fact identical
  to what ``Codec(..., version=3)`` would have produced from the same
  chunking, since both routes feed the same blobs through
  ``write_v3_archive``;
* a full read of the output is bit-identical to a full read of the
  input (``--verify`` checks exactly this before the output is kept);
* already-v3 inputs are rejected with a clear error rather than
  silently double-repacked.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from .core.bytesource import as_source
from .core.container import (MAGIC, MAGIC2, MAGIC3, CorruptArchiveError,
                             parse_chunked_meta, parse_meta, write_v3_archive)


def repack(buf) -> bytes:
    """Re-lay a v1 or v2 archive's bytes into a v3 container.

    ``buf`` is the complete input archive (bytes-like or a
    :class:`~repro.core.bytesource.ByteSource`).  The compressed chunk
    payloads are moved, never re-encoded: a v2 container contributes its
    chunk extents directly; a v1 archive becomes a single-chunk grid
    spanning the whole array.  Raises
    :class:`~repro.core.container.CorruptArchiveError` for malformed
    input and :class:`ValueError` for an already-v3 archive.
    """
    src = as_source(buf)
    magic = bytes(src.read(0, 4))
    if magic == MAGIC3:
        raise ValueError("input is already a plane-major (v3) archive; "
                         "repack upgrades v1/v2 only")
    if magic == MAGIC2:
        meta = parse_chunked_meta(src)
        bounds = [(c.start, c.stop) for c in meta.chunks]
        chunk_bufs = [bytes(src.read(c.offset, c.size))
                      for c in meta.chunks]
    elif magic == MAGIC:
        meta = parse_meta(src)
        if not meta.shape:
            raise CorruptArchiveError(
                "cannot repack a 0-dimensional archive: the v3 chunk "
                "grid slabs along axis 0")
        bounds = [(0, meta.shape[0])]
        chunk_bufs = [bytes(src.read(0, src.size))]
    else:
        raise CorruptArchiveError(
            f"not an IPComp archive: expected magic {MAGIC!r} or "
            f"{MAGIC2!r}, got {magic!r}")
    return write_v3_archive(meta.shape, meta.dtype, meta.eb, meta.interp,
                            bounds, chunk_bufs)


def _full_read(buf) -> np.ndarray:
    from .api import Archive, Fidelity
    return Archive.from_source(buf).open().read(Fidelity.full())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.repack",
        description="Re-lay a v1/v2 IPComp archive plane-major (IPC3) "
                    "without re-compression.")
    ap.add_argument("input", help="path to the v1/v2 archive")
    ap.add_argument("output", help="path for the v3 archive")
    ap.add_argument("--verify", action="store_true",
                    help="decode both archives in full and require "
                         "bit-identical reconstructions before keeping "
                         "the output")
    args = ap.parse_args(argv)

    with open(args.input, "rb") as f:
        raw = f.read()
    try:
        out = repack(raw)
    except (CorruptArchiveError, ValueError) as e:
        print(f"repack: {e}", file=sys.stderr)
        return 2
    if args.verify:
        a, b = _full_read(raw), _full_read(out)
        if a.dtype != b.dtype or a.shape != b.shape \
                or not np.array_equal(a, b, equal_nan=True):
            print("repack: verification FAILED — full reads differ; "
                  "output not written", file=sys.stderr)
            return 3
    with open(args.output, "wb") as f:
        f.write(out)
    delta = len(out) - len(raw)
    print(f"{args.input} ({len(raw)} bytes) -> {args.output} "
          f"({len(out)} bytes, {delta:+d}); "
          f"{'verified bit-identical' if args.verify else 'not verified'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
