"""internvl2-1b [vlm]: InternViT frontend (stub: 256 patch embeddings via
input_specs) + qwen2-arch LM backbone.  [arXiv:2404.16821; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151655, qkv_bias=True, head_dim=64,
    rope_theta=1e6, frontend="vision_stub", n_prefix_embeds=256,
)
