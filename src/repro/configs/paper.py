"""The paper's own experimental config: six SDRBench-like fields (Table 3).

Offline container: synthetic seeded generators with the paper's shapes
(scaled down by `scale` for CPU benchmarking; 1.0 = full shape).
"""
from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class Dataset:
    name: str
    shape: Tuple[int, ...]
    kind: str     # spectral profile


TABLE3 = [
    Dataset("Density", (256, 384, 384), "turbulence"),
    Dataset("Pressure", (256, 384, 384), "turbulence"),
    Dataset("VelocityX", (256, 384, 384), "turbulence"),
    Dataset("Wave", (1008, 1008, 352), "seismic"),
    Dataset("SpeedX", (100, 500, 500), "weather"),
    Dataset("CH4", (500, 500, 500), "combustion"),
]

ERROR_BOUNDS = [1e-6, 1e-9]     # relative (Fig. 5)


def generate(ds: Dataset, scale: float = 0.25, seed: int = 0) -> np.ndarray:
    """Seeded synthetic field with a domain-flavoured spectrum."""
    shape = tuple(max(16, int(s * scale)) for s in ds.shape)
    rng = np.random.default_rng(seed + hash(ds.name) % 1000)
    grids = np.meshgrid(*[np.linspace(0, 2 * np.pi, s) for s in shape],
                        indexing="ij")
    x = np.zeros(shape)
    n_modes, decay, noise = dict(
        turbulence=(8, 1.6, 3e-3), seismic=(5, 1.2, 1e-3),
        weather=(4, 2.0, 1e-3), combustion=(6, 1.8, 5e-4))[ds.kind]
    for m in range(1, n_modes + 1):
        amp = m ** (-decay)
        phase = rng.uniform(0, 2 * np.pi, len(shape))
        term = np.ones(shape)
        for g, ph in zip(grids, phase):
            term = term * np.sin(m * g * rng.uniform(0.5, 1.5) + ph)
        x += amp * term
    x += noise * rng.standard_normal(shape)
    return x
