"""Architecture registry: --arch <id> resolution."""
import importlib
from typing import Tuple

from repro.models.config import ModelConfig, SHAPES, ShapeConfig

ARCHS = {
    "whisper-tiny": "whisper_tiny",
    "yi-6b": "yi_6b",
    "command-r-35b": "command_r_35b",
    "qwen2-0.5b": "qwen2_0_5b",
    "smollm-360m": "smollm_360m",
    "hymba-1.5b": "hymba_1_5b",
    "mamba2-370m": "mamba2_370m",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "internvl2-1b": "internvl2_1b",
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG


def get_opt_kind(arch: str) -> str:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return getattr(mod, "OPT_KIND", "adamw")


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full-attention arch: long_500k skipped (DESIGN.md §6)"
    return True, ""
