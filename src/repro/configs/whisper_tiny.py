"""whisper-tiny [audio]: enc-dec, conv frontend stubbed (frame embeddings
via input_specs).  [arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865,
    encoder_layers=4, encoder_seq=1500, frontend="audio_stub",
    head_dim=64,
)
