"""Kernel-dispatch accounting for the batched chunk engine.

Every public kernel wrapper (``interp_quant`` / ``interp_recon`` /
``bitplane_pack`` / ``bitplane_unpack`` and their ``*_batch`` twins)
records exactly one launch per call: a ``jax.vmap``-ed call is ONE launch
whose batch axis becomes an extra grid dimension, which is the whole point
of batching equal-shaped chunks — B chunks stop costing B dispatches.

The chunk-batching parity tests and ``benchmarks/backend_speed.py`` use
:func:`measure` to assert the batched codec path issues strictly fewer
dispatches than the per-chunk loop (< chunks x levels for the per-level
pack/unpack ops).  Counting happens at the Python wrapper layer, so it is
exact in both interpret mode (CPU) and compiled Mosaic (TPU): one wrapper
call = one ``pallas_call`` execution.
"""
from __future__ import annotations

from collections import Counter
from contextlib import contextmanager
from typing import Dict, Iterator

#: cumulative launches per kernel name since process start (or reset())
_counts: Counter = Counter()
#: cumulative batch elements covered per kernel name (launches weighted by
#: their batch size; equals _counts for unbatched calls)
_elements: Counter = Counter()


def record(name: str, batch: int = 1) -> None:
    """Count one kernel launch covering ``batch`` chunk-sized problems."""
    _counts[name] += 1
    _elements[name] += batch


def counts() -> Dict[str, int]:
    """Launches per kernel since start/reset (copy)."""
    return dict(_counts)


def total() -> int:
    """Total launches across all kernels since start/reset."""
    return sum(_counts.values())


def reset() -> None:
    _counts.clear()
    _elements.clear()


@contextmanager
def measure() -> Iterator[Dict[str, int]]:
    """Collect the launches recorded inside the ``with`` block.

    Yields a dict that is filled in when the block exits:
    ``{kernel_name: launches}`` (kernels not dispatched are absent, so
    ``sum(d.values())`` is the block's total dispatch count).  Nesting and
    interleaving with the global counters are safe — the block only diffs
    snapshots.
    """
    before = Counter(_counts)
    out: Dict[str, int] = {}
    try:
        yield out
    finally:
        out.update((_counts - before))
