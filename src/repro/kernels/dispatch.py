"""Kernel-dispatch accounting for the batched chunk engine.

Every public kernel wrapper (``interp_quant`` / ``interp_recon`` /
``bitplane_pack`` / ``bitplane_unpack`` and their ``*_batch`` twins)
records exactly one launch per call: a ``jax.vmap``-ed call is ONE launch
whose batch axis becomes an extra grid dimension, which is the whole point
of batching equal-shaped chunks — B chunks stop costing B dispatches.

The chunk-batching parity tests and ``benchmarks/backend_speed.py`` use
:func:`measure` to assert the batched codec path issues strictly fewer
dispatches than the per-chunk loop (< chunks x levels for the per-level
pack/unpack ops).  Counting happens at the Python wrapper layer, so it is
exact in both interpret mode (CPU) and compiled Mosaic (TPU): one wrapper
call = one ``pallas_call`` execution.

Sharded execution adds a second axis to the accounting: a sharded call is
ONE logical dispatch (one traced ``shard_map``, counted in ``_counts``
like any other wrapper call) that launches the vmapped kernel on EVERY
mesh device — ``record(..., devices=D)`` stores that fan-out separately
and :func:`device_counts` / :func:`measure_devices` expose it (unsharded
calls record ``devices=1``).  The invariant is strictly per dispatch;
per-RUN totals follow from the *schedule*, which sharding may itself
change (the shape-group cap scales with the mesh size, and decode groups
that stay singleton take the scalar path in every mode), so run-level
claims like "sharded logical count == batched logical count" hold only
when the two schedules coincide — the sharded parity tests construct
chunk grids where they provably do.
"""
from __future__ import annotations

from collections import Counter
from contextlib import contextmanager
from typing import Dict, Iterator

#: cumulative launches per kernel name since process start (or reset())
_counts: Counter = Counter()
#: cumulative batch elements covered per kernel name (launches weighted by
#: their batch size; equals _counts for unbatched calls)
_elements: Counter = Counter()
#: cumulative per-device launches (launches weighted by mesh size; equals
#: _counts for unsharded calls)
_device_counts: Counter = Counter()
#: cumulative bytes moved per kernel name (each launch's input + output
#: array bytes, as accounted by its wrapper) — the numerator of the
#: roofline report's achieved-bytes/s (``benchmarks/roofline_report.py``)
_bytes: Counter = Counter()


def record(name: str, batch: int = 1, devices: int = 1,
           nbytes: int = 0) -> None:
    """Count one kernel launch covering ``batch`` chunk-sized problems.

    ``devices`` is the mesh fan-out of the launch: a ``shard_map``-ed call
    is one *logical* dispatch that runs on ``devices`` devices at once
    (1 = unsharded, the default).  ``nbytes`` is the launch's memory
    traffic (input + output array bytes, pad included — what the launch
    actually moves), accumulated for roofline accounting.
    """
    _counts[name] += 1
    _elements[name] += batch
    _device_counts[name] += devices
    if nbytes:
        _bytes[name] += nbytes


def counts() -> Dict[str, int]:
    """Launches per kernel since start/reset (copy)."""
    return dict(_counts)


def device_counts() -> Dict[str, int]:
    """Per-device launches per kernel since start/reset (copy).

    Each logical dispatch contributes its mesh size (1 when unsharded), so
    this is the number of kernel executions actual hardware performs.
    """
    return dict(_device_counts)


def total() -> int:
    """Total launches across all kernels since start/reset."""
    return sum(_counts.values())


def bytes_counts() -> Dict[str, int]:
    """Bytes moved per kernel since start/reset (copy)."""
    return dict(_bytes)


def reset() -> None:
    _counts.clear()
    _elements.clear()
    _device_counts.clear()
    _bytes.clear()


@contextmanager
def measure() -> Iterator[Dict[str, int]]:
    """Collect the launches recorded inside the ``with`` block.

    Yields a dict that is filled in when the block exits:
    ``{kernel_name: launches}`` (kernels not dispatched are absent, so
    ``sum(d.values())`` is the block's total dispatch count).  Nesting and
    interleaving with the global counters are safe — the block only diffs
    snapshots.
    """
    before = Counter(_counts)
    out: Dict[str, int] = {}
    try:
        yield out
    finally:
        out.update((_counts - before))


@contextmanager
def measure_bytes() -> Iterator[Dict[str, int]]:
    """Like :func:`measure`, but collecting bytes moved per kernel.

    The yielded dict maps kernel name to the total input + output array
    bytes its launches moved inside the block — the numerator of
    achieved bytes/s in the roofline report.
    """
    before = Counter(_bytes)
    out: Dict[str, int] = {}
    try:
        yield out
    finally:
        out.update((_bytes - before))


@contextmanager
def measure_devices() -> Iterator[Dict[str, int]]:
    """Like :func:`measure`, but collecting *per-device* launches.

    The yielded dict maps kernel name to the number of on-device kernel
    executions inside the block: a sharded dispatch over a D-device mesh
    counts D, an unsharded one counts 1.  Pairs with :func:`measure` to
    assert both invariants of the sharded path at once — logical
    dispatches unchanged, device launches = logical x mesh size.
    """
    before = Counter(_device_counts)
    out: Dict[str, int] = {}
    try:
        yield out
    finally:
        out.update((_device_counts - before))
