from .ops import (bitplane_pack, bitplane_pack_batch, bitplane_pack_sharded,
                  bitplane_unpack, bitplane_unpack_batch,
                  bitplane_unpack_sharded)
from .ref import bitplane_pack_ref, bitplane_unpack_ref, unpack_planes_ref

__all__ = ["bitplane_pack", "bitplane_pack_batch", "bitplane_pack_sharded",
           "bitplane_unpack", "bitplane_unpack_batch",
           "bitplane_unpack_sharded", "bitplane_pack_ref",
           "bitplane_unpack_ref", "unpack_planes_ref"]
