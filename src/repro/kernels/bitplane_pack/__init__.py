from .ops import bitplane_pack, bitplane_unpack
from .ref import bitplane_pack_ref, bitplane_unpack_ref, unpack_planes_ref

__all__ = ["bitplane_pack", "bitplane_unpack", "bitplane_pack_ref",
           "bitplane_unpack_ref", "unpack_planes_ref"]
