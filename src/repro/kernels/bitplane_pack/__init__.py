from .ops import bitplane_pack
from .ref import bitplane_pack_ref, unpack_planes_ref

__all__ = ["bitplane_pack", "bitplane_pack_ref", "unpack_planes_ref"]
