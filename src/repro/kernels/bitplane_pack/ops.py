"""Public jit'd wrapper for the bitplane packing kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import GROUP, ROWS_B, bitplane_pack_pallas


def bitplane_pack(q, *, interpret: bool | None = None):
    """(n,) or (R, C) int32 -> (32, R', W) packed planes (+ padding info).

    Pads to (ROWS_B, GROUP) multiples; returns (packed, n_valid) where the
    flattened valid prefix of each plane covers the original n elements.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q = jnp.asarray(q, jnp.int32)
    if q.ndim == 1:
        n = q.shape[0]
        C = 128 * GROUP
        R = -(-n // C)
        q = jnp.pad(q, (0, R * C - n)).reshape(R, C)
    else:
        n = q.size
    R, C = q.shape
    pr, pc = (-R) % ROWS_B, (-C) % GROUP
    if pr or pc:
        q = jnp.pad(q, ((0, pr), (0, pc)))
    packed = bitplane_pack_pallas(q, interpret=interpret)
    return packed, n
