"""Public jit'd wrappers for the bitplane packing / unpacking kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import dispatch, mode
from .kernel import (GROUP, ROWS_B, bitplane_pack_pallas, bitplane_pack_xla,
                     bitplane_unpack_pallas, bitplane_unpack_xla)

# words per row fed to the unpack kernel: 128 lanes of uint32 = 4096
# elements per row, matching the 1-D pack wrapper's C = 128 * GROUP
_UNPACK_W = 128


def _lz_array(low_zero, B: int | None = None):
    """Normalize ``low_zero`` to the kernel's runtime-operand layout:
    (1, 1) uint32 for a scalar call, (B, 1, 1) for a batched one (a lone
    int broadcasts to every batch row)."""
    if B is None:
        return jnp.full((1, 1), int(low_zero), jnp.uint32)
    lz = np.asarray(low_zero, np.uint32).reshape(-1)
    if lz.size == 1:
        lz = np.full(B, lz[0], np.uint32)
    assert lz.size == B, "per-chunk low_zero must match the batch size"
    return jnp.asarray(lz).reshape(B, 1, 1)


def bitplane_pack(q, *, interpret: bool | None = None):
    """(n,) or (R, C) int32 -> (32, R', W) packed planes (+ padding info).

    Pads to (ROWS_B, GROUP) multiples; returns (packed, n_valid) where the
    flattened valid prefix of each plane covers the original n elements.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q = jnp.asarray(q, jnp.int32)
    if q.ndim == 1:
        n = q.shape[0]
        C = 128 * GROUP
        R = -(-n // C)
        q = jnp.pad(q, (0, R * C - n)).reshape(R, C)
    else:
        n = q.size
    R, C = q.shape
    pr, pc = (-R) % ROWS_B, (-C) % GROUP
    if pr or pc:
        q = jnp.pad(q, ((0, pr), (0, pc)))
    dispatch.record("bitplane_pack", nbytes=2 * q.size * 4)
    if mode.use_xla():
        packed = bitplane_pack_xla(q)
    else:
        packed = bitplane_pack_pallas(q, interpret=interpret)
    return packed, n


def bitplane_pack_batch(q, *, interpret: bool | None = None, mesh=None):
    """(B, n) int32 stacked 1-D level streams -> ((B, 32, R, W) packed, n).

    Each batch row gets the 1-D wrapper's layout — pad at the END of its
    flat stream, so ``blobs_from_packed`` per chunk sees the same valid
    prefix as an unbatched call — and the whole stack runs as ONE
    ``jax.vmap``-ed kernel launch instead of B.

    With ``mesh``, the batch axis is zero-padded to a mesh multiple
    (all-zero pad streams pack to all-zero words, sliced back off) and
    split across the 1-D codec mesh; each device packs its local rows
    with the same vmapped kernel.  One function holds both layouts so the
    byte-critical stream padding cannot drift between them.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q = jnp.asarray(q, jnp.int32)
    B, n = q.shape
    C = 128 * GROUP
    R = -(-n // C)
    padb = 0
    if mesh is not None:
        from ...parallel import codec_mesh
        padb = codec_mesh.pad_to_shards(B, mesh)
    q = jnp.pad(q, ((0, padb), (0, R * C - n))).reshape(B + padb, R, C)
    pr = (-R) % ROWS_B
    if pr:
        q = jnp.pad(q, ((0, 0), (0, pr), (0, 0)))

    if mode.use_xla():
        def kernel(a):
            return bitplane_pack_xla(a)
    else:
        def kernel(a):
            return bitplane_pack_pallas(a, interpret=interpret)

    nbytes = 2 * q.size * 4
    if mesh is None:
        dispatch.record("bitplane_pack", batch=B, nbytes=nbytes)
        packed = jax.vmap(kernel)(q)
    else:
        dispatch.record("bitplane_pack", batch=B,
                        devices=codec_mesh.shard_count(mesh), nbytes=nbytes)
        packed = codec_mesh.shard_vmap(kernel, mesh)(q)
    return packed[:B], n


def bitplane_pack_sharded(q, *, mesh, interpret: bool | None = None):
    """Sharded twin: ``bitplane_pack_batch`` with the (B, n) stack split
    over the 1-D codec ``mesh`` (thin alias)."""
    return bitplane_pack_batch(q, interpret=interpret, mesh=mesh)


def bitplane_unpack(plane_words, n: int, *, low_zero: int = 0,
                    with_nb: bool = False,
                    interpret: bool | None = None):
    """(32, NW) uint32 per-plane word streams -> (n,) int32 bins.

    ``plane_words[k]`` is plane k's packed words (32 consecutive elements
    per word, element 0 at the MSB — the flat stream ``bitplane_pack``
    emits and the archive stores); absent planes are all-zero rows.
    ``low_zero`` masks that many least-significant negabinary digits, i.e.
    decodes the truncation defined by a loaded MSB-first plane prefix; it
    is a RUNTIME operand of the kernel, so distinct prefixes share one
    trace.  ``with_nb=True`` returns (q, nb): the kernel holds the
    truncated negabinary word anyway, and the progressive state stores it
    — handing it out saves the caller an exactly-cancelling host
    re-encode.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    pw = jnp.asarray(plane_words, jnp.uint32)
    P, NW = pw.shape
    assert P == 32, "expect one row per negabinary digit"
    need = -(-max(n, 1) // (GROUP * _UNPACK_W))  # rows of _UNPACK_W words
    R = -(-need // ROWS_B) * ROWS_B
    pad = R * _UNPACK_W - NW
    if pad:
        pw = jnp.pad(pw, ((0, 0), (0, pad)))
    pw = pw.reshape(32, R, _UNPACK_W)
    lz = _lz_array(low_zero)
    # traffic: packed planes in + (q, nb) out
    dispatch.record("bitplane_unpack",
                    nbytes=(pw.size + 2 * R * _UNPACK_W * GROUP) * 4)
    if mode.use_xla():
        q, nb = bitplane_unpack_xla(pw, lz)
    else:
        q, nb = bitplane_unpack_pallas(pw, lz, interpret=interpret)
    if with_nb:
        return q.reshape(-1)[:n], nb.reshape(-1)[:n]
    return q.reshape(-1)[:n]


def bitplane_unpack_batch(plane_words, n: int, *, low_zero=0,
                          with_nb: bool = False,
                          interpret: bool | None = None, mesh=None):
    """(B, 32, NW) stacked per-plane word streams -> (B, n) int32 bins.

    The batched twin of ``bitplane_unpack`` for equal-n chunk groups: one
    ``jax.vmap``-ed launch decodes all B streams, each padded exactly like
    a lone call, so per-chunk outputs are bit-identical.  ``low_zero`` may
    be a single int or a length-B sequence — the mask width is a runtime
    per-row operand, so chunks with DIFFERENT loaded plane prefixes still
    share the one launch (the whole point of the dynamic operand: no more
    one-launch-per-(nbits, prefix) fragmentation).

    With ``mesh``, the stream stack is zero-padded to a mesh multiple
    (all-zero pad streams decode to zeros, sliced back off) and split
    across the 1-D codec mesh; every device decodes its local streams
    with the same vmapped kernel.  One function holds both layouts so the
    word padding/reshape math cannot drift between them.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    pw = jnp.asarray(plane_words, jnp.uint32)
    B, P, NW = pw.shape
    assert P == 32, "expect one row per negabinary digit"
    need = -(-max(n, 1) // (GROUP * _UNPACK_W))
    R = -(-need // ROWS_B) * ROWS_B
    pad = R * _UNPACK_W - NW
    padb = 0
    if mesh is not None:
        from ...parallel import codec_mesh
        padb = codec_mesh.pad_to_shards(B, mesh)
    if pad or padb:
        pw = jnp.pad(pw, ((0, padb), (0, 0), (0, pad)))
    pw = pw.reshape(B + padb, 32, R, _UNPACK_W)
    lz = _lz_array(low_zero, B)
    if padb:
        lz = jnp.pad(lz, ((0, padb), (0, 0), (0, 0)))

    if mode.use_xla():
        def kernel(a, z):
            return bitplane_unpack_xla(a, z)
    else:
        def kernel(a, z):
            return bitplane_unpack_pallas(a, z, interpret=interpret)

    nbytes = (pw.size + 2 * (B + padb) * R * _UNPACK_W * GROUP) * 4
    if mesh is None:
        dispatch.record("bitplane_unpack", batch=B, nbytes=nbytes)
        q, nb = jax.vmap(kernel)(pw, lz)
    else:
        dispatch.record("bitplane_unpack", batch=B,
                        devices=codec_mesh.shard_count(mesh), nbytes=nbytes)
        q, nb = codec_mesh.shard_vmap(kernel, mesh, n_out=2)(pw, lz)
    q = q.reshape(B + padb, -1)[:B, :n]
    nb = nb.reshape(B + padb, -1)[:B, :n]
    if with_nb:
        return q, nb
    return q


def bitplane_unpack_sharded(plane_words, n: int, *, mesh, low_zero=0,
                            with_nb: bool = False,
                            interpret: bool | None = None):
    """Sharded twin: ``bitplane_unpack_batch`` with the (B, 32, NW) stack
    split over the 1-D codec ``mesh`` (thin alias; equal-n groups only,
    like the batched twin — per-chunk ``low_zero`` rides along)."""
    return bitplane_unpack_batch(plane_words, n, low_zero=low_zero,
                                 with_nb=with_nb, interpret=interpret,
                                 mesh=mesh)
