"""Pure-jnp oracles for the bitplane packing / unpacking kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_M = np.uint32(0xAAAAAAAA)
GROUP = 32


def bitplane_pack_ref(q: jnp.ndarray) -> jnp.ndarray:
    """(R, C) int32 -> (32, R, C//32) uint32 packed XOR-coded negabinary."""
    u = q.astype(jnp.uint32)
    nb = (u + NEG_M) ^ NEG_M
    enc = nb ^ (nb >> jnp.uint32(1)) ^ (nb >> jnp.uint32(2))
    R, C = q.shape
    g = enc.reshape(R, C // GROUP, GROUP)
    w = (jnp.uint32(1) << jnp.arange(GROUP - 1, -1, -1, dtype=jnp.uint32))
    planes = []
    for k in range(32):
        bits = (g >> jnp.uint32(k)) & jnp.uint32(1)
        planes.append(jnp.sum(bits * w, axis=-1, dtype=jnp.uint32))
    return jnp.stack(planes)


def bitplane_unpack_ref(packed, n_keep_msb: int) -> jnp.ndarray:
    """Oracle for the unpack kernel: top ``n_keep_msb`` planes -> int32 bins
    (sequential XOR recurrence + negabinary decode, vs the kernel's
    closed-form inverse)."""
    nb = unpack_planes_ref(packed, n_keep_msb)
    u = (nb ^ jnp.uint32(NEG_M)) - jnp.uint32(NEG_M)
    return jax.lax.bitcast_convert_type(u, jnp.int32)


def unpack_planes_ref(packed, n_keep_msb: int) -> jnp.ndarray:
    """Inverse for tests: decode the top ``n_keep_msb`` planes back to the
    truncated negabinary word (plane prefix == truncation, §4.4 invariant)."""
    nplanes, R, W = packed.shape
    bits = []
    for k in range(nplanes):
        word = packed[k]
        lane = (word[..., None] >> jnp.arange(GROUP - 1, -1, -1,
                                              dtype=jnp.uint32)) & jnp.uint32(1)
        bits.append(lane.reshape(R, W * GROUP))
    enc = jnp.zeros((R, W * GROUP), jnp.uint32)
    for k in range(nplanes):
        enc = enc | (bits[k].astype(jnp.uint32) << jnp.uint32(k))
    # sequential decode from MSB: b_k = e_k ^ b_{k+1} ^ b_{k+2}
    b = jnp.zeros_like(enc)
    for k in range(31, 31 - n_keep_msb, -1):
        bk1 = (b >> jnp.uint32(k + 1)) & jnp.uint32(1) if k + 1 < 32 else 0
        bk2 = (b >> jnp.uint32(k + 2)) & jnp.uint32(1) if k + 2 < 32 else 0
        ek = (enc >> jnp.uint32(k)) & jnp.uint32(1)
        b = b | ((ek ^ bk1 ^ bk2) << jnp.uint32(k))
    return b
