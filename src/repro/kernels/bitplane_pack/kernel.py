"""Negabinary + XOR-predictive bitplane packing Pallas TPU kernel (§4.4).

Key TPU adaptation (DESIGN.md §3): the paper's per-plane predictive coding
    enc_k = b_k ^ b_{k+1} ^ b_{k+2}
collapses, over ALL planes at once, into THREE integer ops on the whole
word:      enc = nb ^ (nb >> 1) ^ (nb >> 2)
so the kernel converts q -> negabinary -> XOR-encoded word in O(1) VPU ops
per element, then bit-transposes lanes into packed uint32 plane words
(32 lanes -> one word per plane, MSB-first within the word).

Block layout: (ROWS_B, LANES) int32 in VMEM; output (32, ROWS_B, LANES/32).

The decode direction (``bitplane_unpack_pallas``) is the exact inverse with
the same collapsed-word trick: unpacked plane bits are OR-merged back into
the encoded word, the XOR recurrence is undone by its closed-form inverse
(1+x+x^2)^-1 = sum_k x^{3k}(1+x) over GF(2) — 22 shift/XORs instead of the
host's 32-step sequential MSB-down recurrence — and the negabinary word is
decoded back to the int32 quantization bin.

``low_zero`` — the count of absent low negabinary digits a loaded plane
prefix implies — is a RUNTIME operand (a (1, 1) uint32 array), not a
static argname: mixed plane prefixes batch into one launch (each vmapped
element carries its own mask width) instead of fragmenting a chunk group
into one launch per ``(nbits, prefix)`` bucket, and refine ladders stop
re-tracing the kernel once per distinct prefix.

``unpack_words`` is the pure-jnp core shared by the Pallas kernel body and
the jitted XLA twin (``IPCOMP_KERNEL_MODE=xla`` — see ``kernels.mode``):
one definition, so the two execution modes cannot drift.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

ROWS_B = 8
GROUP = 32          # lanes packed per output word
NEG_M = np.uint32(0xAAAAAAAA)


def _kernel(q_ref, out_ref, *, C: int):
    q = q_ref[...]
    u = q.astype(jnp.uint32)
    nb = (u + NEG_M) ^ NEG_M                        # negabinary (§4.4.2)
    enc = nb ^ (nb >> jnp.uint32(1)) ^ (nb >> jnp.uint32(2))  # 2-bit-prefix XOR
    R = enc.shape[0]
    g = enc.reshape(R, C // GROUP, GROUP)
    # pack bit k of 32 consecutive lanes into one uint32 word, lane 0 = MSB.
    # weight exponents come from an in-kernel iota (vector constants cannot
    # be captured by a Pallas kernel body).
    j = jax.lax.broadcasted_iota(jnp.uint32, g.shape, dimension=2)
    shift = jnp.uint32(GROUP - 1) - j
    for k in range(32):
        bits = (g >> jnp.uint32(k)) & jnp.uint32(1)
        out_ref[k, :, :] = jnp.sum(bits << shift, axis=-1, dtype=jnp.uint32)


def unpack_words(planes, lz, *, W: int):
    """Pure core of the unpack direction: (32, R, W) packed plane words +
    runtime ``lz`` (uint32 scalar, low digits to mask) -> (q int32, nb
    uint32), both (R, W*GROUP).  Shared verbatim by the Pallas kernel body
    and the jitted XLA twin so the two modes stay bit-identical."""
    R = planes.shape[1]
    # planes -> XOR-encoded word: bit k of element (r, w*32 + j) is bit
    # (31 - j) of word p[k, r, w] (lane 0 = MSB, np.packbits order)
    j = jax.lax.broadcasted_iota(jnp.uint32, (R, W, GROUP), dimension=2)
    shift = jnp.uint32(GROUP - 1) - j
    enc = jnp.zeros((R, W, GROUP), jnp.uint32)
    for k in range(32):
        w = planes[k].reshape(R, W, 1)
        enc = enc | (((w >> shift) & jnp.uint32(1)) << jnp.uint32(k))
    enc = enc.reshape(R, W * GROUP)
    # XOR-undo: enc = nb ^ (nb>>1) ^ (nb>>2) is multiplication by P(x) =
    # 1 + x + x^2 over GF(2) (x = shift-right-by-one, nilpotent at x^32);
    # P^-1 = (1+x)/(1+x^3) = sum_k x^{3k} (1 + x), a closed form that
    # replaces the host's sequential MSB-down recurrence with 22 shift/XORs
    nb = jnp.zeros_like(enc)
    for k3 in range(0, 32, 3):
        t = enc >> jnp.uint32(k3)
        nb = nb ^ t
        if k3 + 1 < 32:
            nb = nb ^ (t >> jnp.uint32(1))
    # a loaded prefix of planes means low negabinary digits are absent:
    # the recurrence above would free-run on zero input below the cutoff,
    # so mask — this IS the truncation the progressive format defines
    # (§4.4).  lz is a runtime value in [0, 32): shift-by-lz is defined.
    nb = nb & (jnp.uint32(0xFFFFFFFF) << lz.astype(jnp.uint32))
    # negabinary decode (§4.4.2): x = (nb ^ M) - M, modular in uint32; the
    # truncated word itself is emitted too — it is the canonical progressive
    # state (decode_level's contract), already in register here
    u = (nb ^ NEG_M) - NEG_M
    return jax.lax.bitcast_convert_type(u, jnp.int32), nb


def _unpack_kernel(p_ref, lz_ref, q_ref, nb_ref, *, W: int):
    q, nb = unpack_words(p_ref[...], lz_ref[0, 0], W=W)
    nb_ref[...] = nb
    q_ref[...] = q


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitplane_unpack_pallas(planes: jax.Array, low_zero: jax.Array, *,
                           interpret: bool = True):
    """planes: (32, R, W) uint32 packed plane words (the ``bitplane_pack``
    layout; unloaded planes all-zero); low_zero: (1, 1) uint32 runtime
    operand.  Returns (q, nb), both (R, W*32): the int32 bins after
    XOR-undo + negabinary decode, and the truncated negabinary words
    themselves, with the ``low_zero`` least-significant digits masked to
    zero (the progressive truncation of a plane prefix).
    """
    P, R, W = planes.shape
    assert P == 32 and R % ROWS_B == 0
    grid = (R // ROWS_B,)
    bspec_out = pl.BlockSpec((ROWS_B, W * GROUP), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_unpack_kernel, W=W),
        grid=grid,
        in_specs=[pl.BlockSpec((32, ROWS_B, W), lambda i: (0, i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[bspec_out, bspec_out],
        out_shape=[jax.ShapeDtypeStruct((R, W * GROUP), jnp.int32),
                   jax.ShapeDtypeStruct((R, W * GROUP), jnp.uint32)],
        interpret=interpret,
    )(planes, low_zero)


@jax.jit
def bitplane_unpack_xla(planes: jax.Array, low_zero: jax.Array):
    """Jitted XLA twin of :func:`bitplane_unpack_pallas`: the same
    ``unpack_words`` core over the whole array, compiled by XLA on any
    backend (the ``IPCOMP_KERNEL_MODE=xla`` path)."""
    P, R, W = planes.shape
    return unpack_words(planes, low_zero[0, 0], W=W)


def pack_words(q, *, C: int):
    """Pure core of the pack direction: (R, C) int32 -> (32, R, C//GROUP)
    uint32 XOR-coded plane words (the XLA twin of ``_kernel``; same
    arithmetic, stacked output instead of per-plane ref writes)."""
    u = q.astype(jnp.uint32)
    nb = (u + NEG_M) ^ NEG_M
    enc = nb ^ (nb >> jnp.uint32(1)) ^ (nb >> jnp.uint32(2))
    R = enc.shape[0]
    g = enc.reshape(R, C // GROUP, GROUP)
    j = jax.lax.broadcasted_iota(jnp.uint32, g.shape, dimension=2)
    shift = jnp.uint32(GROUP - 1) - j
    return jnp.stack([
        jnp.sum(((g >> jnp.uint32(k)) & jnp.uint32(1)) << shift, axis=-1,
                dtype=jnp.uint32)
        for k in range(32)])


@jax.jit
def bitplane_pack_xla(q: jax.Array):
    """Jitted XLA twin of :func:`bitplane_pack_pallas`."""
    R, C = q.shape
    return pack_words(q, C=C)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitplane_pack_pallas(q: jax.Array, *, interpret: bool = True):
    """q: (R, C) int32, R % ROWS_B == 0, C % GROUP == 0.

    Returns packed (32, R, C // GROUP) uint32, plane k = bit k of the
    XOR-encoded negabinary words.
    """
    R, C = q.shape
    assert R % ROWS_B == 0 and C % GROUP == 0
    grid = (R // ROWS_B,)
    return pl.pallas_call(
        functools.partial(_kernel, C=C),
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS_B, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((32, ROWS_B, C // GROUP), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((32, R, C // GROUP), jnp.uint32),
        interpret=interpret,
    )(q)
