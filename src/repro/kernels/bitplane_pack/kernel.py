"""Negabinary + XOR-predictive bitplane packing Pallas TPU kernel (§4.4).

Key TPU adaptation (DESIGN.md §3): the paper's per-plane predictive coding
    enc_k = b_k ^ b_{k+1} ^ b_{k+2}
collapses, over ALL planes at once, into THREE integer ops on the whole
word:      enc = nb ^ (nb >> 1) ^ (nb >> 2)
so the kernel converts q -> negabinary -> XOR-encoded word in O(1) VPU ops
per element, then bit-transposes lanes into packed uint32 plane words
(32 lanes -> one word per plane, MSB-first within the word).

Block layout: (ROWS_B, LANES) int32 in VMEM; output (32, ROWS_B, LANES/32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

ROWS_B = 8
GROUP = 32          # lanes packed per output word
NEG_M = np.uint32(0xAAAAAAAA)


def _kernel(q_ref, out_ref, *, C: int):
    q = q_ref[...]
    u = q.astype(jnp.uint32)
    nb = (u + NEG_M) ^ NEG_M                        # negabinary (§4.4.2)
    enc = nb ^ (nb >> jnp.uint32(1)) ^ (nb >> jnp.uint32(2))  # 2-bit-prefix XOR
    R = enc.shape[0]
    g = enc.reshape(R, C // GROUP, GROUP)
    # pack bit k of 32 consecutive lanes into one uint32 word, lane 0 = MSB.
    # weight exponents come from an in-kernel iota (vector constants cannot
    # be captured by a Pallas kernel body).
    j = jax.lax.broadcasted_iota(jnp.uint32, g.shape, dimension=2)
    shift = jnp.uint32(GROUP - 1) - j
    for k in range(32):
        bits = (g >> jnp.uint32(k)) & jnp.uint32(1)
        out_ref[k, :, :] = jnp.sum(bits << shift, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitplane_pack_pallas(q: jax.Array, *, interpret: bool = True):
    """q: (R, C) int32, R % ROWS_B == 0, C % GROUP == 0.

    Returns packed (32, R, C // GROUP) uint32, plane k = bit k of the
    XOR-encoded negabinary words.
    """
    R, C = q.shape
    assert R % ROWS_B == 0 and C % GROUP == 0
    grid = (R // ROWS_B,)
    return pl.pallas_call(
        functools.partial(_kernel, C=C),
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS_B, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((32, ROWS_B, C // GROUP), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((32, R, C // GROUP), jnp.uint32),
        interpret=interpret,
    )(q)
