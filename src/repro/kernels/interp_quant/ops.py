"""Public jit'd wrappers for the fused interpolate+quantize kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import dispatch, mode
from .kernel import ROWS_B, interp_quant_pallas, interp_quant_xla


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interp_quant(x, xhat, *, s: int, eb: float, interp: str = "cubic",
                 interpret: bool | None = None):
    """Fused phase sweep for arbitrary (R, C): pads rows to the block size.

    Returns (q int32 (R, T), pred (R, T)) for targets at odd multiples of s
    along the last axis; the dequantized writeback is ``pred + 2*eb*q``
    (left to the caller so it can be computed with the archive-canonical
    numpy rounding — see kernel.py on fma contraction).
    """
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x)
    xhat = jnp.asarray(xhat, x.dtype)
    R, C = x.shape
    pad = (-R) % ROWS_B
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        xhat = jnp.pad(xhat, ((0, pad), (0, 0)))
    dispatch.record("interp_quant",
                    nbytes=(2 * x.size + 2 * R * (x.shape[1] // (2 * s))) *
                    x.dtype.itemsize)
    if mode.use_xla():
        q, pred = interp_quant_xla(x, xhat, s=s, eb=eb, interp=interp)
    else:
        q, pred = interp_quant_pallas(x, xhat, s=s, eb=eb, interp=interp,
                                      interpret=interpret)
    return q[:R], pred[:R]


def interp_quant_batch(x, xhat, *, s: int, eb: float, interp: str = "cubic",
                       interpret: bool | None = None, mesh=None):
    """Batched phase sweep over stacked equal-shape chunks: (B, R, C).

    ``jax.vmap`` turns the batch axis into an extra grid dimension of ONE
    kernel launch, so B chunks cost a single dispatch instead of B.  Each
    batch element is padded/computed exactly like a lone ``interp_quant``
    call, so per-chunk results are bit-identical to the unbatched path.

    With ``mesh`` (a 1-D codec mesh), the batch axis is zero-padded to a
    mesh multiple (``codec_mesh.pad_to_shards``) and ``shard_map`` places
    consecutive rows on consecutive devices, each running the same vmapped
    kernel — one collective-free launch per device, one *logical* dispatch
    total (recorded with ``devices=mesh size``), pad rows sliced off.
    One function holds both layouts so the byte-critical padding/reshape
    math cannot drift between them.
    """
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x)
    xhat = jnp.asarray(xhat, x.dtype)
    B, R, C = x.shape
    pad = (-R) % ROWS_B
    padb = 0
    if mesh is not None:
        from ...parallel import codec_mesh
        padb = codec_mesh.pad_to_shards(B, mesh)
    if pad or padb:
        x = jnp.pad(x, ((0, padb), (0, pad), (0, 0)))
        xhat = jnp.pad(xhat, ((0, padb), (0, pad), (0, 0)))

    if mode.use_xla():
        def kernel(a, b):
            return interp_quant_xla(a, b, s=s, eb=eb, interp=interp)
    else:
        def kernel(a, b):
            return interp_quant_pallas(a, b, s=s, eb=eb, interp=interp,
                                       interpret=interpret)

    nbytes = (2 * x.size + 2 * x.shape[0] * x.shape[1] *
              (x.shape[2] // (2 * s))) * x.dtype.itemsize
    if mesh is None:
        dispatch.record("interp_quant", batch=B, nbytes=nbytes)
        q, pred = jax.vmap(kernel)(x, xhat)
    else:
        dispatch.record("interp_quant", batch=B,
                        devices=codec_mesh.shard_count(mesh), nbytes=nbytes)
        q, pred = codec_mesh.shard_vmap(kernel, mesh, n_out=2)(x, xhat)
    return q[:B, :R], pred[:B, :R]


def interp_quant_sharded(x, xhat, *, s: int, eb: float, mesh,
                         interp: str = "cubic",
                         interpret: bool | None = None):
    """Sharded phase sweep: ``interp_quant_batch`` with the (B, R, C)
    batch axis split over the 1-D codec ``mesh`` (thin alias; see the
    batched entry for the layout/dispatch contract)."""
    return interp_quant_batch(x, xhat, s=s, eb=eb, interp=interp,
                              interpret=interpret, mesh=mesh)
