"""Fused interpolation-predict + quantize Pallas TPU kernel.

One (level, dim) sweep of §4.1 with the sweep axis laid out on lanes:
for a row-block in VMEM, predict target columns (odd multiples of stride s)
from neighbour columns at +-s / +-3s, quantize the residual against the
original values, and emit both the int32 bins and the predictions — one
HBM round-trip for what the CPU reference does in two gather-heavy passes
(predict, quantize).  The dequantized writeback ``pred + 2*eb*q`` is left
to the caller: emitting pred instead of recon keeps the kernel bit-exact
against the numpy reference regardless of FMA contraction (see below).

TPU adaptation (DESIGN.md §3): neighbour access uses *static strided
slices* (lane-aligned, no gathers); boundary fallback masks are trace-time
constants; blocks are (ROWS_B x C) so the whole sweep axis sits in VMEM —
C up to ~16k f32 fits comfortably (8 x 16k x 4B = 512 KiB).

Bit-exactness vs the numpy backend (backend parity tests): XLA freely
contracts ``a*b + c`` into fma, which rounds differently from numpy's
separate mul+add.  Every mul+add pair here is therefore written so that
contraction cannot change the result: ``9*x`` is computed as ``8*x + x``
(8*x is exact, so fma(8, x, x) == round(9x) == round(8x + x)), and the
remaining adds have no adjacent multiply to fuse with.  The final quantize
uses a divide, which XLA never contracts.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

ROWS_B = 8  # sublane-aligned row block


def _neighbors(xh, s: int, C: int, T: int):
    """l3,l1,r1,r3 columns for targets idx=s+2s*j, j<T, via static slices."""
    # l1: idx-s = 0, 2s, 4s, ...            always valid
    l1 = xh[:, 0:2 * s * T:2 * s]
    # r1: idx+s = 2s, 4s, ...               last may exceed C-1
    r1_valid = [c for c in range(2 * s, C, 2 * s)][:T]
    r1 = xh[:, 2 * s:2 * s * (len(r1_valid)) + 1:2 * s]
    if len(r1_valid) < T:  # clamp: reuse l1's last column (copy-left fallback)
        r1 = jnp.concatenate([r1, l1[:, len(r1_valid):T]], axis=1)
    # l3: idx-3s = -2s, 0, 2s, ...          first invalid -> clamp to col 0
    l3 = jnp.concatenate([xh[:, 0:1], xh[:, 0:2 * s * (T - 1):2 * s]], axis=1) \
        if T > 1 else xh[:, 0:1]
    # r3: idx+3s = 4s, 6s, ...              tail may exceed -> clamp to last valid
    r3_cols = [min(c, C - 1) for c in range(4 * s, 4 * s + 2 * s * T, 2 * s)]
    # static slices where possible, then patch the clamped tail
    n_ok = sum(1 for c in range(4 * s, 4 * s + 2 * s * T, 2 * s) if c <= C - 1)
    r3_main = xh[:, 4 * s:4 * s + 2 * s * n_ok:2 * s]
    if n_ok < T:
        r3 = jnp.concatenate([r3_main,
                              jnp.repeat(xh[:, C - 1:C], T - n_ok, axis=1)], axis=1)
    else:
        r3 = r3_main
    return l3, l1, r1, r3


def _masks(s: int, C: int, T: int) -> Tuple[np.ndarray, np.ndarray]:
    idx = np.arange(s, C, 2 * s)[:T]
    r_ok = idx + s <= C - 1
    cubic_ok = (idx - 3 * s >= 0) & (idx + 3 * s <= C - 1) & r_ok
    return cubic_ok, r_ok


def _select_runs(parts_by_choice, choice: np.ndarray):
    """Assemble pred from static runs of identical boundary choice.

    Boundary fallback only happens at the edges, so ``choice`` has <= 4 runs;
    static concatenation of slices avoids both vector-constant captures
    (disallowed in Pallas kernels) and per-lane selects.
    """
    T = choice.size
    runs, start = [], 0
    for j in range(1, T + 1):
        if j == T or choice[j] != choice[start]:
            runs.append((start, j, int(choice[start])))
            start = j
    parts = [parts_by_choice[c][:, a:b] for a, b, c in runs]
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)


def _predict(xh, *, s: int, interp: str, C: int, T: int):
    """Phase-sweep prediction for target columns, shared by the encode
    (interp_quant) and decode (interp_recon) kernels — one definition so the
    fma-contraction-proof spelling below stays bit-identical on both sides.
    """
    l3, l1, r1, r3 = _neighbors(xh, s, C, T)
    lin = 0.5 * (l1 + r1)
    cubic_ok, r_ok = _masks(s, C, T)
    if interp == "linear":
        return _select_runs({1: lin, 0: l1}, r_ok.astype(np.int8))
    # 9*x spelled 8*x + x: fma-contraction-proof (8*x is exact), same
    # association as the numpy reference ((-l3 + 9l1) + 9r1) - r3
    cub = (-l3 + (8.0 * l1 + l1) + (8.0 * r1 + r1) - r3) * (1.0 / 16.0)
    choice = np.where(cubic_ok, 2, np.where(r_ok, 1, 0))
    return _select_runs({2: cub, 1: lin, 0: l1}, choice)


def _kernel(x_ref, xh_ref, q_ref, pred_ref, *, s: int, eb: float,
            interp: str, C: int, T: int):
    xh = xh_ref[...]
    x = x_ref[...]
    pred = _predict(xh, s=s, interp=interp, C=C, T=T)
    tgt = x[:, s:s + 2 * s * T:2 * s]
    # divide (not multiply-by-reciprocal): bit-identical rounding vs the oracle
    q_ref[...] = jnp.rint((tgt - pred) / (2.0 * eb)).astype(jnp.int32)
    pred_ref[...] = pred.astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("s", "eb", "interp"))
def interp_quant_xla(x: jax.Array, xhat: jax.Array, *, s: int, eb: float,
                     interp: str = "cubic"):
    """Jitted XLA twin of :func:`interp_quant_pallas`: the shared
    ``_predict`` core + the same divide-based quantize, compiled on any
    backend (the ``IPCOMP_KERNEL_MODE=xla`` path)."""
    R, C = x.shape
    T = len(range(s, C, 2 * s))
    pred = _predict(xhat, s=s, interp=interp, C=C, T=T)
    tgt = x[:, s:s + 2 * s * T:2 * s]
    q = jnp.rint((tgt - pred) / (2.0 * eb)).astype(jnp.int32)
    return q, pred.astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("s", "eb", "interp", "interpret"))
def interp_quant_pallas(x: jax.Array, xhat: jax.Array, *, s: int, eb: float,
                        interp: str = "cubic", interpret: bool = True):
    """x, xhat: (R, C) with R % ROWS_B == 0. Returns (q (R,T) i32, pred (R,T))."""
    R, C = x.shape
    T = len(range(s, C, 2 * s))
    assert R % ROWS_B == 0 and T > 0
    grid = (R // ROWS_B,)
    bspec_in = pl.BlockSpec((ROWS_B, C), lambda i: (i, 0))
    bspec_out = pl.BlockSpec((ROWS_B, T), lambda i: (i, 0))
    kern = functools.partial(_kernel, s=s, eb=eb, interp=interp, C=C, T=T)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[bspec_in, bspec_in],
        out_specs=[bspec_out, bspec_out],
        out_shape=[jax.ShapeDtypeStruct((R, T), jnp.int32),
                   jax.ShapeDtypeStruct((R, T), x.dtype)],
        interpret=interpret,
    )(x, xhat)
