from .ops import interp_quant, interp_quant_batch, interp_quant_sharded
from .ref import interp_quant_ref, predict_ref

__all__ = ["interp_quant", "interp_quant_batch", "interp_quant_sharded",
           "interp_quant_ref", "predict_ref"]
