"""Pure-jnp oracle for the fused interpolate+quantize phase sweep.

Mirrors repro.core.interpolation.predict_block for a sweep along the last
axis with stride s: targets are odd multiples of s, neighbours at +-s/+-3s,
cubic with linear/copy-left boundary fallback, then linear-scale
quantization q=round(res/2eb).  Like the kernel, returns (q, pred); the
dequantized writeback pred + 2eb*q belongs to the caller.
"""
from __future__ import annotations

import jax.numpy as jnp

COEF = (-1.0 / 16.0, 9.0 / 16.0, 9.0 / 16.0, -1.0 / 16.0)


def predict_ref(xhat: jnp.ndarray, s: int, interp: str = "cubic") -> jnp.ndarray:
    """Predictions for target columns (odd multiples of s) of shape (R, T)."""
    n = xhat.shape[-1]
    idx = jnp.arange(s, n, 2 * s)
    l1 = xhat[..., idx - s]
    r_ok = idx + s <= n - 1
    r1 = xhat[..., jnp.minimum(idx + s, n - 1)]
    lin = 0.5 * (l1 + r1)
    if interp == "linear":
        return jnp.where(r_ok, lin, l1)
    ll_ok = idx - 3 * s >= 0
    rr_ok = idx + 3 * s <= n - 1
    l3 = xhat[..., jnp.maximum(idx - 3 * s, 0)]
    r3 = xhat[..., jnp.minimum(idx + 3 * s, n - 1)]
    cub = COEF[0] * l3 + COEF[1] * l1 + COEF[2] * r1 + COEF[3] * r3
    return jnp.where(ll_ok & rr_ok & r_ok, cub, jnp.where(r_ok, lin, l1))


def interp_quant_ref(x: jnp.ndarray, xhat: jnp.ndarray, s: int, eb: float,
                     interp: str = "cubic"):
    """Returns (q int32 targets, pred targets) for the phase sweep."""
    n = x.shape[-1]
    idx = jnp.arange(s, n, 2 * s)
    pred = predict_ref(xhat, s, interp)
    res = x[..., idx] - pred
    q = jnp.rint(res / (2.0 * eb)).astype(jnp.int32)
    return q, pred.astype(x.dtype)
