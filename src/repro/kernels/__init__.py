"""Pallas TPU kernels for IPComp's compute hot spots.

Two kernels cover the profile of the paper's pipeline (everything else is
metadata-sized):

  interp_quant   — fused interpolation-predict + quantize for one dimension
                   sweep (the O(n) inner loop of §4.1); returns (q, pred) so
                   the archive-canonical dequant-writeback stays in numpy.
  bitplane_pack  — negabinary conversion + 2-bit-prefix XOR predictive coding
                   + cross-lane bitplane packing (§4.4) in a single VMEM pass.

Both codec kernels are wired into ``core.jax_backend`` and drive
``compress(..., backend="jax")``; their blobs/bins are byte-identical to the
numpy reference pipeline (enforced by tests/test_backend_parity.py).
  attention      — flash-attention (GQA) forward for the LM serving/training
                   stack: per-(batch, head, q-tile) programs stream kv tiles
                   with running-softmax state; O(S^2) never touches HBM.

Each kernel ships with ops.py (jit'd public wrapper, interpret-mode switch)
and ref.py (pure-jnp oracle used by the allclose test sweeps).  The container
is CPU-only, so tests run with interpret=True; BlockSpecs are written for
TPU v5e VMEM tiling (8x128-aligned).
"""
