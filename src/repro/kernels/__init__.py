"""Pallas TPU kernels for IPComp's compute hot spots.

Two kernel *pairs* cover the profile of the paper's pipeline — one per
codec direction (everything else is metadata-sized):

  interp_quant    — fused interpolation-predict + quantize for one dimension
                    sweep (the O(n) inner loop of §4.1); returns (q, pred) so
                    the archive-canonical dequant-writeback stays in numpy.
  interp_recon    — its exact inverse: fused predict + add-residual for one
                    reconstruction sweep (the hot loop of retrieval,
                    Algorithms 1–2); shares the prediction code with
                    interp_quant so both directions are bit-identical.
  bitplane_pack   — negabinary conversion + 2-bit-prefix XOR predictive
                    coding + cross-lane bitplane packing (§4.4) in a single
                    VMEM pass (three integer ops per element).
  bitplane_unpack — the inverse: plane-word unpack + closed-form XOR-undo
                    ((1+x+x^2)^-1 over GF(2) = 22 shift/XORs) + negabinary
                    decode back to int32 bins.

All four are wired into ``core.jax_backend`` behind the
``core.pipeline.backends`` registry and drive ``compress`` / ``retrieve`` /
``refine`` / ``decompress`` with ``backend="jax"``; blobs, bins, and
reconstructions are byte/bit-identical to the numpy reference pipeline
(enforced by tests/test_backend_parity.py and tests/test_decode_parity.py).
Each wrapper also ships a ``jax.vmap``-ed ``*_batch`` entry point over
stacks of equal-shaped problems — the chunk-batch engine's unit: B chunks,
one launch — and a ``*_sharded`` entry point that splits the same stack
over a 1-D device mesh via ``parallel.codec_mesh.shard_vmap`` (every
device runs the vmapped kernel on its local rows; one logical dispatch,
mesh-size device launches).  Every launch is counted by
``kernels.dispatch``, including the sharded per-device fan-out (the
batched-vs-looped reduction and the sharded accounting are asserted in
tests and recorded by ``benchmarks/backend_speed.py``).

  attention       — flash-attention (GQA) forward for the LM serving/training
                    stack: per-(batch, head, q-tile) programs stream kv tiles
                    with running-softmax state; O(S^2) never touches HBM.

Each kernel ships with ops.py (jit'd public wrapper, interpret-mode switch)
and ref.py (pure-jnp oracle used by the allclose test sweeps).  The container
is CPU-only, so tests run with interpret=True; BlockSpecs are written for
TPU v5e VMEM tiling (8x128-aligned).
"""
