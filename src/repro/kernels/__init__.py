"""Pallas TPU kernels for IPComp's compute hot spots.

A kernel *quintet* covers the profile of the paper's pipeline — two per
codec direction plus a fused decode megakernel (everything else is
metadata-sized):

  interp_quant    — fused interpolation-predict + quantize for one dimension
                    sweep (the O(n) inner loop of §4.1); returns (q, pred) so
                    the archive-canonical dequant-writeback stays in numpy.
  interp_recon    — its exact inverse: fused predict + add-residual for one
                    reconstruction sweep (the hot loop of retrieval,
                    Algorithms 1–2); shares the prediction code with
                    interp_quant so both directions are bit-identical.  Its
                    ``interp_recon_level`` entry runs BOTH (level, dim)
                    phases of a 2-D level plus the escape overrides in one
                    launch on the level's stride-s subgrid.
  bitplane_pack   — negabinary conversion + 2-bit-prefix XOR predictive
                    coding + cross-lane bitplane packing (§4.4) in a single
                    VMEM pass (three integer ops per element).
  bitplane_unpack — the inverse: plane-word unpack + closed-form XOR-undo
                    ((1+x+x^2)^-1 over GF(2) = 22 shift/XORs) + negabinary
                    decode back to int32 bins.  The truncation mask
                    (``low_zero``) is a RUNTIME operand, so batched streams
                    with different loaded-plane prefixes share one launch.
  decode_fused    — the progressive-decode megakernel: bitplane_unpack +
                    negabinary dequantize + Algorithm 2's delta against the
                    session's previous truncation, one launch per level;
                    ``low_zero`` and the error bound ride along as runtime
                    per-row operands.

All five are wired into ``core.jax_backend`` behind the
``core.pipeline.backends`` registry and drive ``compress`` / ``retrieve`` /
``refine`` / ``decompress`` with ``backend="jax"``; blobs, bins, and
reconstructions are byte/bit-identical to the numpy reference pipeline
(enforced by tests/test_backend_parity.py, tests/test_decode_parity.py and
tests/test_fused_decode.py).  Each wrapper also ships a ``jax.vmap``-ed
``*_batch`` entry point over stacks of equal-shaped problems — the
chunk-batch engine's unit: B chunks, one launch — and a ``*_sharded``
entry point that splits the same stack over a 1-D device mesh via
``parallel.codec_mesh.shard_vmap`` (every device runs the vmapped kernel
on its local rows; one logical dispatch, mesh-size device launches).
Every launch is counted — and its HBM traffic metered — by
``kernels.dispatch`` (the batched-vs-looped reduction and the sharded
accounting are asserted in tests; ``benchmarks/backend_speed.py`` records
throughput and ``benchmarks/roofline_report.py`` turns the byte meters
into achieved-vs-peak bandwidth).

Each kernel ships with ops.py (jit'd public wrapper, interpret-mode
switch) and ref.py or a pure-jnp XLA twin in kernel.py (the oracle for
the parity sweeps).  ``kernels.mode`` selects the substrate per call:
``IPCOMP_KERNEL_MODE=xla`` routes every wrapper to its jitted pure-jnp
twin — the same core functions, compiled by XLA on any backend — which is
what CI's ``compiled`` lane runs on CPU, where Pallas itself is
interpret-only.  BlockSpecs are written for TPU v5e VMEM tiling
(8x128-aligned).
"""
