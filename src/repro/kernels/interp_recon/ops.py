"""Public jit'd wrapper for the fused interpolate+add-residual kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import ROWS_B, interp_recon_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interp_recon(xhat, res, *, s: int, interp: str = "cubic",
                 interpret: bool | None = None):
    """Fused decode phase sweep for arbitrary (R, C): pads rows to the block.

    ``xhat`` (R, C) is the partially reconstructed surface (even multiples of
    s already known), ``res`` (R, T) the dequantized residuals for the target
    columns (odd multiples of s).  Returns recon (R, T) = pred + res; the
    caller scatters it back into the sweep view (and applies any escape
    overrides) — the exact inverse of ``interp_quant``'s contract.
    """
    if interpret is None:
        interpret = not _on_tpu()
    xhat = jnp.asarray(xhat)
    res = jnp.asarray(res, xhat.dtype)
    R, C = xhat.shape
    pad = (-R) % ROWS_B
    if pad:
        xhat = jnp.pad(xhat, ((0, pad), (0, 0)))
        res = jnp.pad(res, ((0, pad), (0, 0)))
    out = interp_recon_pallas(xhat, res, s=s, interp=interp,
                              interpret=interpret)
    return out[:R]
