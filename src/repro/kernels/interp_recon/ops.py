"""Public jit'd wrappers for the fused interpolate+add-residual kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import dispatch, mode
from .kernel import (ROWS_B, interp_recon_level_pallas,
                     interp_recon_level_xla, interp_recon_pallas,
                     interp_recon_xla)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interp_recon(xhat, res, *, s: int, interp: str = "cubic",
                 interpret: bool | None = None):
    """Fused decode phase sweep for arbitrary (R, C): pads rows to the block.

    ``xhat`` (R, C) is the partially reconstructed surface (even multiples of
    s already known), ``res`` (R, T) the dequantized residuals for the target
    columns (odd multiples of s).  Returns recon (R, T) = pred + res; the
    caller scatters it back into the sweep view (and applies any escape
    overrides) — the exact inverse of ``interp_quant``'s contract.
    """
    if interpret is None:
        interpret = not _on_tpu()
    xhat = jnp.asarray(xhat)
    res = jnp.asarray(res, xhat.dtype)
    R, C = xhat.shape
    pad = (-R) % ROWS_B
    if pad:
        xhat = jnp.pad(xhat, ((0, pad), (0, 0)))
        res = jnp.pad(res, ((0, pad), (0, 0)))
    isz = xhat.dtype.itemsize
    dispatch.record("interp_recon",
                    nbytes=(xhat.size + 2 * res.size) * isz)
    if mode.use_xla():
        out = interp_recon_xla(xhat, res, s=s, interp=interp)
    else:
        out = interp_recon_pallas(xhat, res, s=s, interp=interp,
                                  interpret=interpret)
    return out[:R]


def interp_recon_batch(xhat, res, *, s: int, interp: str = "cubic",
                       interpret: bool | None = None, mesh=None):
    """Batched decode phase sweep over stacked equal-shape chunks: (B, R, C).

    ``jax.vmap`` makes the batch axis an extra grid dimension of ONE kernel
    launch — B chunks, one dispatch.  Each batch element is padded/computed
    exactly like a lone ``interp_recon`` call, so per-chunk reconstructions
    are bit-identical to the unbatched path.

    With ``mesh``, the batch axis is zero-padded to a mesh multiple and
    split across the 1-D codec mesh by ``shard_map`` around the identical
    vmapped kernel — no collectives, one logical dispatch, ``mesh size``
    device launches, pad rows sliced off.  One function holds both
    layouts so the padding/reshape math cannot drift between them.
    """
    if interpret is None:
        interpret = not _on_tpu()
    xhat = jnp.asarray(xhat)
    res = jnp.asarray(res, xhat.dtype)
    B, R, C = xhat.shape
    pad = (-R) % ROWS_B
    padb = 0
    if mesh is not None:
        from ...parallel import codec_mesh
        padb = codec_mesh.pad_to_shards(B, mesh)
    if pad or padb:
        xhat = jnp.pad(xhat, ((0, padb), (0, pad), (0, 0)))
        res = jnp.pad(res, ((0, padb), (0, pad), (0, 0)))

    if mode.use_xla():
        def kernel(a, b):
            return interp_recon_xla(a, b, s=s, interp=interp)
    else:
        def kernel(a, b):
            return interp_recon_pallas(a, b, s=s, interp=interp,
                                       interpret=interpret)

    isz = xhat.dtype.itemsize
    nbytes = (xhat.size + 2 * res.size) * isz
    if mesh is None:
        dispatch.record("interp_recon", batch=B, nbytes=nbytes)
        out = jax.vmap(kernel)(xhat, res)
    else:
        dispatch.record("interp_recon", batch=B,
                        devices=codec_mesh.shard_count(mesh), nbytes=nbytes)
        out = codec_mesh.shard_vmap(kernel, mesh)(xhat, res)
    return out[:B, :R]


def interp_recon_sharded(xhat, res, *, s: int, mesh, interp: str = "cubic",
                         interpret: bool | None = None):
    """Sharded decode phase sweep: ``interp_recon_batch`` with the batch
    axis split over the 1-D codec ``mesh`` (thin alias)."""
    return interp_recon_batch(xhat, res, s=s, interp=interp,
                              interpret=interpret, mesh=mesh)


def _level_nbytes(g, res0, res1, ov0, ov1) -> int:
    n = 2 * g.size
    for r in (res0, res1):
        if r is not None:
            n += r.size
    for ov in (ov0, ov1):
        if ov is not None:
            n += ov[0].size + ov[1].size
    return n * g.dtype.itemsize


def interp_recon_level(g, res0=None, res1=None, *, interp: str = "cubic",
                       ov0=None, ov1=None, interpret: bool | None = None):
    """ONE launch for one whole 2-D level: both (level, dim) phase sweeps
    plus escape overrides, on the level's stride-s subgrid.

    ``g`` (Ms, Ns) is ``xhat[::s, ::s]``; ``res0`` (T0, Nse) / ``res1``
    (Ms, T1) the phases' dequantized residual blocks (None = phase empty);
    ``ov0`` / ``ov1`` optional ``(mask, values)`` dense override pairs per
    block.  Returns the updated subgrid — the caller writes it back with
    ``xhat[::s, ::s] = out``.  Replaces two ``interp_recon`` launches and
    a host override scatter per level.
    """
    if interpret is None:
        interpret = not _on_tpu()
    g = jnp.asarray(g)
    res0 = None if res0 is None else jnp.asarray(res0, g.dtype)
    res1 = None if res1 is None else jnp.asarray(res1, g.dtype)
    m0 = v0 = m1 = v1 = None
    if ov0 is not None:
        m0 = jnp.asarray(ov0[0], jnp.int32)
        v0 = jnp.asarray(ov0[1], g.dtype)
    if ov1 is not None:
        m1 = jnp.asarray(ov1[0], jnp.int32)
        v1 = jnp.asarray(ov1[1], g.dtype)
    dispatch.record("interp_recon",
                    nbytes=_level_nbytes(g, res0, res1, ov0, ov1))
    if mode.use_xla():
        return interp_recon_level_xla(g, res0, res1, m0, v0, m1, v1,
                                      interp=interp)
    return interp_recon_level_pallas(g, res0, res1, m0, v0, m1, v1,
                                     interp=interp, interpret=interpret)


def interp_recon_level_batch(g, res0=None, res1=None, *,
                             interp: str = "cubic", ov0=None, ov1=None,
                             interpret: bool | None = None, mesh=None):
    """Batched whole-level sweep over stacked equal-shape chunks.

    ``g`` is (B, Ms, Ns); residual blocks and override pairs carry the same
    leading batch axis (phase presence is uniform across the stack — equal
    shapes share a traversal).  One vmapped launch covers all B chunks;
    with ``mesh`` the stack is zero-padded to a mesh multiple and split
    across the 1-D codec mesh (pad subgrids reconstruct zeros, sliced off).
    """
    if interpret is None:
        interpret = not _on_tpu()
    g = jnp.asarray(g)
    B = g.shape[0]
    res0 = None if res0 is None else jnp.asarray(res0, g.dtype)
    res1 = None if res1 is None else jnp.asarray(res1, g.dtype)
    m0 = v0 = m1 = v1 = None
    if ov0 is not None:
        m0 = jnp.asarray(ov0[0], jnp.int32)
        v0 = jnp.asarray(ov0[1], g.dtype)
    if ov1 is not None:
        m1 = jnp.asarray(ov1[0], jnp.int32)
        v1 = jnp.asarray(ov1[1], g.dtype)
    padb = 0
    if mesh is not None:
        from ...parallel import codec_mesh
        padb = codec_mesh.pad_to_shards(B, mesh)
        if padb:
            def padb_fn(a):
                return None if a is None else jnp.pad(
                    a, ((0, padb),) + ((0, 0),) * (a.ndim - 1))
            g, res0, res1, m0, v0, m1, v1 = (
                padb_fn(a) for a in (g, res0, res1, m0, v0, m1, v1))

    has0, ovf0 = res0 is not None, m0 is not None
    has1, ovf1 = res1 is not None, m1 is not None
    args = [a for a in (g, res0, m0, v0, res1, m1, v1) if a is not None]

    def kernel(*a):
        it = iter(a)
        gg = next(it)
        r0 = next(it) if has0 else None
        mm0 = next(it) if ovf0 else None
        vv0 = next(it) if ovf0 else None
        r1 = next(it) if has1 else None
        mm1 = next(it) if ovf1 else None
        vv1 = next(it) if ovf1 else None
        if mode.use_xla():
            return interp_recon_level_xla(gg, r0, r1, mm0, vv0, mm1, vv1,
                                          interp=interp)
        return interp_recon_level_pallas(gg, r0, r1, mm0, vv0, mm1, vv1,
                                         interp=interp, interpret=interpret)

    nbytes = _level_nbytes(g, res0, res1, ov0, ov1)
    if mesh is None:
        dispatch.record("interp_recon", batch=B, nbytes=nbytes)
        out = jax.vmap(kernel)(*args)
    else:
        dispatch.record("interp_recon", batch=B,
                        devices=codec_mesh.shard_count(mesh), nbytes=nbytes)
        out = codec_mesh.shard_vmap(kernel, mesh)(*args)
    return out[:B]


def interp_recon_level_sharded(g, res0=None, res1=None, *, mesh,
                               interp: str = "cubic", ov0=None, ov1=None,
                               interpret: bool | None = None):
    """Sharded whole-level sweep: ``interp_recon_level_batch`` with the
    stack split over the 1-D codec ``mesh`` (thin alias)."""
    return interp_recon_level_batch(g, res0, res1, interp=interp, ov0=ov0,
                                    ov1=ov1, interpret=interpret, mesh=mesh)
