"""Public jit'd wrappers for the fused interpolate+add-residual kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import dispatch
from .kernel import ROWS_B, interp_recon_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interp_recon(xhat, res, *, s: int, interp: str = "cubic",
                 interpret: bool | None = None):
    """Fused decode phase sweep for arbitrary (R, C): pads rows to the block.

    ``xhat`` (R, C) is the partially reconstructed surface (even multiples of
    s already known), ``res`` (R, T) the dequantized residuals for the target
    columns (odd multiples of s).  Returns recon (R, T) = pred + res; the
    caller scatters it back into the sweep view (and applies any escape
    overrides) — the exact inverse of ``interp_quant``'s contract.
    """
    if interpret is None:
        interpret = not _on_tpu()
    xhat = jnp.asarray(xhat)
    res = jnp.asarray(res, xhat.dtype)
    R, C = xhat.shape
    pad = (-R) % ROWS_B
    if pad:
        xhat = jnp.pad(xhat, ((0, pad), (0, 0)))
        res = jnp.pad(res, ((0, pad), (0, 0)))
    dispatch.record("interp_recon")
    out = interp_recon_pallas(xhat, res, s=s, interp=interp,
                              interpret=interpret)
    return out[:R]


def interp_recon_batch(xhat, res, *, s: int, interp: str = "cubic",
                       interpret: bool | None = None):
    """Batched decode phase sweep over stacked equal-shape chunks: (B, R, C).

    ``jax.vmap`` makes the batch axis an extra grid dimension of ONE kernel
    launch — B chunks, one dispatch.  Each batch element is padded/computed
    exactly like a lone ``interp_recon`` call, so per-chunk reconstructions
    are bit-identical to the unbatched path.
    """
    if interpret is None:
        interpret = not _on_tpu()
    xhat = jnp.asarray(xhat)
    res = jnp.asarray(res, xhat.dtype)
    B, R, C = xhat.shape
    pad = (-R) % ROWS_B
    if pad:
        xhat = jnp.pad(xhat, ((0, 0), (0, pad), (0, 0)))
        res = jnp.pad(res, ((0, 0), (0, pad), (0, 0)))
    dispatch.record("interp_recon", batch=B)
    out = jax.vmap(lambda a, b: interp_recon_pallas(a, b, s=s, interp=interp,
                                                    interpret=interpret))(
        xhat, res)
    return out[:, :R]
