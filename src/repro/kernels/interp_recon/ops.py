"""Public jit'd wrappers for the fused interpolate+add-residual kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import dispatch
from .kernel import ROWS_B, interp_recon_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interp_recon(xhat, res, *, s: int, interp: str = "cubic",
                 interpret: bool | None = None):
    """Fused decode phase sweep for arbitrary (R, C): pads rows to the block.

    ``xhat`` (R, C) is the partially reconstructed surface (even multiples of
    s already known), ``res`` (R, T) the dequantized residuals for the target
    columns (odd multiples of s).  Returns recon (R, T) = pred + res; the
    caller scatters it back into the sweep view (and applies any escape
    overrides) — the exact inverse of ``interp_quant``'s contract.
    """
    if interpret is None:
        interpret = not _on_tpu()
    xhat = jnp.asarray(xhat)
    res = jnp.asarray(res, xhat.dtype)
    R, C = xhat.shape
    pad = (-R) % ROWS_B
    if pad:
        xhat = jnp.pad(xhat, ((0, pad), (0, 0)))
        res = jnp.pad(res, ((0, pad), (0, 0)))
    dispatch.record("interp_recon")
    out = interp_recon_pallas(xhat, res, s=s, interp=interp,
                              interpret=interpret)
    return out[:R]


def interp_recon_batch(xhat, res, *, s: int, interp: str = "cubic",
                       interpret: bool | None = None, mesh=None):
    """Batched decode phase sweep over stacked equal-shape chunks: (B, R, C).

    ``jax.vmap`` makes the batch axis an extra grid dimension of ONE kernel
    launch — B chunks, one dispatch.  Each batch element is padded/computed
    exactly like a lone ``interp_recon`` call, so per-chunk reconstructions
    are bit-identical to the unbatched path.

    With ``mesh``, the batch axis is zero-padded to a mesh multiple and
    split across the 1-D codec mesh by ``shard_map`` around the identical
    vmapped kernel — no collectives, one logical dispatch, ``mesh size``
    device launches, pad rows sliced off.  One function holds both
    layouts so the padding/reshape math cannot drift between them.
    """
    if interpret is None:
        interpret = not _on_tpu()
    xhat = jnp.asarray(xhat)
    res = jnp.asarray(res, xhat.dtype)
    B, R, C = xhat.shape
    pad = (-R) % ROWS_B
    padb = 0
    if mesh is not None:
        from ...parallel import codec_mesh
        padb = codec_mesh.pad_to_shards(B, mesh)
    if pad or padb:
        xhat = jnp.pad(xhat, ((0, padb), (0, pad), (0, 0)))
        res = jnp.pad(res, ((0, padb), (0, pad), (0, 0)))

    def kernel(a, b):
        return interp_recon_pallas(a, b, s=s, interp=interp,
                                   interpret=interpret)

    if mesh is None:
        dispatch.record("interp_recon", batch=B)
        out = jax.vmap(kernel)(xhat, res)
    else:
        dispatch.record("interp_recon", batch=B,
                        devices=codec_mesh.shard_count(mesh))
        out = codec_mesh.shard_vmap(kernel, mesh)(xhat, res)
    return out[:B, :R]


def interp_recon_sharded(xhat, res, *, s: int, mesh, interp: str = "cubic",
                         interpret: bool | None = None):
    """Sharded decode phase sweep: ``interp_recon_batch`` with the batch
    axis split over the 1-D codec ``mesh`` (thin alias)."""
    return interp_recon_batch(xhat, res, s=s, interp=interp,
                              interpret=interpret, mesh=mesh)
