"""Pure-jnp oracle for the fused interpolate+add-residual decode sweep.

Mirrors ``repro.core.interpolation.predict_block`` + the ``pred + res``
writeback of ``interpolation.reconstruct`` for a sweep along the last axis
with stride s; shares ``predict_ref`` with the encode oracle so the two
directions stay inverses by construction.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..interp_quant.ref import predict_ref


def interp_recon_ref(xhat: jnp.ndarray, res: jnp.ndarray, s: int,
                     interp: str = "cubic") -> jnp.ndarray:
    """Returns recon targets (R, T) = predict(xhat) + res."""
    pred = predict_ref(xhat, s, interp)
    return (pred + res).astype(xhat.dtype)
