from .ops import (interp_recon, interp_recon_batch, interp_recon_level,
                  interp_recon_level_batch, interp_recon_level_sharded,
                  interp_recon_sharded)
from .ref import interp_recon_ref

__all__ = ["interp_recon", "interp_recon_batch", "interp_recon_level",
           "interp_recon_level_batch", "interp_recon_level_sharded",
           "interp_recon_sharded", "interp_recon_ref"]
