"""Fused interpolation-predict + add-residual Pallas TPU kernel (decode).

The exact inverse of ``interp_quant``'s phase sweep: for a row-block in
VMEM, predict target columns (odd multiples of stride s) from neighbour
columns of the partially reconstructed surface at +-s / +-3s, then add the
dequantized residual — one HBM round-trip for what the CPU reference does
in two gather-heavy passes (predict, add).  This is the hot loop of
retrieval (paper Algorithms 1–2): every (level, dim) phase of
``interpolation.reconstruct`` maps to one launch.

Bit-exactness vs the numpy decoder: the prediction reuses the encode
kernel's ``_predict`` verbatim (fma-contraction-proof spelling — see
``interp_quant.kernel``), and the residual arrives already dequantized
(f64) so the final ``pred + res`` is a bare add with no adjacent multiply
for XLA to contract.  The escape-override writeback (exact values at
escaped points) is left to the caller: it is a scatter of host-resident
records, and overwriting after the kernel keeps the kernel oblivious to
the escape channel — same division of labour as the encode path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..interp_quant.kernel import ROWS_B, _predict


def _kernel(xh_ref, res_ref, out_ref, *, s: int, interp: str, C: int, T: int):
    xh = xh_ref[...]
    res = res_ref[...]
    pred = _predict(xh, s=s, interp=interp, C=C, T=T)
    # bare add: numpy computes pred and res separately then adds, and there
    # is no multiply adjacent to this add, so contraction cannot occur
    out_ref[...] = (pred + res).astype(xh.dtype)


@functools.partial(jax.jit, static_argnames=("s", "interp", "interpret"))
def interp_recon_pallas(xhat: jax.Array, res: jax.Array, *, s: int,
                        interp: str = "cubic", interpret: bool = True):
    """xhat: (R, C), res: (R, T) with R % ROWS_B == 0.  Returns recon (R, T):
    ``pred + res`` at target columns (odd multiples of s)."""
    R, C = xhat.shape
    T = len(range(s, C, 2 * s))
    assert R % ROWS_B == 0 and T > 0 and res.shape == (R, T)
    grid = (R // ROWS_B,)
    kern = functools.partial(_kernel, s=s, interp=interp, C=C, T=T)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS_B, C), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS_B, T), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS_B, T), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, T), xhat.dtype),
        interpret=interpret,
    )(xhat, res)


@functools.partial(jax.jit, static_argnames=("s", "interp"))
def interp_recon_xla(xhat: jax.Array, res: jax.Array, *, s: int,
                     interp: str = "cubic"):
    """Jitted XLA twin of :func:`interp_recon_pallas`: the shared
    ``_predict`` core over the whole array, compiled on any backend
    (the ``IPCOMP_KERNEL_MODE=xla`` path)."""
    R, C = xhat.shape
    T = len(range(s, C, 2 * s))
    pred = _predict(xhat, s=s, interp=interp, C=C, T=T)
    return (pred + res).astype(xhat.dtype)


def level_core(g, res0=None, res1=None, m0=None, v0=None, m1=None, v1=None,
               *, interp: str = "cubic"):
    """Whole-level reconstruction on the level's subgrid — BOTH (level, dim)
    phases of a 2-D level in one pass.

    ``g`` is the stride-s subgrid ``xhat[::s, ::s]`` (Ms, Ns): level-s
    traversal touches ONLY s-multiples, and on the subgrid the stride
    becomes 1, so the boundary-fallback masks are the full-array masks
    verbatim (``floor((M-1)/2s) == floor((Ms-1)/2)`` — the clamp counts
    coincide, which is what makes the subgrid view bit-identical to the
    strided-view sweeps the host traversal performs).

    Phase 0 (dim 0): predict odd rows from even rows at even columns —
    ``res0`` is (T0, Nse), T0 = Ms//2, Nse = ceil(Ns/2), the phase's
    residual block in stream C-order.  Phase 1 (dim 1): predict odd
    columns from even columns over all Ms rows — ``res1`` is (Ms, T1),
    T1 = Ns//2.  Either may be None (degenerate extents skip the phase,
    mirroring ``iter_phases`` dropping empty target sets).

    ``m0/v0`` and ``m1/v1`` are optional dense escape-override masks and
    values for each phase block (mask != 0 -> take the exact value instead
    of pred + res) — the lossless escape channel applied inside the same
    launch instead of a host writeback between phases.

    Shared by the Pallas kernel body and the jitted XLA twin.
    """
    Ms, Ns = g.shape
    if res0 is not None:
        T0 = res0.shape[0]
        ge = g[:, ::2]                        # (Ms, Nse) even columns
        pred0 = _predict(ge.T, s=1, interp=interp, C=Ms, T=T0).T
        blk0 = pred0 + res0
        if m0 is not None:
            blk0 = jnp.where(m0 != 0, v0, blk0)
        g = g.at[1::2, ::2].set(blk0)
    if res1 is not None:
        T1 = res1.shape[1]
        pred1 = _predict(g, s=1, interp=interp, C=Ns, T=T1)
        blk1 = pred1 + res1
        if m1 is not None:
            blk1 = jnp.where(m1 != 0, v1, blk1)
        g = g.at[:, 1::2].set(blk1)
    return g


def _lvl_kernel(*refs, interp: str, has0: bool, ov0: bool, has1: bool,
                ov1: bool):
    it = iter(refs)
    g = next(it)[...]
    res0 = next(it)[...] if has0 else None
    m0 = next(it)[...] if ov0 else None
    v0 = next(it)[...] if ov0 else None
    res1 = next(it)[...] if has1 else None
    m1 = next(it)[...] if ov1 else None
    v1 = next(it)[...] if ov1 else None
    out_ref = next(it)
    out_ref[...] = level_core(g, res0, res1, m0, v0, m1, v1, interp=interp)


@functools.partial(jax.jit, static_argnames=("interp", "interpret"))
def interp_recon_level_pallas(g: jax.Array,
                              res0: Optional[jax.Array] = None,
                              res1: Optional[jax.Array] = None,
                              m0: Optional[jax.Array] = None,
                              v0: Optional[jax.Array] = None,
                              m1: Optional[jax.Array] = None,
                              v1: Optional[jax.Array] = None, *,
                              interp: str = "cubic", interpret: bool = True):
    """One launch for one whole level: both phase sweeps + escape overrides
    on the (Ms, Ns) subgrid in a single grid step (the level's working set
    is the subgrid itself, so the block IS the array).  Returns the updated
    subgrid; the caller scatters it back with ``xhat[::s, ::s] = out``.
    """
    Ms, Ns = g.shape
    ops, specs = [g], [pl.BlockSpec((Ms, Ns), lambda i: (0, 0))]
    for a in (res0, m0, v0) if m0 is not None else (res0,):
        if a is not None:
            ops.append(a)
            specs.append(pl.BlockSpec(a.shape, lambda i: (0, 0)))
    for a in (res1, m1, v1) if m1 is not None else (res1,):
        if a is not None:
            ops.append(a)
            specs.append(pl.BlockSpec(a.shape, lambda i: (0, 0)))
    kern = functools.partial(_lvl_kernel, interp=interp,
                             has0=res0 is not None, ov0=m0 is not None,
                             has1=res1 is not None, ov1=m1 is not None)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=specs,
        out_specs=pl.BlockSpec((Ms, Ns), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Ms, Ns), g.dtype),
        interpret=interpret,
    )(*ops)


@functools.partial(jax.jit, static_argnames=("interp",))
def interp_recon_level_xla(g: jax.Array,
                           res0: Optional[jax.Array] = None,
                           res1: Optional[jax.Array] = None,
                           m0: Optional[jax.Array] = None,
                           v0: Optional[jax.Array] = None,
                           m1: Optional[jax.Array] = None,
                           v1: Optional[jax.Array] = None, *,
                           interp: str = "cubic"):
    """Jitted XLA twin of :func:`interp_recon_level_pallas`."""
    return level_core(g, res0, res1, m0, v0, m1, v1, interp=interp)
