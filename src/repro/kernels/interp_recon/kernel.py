"""Fused interpolation-predict + add-residual Pallas TPU kernel (decode).

The exact inverse of ``interp_quant``'s phase sweep: for a row-block in
VMEM, predict target columns (odd multiples of stride s) from neighbour
columns of the partially reconstructed surface at +-s / +-3s, then add the
dequantized residual — one HBM round-trip for what the CPU reference does
in two gather-heavy passes (predict, add).  This is the hot loop of
retrieval (paper Algorithms 1–2): every (level, dim) phase of
``interpolation.reconstruct`` maps to one launch.

Bit-exactness vs the numpy decoder: the prediction reuses the encode
kernel's ``_predict`` verbatim (fma-contraction-proof spelling — see
``interp_quant.kernel``), and the residual arrives already dequantized
(f64) so the final ``pred + res`` is a bare add with no adjacent multiply
for XLA to contract.  The escape-override writeback (exact values at
escaped points) is left to the caller: it is a scatter of host-resident
records, and overwriting after the kernel keeps the kernel oblivious to
the escape channel — same division of labour as the encode path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..interp_quant.kernel import ROWS_B, _predict


def _kernel(xh_ref, res_ref, out_ref, *, s: int, interp: str, C: int, T: int):
    xh = xh_ref[...]
    res = res_ref[...]
    pred = _predict(xh, s=s, interp=interp, C=C, T=T)
    # bare add: numpy computes pred and res separately then adds, and there
    # is no multiply adjacent to this add, so contraction cannot occur
    out_ref[...] = (pred + res).astype(xh.dtype)


@functools.partial(jax.jit, static_argnames=("s", "interp", "interpret"))
def interp_recon_pallas(xhat: jax.Array, res: jax.Array, *, s: int,
                        interp: str = "cubic", interpret: bool = True):
    """xhat: (R, C), res: (R, T) with R % ROWS_B == 0.  Returns recon (R, T):
    ``pred + res`` at target columns (odd multiples of s)."""
    R, C = xhat.shape
    T = len(range(s, C, 2 * s))
    assert R % ROWS_B == 0 and T > 0 and res.shape == (R, T)
    grid = (R // ROWS_B,)
    kern = functools.partial(_kernel, s=s, interp=interp, C=C, T=T)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS_B, C), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS_B, T), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS_B, T), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, T), xhat.dtype),
        interpret=interpret,
    )(xhat, res)
