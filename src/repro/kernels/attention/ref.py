"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, causal: bool = True) -> jnp.ndarray:
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32))
    s = s / np.sqrt(D)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)
