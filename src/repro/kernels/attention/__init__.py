from .ops import flash_attention_tpu
from .ref import attention_ref

__all__ = ["flash_attention_tpu", "attention_ref"]
