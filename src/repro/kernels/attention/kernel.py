"""Flash-attention (GQA) Pallas TPU kernel — forward pass.

Grid (B, H, nQ): each program owns one (qb x D) query tile in VMEM and
streams its kv-head's keys/values (index_map folds GQA: kv head = h // G),
carrying the running (max, denom, acc) flash state through a fori_loop
over kv tiles.  Causal masking compares absolute positions built from
``program_id`` and in-kernel iota.  The O(S^2) probability tile exists
only as a (qb x kb) register block — never in HBM.

This is the TPU-native sibling of the pure-XLA ``layers.flash_attention``
(which the dry-run uses so cost_analysis sees the FLOPs); on real v5e
hardware this kernel replaces it via ops.flash_attention_tpu.
VMEM budget per program: q (qb x D) + k,v (kb x D each) + acc — with
qb=kb=512, D=128, f32: ~0.8 MiB, well under the 16 MiB/core budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

QB = 256   # query tile rows
KB = 256   # kv tile rows


def _kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, Sk: int, D: int,
            kb: int):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)          # (qb, D)
    qb = q.shape[0]
    scale = 1.0 / np.sqrt(D)
    nk = Sk // kb

    qpos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)

    def body(ki, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0, 0], ki * kb, kb,
                                         axis=0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0, 0], ki * kb, kb,
                                         axis=0).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            kpos = ki * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
            s = jnp.where(qpos >= kpos, s, -1e30)
        mi = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - mi[:, None])
        a = jnp.exp(m - mi)
        l2 = l * a + jnp.sum(p, axis=1)
        acc2 = acc * a[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return mi, l2, acc2

    m0 = jnp.full((qb,), -1e30, jnp.float32)
    l0 = jnp.zeros((qb,), jnp.float32)
    a0 = jnp.zeros((qb, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "qb", "kb", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, qb: int = QB, kb: int = KB,
                           interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D); H = KV * G; Sq % qb == 0,
    Sk % kb == 0.  Returns (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    grid = (B, H, Sq // qb)
    return pl.pallas_call(
        functools.partial(_kernel, causal=causal, Sk=Sk, D=D, kb=kb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qb, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
