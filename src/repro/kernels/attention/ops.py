"""Public wrapper: (B, S, H, D) layout + padding handling."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import KB, QB, flash_attention_pallas


def flash_attention_tpu(q, k, v, *, causal: bool = True,
                        interpret: bool | None = None):
    """Layout-compatible with layers.flash_attention: q (B, Sq, H, D),
    k/v (B, Sk, KV, D) -> (B, Sq, H, D)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    qb = min(QB, Sq)
    kb = min(KB, Sk)
    pq, pk = (-Sq) % qb, (-Sk) % kb
    qt = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    if pk and not causal:
        # padded keys must not attract mass: push them to -inf via a huge
        # negative key? cleaner: mask by extending causal... for the
        # non-causal path we fall back to masking with a length argument.
        raise NotImplementedError(
            "non-causal with padded Sk: pad Sk to a KB multiple upstream")
    o = flash_attention_pallas(qt, kt, vt, causal=causal, qb=qb, kb=kb,
                               interpret=interpret)
    return o.transpose(0, 2, 1, 3)[:, :Sq]
