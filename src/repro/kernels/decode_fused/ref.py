"""Numpy oracle for the fused progressive-decode megakernel.

The unfused host pipeline, verbatim: sequential MSB-down plane unpack
(``bitplane_pack.ref``), negabinary decode of both words on the host, int
subtraction, ``* 2 * eb`` in the host's association.  The fused kernel
must match this bit-for-bit — the parity suite pins it.
"""
from __future__ import annotations

import numpy as np

from ..bitplane_pack.ref import NEG_M


def _bins(nb: np.ndarray) -> np.ndarray:
    """Negabinary word -> int64 bin (the host ``from_negabinary``)."""
    u = (np.asarray(nb, np.uint32) ^ NEG_M) - NEG_M
    return u.view(np.int32).astype(np.int64)


def decode_fused_ref(nb_new: np.ndarray, nb_old: np.ndarray,
                     eb: float) -> np.ndarray:
    """Reference delta for already-unpacked words: the exact host-side
    arithmetic of the unfused path (int64 bin difference, f64 cast, then
    ``* 2.0 * eb`` left-to-right)."""
    dq = _bins(nb_new) - _bins(nb_old)
    return dq.astype(np.float64) * 2.0 * eb
