"""Fused progressive-decode megakernel (unpack + dequantize-delta).

The retrieval hot path (Algorithm 2 delta cascade) previously did, per
level: one ``bitplane_unpack`` launch, then THREE host passes over the
level stream — negabinary-decode the new word, negabinary-decode the old
word, subtract and scale by ``2 * eb``.  This kernel fuses all of it into
ONE launch: packed plane words + the previous progressive state (the
truncated negabinary words the session already holds) go in, the new
negabinary words and the ready-to-apply f64 residual delta come out.  The
host never touches the int bins again.

Bit parity with the host pipeline is exact, not approximate: both old and
new bins are int32-valued, so their f64 difference is exact (< 2^33), the
``* 2.0`` is exact, and the single rounding happens at ``* eb`` — the same
one rounding the host's ``(q_new - q_old).astype(f64) * 2.0 * eb``
performs.  The spelling ``(dq * 2.0) * eb`` pins the association.

``low_zero`` (plane-prefix truncation) and ``eb`` (level error bound) are
RUNTIME operands — (1, 1) arrays — so one trace serves every prefix depth
and every level, and vmapping gives each batched chunk its own pair.

``decode_fused_core`` is the pure-jnp core shared by the Pallas body and
the jitted XLA twin (``IPCOMP_KERNEL_MODE=xla``); it builds on
``bitplane_pack.kernel.unpack_words`` so the unpack arithmetic has exactly
one definition in the tree.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..bitplane_pack.kernel import GROUP, NEG_M, ROWS_B, unpack_words


def decode_fused_core(planes, nb_old, lz, eb, *, W: int):
    """(32, R, W) packed planes + (R, W*GROUP) previous negabinary words +
    runtime (lz, eb) -> (nb_new uint32, delta f64), both (R, W*GROUP).

    ``delta`` is the dequantized residual increment the level sweep adds:
    ``(bin(nb_new) - bin(nb_old)) * 2 * eb``.
    """
    q_new, nb_new = unpack_words(planes, lz, W=W)
    u_old = (nb_old ^ NEG_M) - NEG_M
    q_old = jax.lax.bitcast_convert_type(u_old, jnp.int32)
    dq = q_new.astype(jnp.float64) - q_old.astype(jnp.float64)
    # one rounding, at * eb — matches the host reference's association
    delta = (dq * 2.0) * eb.astype(jnp.float64)
    return nb_new, delta


def _fused_kernel(p_ref, old_ref, lz_ref, eb_ref, nb_ref, d_ref, *, W: int):
    nb_new, delta = decode_fused_core(p_ref[...], old_ref[...],
                                      lz_ref[0, 0], eb_ref[0, 0], W=W)
    nb_ref[...] = nb_new
    d_ref[...] = delta


def _rows_block(R: int) -> int:
    """Row-block size: whole array when small, else the largest multiple of
    ROWS_B that divides R and stays <= 64 — fewer grid steps than the
    unfused unpack's fixed ROWS_B, which matters in interpret mode where
    every grid step is a Python-level iteration."""
    if R <= 64:
        return R
    for rb in (64, 32, 16):
        if R % rb == 0:
            return rb
    return ROWS_B


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_fused_pallas(planes: jax.Array, nb_old: jax.Array,
                        low_zero: jax.Array, eb: jax.Array, *,
                        interpret: bool = True):
    """planes: (32, R, W) uint32; nb_old: (R, W*32) uint32 previous
    progressive words; low_zero, eb: (1, 1) runtime operands.  Returns
    (nb_new (R, W*32) uint32, delta (R, W*32) f64).
    """
    P, R, W = planes.shape
    assert P == 32 and R % ROWS_B == 0
    assert nb_old.shape == (R, W * GROUP)
    RB = _rows_block(R)
    grid = (R // RB,)
    bspec_sc = pl.BlockSpec((1, 1), lambda i: (0, 0))
    bspec_row = pl.BlockSpec((RB, W * GROUP), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_fused_kernel, W=W),
        grid=grid,
        in_specs=[pl.BlockSpec((32, RB, W), lambda i: (0, i, 0)),
                  bspec_row, bspec_sc, bspec_sc],
        out_specs=[bspec_row, bspec_row],
        out_shape=[jax.ShapeDtypeStruct((R, W * GROUP), jnp.uint32),
                   jax.ShapeDtypeStruct((R, W * GROUP), jnp.float64)],
        interpret=interpret,
    )(planes, nb_old, low_zero, eb)


@jax.jit
def decode_fused_xla(planes: jax.Array, nb_old: jax.Array,
                     low_zero: jax.Array, eb: jax.Array):
    """Jitted XLA twin of :func:`decode_fused_pallas` (same core, whole
    array, compiled on any backend)."""
    P, R, W = planes.shape
    return decode_fused_core(planes, nb_old, low_zero[0, 0], eb[0, 0], W=W)
