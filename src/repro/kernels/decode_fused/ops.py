"""Public wrappers for the fused progressive-decode megakernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import dispatch, mode
from ..bitplane_pack.kernel import GROUP, ROWS_B
from ..bitplane_pack.ops import _UNPACK_W, _lz_array
from .kernel import decode_fused_pallas, decode_fused_xla


def _eb_array(eb, B: int | None = None):
    """Normalize ``eb`` to the runtime-operand layout ((1, 1) f64 scalar,
    (B, 1, 1) batched; a lone float broadcasts)."""
    if B is None:
        return jnp.full((1, 1), float(eb), jnp.float64)
    e = np.asarray(eb, np.float64).reshape(-1)
    if e.size == 1:
        e = np.full(B, e[0], np.float64)
    assert e.size == B, "per-chunk eb must match the batch size"
    return jnp.asarray(e).reshape(B, 1, 1)


def decode_fused(plane_words, nb_old, n: int, *, eb: float, low_zero=0,
                 interpret: bool | None = None):
    """One launch per level: (32, NW) packed plane words + the previous
    (n,) negabinary state -> (nb_new (n,) uint32, delta (n,) f64).

    ``delta`` is the dequantized residual increment of Algorithm 2's
    cascade — ``(bin_new - bin_old) * 2 * eb`` — computed on device, bit-
    identical to the unfused host arithmetic.  Replaces one unpack launch
    plus three host passes over the level stream.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    with jax.experimental.enable_x64():
        pw = jnp.asarray(plane_words, jnp.uint32)
        P, NW = pw.shape
        assert P == 32, "expect one row per negabinary digit"
        need = -(-max(n, 1) // (GROUP * _UNPACK_W))
        R = -(-need // ROWS_B) * ROWS_B
        C = R * _UNPACK_W * GROUP
        pad = R * _UNPACK_W - NW
        if pad:
            pw = jnp.pad(pw, ((0, 0), (0, pad)))
        pw = pw.reshape(32, R, _UNPACK_W)
        old = jnp.asarray(nb_old, jnp.uint32).reshape(-1)
        old = jnp.pad(old, (0, C - old.shape[0])).reshape(R, _UNPACK_W * GROUP)
        lz = _lz_array(low_zero)
        ebp = _eb_array(eb)
        # traffic: planes + old words in, new words + f64 delta out
        dispatch.record("decode_fused", nbytes=pw.size * 4 + C * (4 + 4 + 8))
        if mode.use_xla():
            nb_new, delta = decode_fused_xla(pw, old, lz, ebp)
        else:
            nb_new, delta = decode_fused_pallas(pw, old, lz, ebp,
                                                interpret=interpret)
        return nb_new.reshape(-1)[:n], delta.reshape(-1)[:n]


def decode_fused_batch(plane_words, nb_old, n: int, *, eb, low_zero=0,
                       interpret: bool | None = None, mesh=None):
    """Batched twin over stacked equal-n chunks: (B, 32, NW) plane words +
    (B, n) previous states -> ((B, n) nb_new, (B, n) f64 delta), ONE
    launch.  ``low_zero`` and ``eb`` may be scalars or length-B sequences
    — both are runtime per-row operands, so chunks with different loaded
    prefixes AND different level error bounds share the launch.

    With ``mesh``, the stack is zero-padded to a mesh multiple (pad rows
    decode to zero deltas, sliced back off) and split across the 1-D codec
    mesh like every other sharded kernel wrapper.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    with jax.experimental.enable_x64():
        pw = jnp.asarray(plane_words, jnp.uint32)
        B, P, NW = pw.shape
        assert P == 32, "expect one row per negabinary digit"
        need = -(-max(n, 1) // (GROUP * _UNPACK_W))
        R = -(-need // ROWS_B) * ROWS_B
        C = R * _UNPACK_W * GROUP
        pad = R * _UNPACK_W - NW
        padb = 0
        if mesh is not None:
            from ...parallel import codec_mesh
            padb = codec_mesh.pad_to_shards(B, mesh)
        if pad or padb:
            pw = jnp.pad(pw, ((0, padb), (0, 0), (0, pad)))
        pw = pw.reshape(B + padb, 32, R, _UNPACK_W)
        old = jnp.asarray(nb_old, jnp.uint32).reshape(B, -1)
        old = jnp.pad(old, ((0, padb), (0, C - old.shape[1])))
        old = old.reshape(B + padb, R, _UNPACK_W * GROUP)
        lz = _lz_array(low_zero, B)
        ebp = _eb_array(eb, B)
        if padb:
            lz = jnp.pad(lz, ((0, padb), (0, 0), (0, 0)))
            ebp = jnp.pad(ebp, ((0, padb), (0, 0), (0, 0)))

        if mode.use_xla():
            def kernel(a, o, z, e):
                return decode_fused_xla(a, o, z, e)
        else:
            def kernel(a, o, z, e):
                return decode_fused_pallas(a, o, z, e, interpret=interpret)

        nbytes = pw.size * 4 + (B + padb) * C * (4 + 4 + 8)
        if mesh is None:
            dispatch.record("decode_fused", batch=B, nbytes=nbytes)
            nb_new, delta = jax.vmap(kernel)(pw, old, lz, ebp)
        else:
            dispatch.record("decode_fused", batch=B,
                            devices=codec_mesh.shard_count(mesh),
                            nbytes=nbytes)
            nb_new, delta = codec_mesh.shard_vmap(kernel, mesh,
                                                  n_out=2)(pw, old, lz, ebp)
        nb_new = nb_new.reshape(B + padb, -1)[:B, :n]
        delta = delta.reshape(B + padb, -1)[:B, :n]
        return nb_new, delta


def decode_fused_sharded(plane_words, nb_old, n: int, *, mesh, eb,
                         low_zero=0, interpret: bool | None = None):
    """Sharded twin: ``decode_fused_batch`` with the stack split over the
    1-D codec ``mesh`` (thin alias)."""
    return decode_fused_batch(plane_words, nb_old, n, eb=eb,
                              low_zero=low_zero, interpret=interpret,
                              mesh=mesh)
