from .ops import decode_fused, decode_fused_batch, decode_fused_sharded
from .ref import decode_fused_ref

__all__ = ["decode_fused", "decode_fused_batch", "decode_fused_sharded",
           "decode_fused_ref"]
