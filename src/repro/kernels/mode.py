"""Kernel execution mode: Pallas launches vs jitted XLA twins.

Pallas on CPU only supports interpret mode (jax refuses ``interpret=False``
outside TPU), so CI cannot literally compile the kernels on its CPU
runners.  The ``compiled`` CI lane instead sets ``IPCOMP_KERNEL_MODE=xla``:
every public kernel wrapper then routes to a ``jax.jit``-ed pure-jnp twin
of the kernel body — genuinely compiled XLA CPU execution of the same
arithmetic (the twins share the kernel-body core functions, so bit parity
cannot drift), with dispatch accounting still recorded at the wrapper
layer (one wrapper call = one compiled dispatch, same invariant as one
``pallas_call``).

Modes:

  * ``pallas`` (default) — ``pl.pallas_call``; interpret mode on CPU/GPU,
    Mosaic-compiled on TPU;
  * ``xla``             — the jitted pure-jnp core, any backend.

The knob is read per wrapper call (cheap: one env lookup), so tests can
flip it with ``monkeypatch.setenv`` without reimporting anything.
"""
from __future__ import annotations

import os

PALLAS = "pallas"
XLA = "xla"

ENV = "IPCOMP_KERNEL_MODE"


def kernel_mode() -> str:
    """Resolve the active kernel execution mode from the environment."""
    m = os.environ.get(ENV, PALLAS).strip().lower() or PALLAS
    if m not in (PALLAS, XLA):
        raise ValueError(f"{ENV} must be '{PALLAS}' or '{XLA}', got {m!r}")
    return m


def use_xla() -> bool:
    """True when wrappers should dispatch the jitted XLA twin."""
    return kernel_mode() == XLA
