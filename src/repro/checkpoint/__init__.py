from .store import (CheckpointManager, save_checkpoint, restore_checkpoint,
                    progressive_restore)

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint",
           "progressive_restore"]
