from .bundle import Bundle, LeafSpec, write_bundle
from .restore import RestoreSession, read_full
from .store import (CheckpointManager, latest_step, progressive_restore,
                    restore_checkpoint, save_checkpoint, step_path)

__all__ = ["Bundle", "CheckpointManager", "LeafSpec", "RestoreSession",
           "latest_step", "progressive_restore", "read_full",
           "restore_checkpoint", "save_checkpoint", "step_path",
           "write_bundle"]
