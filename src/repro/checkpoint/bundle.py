"""Single-file checkpoint bundles (``IPCB``): a manifest-indexed
directory of per-leaf ``version=3`` archives.

One training step checkpoints to ONE file::

    b"IPCB" | u32 manifest_len | manifest JSON | leaf regions ...

The manifest maps each leaf id to its region ``[offset, offset+nbytes)``
(offsets relative to the data section, so the manifest never depends on
its own rendered length), the leaf's original shape/dtype, the shape it
was compressed as, a full-blob ``sha`` (sha256), and a verified-prefix
pair ``(pfx_size, pfx_sha)`` covering the archive's header + anchors +
escapes region — everything a coarse read touches before the bitplane
ladder — so integrity is checkable on *partial* reads too, not only
full ones.

Layout property the restore path relies on: each ``ipc`` leaf is a
self-contained IPC3 plane-major archive (single chunk by default), so a
coarse restore of the whole bundle reads one contiguous range per leaf
prefix — header, anchors, escapes, then the first ladder segments — and
a refine extends each leaf's range monotonically.  Opened through any
:class:`~repro.core.bytesource.ByteSource`, remote restore over
HTTP-range (``repro.core.remote.HTTPSource``, with its retry/backoff
semantics) is the same code path as a local mmap restore.

Writing is a **parallel partitioned encode**: ``workers`` encoder
threads each compress a deterministic partition of the leaves into a
private ``shard_<k>.bin`` + ``shard_<k>.json`` (the shard manifest);
the merge pass then streams the shards into the final bundle in
original leaf order and publishes it with one atomic ``os.replace`` —
bundle bytes are identical for any worker count.  This is the
single-host shape of per-host sharded encode.

This module is deliberately free of tree/framework concerns: it speaks
``(leaf_id, float32 array)`` pairs.  ``checkpoint.store`` owns the
pytree flattening and the ``LATEST`` pointer; ``checkpoint.restore``
owns progressive decode sessions over these bundles.
"""
from __future__ import annotations

import hashlib
import json
import os
import struct
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Union

import numpy as np

from ..core.bytesource import BufferSource, ByteSource, FileSource, as_source
from ..core.container import (CorruptArchiveError, _read_exact, parse_meta,
                              parse_v3_meta)

MAGIC = b"IPCB"
BUNDLE_VERSION = 1


def _sha(data) -> str:
    return hashlib.sha256(bytes(data)).hexdigest()


@dataclass
class LeafSpec:
    """One leaf handed to the bundle writer: the float32 payload plus
    the metadata needed to restore the original leaf exactly."""
    lid: str
    arr: np.ndarray            # float32, original (pre-compression) shape
    dtype: str                 # original dtype string (restored on read)
    raw_nbytes: int            # original in-memory footprint (accounting)


def _raw_entry(arr: np.ndarray, dtype: str):
    blob = np.ascontiguousarray(arr, np.float32).tobytes()
    digest = _sha(blob)
    entry = dict(kind="raw", shape=list(arr.shape), dtype=dtype,
                 comp_shape=None, nbytes=len(blob), sha=digest,
                 pfx_size=len(blob), pfx_sha=digest)
    return entry, blob


def encode_leaf(spec: LeafSpec, *, rel_eb: float, interp: str,
                lossless_small: int = 4096,
                chunk_elems: Optional[int] = None):
    """Compress one leaf; returns ``(entry, blob)``.

    Leaves smaller than ``lossless_small`` elements (norms, biases,
    scalars) are stored raw — compression metadata would dominate — and
    their verified prefix is the whole blob (raw leaves are always read
    whole).  Everything else is container-selected by measured size:

    * ``ipc``  — an IPC3 plane-major archive (single chunk unless
      ``chunk_elems`` splits it); the target container — coarse reads
      are one contiguous prefix per leaf.  Its verified prefix covers
      header + anchors + escapes (``V3Meta.base_end``).
    * ``ipc1`` — the compact v1 container, chosen when the v3
      plane-major segment directory does not pay for itself at this
      leaf's size (small leaves: the directory is per-(level, plane)
      metadata, near-constant in leaf size).  Still fully bitplane-
      progressive; its verified prefix covers the header (the blob
      index — the payload is verified by the full-read sha path).
    * ``raw``  — fallback when even v1 does not beat the float32 bytes
      (incompressible leaf at this eb): honesty over format purity.

    The choice is per-leaf and recorded in the manifest; restore
    dispatches on it.
    """
    arr = spec.arr
    if arr.size <= lossless_small or arr.ndim == 0:
        return _raw_entry(arr, spec.dtype)
    from ..api import Codec  # deferred: keep the format importable early
    a2 = arr.reshape(arr.shape[0], -1) if arr.ndim > 2 else arr
    raw_len = a2.size * 4
    kind = "ipc"
    blob = Codec(eb=rel_eb, interp=interp, relative=True,
                 chunk_elems=chunk_elems, version=3).compress(a2).tobytes()
    if len(blob) >= raw_len:
        blob1 = Codec(eb=rel_eb, interp=interp,
                      relative=True).compress(a2).tobytes()
        if len(blob1) < len(blob):
            kind, blob = "ipc1", blob1
    if len(blob) >= raw_len:
        return _raw_entry(arr, spec.dtype)
    pfx = parse_v3_meta(BufferSource(blob)).base_end if kind == "ipc" \
        else parse_meta(BufferSource(blob)).header_end
    entry = dict(kind=kind, shape=list(arr.shape), dtype=spec.dtype,
                 comp_shape=list(a2.shape), nbytes=len(blob),
                 sha=_sha(blob), pfx_size=int(pfx), pfx_sha=_sha(blob[:pfx]))
    return entry, blob


def write_bundle(path: str, leaves: List[LeafSpec], *, step: int,
                 rel_eb: float, interp: str, treedef: Optional[str] = None,
                 lossless_small: int = 4096, workers: int = 1,
                 chunk_elems: Optional[int] = None,
                 shard_dir: Optional[str] = None) -> Dict:
    """Parallel partitioned encode + atomic merge; returns the manifest.

    ``workers`` encoder threads each take the deterministic partition
    ``leaves[k::n]``, write their blobs to ``shard_<k>.bin`` and publish
    a ``shard_<k>.json`` shard manifest in ``shard_dir`` (which the
    caller owns — typically a ``.step_*`` temp dir next to ``path``).
    The merge assigns final offsets in original leaf order — NOT shard
    order — so the published bundle is byte-identical for any worker
    count, then streams shard bytes into ``path + ".tmp"`` and
    ``os.replace``\\ s it into place (atomic on POSIX: readers see the
    old bundle or the new one, never a torn one).
    """
    workers = max(1, int(workers or 1))
    nshards = min(workers, max(1, len(leaves)))
    if shard_dir is None:
        shard_dir = os.path.dirname(os.path.abspath(path))
    parts = [leaves[k::nshards] for k in range(nshards)]

    def _encode_shard(k: int) -> Dict[str, Dict]:
        entries: Dict[str, Dict] = {}
        off = 0
        with open(os.path.join(shard_dir, f"shard_{k}.bin"), "wb") as f:
            for spec in parts[k]:
                entry, blob = encode_leaf(
                    spec, rel_eb=rel_eb, interp=interp,
                    lossless_small=lossless_small, chunk_elems=chunk_elems)
                f.write(blob)
                entries[spec.lid] = dict(entry=entry, local_offset=off)
                off += len(blob)
        with open(os.path.join(shard_dir, f"shard_{k}.json"), "w") as f:
            json.dump(entries, f)
        return entries

    if nshards == 1:
        shard_manifests = [_encode_shard(0)]
    else:
        with ThreadPoolExecutor(max_workers=nshards) as ex:
            shard_manifests = list(ex.map(_encode_shard, range(nshards)))

    where: Dict[str, tuple] = {}
    for k, ents in enumerate(shard_manifests):
        for lid, rec in ents.items():
            where[lid] = (k, rec)

    man_leaves: Dict[str, Dict] = {}
    order: List[str] = []
    off = 0
    for spec in leaves:
        entry = dict(where[spec.lid][1]["entry"])
        entry["offset"] = off          # relative to the data section
        man_leaves[spec.lid] = entry
        order.append(spec.lid)
        off += entry["nbytes"]
    manifest = dict(format="IPCB", version=BUNDLE_VERSION, step=int(step),
                    rel_eb=float(rel_eb), interp=interp, treedef=treedef,
                    order=order, leaves=man_leaves,
                    total_raw=int(sum(s.raw_nbytes for s in leaves)),
                    total_comp=int(off))
    mbytes = json.dumps(manifest, sort_keys=True).encode("utf-8")

    tmp_out = os.path.join(shard_dir, "bundle.tmp") \
        if os.path.isdir(shard_dir) else path + ".tmp"
    shard_fs = [open(os.path.join(shard_dir, f"shard_{k}.bin"), "rb")
                for k in range(nshards)]
    try:
        with open(tmp_out, "wb") as out:
            out.write(MAGIC)
            out.write(struct.pack("<I", len(mbytes)))
            out.write(mbytes)
            for spec in leaves:
                k, rec = where[spec.lid]
                shard_fs[k].seek(rec["local_offset"])
                out.write(shard_fs[k].read(man_leaves[spec.lid]["nbytes"]))
            out.flush()
            os.fsync(out.fileno())
    finally:
        for f in shard_fs:
            f.close()
    os.replace(tmp_out, path)          # atomic publish
    for k in range(nshards):
        for suffix in (".bin", ".json"):
            try:
                os.unlink(os.path.join(shard_dir, f"shard_{k}{suffix}"))
            except OSError:
                pass
    return manifest


class Bundle:
    """Read side of an ``IPCB`` bundle over any :class:`ByteSource`.

    The manifest is parsed ONCE at open and cached on the instance —
    every restore round (and every refinement round of a
    :class:`~repro.checkpoint.restore.RestoreSession` holding this
    bundle) reuses it; no path re-reads it per round.  Framing, extents
    and region tiling are validated here, so a truncated or rewritten
    bundle fails at open with :class:`CorruptArchiveError` instead of
    decoding garbage later.
    """

    def __init__(self, src: Union[bytes, ByteSource]):
        self.source = as_source(src)
        head = bytes(_read_exact(self.source, 0, 8, "bundle framing"))
        if head[:4] != MAGIC:
            raise CorruptArchiveError(
                f"not an IPCB checkpoint bundle: expected magic {MAGIC!r}, "
                f"got {head[:4]!r}")
        mlen = struct.unpack("<I", head[4:8])[0]
        if 8 + mlen > self.source.size:
            raise CorruptArchiveError(
                f"corrupt bundle: manifest claims {mlen} bytes but the "
                f"source holds {self.source.size}")
        mbytes = bytes(_read_exact(self.source, 8, mlen, "bundle manifest"))
        self.manifest_sha = _sha(mbytes)
        try:
            self.manifest: Dict[str, Any] = json.loads(mbytes)
        except ValueError as e:
            raise CorruptArchiveError(
                f"corrupt bundle: undecodable manifest ({e})") from e
        if self.manifest.get("format") != "IPCB":
            raise CorruptArchiveError(
                "corrupt bundle: manifest is not an IPCB manifest")
        self.data_start = 8 + mlen
        end = 0
        for lid in self.manifest["order"]:
            e = self.manifest["leaves"][lid]
            if e["offset"] != end:
                raise CorruptArchiveError(
                    f"corrupt bundle: leaf {lid!r} starts at {e['offset']}, "
                    f"expected {end} — leaf regions must tile the data "
                    "section contiguously in manifest order")
            end = e["offset"] + e["nbytes"]
        if self.data_start + end != self.source.size:
            raise CorruptArchiveError(
                f"corrupt bundle: leaf regions end at byte "
                f"{self.data_start + end} but the source holds "
                f"{self.source.size} (truncated or padded bundle)")

    # ------------------------------------------------------------ opening

    @classmethod
    def open(cls, path_or_url, **remote_opts) -> "Bundle":
        """Open a bundle from a local path, an ``http(s)://`` URL, or an
        already-built :class:`ByteSource`.  ``remote_opts`` forward to
        :class:`~repro.core.remote.HTTPSource` (``retries``, ``timeout``,
        ``backoff``, ...), so remote restores inherit the retry /
        degradation semantics of the remote retrieval layer."""
        if isinstance(path_or_url, ByteSource):
            return cls(path_or_url)
        target = os.fspath(path_or_url)
        if target.startswith(("http://", "https://")):
            from ..core.remote import HTTPSource
            return cls(HTTPSource(target, **remote_opts))
        return cls(FileSource(target))

    # ------------------------------------------------------------ manifest

    @property
    def step(self) -> int:
        return int(self.manifest["step"])

    @property
    def rel_eb(self) -> float:
        return float(self.manifest["rel_eb"])

    @property
    def interp(self) -> str:
        return self.manifest["interp"]

    @property
    def leaf_order(self) -> List[str]:
        return list(self.manifest["order"])

    def entry(self, lid: str) -> Dict:
        try:
            return self.manifest["leaves"][lid]
        except KeyError:
            raise KeyError(
                f"bundle for step {self.step} has no leaf {lid!r} "
                f"({len(self.manifest['leaves'])} leaves present)") from None

    # ------------------------------------------------------------ regions

    def leaf_region(self, lid: str):
        e = self.entry(lid)
        return self.data_start + e["offset"], e["nbytes"]

    def leaf_source(self, lid: str) -> ByteSource:
        """A windowed view of the leaf's region: position 0 is the leaf's
        first byte, reads land on the bundle source at absolute offsets
        (range accounting and HTTP Range requests see real bundle
        positions)."""
        off, size = self.leaf_region(lid)
        return self.source.window(off, size)

    def read_leaf_bytes(self, lid: str, verify: bool = True) -> bytes:
        """The leaf's full blob; with ``verify`` the manifest's sha256 is
        checked and a mismatch raises :class:`CorruptArchiveError` naming
        the leaf — on every path, local or remote."""
        off, size = self.leaf_region(lid)
        blob = bytes(_read_exact(self.source, off, size, f"leaf {lid!r}"))
        if verify and _sha(blob) != self.entry(lid)["sha"]:
            raise CorruptArchiveError(
                f"checkpoint leaf {lid!r} failed integrity check: stored "
                f"bytes do not match the manifest sha256 (corrupt or "
                "tampered bundle)")
        return blob

    def verify_leaf_prefix(self, lid: str) -> None:
        """Check the leaf's verified prefix (header + anchors + escapes
        for ``ipc`` leaves, the whole blob for ``raw``) against the
        manifest — the integrity gate for *partial* (progressive) reads,
        which never see the full blob."""
        e = self.entry(lid)
        off, _ = self.leaf_region(lid)
        pfx = bytes(_read_exact(self.source, off, e["pfx_size"],
                                f"leaf {lid!r} prefix"))
        if _sha(pfx) != e["pfx_sha"]:
            raise CorruptArchiveError(
                f"checkpoint leaf {lid!r} failed integrity check: archive "
                f"prefix ({e['pfx_size']} bytes) does not match the "
                "manifest sha256 (corrupt or tampered bundle)")

    # ------------------------------------------------------------ lifecycle

    def close(self) -> None:
        self.source.close()

    def __enter__(self) -> "Bundle":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return (f"Bundle(step={self.step}, {len(self.manifest['leaves'])} "
                f"leaves, {self.source.size} bytes)")
