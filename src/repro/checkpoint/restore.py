"""Progressive restore sessions over checkpoint bundles.

:class:`RestoreSession` is the restart-side consumer of everything the
retrieval stack provides:

* **Grouped decode** — equal-shaped chunk jobs from *different* leaves
  are bucketed together and executed through the shared
  :func:`~repro.core.pipeline.decode.decode_group` batched path, so a
  transformer checkpoint with N identical attention matrices decodes in
  one kernel launch per shape group instead of one per leaf
  (``group_leaves=False`` keeps the per-leaf loop for A/B dispatch
  accounting; bits are identical either way).
* **Refine-reads-only-the-delta** — per-leaf
  :class:`~repro.core.pipeline.state.ChunkedRetrievalState` carries the
  loaded ladder prefix between rounds; a tighter ``weight_error`` (or
  ``None`` = full precision) fetches exactly the missing plane
  segments.  The bundle manifest is parsed once at open and cached on
  the session's :class:`~repro.checkpoint.bundle.Bundle` — no per-round
  manifest re-reads.
* **Restore-while-refine** — :meth:`refine_async` streams the remaining
  planes on a background thread while the trainer steps on the coarse
  weights.  Each round assembles *fresh* output arrays and publishes
  them with one attribute swap under the session lock (double-buffered:
  the tree the trainer holds is never mutated mid-step).
* **Integrity on read** — each leaf's verified prefix (header + anchors
  + escapes; whole blob for raw leaves) is sha-checked the first time
  the leaf is opened, local or remote, raising
  :class:`~repro.core.container.CorruptArchiveError` naming the leaf.
* **Honest accounting** — ``raw`` leaves are read once, cached, and
  report exact-zero error in ``leaf_bounds``; ``bytes_read`` aggregates
  the per-leaf reader ledgers plus the one-time raw reads (integrity
  verification reads are overhead, not retrieval volume, and are not
  counted).

Sessions are framework-free (numpy in, numpy out, keyed by leaf id);
``checkpoint.store`` supplies the pytree ``unflatten`` hook.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from ..core import loader
from ..core.container import (ArchiveReader, CorruptArchiveError,
                              V3ArchiveReader, open_reader)
from ..core.pipeline import spec as pipeline_spec
from ..core.pipeline.decode import decode_group, plan_ladder, plan_retrieval
from ..core.pipeline.encode import group_cap
from ..core.pipeline.spec import ExecPolicy, Fidelity
from ..core.pipeline.state import ChunkedRetrievalState, RetrievalState
from .bundle import Bundle


def read_full(bundle: Bundle, *, verify: bool = True,
              policy: Optional[ExecPolicy] = None) -> Dict[str, np.ndarray]:
    """Full-precision, fully-verified read of every leaf: each blob is
    fetched whole, sha256-checked against the manifest (raising
    :class:`CorruptArchiveError` naming the leaf), then decoded at
    ``Fidelity.full()``.  The non-progressive restore path."""
    from ..api import Archive
    out: Dict[str, np.ndarray] = {}
    for lid in bundle.leaf_order:
        e = bundle.entry(lid)
        blob = bundle.read_leaf_bytes(lid, verify=verify)
        if e["kind"] == "raw":
            arr = np.frombuffer(blob, np.float32).reshape(e["shape"])
        else:
            arr = Archive(blob).open(policy).read(Fidelity.full())
        out[lid] = arr.reshape(e["shape"]).astype(np.dtype(e["dtype"]))
    return out


class RestoreSession:
    """Progressive, refinable restore of one checkpoint bundle.

    ``unflatten`` (optional) maps the session's ``{leaf_id: array}``
    result dict to the caller's tree type; without it, methods return
    the dict itself.  All public methods are thread-safe; decode rounds
    serialize on the session lock (the background refiner and a
    foreground ``restore`` never interleave mid-round).
    """

    def __init__(self, bundle: Union[Bundle, str, bytes], *,
                 policy: Optional[ExecPolicy] = None,
                 propagation: str = loader.SAFE,
                 plane_cache=None, group_leaves: bool = True,
                 verify: bool = True,
                 exact: Optional[Callable[[str], bool]] = None,
                 unflatten: Optional[Callable[[Dict[str, np.ndarray]],
                                              Any]] = None):
        self.bundle = bundle if isinstance(bundle, Bundle) \
            else Bundle.open(bundle)
        self.policy = pipeline_spec.DEFAULT_POLICY if policy is None \
            else policy
        self.propagation = propagation
        self.plane_cache = plane_cache
        self.group_leaves = group_leaves
        self.verify = verify
        #: precision-critical leaf predicate: leaves matching ``exact``
        #: decode at full precision in every round, regardless of the
        #: requested ``weight_error`` (e.g. optimizer second moments,
        #: whose near-zero values flip sign under a range-relative
        #: coarse bound and destabilize the resumed update rule)
        self.exact = exact
        self.unflatten = unflatten
        #: backend-independent primitive counts (``decode_level`` /
        #: ``reconstruct`` / ...) accumulated across rounds — the
        #: dispatch-accounting surface that works on every backend
        self.counters: Dict[str, int] = {}
        #: per-leaf achieved absolute error bound after the last round
        #: (``raw`` leaves: exact 0.0)
        self.leaf_bounds: Dict[str, float] = {}
        self.closed = False
        #: per-leaf reader: V3ArchiveReader for ``ipc`` leaves (plane-
        #: major, contiguous-prefix reads), plain ArchiveReader for the
        #: compact ``ipc1`` leaves (still bitplane-progressive)
        self._readers: Dict[str, Any] = {}
        #: per-leaf decode state: ChunkedRetrievalState for ``ipc``,
        #: RetrievalState (or None before the first round) for ``ipc1``
        self._states: Dict[str, Any] = {}
        self._raw: Dict[str, np.ndarray] = {}
        self._raw_bytes = 0
        self._lock = threading.RLock()
        self._refiner: Optional[threading.Thread] = None
        self._refined: Optional[Tuple[Optional[float], Any]] = None
        self._refine_exc: Optional[BaseException] = None

    # --------------------------------------------------------- properties

    @property
    def manifest(self) -> Dict:
        """The bundle manifest — parsed once at open, cached for every
        refinement round."""
        return self.bundle.manifest

    @property
    def step(self) -> int:
        return self.bundle.step

    @property
    def bytes_read(self) -> int:
        """Retrieval volume so far: per-leaf reader ledgers (anchors +
        escapes + fetched plane blobs) plus one-time raw-leaf reads."""
        with self._lock:
            return sum(r.bytes_read for r in self._readers.values()) \
                + self._raw_bytes

    @property
    def achieved_bound(self) -> float:
        """Max achieved absolute error bound across leaves (0.0 before
        the first round / when every leaf is raw)."""
        with self._lock:
            return max(self.leaf_bounds.values(), default=0.0)

    def leaf_bound(self, lid: str,
                   weight_error: Optional[float]) -> Optional[float]:
        """The absolute per-leaf error bound a relative ``weight_error``
        induces: ``weight_error`` scales each leaf's value range (the
        stored eb is ``rel_eb`` of the range, so the ratio recovers the
        range), floored at the leaf's own eb.  ``None`` = full
        precision; ``raw`` leaves are always exact (0.0); leaves
        matching the session's ``exact`` predicate always restore at
        full precision."""
        if self.bundle.entry(lid)["kind"] == "raw":
            return 0.0
        if weight_error is None or \
                (self.exact is not None and self.exact(lid)):
            return None
        eb = self._reader(lid).meta.eb
        return max(weight_error * eb / self.bundle.rel_eb, eb)

    # ----------------------------------------------------------- plumbing

    def _reader(self, lid: str):
        """The leaf's archive reader, verified on first open.  The
        manifest's ``kind`` must match the stored container (``ipc`` =
        IPC3 plane-major, ``ipc1`` = compact v1) — a mismatch means the
        bundle was rewritten and fails loudly."""
        r = self._readers.get(lid)
        if r is None:
            kind = self.bundle.entry(lid)["kind"]
            if self.verify:
                self.bundle.verify_leaf_prefix(lid)
            r = open_reader(self.bundle.leaf_source(lid))
            want = V3ArchiveReader if kind == "ipc" else ArchiveReader
            if type(r) is not want:
                raise CorruptArchiveError(
                    f"checkpoint leaf {lid!r} is declared {kind!r} in the "
                    f"manifest but its bytes hold a different container "
                    "(rewritten or corrupt bundle)")
            if self.plane_cache is not None:
                r.cache_scope = (self.bundle.manifest_sha, lid)
            self._readers[lid] = r
            self._states[lid] = ChunkedRetrievalState(
                reader=r, chunk_states=[None] * len(r.meta.chunks)) \
                if kind == "ipc" else None
        return r

    def _raw_leaf(self, lid: str) -> np.ndarray:
        arr = self._raw.get(lid)
        if arr is None:
            e = self.bundle.entry(lid)
            blob = self.bundle.read_leaf_bytes(lid, verify=self.verify)
            arr = np.frombuffer(blob, np.float32).reshape(e["shape"]) \
                .astype(np.dtype(e["dtype"]))
            self._raw[lid] = arr
            self._raw_bytes += len(blob)
            self.leaf_bounds[lid] = 0.0   # lossless: honest zero error
        return arr

    # ------------------------------------------------------------ restore

    def restore(self, weight_error: Optional[float] = None):
        """One decode round at ``weight_error`` (relative to each leaf's
        value range; ``None`` = full precision).  Returns fresh arrays —
        previously returned trees are never mutated.  Successive calls
        refine: only the missing plane segments are fetched, and a
        looser request than what is already loaded is a no-op read
        (prefixes never shrink)."""
        with self._lock:
            arrays = self._restore_locked(weight_error)
        return self.unflatten(arrays) if self.unflatten else arrays

    def _restore_locked(self, weight_error: Optional[float]
                        ) -> Dict[str, np.ndarray]:
        if self.closed:
            raise RuntimeError(
                "RestoreSession is closed; open a new session to restore")
        ctx = self.policy.bind(chunked=True, encode=False)
        # plan every compressed leaf first (one ensure_prefix = one
        # contiguous range per plane-major leaf), then bucket chunk jobs
        # ACROSS leaves by chunk shape so equal-shaped leaves share
        # batched kernel launches; an ipc1 leaf is a single job keyed by
        # its own shape, so same-shape v1 leaves batch with each other
        # (and with same-shape v3 chunks — both are plain v1 sub-readers)
        buckets: Dict[Any, List[tuple]] = {}
        round_ts: Dict[str, int] = {}
        for lid in self.bundle.leaf_order:
            e = self.bundle.entry(lid)
            if e["kind"] == "raw":
                self._raw_leaf(lid)
                continue
            reader = self._reader(lid)
            m = reader.meta
            bound = self.leaf_bound(lid, weight_error)
            fid = Fidelity.full() if bound is None \
                else Fidelity.error_bound(bound)
            if e["kind"] == "ipc1":
                keep = plan_retrieval(m, fid, self.propagation).keep_planes
                key = tuple(m.shape) if self.group_leaves else (lid,)
                buckets.setdefault(key, []).append(
                    (lid, None, reader, self._states[lid], keep))
                continue
            st = self._states[lid]
            t = plan_ladder(m, fid, self.propagation, t_min=st.ladder_pos)
            reader.ensure_prefix(t)
            keeps = m.ladder_keeps(t)
            round_ts[lid] = t
            for ci in range(len(m.chunks)):
                sub = reader.chunk_reader(ci)
                key = tuple(sub.meta.shape) if self.group_leaves \
                    else (lid, ci)
                buckets.setdefault(key, []).append(
                    (lid, ci, sub, st.chunk_states[ci], keeps[ci]))
        cap = group_cap(ctx.mesh)
        for jobs in buckets.values():
            for lo in range(0, len(jobs), cap):
                grp = jobs[lo:lo + cap]
                sts = decode_group([j[2] for j in grp], [j[3] for j in grp],
                                   [j[4] for j in grp], ctx,
                                   self.propagation, cache=self.plane_cache,
                                   counters=self.counters)
                for (lid, ci, *_), st_new in zip(grp, sts):
                    if ci is None:
                        self._states[lid] = st_new
                    else:
                        self._states[lid].chunk_states[ci] = st_new
        # finalize per-leaf accounting and assemble fresh outputs
        arrays: Dict[str, np.ndarray] = {}
        for lid in self.bundle.leaf_order:
            e = self.bundle.entry(lid)
            if e["kind"] == "raw":
                arrays[lid] = self._raw[lid]
                continue
            reader, st = self._readers[lid], self._states[lid]
            m = reader.meta
            if e["kind"] == "ipc1":
                out = st.xhat
            else:
                st.err_bound = max(cs.err_bound for cs in st.chunk_states)
                st.bytes_read = reader.bytes_read
                st.ladder_pos = max(st.ladder_pos, round_ts[lid])
                out = np.empty(m.shape, np.dtype(m.dtype))
                for ci, cm in enumerate(m.chunks):
                    out[cm.start:cm.stop] = \
                        st.chunk_states[ci].xhat.astype(np.dtype(m.dtype))
            arrays[lid] = np.asarray(out).reshape(e["shape"]) \
                .astype(np.dtype(e["dtype"]))
            self.leaf_bounds[lid] = float(st.err_bound)
        return arrays

    # ----------------------------------------------- refine-while-training

    def refine_async(self, weight_error: Optional[float] = None,
                     on_update: Optional[Callable] = None
                     ) -> threading.Thread:
        """Stream the remaining planes to ``weight_error`` (``None`` =
        full precision) on a background daemon thread while the caller
        keeps using the coarse tree.  The refined tree is published
        atomically (:meth:`poll_refined` / :meth:`refined`); ``on_update
        (weight_error, tree)`` fires after publication.  One refiner at
        a time."""
        with self._lock:
            if self.closed:
                raise RuntimeError("RestoreSession is closed")
            if self._refiner is not None and self._refiner.is_alive():
                raise RuntimeError("a background refiner is already running")
            self._refine_exc = None
            self._refiner = threading.Thread(
                target=self._refine_body, args=(weight_error, on_update),
                name=f"ckpt-refine-step{self.step}", daemon=True)
            self._refiner.start()
            return self._refiner

    def _refine_body(self, weight_error, on_update):
        try:
            tree = self.restore(weight_error)
            with self._lock:
                self._refined = (weight_error, tree)
            if on_update is not None:
                on_update(weight_error, tree)
        except BaseException as e:     # surfaced via poll_refined/refined
            self._refine_exc = e

    @property
    def refining(self) -> bool:
        t = self._refiner
        return t is not None and t.is_alive()

    @property
    def done(self) -> bool:
        """No refiner running (either never started or finished)."""
        return not self.refining

    def poll_refined(self):
        """Non-blocking: the latest published refined tree, or ``None``
        if not ready.  Re-raises a failed refiner's exception."""
        with self._lock:
            if self._refine_exc is not None:
                exc, self._refine_exc = self._refine_exc, None
                raise exc
            return None if self._refined is None else self._refined[1]

    def refined(self, timeout: Optional[float] = None):
        """Join the refiner and return the refined tree (``None`` if no
        refiner ran).  Re-raises the refiner's exception on failure."""
        t = self._refiner
        if t is not None:
            t.join(timeout)
        return self.poll_refined()

    # --------------------------------------------------- plan introspection

    def ladder_positions(self) -> Dict[str, int]:
        """Per-leaf loaded ladder-prefix length (plane segments) — only
        plane-major (``ipc``) leaves have a ladder."""
        with self._lock:
            return {lid: st.ladder_pos for lid, st in self._states.items()
                    if isinstance(st, ChunkedRetrievalState)}

    def plane_bytes_between(self, before: Dict[str, int],
                            after: Dict[str, int]) -> int:
        """Exact plane-segment bytes between two :meth:`ladder_positions`
        snapshots — what a refine *should* fetch.  The refine-never-
        rereads gate compares this against the session's ``bytes_read``
        delta."""
        total = 0
        with self._lock:
            for lid, t1 in after.items():
                cum = self._readers[lid].meta.cum_bytes
                total += cum[t1] - cum[before.get(lid, 0)]
        return total

    # ----------------------------------------------------------- lifecycle

    def close(self) -> None:
        """Join any refiner, release the bundle source, and mark the
        session closed (a manager's keep-rotation gc treats the pinned
        step as collectable again)."""
        t = self._refiner
        if t is not None and t.is_alive():
            t.join()
        with self._lock:
            if self.closed:
                return
            self.closed = True
        self.bundle.close()

    def __enter__(self) -> "RestoreSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
