"""Progressive checkpointing — the paper's technique as a first-class
training-infrastructure feature.

Every parameter leaf is an IPComp archive (error-bounded, bitplane-
progressive).  Restart paths:

  * ``restore_checkpoint``       — full precision (error <= eb everywhere).
  * ``progressive_restore``      — coarse-first: load only the bitplanes
    needed for a requested weight error bound, start stepping immediately,
    refine in the background (Algorithm 2) touching ONLY the missing planes.
    At 1000-node scale this turns a cold restart's all-hosts-read-everything
    storm into a small fraction of the bytes (measured in the benchmarks).

Layout (object-store friendly):
  <dir>/step_<N>/manifest.json       leaf index, shapes, dtypes, eb, hashes
  <dir>/step_<N>/<leaf_id>.ipc       one IPComp archive per leaf
  <dir>/LATEST                       atomic pointer (rename)

Checkpoints are sharding-agnostic: leaves are saved as logical (gathered)
arrays and re-sharded on restore against whatever mesh the restart uses —
elastic scaling after node failure.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..api import Archive, Codec, Fidelity


def _leaf_id(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return ".".join(parts).replace("/", "_")


def _as_f32(x: np.ndarray) -> np.ndarray:
    return np.asarray(jax.device_get(x)).astype(np.float32)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    rel_eb: float = 1e-6, interp: str = "cubic",
                    lossless_small: int = 4096) -> Dict:
    """Write ``tree`` (params or full TrainState) at ``step``.

    Leaves smaller than ``lossless_small`` elements (norms, biases, scalars)
    are stored raw — compression metadata would dominate.
    """
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".step_{step}_")
    leaves = {}
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    total_raw = total_comp = 0
    for path, leaf in flat:
        lid = _leaf_id(path)
        arr = _as_f32(leaf)
        raw = arr.size * np.asarray(leaf).dtype.itemsize
        if arr.size <= lossless_small or arr.ndim == 0:
            blob = arr.tobytes()
            kind = "raw"
        else:
            a2 = arr.reshape(arr.shape[0], -1) if arr.ndim > 2 else arr
            blob = Codec(eb=rel_eb, interp=interp,
                         relative=True).compress(a2).tobytes()
            kind = "ipc"
        with open(os.path.join(tmp, lid + ".ipc"), "wb") as f:
            f.write(blob)
        leaves[lid] = dict(
            kind=kind, shape=list(np.asarray(leaf).shape),
            dtype=str(np.asarray(leaf).dtype),
            comp_shape=list(a2.shape) if kind == "ipc" else None,
            nbytes=len(blob),
            sha=hashlib.sha256(blob).hexdigest()[:16])
        total_raw += raw
        total_comp += len(blob)
    manifest = dict(step=step, rel_eb=rel_eb, interp=interp, leaves=leaves,
                    total_raw=total_raw, total_comp=total_comp,
                    treedef=str(treedef))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                        # atomic publish
    with open(os.path.join(ckpt_dir, ".LATEST_tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, ".LATEST_tmp"),
               os.path.join(ckpt_dir, "LATEST"))  # atomic pointer flip
    return manifest


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    return int(open(p).read().strip())


def _load_leaf(d: str, lid: str, meta: dict,
               error_bound: Optional[float] = None) -> np.ndarray:
    """Full-precision leaf load (progressive loads go through the per-leaf
    sessions in :func:`progressive_restore`)."""
    path = os.path.join(d, lid + ".ipc")
    if meta["kind"] == "raw":
        blob = open(path, "rb").read()
        arr = np.frombuffer(blob, np.float32).reshape(meta["shape"])
        return arr.astype(np.dtype(meta["dtype"]))
    sess = Archive.load(path).open()
    out = sess.read(None if error_bound is None
                    else Fidelity.error_bound(error_bound))
    return out.reshape(meta["shape"]).astype(np.dtype(meta["dtype"]))


def restore_checkpoint(ckpt_dir: str, step: int, like: Any) -> Any:
    """Full-precision restore into the structure of ``like`` (re-sharding
    against whatever mesh ``like``'s shardings carry)."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in flat:
        lid = _leaf_id(path)
        arr = _load_leaf(d, lid, manifest["leaves"][lid], None)
        out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out)


@dataclass
class ProgressiveRestore:
    """Carries per-leaf ProgressiveReader sessions between refinement
    rounds."""
    dir: str
    step: int
    manifest: dict
    states: Dict[str, Any]
    bytes_read: int = 0


def progressive_restore(ckpt_dir: str, step: int, like: Any, *,
                        weight_error: float,
                        session: Optional[ProgressiveRestore] = None
                        ) -> Tuple[Any, ProgressiveRestore]:
    """Coarse-first restore: load only the bitplanes needed for
    ``weight_error`` (relative to each leaf's range).  Call again with the
    returned session and a smaller bound to refine incrementally — only the
    missing planes are read (Algorithm 2 at checkpoint scale)."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    if session is None:
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        session = ProgressiveRestore(dir=d, step=step, manifest=manifest,
                                     states={})
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in flat:
        lid = _leaf_id(path)
        meta = session.manifest["leaves"][lid]
        if meta["kind"] == "ipc":
            sess = session.states.get(lid)
            if sess is None:
                sess = Archive.load(os.path.join(d, lid + ".ipc")).open()
                session.states[lid] = sess
            # absolute bound per leaf: weight_error is relative to range
            # (eb stored absolute; manifest rel_eb relates it to the range)
            eb = sess.archive.eb
            bound = max(weight_error * eb / session.manifest["rel_eb"], eb)
            arr = sess.read(Fidelity.error_bound(bound)) \
                .reshape(meta["shape"]).astype(np.dtype(meta["dtype"]))
        else:
            arr = _load_leaf(d, lid, meta, None)
        out.append(jax.numpy.asarray(arr))
    session.bytes_read = sum(
        st.bytes_read for st in session.states.values())
    return treedef.unflatten(out), session


class CheckpointManager:
    """keep_n rotation + restart helper for the training driver."""

    def __init__(self, ckpt_dir: str, keep_n: int = 3, rel_eb: float = 1e-6):
        self.dir = ckpt_dir
        self.keep_n = keep_n
        self.rel_eb = rel_eb
        os.makedirs(ckpt_dir, exist_ok=True)

    def save(self, step: int, tree: Any) -> Dict:
        man = save_checkpoint(self.dir, step, tree, rel_eb=self.rel_eb)
        self._gc()
        return man

    def _gc(self):
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(self.dir)
                       if n.startswith("step_"))
        for s in steps[: -self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def restore_latest(self, like: Any) -> Tuple[Optional[int], Any]:
        step = latest_step(self.dir)
        if step is None:
            return None, like
        return step, restore_checkpoint(self.dir, step, like)
