"""Progressive checkpointing — the paper's technique as a first-class
training-infrastructure feature.

A checkpoint step is ONE bundle file (``checkpoint.bundle``): a
manifest-indexed directory of per-leaf IPC3 plane-major archives, so a
coarse restore reads one contiguous range per leaf prefix and a refine
extends each range monotonically.  Restart paths:

  * ``restore_checkpoint``  — full precision (error <= eb everywhere),
    every leaf blob sha-verified on read.
  * ``progressive_restore`` / ``CheckpointManager.restore_progressive``
    — coarse-first through a ``checkpoint.restore.RestoreSession``:
    load only the bitplanes needed for a requested weight error, start
    stepping immediately, refine in the background touching ONLY the
    missing planes.  At 1000-node scale this turns a cold restart's
    all-hosts-read-everything storm into a small fraction of the bytes
    (gated in ``benchmarks/ckpt_bench.py``).

Layout (object-store friendly)::

  <dir>/step_<N>.ckpt     one IPCB bundle per step (atomic os.replace)
  <dir>/LATEST            atomic pointer (rename)
  <dir>/.step_<N>_*       in-flight save scratch (shards + merge buffer);
                          ignored by readers, reaped by the manager's gc

Saves are parallel partitioned encodes (``workers`` encoder threads,
deterministic output — see ``bundle.write_bundle``).  Checkpoints are
sharding-agnostic: leaves are saved as logical (gathered) arrays and
re-sharded on restore against whatever mesh the restart uses — elastic
scaling after node failure.  Remote restore: pass an ``http(s)://``
URL to ``Bundle.open`` / ``RestoreSession`` and the same session code
path runs over HTTP range requests with the remote layer's
retry/degradation semantics.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import weakref
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from . import bundle as bundle_mod
from .bundle import Bundle, LeafSpec
from .restore import RestoreSession, read_full


def _leaf_id(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return ".".join(parts).replace("/", "_")


def _as_f32(x: np.ndarray) -> np.ndarray:
    return np.asarray(jax.device_get(x)).astype(np.float32)


def step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step}.ckpt")


def _tree_unflattener(like: Any):
    """(leaf ids in flatten order, dict->tree unflatten hook) for ``like``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    lids = [_leaf_id(p) for p, _ in flat]

    def unflatten(arrays: Dict[str, np.ndarray]):
        return treedef.unflatten([jax.numpy.asarray(arrays[l])
                                  for l in lids])
    return lids, unflatten


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    rel_eb: float = 1e-6, interp: str = "cubic",
                    lossless_small: int = 4096, workers: int = 1,
                    chunk_elems: Optional[int] = None) -> Dict:
    """Write ``tree`` (params or full TrainState) at ``step`` as one
    bundle file, via ``workers`` parallel encoder shards merged
    atomically (output bytes are worker-count independent).

    Leaves smaller than ``lossless_small`` elements (norms, biases,
    scalars) are stored raw — compression metadata would dominate.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs: List[LeafSpec] = []
    for path, leaf in flat:
        nd = np.asarray(jax.device_get(leaf))
        specs.append(LeafSpec(lid=_leaf_id(path), arr=nd.astype(np.float32),
                              dtype=str(nd.dtype),
                              raw_nbytes=nd.size * nd.dtype.itemsize))
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".step_{step}_")
    try:
        manifest = bundle_mod.write_bundle(
            step_path(ckpt_dir, step), specs, step=step, rel_eb=rel_eb,
            interp=interp, treedef=str(treedef),
            lossless_small=lossless_small, workers=workers,
            chunk_elems=chunk_elems, shard_dir=tmp)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    with open(os.path.join(ckpt_dir, ".LATEST_tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, ".LATEST_tmp"),
               os.path.join(ckpt_dir, "LATEST"))  # atomic pointer flip
    return manifest


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    return int(open(p).read().strip())


def restore_checkpoint(ckpt_dir: str, step: int, like: Any) -> Any:
    """Full-precision restore into the structure of ``like`` (re-sharding
    against whatever mesh ``like``'s shardings carry).  Every leaf blob
    is sha256-verified against the manifest."""
    _, unflatten = _tree_unflattener(like)
    with Bundle.open(step_path(ckpt_dir, step)) as b:
        return unflatten(read_full(b, verify=True))


def progressive_restore(ckpt_dir: str, step: int, like: Any, *,
                        weight_error: Optional[float],
                        session: Optional[RestoreSession] = None
                        ) -> Tuple[Any, RestoreSession]:
    """Coarse-first restore: load only the bitplanes needed for
    ``weight_error`` (relative to each leaf's range).  Call again with
    the returned session and a smaller bound to refine incrementally —
    only the missing planes are read (Algorithm 2 at checkpoint scale).
    The session caches the parsed manifest and the raw (lossless)
    leaves; raw leaves report exact-zero error in
    ``session.leaf_bounds``."""
    if session is None:
        _, unflatten = _tree_unflattener(like)
        session = RestoreSession(Bundle.open(step_path(ckpt_dir, step)),
                                 unflatten=unflatten)
    return session.restore(weight_error), session


class CheckpointManager:
    """keep_n rotation + restart helper for the training driver.

    Tracks live :class:`RestoreSession`\\ s it handed out: the rotation
    gc never deletes a step an unclosed session is reading, so an
    in-flight progressive restore either completes from its open source
    or — if the bundle was removed out-of-band — fails loudly, never
    returns wrong bytes.  Leftover ``.step_*`` scratch dirs from
    crashed saves are ignored by every reader and reaped here.
    """

    def __init__(self, ckpt_dir: str, keep_n: int = 3, rel_eb: float = 1e-6,
                 workers: int = 1):
        self.dir = ckpt_dir
        self.keep_n = keep_n
        self.rel_eb = rel_eb
        self.workers = workers
        os.makedirs(ckpt_dir, exist_ok=True)
        self._live: List[Tuple[int, "weakref.ref[RestoreSession]"]] = []

    def save(self, step: int, tree: Any) -> Dict:
        man = save_checkpoint(self.dir, step, tree, rel_eb=self.rel_eb,
                              workers=self.workers)
        self._gc()
        return man

    # ------------------------------------------------------------ rotation

    def _pinned_steps(self) -> set:
        alive, keep = set(), []
        for s, ref in self._live:
            sess = ref()
            if sess is not None and not sess.closed:
                alive.add(s)
                keep.append((s, ref))
        self._live = keep
        return alive

    @staticmethod
    def _parse_step_name(name: str) -> Optional[int]:
        if name.startswith("step_"):
            stem = name[5:-5] if name.endswith(".ckpt") else name[5:]
            try:
                return int(stem)
            except ValueError:
                return None
        return None

    def _gc(self):
        pinned = self._pinned_steps()
        found: List[Tuple[int, str]] = []
        for n in os.listdir(self.dir):
            p = os.path.join(self.dir, n)
            if n.startswith(".step_"):
                # crashed-save scratch: never referenced by LATEST or any
                # manifest — reap it (our own save's scratch is already
                # gone by the time save() calls _gc)
                if os.path.isdir(p):
                    shutil.rmtree(p, ignore_errors=True)
                else:
                    try:
                        os.unlink(p)
                    except OSError:
                        pass
                continue
            s = self._parse_step_name(n)
            if s is not None:
                found.append((s, p))
        found.sort()
        for s, p in found[: -self.keep_n] if self.keep_n else found:
            if s in pinned:
                continue   # an unclosed RestoreSession is reading this step
            if os.path.isdir(p):
                shutil.rmtree(p, ignore_errors=True)
            else:
                try:
                    os.unlink(p)
                except OSError:
                    pass

    # ------------------------------------------------------------- restore

    def restore_latest(self, like: Any) -> Tuple[Optional[int], Any]:
        step = latest_step(self.dir)
        if step is None:
            return None, like
        return step, restore_checkpoint(self.dir, step, like)

    def restore_progressive(self, like: Any, *, weight_error: float,
                            refine_to: Any = None,
                            step: Optional[int] = None,
                            exact=None
                            ) -> Tuple[Optional[int], Any,
                                       Optional[RestoreSession]]:
        """Coarse-first restart: restore the latest (or given) step at
        ``weight_error`` and return ``(step, tree, session)`` — the
        caller starts stepping on ``tree`` immediately.

        ``refine_to`` starts the session's background refiner:
        ``"full"`` streams every remaining plane, a float refines to
        that (tighter) weight error, ``None`` leaves refinement to the
        caller.  Poll ``session.poll_refined()`` for the refined tree
        and ``session.close()`` when done (closing releases the step
        for keep-rotation gc).  With no checkpoint present, returns
        ``(None, like, None)``.

        ``exact`` (optional ``lid -> bool``) marks precision-critical
        leaves that must restore at full precision even in the coarse
        round — e.g. optimizer second moments, where a range-relative
        bound flips near-zero entries negative.
        """
        step = latest_step(self.dir) if step is None else step
        if step is None:
            return None, like, None
        path = step_path(self.dir, step)
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"checkpoint step {step} not found at {path} — was it "
                "rotated out by keep_n gc? (LATEST may be stale)")
        _, unflatten = _tree_unflattener(like)
        session = RestoreSession(Bundle.open(path), unflatten=unflatten,
                                 exact=exact)
        self._live.append((step, weakref.ref(session)))
        tree = session.restore(weight_error)
        if refine_to is not None:
            session.refine_async(
                None if refine_to == "full" else float(refine_to))
        return step, tree, session
