"""Serving-tier behaviour: the continuous-batching RetrievalServer.

Pins the tentpole contracts: served reconstructions are bit-identical
to private uncached sessions at the same fidelity, cross-request
coalescing strictly reduces dispatch counts, the shared plane cache sees
real reuse with byte accounting, refine chains ride earlier requests'
progressive state, and a planner rejection fails only its own request.
"""
import numpy as np
import pytest

from _fields import smooth_field
from repro import Codec, ExecPolicy, Fidelity
from repro.serving import (DONE, FAILED, PlaneCache, RetrievalServer,
                           ServeRequest)

X = smooth_field((48, 40), seed=2)
Y = smooth_field((32, 32), seed=4)
V2 = Codec(eb=1e-5, chunk_elems=512)
V1 = Codec(eb=1e-5)

MIX = (Fidelity.error_bound(1e-2), Fidelity.error_bound(1e-4),
       Fidelity.bitrate(4.0), Fidelity.full())


def _server(**kw):
    srv = RetrievalServer(**kw)
    srv.add_archive("x2", V2.compress(X))
    srv.add_archive("y2", V2.compress(Y))
    srv.add_archive("x1", V1.compress(X))
    return srv


def _mixed_wave(srv):
    return [srv.submit(aid, fid)
            for aid in ("x2", "y2", "x1") for fid in MIX]


@pytest.mark.parametrize("coalesce", [True, False], ids=["coal", "percall"])
@pytest.mark.parametrize("cached", [True, False], ids=["cache", "nocache"])
def test_served_bits_match_private_sessions(coalesce, cached):
    """Every (coalesce, cache) corner serves the exact bits a private
    uncached session produces, with the same achieved bound."""
    srv = _server(cache=PlaneCache() if cached else None, coalesce=coalesce)
    reqs = _mixed_wave(srv)
    srv.drain()
    for req in reqs:
        assert req.status == DONE, req.error
        session = srv._archives[req.archive_id].open()
        ref = session.read(req.fidelity)
        assert np.array_equal(req.result, ref)
        assert req.err_bound == session.achieved_bound
        assert req.bytes_read <= session.bytes_read


def test_coalescing_reduces_dispatches():
    """Same workload, jax backend (batched decode slots): coalesced
    groups run strictly fewer backend primitives than per-request
    groups."""
    policy = ExecPolicy(backend="jax")
    counts = {}
    for coalesce in (False, True):
        srv = _server(policy=policy, coalesce=coalesce)
        _mixed_wave(srv)
        srv.drain()
        counts[coalesce] = sum(v for k, v in srv.counters.items()
                               if k != "dedup_reuse")
    assert counts[True] < counts[False]


def test_cache_reuse_across_requests():
    cache = PlaneCache()
    srv = _server(cache=cache)
    reqs = _mixed_wave(srv)
    # a second identical wave: every prefix is already decoded
    hits_before = cache.hits
    again = _mixed_wave(srv)
    srv.drain()
    assert cache.hits > hits_before
    assert cache.hit_bytes > 0 and cache.bytes_cached > 0
    assert cache.fetch_bytes_saved > 0
    for first, second in zip(reqs, again):
        assert np.array_equal(first.result, second.result)
        # the repeat request fetched fewer bytes than the first
        assert second.bytes_read <= first.bytes_read


def test_refine_chain_rides_parent_state():
    """A refine_of child reuses the parent's progressive state: bits
    equal a private session walking the same ladder, and the chain's
    total bytes stay below two cold reads."""
    srv = _server(cache=PlaneCache())
    parent = srv.submit("x2", Fidelity.error_bound(1e-2))
    child = srv.submit("x2", Fidelity.full(), refine_of=parent)
    srv.drain()
    assert parent.status == DONE and child.status == DONE
    session = srv._archives["x2"].open()
    session.read(Fidelity.error_bound(1e-2))
    ref = session.read(Fidelity.full())
    assert np.array_equal(child.result, ref)
    assert child.bytes_read <= session.bytes_read


def test_planner_rejection_isolated_to_request():
    """An infeasible byte budget (below the escape-channel floor) fails
    its own request with the planner's message; the rest of the tick
    completes."""
    x = X.copy()
    x[13, 17] = 1e15          # escape outlier -> nonzero plan floor
    srv = RetrievalServer()
    srv.add_archive("esc", Codec(eb=1e-7).compress(x))
    bad = srv.submit("esc", Fidelity.max_bytes(1))
    good = srv.submit("esc", Fidelity.error_bound(1e-2))
    srv.drain()
    assert bad.status == FAILED and "infeasible" in bad.error
    assert good.status == DONE
    assert srv.stats()["failed"] == 1 and srv.stats()["done"] == 1


def test_failed_parent_fails_child():
    x = X.copy()
    x[13, 17] = 1e15
    srv = RetrievalServer()
    srv.add_archive("esc", Codec(eb=1e-7).compress(x))
    parent = srv.submit("esc", Fidelity.max_bytes(1))
    child = srv.submit("esc", Fidelity.full(), refine_of=parent)
    settled = srv.drain()
    assert parent.status == FAILED
    assert child.status == FAILED and "parent" in child.error
    # both settle THROUGH the tick contract: drain reports each exactly
    # once (parent-failure children used to vanish from the settled list)
    assert sorted(r.req_id for r in settled) == [parent.req_id,
                                                 child.req_id]
    assert child.latency_s > 0
    assert srv.stats()["failed"] == 2


@pytest.mark.parametrize("aid", ["x2", "x1"], ids=["v2", "v1"])
@pytest.mark.parametrize("order", ["tight-first", "loose-first"])
def test_sibling_refines_are_private_sessions(aid, order):
    """Two refine_of children of one parent, runnable in the same tick,
    each serve exactly their own fidelity's bits.  (Siblings used to
    alias the parent's mutable state/reader: the later job computed its
    delta against the earlier sibling's planes, so a Fidelity.full()
    sibling could silently regress and all siblings returned identical
    bits.)"""
    fids = (Fidelity.full(), Fidelity.error_bound(1e-4))
    if order == "loose-first":
        fids = fids[::-1]
    srv = _server()
    parent = srv.submit(aid, Fidelity.error_bound(1e-2))
    kids = [srv.submit(aid, f, refine_of=parent) for f in fids]
    srv.drain()
    assert parent.status == DONE
    for child in kids:
        assert child.status == DONE, child.error
        session = srv._archives[aid].open()
        session.read(Fidelity.error_bound(1e-2))
        ref = session.read(child.fidelity)
        assert np.array_equal(child.result, ref)
        assert child.err_bound == session.achieved_bound
    # private branches: no shared mutable state anywhere in the family
    assert kids[0]._state is not kids[1]._state
    assert all(k._state is not parent._state for k in kids)
    assert all(k._reader is not parent._reader for k in kids)
    # the parent's own result is untouched by its children's refinements
    session = srv._archives[aid].open()
    assert np.array_equal(parent.result,
                          session.read(Fidelity.error_bound(1e-2)))


def test_v1_requests_bind_unsharded():
    """An explicit mesh policy: v2 requests run sharded over the chunk
    grid, a v1 request fails with the same error a session raises (v1
    has no chunks to place on the mesh) — server dispatch semantics never
    diverge from the session path, and the failure is isolated."""
    from repro.parallel import codec_mesh
    policy = ExecPolicy(backend="jax", shard=codec_mesh.codec_mesh())
    srv = _server(policy=policy)
    ok = srv.submit("x2", Fidelity.error_bound(1e-3))
    bad = srv.submit("x1", Fidelity.error_bound(1e-3))
    settled = srv.drain()
    assert ok.status == DONE, ok.error
    assert bad.status == FAILED and "chunk" in bad.error
    assert {r.req_id for r in settled} == {ok.req_id, bad.req_id}
    session = srv._archives["x2"].open()
    assert np.array_equal(ok.result, session.read(Fidelity.error_bound(1e-3)))


def test_registry_guards():
    srv = _server()
    with pytest.raises(KeyError):
        srv.submit("nope", Fidelity.full())
    # idempotent re-registration of equal bytes is fine
    srv.add_archive("x2", V2.compress(X))
    # rebinding an id to different bytes would poison cache scopes
    with pytest.raises(ValueError, match="different"):
        srv.add_archive("x2", V2.compress(Y))
    a = srv.submit("x2", Fidelity.full())
    with pytest.raises(ValueError, match="refine_of"):
        srv.submit("y2", Fidelity.full(), refine_of=a)


def test_request_lifecycle_and_stats():
    srv = _server(cache=PlaneCache())
    reqs = _mixed_wave(srv)
    assert srv.pending == len(reqs)
    settled = srv.drain()
    assert srv.pending == 0
    assert {r.req_id for r in settled} == {r.req_id for r in reqs}
    s = srv.stats()
    assert s["done"] == len(reqs) and s["failed"] == 0
    assert s["ticks"] >= 1
    assert s["counters"]["decode_level"] > 0
    assert s["cache"]["hits"] > 0
    for r in reqs:
        assert r.latency_s > 0
        assert isinstance(r, ServeRequest)


def test_duplicate_fidelity_requests_share_work():
    """N identical requests in one tick: with coalescing + jax batching
    the same-prefix decodes deduplicate (one leader decode, N-1
    reuses)."""
    srv = _server(policy=ExecPolicy(backend="jax"), coalesce=True)
    reqs = [srv.submit("x2", Fidelity.error_bound(1e-3))
            for _ in range(3)]
    srv.drain()
    assert srv.counters.get("dedup_reuse", 0) > 0
    assert all(np.array_equal(reqs[0].result, r.result) for r in reqs[1:])


def test_drain_guard_on_stuck_dependencies():
    srv = _server()
    phantom = ServeRequest(req_id=10 ** 6, archive_id="x2",
                           fidelity=Fidelity.full())   # never scheduled
    srv.submit("x2", Fidelity.full(), refine_of=phantom)
    with pytest.raises(RuntimeError, match="stalled"):
        srv.drain()
