"""Pallas kernel f64 sweep (x64 enabled per-test via context manager —
flipping the global flag would poison dtype expectations of the rest of
the suite running in the same process)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.interp_quant import interp_quant, interp_quant_ref
from repro.kernels.interp_recon import interp_recon, interp_recon_ref


@pytest.mark.parametrize("shape,s", [((8, 128), 1), ((16, 256), 4),
                                     ((8, 130), 1)])
@pytest.mark.parametrize("interp", ["linear", "cubic"])
def test_interp_quant_f64(shape, s, interp):
    with jax.experimental.enable_x64():
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal(shape), jnp.float64)
        xh = jnp.asarray(rng.standard_normal(shape), jnp.float64)
        q, pred = interp_quant(x, xh, s=s, eb=1e-6, interp=interp)
        q_ref, pred_ref = interp_quant_ref(x, xh, s, 1e-6, interp)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
        np.testing.assert_allclose(np.asarray(pred), np.asarray(pred_ref),
                                   rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("shape,s", [((8, 128), 1), ((16, 256), 4),
                                     ((8, 130), 1)])
@pytest.mark.parametrize("interp", ["linear", "cubic"])
def test_interp_recon_f64(shape, s, interp):
    with jax.experimental.enable_x64():
        rng = np.random.default_rng(2)
        R, C = shape
        T = len(range(s, C, 2 * s))
        xh = jnp.asarray(rng.standard_normal(shape), jnp.float64)
        res = jnp.asarray(rng.standard_normal((R, T)), jnp.float64)
        out = interp_recon(xh, res, s=s, interp=interp)
        ref = interp_recon_ref(xh, res, s, interp)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
