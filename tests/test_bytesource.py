"""The pluggable byte-range I/O layer under the container readers.

BufferSource zero-copy semantics, FileSource mmap-backed file access,
CountingSource range accounting (the v3 monotone-contiguity test double),
and window forwarding at absolute offsets.
"""
import numpy as np
import pytest

from _fields import smooth_field
from repro.core.bytesource import (BufferSource, ByteSource, CountingSource,
                                   FileSource, as_source)

PAYLOAD = bytes(range(256)) * 8


# --------------------------------------------------------------- coercion

def test_as_source_wraps_bytes_and_passes_sources_through():
    src = as_source(PAYLOAD)
    assert isinstance(src, BufferSource)
    assert as_source(src) is src                      # no double wrapping
    cs = CountingSource(PAYLOAD)
    assert as_source(cs) is cs


def test_buffer_source_reads_and_size():
    src = BufferSource(PAYLOAD)
    assert src.size == len(PAYLOAD)
    assert bytes(src.read(0, 4)) == PAYLOAD[:4]
    assert bytes(src.read(100, 50)) == PAYLOAD[100:150]
    assert bytes(src.read(0, src.size)) == PAYLOAD
    assert src.tobytes() == PAYLOAD


def test_buffer_source_is_zero_copy():
    src = BufferSource(PAYLOAD)
    view = src.read(10, 6)
    assert isinstance(view, memoryview)
    assert bytes(view) == PAYLOAD[10:16]


# ------------------------------------------------------------ file source

def test_file_source_reads_ranges(tmp_path):
    p = tmp_path / "payload.bin"
    p.write_bytes(PAYLOAD)
    src = FileSource(p)                               # pathlib.Path accepted
    assert src.size == len(PAYLOAD)
    assert bytes(src.read(7, 13)) == PAYLOAD[7:20]
    assert bytes(src.read(0, src.size)) == PAYLOAD
    src.close()
    src.close()                                       # idempotent


def test_file_source_empty_file(tmp_path):
    p = tmp_path / "empty.bin"
    p.write_bytes(b"")
    src = FileSource(str(p))                          # str path accepted
    assert src.size == 0
    assert bytes(src.read(0, 0)) == b""
    src.close()


# -------------------------------------------------------- range accounting

def test_counting_source_logs_in_order():
    cs = CountingSource(PAYLOAD)
    assert bytes(cs.read(0, 4)) == PAYLOAD[:4]
    cs.read(4, 8)
    cs.read(100, 10)
    assert cs.requests == [(0, 4), (4, 8), (100, 10)]
    assert cs.n_requests == 3
    assert cs.bytes_requested == 22
    assert cs.size == len(PAYLOAD)


def test_counting_source_ignores_zero_byte_reads():
    """Empty planes / empty escape blobs hit no storage and must not
    distort the range metrics."""
    cs = CountingSource(PAYLOAD)
    cs.read(0, 4)
    cs.read(50, 0)
    cs.read(4, 4)
    assert cs.requests == [(0, 4), (4, 4)]
    assert len(cs.coalesced()) == 1                   # still one run


def test_coalesced_merges_adjacent_in_order():
    cs = CountingSource(PAYLOAD)
    for off, size in [(0, 10), (10, 5), (15, 5), (40, 8), (48, 2), (0, 4)]:
        cs.read(off, size)
    assert cs.coalesced() == [(0, 20), (40, 10), (0, 4)]


def test_monotone_and_seek_distance():
    cs = CountingSource(PAYLOAD)
    cs.read(0, 10)
    cs.read(10, 10)
    cs.read(30, 5)                                    # forward gap: ok
    assert cs.monotone()
    assert cs.seek_distance == 10                     # the 20 -> 30 gap
    cs.read(5, 3)                                     # backward seek
    assert not cs.monotone()
    cs.reset()
    assert cs.requests == [] and cs.monotone() and cs.seek_distance == 0


def test_counting_wraps_any_source(tmp_path):
    p = tmp_path / "x.bin"
    p.write_bytes(PAYLOAD)
    inner = FileSource(p)
    cs = CountingSource(inner)
    assert bytes(cs.read(3, 5)) == PAYLOAD[3:8]
    assert cs.requests == [(3, 5)]
    cs.close()                                        # forwards to inner


def test_range_log_is_thread_safe():
    """Concurrent readers (the serving tier's shared-archive case) must
    not lose or tear log appends: list.append is atomic under CPython,
    but the metric snapshots iterate the list while writers append — the
    log takes a lock so both sides see a consistent sequence."""
    import threading

    cs = CountingSource(PAYLOAD)
    N_THREADS, N_READS = 8, 400
    errors = []

    def reader(tid):
        try:
            for i in range(N_READS):
                off = (tid * N_READS + i) % (len(PAYLOAD) - 8)
                assert bytes(cs.read(off, 8)) == PAYLOAD[off:off + 8]
                # exercise the snapshotting metrics concurrently with
                # the appends — this is what used to race
                cs.coalesced()
                cs.monotone()
                assert cs.bytes_requested >= 8
        except Exception as e:                        # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(t,))
               for t in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    assert cs.n_requests == N_THREADS * N_READS       # no lost appends
    assert cs.bytes_requested == N_THREADS * N_READS * 8


# ---------------------------------------------------------------- windows

def test_window_forwards_absolute_offsets():
    """A chunk sub-reader windowed into a container must surface its
    requests at real container positions — that is what makes range
    accounting comparable across container versions."""
    cs = CountingSource(PAYLOAD)
    win = cs.window(100, 40)
    assert win.size == 40
    assert bytes(win.read(0, 10)) == PAYLOAD[100:110]
    assert bytes(win.read(30, 10)) == PAYLOAD[130:140]
    assert cs.requests == [(100, 10), (130, 10)]


def test_byte_source_base_is_abstract():
    src = ByteSource()
    with pytest.raises(NotImplementedError):
        src.read(0, 1)
    with pytest.raises(NotImplementedError):
        src.size


# ------------------------------------------- readers ride on byte sources

def test_archive_reader_accepts_sources():
    """Every container parser/reader entry accepts a ByteSource in place
    of bytes, with identical results."""
    from repro.api import Codec
    from repro.core import container

    x = smooth_field((24, 18), seed=3)
    buf = Codec(eb=1e-4).compress(x).tobytes()
    m_bytes = container.parse_meta(buf)
    m_src = container.parse_meta(BufferSource(buf))
    assert m_bytes.levels[0].plane_offsets == m_src.levels[0].plane_offsets
    r = container.open_reader(CountingSource(buf))
    assert r.anchors().shape == tuple(m_bytes.anchors_shape)
