"""Cross-version container parity: v1 / v2 / v3 archives of one array.

Pins the compatibility contract of docs/format.md: every version stays
readable forever, full-precision reconstructions are bit-identical across
versions, error bounds hold at every ladder rung on every version, and
the progressive accounting invariants (refine-never-rereads, bytes_read
consistency) are version-independent.
"""
import numpy as np
import pytest

from _fields import smooth_field
from repro.api import Archive, Codec, Fidelity
from repro.core.bytesource import CountingSource

X = smooth_field((56, 36), seed=11)
EB = 1e-5
LADDER = [1e-1, 1e-2, 1e-3, 1e-4, 1e-5]

CODECS = {
    "v1": Codec(eb=EB),
    "v2": Codec(eb=EB, chunk_elems=600),
    "v3": Codec(eb=EB, chunk_elems=600, version=3),
}
ARCHIVES = {name: c.compress(X) for name, c in CODECS.items()}


@pytest.mark.parametrize("name", list(ARCHIVES))
def test_version_tag_and_reread(name):
    a = ARCHIVES[name]
    assert a.version == int(name[1])
    # byte round trip through frombytes preserves everything
    b = Archive.frombytes(a.tobytes())
    assert b == a and b.version == a.version


def test_full_reads_bit_identical_where_layout_allows():
    """v2 and v3 hold the same per-chunk streams in different layouts, so
    their full reconstructions are bit-identical.  v1's predictor runs on
    the unchunked array — a different codec path — so it only shares the
    error bound, not the bits."""
    outs = {name: a.open().read() for name, a in ARCHIVES.items()}
    assert np.array_equal(outs["v2"], outs["v3"])
    for name, out in outs.items():
        assert np.abs(out - X).max() <= EB, name


@pytest.mark.parametrize("name", list(ARCHIVES))
def test_error_bounds_hold_at_every_rung(name):
    s = ARCHIVES[name].open()
    for E in LADDER:
        out = s.read(Fidelity.error_bound(E))
        assert np.abs(out - X).max() <= E, (name, E)
        assert s.achieved_bound <= E


@pytest.mark.parametrize("name", list(ARCHIVES))
def test_refine_never_rereads(name):
    """Tightening the target only adds bytes; repeating or loosening a
    target reads nothing — on every container version."""
    a = ARCHIVES[name]
    cs = CountingSource(a.tobytes())
    s = Archive.from_source(cs).open()
    prev_bytes = -1
    for E in LADDER:
        s.read(Fidelity.error_bound(E))
        assert s.bytes_read >= prev_bytes
        prev_bytes = s.bytes_read
        n_req = cs.n_requests
        s.read(Fidelity.error_bound(E))           # repeat: nothing fetched
        assert cs.n_requests == n_req
        assert s.bytes_read == prev_bytes
    s.read(Fidelity.error_bound(LADDER[0]))       # loosen: nothing fetched
    assert s.bytes_read == prev_bytes


@pytest.mark.parametrize("name", list(ARCHIVES))
def test_bytes_read_consistent_with_requests(name):
    """``bytes_read`` (tag-deduped blob accounting) never exceeds what the
    source actually served, and a full read's accounting is the same
    whether reached directly or via the ladder."""
    a = ARCHIVES[name]
    cs = CountingSource(a.tobytes())
    s = Archive.from_source(cs).open()
    for E in LADDER:
        s.read(Fidelity.error_bound(E))
    ladder_bytes = s.bytes_read
    s.read(Fidelity.full())
    direct = a.open()
    direct.read(Fidelity.full())
    assert s.bytes_read == direct.bytes_read
    assert ladder_bytes <= s.bytes_read


@pytest.mark.parametrize("name", list(ARCHIVES))
def test_file_round_trip(name, tmp_path):
    """save/load via pathlib.Path on every version; loaded archives are
    file-backed (no full read) yet reconstruct identically."""
    a = ARCHIVES[name]
    p = tmp_path / f"{name}.ipc"
    a.save(p)
    assert p.stat().st_size == a.nbytes
    b = Archive.load(p)
    assert type(b._src).__name__ == "FileSource"
    assert b == a and hash(b) == hash(a)
    assert np.array_equal(b.open().read(), a.open().read())


def test_v3_monotone_contiguous_v2_is_not():
    """The layout claim as a *differential* assertion: the same ladder
    that scatters reads on v2 streams on v3."""
    ladder = [Fidelity.error_bound(E) for E in LADDER]

    def data_runs(a):
        cs = CountingSource(a.tobytes())
        s = Archive.from_source(cs).open()
        for f in ladder:
            s.read(f)
        he = a._meta.header_end
        runs = CountingSource(b"")
        runs.requests = [r for r in cs.requests if r[0] >= he]
        return runs

    r2, r3 = data_runs(ARCHIVES["v2"]), data_runs(ARCHIVES["v3"])
    assert r3.monotone()
    assert len(r3.coalesced()) == 1
    assert len(r3.coalesced()) < len(r2.coalesced())
    assert r3.seek_distance < r2.seek_distance
