"""Pin ``compression/grad.py``'s plane-drop semantics against the core
negabinary/bitplane truncation (``core/negabinary.py``).

The gradient path truncates with an arithmetic shift ``(q >> s) << s``;
the checkpoint/codec path zeroes ``s`` low negabinary digits.  These
coincide bit-exactly for s in {0, 1} and deliberately diverge deeper
(both stay within 2^s of the input — same error class, different
codewords); this suite pins the exact relationship so a change on
either side trips loudly."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression.grad import _quantize_leaf, _trunc_occupied
from repro.core.negabinary import from_negabinary, to_negabinary, truncate


def np_trunc_occupied(q: np.ndarray, keep_bits: int):
    """Bit-exact numpy reference of ``grad._trunc_occupied`` (f32 width
    computation, arithmetic shift on negatives)."""
    maxq = np.float32(np.max(np.abs(q)))
    nbits = int(np.ceil(np.log2(maxq + np.float32(1.0)), dtype=np.float32))
    shift = max(nbits - keep_bits, 0)
    q64 = q.astype(np.int64)
    return (q64 >> shift) << shift, shift


def nb_trunc(q: np.ndarray, drop: int) -> np.ndarray:
    """The codec-side truncation: drop ``drop`` low negabinary digits."""
    return from_negabinary(truncate(to_negabinary(q.astype(np.int64)), drop))


def rand_q(seed, lo=-(2 ** 12), hi=2 ** 12, n=512):
    return np.random.default_rng(seed).integers(lo, hi, size=n,
                                                dtype=np.int64)


# ------------------------------------------------- reference == jax impl

@pytest.mark.parametrize("keep_bits", [1, 3, 6, 8, 12, 16, 31])
def test_numpy_reference_matches_jax_bit_exactly(keep_bits):
    for seed in range(3):
        q = rand_q(seed)
        jq, jshift = _trunc_occupied(jnp.asarray(q, jnp.int32), keep_bits)
        rq, rshift = np_trunc_occupied(q, keep_bits)
        assert int(jshift) == rshift
        np.testing.assert_array_equal(np.asarray(jq, np.int64), rq)


def test_arithmetic_shift_on_negatives_pinned():
    # jax int32 >> is arithmetic: -1 >> 1 << 1 == -2, not 0
    q = jnp.asarray([-1, -2, -3, -7], jnp.int32)
    out, shift = _trunc_occupied(q, 2)      # nbits=3 -> shift=1
    assert int(shift) == 1
    np.testing.assert_array_equal(np.asarray(out), [-2, -2, -4, -8])


# ------------------------------------------- parity with the core codec

def test_bit_exact_vs_negabinary_for_shift_0_and_1():
    """At shift 0 (identity) and shift 1 the arithmetic drop IS the
    negabinary digit drop: q mod 2 equals negabinary digit 0."""
    for seed in range(4):
        q = rand_q(seed, lo=-100, hi=100)   # nbits = 7
        for keep_bits, want_shift in ((7, 0), (6, 1), (32, 0)):
            got, shift = np_trunc_occupied(q, keep_bits)
            assert shift == want_shift
            np.testing.assert_array_equal(got, nb_trunc(q, shift))


def test_semantics_diverge_beyond_shift_1_pinned():
    """Deeper drops legitimately differ (different codeword grids);
    pin the known counterexamples so neither side drifts silently."""
    q = np.array([2, 6], np.int64)
    arith = (q >> 2) << 2
    nb = nb_trunc(q, 2)
    np.testing.assert_array_equal(arith, [0, 4])
    np.testing.assert_array_equal(nb, [4, 8])
    assert not np.array_equal(arith, nb)


@pytest.mark.parametrize("drop", [0, 1, 2, 3, 5, 7])
def test_both_paths_within_2_pow_drop(drop):
    """Shared error contract: dropping ``drop`` low planes moves any
    value by < 2^drop on BOTH paths (what makes the gradient path's
    keep_bits accounting compatible with the codec's plane ladder)."""
    for seed in range(3):
        q = rand_q(seed)
        assert np.max(np.abs(q - ((q >> drop) << drop))) < 2 ** drop \
            or drop == 0
        assert np.max(np.abs(q - nb_trunc(q, drop))) < max(2 ** drop, 1)


def test_identity_when_keep_covers_occupied_width():
    q = rand_q(0, lo=-(2 ** 9), hi=2 ** 9)  # nbits = 10
    out, shift = np_trunc_occupied(q, 10)
    assert shift == 0
    np.testing.assert_array_equal(out, q)
    jq, _ = _trunc_occupied(jnp.asarray(q, jnp.int32), 10)
    np.testing.assert_array_equal(np.asarray(jq, np.int64), q)


# ------------------------------------------------- quantizer invariants

def test_quantize_leaf_error_feedback_closes_the_loop():
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.standard_normal(256), jnp.float32)
    ef = jnp.zeros_like(g)
    q, scale, err = _quantize_leaf(g, ef, rel_eb=1e-3, keep_bits=8)
    recon = np.asarray(q, np.float32) * (2.0 * float(scale))
    # the returned feedback is exactly the reconstruction residue
    np.testing.assert_allclose(np.asarray(err), np.asarray(g) - recon,
                               rtol=0, atol=1e-6)
    # truncated q really dropped the low planes: re-truncating at the
    # same keep_bits is the identity (the low planes are already zero)
    q64 = np.asarray(q, np.int64)
    again, shift = np_trunc_occupied(q64, 8)
    assert shift > 0                        # something WAS dropped here
    np.testing.assert_array_equal(again, q64)
