"""Container parsing hardening: overlapping / out-of-order blob extents.

Out-of-bounds extents were already rejected; these are the sneakier
corruptions — extents that stay inside the buffer but alias or reorder
each other, which a naive reader would decode into silently wrong data.
All three container versions reject them at parse time with
``CorruptArchiveError``.
"""
import json
import struct

import numpy as np
import pytest

from _fields import smooth_field
from repro.api import Archive, Codec, CorruptArchiveError
from repro.core import container

X = smooth_field((48, 30), seed=5)


def _v1_buf():
    return Codec(eb=1e-4).compress(X).tobytes()


def _v2_buf():
    return Codec(eb=1e-4, chunk_elems=500).compress(X).tobytes()


def _v3_buf():
    return Codec(eb=1e-4, chunk_elems=500, version=3).compress(X).tobytes()


def _remutate(buf, magic, fn):
    """Apply ``fn`` to the header dict and reframe, padding the JSON back
    to its original length so blob offsets stay valid."""
    (hlen,) = struct.unpack("<I", buf[4:8])
    h = json.loads(buf[8:8 + hlen].decode())
    fn(h)
    hj = json.dumps(h, separators=(",", ":")).encode()
    assert len(hj) <= hlen, "mutation grew the header"
    hj = hj[:-1] + b" " * (hlen - len(hj)) + hj[-1:]
    return magic + struct.pack("<I", hlen) + hj + buf[8 + hlen:]


# ------------------------------------------------------------------- v1

def _first_sized_level(h):
    for lv in h["levels"]:
        for k, size in enumerate(lv["plane_sizes"]):
            if size:
                return lv, k
    raise AssertionError("archive has no non-empty plane")


def test_v1_rejects_overlapping_planes():
    """A plane whose extent overlaps its predecessor parses in-bounds but
    aliases bytes — rejected."""
    buf = _v1_buf()

    def overlap(h):
        # anchors always carry bytes and come first in the canonical
        # order, so aliasing any sized plane onto them must trip the check
        lv, k = _first_sized_level(h)
        lv["plane_offsets"][k] = h["anchors_offset"]
    with pytest.raises(CorruptArchiveError, match="overlaps|precedes"):
        Archive(_remutate(buf, container.MAGIC, overlap))


def test_v1_rejects_out_of_order_blobs():
    buf = _v1_buf()

    def reorder(h):
        lv, k = _first_sized_level(h)
        # move a later plane's extent before an earlier one's
        lv["plane_offsets"][k] = lv["plane_offsets"][k] + \
            sum(lv["plane_sizes"])
    # either the cursor walk or the bounds check trips — both are
    # CorruptArchiveError at Archive construction
    with pytest.raises(CorruptArchiveError):
        Archive(_remutate(buf, container.MAGIC, reorder))


def test_v1_rejects_blob_overlapping_header():
    buf = _v1_buf()

    def into_header(h):
        h["anchors_offset"] = 4
    with pytest.raises(CorruptArchiveError, match="overlaps|precedes"):
        Archive(_remutate(buf, container.MAGIC, into_header))


def test_v1_zero_size_blobs_stay_legal():
    """Size-0 planes carry no bytes and are exempt from ordering — the
    happy path must keep parsing."""
    buf = _v1_buf()
    m = container.parse_meta(buf)
    assert any(s == 0 for lv in m.levels for s in [lv.esc_size]) or True
    assert Archive(buf).nbytes == len(buf)


# ------------------------------------------------------------------- v2

def test_v2_rejects_overlapping_chunks():
    buf = _v2_buf()

    def overlap(h):
        h["chunks"][1]["offset"] = h["chunks"][0]["offset"]
    with pytest.raises(CorruptArchiveError, match="overlaps|precedes"):
        Archive(_remutate(buf, container.MAGIC2, overlap))


def test_v2_rejects_out_of_order_chunks():
    buf = _v2_buf()

    def swap(h):
        c0, c1 = h["chunks"][0], h["chunks"][1]
        c0["offset"], c1["offset"] = c1["offset"], c0["offset"]
        c0["size"], c1["size"] = c1["size"], c0["size"]
    with pytest.raises(CorruptArchiveError, match="overlaps|precedes"):
        Archive(_remutate(buf, container.MAGIC2, swap))


# ------------------------------------------------------------------- v3

def test_v3_rejects_overlapping_chunk_blobs_in_segment():
    """Two chunks' blobs inside one v3 segment must not alias."""
    buf = _v3_buf()

    def alias(h):
        # point chunk 1's first sized plane blob at chunk 0's
        for li, lv1 in enumerate(h["chunk_headers"][1]["levels"]):
            lv0 = h["chunk_headers"][0]["levels"][li]
            for k, size in enumerate(lv1["plane_sizes"]):
                if size and lv0["plane_sizes"][k]:
                    lv1["plane_offsets"][k] = lv0["plane_offsets"][k]
                    return
        raise AssertionError("no shared sized plane")
    with pytest.raises(CorruptArchiveError, match="overlaps|precedes"):
        Archive(_remutate(buf, container.MAGIC3, alias))


def test_v3_rejects_segment_overlap():
    buf = _v3_buf()

    def overlap(h):
        h["segments"][1]["offset"] = h["segments"][0]["offset"]
    with pytest.raises(CorruptArchiveError, match="contiguous|expected"):
        Archive(_remutate(buf, container.MAGIC3, overlap))


def test_v3_rejects_duplicate_segment_identity():
    buf = _v3_buf()

    def dup(h):
        planes = [s for s in h["segments"] if s["kind"] == "planes"]
        # give the second plane segment the first one's identity
        tgt = [s for s in h["segments"] if s["kind"] == "planes"][1]
        tgt["level"], tgt["plane"] = planes[0]["level"], planes[0]["plane"]
    with pytest.raises(CorruptArchiveError):
        Archive(_remutate(buf, container.MAGIC3, dup))


# --------------------------------------------------- short-read matrix
# A source that stops producing bytes at position ``cut`` — the remote
# analogue of a truncated file or an object whose tail was never
# written.  It still *claims* the full size, so only the read path can
# notice.  Every framing boundary must surface the short read as
# CorruptArchiveError, never as struct/json noise or silently wrong
# data.

class _CutSource(container.ByteSource):
    def __init__(self, buf, cut):
        self.buf, self.cut = buf, cut

    def read(self, offset, size, tag=None):
        return self.buf[offset:min(offset + size, self.cut)]

    @property
    def size(self):
        return len(self.buf)


def _cuts(buf):
    """Cut positions hitting each framing boundary: mid-magic,
    mid-header-length, mid-header JSON, first data byte, mid-data,
    last byte."""
    (hlen,) = struct.unpack("<I", buf[4:8])
    he = 8 + hlen
    return {"magic": 2, "hlen": 6, "header": 8 + hlen // 2,
            "data-start": he, "data-mid": (he + len(buf)) // 2,
            "last-byte": len(buf) - 1}


@pytest.mark.parametrize("make", [_v1_buf, _v2_buf, _v3_buf],
                         ids=["v1", "v2", "v3"])
@pytest.mark.parametrize("where", ["magic", "hlen", "header", "data-start",
                                   "data-mid", "last-byte"])
def test_short_read_surfaces_as_corrupt_archive(make, where):
    buf = make()
    src = _CutSource(buf, _cuts(buf)[where])
    with pytest.raises(CorruptArchiveError):
        Archive.from_source(src).open().read()


@pytest.mark.parametrize("make", [_v1_buf, _v2_buf, _v3_buf],
                         ids=["v1", "v2", "v3"])
def test_cut_past_end_is_harmless(make):
    """The guard rejects short reads, not sources: a cut at EOF never
    fires and the archive decodes normally."""
    buf = make()
    out = Archive.from_source(_CutSource(buf, len(buf))).open().read()
    assert np.abs(out - X).max() <= 1e-4


# ------------------------------------------- unchanged archives still parse

@pytest.mark.parametrize("make", [_v1_buf, _v2_buf, _v3_buf],
                         ids=["v1", "v2", "v3"])
def test_well_formed_archives_round_trip(make):
    """The hardening rejects corruption, not valid archives."""
    buf = make()
    a = Archive(buf)
    out = a.open().read()
    assert np.abs(out - X).max() <= 1e-4
