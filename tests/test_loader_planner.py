"""Loader-planner regression suite: propagation parity + infeasible
byte budgets.

Two planner bugs pinned here:

* ``plan_full`` hardcoded the PAPER propagation model for its reported
  ``err_bound`` while sessions default to SAFE — every plan mode must
  now report the *same* bound the session's ``update_achieved_bound``
  recomputes after executing the plan, under either propagation model.
* ``plan_bitrate_mode`` with a budget below the plan floor (escape
  channels always travel with their level) silently returned a plan
  whose ``loaded_bytes`` exceeded ``max_bytes`` — it must raise a clear
  ValueError instead, on v1 and chunked v2 alike; a budget exactly at
  the floor stays feasible.
"""
import numpy as np
import pytest

from _fields import smooth_field
from repro import Archive, Codec, Fidelity
from repro.core import container, loader
from repro.core.pipeline import decode

X = smooth_field((40, 37), seed=5)

#: forces escape channels: isolated extreme outliers blow the quantizer
#: range so the encoder stores them losslessly (esc_size > 0)
X_ESC = X.copy()
X_ESC[13, 17] = 1e15
X_ESC[0, 0] = -1e15


def _meta(x, **codec_kw):
    arc = Codec(**codec_kw).compress(x)
    return container.open_reader(arc.tobytes()).meta, arc


FIDELITIES = [Fidelity.error_bound(1e-2), Fidelity.error_bound(1e-4),
              Fidelity.max_bytes(2500), Fidelity.bitrate(4.0),
              Fidelity.full()]
_F_IDS = ["eb1e-2", "eb1e-4", "bytes2500", "bitrate4", "full"]


@pytest.mark.parametrize("propagation", [loader.PAPER, loader.SAFE])
@pytest.mark.parametrize("fidelity", FIDELITIES, ids=_F_IDS)
def test_plan_bound_matches_achieved_bound(fidelity, propagation):
    """Every plan mode's reported err_bound equals the bound the session
    recomputes from the loaded planes, under the same propagation —
    planner and accountant share one model (plan_full used to hardcode
    PAPER)."""
    meta, arc = _meta(X, eb=1e-5)
    plan = decode.plan_retrieval(meta, fidelity, propagation)
    reader = arc.new_reader()
    _, st = decode.read_archive(reader, fidelity, propagation=propagation)
    assert st.planes_loaded == plan.keep_planes
    assert st.err_bound == plan.err_bound


def test_plan_full_threads_propagation():
    """plan_full accepts and forwards the propagation model (its cost
    tables must be the requested model's, not PAPER's)."""
    meta, _ = _meta(X, eb=1e-5)
    for prop in (loader.PAPER, loader.SAFE):
        plan = loader.plan_full(meta, prop)
        errs, _ = loader._level_cost_tables(meta, prop)
        want = meta.eb + sum(float(e[0]) for e in errs)
        assert plan.err_bound == want
        assert plan.keep_planes == [lv.nbits for lv in meta.levels]
    # and the Fidelity dispatcher passes the model through
    assert decode.plan_retrieval(meta, Fidelity.full(),
                                 loader.SAFE).err_bound == \
        loader.plan_full(meta, loader.SAFE).err_bound


def test_bitrate_below_floor_raises_v1():
    """A byte budget below the escape-channel floor is infeasible and
    raises (the old silent behaviour returned loaded_bytes > max_bytes)."""
    meta, _ = _meta(X_ESC, eb=1e-7)
    floor = sum(lv.esc_size for lv in meta.levels)
    assert floor > 0, "fixture must force escape channels"
    with pytest.raises(ValueError, match="infeasible"):
        loader.plan_bitrate_mode(meta, floor - 1)
    # exactly at the floor: feasible, minimal plan, contract holds
    plan = loader.plan_bitrate_mode(meta, floor)
    assert plan.keep_planes == [0] * len(meta.levels)
    assert plan.loaded_bytes == floor <= floor


def test_bitrate_floor_plan_respects_max_bytes():
    """Any feasible budget must come back with loaded_bytes <= max_bytes
    (the violated contract of the original bug)."""
    meta, _ = _meta(X_ESC, eb=1e-7)
    floor = sum(lv.esc_size for lv in meta.levels)
    for budget in (floor, floor + 1, floor + 500, 10 ** 9):
        plan = loader.plan_bitrate_mode(meta, budget)
        assert plan.loaded_bytes <= budget


def test_bitrate_below_floor_raises_through_session_v1():
    _, arc = _meta(X_ESC, eb=1e-7)
    with pytest.raises(ValueError, match="infeasible"):
        arc.open().read(Fidelity.max_bytes(1))


def test_bitrate_below_floor_raises_through_session_v2():
    """Chunked archives split the budget per chunk; a chunk whose share
    falls below its escape floor surfaces the same clear error."""
    _, arc = _meta(X_ESC, eb=1e-7, chunk_elems=370)
    assert arc.chunked
    with pytest.raises(ValueError, match="infeasible"):
        arc.open().read(Fidelity.max_bytes(1))


def test_chunked_feasible_budget_never_starves_escape_chunk():
    """A budget at or above the summed per-chunk escape floors succeeds
    even when the escape bytes concentrate in few chunks: each chunk's
    floor is reserved before the proportional element-count split.  (The
    old proportional-only split handed the escape-heavy chunk less than
    its floor and failed the whole — globally feasible — read.)"""
    _, arc = _meta(X_ESC, eb=1e-7, chunk_elems=370)
    r = container.open_reader(arc.tobytes())
    floors = [sum(lv.esc_size for lv in r.chunk_reader(i).meta.levels)
              for i in range(len(r.meta.chunks))]
    assert max(floors) > 0 and min(floors) == 0, \
        "fixture must concentrate escapes in a subset of chunks"
    total = sum(floors) + max(floors) // 2
    # the regression precondition: a pure proportional split would hand
    # the escape-heaviest chunk less than its own floor
    assert total // len(floors) < max(floors)
    out = arc.open().read(Fidelity.max_bytes(total))
    assert out.shape == X_ESC.shape


def test_zero_budget_without_escapes_is_feasible():
    """With no escape channels the plan floor is zero bytes: max_bytes=0
    returns the anchors-only plan instead of raising."""
    meta, arc = _meta(X, eb=1e-5)
    assert all(lv.esc_size == 0 for lv in meta.levels)
    plan = loader.plan_bitrate_mode(meta, 0)
    assert plan.keep_planes == [0] * len(meta.levels)
    assert plan.loaded_bytes == 0
    out = arc.open().read(Fidelity.max_bytes(0))
    assert out.shape == X.shape
