"""Serving-path integration: multi-token batched decode across families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M

# whole-module: multi-second decode loops, excluded from the CI fast lane
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-370m", "hymba-1.5b",
                                  "whisper-tiny", "kimi-k2-1t-a32b"])
def test_batched_decode_loop(arch):
    """Prefill + 6 decode steps: finite logits, cache length advances."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    B, S, new = 3, 24, 6
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.family == "encdec":
        kw["encoder_frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model))
    logits, cache = M.prefill(params, tokens, cfg, max_len=S + new + 2, **kw)
    assert int(cache["len"]) == S
    decode = jax.jit(lambda p, c, t: M.decode_step(p, c, t, cfg))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = []
    for _ in range(new):
        lg, cache = decode(params, cache, tok)
        assert bool(jnp.isfinite(lg).all())
        tok = jnp.argmax(lg[:, 0], -1)[:, None].astype(jnp.int32)
        outs.append(np.asarray(tok))
    assert int(cache["len"]) == S + new
    gen = np.concatenate(outs, axis=1)
    assert gen.shape == (B, new)
    assert (gen >= 0).all() and (gen < cfg.vocab).all()


def test_greedy_decode_matches_teacher_forcing():
    """Multi-step greedy decode == argmax of teacher-forced forward."""
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, KEY)
    B, S, new = 2, 20, 4
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    logits, cache = M.prefill(params, tokens, cfg, max_len=S + new + 1)
    seq = tokens
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(new):
        seq = jnp.concatenate([seq, tok], axis=1)
        h = M.forward(params, seq, cfg)
        want = jnp.argmax(M.lm_head(params, h[:, -1:], cfg)[:, 0], -1)
        lg, cache = M.decode_step(params, cache, tok, cfg)
        got = jnp.argmax(lg[:, 0], -1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        tok = got[:, None].astype(jnp.int32)
