"""Checkpoint lifecycle under contention: keep-rotation gc racing
in-flight RestoreSessions, out-of-band deletion mid-session, crashed
saves (scratch dirs, LATEST atomicity), and concurrent refine/save."""
import os
import shutil
import threading

import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, step_path
from repro.checkpoint.store import save_checkpoint


def make_tree(seed=0):
    rng = np.random.default_rng(seed)
    smoothed = np.cumsum(rng.standard_normal((64, 256)), axis=-1)
    return {"w": jax.numpy.asarray(smoothed, jax.numpy.float32),
            "b": jax.numpy.asarray(np.linspace(0, 1, 32), jax.numpy.float32)}


def assert_tree_close(got, ref, tol=1e-3):
    for k in ref:
        assert float(np.max(np.abs(np.asarray(got[k])
                                   - np.asarray(ref[k])))) <= tol


# ------------------------------------------------------ gc vs sessions

def test_gc_never_reaps_step_held_by_open_session(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep_n=1, rel_eb=1e-5)
    t1 = make_tree(1)
    mgr.save(1, t1)
    step, coarse, sess = mgr.restore_progressive(
        t1, weight_error=1e-2, refine_to=None)
    assert step == 1
    # two more saves would rotate step 1 out — but the session pins it
    mgr.save(2, make_tree(2))
    mgr.save(3, make_tree(3))
    assert os.path.exists(step_path(d, 1))
    # the in-flight session completes CORRECTLY from the pinned bundle
    full = sess.restore(None)
    assert_tree_close(full, t1, tol=1e-3)
    sess.close()
    mgr.save(4, make_tree(4))               # now the pin is gone: reaped
    assert not os.path.exists(step_path(d, 1))
    assert os.path.exists(step_path(d, 4))


def test_deleted_bundle_mid_session_completes_or_fails_loudly(tmp_path):
    """An unpinned deletion under an open session must never yield wrong
    bytes: the mmap keeps the published (immutable) bundle alive, so the
    restore completes with the ORIGINAL step's data."""
    d = str(tmp_path)
    t1 = make_tree(1)
    save_checkpoint(d, 1, t1, rel_eb=1e-5)
    from repro.checkpoint import Bundle, RestoreSession
    sess = RestoreSession(Bundle.open(step_path(d, 1)))
    sess.restore(1e-2)
    os.unlink(step_path(d, 1))              # out-of-band removal
    save_checkpoint(d, 2, make_tree(2), rel_eb=1e-5)  # unrelated new step
    try:
        full = sess.restore(None)
    except Exception:
        pass                                # loud failure is acceptable...
    else:                                   # ...silent wrong bytes are not
        assert float(np.max(np.abs(full["w"]
                                   - np.asarray(t1["w"])))) <= 1e-3
    finally:
        sess.close()


def test_refine_async_races_gc_saves(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep_n=1, rel_eb=1e-5)
    t1 = make_tree(1)
    mgr.save(1, t1)
    step, coarse, sess = mgr.restore_progressive(
        t1, weight_error=1e-1, refine_to="full")
    for s in range(2, 6):                   # rotation churns while refining
        mgr.save(s, make_tree(s))
    refined = sess.refined(timeout=60)
    assert refined is not None
    assert_tree_close(refined, t1, tol=1e-3)
    sess.close()


# ------------------------------------------------------- crashed saves

def test_crashed_save_scratch_ignored_and_reaped(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep_n=3, rel_eb=1e-5)
    t1 = make_tree(1)
    mgr.save(1, t1)
    # a save that died mid-encode leaves shard scratch + a merge buffer
    junk_dir = os.path.join(d, ".step_9_abc123")
    os.makedirs(junk_dir)
    open(os.path.join(junk_dir, "shard_0.bin"), "wb").write(b"\0" * 64)
    open(os.path.join(junk_dir, "bundle.tmp"), "wb").write(b"IPCB????")
    open(os.path.join(d, ".step_9_stray"), "wb").write(b"junk")
    # readers ignore the scratch entirely
    assert latest_step(d) == 1
    step, restored = mgr.restore_latest(t1)
    assert step == 1
    assert_tree_close(restored, t1, tol=1e-3)
    # the next save's gc reaps it
    mgr.save(2, make_tree(2))
    assert not os.path.exists(junk_dir)
    assert not os.path.exists(os.path.join(d, ".step_9_stray"))


def test_latest_pointer_flip_is_atomic_across_crash(tmp_path):
    """A crash BEFORE the pointer flip leaves LATEST on the old step and
    a complete old bundle — never a torn pointer or a half bundle."""
    d = str(tmp_path)
    t1 = make_tree(1)
    save_checkpoint(d, 1, t1, rel_eb=1e-5)
    # simulate dying between bundle publish and pointer flip for step 2
    save_checkpoint(d, 2, make_tree(2), rel_eb=1e-5)
    with open(os.path.join(d, "LATEST"), "w") as f:
        f.write("1")                        # pointer still on the old step
    open(os.path.join(d, ".LATEST_tmp"), "w").write("2")  # stranded tmp
    mgr = CheckpointManager(d, keep_n=3, rel_eb=1e-5)
    step, restored = mgr.restore_latest(t1)
    assert step == 1                        # old pointer honored
    assert_tree_close(restored, t1, tol=1e-3)
    mgr.save(3, make_tree(3))               # next save replaces LATEST
    assert latest_step(d) == 3


def test_save_gc_threads_against_reader_threads(tmp_path):
    """Hammer save+gc on one thread while sessions restore on others —
    every completed restore must match its own step's tree."""
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep_n=2, rel_eb=1e-5)
    trees = {s: make_tree(s) for s in range(1, 7)}
    mgr.save(1, trees[1])
    errors = []

    def reader():
        try:
            for _ in range(4):
                step, restored = mgr.restore_latest(trees[1])
                if step is not None:
                    assert_tree_close(restored, trees[step], tol=1e-3)
        except FileNotFoundError:
            pass                            # rotated under us: loud, not wrong
        except Exception as e:              # wrong bytes / crashes: fail
            errors.append(e)

    threads = [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    for s in range(2, 7):
        mgr.save(s, trees[s])
    for t in threads:
        t.join(60)
    assert not errors, errors
