"""Model math invariants: flash attention vs naive oracle, SSD vs direct
recurrence, causal masking, GQA broadcasting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import decode_attention, flash_attention
from repro.models.ssm import ssd_scan


def naive_attention(q, k, v, causal=True, window=0):
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) / np.sqrt(D)
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qi >= ki
    if window:
        mask &= qi - ki < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


@pytest.mark.parametrize("S,H,KV,D,qc,kc", [
    (64, 4, 2, 16, 16, 16),
    (100, 6, 3, 8, 32, 48),     # ragged: S % chunk != 0
    (128, 8, 8, 16, 128, 128),  # single tile (MHA)
    (96, 4, 1, 8, 24, 96),      # MQA
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24), (False, 0)])
def test_flash_matches_naive(S, H, KV, D, qc, kc, causal, window):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, S, KV, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_chunk=qc, kv_chunk=kc)
    want = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_grads_match_naive():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 48, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 48, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 48, 2, 8)), jnp.float32)

    def f(fn):
        return jax.grad(lambda a, b, c: jnp.sum(
            fn(a, b, c) ** 2), argnums=(0, 1, 2))(q, k, v)

    ga = f(lambda a, b, c: flash_attention(a, b, c, causal=True,
                                           q_chunk=16, kv_chunk=16))
    gb = f(lambda a, b, c: naive_attention(a, b, c, causal=True))
    for x, y in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=5e-4, rtol=5e-4)


def test_causality():
    """Perturbing a future token must not change past outputs."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
    base = flash_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    k2 = k.at[:, 20:].set(rng.standard_normal((1, 12, 2, 8)))
    v2 = v.at[:, 20:].set(rng.standard_normal((1, 12, 2, 8)))
    pert = flash_attention(q, k2, v2, causal=True, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(base[:, :20]),
                               np.asarray(pert[:, :20]), atol=1e-6)
    assert not np.allclose(np.asarray(base[:, 21:]), np.asarray(pert[:, 21:]))


def test_decode_attention_matches_naive_last_position():
    rng = np.random.default_rng(3)
    S = 24
    k = jnp.asarray(rng.standard_normal((2, S, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, S, 2, 8)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((2, 1, 4, 8)), jnp.float32)
    # pad cache to 32, only S valid
    kp = jnp.pad(k, ((0, 0), (0, 8), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 8), (0, 0), (0, 0)))
    got = decode_attention(q, kp, vp, jnp.full((2,), S))
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------------ SSD

def ssd_reference(x, dt, B, C, A):
    """Direct O(S) recurrence: h_{t} = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    h = np.zeros((b, H, P, N))
    ys = np.zeros((b, S, H, P))
    x, dt, B, C = map(np.asarray, (x, dt, B, C))
    A = np.asarray(A)
    for t in range(S):
        decay = np.exp(dt[:, t] * A)                       # (b,H)
        upd = np.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], B[:, t])
        h = h * decay[..., None, None] + upd
        ys[:, t] = np.einsum("bn,bhpn->bhp", C[:, t], h)
    return ys, h


@pytest.mark.parametrize("S,chunk", [(32, 8), (40, 16), (16, 16), (33, 8)])
def test_ssd_chunked_matches_recurrence(S, chunk):
    rng = np.random.default_rng(4)
    b, H, P, N = 2, 3, 4, 5
    x = jnp.asarray(rng.standard_normal((b, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, S, H)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, S, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, S, N)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    y, h = ssd_scan(x, dt, B, C, A, chunk=chunk)
    y_ref, h_ref = ssd_reference(x, dt, B, C, A)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, atol=1e-4, rtol=1e-4)


def test_ssd_state_carry_composes():
    """scan(x1++x2) == scan(x2, prev_state=scan(x1).state)."""
    rng = np.random.default_rng(5)
    b, S, H, P, N = 1, 24, 2, 4, 3
    x = jnp.asarray(rng.standard_normal((b, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, S, H)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, S, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, S, N)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    y_full, h_full = ssd_scan(x, dt, B, C, A, chunk=8)
    y1, h1 = ssd_scan(x[:, :12], dt[:, :12], B[:, :12], C[:, :12], A, chunk=8)
    y2, h2 = ssd_scan(x[:, 12:], dt[:, 12:], B[:, 12:], C[:, 12:], A,
                      prev_state=h1, chunk=8)
    np.testing.assert_allclose(np.asarray(y_full[:, 12:]), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2),
                               atol=1e-4, rtol=1e-4)
