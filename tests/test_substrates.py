"""Substrate tests: data pipeline, grad compression, checkpointing, driver."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save_checkpoint, \
    restore_checkpoint, progressive_restore
from repro.compression import compress_gradients, init_error_feedback
from repro.configs import get_config
from repro.data.pipeline import TokenStream
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import make_train_state
from repro.runtime import DriverConfig, FailureInjector, TrainDriver


# ------------------------------------------------------------ data

def test_data_stateless_indexing():
    s = TokenStream(vocab=1000, seq_len=64, global_batch=4, seed=7)
    a = s.batch_at(42)
    b = s.batch_at(42)
    np.testing.assert_array_equal(a, b)          # restart-deterministic
    assert not np.array_equal(a, s.batch_at(43))
    assert a.shape == (4, 65) and a.max() < 1000 and a.min() >= 0


def test_data_host_sharding_partitions_batch():
    full = TokenStream(vocab=100, seq_len=16, global_batch=8, seed=1)
    parts = [TokenStream(vocab=100, seq_len=16, global_batch=8, seed=1,
                         process_index=i, process_count=4) for i in range(4)]
    assert all(p.local_batch == 2 for p in parts)
    # shards differ from each other (different host substreams)
    assert not np.array_equal(parts[0].batch_at(0), parts[1].batch_at(0))


# ------------------------------------------------------------ grad comp

def test_grad_compression_error_feedback_converges():
    """Sum of (compressed grad + carried error) == true grad over time."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    ef = init_error_feedback(g_true)
    acc = jnp.zeros_like(g_true["w"])
    for _ in range(30):
        gq, ef, bits = compress_gradients(g_true, ef, rel_eb=1e-2,
                                          keep_bits=8)
        acc = acc + gq["w"]
    # average applied gradient ~= true gradient (error feedback is unbiased)
    err = float(jnp.max(jnp.abs(acc / 30 - g_true["w"])))
    assert err < 0.05 * float(jnp.max(jnp.abs(g_true["w"])))


def test_grad_compression_bounded_per_step():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.standard_normal((128,)), jnp.float32)}
    ef = init_error_feedback(g)
    gq, ef2, _ = compress_gradients(g, ef, rel_eb=1e-3, keep_bits=32)
    # keep_bits=32 => pure quantization, error <= scale
    scale = float(jnp.max(jnp.abs(g["w"]))) * 1e-3
    assert float(jnp.max(jnp.abs(gq["w"] - g["w"]))) <= scale * (1 + 1e-5)


def test_compressed_psum_matches_psum():
    from repro.compression import compressed_psum
    devs = jax.devices()
    if len(devs) < 2:
        # single-device container: shard_map over a 1-sized axis still works
        mesh = jax.make_mesh((1,), ("pod",))
    else:
        mesh = jax.make_mesh((2,), ("pod",))
    from jax.sharding import PartitionSpec as P
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)),
                    jnp.float32)

    from repro.parallel.compat import shard_map
    f = shard_map(lambda a: compressed_psum(a, "pod", keep_bits=16,
                                            rel_eb=1e-5),
                  mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                  axis_names={"pod"}, check_vma=False)
    got = f(x)
    # with one pod the compressed psum is just quantize/dequantize
    assert float(jnp.max(jnp.abs(got - x))) < 1e-3


# ------------------------------------------------------------ checkpoint

def _tiny_state():
    cfg = get_config("smollm-360m").reduced(n_layers=1, d_model=64, d_ff=128,
                                            vocab=128)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, make_train_state(params)


@pytest.mark.slow
def test_checkpoint_roundtrip(tmp_path):
    cfg, state = _tiny_state()
    man = save_checkpoint(str(tmp_path), 5, state.params, rel_eb=1e-6)
    assert man["total_comp"] < man["total_raw"]   # it actually compresses
    got = restore_checkpoint(str(tmp_path), 5, state.params)
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(got)):
        rng = float(jnp.max(a) - jnp.min(a)) or 1.0
        # eb plus one f32 ulp of slack (archive math is f64, leaves are f32)
        tol = 1e-6 * rng + float(jnp.max(jnp.abs(a))) * 2 ** -23
        assert float(jnp.max(jnp.abs(a - b))) <= tol


def test_progressive_restore_reads_fewer_bytes(tmp_path):
    cfg, state = _tiny_state()
    save_checkpoint(str(tmp_path), 1, state.params, rel_eb=1e-7)
    coarse, sess = progressive_restore(str(tmp_path), 1, state.params,
                                       weight_error=1e-2)
    coarse_bytes = sess.bytes_read
    fine, sess = progressive_restore(str(tmp_path), 1, state.params,
                                     weight_error=1e-6, session=sess)
    assert coarse_bytes < sess.bytes_read        # refinement added bytes
    # coarse restore error within requested bound
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(coarse)):
        if a.size > 4096:
            rng = float(jnp.max(a) - jnp.min(a)) or 1.0
            assert float(jnp.max(jnp.abs(a - b))) <= 1e-2 * rng * 1.01
    # fine restore strictly better than coarse
    for a, c, f in zip(jax.tree_util.tree_leaves(state.params),
                       jax.tree_util.tree_leaves(coarse),
                       jax.tree_util.tree_leaves(fine)):
        if a.size > 4096:
            assert (float(jnp.max(jnp.abs(a - f)))
                    <= float(jnp.max(jnp.abs(a - c))) + 1e-12)


# ------------------------------------------------------------ driver / FT

@pytest.mark.slow
def test_driver_checkpoint_restart_after_failure(tmp_path):
    cfg, state = _tiny_state()
    step_fn = jax.jit(make_train_step(cfg))
    stream = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=2)
    driver = TrainDriver(
        step_fn=step_fn, stream=stream,
        ckpt=CheckpointManager(str(tmp_path), keep_n=2),
        cfg=DriverConfig(total_steps=12, ckpt_every=4),
        injector=FailureInjector([6]))
    report = driver.run(state)
    assert report["restarts"] == 1
    assert report["final_step"] == 12
    assert np.isfinite(report["losses"]).all()


@pytest.mark.slow
def test_driver_loss_decreases(tmp_path):
    cfg, state = _tiny_state()
    step_fn = jax.jit(make_train_step(cfg))
    stream = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=4)
    driver = TrainDriver(step_fn=step_fn, stream=stream,
                         ckpt=CheckpointManager(str(tmp_path)),
                         cfg=DriverConfig(total_steps=40, ckpt_every=20))
    report = driver.run(state)
    assert np.mean(report["losses"][-5:]) < np.mean(report["losses"][:5])
