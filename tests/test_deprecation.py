"""The deprecation contract of the legacy free-function shims.

Every legacy entry point (``compress`` / ``retrieve`` / ``refine`` /
``decompress``) emits EXACTLY ONE ``IPCompDeprecationWarning`` per call —
no more (shims must not chain through each other) and no less — while the
object API emits none at all.  The CI deprecation lane runs the new-API
suites under ``-W error::repro.api.IPCompDeprecationWarning``; this file
pins the shim side of the contract.
"""
import warnings

import numpy as np
import pytest

from _fields import smooth_field
from repro import (Archive, Codec, ExecPolicy, Fidelity,
                   IPCompDeprecationWarning)
from repro.core import compress, decompress, refine, retrieve

X = smooth_field((30, 20), seed=2)


def _count(fn, *a, **kw):
    """Run fn and count IPCompDeprecationWarnings it emits."""
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        result = fn(*a, **kw)
    return result, sum(issubclass(w.category, IPCompDeprecationWarning)
                       for w in caught)


@pytest.mark.parametrize("chunk_elems", [None, 200], ids=["v1", "v2"])
def test_each_legacy_entry_point_warns_exactly_once(chunk_elems):
    buf, n = _count(compress, X, 1e-5, chunk_elems=chunk_elems)
    assert n == 1
    (out, state), n = _count(retrieve, buf, error_bound=1e-3)
    assert n == 1
    (out, state), n = _count(refine, state, error_bound=1e-4)
    assert n == 1
    _, n = _count(decompress, buf)
    assert n == 1


def test_legacy_warns_even_on_error_paths():
    """The warning precedes validation: a bad call still names its
    replacement."""
    _, n = _count(lambda: pytest.raises(ValueError, compress, X, -1.0))
    assert n == 1
    buf, _ = _count(compress, X, 1e-5)
    _, n = _count(lambda: pytest.raises(ValueError, retrieve, buf,
                                        error_bound=1e-3, bitrate=2.0))
    assert n == 1


def test_warning_category_and_message():
    with pytest.warns(IPCompDeprecationWarning, match="Codec"):
        compress(X, 1e-5)
    assert issubclass(IPCompDeprecationWarning, DeprecationWarning)
    # the category is importable where the CI lane's -W filter looks
    from repro.api import IPCompDeprecationWarning as from_api
    assert from_api is IPCompDeprecationWarning


def test_object_api_is_warning_clean(tmp_path):
    """A full object-API workflow — compress, serialize, session ladder,
    policy swap — emits zero shim warnings."""
    def workflow():
        arc = Codec(eb=1e-5, chunk_elems=200).compress(
            X, policy=ExecPolicy(backend="numpy"))
        arc.save(tmp_path / "a.ipc")
        s = Archive.load(tmp_path / "a.ipc").open()
        for _ in s.ladder([Fidelity.error_bound(1e-2),
                           Fidelity.max_bytes(2000), Fidelity.full()]):
            pass
        s.policy = ExecPolicy(batch_chunks=False)
        return s.refine()

    out, n = _count(workflow)
    assert n == 0
    assert np.abs(out - X).max() <= 1e-5


def test_legacy_and_new_apis_agree():
    """The shims are *thin*: same bytes from compress vs Codec, same bits
    and accounting from retrieve/refine vs a session."""
    arc = Codec(eb=1e-5, chunk_elems=200).compress(X)
    buf, _ = _count(compress, X, 1e-5, chunk_elems=200)
    assert buf == arc.tobytes()

    session = arc.open()
    s_out = session.read(Fidelity.error_bound(1e-3))
    (l_out, l_state), _ = _count(retrieve, buf, error_bound=1e-3)
    assert np.array_equal(s_out, l_out)
    assert session.bytes_read == l_state.bytes_read
    assert session.achieved_bound == l_state.err_bound

    s_ref = session.refine(Fidelity.full())
    (l_ref, l_state), _ = _count(refine, l_state)
    assert np.array_equal(s_ref, l_ref)
    assert session.bytes_read == l_state.bytes_read
