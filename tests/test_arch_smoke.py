"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (full configs are exercised only via
the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import ARCHS, get_config
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import make_train_state

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    batch = {"tokens": jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["prefix"] = jax.random.normal(
            KEY, (B, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    h = M.forward(params, batch["tokens"][:, :-1], cfg,
                  prefix_embeds=batch.get("prefix"),
                  encoder_frames=batch.get("frames"))
    extra = cfg.n_prefix_embeds if cfg.family == "vlm" else 0
    assert h.shape == (2, 32 + extra, cfg.d_model)
    assert bool(jnp.isfinite(h).all())
    logits = M.lm_head(params, h[:, -1:], cfg)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_train_step(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    state = make_train_state(params)
    step = jax.jit(make_train_step(cfg))
    state2, m = step(state, _batch(cfg))
    assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) > 0
    # params actually moved
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree_util.tree_map(lambda a, b: a - b, state.params, state2.params),
        0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.family == "moe":
        cfg = replace(cfg, moe_capacity_factor=8.0)  # no token dropping
    params = M.init_params(cfg, KEY)
    B, S = 2, 32
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.family == "encdec":
        kw["encoder_frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        kw["prefix_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_prefix_embeds, cfg.d_model))
    total = S + cfg.n_prefix_embeds + 8
    logits, cache = M.prefill(params, tokens, cfg, max_len=total,
                              prefix_embeds=kw.get("prefix_embeds"),
                              encoder_frames=kw.get("encoder_frames"))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    lg, cache = M.decode_step(params, cache, tok, cfg)
    full = M.forward(params, jnp.concatenate([tokens, tok], 1), cfg, **kw)
    lf = M.lm_head(params, full[:, -1:], cfg)[:, 0]
    tol = 5e-4 if cfg.sliding_window else 1e-4
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(lf),
                               atol=tol, rtol=1e-3)


def test_param_count_sanity():
    """Analytic param counts are within family-plausible ranges at full size."""
    approx = {
        "yi-6b": (5e9, 8e9),
        "command-r-35b": (30e9, 42e9),
        "qwen2-0.5b": (3e8, 7e8),
        "smollm-360m": (2.5e8, 5e8),
        "mamba2-370m": (2.5e8, 5e8),
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        # assignment table: MoE in EVERY layer (real Maverick interleaves
        # dense layers) -> analytic count lands at ~778B
        "llama4-maverick-400b-a17b": (3e11, 9e11),
        "internvl2-1b": (3e8, 9e8),
        "hymba-1.5b": (1e9, 2.2e9),
        "whisper-tiny": (2e7, 7e7),
    }
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    assert cfg.active_param_count() < 0.1 * cfg.param_count()
