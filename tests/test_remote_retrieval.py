"""End-to-end remote retrieval over real loopback HTTP (marker: network).

The v3 claims — one coalesced Range per rung, bit-parity with local
reads — were pinned against the in-memory ``CountingSource`` double in
``test_v3_format.py``; here they are proven over an actual socket:
``HTTPSource`` against the in-process ``tests/range_server.py``, with
the *server's* request log as ground truth.  Plus what only a network
can do: injected faults at every rung boundary (survived via retry),
server restart mid-ladder, range-less servers, and exhausted retry
budgets.
"""
import numpy as np
import pytest

from _fields import smooth_field
from range_server import RangeHTTPServer, ServerFault, serve
from repro.api import Archive, Codec, Fidelity
from repro.core.remote import HTTPSource, RemoteReadError

pytestmark = pytest.mark.network

X = smooth_field((60, 40), seed=7)
EB = 1e-5
LADDER = [1e-1, 1e-2, 1e-3, 1e-4, 1e-5]

V3 = Codec(eb=EB, chunk_elems=600, version=3).compress(X).tobytes()
V2 = Codec(eb=EB, chunk_elems=600).compress(X).tobytes()
HEADER_END = Archive.frombytes(V3)._meta.header_end


def _source(srv, **kw):
    kw.setdefault("timeout", 5.0)
    kw.setdefault("backoff", 0.01)
    return HTTPSource(srv.url, **kw)


def _data_gets(srv):
    """Data-section Range requests the server actually saw (framing and
    header reads excluded)."""
    return [r for r in srv.get_ranges()
            if r is not None and r[0] >= HEADER_END]


# -------------------------------------------------------- the v3 claims

def test_v3_ladder_bit_parity_and_one_range_per_rung():
    """Acceptance: a v3 fidelity ladder through HTTPSource is
    bit-identical to a BufferSource read and issues exactly one Range
    request per advancing rung — counted at the SERVER."""
    local = Archive.frombytes(V3).open()
    with serve(V3) as srv:
        src = _source(srv)
        session = Archive.from_source(src).open()
        for E in LADDER:
            before = len(_data_gets(srv))
            end_before = (session._state.reader._stage.end
                          if session._state else HEADER_END)
            out = session.read(Fidelity.error_bound(E))
            ref = local.read(Fidelity.error_bound(E))
            assert np.array_equal(out, ref), f"parity broke at E={E}"
            issued = len(_data_gets(srv)) - before
            grew = session._state.reader._stage.end > end_before
            assert issued == (1 if grew else 0), \
                f"rung E={E}: {issued} ranges, staged grew={grew}"
        # the wire ranges tile the data section contiguously, in order
        gets = _data_gets(srv)
        assert gets[0][0] == HEADER_END
        for (s0, e0), (s1, _) in zip(gets, gets[1:]):
            assert s1 == e0 + 1
        assert src.monotone()
        assert src.retry_count == 0


def test_v2_ladder_bit_parity_over_http():
    """v2 works over HTTP too — scattered ranges, same bits."""
    local = Archive.frombytes(V2).open()
    with serve(V2) as srv:
        session = Archive.from_source(_source(srv)).open()
        for E in LADDER:
            assert np.array_equal(session.read(Fidelity.error_bound(E)),
                                  local.read(Fidelity.error_bound(E)))
        assert srv.n_gets > len(LADDER)  # v2 scatters; v3's win is real


def test_v3_strictly_fewer_ranges_than_v2():
    with serve(V3) as s3:
        Archive.from_source(_source(s3)).open().read(
            Fidelity.error_bound(1e-4))
        n3 = s3.n_gets
    with serve(V2) as s2:
        Archive.from_source(_source(s2)).open().read(
            Fidelity.error_bound(1e-4))
        n2 = s2.n_gets
    assert n3 < n2


# ------------------------------------------------------ fault tolerance

def test_fault_at_every_rung_boundary_survives_via_retry():
    """Drop the connection on the FIRST attempt of every rung's range
    read; each rung must recover via retry, bits intact."""
    local = Archive.frombytes(V3).open()
    with serve(V3) as srv:
        src = _source(srv, retries=3)
        session = Archive.from_source(src).open()
        armed = set()
        for E in LADDER:
            # arm a one-shot drop for the NEXT wire request (this rung's
            # range read, wherever the ladder plan puts it)
            if srv.n_gets not in armed:
                armed.add(srv.n_gets)
                srv.faults.append(ServerFault("drop", at=srv.n_gets))
            out = session.read(Fidelity.error_bound(E))
            assert np.array_equal(out, local.read(Fidelity.error_bound(E)))
        fired = sum(1 for f in srv.faults if f.at < srv.n_gets)
        assert src.retry_count >= fired > 0


@pytest.mark.parametrize("fault", [
    ServerFault("drop", at=0),
    ServerFault("status", at=0, arg=500),
    ServerFault("status", at=0, arg=503),
    ServerFault("truncate", at=0, arg=3),
])
def test_single_fault_kinds_recover(fault):
    payload = bytes(range(256)) * 8
    with serve(payload, faults=[fault]) as srv:
        src = _source(srv, retries=3)
        assert bytes(src.read(16, 64)) == payload[16:80]
        assert src.retry_count == 1


def test_stalled_server_times_out_and_recovers():
    payload = bytes(range(256)) * 8
    with serve(payload, faults=[ServerFault("stall", at=0, arg=2.0)]) as srv:
        src = _source(srv, timeout=0.3, retries=2)
        assert bytes(src.read(0, 32)) == payload[:32]
        assert src.retry_count >= 1


def test_exhausted_retries_raise_remote_read_error():
    with serve(V3, faults=[ServerFault("drop", at=0, persist=True)]) as srv:
        src = _source(srv, retries=2, timeout=0.5)
        with pytest.raises(RemoteReadError, match="3 attempts"):
            src.read(0, 4)
        # RemoteReadError is an OSError: generic transport handling sees it
        with pytest.raises(OSError):
            src.read(0, 4)


def test_server_restart_mid_ladder():
    """Kill the server between rungs and restart it on the same port:
    the source reconnects transparently and the ladder completes with
    bit parity."""
    # the reference steps the same rungs: progressive refinement and a
    # cold read agree within the bound but not bit-for-bit (incremental
    # delta accumulation orders float sums differently)
    local = Archive.frombytes(V3).open()
    srv = RangeHTTPServer(V3)
    try:
        src = _source(srv, retries=3)
        session = Archive.from_source(src).open()
        for E in LADDER[:2]:
            session.read(Fidelity.error_bound(E))
            local.read(Fidelity.error_bound(E))
        port = srv.port
        srv.stop()
        srv = RangeHTTPServer(V3, port=port)       # same port, fresh process
        for E in LADDER[2:]:
            out = session.read(Fidelity.error_bound(E))
            assert np.array_equal(out, local.read(Fidelity.error_bound(E)))
    finally:
        srv.stop()


# --------------------------------------------- protocol/transport detail

def test_size_probe_is_a_single_lazy_head():
    with serve(V3) as srv:
        src = _source(srv)
        assert srv.log == []                       # constructing is free
        _ = src.size
        _ = src.size
        session = Archive.from_source(src).open()
        session.read(Fidelity.error_bound(1e-3))
        heads = [m for m, _ in srv.log if m == "HEAD"]
        assert len(heads) == 1


def test_rangeless_server_still_bit_exact():
    """A server that ignores Range (200 + full body every time) costs
    bandwidth, never correctness."""
    faults = [ServerFault("ignore_range", at=0, persist=True)]
    local = Archive.frombytes(V3).open()
    with serve(V3, faults=faults) as srv:
        src = _source(srv)
        session = Archive.from_source(src).open()
        for E in LADDER:
            assert np.array_equal(session.read(Fidelity.error_bound(E)),
                                  local.read(Fidelity.error_bound(E)))
        assert src.range_ignored > 0
        assert src.wire_bytes >= len(V3)


def test_readahead_collapses_header_reads():
    with serve(V3) as srv:
        src = _source(srv, readahead=1 << 16)
        Archive.from_source(src)                   # magic + hlen + header
        assert src.readahead_hits >= 2
        assert len([r for m, r in srv.log if m == "GET"]) == 1


def test_counting_metrics_match_server_log():
    """HTTPSource's RangeLog is the client-side mirror of the server's
    request log — same ranges, same order."""
    with serve(V3) as srv:
        src = _source(srv)
        session = Archive.from_source(src).open()
        session.read(Fidelity.error_bound(1e-3))
        gets = [r for m, r in srv.log if m == "GET" and r is not None]
        assert [(s, e - s + 1) for s, e in gets] == list(src.requests)
