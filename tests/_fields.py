"""Shared synthetic-field generator for the codec test suites."""
import numpy as np


def smooth_field(shape, seed=0, noise=0.01):
    rng = np.random.default_rng(seed)
    grids = np.meshgrid(*[np.linspace(0, 3 * np.pi, s) for s in shape],
                        indexing="ij")
    x = np.ones(shape)
    for i, g in enumerate(grids):
        x = x * np.sin(g * (0.7 + 0.3 * i))
    return x + noise * rng.standard_normal(shape)
