"""Progressive monotonicity across refinement ladders (Algorithm 2).

Across a ladder of shrinking error bounds — on v1 and chunked v2 archives,
on both decode backends — the progressive contract must hold at every rung:

  * ``err_bound`` never increases (refinement never loses precision),
  * ``bytes_read`` never decreases (and never re-reads loaded planes),
  * refining to a bound equals a fresh retrieval at that same bound
    (the delta cascade reaches the identical plane set; arrays match to
    float-accumulation tolerance, bitwise across backends).
"""
import numpy as np
import pytest

from _fields import smooth_field
from repro.core import CUBIC, compress, metrics, open_archive, refine, retrieve

LADDER = (1e-1, 1e-2, 1e-3, 1e-5)


def _archive(version):
    x = smooth_field((72, 40), 9)
    kw = dict(chunk_elems=900) if version == "v2" else {}
    return x, compress(x, 1e-7, CUBIC, **kw)


def _plane_sets(st):
    """planes_loaded across v1 / v2 states, as one flat list."""
    if hasattr(st, "chunk_states"):
        return [cs.planes_loaded for cs in st.chunk_states]
    return [st.planes_loaded]


@pytest.mark.parametrize("version", ["v1", "v2"])
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_ladder_monotone_and_refine_equals_fresh(version, backend):
    x, buf = _archive(version)
    reader = open_archive(buf)
    st = None
    prev_err, prev_bytes = float("inf"), 0
    for E in LADDER:
        out, st = retrieve(reader, error_bound=E, state=st, backend=backend)
        # monotone guarantees
        assert st.err_bound <= prev_err
        assert st.bytes_read >= prev_bytes
        assert st.err_bound <= E
        assert metrics.linf(x, out) <= E
        prev_err, prev_bytes = st.err_bound, st.bytes_read
        # vs a fresh retrieval at the same bound: the refined plane union
        # contains the fresh plan (want = max(have, plan)), so the ladder
        # state can only dominate — DP plans need not nest across bounds,
        # so exact equality is only required when the plane sets coincide
        fresh, fst = retrieve(open_archive(buf), error_bound=E,
                              backend=backend)
        assert metrics.linf(x, fresh) <= E
        assert st.bytes_read >= fst.bytes_read
        assert st.err_bound <= fst.err_bound
        if _plane_sets(st) == _plane_sets(fst):
            np.testing.assert_allclose(out, fresh, atol=1e-12)
    # full precision: the plan is every plane, so refine == fresh exactly
    out, st = retrieve(reader, state=st, backend=backend)
    fresh, fst = retrieve(open_archive(buf), backend=backend)
    assert _plane_sets(st) == _plane_sets(fst)
    assert st.bytes_read == fst.bytes_read
    assert st.err_bound == fst.err_bound
    np.testing.assert_allclose(out, fresh, atol=1e-12)


@pytest.mark.parametrize("version", ["v1", "v2"])
def test_ladder_bit_identical_across_backends(version):
    """The same ladder stepped on numpy and jax: every rung bit-identical."""
    x, buf = _archive(version)
    rn, rj = open_archive(buf), open_archive(buf)
    sn = sj = None
    for E in LADDER:
        on, sn = retrieve(rn, error_bound=E, state=sn, backend="numpy")
        oj, sj = retrieve(rj, error_bound=E, state=sj, backend="jax")
        assert np.array_equal(on, oj)
        assert sn.err_bound == sj.err_bound
        assert sn.bytes_read == sj.bytes_read


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_refine_api_monotone_bitrate(backend):
    """refine() under growing byte budgets: error monotone non-increasing,
    volume monotone non-decreasing."""
    x = smooth_field((64, 48), 12)
    buf = compress(x, 1e-7, CUBIC)
    out, st = retrieve(buf, bitrate=0.25, backend=backend)
    prev_err, prev_bytes = st.err_bound, st.bytes_read
    for bpp in (0.5, 1.0, 2.0):
        out, st = refine(st, bitrate=bpp, backend=backend)
        assert st.err_bound <= prev_err
        assert st.bytes_read >= prev_bytes
        prev_err, prev_bytes = st.err_bound, st.bytes_read
