"""The v2 -> v3 repack path: byte-identity, bit parity, and the CLI
(ISSUE 9 tentpole d; ROADMAP item 5 residual).
"""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from _fields import smooth_field
from repro.api import Archive, Codec, CorruptArchiveError, Fidelity
from repro.repack import main, repack

X = smooth_field((60, 40), seed=7)
EB = 1e-5
V2 = Codec(eb=EB, chunk_elems=600).compress(X).tobytes()
V3 = Codec(eb=EB, chunk_elems=600, version=3).compress(X).tobytes()
V1 = Codec(eb=EB).compress(X).tobytes()


def test_repack_v2_is_byte_identical_to_native_v3():
    """repack moves blobs through the same write_v3_archive the encoder
    uses: given the same chunking, the outputs are the same bytes."""
    assert repack(V2) == V3


def test_repack_output_is_valid_v3_with_bit_identical_full_read():
    out = repack(V2)
    a = Archive.frombytes(out)                    # parses + validates
    assert a.version == 3 and a.n_chunks == Archive.frombytes(V2).n_chunks
    assert np.array_equal(a.open().read(Fidelity.full()),
                          Archive.frombytes(V2).open().read(Fidelity.full()))


def test_repack_v1_single_chunk_grid():
    out = repack(V1)
    a = Archive.frombytes(out)
    assert a.version == 3 and a.n_chunks == 1
    assert np.array_equal(a.open().read(Fidelity.full()),
                          Archive.frombytes(V1).open().read(Fidelity.full()))


def test_repacked_archive_ladders_monotone():
    """The upgraded layout delivers the v3 access pattern, not just v3
    framing."""
    from repro.core.bytesource import CountingSource
    cs = CountingSource(repack(V2))
    s = Archive.from_source(cs).open()
    he = Archive.frombytes(repack(V2))._meta.header_end
    for E in (1e-1, 1e-2, 1e-3, 1e-4):
        out = s.read(Fidelity.error_bound(E))
        assert np.abs(out - X).max() <= E
    assert cs.monotone()
    data = [r for r in cs.requests if r[0] >= he]
    runs = CountingSource(b"")
    runs.requests = data
    assert len(runs.coalesced()) == 1


def test_repack_rejects_v3_input():
    with pytest.raises(ValueError, match="already"):
        repack(V3)


def test_repack_rejects_garbage():
    with pytest.raises(CorruptArchiveError):
        repack(b"NOPE" + bytes(64))
    with pytest.raises(CorruptArchiveError):
        repack(V2[:40])                            # truncated header


# ----------------------------------------------------------------- the CLI

def test_cli_roundtrip(tmp_path: Path):
    src, dst = tmp_path / "in.ipc2", tmp_path / "out.ipc3"
    src.write_bytes(V2)
    assert main([str(src), str(dst), "--verify"]) == 0
    assert dst.read_bytes() == V3


def test_cli_rejects_bad_input(tmp_path: Path, capsys):
    src, dst = tmp_path / "in.ipc3", tmp_path / "out.ipc3"
    src.write_bytes(V3)
    assert main([str(src), str(dst)]) == 2
    assert not dst.exists()
    assert "already" in capsys.readouterr().err


def test_cli_module_entrypoint(tmp_path: Path):
    """`python -m repro.repack` works as documented."""
    src, dst = tmp_path / "in.ipc2", tmp_path / "out.ipc3"
    src.write_bytes(V2)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.repack", str(src), str(dst)],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(Path(__file__).resolve().parents[1] / "src"),
             "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert proc.returncode == 0, proc.stderr
    assert dst.read_bytes() == V3
    assert "->" in proc.stdout
