"""In-process HTTP range server with a scripted fault schedule.

The network-lane test harness: a ``ThreadingHTTPServer`` on a loopback
ephemeral port serving ONE byte payload with real HTTP/1.1 semantics —
``HEAD`` (Content-Length), ``GET`` with ``Range`` (206 + Content-Range),
``GET`` without (200 full body) — so ``HTTPSource`` is exercised over an
actual socket, not a mock.

Faults are scripted per GET index (HEADs don't consume indices), making
every retry path deterministic:

* ``drop``       — close the connection without any response;
* ``truncate``   — send honest 206 headers, then only ``arg`` body bytes;
* ``stall``      — sleep ``arg`` seconds before responding (client
                   timeouts fire; keep ``arg`` > the client timeout);
* ``status``     — respond ``arg`` (e.g. 500/503) with an empty body;
* ``ignore_range`` — answer 200 with the full body as if ``Range`` were
                   never sent.

``server.log`` records every request as ``(method, range | None)`` —
the ground truth behind "exactly one Range request per rung" — and
``server.stop()`` + ``RangeHTTPServer(payload, port=old_port)`` models
a server restart on the same port mid-ladder (``allow_reuse_address``
makes the rebind immediate).

Usage::

    with serve(payload, faults=[ServerFault("drop", at=2)]) as srv:
        src = HTTPSource(srv.url, timeout=0.5, backoff=0.01)
        ...
        assert [r for m, r in srv.log if m == "GET"] == [...]
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import List, Optional, Tuple


@dataclass
class ServerFault:
    """One scripted server-side fault, firing on GET number ``at``
    (0-based, in arrival order); ``persist=True`` fires from ``at``
    onward (a server that stays broken)."""
    kind: str                    # drop | truncate | stall | status | ignore_range
    at: int
    arg: Optional[float] = None  # truncate: body bytes; stall: secs; status: code
    persist: bool = False

    def __post_init__(self):
        kinds = ("drop", "truncate", "stall", "status", "ignore_range")
        if self.kind not in kinds:
            raise ValueError(f"unknown server fault kind {self.kind!r}")


class RangeHTTPServer:
    """Threaded loopback range server over one immutable payload."""

    def __init__(self, payload: bytes,
                 faults: Optional[List[ServerFault]] = None, port: int = 0):
        self.payload = bytes(payload)
        self.faults: List[ServerFault] = list(faults or [])
        self.log: List[Tuple[str, Optional[Tuple[int, int]]]] = []
        self._gets = 0
        self._lock = threading.Lock()
        owner = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def log_message(self, *a):  # keep pytest output clean
                pass

            def do_HEAD(self):
                with owner._lock:
                    owner.log.append(("HEAD", None))
                self.send_response(200)
                self.send_header("Accept-Ranges", "bytes")
                self.send_header("Content-Length", str(len(owner.payload)))
                self.end_headers()

            def do_GET(self):
                rng = self._parse_range()
                with owner._lock:
                    owner.log.append(("GET", rng))
                    idx = owner._gets
                    owner._gets += 1
                    fault = next(
                        (f for f in owner.faults
                         if f.at == idx or (f.persist and idx >= f.at)),
                        None)
                if fault is not None and fault.kind == "stall":
                    time.sleep(1.0 if fault.arg is None else fault.arg)
                    fault = None  # then answer normally
                if fault is not None:
                    return self._apply_fault(fault, rng)
                if rng is None:
                    return self._send_full()
                return self._send_range(*rng)

            # ---- plumbing

            def _parse_range(self):
                h = self.headers.get("Range", "")
                if not h.startswith("bytes="):
                    return None
                lo, _, hi = h[len("bytes="):].partition("-")
                try:
                    start = int(lo)
                    end = int(hi) if hi else len(owner.payload) - 1
                except ValueError:
                    return None
                return (start, end)

            def _send_full(self):
                body = owner.payload
                self.send_response(200)
                self.send_header("Accept-Ranges", "bytes")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def _send_range(self, start, end):
                total = len(owner.payload)
                if start >= total:
                    self.send_response(416)
                    self.send_header("Content-Range", f"bytes */{total}")
                    self.send_header("Content-Length", "0")
                    self.end_headers()
                    return
                end = min(end, total - 1)
                body = owner.payload[start: end + 1]
                self.send_response(206)
                self.send_header("Content-Range",
                                 f"bytes {start}-{end}/{total}")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def _apply_fault(self, fault, rng):
                if fault.kind == "drop":
                    # no response at all: the client sees a reset/empty
                    # status line and classifies it retryable
                    self.close_connection = True
                    try:
                        self.connection.close()
                    except OSError:
                        pass
                    return
                if fault.kind == "status":
                    code = int(500 if fault.arg is None else fault.arg)
                    self.send_response(code)
                    self.send_header("Content-Length", "0")
                    self.end_headers()
                    return
                if fault.kind == "ignore_range":
                    return self._send_full()
                # truncate: honest headers, short body, dead connection
                total = len(owner.payload)
                start, end = rng if rng else (0, total - 1)
                end = min(end, total - 1)
                body = owner.payload[start: end + 1]
                keep = int(len(body) // 2 if fault.arg is None else fault.arg)
                self.send_response(206 if rng else 200)
                if rng:
                    self.send_header("Content-Range",
                                     f"bytes {start}-{end}/{total}")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body[:keep])
                self.close_connection = True
                try:
                    self.connection.close()
                except OSError:
                    pass

        class _QuietServer(ThreadingHTTPServer):
            daemon_threads = True

            def handle_error(self, request, client_address):
                # injected stalls/timeouts make clients hang up mid-write
                # by design; the default handler would spam tracebacks
                pass

        self._httpd = _QuietServer(("127.0.0.1", port), Handler)
        self.port = self._httpd.server_address[1]
        self.url = f"http://127.0.0.1:{self.port}/archive"
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name=f"range-server:{self.port}",
                                        daemon=True)
        self._thread.start()

    @property
    def n_gets(self) -> int:
        with self._lock:
            return self._gets

    def get_ranges(self) -> List[Optional[Tuple[int, int]]]:
        """The Range tuples of every GET so far, in arrival order."""
        with self._lock:
            return [r for m, r in self.log if m == "GET"]

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)


class serve:
    """Context manager: ``with serve(payload, faults=...) as srv``."""

    def __init__(self, payload: bytes,
                 faults: Optional[List[ServerFault]] = None, port: int = 0):
        self._args = (payload, faults, port)

    def __enter__(self) -> RangeHTTPServer:
        self.server = RangeHTTPServer(*self._args)
        return self.server

    def __exit__(self, *exc) -> None:
        self.server.stop()
