"""The ExecPolicy structural guarantee, as a matrix.

``ExecPolicy`` bundles exactly the knobs that may never change archive
bytes or reconstruction bits — backend substrate, chunk batching, mesh
sharding.  This suite drives the *new* object API (Codec / Archive /
ProgressiveReader) across the full policy matrix on v1 and v2 archives
and pins:

  * byte-identical archives from ``Codec.compress`` under every policy;
  * bit-identical reconstructions and refine deltas from
    ``ProgressiveReader`` under every policy, at every fidelity kind;
  * identical progressive accounting (bytes_read, achieved_bound).

Runs warning-clean by construction (no legacy shims are touched); the CI
deprecation lane enforces that with
``-W error::repro.api.IPCompDeprecationWarning``.
"""
import numpy as np
import pytest

from _fields import smooth_field
from repro import Archive, Codec, ExecPolicy, Fidelity

X = smooth_field((50, 41), seed=3)
V1 = Codec(eb=1e-5)
V2 = Codec(eb=1e-5, chunk_elems=400)   # several equal slabs + ragged tail


def _policies():
    """The matrix: backend x batch_chunks x shard, plus an explicit
    single-device mesh (valid everywhere a mesh is representable)."""
    pols = [ExecPolicy()]                                    # the default
    for backend in ("numpy", "jax"):
        for batch in (None, True, False):
            pols.append(ExecPolicy(backend=backend, batch_chunks=batch))
        pols.append(ExecPolicy(backend=backend, shard="auto"))
    import jax  # noqa: F401  (explicit mesh needs a device)
    from repro.parallel import codec_mesh
    pols.append(ExecPolicy(backend="jax", shard=codec_mesh.codec_mesh(1)))
    pols.append(ExecPolicy(backend="numpy",
                           shard=codec_mesh.codec_mesh(1)))  # falls back
    return pols


POLICIES = _policies()
_IDS = [f"{p.backend}-b{p.batch_chunks}-s{getattr(p.shard, 'shape', p.shard)}"
        for p in POLICIES]

LADDER = (Fidelity.error_bound(1e-2), Fidelity.max_bytes(2500),
          Fidelity.bitrate(4.0), Fidelity.full())


def _session_trace(codec, policy):
    """Compress + a full progressive session under one policy ->
    (archive bytes, [(data, bytes_read, achieved_bound) per rung])."""
    arc = codec.compress(X, policy=policy)
    session = arc.open(policy)
    trace = []
    for fid, out in session.ladder(LADDER):
        trace.append((out.copy(), session.bytes_read,
                      session.achieved_bound))
    return arc.tobytes(), trace


# reference: the numpy default policy, computed once per codec
_REF = {c: _session_trace(c, ExecPolicy()) for c in (V1, V2)}


@pytest.mark.parametrize("policy", POLICIES, ids=_IDS)
@pytest.mark.parametrize("codec", [V1, V2], ids=["v1", "v2"])
def test_policy_never_changes_bytes_or_bits(codec, policy):
    if codec.chunk_elems is None and policy.shard is not None \
            and policy.shard != "auto":
        pytest.skip("explicit mesh on a v1 archive raises by contract "
                    "(covered in test_object_api)")
    ref_bytes, ref_trace = _REF[codec]
    got_bytes, got_trace = _session_trace(codec, policy)
    assert got_bytes == ref_bytes, "archive bytes depend on ExecPolicy"
    for (out, rd, bound), (rout, rrd, rbound) in zip(got_trace, ref_trace):
        assert np.array_equal(out, rout), \
            "reconstruction bits depend on ExecPolicy"
        assert rd == rrd and bound == rbound, \
            "progressive accounting depends on ExecPolicy"


def test_mixed_policy_session_equals_fixed_policy_session():
    """Swapping the policy between rungs of one session is invisible in
    the bits: the state is policy-agnostic by design."""
    arc = V2.compress(X)
    fixed = arc.open(ExecPolicy())
    mixed = arc.open(ExecPolicy())
    swaps = (ExecPolicy(backend="jax"), ExecPolicy(batch_chunks=False),
             ExecPolicy(backend="jax", shard="auto"), ExecPolicy())
    for fid, pol in zip(LADDER, swaps):
        mixed.policy = pol
        assert np.array_equal(fixed.read(fid), mixed.read(fid))
        assert fixed.bytes_read == mixed.bytes_read
        assert fixed.achieved_bound == mixed.achieved_bound


@pytest.mark.parametrize("policy",
                         [ExecPolicy(), ExecPolicy(backend="jax"),
                          ExecPolicy(backend="jax", batch_chunks=True)],
                         ids=["default", "jax", "jax-batched"])
@pytest.mark.parametrize("codec", [V1, V2], ids=["v1", "v2"])
def test_plane_cache_never_changes_bits(codec, policy):
    """Caching is an ExecPolicy-class concern: a shared plane cache (the
    serving tier's cross-session reuse, ``repro.serving.PlaneCache``)
    must never change reconstruction bits or achieved bounds — only
    ``bytes_read`` may shrink, when a hit skips already-decoded plane
    fetches."""
    from repro.serving import PlaneCache
    ref_bytes, ref_trace = _REF[codec]
    cache = PlaneCache()
    arc = Archive.frombytes(ref_bytes)
    arc.open(policy, plane_cache=cache).read(Fidelity.full())  # warm peer
    session = arc.open(policy, plane_cache=cache)
    for fid, (rout, rrd, rbound) in zip(LADDER, ref_trace):
        out = session.read(fid)
        assert np.array_equal(out, rout), \
            "reconstruction bits depend on the plane cache"
        assert session.achieved_bound == rbound, \
            "achieved bound depends on the plane cache"
        assert session.bytes_read <= rrd, \
            "a cache hit may only shrink bytes_read"
    assert cache.hits > 0, "the warmed cache must actually serve the session"


def test_writer_reader_policy_independence():
    """An archive written under any policy is read identically under any
    other (the format records nothing about the writer's policy)."""
    arc_np = V2.compress(X, policy=ExecPolicy(backend="numpy"))
    arc_jx = V2.compress(X, policy=ExecPolicy(backend="jax",
                                              batch_chunks=True))
    assert arc_np == arc_jx
    out_np = arc_jx.open(ExecPolicy(backend="numpy")).read(
        Fidelity.error_bound(1e-3))
    out_jx = arc_np.open(ExecPolicy(backend="jax")).read(
        Fidelity.error_bound(1e-3))
    assert np.array_equal(out_np, out_jx)
