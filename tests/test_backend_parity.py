"""numpy vs jax (Pallas) compression backend parity.

The jax backend must be a drop-in: same archive bytes, same decode, same
escape channel, across dims/interps/dtypes — including the adversarial
regimes that historically broke bit-exactness (fma contraction on rough
data, int32 wrap/saturation at escape outliers, kernel pad-region
truncation in the bitplane packer).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container has no hypothesis; vendored fallback
    from _hypothesis_shim import given, settings, strategies as st

from _fields import smooth_field
from repro.core import (CUBIC, LINEAR, compress, decompress, jax_backend,
                        metrics, retrieve)
from repro.core import bitplane as bp
from repro.core import negabinary as nbmod


# ------------------------------------------------------- archive parity

@pytest.mark.parametrize("shape", [(257,), (33, 41), (17, 13, 11)])
@pytest.mark.parametrize("interp", [LINEAR, CUBIC])
def test_archives_byte_identical_smooth(shape, interp):
    x = smooth_field(shape)
    eb = 1e-4 * (x.max() - x.min())
    a = compress(x, eb, interp, backend="numpy")
    b = compress(x, eb, interp, backend="jax")
    assert a == b
    xa, xb = decompress(a), decompress(b)
    assert np.array_equal(xa, xb)
    assert metrics.linf(x, xb) <= eb


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(0, 10 ** 6),
       st.sampled_from([LINEAR, CUBIC]),
       st.floats(1e-5, 1e-1))
def test_archives_byte_identical_property(ndim, seed, interp, rel_eb):
    """Rough random data + large relative eb: the fma-sensitive regime."""
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(2, [160, 30, 14][ndim - 1]))
                  for _ in range(ndim))
    x = rng.standard_normal(shape) * rng.uniform(0.1, 100)
    eb = rel_eb * (x.max() - x.min())
    a = compress(x, eb, interp, backend="numpy")
    b = compress(x, eb, interp, backend="jax")
    assert a == b
    assert np.array_equal(decompress(a), decompress(b))


def test_archives_byte_identical_with_escapes():
    """Outliers exercise the int32 wrap/saturate path of the kernel bins."""
    x = smooth_field((40, 40), 1)
    x[13, 17] = 1e15
    x[0, 0] = -1e15
    eb = 1e-7
    with np.errstate(invalid="ignore"):
        a = compress(x, eb, CUBIC, backend="numpy")
    b = compress(x, eb, CUBIC, backend="jax")
    assert a == b
    assert metrics.linf(x, decompress(b)) <= eb


def test_archives_byte_identical_f32_and_chunked():
    x = smooth_field((50, 60), 2).astype(np.float32)
    a = compress(x, 1e-3, backend="numpy")
    b = compress(x, 1e-3, backend="jax")
    assert a == b
    assert decompress(b).dtype == np.float32
    y = smooth_field((96, 50), 3)
    a = compress(y, 1e-5, CUBIC, backend="numpy", chunk_elems=1000)
    b = compress(y, 1e-5, CUBIC, backend="jax", chunk_elems=1000)
    assert a == b


def test_jax_archive_readable_by_numpy_retrieve():
    """Cross-backend progressive read: jax-written, numpy-planned/decoded."""
    x = smooth_field((48, 48))
    buf = compress(x, 1e-6, CUBIC, backend="jax")
    for E in (1e-2, 1e-4):
        out, state = retrieve(buf, error_bound=E)
        assert metrics.linf(x, out) <= E
        assert 0 < state.bytes_read < len(buf)


def test_backend_resolve():
    assert jax_backend.resolve("numpy") == "numpy"
    assert jax_backend.resolve("jax") == "jax"
    assert jax_backend.resolve(None) in ("numpy", "jax")
    assert jax_backend.resolve("auto") == jax_backend.resolve(None)
    with pytest.raises(ValueError):
        jax_backend.resolve("cuda")


# ------------------------------------------- bitplane_pack blob parity

def _enc_parity(q):
    q = np.asarray(q, np.int64)
    nb = nbmod.to_negabinary(q)
    want = bp.encode_level(nb)
    got = jax_backend.encode_level(q)
    assert got[1] == want[1], "nbits mismatch"
    assert got[0] == want[0], "blob mismatch"


@pytest.mark.parametrize("n", [1, 7, 255, 256, 4095, 4096, 4097, 8192 + 3])
def test_encode_level_padding_edges(n):
    """n not a multiple of ROWS_B*GROUP: pad region must not leak into blobs."""
    rng = np.random.default_rng(n)
    _enc_parity(rng.integers(-(1 << 20), 1 << 20, n))


def test_encode_level_nbits_zero():
    _enc_parity(np.zeros(100, np.int64))        # all-zero: ([], 0)
    assert jax_backend.encode_level(np.zeros(0, np.int64)) == ([], 0)


def test_encode_level_all_zero_middle_plane():
    """A zero XOR-plane below the MSB must produce the b'' blob convention."""
    # nb(5) = 0b101 -> enc = 0b110: plane 0 all-zero, planes 1-2 set
    _enc_parity(np.full(500, 5, np.int64))


def test_encode_level_extreme_bins():
    """Bins at the QMAX boundary occupy all 32 negabinary digits."""
    rng = np.random.default_rng(0)
    q = rng.integers(-(1 << 30), 1 << 30, 3000)
    q[0], q[1] = (1 << 30), -(1 << 30)
    _enc_parity(q)


@given(st.lists(st.integers(-(1 << 30), 1 << 30), min_size=1, max_size=400))
def test_encode_level_property(vals):
    _enc_parity(np.array(vals, np.int64))
