"""FaultInjectingSource unit contract + the no-wrong-bytes property.

The property half is the point of the harness (ISSUE 9 satellite):
*random* fault schedules hammered against the full decode pipeline must
never yield a wrong-bytes reconstruction — every outcome is either
correct data (the fidelity's bound holds) or a raised /
structured-``partial`` failure.  Runs under real hypothesis when
installed, else the vendored shim.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from _fields import smooth_field
from repro.api import Archive, Codec, CorruptArchiveError, Fidelity
from repro.core.bytesource import BufferSource
from repro.core.faults import Fault, FaultInjectingSource

X = smooth_field((48, 32), seed=11)
EB = 1e-4
V3 = Codec(eb=EB, chunk_elems=512, version=3).compress(X).tobytes()
V2 = Codec(eb=EB, chunk_elems=512).compress(X).tobytes()
V1 = Codec(eb=EB).compress(X).tobytes()

_no_sleep = lambda s: None  # noqa: E731  — stalls cost zero wall clock


# ----------------------------------------------------------- unit contract

def test_passthrough_is_byte_identical():
    fif = FaultInjectingSource(V3)
    assert bytes(fif.read(0, 64)) == V3[:64]
    assert fif.size == len(V3)
    assert fif.calls == 1 and fif.fired == []


def test_error_fault_fires_once_at_index():
    fif = FaultInjectingSource(V3, schedule=[Fault("error", at=1)])
    fif.read(0, 4)
    with pytest.raises(ConnectionError, match="injected"):
        fif.read(4, 4)
    assert bytes(fif.read(4, 4)) == V3[4:8]        # next call is clean
    assert [f.kind for f in fif.fired] == ["error"]


def test_persistent_fault_stays_down():
    fif = FaultInjectingSource(V3, schedule=[Fault("error", at=2,
                                                   persist=True)])
    fif.read(0, 4)
    fif.read(4, 4)
    for _ in range(3):
        with pytest.raises(ConnectionError):
            fif.read(8, 4)


def test_truncate_fault_returns_short():
    fif = FaultInjectingSource(V3, schedule=[Fault("truncate", at=0, arg=3)])
    assert bytes(fif.read(0, 10)) == V3[:3]


def test_stall_fault_sleeps_then_succeeds():
    slept = []
    fif = FaultInjectingSource(V3, sleep=slept.append,
                               schedule=[Fault("stall", at=0, arg=0.5)])
    assert bytes(fif.read(0, 8)) == V3[:8]
    assert slept == [0.5]


def test_arm_resolves_to_next_call():
    fif = FaultInjectingSource(V3)
    fif.read(0, 4)
    f = fif.arm(Fault("error"))
    assert f.at == 1
    with pytest.raises(ConnectionError):
        fif.read(4, 4)


def test_schedule_requires_explicit_index():
    with pytest.raises(ValueError, match="at"):
        FaultInjectingSource(V3, schedule=[Fault("error")])
    with pytest.raises(ValueError, match="kind"):
        Fault("explode")


# ------------------------------------------------- short-read => corrupt

@pytest.mark.parametrize("buf", [V1, V2, V3], ids=["v1", "v2", "v3"])
def test_persistent_truncation_surfaces_as_corrupt_archive(buf):
    """A source that always returns short must surface as
    CorruptArchiveError at some boundary — never as struct/json noise,
    never as garbage data."""
    fif = FaultInjectingSource(
        buf, schedule=[Fault("truncate", at=0, arg=2, persist=True)])
    with pytest.raises(CorruptArchiveError):
        Archive.from_source(fif).open().read()


# -------------------------------------------------- the no-wrong-bytes law

def _outcome(buf, schedule, fidelity):
    """Run one retrieval through a faulted source; classify the result."""
    fif = FaultInjectingSource(BufferSource(buf), schedule=schedule,
                               sleep=_no_sleep)
    try:
        out = Archive.from_source(fif).open().read(fidelity)
    except (OSError, CorruptArchiveError, ValueError) as e:
        return ("raised", type(e).__name__, fif)
    return ("data", out, fif)


@settings(max_examples=25)
@given(
    st.sampled_from(["v1", "v2", "v3"]),
    st.lists(st.sampled_from(["error", "truncate", "stall"]),
             min_size=0, max_size=6),
    st.lists(st.integers(0, 40), min_size=6, max_size=6),
    st.integers(0, 2),
)
def test_random_fault_schedules_never_yield_wrong_bytes(
        version, kinds, positions, e_idx):
    """THE invariant: any schedule of errors/truncations/stalls produces
    either a reconstruction honoring the requested bound, or a raised
    failure — silent corruption is impossible."""
    buf = {"v1": V1, "v2": V2, "v3": V3}[version]
    E = [1e-1, 1e-3, EB][e_idx]
    schedule = [Fault(k, at=p, arg=2 if k == "truncate" else 0)
                for k, p in zip(kinds, positions)]
    kind, payload, fif = _outcome(buf, schedule, Fidelity.error_bound(E))
    if kind == "data":
        assert np.abs(payload - X).max() <= E, \
            f"wrong bytes past {len(fif.fired)} faults: {schedule}"
    # "raised" is always acceptable — never wrong data


@settings(max_examples=10)
@given(
    st.lists(st.integers(0, 60), min_size=1, max_size=4),
)
def test_random_faults_in_refine_chain_never_corrupt(positions):
    """Faults landing mid-ladder: every successful rung of a refine
    chain still honors its bound, whatever failed before it."""
    fif = FaultInjectingSource(
        BufferSource(V3),
        schedule=[Fault("error", at=p) for p in positions],
        sleep=_no_sleep)
    try:
        session = Archive.from_source(fif).open()
    except (OSError, CorruptArchiveError):
        return
    for E in (1e-1, 1e-2, 1e-3, EB):
        try:
            out = session.read(Fidelity.error_bound(E))
        except (OSError, CorruptArchiveError):
            continue
        assert np.abs(out - X).max() <= E
