"""Fused decode megakernel + dynamic plane prefixes: acceptance suite.

The bar for the fused progressive-decode path: routing the jax backend's
retrieval through ``decode_level_fused`` (plane-unpack + negabinary
dequantize + Algorithm 2 delta in ONE launch per level) and grouping chunk
decode jobs by ``(nbits,)`` alone — the loaded-prefix length is a runtime
kernel operand now — must be bit-identical to both the pre-fusion jax path
(registered as the ``jax_unfused`` backend) and the numpy reference, on v1
and chunked v2 archives, across escapes, mixed per-chunk prefixes,
refine-after-retrieve interleaves, and mesh sharding.  And it must be
strictly CHEAPER: fewer kernel dispatches than the ``(nbits, prefix)``
grouping produced.
"""
import jax
import numpy as np
import pytest

from _fields import smooth_field
from repro.core import (CUBIC, compress, decompress, metrics, open_archive,
                        refine, retrieve)
from repro.core import jax_backend
from repro.core.pipeline import backends
from repro.kernels import dispatch
from repro.parallel import codec_mesh

N_DEV = jax.device_count()


def _chunky_field(shape=(50, 41), seed=0, rough=0.01):
    rng = np.random.default_rng(seed)
    return smooth_field(shape, seed) + rough * rng.standard_normal(shape)


# ----------------------------------------------------- backend registration

def test_fused_backend_slots():
    """jax ships the fused family + dynamic grouping; jax_unfused is the
    same encode side with the pre-fusion decode, numpy has neither."""
    jx = backends.get("jax")
    assert jx.decode_level_fused is not None
    assert jx.decode_level_fused_batch is not None
    assert jx.inflate_level is not None and jx.inflate_level_batch is not None
    assert jx.dynamic_low_zero
    unf = backends.get("jax_unfused")
    assert unf.decode_level_fused is None
    assert not unf.dynamic_low_zero
    assert unf.decorrelate is jx.decorrelate  # shared encode side
    np_ = backends.get("numpy")
    assert np_.decode_level_fused is None and not np_.dynamic_low_zero
    # registered names are ExecPolicy-selectable
    assert "jax_unfused" in backends.names()


# ------------------------------------------------- kernel-level bit parity

@pytest.mark.parametrize("nprev,want", [(0, 3), (2, 5), (5, 5), (3, 11)])
def test_decode_level_fused_matches_unfused(nprev, want):
    """One fused launch == unfused decode + three host passes, bit for
    bit, at every (previous prefix, new prefix) rung."""
    from repro.core import negabinary

    rng = np.random.default_rng(nprev * 16 + want)
    q = rng.integers(-900, 900, size=1023).astype(np.int64)
    blobs, nbits = jax_backend.encode_level(q)
    eb = 3.7e-4
    prev = [blobs[i] if i < min(nprev, nbits) else None for i in range(nbits)]
    cur = [blobs[i] if i < min(want, nbits) else None for i in range(nbits)]
    nb_old = jax_backend.decode_level(prev, nbits, q.size)
    nb_ref = jax_backend.decode_level(cur, nbits, q.size)
    dq = negabinary.from_negabinary(nb_ref) - negabinary.from_negabinary(nb_old)
    dy_ref = dq.astype(np.float64) * 2.0 * eb
    with dispatch.measure() as d:
        nb_new, dy = jax_backend.decode_level_fused(cur, nbits, q.size,
                                                    nb_old, eb)
    assert np.array_equal(nb_new, nb_ref)
    assert np.array_equal(dy, dy_ref)
    assert d.get("decode_fused", 0) == 1


def test_decode_level_fused_batch_mixed_prefixes_and_ebs():
    """Per-chunk prefixes AND per-chunk error bounds ride one launch."""
    from repro.core import negabinary

    rng = np.random.default_rng(9)
    q = rng.integers(-500, 500, size=640).astype(np.int64)
    blobs, nbits = jax_backend.encode_level(q)
    wants = [nbits, max(1, nbits - 2), 1, 0]
    ebs = [1e-3, 2e-4, 5e-5, 1e-3]
    blob_lists = [[blobs[i] if i < w else None for i in range(nbits)]
                  for w in wants]
    olds = [jax_backend.decode_level(
        [blobs[i] if i < max(0, w - 1) else None for i in range(nbits)],
        nbits, q.size) for w in wants]
    with dispatch.measure() as d:
        outs = jax_backend.decode_level_fused_batch(blob_lists, nbits,
                                                    q.size, olds, ebs)
    assert d["decode_fused"] == 1
    for (nb_new, dy), bl, old, eb, w in zip(outs, blob_lists, olds, ebs,
                                            wants):
        nb_ref = jax_backend.decode_level(bl, nbits, q.size)
        if w == 0:  # nothing loaded: state untouched, delta zero
            assert np.array_equal(nb_new, old)
            assert not dy.any()
            continue
        dq = negabinary.from_negabinary(nb_ref) - \
            negabinary.from_negabinary(old)
        assert np.array_equal(nb_new, nb_ref)
        assert np.array_equal(dy, dq.astype(np.float64) * 2.0 * eb)


def test_inflate_level_prefetch_seam():
    """``decode_level_fused(words=...)`` consumes a pre-inflated
    ``inflate_level`` result unchanged — the two-slot prefetch seam."""
    q = np.arange(-200, 200, dtype=np.int64)
    blobs, nbits = jax_backend.encode_level(q)
    nb_old = np.zeros(q.size, np.uint32)
    direct = jax_backend.decode_level_fused(blobs, nbits, q.size, nb_old,
                                            1e-4)
    words = jax_backend.inflate_level(blobs, nbits, q.size)
    via = jax_backend.decode_level_fused(blobs, nbits, q.size, nb_old,
                                         1e-4, words=words)
    assert np.array_equal(direct[0], via[0])
    assert np.array_equal(direct[1], via[1])


# ------------------------------------------------- session-level bit parity

def test_v1_ladder_fused_vs_unfused_vs_numpy():
    """Progressive v1 ladder with escapes: every rung bit-identical across
    the three backends, byte accounting included."""
    x = smooth_field((60, 47), 2)
    x[11, 7] = 1e14  # escape
    with np.errstate(invalid="ignore"):
        buf = compress(x, 1e-6, CUBIC)
    ladders = {}
    for bk in ("numpy", "jax", "jax_unfused"):
        st, rungs = None, []
        for E in (1e-1, 1e-3, None):
            kw = {} if E is None else dict(error_bound=E)
            out, st = retrieve(open_archive(buf), state=st, backend=bk, **kw)
            rungs.append((out.copy(), st.bytes_read))
        ladders[bk] = rungs
    for bk in ("jax", "jax_unfused"):
        for (o1, b1), (o2, b2) in zip(ladders["numpy"], ladders[bk]):
            assert np.array_equal(o1, o2), bk
            assert b1 == b2, bk
    assert metrics.linf(x, ladders["jax"][-1][0]) <= 1e-6


def test_chunked_budget_ladder_fused_vs_unfused():
    """Chunked v2 + byte budgets (mixed per-chunk prefixes) + an escape
    chunk + refine-after-retrieve interleave: fused == unfused == numpy at
    every step."""
    rng = np.random.default_rng(3)
    x = smooth_field((60, 33), 1)
    x[:20] += 0.5 * rng.standard_normal((20, 33))  # chunk 0 much rougher
    x[40, 5] = -1e15                               # escape in chunk 2
    with np.errstate(invalid="ignore"):
        buf = compress(x, 1e-6, chunk_elems=700)
    outs = {}
    for bk in ("numpy", "jax", "jax_unfused"):
        out1, st = retrieve(open_archive(buf), max_bytes=4000, backend=bk)
        out2, st = refine(st, max_bytes=9000, backend=bk)
        out3, st = refine(st, backend=bk)
        outs[bk] = (out1, out2, out3, st.bytes_read)
    for bk in ("jax", "jax_unfused"):
        for a, b in zip(outs["numpy"][:3], outs[bk][:3]):
            assert np.array_equal(a, b), bk
        assert outs[bk][3] == outs["numpy"][3], bk
    assert metrics.linf(x, outs["jax"][2]) <= 1e-6


def test_fused_sharded_parity():
    """Mesh-sharded fused retrieval equals the unsharded one bit for bit
    (degenerates to 1 device gracefully; CI's 8-device lane exercises the
    real fan-out)."""
    x = _chunky_field((48, 41))
    buf = compress(x, 1e-5, chunk_elems=500)
    mesh = codec_mesh.codec_mesh()
    a, sa = retrieve(open_archive(buf), error_bound=1e-3, backend="jax")
    b, sb = retrieve(open_archive(buf), error_bound=1e-3, backend="jax",
                     shard=mesh)
    assert np.array_equal(a, b)
    assert sa.bytes_read == sb.bytes_read


# ------------------------------------------------- dispatch-count collapse

def test_dynamic_grouping_fewer_dispatches_than_per_prefix():
    """The tentpole's scheduling win, in the serving shape that exposes
    it: sessions over the SAME archive bytes (equal nbits) targeting
    DIFFERENT fidelities want different plane prefixes.  The old
    (nbits, prefix) grouping fragments each level into one launch per
    distinct prefix; the (nbits,) grouping runs ONE fused launch per
    level — strictly fewer dispatches, same bits per session."""
    from repro.core import loader
    from repro.core.pipeline.decode import decode_group
    from repro.core.pipeline.spec import ExecPolicy

    x = smooth_field((48, 41), 4)
    buf = compress(x, 1e-6)
    bounds = (1e-1, 1e-3, 1e-5)
    results = {}
    for bk in ("jax_unfused", "jax"):
        readers = [open_archive(buf) for _ in bounds]
        keeps = [loader.plan_error_mode(r.meta, E, loader.SAFE).keep_planes
                 for r, E in zip(readers, bounds)]
        assert len({tuple(k) for k in keeps}) == 3  # genuinely mixed
        ctx = ExecPolicy(backend=bk).bind(chunked=False, encode=False)
        with dispatch.measure() as d:
            sts = decode_group(readers, [None] * len(readers), keeps, ctx)
        results[bk] = ([st.xhat.copy() for st in sts], dict(d))
    for a, b in zip(results["jax"][0], results["jax_unfused"][0]):
        assert np.array_equal(a, b)
    d_new, d_old = results["jax"][1], results["jax_unfused"][1]
    # per-prefix grouping launched one unpack per distinct prefix per
    # level; dynamic grouping runs one fused launch per populated level
    assert d_new["decode_fused"] < d_old["bitplane_unpack"]
    assert sum(d_new.values()) < sum(d_old.values())


def test_refine_interleave_dispatch_and_bits():
    """Refine-after-retrieve on the fused path: deltas decode through the
    same fused launches, nothing is re-read, bits match the unfused path."""
    x = _chunky_field((50, 41))
    buf = compress(x, 1e-6, chunk_elems=500)
    outs = {}
    for bk in ("jax", "jax_unfused"):
        out1, st = retrieve(open_archive(buf), error_bound=1e-2, backend=bk,
                            batch_chunks=True)
        with dispatch.measure() as d:
            out2, st = refine(st, error_bound=1e-4, backend=bk,
                              batch_chunks=True)
        prev = st.bytes_read
        out3, st = refine(st, error_bound=1e-4, backend=bk,
                          batch_chunks=True)
        assert st.bytes_read == prev  # nothing re-read
        outs[bk] = (out1, out2, out3, d)
    for a, b in zip(outs["jax"][:3], outs["jax_unfused"][:3]):
        assert np.array_equal(a, b)
    assert outs["jax"][3]["decode_fused"] <= \
        outs["jax_unfused"][3]["bitplane_unpack"]


def test_fused_records_kernel_bytes():
    """The roofline report reads bytes-moved per dispatch: the fused path
    must account its traffic."""
    x = smooth_field((40, 40), 5)
    buf = compress(x, 1e-5)
    with dispatch.measure_bytes() as nb:
        retrieve(open_archive(buf), error_bound=1e-3, backend="jax")
    assert nb.get("decode_fused", 0) > 0
    assert nb.get("interp_recon", 0) > 0
