"""Plane-cache behaviour: LRU accounting, eviction, and the structural
invariant that caching never changes reconstruction bits.

The cache stores decoded truncated-negabinary prefixes — deterministic
functions of (archive bytes, level, prefix) — so sharing them across
sessions is an execution detail: hits may shrink a session's
``bytes_read`` (the serving win) but bits and achieved bounds are
untouchable (see also the policy-matrix extension in
``test_policy_matrix.py``).
"""
import numpy as np
import pytest

from _fields import smooth_field
from repro import Codec, ExecPolicy, Fidelity
from repro.serving import PlaneCache

X = smooth_field((48, 40), seed=9)
V1 = Codec(eb=1e-5)
V2 = Codec(eb=1e-5, chunk_elems=512)

LADDER = (Fidelity.error_bound(1e-2), Fidelity.error_bound(1e-4),
          Fidelity.full())


def _arr(nbytes, fill=1):
    return np.full(nbytes // 4, fill, np.uint32)


# ---- unit behaviour of the LRU map

def test_get_put_roundtrip_and_stats():
    c = PlaneCache()
    assert c.get("k") is None
    a = _arr(64)
    c.put("k", a)
    got = c.get("k")
    assert got is a
    assert c.hits == 1 and c.misses == 1
    assert c.hit_bytes == a.nbytes
    assert c.bytes_cached == a.nbytes
    assert c.hit_rate == 0.5
    s = c.stats()
    assert s["entries"] == 1 and s["insertions"] == 1


def test_duplicate_put_is_idempotent():
    c = PlaneCache()
    c.put("k", _arr(64))
    c.put("k", _arr(64, fill=2))  # decode is deterministic: ignored
    assert int(c.get("k")[0]) == 1
    assert c.insertions == 1 and c.bytes_cached == 64


def test_duplicate_put_refreshes_recency():
    """Re-publishing an already-cached key is a use: it refreshes the
    entry's LRU position exactly like a get()."""
    c = PlaneCache(max_bytes=256)
    for i in range(4):
        c.put(i, _arr(64, fill=i))
    c.put(0, _arr(64))            # duplicate put: 1 becomes the LRU entry
    c.put(4, _arr(64, fill=4))
    assert 1 not in c and 0 in c and 4 in c
    assert c.evictions == 1


def test_lru_eviction_under_byte_cap():
    c = PlaneCache(max_bytes=256)
    for i in range(4):
        c.put(i, _arr(64, fill=i))
    c.get(0)                      # refresh 0: 1 becomes the LRU entry
    c.put(4, _arr(64, fill=4))
    assert 1 not in c and 0 in c and 4 in c
    assert c.evictions == 1
    assert c.bytes_cached <= 256


def test_oversized_entry_not_admitted():
    c = PlaneCache(max_bytes=128)
    c.put("small", _arr(64))
    c.put("huge", _arr(512))      # would evict everything for one entry
    assert "huge" not in c and "small" in c
    assert c.bytes_cached == 64


def test_saved_fetch_accumulates():
    c = PlaneCache()
    c.saved_fetch(100)
    c.saved_fetch(23)
    assert c.fetch_bytes_saved == 123


def test_clear_keeps_lifetime_counters():
    c = PlaneCache()
    c.put("k", _arr(64))
    c.get("k")
    c.clear()
    assert len(c) == 0 and c.bytes_cached == 0
    assert c.hits == 1 and c.insertions == 1


def test_invalid_cap_rejected():
    with pytest.raises(ValueError):
        PlaneCache(max_bytes=0)


# ---- sessions sharing a cache

@pytest.mark.parametrize("codec", [V1, V2], ids=["v1", "v2"])
def test_interleaved_sessions_share_prefixes(codec):
    """Two sessions over equal archives: the second's reads hit the
    first's decoded prefixes (hit/miss accounting moves), interleaving
    rungs freely; bits and bounds match cache-off sessions exactly."""
    arc = codec.compress(X)
    cache = PlaneCache()
    a = arc.open(plane_cache=cache)
    b = arc.open(plane_cache=cache)
    ref = arc.open()
    for fid in LADDER:
        out_a = a.read(fid)
        hits_before = cache.hits
        out_b = b.read(fid)          # same prefix, decoded moments ago
        out_ref = ref.read(fid)
        assert cache.hits > hits_before
        assert np.array_equal(out_a, out_ref)
        assert np.array_equal(out_b, out_ref)
        assert a.achieved_bound == b.achieved_bound == ref.achieved_bound
    # the hitting session skipped plane fetches: strictly fewer bytes
    assert b.bytes_read < a.bytes_read == ref.bytes_read
    assert cache.fetch_bytes_saved > 0
    assert cache.hit_bytes > 0


def test_cache_entries_are_frozen():
    arc = V1.compress(X)
    cache = PlaneCache()
    arc.open(plane_cache=cache).read(Fidelity.full())
    assert len(cache) > 0
    for arr in cache._entries.values():
        assert not arr.flags.writeable


@pytest.mark.parametrize("codec", [V1, V2], ids=["v1", "v2"])
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_cache_on_off_bit_identical(codec, backend):
    """The whole ladder, cache on vs off, both backends: identical bits
    and bounds at every rung (bytes_read may only shrink with the
    cache)."""
    arc = codec.compress(X)
    policy = ExecPolicy(backend=backend)
    cache = PlaneCache()
    arc.open(policy, plane_cache=cache).read(Fidelity.full())  # warm
    on = arc.open(policy, plane_cache=cache)
    off = arc.open(policy)
    for fid in LADDER:
        assert np.array_equal(on.read(fid), off.read(fid))
        assert on.achieved_bound == off.achieved_bound
        assert on.bytes_read <= off.bytes_read


def test_eviction_during_session_keeps_bits():
    """A cache too small to hold the working set evicts mid-ladder and
    later reads decode afresh — still bit-identical."""
    arc = V2.compress(X)
    cache = PlaneCache(max_bytes=4096)
    on = arc.open(plane_cache=cache)
    off = arc.open()
    for fid in LADDER:
        assert np.array_equal(on.read(fid), off.read(fid))
    arc.open(plane_cache=cache).read(Fidelity.full())
    assert cache.evictions > 0
    assert cache.bytes_cached <= 4096


def test_distinct_archives_never_collide():
    """Different archive bytes get different cache scopes even in one
    shared cache: reads stay correct for both."""
    y = smooth_field((48, 40), seed=10)
    arc_x, arc_y = V1.compress(X), V1.compress(y)
    cache = PlaneCache()
    sx = arc_x.open(plane_cache=cache)
    sy = arc_y.open(plane_cache=cache)
    out_x, out_y = sx.read(Fidelity.full()), sy.read(Fidelity.full())
    assert np.abs(out_x - X).max() <= 1e-5
    assert np.abs(out_y - y).max() <= 1e-5
