"""IPCB checkpoint bundle format: roundtrip, parallel-encode
determinism, and the corruption/truncation integrity matrix
(every failure must raise ``CorruptArchiveError`` and name the leaf)."""
import os

import numpy as np
import pytest

from repro.checkpoint import Bundle, LeafSpec, read_full, write_bundle
from repro.checkpoint.bundle import MAGIC, encode_leaf
from repro.core.bytesource import BufferSource
from repro.core.container import CorruptArchiveError

REL_EB = 1e-4


def smooth(shape, seed=0):
    rng = np.random.default_rng(seed)
    x = np.cumsum(rng.standard_normal(shape), axis=-1)
    return x.astype(np.float32)


def make_specs():
    leaves = {
        "blocks.0.attn.w": smooth((64, 256), 1),
        "blocks.1.attn.w": smooth((64, 256), 2),
        "blocks.0.mlp.w": smooth((8, 32, 64), 3),     # ndim>2: reshaped
        "final_norm.scale": np.linspace(-1.0, 1.0, 64,
                                        dtype=np.float32),  # raw (small)
        "step_scalar": np.float32(3.5).reshape(()),         # raw (0-d)
    }
    specs = [LeafSpec(lid=k, arr=np.asarray(v, np.float32),
                      dtype=str(np.asarray(v).dtype),
                      raw_nbytes=np.asarray(v).nbytes)
             for k, v in leaves.items()]
    return leaves, specs


def write_tmp(tmp_path, name="b.ckpt", workers=1, **kw):
    leaves, specs = make_specs()
    path = os.path.join(str(tmp_path), name)
    man = write_bundle(path, specs, step=7, rel_eb=REL_EB, interp="cubic",
                       workers=workers, **kw)
    return leaves, path, man


# ------------------------------------------------------------ roundtrip

def test_bundle_roundtrip_full_precision(tmp_path):
    leaves, path, man = write_tmp(tmp_path)
    with Bundle.open(path) as b:
        assert b.step == 7 and b.leaf_order == list(leaves)
        out = read_full(b, verify=True)
    for lid, ref in leaves.items():
        got = out[lid]
        assert got.shape == np.asarray(ref).shape
        assert got.dtype == np.asarray(ref).dtype
        e = man["leaves"][lid]
        if e["kind"] == "raw":
            np.testing.assert_array_equal(got, ref)
        else:
            rng_v = float(ref.max() - ref.min())
            assert np.max(np.abs(got - ref)) <= REL_EB * rng_v * 1.001


def test_manifest_regions_tile_and_kinds(tmp_path):
    leaves, path, man = write_tmp(tmp_path)
    end = 0
    for lid in man["order"]:
        e = man["leaves"][lid]
        assert e["offset"] == end
        end += e["nbytes"]
        assert e["kind"] in ("ipc", "ipc1", "raw")
        assert len(e["sha"]) == 64 and len(e["pfx_sha"]) == 64
        assert 0 < e["pfx_size"] <= e["nbytes"]
    assert man["total_comp"] == end
    # small/scalar leaves are raw; the big smooth matrices compress
    assert man["leaves"]["final_norm.scale"]["kind"] == "raw"
    assert man["leaves"]["step_scalar"]["kind"] == "raw"
    assert man["leaves"]["blocks.0.attn.w"]["kind"] in ("ipc", "ipc1")
    assert man["leaves"]["blocks.0.attn.w"]["nbytes"] < 64 * 256 * 4


def test_parallel_encode_bytes_identical(tmp_path):
    _, p1, _ = write_tmp(tmp_path, "w1.ckpt", workers=1)
    for w in (2, 3, 5):
        _, pw, _ = write_tmp(tmp_path, f"w{w}.ckpt", workers=w)
        assert open(pw, "rb").read() == open(p1, "rb").read(), \
            f"bundle bytes differ at workers={w}"


def test_raw_fallback_for_incompressible_leaf():
    rng = np.random.default_rng(0)
    noise = (rng.random((64, 256)).astype(np.float32) * 2 - 1)
    spec = LeafSpec(lid="noise", arr=noise, dtype="float32",
                    raw_nbytes=noise.nbytes)
    entry, blob = encode_leaf(spec, rel_eb=1e-9, interp="cubic")
    assert entry["kind"] == "raw"          # honesty over format purity
    assert len(blob) == noise.nbytes
    np.testing.assert_array_equal(
        np.frombuffer(blob, np.float32).reshape(64, 256), noise)


# ------------------------------------------------------------ integrity

def _bundle_bytes(tmp_path):
    leaves, path, man = write_tmp(tmp_path)
    return leaves, man, bytearray(open(path, "rb").read())


def test_corrupted_leaf_full_read_names_leaf(tmp_path):
    _, man, buf = _bundle_bytes(tmp_path)
    b = Bundle(BufferSource(bytes(buf)))
    lid = "blocks.1.attn.w"
    off, size = b.leaf_region(lid)
    buf[off + size - 3] ^= 0xFF            # flip a byte deep in the blob
    bad = Bundle(BufferSource(bytes(buf)))
    with pytest.raises(CorruptArchiveError, match="blocks.1.attn.w"):
        bad.read_leaf_bytes(lid, verify=True)
    # other leaves still verify: corruption is isolated per leaf
    bad.read_leaf_bytes("blocks.0.attn.w", verify=True)


@pytest.mark.parametrize("lid", ["blocks.0.attn.w", "final_norm.scale"])
def test_corrupted_prefix_fails_partial_read_gate(tmp_path, lid):
    _, man, buf = _bundle_bytes(tmp_path)
    b = Bundle(BufferSource(bytes(buf)))
    off, _ = b.leaf_region(lid)
    buf[off + 1] ^= 0x01                   # inside the verified prefix
    bad = Bundle(BufferSource(bytes(buf)))
    with pytest.raises(CorruptArchiveError, match=lid.replace(".", r"\.")):
        bad.verify_leaf_prefix(lid)


def test_truncated_bundle_rejected_at_open(tmp_path):
    _, _, buf = _bundle_bytes(tmp_path)
    with pytest.raises(CorruptArchiveError, match="truncated|holds"):
        Bundle(BufferSource(bytes(buf[:-10])))
    # truncated INSIDE the manifest region
    with pytest.raises(CorruptArchiveError):
        Bundle(BufferSource(bytes(buf[:12])))


def test_bad_magic_and_garbage_manifest(tmp_path):
    _, _, buf = _bundle_bytes(tmp_path)
    with pytest.raises(CorruptArchiveError, match="IPCB"):
        Bundle(BufferSource(b"NOPE" + bytes(buf[4:])))
    bad = bytearray(buf)
    bad[8] ^= 0xFF                         # first manifest byte -> not JSON
    with pytest.raises(CorruptArchiveError):
        Bundle(BufferSource(bytes(bad)))
    assert buf[:4] == MAGIC


def test_padded_bundle_rejected(tmp_path):
    _, _, buf = _bundle_bytes(tmp_path)
    with pytest.raises(CorruptArchiveError, match="truncated or padded"):
        Bundle(BufferSource(bytes(buf) + b"\0" * 8))


def test_missing_leaf_keyerror_names_leaf(tmp_path):
    _, path, _ = write_tmp(tmp_path)
    with Bundle.open(path) as b:
        with pytest.raises(KeyError, match="no_such_leaf"):
            b.entry("no_such_leaf")
