"""Core IPComp codec: round-trip, error-bound, and progressive invariants."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container has no hypothesis; vendored fallback
    from _hypothesis_shim import given, settings, strategies as st

from _fields import smooth_field
from repro.core import (CUBIC, LINEAR, compress, decompress, metrics,
                        open_archive, retrieve)
from repro.core import negabinary as nb
from repro.core import bitplane as bp
from repro.core import loader
from repro.core.container import parse_meta


# ------------------------------------------------------------ negabinary

@given(st.lists(st.integers(-(1 << 30), 1 << 30), min_size=1, max_size=200))
def test_negabinary_roundtrip(vals):
    q = np.array(vals, np.int64)
    assert np.array_equal(nb.from_negabinary(nb.to_negabinary(q)), q)


def test_negabinary_paper_examples():
    # paper §4.4.2: 1 -> ...0001, -1 -> ...0011
    assert int(nb.to_negabinary(np.array([1]))[0]) == 0b1
    assert int(nb.to_negabinary(np.array([-1]))[0]) == 0b11
    assert int(nb.to_negabinary(np.array([-2]))[0]) == 0b10


@given(st.lists(st.integers(-(1 << 20), 1 << 20), min_size=1, max_size=64),
       st.integers(0, 24))
def test_negabinary_truncation_uncertainty(vals, d):
    """Truncating d digits perturbs the value by < (2/3)*2^d + 1 (paper formula)."""
    q = np.array(vals, np.int64)
    x = nb.to_negabinary(q)
    t = nb.from_negabinary(nb.truncate(x, d))
    bound = (2.0 / 3.0) * (1 << d)
    assert np.all(np.abs(q - t) <= bound + 1)


# ------------------------------------------------------------ bitplanes

@given(st.lists(st.integers(0, (1 << 31) - 1), min_size=1, max_size=300))
def test_bitplane_roundtrip(vals):
    x = np.array(vals, np.uint32)
    blobs, nbits = bp.encode_level(x)
    got = bp.decode_level(list(blobs), nbits, x.size)
    assert np.array_equal(got, x)


@given(st.lists(st.integers(0, (1 << 20) - 1), min_size=4, max_size=200),
       st.integers(0, 19))
def test_bitplane_prefix_decode_is_truncation(vals, keep_from_msb):
    """Loading a plane prefix must equal negabinary truncation exactly."""
    x = np.array(vals, np.uint32)
    blobs, nbits = bp.encode_level(x)
    k = min(keep_from_msb, nbits)
    part = list(blobs[:k]) + [None] * (nbits - k)
    got = bp.decode_level(part, nbits, x.size)
    assert np.array_equal(got, nb.truncate(x, nbits - k))


# ------------------------------------------------------------ round trip

@pytest.mark.parametrize("shape", [(1000,), (64, 80), (24, 37, 41)])
@pytest.mark.parametrize("interp", [LINEAR, CUBIC])
def test_roundtrip_error_bound(shape, interp):
    x = smooth_field(shape)
    eb = 1e-4 * (x.max() - x.min())
    buf = compress(x, eb, interp)
    xh = decompress(buf)
    assert metrics.linf(x, xh) <= eb
    assert len(buf) < x.nbytes  # it actually compresses smooth data


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.integers(0, 10 ** 6),
       st.sampled_from([LINEAR, CUBIC]),
       st.floats(1e-6, 1e-1))
def test_roundtrip_property(ndim, seed, interp, rel_eb):
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(2, [200, 40, 18][ndim - 1])) for _ in range(ndim))
    x = rng.standard_normal(shape) * rng.uniform(0.1, 100)
    eb = rel_eb * (x.max() - x.min())
    xh = decompress(compress(x, eb, interp))
    assert metrics.linf(x, xh) <= eb * (1 + 1e-12)


def test_outlier_escape_channel():
    """Huge outliers (escape channel) must still satisfy the bound exactly."""
    x = smooth_field((40, 40))
    x[13, 17] = 1e15
    x[0, 0] = -1e15
    eb = 1e-7
    xh = decompress(compress(x, eb, CUBIC))
    assert metrics.linf(x, xh) <= eb


def test_f32_input_roundtrip():
    x = smooth_field((50, 60)).astype(np.float32)
    eb = 1e-3
    xh = decompress(compress(x, eb))
    assert xh.dtype == np.float32
    assert metrics.linf(x, xh) <= eb + 1e-6  # f32 cast slack


# ------------------------------------------------------------ progressive

def test_progressive_error_bounds_hold():
    x = smooth_field((48, 48, 48))
    buf = compress(x, 1e-6, CUBIC)
    r = open_archive(buf)
    st_ = None
    prev_bytes = 0
    for E in (1e-1, 1e-2, 1e-3, 1e-4, 1e-5):
        out, st_ = retrieve(r, error_bound=E, state=st_)
        assert metrics.linf(x, out) <= E, f"bound {E} violated"
        assert st_.err_bound <= E
        assert st_.bytes_read >= prev_bytes  # refinement only adds data
        prev_bytes = st_.bytes_read


def test_progressive_adversarial_noise():
    rng = np.random.default_rng(7)
    x = rng.standard_normal((37, 53)) * 50
    buf = compress(x, 1e-3, CUBIC)
    for E in (10.0, 1.0, 1e-1, 1e-2):
        out, _ = retrieve(buf, error_bound=E)
        assert metrics.linf(x, out) <= E


def test_refine_equals_scratch():
    x = smooth_field((32, 40, 24))
    buf = compress(x, 1e-5, CUBIC)
    r = open_archive(buf)
    out_a, st_ = retrieve(r, error_bound=1e-1)
    out_a, st_ = retrieve(r, error_bound=1e-3, state=st_)
    out_a, st_ = retrieve(r, state=st_)           # full
    out_b = decompress(buf)
    np.testing.assert_allclose(out_a, out_b, atol=1e-12)


def test_single_pass_retrieval_volume():
    """Partial retrieval must touch strictly less data than the archive."""
    x = smooth_field((48, 48, 48))
    buf = compress(x, 1e-6, CUBIC)
    out, st_ = retrieve(buf, error_bound=1e-2)
    assert 0 < st_.bytes_read < len(buf)


def test_bitrate_mode_respects_budget():
    x = smooth_field((48, 48, 48))
    buf = compress(x, 1e-6, CUBIC)
    n = x.size
    for target_bpp in (0.5, 1.0, 2.0, 4.0):
        out, st_ = retrieve(buf, bitrate=target_bpp)
        got_bpp = 8 * st_.bytes_read / n
        assert got_bpp <= target_bpp * 1.05 + 0.2
        # fidelity should improve with bitrate
    errs = []
    for target_bpp in (0.5, 1.0, 2.0, 4.0):
        out, _ = retrieve(buf, bitrate=target_bpp)
        errs.append(metrics.linf(x, out))
    assert errs == sorted(errs, reverse=True) or errs[-1] <= errs[0]


def test_arbitrary_error_bounds_supported():
    """IPComp supports arbitrary eb (vs residual baselines' fixed ladder)."""
    x = smooth_field((40, 40))
    buf = compress(x, 1e-7, CUBIC)
    rng = np.random.default_rng(3)
    for _ in range(10):
        E = 10 ** rng.uniform(-6.5, -1)
        out, _ = retrieve(buf, error_bound=E)
        assert metrics.linf(x, out) <= E


# ------------------------------------------------------------ DP loader

def _tiny_meta():
    x = smooth_field((32, 32))
    buf = compress(x, 1e-5, CUBIC)
    return parse_meta(buf), buf, x


def test_dp_plan_feasible_and_brute_force_competitive():
    m, buf, x = _tiny_meta()
    for E in (1e-1, 1e-2, 1e-3):
        plan = loader.plan_error_mode(m, E, loader.SAFE)
        assert plan.err_bound <= E
    # brute force over small level subsets to confirm DP near-optimality
    import itertools
    E = 1e-2
    plan = loader.plan_error_mode(m, E, loader.SAFE)
    errs, sizes = loader._level_cost_tables(m, loader.SAFE)
    best = None
    nl = len(m.levels)
    choices = [range(lv.nbits + 1) for lv in m.levels]
    if np.prod([len(c) for c in choices]) <= 200000:
        for combo in itertools.product(*choices):
            e = m.eb + sum(float(errs[i][b]) for i, b in enumerate(combo))
            if e <= E:
                sz = sum(int(sizes[i][b]) for i, b in enumerate(combo))
                if best is None or sz < best:
                    best = sz
        assert best is not None
        # DP discretization costs at most a few % extra volume
        assert plan.loaded_bytes <= best * 1.10 + 4096


def test_dp_bitrate_plan_within_budget():
    m, buf, x = _tiny_meta()
    total = m.total_size
    _, sizes = loader._level_cost_tables(m, loader.SAFE)
    min_bytes = sum(int(s[-1]) for s in sizes)  # escape channels only
    prev_err = None
    for frac in (0.05, 0.2, 0.5, 0.8, 1.0):
        S = max(int(total * frac), min_bytes)
        plan = loader.plan_bitrate_mode(m, S, loader.SAFE)
        assert plan.loaded_bytes <= S
        if prev_err is not None:
            assert plan.err_bound <= prev_err + 1e-15  # more budget, less error
        prev_err = plan.err_bound
