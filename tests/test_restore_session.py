"""RestoreSession: coarse-first restore, refine-reads-only-the-delta
accounting, grouped decode dispatch counts, the background refiner, and
remote (HTTP-range) restore parity with the local path."""
import os
import threading

import numpy as np
import pytest

from repro.checkpoint import Bundle, LeafSpec, RestoreSession, read_full, \
    write_bundle
from repro.core.bytesource import CountingSource, FileSource
from repro.core.container import CorruptArchiveError
from repro.core.pipeline.spec import ExecPolicy
from repro.kernels import dispatch

REL_EB = 1e-5
WEIGHT_ERR = 1e-2


def smooth(shape, seed=0):
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.standard_normal(shape), axis=-1).astype(np.float32)


def build(tmp_path, n_big=3, name="s.ckpt", lossless_small=4096):
    leaves = {f"blocks.{i}.w": smooth((64, 256), i) for i in range(n_big)}
    leaves["norm.scale"] = np.linspace(0.5, 1.5, 48).astype(np.float32)
    specs = [LeafSpec(lid=k, arr=v, dtype="float32", raw_nbytes=v.nbytes)
             for k, v in leaves.items()]
    path = os.path.join(str(tmp_path), name)
    man = write_bundle(path, specs, step=3, rel_eb=REL_EB, interp="cubic",
                       lossless_small=lossless_small)
    return leaves, path, man


# ----------------------------------------------------------- semantics

def test_coarse_then_refine_to_full(tmp_path):
    leaves, path, man = build(tmp_path)
    with RestoreSession(Bundle.open(path)) as s:
        coarse = s.restore(WEIGHT_ERR)
        coarse_bytes = s.bytes_read
        assert 0 < coarse_bytes < os.path.getsize(path)
        for lid, ref in leaves.items():
            rng_v = float(ref.max() - ref.min()) or 1.0
            tol = 0.0 if man["leaves"][lid]["kind"] == "raw" \
                else WEIGHT_ERR * rng_v * 1.001
            assert np.max(np.abs(coarse[lid] - ref)) <= tol
        full = s.restore(None)
        assert s.bytes_read > coarse_bytes
        assert s.achieved_bound <= REL_EB * max(
            float(v.max() - v.min()) for v in leaves.values()) * 1.001
    # progressive full == the one-shot verified full restore, bit for bit
    with Bundle.open(path) as b:
        direct = read_full(b)
    for lid in leaves:
        np.testing.assert_array_equal(full[lid], direct[lid])


def test_refine_reads_exactly_the_missing_planes(tmp_path):
    _, path, _ = build(tmp_path)
    with RestoreSession(Bundle.open(path)) as s:
        s.restore(WEIGHT_ERR)
        pos0 = s.ladder_positions()
        b0 = s.bytes_read
        s.restore(WEIGHT_ERR)               # same bound: no new bytes
        assert s.bytes_read == b0
        s.restore(None)
        pos1 = s.ladder_positions()
        delta = s.bytes_read - b0
        assert delta == s.plane_bytes_between(pos0, pos1)
        assert delta > 0
        b1 = s.bytes_read
        s.restore(None)                     # already full: no re-reads
        assert s.bytes_read == b1


def test_looser_request_never_shrinks_prefix(tmp_path):
    leaves, path, _ = build(tmp_path)
    with RestoreSession(Bundle.open(path)) as s:
        full = s.restore(None)
        full_bytes = s.bytes_read
        loose = s.restore(1.0)              # way looser than what's loaded
        assert s.bytes_read == full_bytes   # no new reads...
        for lid in leaves:                  # ...and no precision lost
            np.testing.assert_array_equal(loose[lid], full[lid])


def test_raw_leaf_zero_bound_and_manifest_read_once(tmp_path):
    leaves, path, _ = build(tmp_path)
    src = CountingSource(FileSource(path))
    with RestoreSession(Bundle.open(src)) as s:
        for we in (WEIGHT_ERR, 1e-3, None):
            out = s.restore(we)
            np.testing.assert_array_equal(out["norm.scale"],
                                          leaves["norm.scale"])
            assert s.leaf_bounds["norm.scale"] == 0.0   # honest zero error
        raw_off = s.bundle.leaf_region("norm.scale")[0]
        reqs = src.requests
    # the manifest is parsed once at open and cached on the session —
    # exactly one read of the manifest region across all three rounds
    assert sum(1 for off, _ in reqs if off == 8) == 1
    # the raw leaf is fetched once and served from cache afterwards
    assert sum(1 for off, _ in reqs if off == raw_off) == 1


def test_closed_session_rejects_restore(tmp_path):
    _, path, _ = build(tmp_path, n_big=1)
    s = RestoreSession(Bundle.open(path))
    s.close()
    with pytest.raises(RuntimeError, match="closed"):
        s.restore(None)


def test_manifest_kind_container_mismatch_detected(tmp_path):
    _, path, _ = build(tmp_path, n_big=1)
    buf = bytearray(open(path, "rb").read())
    b = Bundle.open(path)
    off, _ = b.leaf_region("blocks.0.w")
    b.close()
    buf[off:off + 4] = b"IPC\x01"           # v3 bytes relabeled as v1 framing
    s = RestoreSession(Bundle(bytes(buf)), verify=False)
    with pytest.raises(CorruptArchiveError):
        s.restore(WEIGHT_ERR)


def test_session_detects_corrupt_prefix_on_first_open(tmp_path):
    _, path, _ = build(tmp_path, n_big=2)
    buf = bytearray(open(path, "rb").read())
    b = Bundle.open(path)
    off, _ = b.leaf_region("blocks.1.w")
    b.close()
    buf[off + 8] ^= 0x40                    # inside the verified prefix
    with RestoreSession(Bundle(bytes(buf))) as s:
        with pytest.raises(CorruptArchiveError, match=r"blocks\.1\.w"):
            s.restore(WEIGHT_ERR)


# ------------------------------------------------------- grouped decode

def test_grouped_decode_fewer_dispatches_than_per_leaf():
    pytest.importorskip("jax")
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        _, path, man = build(td, n_big=4, lossless_small=0)
        kinds = {e["kind"] for lid, e in man["leaves"].items()
                 if lid.startswith("blocks.")}
        assert kinds == {"ipc"}, f"expected all-v3 big leaves, got {kinds}"
        pol = ExecPolicy(backend="jax")

        def run(group_leaves):
            with RestoreSession(Bundle.open(path), policy=pol,
                                group_leaves=group_leaves) as s:
                with dispatch.measure() as d:
                    out = s.restore(None)
            return out, sum(d.values())

        grouped, n_grouped = run(True)
        per_leaf, n_per_leaf = run(False)
        # the acceptance gate: equal-shaped leaves share batched kernel
        # launches — strictly fewer dispatches, identical bits
        assert n_grouped < n_per_leaf, (n_grouped, n_per_leaf)
        for lid in grouped:
            np.testing.assert_array_equal(grouped[lid], per_leaf[lid])


# --------------------------------------------------- background refiner

def test_refine_async_publishes_full_tree(tmp_path):
    leaves, path, _ = build(tmp_path)
    with RestoreSession(Bundle.open(path)) as s:
        coarse = s.restore(WEIGHT_ERR)
        frozen = {k: v.copy() for k, v in coarse.items()}
        s.refine_async(None)
        refined = s.refined(timeout=60)
        assert refined is not None and s.done
    with Bundle.open(path) as b:
        direct = read_full(b)
    for lid in leaves:
        # the background refiner converges to the one-shot full restore
        np.testing.assert_array_equal(refined[lid], direct[lid])
        # double-buffered: the coarse tree was never mutated
        np.testing.assert_array_equal(coarse[lid], frozen[lid])


def test_refiner_failure_surfaces_in_poll(tmp_path):
    _, path, _ = build(tmp_path, n_big=1)
    s = RestoreSession(Bundle.open(path))
    s.restore(WEIGHT_ERR)
    s.bundle.source.close()                 # pull the rug under the refiner
    t = s.refine_async(None)
    t.join(30)
    with pytest.raises(Exception):
        s.refined()
    s.closed = True                         # source already gone


def test_exact_leaves_restore_full_in_coarse_round(tmp_path):
    """Leaves matching the ``exact`` predicate decode at full precision
    in the coarse round (a restart's optimizer moments must never be
    approximated — near-zero entries flip sign under a range-relative
    bound), while non-matching leaves stay coarse."""
    leaves, path, _ = build(tmp_path)
    with RestoreSession(Bundle.open(path)) as ref:
        full = ref.restore(None)
    exact_lid = "blocks.0.w"
    s = RestoreSession(Bundle.open(path),
                       exact=lambda lid: lid == exact_lid)
    with s:
        assert s.leaf_bound(exact_lid, WEIGHT_ERR) is None
        assert s.leaf_bound("blocks.1.w", WEIGHT_ERR) is not None
        coarse = s.restore(WEIGHT_ERR)
        coarse_bytes = s.bytes_read
        np.testing.assert_array_equal(coarse[exact_lid], full[exact_lid])
        assert not np.array_equal(coarse["blocks.1.w"], full["blocks.1.w"])
        # refine still only fetches the OTHER leaves' missing planes
        pos0 = s.ladder_positions()
        out = s.restore(None)
        assert s.bytes_read - coarse_bytes \
            == s.plane_bytes_between(pos0, s.ladder_positions())
    for lid in leaves:
        np.testing.assert_array_equal(out[lid], full[lid])


def test_unflatten_hook_applied(tmp_path):
    leaves, path, _ = build(tmp_path, n_big=1)
    order = sorted(leaves)
    s = RestoreSession(Bundle.open(path),
                       unflatten=lambda d: [d[k] for k in order])
    with s:
        out = s.restore(None)
    assert isinstance(out, list) and len(out) == len(order)


# -------------------------------------------------------------- remote

@pytest.mark.network
def test_remote_restore_bit_identical_with_fault(tmp_path):
    from tests.range_server import ServerFault, serve
    leaves, path, _ = build(tmp_path)
    payload = open(path, "rb").read()
    with RestoreSession(Bundle.open(path)) as s:
        local_coarse = s.restore(WEIGHT_ERR)
        local_full = s.restore(None)
    with serve(payload, faults=[ServerFault("drop", at=2)]) as srv:
        with RestoreSession(Bundle.open(srv.url, timeout=2.0,
                                        backoff=0.01)) as s:
            remote_coarse = s.restore(WEIGHT_ERR)
            s.refine_async(None)
            remote_full = s.refined(timeout=60)
        gets = [r for m, r in srv.log if m == "GET"]
    assert len(gets) >= 3                   # the dropped GET was retried
    for lid in leaves:
        np.testing.assert_array_equal(remote_coarse[lid], local_coarse[lid])
        np.testing.assert_array_equal(remote_full[lid], local_full[lid])


@pytest.mark.network
def test_remote_restore_persistent_failure_raises(tmp_path):
    from repro.core.remote import RemoteError
    from tests.range_server import ServerFault, serve
    _, path, _ = build(tmp_path, n_big=1)
    payload = open(path, "rb").read()
    with serve(payload,
               faults=[ServerFault("status", at=0, arg=503,
                                   persist=True)]) as srv:
        with pytest.raises(RemoteError):
            Bundle.open(srv.url, timeout=1.0, retries=2, backoff=0.01)
