"""ProgressiveReader session semantics (paper §4, Algorithm 2 as an object).

The session owns what the legacy API made callers hand-carry — the
container reader and the RetrievalState — so these tests pin the object
behaviors the free functions could not express: independent sessions
over one Archive, refine monotonicity through the session accessors,
lazy fidelity-ladder iteration, and no-op behavior on looser targets.
Mid-session *policy* swaps are pinned in ``test_policy_matrix.py``.

Runs warning-clean (new API only); the CI deprecation lane enforces it.
"""
import numpy as np
import pytest

from _fields import smooth_field
from repro import Archive, Codec, ExecPolicy, Fidelity
from repro.core import metrics

X = smooth_field((40, 30), seed=1)


@pytest.fixture(params=[None, 300], ids=["v1", "v2"])
def archive(request):
    return Codec(eb=1e-6, chunk_elems=request.param).compress(X)


def test_fresh_session_state(archive):
    s = archive.open()
    assert s.data is None
    assert s.bytes_read == 0
    assert s.achieved_bound == float("inf")
    assert s.archive is archive


def test_refine_monotonicity(archive):
    """Down a fidelity ladder: achieved bounds non-increasing and honored,
    bytes_read non-decreasing, data always the latest reconstruction."""
    s = archive.open()
    last_bound, last_read = float("inf"), 0
    for e in (1e-1, 1e-2, 1e-3, 1e-4, 1e-5):
        out = s.refine(Fidelity.error_bound(e))
        assert metrics.linf(X, out) <= e
        assert s.achieved_bound <= min(e, last_bound)
        assert s.bytes_read >= last_read
        assert out is s.data
        last_bound, last_read = s.achieved_bound, s.bytes_read
    exact = s.read()                      # default = Fidelity.full()
    assert metrics.linf(X, exact) <= archive.eb


def test_looser_target_is_a_noop(archive):
    """Refinement never drops planes: a looser request after a tight one
    fetches nothing and keeps the achieved bound."""
    s = archive.open()
    tight = s.read(Fidelity.error_bound(1e-4))
    read, bound = s.bytes_read, s.achieved_bound
    loose = s.read(Fidelity.error_bound(1e-1))
    assert np.array_equal(tight, loose)
    assert s.bytes_read == read and s.achieved_bound == bound


def test_sessions_are_independent(archive):
    """Each open() gets its own reader and state: progress in one session
    costs and changes nothing in another."""
    a, b = archive.open(), archive.open()
    a.read(Fidelity.error_bound(1e-4))
    assert b.bytes_read == 0 and b.data is None
    out_b = b.read(Fidelity.error_bound(1e-2))
    assert metrics.linf(X, out_b) <= 1e-2
    assert a.bytes_read >= b.bytes_read
    # refining b is unaffected by a's deeper position; both sessions meet
    # the bound (their loaded plane unions differ by path, so exact bytes
    # may too — that is Algorithm 2, not leakage between sessions)
    b.read(Fidelity.error_bound(1e-4))
    assert metrics.linf(X, b.data) <= 1e-4
    assert metrics.linf(X, a.data) <= 1e-4


def test_session_equals_oneshot(archive):
    """Refining stepwise lands where a cold full read lands: at full
    precision the plan is every plane, so the loaded set — and the byte
    accounting — match exactly; the cascade sum is equal to float
    accumulation order (the contract test_progressive_monotonicity pins
    for the legacy surface)."""
    stepped = archive.open()
    for fid in (Fidelity.max_bytes(1200), Fidelity.error_bound(1e-3),
                Fidelity.full()):
        stepped.read(fid)
    cold = archive.open()
    out = cold.read(Fidelity.full())
    assert stepped.bytes_read == cold.bytes_read
    assert stepped.achieved_bound == cold.achieved_bound
    np.testing.assert_allclose(stepped.data, out, atol=1e-12)


def test_ladder_iteration(archive):
    """ladder() yields (fidelity, data) per rung, lazily."""
    fids = [Fidelity.error_bound(e) for e in (1e-2, 1e-3, 1e-4)]
    s = archive.open()
    seen = []
    for fid, out in s.ladder(fids):
        assert metrics.linf(X, out) <= fid.value
        seen.append(fid)
    assert seen == fids

    # lazy: breaking early stops fetching
    s2 = archive.open()
    it = s2.ladder(iter(fids))
    next(it)
    partial = s2.bytes_read
    assert partial < s.bytes_read
    next(it)
    assert s2.bytes_read > partial


def test_byte_budget_fidelities(archive):
    """Growing max_bytes rungs refine monotonically (the DP spends only
    the planned plane bytes; anchors/escapes ride on top, so bytes_read
    tracks but is not capped by the budget — the legacy contract)."""
    s = archive.open()
    prev_bound, prev_read = float("inf"), 0
    for budget in (800, 1600, 3200):
        s.read(Fidelity.max_bytes(budget))
        assert s.achieved_bound <= prev_bound
        assert s.bytes_read >= prev_read
        prev_bound, prev_read = s.achieved_bound, s.bytes_read
    assert s.achieved_bound < float("inf")


def test_policy_setter_validates(archive):
    s = archive.open()
    with pytest.raises(TypeError, match="ExecPolicy"):
        s.policy = "jax"
    s.policy = ExecPolicy(backend="numpy", batch_chunks=False)
    assert s.policy.batch_chunks is False


def test_propagation_is_session_wide():
    """open(propagation=) pins the planner's propagation model for every
    rung of the session.  SAFE (default) actually guarantees the bound;
    PAPER uses Theorem 1's smaller amplification factors, so it plans no
    more bytes than SAFE (and, per the repro findings, may overshoot the
    true error — which is why it is opt-in)."""
    arc = Codec(eb=1e-6).compress(X)
    safe = arc.open(propagation="safe")
    paper = arc.open(propagation="paper")
    out = safe.read(Fidelity.error_bound(1e-3))
    assert metrics.linf(X, out) <= 1e-3
    paper.read(Fidelity.error_bound(1e-3))
    assert paper.bytes_read <= safe.bytes_read
    assert paper.achieved_bound < float("inf")
