"""The first-class object API: Codec / Archive / Fidelity / ExecPolicy.

Construction-time validation, serialization round-trips, parity with the
legacy free functions (same bytes, same bits), and the hardened container
error paths (CorruptArchiveError on unknown magic / truncation at every
header boundary).  Session behavior lives in
``test_progressive_reader.py``; policy invariance in
``test_policy_matrix.py``.
"""
import struct

import numpy as np
import pytest

from _fields import smooth_field
from repro import (Archive, Codec, CorruptArchiveError, ExecPolicy,
                   Fidelity, IPCompDeprecationWarning, ProgressiveReader)
from repro.core import CUBIC, LINEAR, compress, decompress, metrics, retrieve
from repro.core import container


X = smooth_field((40, 30))


def _legacy(fn, *a, **kw):
    """Run a legacy shim, swallowing exactly its deprecation warning."""
    with pytest.warns(IPCompDeprecationWarning):
        return fn(*a, **kw)


# ------------------------------------------------------------------- Codec

def test_codec_validation():
    with pytest.raises(ValueError, match="positive"):
        Codec(eb=0.0)
    with pytest.raises(ValueError, match="positive"):
        Codec(eb=-1e-3)
    with pytest.raises(ValueError, match="interpolator"):
        Codec(eb=1e-4, interp="quintic")
    with pytest.raises(ValueError, match="chunk_elems"):
        Codec(eb=1e-4, chunk_elems=0)
    # frozen + hashable: usable as a cache key
    assert Codec(eb=1e-4) == Codec(eb=1e-4)
    assert hash(Codec(eb=1e-4)) == hash(Codec(eb=1e-4))
    with pytest.raises(AttributeError):
        Codec(eb=1e-4).eb = 2e-4


@pytest.mark.parametrize("chunk_elems", [None, 300])
def test_codec_matches_legacy_bytes(chunk_elems):
    """Codec.compress is the legacy compress, re-housed: same bytes."""
    arc = Codec(eb=1e-5, chunk_elems=chunk_elems).compress(X)
    legacy = _legacy(compress, X, 1e-5, chunk_elems=chunk_elems)
    assert arc.tobytes() == legacy


def test_codec_relative_and_interp():
    rng = float(X.max() - X.min())
    arc = Codec(eb=1e-4, relative=True, interp=LINEAR).compress(X)
    assert arc.eb == pytest.approx(1e-4 * rng)
    assert arc.interp == LINEAR
    out = arc.open().read()
    assert metrics.linf(X, out) <= arc.eb


# ----------------------------------------------------------------- Archive

def test_archive_views_and_roundtrip(tmp_path):
    arc = Codec(eb=1e-5).compress(X)
    assert arc.shape == X.shape and arc.dtype == X.dtype
    assert arc.eb == 1e-5 and arc.interp == CUBIC
    assert not arc.chunked and arc.n_chunks == 1
    assert arc.nbytes == len(arc.tobytes()) == len(arc)

    assert Archive.frombytes(arc.tobytes()) == arc
    assert hash(Archive.frombytes(arc.tobytes())) == hash(arc)

    p = tmp_path / "field.ipc"
    arc.save(p)
    assert Archive.load(p) == arc

    v2 = Codec(eb=1e-5, chunk_elems=300).compress(X)
    assert v2.chunked and v2.n_chunks > 1
    assert v2 != arc
    assert "v2" in repr(v2) and "v1" in repr(arc)

    # sessions share the Archive's validated header (no re-parse) while
    # keeping independent byte accounting
    a, b = v2.open(), v2.open()
    assert a._reader.meta is b._reader.meta
    a.read(Fidelity.error_bound(1e-2))
    assert a.bytes_read > 0 and b.bytes_read == 0


def test_archive_readable_by_legacy_functions():
    """Archive bytes are ordinary container bytes: the legacy surface and
    any pre-existing archive interoperate both ways."""
    arc = Codec(eb=1e-5, chunk_elems=300).compress(X)
    out, _ = _legacy(retrieve, arc.tobytes(), error_bound=1e-3)
    assert metrics.linf(X, out) <= 1e-3
    legacy_buf = _legacy(compress, X, 1e-5)
    assert np.array_equal(Archive(legacy_buf).open().read(),
                          _legacy(decompress, legacy_buf))


# ---------------------------------------------------------------- Fidelity

def test_fidelity_sum_type():
    assert Fidelity.error_bound(1e-3).kind == "error_bound"
    assert Fidelity.max_bytes(100).value == 100
    assert Fidelity.bitrate(2.0).kind == "bitrate"
    assert Fidelity.full().value is None
    # over-specification is unrepresentable through constructors and a
    # clear error through the legacy-coercion path
    with pytest.raises(ValueError, match="at most one"):
        Fidelity.from_targets(error_bound=1e-3, max_bytes=100)
    assert Fidelity.from_targets() == Fidelity.full()
    assert Fidelity.from_targets(bitrate=2.0) == Fidelity.bitrate(2.0)

    with pytest.raises(ValueError, match="positive"):
        Fidelity.error_bound(0)
    with pytest.raises(ValueError, match="positive"):
        Fidelity.bitrate(-1)
    with pytest.raises(ValueError, match="non-negative integer"):
        Fidelity.max_bytes(-5)
    with pytest.raises(ValueError, match="no value"):
        Fidelity("full", 3.0)
    with pytest.raises(ValueError, match="needs a value"):
        Fidelity("error_bound")
    with pytest.raises(ValueError, match="unknown fidelity"):
        Fidelity("psnr", 40.0)

    # fractional byte budgets are rejected, not silently truncated, and
    # whole floats normalize so both spellings compare equal
    with pytest.raises(ValueError, match="non-negative integer"):
        Fidelity.max_bytes(1000.7)
    assert Fidelity.max_bytes(64.0) == Fidelity.max_bytes(64)
    assert Fidelity.max_bytes(64.0).value == 64

    # bitrate converts exactly as the legacy path did
    assert Fidelity.bitrate(2.0).target_bytes(1000) == 250
    assert Fidelity.max_bytes(77).target_bytes(10) == 77
    assert Fidelity.full().target_bytes(10) is None
    assert eval(repr(Fidelity.max_bytes(64)),
                {"Fidelity": Fidelity}) == Fidelity.max_bytes(64)


# -------------------------------------------------------------- ExecPolicy

def test_exec_policy_validation():
    with pytest.raises(ValueError, match="unknown backend"):
        ExecPolicy(backend="cuda")
    with pytest.raises(ValueError, match="batch_chunks"):
        ExecPolicy(batch_chunks="yes")
    with pytest.raises(ValueError, match="shard must be"):
        ExecPolicy(shard="always")
    # frozen: policies are shareable values
    with pytest.raises(AttributeError):
        ExecPolicy().backend = "jax"
    # "auto" backends/meshes are symbolic until bind time
    assert ExecPolicy(backend="auto").backend == "auto"
    assert ExecPolicy(shard="auto").unsharded().shard is None


def test_exec_policy_mesh_contradictions():
    jax = pytest.importorskip("jax")
    from repro.parallel import codec_mesh
    mesh = codec_mesh.codec_mesh(1)
    # the archive-independent contradiction fails at CONSTRUCTION
    with pytest.raises(ValueError, match="stacked shape-group"):
        ExecPolicy(shard=mesh, batch_chunks=False)
    # the archive-dependent rule fails at bind time: v1 has no chunk grid
    pol = ExecPolicy(backend="jax", shard=mesh)
    with pytest.raises(ValueError, match="chunk grid"):
        Archive(Codec(eb=1e-4).compress(X).tobytes()).open(pol).read()
    # "auto" degrades quietly in the same situation
    out = Codec(eb=1e-4).compress(X).open(
        ExecPolicy(backend="jax", shard="auto")).read(
        Fidelity.error_bound(1e-2))
    assert metrics.linf(X, out) <= 1e-2


# ------------------------------------------------- decompress signature fix

def test_decompress_accepts_retrieve_kwargs():
    """Signature-drift regression: decompress takes the same execution
    kwargs as retrieve (batch_chunks included) and routes through the
    object API."""
    buf = _legacy(compress, X, 1e-5, chunk_elems=300)
    base = _legacy(decompress, buf)
    assert np.array_equal(base, _legacy(decompress, buf,
                                        batch_chunks=False))
    assert np.array_equal(base, _legacy(decompress, buf, backend="numpy",
                                        shard=None, batch_chunks=None))
    assert metrics.linf(X, base) <= 1e-5


# ------------------------------------------------- hardened container paths

def _v1():
    return Codec(eb=1e-5).compress(X).tobytes()


def _v2():
    return Codec(eb=1e-5, chunk_elems=300).compress(X).tobytes()


@pytest.mark.parametrize("make", [_v1, _v2], ids=["v1", "v2"])
def test_truncation_at_each_header_boundary(make):
    """Every framing boundary fails as CorruptArchiveError, not struct /
    json noise: magic, header-length word, header body, blob section."""
    buf = make()
    (hlen,) = struct.unpack("<I", buf[4:8])
    boundaries = [0, 2,            # inside the magic
                  4, 6,            # inside the header-length word
                  8, 8 + hlen // 2,  # inside the header JSON
                  8 + hlen + 1]    # inside the blob section
    for cut in boundaries:
        with pytest.raises(CorruptArchiveError):
            Archive(buf[:cut])
        with pytest.raises(CorruptArchiveError):
            container.open_reader(buf[:cut])


def test_unknown_magic_and_garbage():
    for junk in (b"", b"IP", b"ZSTD" + b"\0" * 64, b"IPC9" + b"\0" * 64):
        with pytest.raises(CorruptArchiveError, match="magic|truncated"):
            Archive(junk)
    # undecodable header JSON
    bad = container.MAGIC + struct.pack("<I", 4) + b"\xff\xfe\xfd\xfc"
    with pytest.raises(CorruptArchiveError, match="undecodable"):
        Archive(bad)
    # decodable JSON, wrong schema
    bad = container.MAGIC + struct.pack("<I", 2) + b"[]"
    with pytest.raises(CorruptArchiveError, match="malformed|expected an"):
        Archive(bad)


def test_corrupt_archive_error_is_a_value_error():
    """Compatibility: pre-existing ``except ValueError`` handling (and
    the historical parse_meta v2-dispatch error) keep working."""
    assert issubclass(CorruptArchiveError, ValueError)
    with pytest.raises(ValueError):
        container.parse_meta(_v2())  # v2 buffer through the v1 parser


def test_header_internal_inconsistency():
    """A decodable header whose tables contradict each other (nbits vs
    plane lists vs delta table, anchors size vs shape) fails at Archive
    construction, not as an IndexError mid-retrieval."""
    import json
    buf = _v1()
    (hlen,) = struct.unpack("<I", buf[4:8])
    h = json.loads(buf[8:8 + hlen].decode())

    def rebuild(hh):
        hj = json.dumps(hh, separators=(",", ":")).encode()
        return container.MAGIC + struct.pack("<I", len(hj)) + hj \
            + buf[8 + hlen:]

    bad = json.loads(json.dumps(h))
    bad["levels"][0]["nbits"] += 1            # planes no longer match
    with pytest.raises(CorruptArchiveError, match="nbits"):
        Archive(rebuild(bad))
    bad = json.loads(json.dumps(h))
    bad["levels"][-1]["delta_table"] = bad["levels"][-1]["delta_table"][:-1]
    with pytest.raises(CorruptArchiveError, match="delta table"):
        Archive(rebuild(bad))
    bad = json.loads(json.dumps(h))
    bad["anchors_shape"] = [s + 1 for s in bad["anchors_shape"]]
    with pytest.raises(CorruptArchiveError, match="anchors"):
        Archive(rebuild(bad))


def test_read_rejects_bare_numbers():
    """The likeliest migration slip — session.read(1e-3) instead of
    read(Fidelity.error_bound(1e-3)) — is a clear TypeError at the
    session boundary, not an AttributeError inside the planner."""
    s = Codec(eb=1e-4).compress(X).open()
    with pytest.raises(TypeError, match="Fidelity"):
        s.read(1e-3)
    with pytest.raises(TypeError, match="Fidelity"):
        s.refine("full")


def test_corrupt_chunk_table_extents():
    """A v2 header whose chunk extent points outside the buffer fails at
    parse time, not as a short read mid-retrieval."""
    buf = _v2()
    # drop the last 8 bytes of the final chunk's archive
    with pytest.raises(CorruptArchiveError, match="extent"):
        Archive(buf[:-8])
