"""Serving-tier graceful degradation: retry budget, PARTIAL settlement,
and non-poisoned refine chains (ISSUE 9 tentpole c).

The contract pinned here: transient transport failures consume a
per-request retry budget and re-plan from committed state; an exhausted
budget settles the request ``partial`` at its last fully decoded rung —
bit-exact, bound-honest, chainable — and permanent errors (corruption,
planner rejections) still fail immediately.
"""
import numpy as np
import pytest

from _fields import smooth_field
from repro.api import Archive, Codec, Fidelity
from repro.core.faults import Fault, FaultInjectingSource
from repro.serving.server import (DONE, FAILED, PARTIAL, RetrievalServer,
                                  _retryable)
from repro.core.remote import (RemoteProtocolError, RemoteReadError)

X = smooth_field((60, 40), seed=7)
EB = 1e-5
V3 = Codec(eb=EB, chunk_elems=600, version=3).compress(X).tobytes()
V2 = Codec(eb=EB, chunk_elems=600).compress(X).tobytes()

_no_sleep = lambda s: None  # noqa: E731


def _server(buf, archive_id="a", **kw):
    fif = FaultInjectingSource(buf, sleep=_no_sleep)
    srv = RetrievalServer(**kw)
    srv.add_archive(archive_id, Archive.from_source(fif))
    return srv, fif


# ---------------------------------------------------------- classification

def test_retryable_classification():
    assert _retryable(ConnectionError("reset"))
    assert _retryable(TimeoutError())
    assert _retryable(RemoteReadError("out of retries"))
    assert not _retryable(RemoteProtocolError("HTTP 404"))
    assert not _retryable(ValueError("planner says no"))


# ------------------------------------------------------------ retry paths

def test_transient_fault_retries_to_done():
    srv, fif = _server(V3, retry_budget=2)
    fif.arm(Fault("error"))                       # one-shot, first read
    req = srv.submit("a", Fidelity.error_bound(1e-3))
    srv.drain()
    assert req.status == DONE and req.retries == 1
    assert np.abs(req.result - X).max() <= 1e-3
    assert srv.stats()["retries"] == 1 and srv.stats()["partial"] == 0


def test_retry_replans_from_committed_state():
    """A fault mid-refine must not lose the rungs already committed: the
    retry re-plans from ladder_pos, and the final bits match a fault-free
    session stepping the same rungs."""
    ref = Archive.frombytes(V3).open()
    srv, fif = _server(V3, retry_budget=3)
    parent = srv.submit("a", Fidelity.error_bound(1e-1))
    srv.drain()
    ref.read(Fidelity.error_bound(1e-1))
    fif.arm(Fault("error"))                       # breaks the refine once
    child = srv.submit("a", Fidelity.error_bound(1e-4), refine_of=parent)
    srv.drain()
    assert child.status == DONE and child.retries == 1
    assert np.array_equal(child.result, ref.read(Fidelity.error_bound(1e-4)))


def test_exhausted_budget_settles_partial_at_last_rung():
    srv, fif = _server(V3, retry_budget=2)
    parent = srv.submit("a", Fidelity.error_bound(1e-1))
    srv.drain()
    assert parent.status == DONE
    fif.arm(Fault("error", persist=True))         # source goes dark
    child = srv.submit("a", Fidelity.error_bound(1e-4), refine_of=parent)
    srv.drain()
    assert child.status == PARTIAL
    assert child.retries == 2
    assert "retry budget exhausted" in child.error
    # settled at the parent's rung: same bits, same honest bound
    assert np.array_equal(child.result, parent.result)
    assert child.err_bound == parent.err_bound
    assert np.abs(child.result - X).max() <= child.err_bound
    assert srv.stats()["partial"] == 1


def test_fresh_request_with_no_rung_fails_outright():
    """Nothing achieved -> FAILED, not a bogus empty partial."""
    srv, fif = _server(V3, retry_budget=1)
    fif.arm(Fault("error", persist=True))
    req = srv.submit("a", Fidelity.error_bound(1e-2))
    srv.drain()
    assert req.status == FAILED
    assert req.result is None
    assert "retry budget exhausted" in req.error


def test_partial_parent_is_chainable():
    """Degradation never poisons the chain: children refine from the
    partial parent's achieved rung once the source heals."""
    srv, fif = _server(V3, retry_budget=1)
    parent = srv.submit("a", Fidelity.error_bound(1e-1))
    srv.drain()
    fif.arm(Fault("error", persist=True))
    mid = srv.submit("a", Fidelity.error_bound(1e-4), refine_of=parent)
    srv.drain()
    assert mid.status == PARTIAL
    fif.schedule.clear()                          # source heals
    child = srv.submit("a", Fidelity.error_bound(1e-4), refine_of=mid)
    srv.drain()
    assert child.status == DONE
    assert np.abs(child.result - X).max() <= 1e-4
    assert child.bytes_read >= mid.bytes_read


def test_failed_parent_still_fails_children():
    srv, fif = _server(V3, retry_budget=0)
    fif.arm(Fault("error", persist=True))
    parent = srv.submit("a", Fidelity.error_bound(1e-2))
    child = srv.submit("a", Fidelity.error_bound(1e-4), refine_of=parent)
    srv.drain()
    assert parent.status == FAILED
    assert child.status == FAILED and "refine parent" in child.error


def test_permanent_errors_do_not_consume_retries():
    """Planner rejections fail immediately, budget untouched."""
    srv, _ = _server(V3, retry_budget=5)
    req = srv.submit("a", Fidelity.error_bound(EB / 100))  # below archive eb
    srv.drain()
    assert req.status == FAILED and req.retries == 0
    assert srv.stats()["retries"] == 0


def test_per_request_budget_overrides_server_default():
    srv, fif = _server(V3, retry_budget=5)
    fif.arm(Fault("error", persist=True))
    req = srv.submit("a", Fidelity.error_bound(1e-2), retry_budget=1)
    srv.drain()
    assert req.status == FAILED and req.retries == 1


def test_v2_transient_fault_also_retries():
    """The budget covers v2's scattered per-chunk reads too (faults fire
    inside decode_group, not prefix staging)."""
    srv, fif = _server(V2, retry_budget=2)
    fif.arm(Fault("error"))
    req = srv.submit("a", Fidelity.error_bound(1e-3))
    srv.drain()
    assert req.status == DONE and req.retries == 1
    assert np.abs(req.result - X).max() <= 1e-3


def test_faulty_request_does_not_disturb_neighbors():
    """Tick isolation: a request driven partial by its source leaves
    same-tick requests on a healthy archive untouched."""
    good = FaultInjectingSource(V3, sleep=_no_sleep)
    bad = FaultInjectingSource(V3, sleep=_no_sleep)
    srv = RetrievalServer(retry_budget=1)
    srv.add_archive("good", Archive.from_source(good))
    srv.add_archive("bad", Archive.from_source(bad))
    bad.arm(Fault("error", persist=True))
    r_bad = srv.submit("bad", Fidelity.error_bound(1e-3))
    r_good = srv.submit("good", Fidelity.error_bound(1e-3))
    srv.drain()
    assert r_good.status == DONE
    assert np.abs(r_good.result - X).max() <= 1e-3
    assert r_bad.status == FAILED


def test_drain_counts_retry_ticks_as_progress():
    """A tick that only re-queues retries must not trip the stall guard."""
    srv, fif = _server(V3, retry_budget=3)
    fif.arm(Fault("error", at=0, persist=True))
    req = srv.submit("a", Fidelity.error_bound(1e-2))
    settled = srv.drain()                          # no RuntimeError
    assert [r.req_id for r in settled] == [req.req_id]
    assert srv.ticks == 4                          # 1 first try + 3 retries


def test_stats_and_repr_surface_degradation():
    srv, fif = _server(V3, retry_budget=0)
    fif.arm(Fault("error", persist=True))
    srv.submit("a", Fidelity.error_bound(1e-2))
    srv.drain()
    s = srv.stats()
    assert s["failed"] == 1 and s["retry_budget"] == 0
    assert "partial" in repr(srv)


def test_pipeline_truncation_is_permanent():
    """A truncating source is corruption, not a transient: no retry."""
    srv, fif = _server(V3, retry_budget=5)
    parent = srv.submit("a", Fidelity.error_bound(1e-1))
    srv.drain()
    assert parent.status == DONE
    fif.arm(Fault("truncate", arg=1, persist=True))
    child = srv.submit("a", Fidelity.error_bound(1e-4), refine_of=parent)
    srv.drain()
    assert child.status == FAILED and child.retries == 0
    assert "CorruptArchiveError" in child.error
