"""IPC3 plane-major container: layout invariants, streaming access
pattern, and corruption rejection (docs/format.md §3).

The headline claim pinned here: a Fidelity ladder over a v3 archive
issues monotonically increasing contiguous byte ranges — asserted through
``CountingSource`` range accounting, not inferred from the layout.
"""
import json
import struct

import numpy as np
import pytest

from _fields import smooth_field
from repro.api import Archive, Codec, CorruptArchiveError, Fidelity
from repro.core import container, loader
from repro.core.bytesource import CountingSource
from repro.core.container import (MAGIC3, V3ArchiveReader, V3Meta,
                                  parse_v3_meta)

X = smooth_field((60, 40), seed=7)
EB = 1e-5
LADDER = [1e-1, 1e-2, 1e-3, 1e-4, 1e-5]


def _v3(chunk_elems=600):
    return Codec(eb=EB, chunk_elems=chunk_elems, version=3).compress(X)


def _v2(chunk_elems=600):
    return Codec(eb=EB, chunk_elems=chunk_elems).compress(X)


# ----------------------------------------------------------------- layout

def test_v3_round_trip_and_bound():
    a = _v3()
    assert a.version == 3 and a.chunked and a.n_chunks > 1
    out = a.open().read()
    assert np.abs(out - X).max() <= EB


def test_v3_full_read_bit_identical_to_v2():
    """The framing regroups identical per-chunk streams: cold full reads
    of v2 and v3 archives of one array are bit-identical."""
    assert np.array_equal(_v2().open().read(), _v3().open().read())


def test_v3_segments_tile_contiguously_in_ladder_order():
    m = _v3()._meta
    assert isinstance(m, V3Meta)
    cursor = m.header_end
    for s in m.segments:
        assert s.offset == cursor
        cursor += s.size
    assert cursor == m.total_size
    # base region (anchors + escapes) strictly precedes every plane segment
    kinds = [s.kind for s in m.segments]
    assert kinds[0] == "anchors"
    assert "planes" not in kinds[:kinds.index("planes")]
    # within a level, plane segments are MSB-first
    per_level = {}
    for s in m.plane_segments:
        assert s.plane == per_level.get(s.level, -1) + 1
        per_level[s.level] = s.plane


def test_v3_matches_write_time_ladder_order():
    m = _v3()._meta
    order = loader.ladder_order(m.chunk_metas)
    assert [(s.level, s.plane) for s in m.plane_segments] == order


def test_ladder_keeps_clamps_to_chunk_nbits():
    m = _v3()._meta
    T = len(m.plane_segments)
    keeps_full = m.ladder_keeps(T)
    assert keeps_full == [[lv.nbits for lv in cm.levels]
                          for cm in m.chunk_metas]
    assert m.ladder_keeps(0) == [[0] * len(cm.levels)
                                 for cm in m.chunk_metas]
    # monotone, per-chunk bounded prefix growth
    prev = m.ladder_keeps(0)
    for t in range(1, T + 1):
        cur = m.ladder_keeps(t)
        for pc, cc, cm in zip(prev, cur, m.chunk_metas):
            assert all(c >= p for p, c in zip(pc, cc))
            assert all(c <= lv.nbits for c, lv in zip(cc, cm.levels))
        prev = cur


def test_cum_bytes_matches_segment_sizes():
    m = _v3()._meta
    esc = sum(s.size for s in m.segments if s.kind == "escapes")
    assert m.cum_bytes[0] == esc
    for t, s in enumerate(m.plane_segments):
        assert m.cum_bytes[t + 1] == m.cum_bytes[t] + s.size


# -------------------------------------------- the streaming access pattern

def test_fidelity_ladder_reads_monotone_contiguous_ranges():
    """THE v3 claim: refining through a fidelity ladder issues monotone
    byte ranges whose data-section portion coalesces to ONE contiguous
    run — no per-chunk scatter, no re-seeks."""
    a = _v3()
    cs = CountingSource(a.tobytes())
    s = Archive.from_source(cs).open()
    he = a._meta.header_end
    for E in LADDER:
        out = s.read(Fidelity.error_bound(E))
        assert np.abs(out - X).max() <= E
    assert cs.monotone()
    data_reqs = [r for r in cs.requests if r[0] >= he]
    runs = CountingSource(b"")
    runs.requests = data_reqs
    assert len(runs.coalesced()) == 1
    start, size = runs.coalesced()[0]
    assert start == he                      # the run starts at the base region


def test_each_refine_issues_at_most_one_data_read():
    a = _v3()
    cs = CountingSource(a.tobytes())
    s = Archive.from_source(cs).open()
    he = a._meta.header_end
    for E in LADDER:
        before = len([r for r in cs.requests if r[0] >= he])
        s.read(Fidelity.error_bound(E))
        after = len([r for r in cs.requests if r[0] >= he])
        assert after - before <= 1


def test_refine_never_rereads_and_looser_target_noops():
    a = _v3()
    s = a.open()
    s.read(Fidelity.error_bound(1e-3))
    br = s.bytes_read
    pos = s._state.ladder_pos
    out = s.read(Fidelity.error_bound(1e-1))          # looser: no-op
    assert s.bytes_read == br and s._state.ladder_pos == pos
    assert np.abs(out - X).max() <= 1e-3              # keeps the finer data
    s.read(Fidelity.error_bound(1e-5))
    assert s._state.ladder_pos >= pos


def test_ensure_prefix_stages_one_contiguous_read():
    a = _v3()
    cs = CountingSource(a.tobytes())
    r = V3ArchiveReader(cs)
    he = r.meta.header_end
    T = len(r.meta.plane_segments)
    cs.reset()
    r.ensure_prefix(T // 2)
    data = [q for q in cs.requests if q[0] >= he]
    assert len(data) == 1 and data[0][0] == he
    r.ensure_prefix(T // 2)                           # already staged: no-op
    r.ensure_prefix(T // 4)                           # shrink: no-op
    assert len([q for q in cs.requests if q[0] >= he]) == 1
    r.ensure_prefix(T)
    data = [q for q in cs.requests if q[0] >= he]
    assert len(data) == 2
    assert data[1][0] == data[0][0] + data[0][1]      # gap read, contiguous


def test_forks_share_the_staged_prefix():
    """Fork accounting is independent, but the staged transport buffer is
    shared: a branch never re-fetches ranges its sibling staged."""
    a = _v3()
    cs = CountingSource(a.tobytes())
    s = Archive.from_source(cs).open()
    s.read(Fidelity.error_bound(1e-3))
    n = cs.n_requests
    from repro.core.pipeline.state import fork_state
    st2 = fork_state(s._state)
    assert st2.ladder_pos == s._state.ladder_pos
    assert st2.bytes_read == s._state.bytes_read
    assert cs.n_requests == n                         # forking fetched nothing


# ------------------------------------------------------------ plan modes

def test_ladder_bitrate_mode_respects_budget():
    a = _v3()
    m = a._meta
    for frac in (0.1, 0.3, 0.7, 1.0):
        budget = int(m.cum_bytes[-1] * frac) + m.cum_bytes[0]
        t = loader.ladder_bitrate_mode(m, budget)
        assert m.cum_bytes[t] <= budget
        if t < len(m.plane_segments):
            assert m.cum_bytes[t + 1] > budget        # maximal prefix
    # t_min floors the plan
    assert loader.ladder_bitrate_mode(m, m.cum_bytes[0], t_min=5) == 5


def test_ladder_error_mode_bounds_and_floor():
    m = _v3()._meta
    with pytest.raises(ValueError, match="compression bound"):
        loader.ladder_error_mode(m, EB / 10)
    t_loose = loader.ladder_error_mode(m, 1e-2)
    t_tight = loader.ladder_error_mode(m, 1e-4)
    assert 0 < t_loose <= t_tight <= len(m.plane_segments)
    assert loader.ladder_error_mode(m, 1e-2, t_min=t_tight) == t_tight


def test_max_bytes_session_stays_within_budget():
    a = _v3()
    budget = a.nbytes // 3
    s = a.open()
    s.read(Fidelity.max_bytes(budget))
    assert s.bytes_read <= budget


# ------------------------------------------------------- serving tier (v3)

def test_server_serves_v3_with_shared_cache():
    from repro.serving.cache import PlaneCache
    from repro.serving.server import RetrievalServer

    a = _v3()
    srv = RetrievalServer(cache=PlaneCache())
    srv.add_archive("a", a)
    r1 = srv.submit("a", Fidelity.error_bound(1e-2))
    r2 = srv.submit("a", Fidelity.error_bound(1e-4))
    srv.drain()
    assert r1.status == "done" and np.abs(r1.result - X).max() <= 1e-2
    assert r2.status == "done" and np.abs(r2.result - X).max() <= 1e-4
    # bit parity with a private uncached session at the same fidelity
    assert np.array_equal(a.open().read(Fidelity.error_bound(1e-2)),
                          r1.result)
    # refine chain advances the ladder without re-reading
    r3 = srv.submit("a", Fidelity.error_bound(1e-5), refine_of=r2)
    srv.drain()
    assert r3.status == "done" and np.abs(r3.result - X).max() <= 1e-5
    assert r3.bytes_read >= r2.bytes_read
    assert r3._state.ladder_pos >= r2._state.ladder_pos


def test_server_v3_requests_read_monotone_ranges():
    from repro.serving.server import RetrievalServer

    buf = _v3().tobytes()
    cs = CountingSource(buf)
    srv = RetrievalServer()
    srv.add_archive("a", Archive.from_source(cs))
    parent = srv.submit("a", Fidelity.error_bound(1e-1))
    srv.drain()
    for E in (1e-2, 1e-3, 1e-4):
        parent = srv.submit("a", Fidelity.error_bound(E), refine_of=parent)
        srv.drain()
    assert parent.status == "done"
    assert cs.monotone()


# ---------------------------------------------------- corruption rejection

def _mutate(buf: bytes, fn):
    """Round-trip the v3 header JSON through ``fn`` and reframe."""
    (hlen,) = struct.unpack("<I", buf[4:8])
    h = json.loads(buf[8:8 + hlen].decode())
    fn(h)
    hj = json.dumps(h, separators=(",", ":")).encode()
    pad = hlen - len(hj)
    if pad < 0:
        raise AssertionError("mutation grew the header; offsets would shift")
    # keep the header length identical so blob offsets stay valid
    hj = hj[:-1] + b" " * pad + hj[-1:]
    return MAGIC3 + struct.pack("<I", hlen) + hj + buf[8 + hlen:]


def test_v3_rejects_non_contiguous_segments():
    buf = _v3().tobytes()

    def gap(h):
        h["segments"][2]["offset"] += 1
    with pytest.raises(CorruptArchiveError, match="contiguous|expected"):
        Archive(_mutate(buf, gap))


def test_v3_rejects_plane_order_violation():
    buf = _v3().tobytes()

    def swap(h):
        planes = [i for i, s in enumerate(h["segments"])
                  if s["kind"] == "planes"]
        a, b = planes[0], planes[1]
        # swap the (level, plane) identities but keep extents in place
        for k in ("kind", "level", "plane"):
            h["segments"][a][k], h["segments"][b][k] = \
                h["segments"][b][k], h["segments"][a][k]
    with pytest.raises(CorruptArchiveError):
        Archive(_mutate(buf, swap))


def test_v3_rejects_base_segment_after_planes():
    buf = _v3().tobytes()

    def demote(h):
        segs = h["segments"]
        planes = [i for i, s in enumerate(segs) if s["kind"] == "planes"]
        esc = [i for i, s in enumerate(segs) if s["kind"] == "escapes"]
        # relabel a plane segment in the tail as an escapes segment
        segs[planes[-1]]["kind"] = "escapes"
        segs[planes[-1]]["plane"] = -1
        segs[esc[0]]["kind"] = "planes"
    with pytest.raises(CorruptArchiveError):
        Archive(_mutate(buf, demote))


def test_v3_rejects_blob_outside_its_segment():
    buf = _v3().tobytes()

    def stray(h):
        # relocate a plane blob into the anchors segment: in bounds, but
        # outside the (level, plane) segment that should contain it
        ch = h["chunk_headers"][0]["levels"][0]
        ch["plane_offsets"][0] = h["segments"][0]["offset"]
    with pytest.raises(CorruptArchiveError, match="segment"):
        Archive(_mutate(buf, stray))


def test_v3_rejects_truncation_everywhere():
    buf = _v3().tobytes()
    (hlen,) = struct.unpack("<I", buf[4:8])
    for cut in (0, 2, 4, 6, 8, 8 + hlen // 2, 8 + hlen + 1, len(buf) - 3):
        with pytest.raises(CorruptArchiveError):
            Archive(buf[:cut])


def test_v3_rejects_wrong_parser_and_magic():
    v3 = _v3().tobytes()
    with pytest.raises(ValueError, match="v3|plane-major|IPC3"):
        container.parse_meta(v3)
    with pytest.raises(CorruptArchiveError, match="magic"):
        parse_v3_meta(_v2().tobytes())


def test_v3_single_chunk_without_chunk_elems():
    """version=3 without chunk_elems frames the whole array as one chunk
    — still a valid, ladder-ordered v3 archive."""
    a = Codec(eb=1e-4, version=3).compress(X)
    assert a.version == 3 and a.n_chunks == 1
    assert np.abs(a.open().read() - X).max() <= 1e-4


def test_codec_version_validation():
    with pytest.raises(ValueError, match="version"):
        Codec(eb=1e-4, version=4)
    with pytest.raises(ValueError, match="chunks"):
        Codec(eb=1e-4, chunk_elems=100, version=1)
    with pytest.raises(ValueError, match="chunk_elems"):
        Codec(eb=1e-4, version=2)
