"""Progressive invariants under the chunked (v2) container.

Per-chunk error bounds, refine-only-reads-new-planes accounting, v1
backward compatibility, and the chunk framing itself.
"""
import numpy as np
import pytest

from _fields import smooth_field
from repro.core import (CUBIC, ChunkedRetrievalState, chunk_bounds, compress,
                        decompress, metrics, open_archive, refine, retrieve)
from repro.core.container import (MAGIC, MAGIC2, ArchiveReader,
                                  ChunkedArchiveReader, parse_meta)
from repro.core.pipeline import refine_budgets, split_budget


# ------------------------------------------------------------ framing

def test_chunk_bounds_cover_axis0():
    assert chunk_bounds((10,), 3) == [(0, 3), (3, 6), (6, 9), (9, 10)]
    assert chunk_bounds((5, 7), 14) == [(0, 2), (2, 4), (4, 5)]
    assert chunk_bounds((4, 100), 10) == [(0, 1), (1, 2), (2, 3), (3, 4)]
    assert chunk_bounds((3,), 1000) == [(0, 3)]
    with pytest.raises(ValueError):
        chunk_bounds((10,), 0)


def test_chunk_bounds_rejects_0d_and_empty():
    """0-d / empty inputs fail with a clear ValueError, not IndexError."""
    with pytest.raises(ValueError, match="0-d"):
        chunk_bounds((), 4)
    with pytest.raises(ValueError, match="empty"):
        chunk_bounds((0,), 4)
    with pytest.raises(ValueError, match="empty"):
        chunk_bounds((5, 0, 3), 4)
    with pytest.raises(ValueError, match="0-d"):
        compress(np.float64(1.5), 1e-3, chunk_elems=4)
    with pytest.raises(ValueError, match="empty"):
        compress(np.zeros((0, 8)), 1e-3, chunk_elems=4)


# ------------------------------------------------------- budget splitting

def test_split_budget_sums_exactly():
    """Regression for the floor-division remainder loss: every allocation
    sums to precisely the requested total."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        k = int(rng.integers(1, 12))
        weights = rng.integers(1, 10 ** 6, k).tolist()
        total = int(rng.integers(0, 10 ** 7))
        parts = split_budget(total, weights)
        assert len(parts) == k
        assert sum(parts) == total
        assert all(p >= 0 for p in parts)


def test_split_budget_proportional_and_deterministic():
    assert split_budget(1000, [1, 1]) == [500, 500]
    assert split_budget(7, [1, 1, 1]) == [3, 2, 2]      # remainder ties: first
    assert split_budget(0, [3, 5]) == [0, 0]
    assert split_budget(10, []) == []
    # floor would give [0, 0, 0] and drop everything
    assert sum(split_budget(2, [10 ** 9, 10 ** 9, 10 ** 9])) == 2


def test_split_budget_rejects_degenerate_inputs():
    """Regression: a zero-sum weight vector used to produce NaN quotas and
    crash inside np.floor(...).astype; negative totals fell through to
    nonsense allocations.  Both are clear ValueErrors now."""
    with pytest.raises(ValueError, match="positive sum"):
        split_budget(100, [0, 0, 0])
    with pytest.raises(ValueError, match="non-negative"):
        split_budget(-5, [1, 2])
    with pytest.raises(ValueError, match="non-negative"):
        split_budget(10, [3, -1])
    assert split_budget(10, []) == []            # empty stays legal
    assert split_budget(7, [0, 1]) == [0, 7]     # zero weights are fine


def test_retrieve_rejects_overspecified_targets():
    """Regression: the docstring says "exactly one of" error_bound /
    max_bytes / bitrate, but retrieve silently preferred error_bound when
    several were passed.  Over-specification is a ValueError on v1 and
    chunked archives and on refine."""
    x = smooth_field((40, 30))
    v1 = compress(x, 1e-5)
    v2 = compress(x, 1e-5, chunk_elems=300)
    for buf in (v1, v2):
        with pytest.raises(ValueError, match="error_bound, max_bytes"):
            retrieve(buf, error_bound=1e-3, max_bytes=1000)
        with pytest.raises(ValueError, match="bitrate"):
            retrieve(buf, max_bytes=1000, bitrate=2.0)
        with pytest.raises(ValueError, match="at most one"):
            retrieve(buf, error_bound=1e-3, max_bytes=1000, bitrate=2.0)
        # single targets (and none at all) still work
        retrieve(buf, error_bound=1e-3)
        retrieve(buf, max_bytes=1000)
        retrieve(buf, bitrate=2.0)
        retrieve(buf)
    _, st = retrieve(v2, error_bound=1e-2)
    with pytest.raises(ValueError, match="at most one"):
        refine(st, error_bound=1e-4, bitrate=1.0)


def test_refine_budgets_subtracts_spent_bytes():
    """Unit regression for the refine re-split: chunks keep what they read
    and only the remainder is distributed."""
    # fresh state: identical to a plain split
    assert refine_budgets(100, [1, 1], [0, 0]) == split_budget(100, [1, 1])
    # chunk 0 already read 150 of a 300 refine: it still gets half of the
    # remaining 140 on top — the old full re-split gave it 150, a no-op
    assert refine_budgets(300, [1, 1], [150, 10]) == [220, 80]
    # budget already exhausted: plans stay pinned at what is loaded
    assert refine_budgets(100, [1, 1], [80, 40]) == [80, 40]
    # proportionality applies to the remainder, not the total
    assert refine_budgets(260, [3, 1], [100, 100]) == [145, 115]


def test_refine_budgets_reserves_floors_first():
    """Per-chunk plan floors (escape channels) are allocated before the
    proportional split, so a globally feasible total never starves an
    escape-heavy chunk below its floor — and an infeasible total raises a
    clear error instead of failing deep inside one chunk's DP."""
    # pure proportional would give chunk 0 only 40 < its 90-byte floor
    assert refine_budgets(120, [1, 1, 1], [0, 0, 0],
                          floors=[90, 0, 0]) == [100, 10, 10]
    # spent above the floor already covers the reservation
    assert refine_budgets(120, [1, 1], [50, 10],
                          floors=[30, 0]) == [80, 40]
    # exhausted budget with floors covered: plans stay at what's loaded
    assert refine_budgets(50, [1, 1], [40, 20], floors=[10, 0]) == [40, 20]
    with pytest.raises(ValueError, match="infeasible"):
        refine_budgets(80, [1, 1, 1], [0, 0, 0], floors=[90, 0, 0])


def test_chunked_refine_byte_budget_feeds_overspent_chunks():
    """End-to-end regression: chunk 0 is far less compressible, so an
    error-bound retrieval loads it well past its element-proportional
    share.  A byte-budget refine must still deliver NEW planes to chunk 0
    instead of handing it a from-scratch plan below its loaded prefix."""
    rng = np.random.default_rng(5)
    x = smooth_field((60, 33), 1)
    x[:30] += 10 * rng.standard_normal((30, 33))  # rough half
    buf = compress(x, 1e-7, chunk_elems=30 * 33)  # 2 chunks, equal elements
    out, st = retrieve(open_archive(buf), error_bound=1e-5)
    spent = [cs.bytes_read for cs in st.chunk_states]
    grow = 800
    # precondition for the old bug: re-splitting the full cumulative budget
    # 50/50 would hand chunk 0 LESS than it already read — a silent no-op
    assert spent[0] > (sum(spent) + grow) // 2
    out, st = refine(st, max_bytes=sum(spent) + grow)
    new = [cs.bytes_read - s for cs, s in zip(st.chunk_states, spent)]
    # the fix splits only the *new* budget: both chunks make progress
    assert new[0] > 0 and new[1] > 0
    # and the refine stays within the cumulative request
    assert st.bytes_read <= sum(spent) + grow


def test_chunked_max_bytes_budget_fully_allocated():
    """End to end: per-chunk budgets of a v2 bitrate retrieval cover the
    whole request (the old floor split dropped len(chunks)-1 bytes)."""
    x = smooth_field((10, 101), 8)   # 1010 elements: 3 chunks of 404/404/202
    buf = compress(x, 1e-6, CUBIC, chunk_elems=404)
    r = open_archive(buf)
    sub_ns = [r.chunk_reader(i).meta.n_elements
              for i in range(len(r.meta.chunks))]
    for total in (1001, 997, 64):
        parts = split_budget(total, sub_ns)
        assert sum(parts) == total
    out, st = retrieve(buf, max_bytes=3000)
    assert metrics.linf(x, out) < 1e-1


def test_v2_magic_and_reader_dispatch():
    x = smooth_field((64, 32))
    v1 = compress(x, 1e-4)
    v2 = compress(x, 1e-4, chunk_elems=512)
    assert v1[:4] == MAGIC and v2[:4] == MAGIC2
    assert isinstance(open_archive(v1), ArchiveReader)
    r2 = open_archive(v2)
    assert isinstance(r2, ChunkedArchiveReader)
    assert len(r2.meta.chunks) == 4
    # chunk interiors are complete v1 archives
    cm = r2.meta.chunks[1]
    sub = parse_meta(v2[cm.offset: cm.offset + cm.size])
    assert sub.shape == [16, 32]
    with pytest.raises(ValueError):
        parse_meta(v2)  # v2 needs the chunked reader


def test_v1_archive_roundtrips_through_new_reader():
    """Old (unchunked) archives keep working end to end."""
    x = smooth_field((48, 40))
    buf = compress(x, 1e-5, CUBIC)          # v1 is still the default
    assert buf[:4] == MAGIC
    assert metrics.linf(x, decompress(buf)) <= 1e-5
    r = open_archive(buf)
    out, st = retrieve(r, error_bound=1e-2)
    out, st = retrieve(r, error_bound=1e-4, state=st)
    assert metrics.linf(x, out) <= 1e-4


# ------------------------------------------------------- error bounds

@pytest.mark.parametrize("shape,chunk", [((3000,), 700), ((96, 50), 1000),
                                         ((24, 20, 18), 2000)])
def test_chunked_roundtrip_and_error_mode(shape, chunk):
    x = smooth_field(shape)
    eb = 1e-5
    buf = compress(x, eb, CUBIC, chunk_elems=chunk)
    assert metrics.linf(x, decompress(buf)) <= eb
    for E in (1e-1, 1e-3):
        out, st = retrieve(buf, error_bound=E)
        assert metrics.linf(x, out) <= E
        assert st.err_bound <= E


def test_error_bound_honored_per_chunk():
    """Every chunk's planned bound (not just the global max-err) obeys E."""
    x = smooth_field((90, 40), 5)
    buf = compress(x, 1e-6, CUBIC, chunk_elems=1200)
    out, st = retrieve(buf, error_bound=1e-3)
    assert isinstance(st, ChunkedRetrievalState)
    bounds = [cs.err_bound for cs in st.chunk_states]
    assert all(b <= 1e-3 for b in bounds)
    # and per-chunk reconstruction actually meets it
    for cm, cs in zip(st.reader.meta.chunks, st.chunk_states):
        sub = x[cm.start:cm.stop]
        assert metrics.linf(sub, cs.xhat) <= 1e-3


# ---------------------------------------------------- refine accounting

def test_refine_never_rereads_loaded_planes():
    """Progressive refinement to full precision reads exactly the bytes a
    cold full retrieval would — cached plane fetches are not re-counted."""
    x = smooth_field((80, 44), 2)
    buf = compress(x, 1e-6, CUBIC, chunk_elems=900)
    r = open_archive(buf)
    st = None
    prev = 0
    for E in (1e-1, 1e-2, 1e-4):
        out, st = retrieve(r, error_bound=E, state=st)
        assert st.bytes_read >= prev
        prev = st.bytes_read
    # repeat at the same bound: no new bytes
    out, st = retrieve(r, error_bound=1e-4, state=st)
    assert st.bytes_read == prev
    out, st = retrieve(r, state=st)         # finish to full precision
    cold_out, cold_st = retrieve(open_archive(buf))
    assert st.bytes_read == cold_st.bytes_read
    # Algorithm 2's delta cascade accumulates float rounding vs scratch
    # (same tolerance as test_refine_equals_scratch on v1 archives)
    np.testing.assert_allclose(out, cold_out, atol=1e-12)


def test_chunked_partial_retrieval_volume():
    x = smooth_field((96, 48), 3)
    buf = compress(x, 1e-7, CUBIC, chunk_elems=1024)
    out, st = retrieve(buf, error_bound=1e-2)
    assert 0 < st.bytes_read < len(buf)


def test_chunked_bitrate_mode_budget_and_monotonicity():
    x = smooth_field((64, 64), 4)
    buf = compress(x, 1e-7, CUBIC, chunk_elems=1024)
    errs = []
    for bpp in (0.5, 1.0, 2.0, 4.0):
        out, st = retrieve(buf, bitrate=bpp)
        assert 8 * st.bytes_read / x.size <= bpp * 1.05 + 0.2
        errs.append(metrics.linf(x, out))
    assert errs[-1] <= errs[0]


def test_chunked_backend_jax_progressive():
    """The acceptance path: jax-compressed chunked archive, numpy decode."""
    x = smooth_field((72, 36), 6)
    buf = compress(x, 1e-6, CUBIC, backend="jax", chunk_elems=800)
    r = open_archive(buf)
    out, st = retrieve(r, error_bound=1e-2)
    b1 = st.bytes_read
    out, st = retrieve(r, error_bound=1e-5, state=st)
    assert st.bytes_read > b1
    assert metrics.linf(x, out) <= 1e-5
